#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md): build, test, format check.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
# Advisory until the tree has been run through rustfmt once (the seed
# predates the gate); flip to a hard failure after that cleanup PR.
cargo fmt --check || echo "WARN: rustfmt differences (advisory for now)"
# Advisory for the same reason: the seed tree has never been linted in a
# toolchain environment. Flip to a hard failure (drop the `|| echo`)
# once the pre-existing findings, if any, are cleaned up.
cargo clippy --all-targets -- -D warnings \
    || echo "WARN: clippy findings (advisory until the tree is lint-clean)"
echo "verify OK"
