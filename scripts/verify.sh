#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md): build, test, format check,
# lint, and the architecture open-closed gate.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
# Advisory until the tree has been run through rustfmt once (the seed
# predates the gate); flip to a hard failure after that cleanup PR.
cargo fmt --check || echo "WARN: rustfmt differences (advisory for now)"
# Hard gate since the model-layer PR linted the tree (PR 2 introduced it
# as advisory).
cargo clippy --all-targets -- -D warnings

# Architecture open-closed gate: per-architecture dispatch must live in
# the model/ cost-model impls only. A `Architecture::X =>` match arm
# anywhere else reintroduces the scattered fan-outs the model subsystem
# removed.
if grep -rn --include='*.rs' -E \
    'Architecture::[A-Za-z_]+[[:space:]]*=>' \
    rust/src rust/tests rust/benches examples \
    | grep -v '^rust/src/model/'; then
  echo "FAIL: per-architecture match arm outside rust/src/model/" >&2
  exit 1
fi

# Serving open-closed gate: PJRT construction is the serve layer's
# business — the InferenceBackend trait exists so no other layer welds
# itself to the XLA artifacts. Everything else opens runtimes through
# serve::open_runtime (and serving goes through a registered backend).
if grep -rn --include='*.rs' -E \
    'Runtime::new\(|PjRtClient::' \
    rust/src rust/tests rust/benches examples \
    | grep -vE '^rust/src/(serve|runtime)/'; then
  echo "FAIL: direct PJRT runtime construction outside rust/src/serve/" >&2
  exit 1
fi

# Event-core gate: the ladder queue is the production scheduler; the
# std BinaryHeap lives on only as the differential-testing reference in
# event/refqueue.rs. A heap anywhere else in event/ means the hot path
# regressed to O(log n) scattered sift-downs. (Matches real uses —
# `BinaryHeap<..>` / `collections::BinaryHeap` — not doc mentions of
# the BinaryHeapQueue reference type.)
if grep -rn --include='*.rs' -E \
    'collections::BinaryHeap|BinaryHeap<|BinaryHeap::' \
    rust/src/event \
    | grep -v '^rust/src/event/refqueue.rs'; then
  echo "FAIL: BinaryHeap in rust/src/event/ outside refqueue.rs" >&2
  exit 1
fi

# Scenario open-closed gate: main.rs dispatches through the scenario
# registry only. A literal-command match arm ("simulate" => ...) there
# reintroduces the hand-rolled per-experiment fan-out the scenario
# subsystem removed; new experiments register in scenario/registry.rs.
if grep -nE '"[A-Za-z0-9_-]+"[[:space:]]*=>' rust/src/main.rs; then
  echo "FAIL: scenario-specific match arm in rust/src/main.rs" >&2
  exit 1
fi

# Thread-factory gate: util/pool.rs is the crate's only thread factory
# (persistent workers, the spawn-per-call baseline, on_fresh_thread);
# the serve layer keeps its long-lived coordinator/batcher threads. A
# thread::spawn / thread::scope anywhere else bypasses the pool's
# nesting guard and determinism contract — route the work through
# pool::map / for_each_indexed / on_fresh_thread instead.
if grep -rn --include='*.rs' -E \
    'thread::spawn|thread::scope' \
    rust/src rust/tests rust/benches examples \
    | grep -vE '^rust/src/(util/pool\.rs|serve/)'; then
  echo "FAIL: thread::spawn/thread::scope outside rust/src/util/pool.rs" \
       "and rust/src/serve/ — use util::pool" >&2
  exit 1
fi

# Fleet open-closed gate: chip-selection policy dispatch lives in
# serve/fleet.rs only. A `RouterPolicy::X =>` match arm anywhere else
# means a caller is special-casing a policy instead of going through
# run_fleet — new policies register inside the fleet module.
if grep -rn --include='*.rs' -E \
    'RouterPolicy::[A-Za-z_]+[[:space:]]*=>' \
    rust/src rust/tests rust/benches examples \
    | grep -v '^rust/src/serve/fleet.rs'; then
  echo "FAIL: router-policy match arm outside rust/src/serve/fleet.rs" >&2
  exit 1
fi

# Offload open-closed gate: per-placement dispatch lives in the offload
# subsystem and the NPU cost model only. A `Placement::X =>` match arm
# anywhere else means a caller is special-casing the hybrid split
# instead of using Placement::is_npu / the offload search — the same
# scattered fan-out the Architecture gate prevents.
if grep -rn --include='*.rs' -E \
    'Placement::[A-Za-z_]+[[:space:]]*=>' \
    rust/src rust/tests rust/benches examples \
    | grep -vE '^rust/src/(offload/|model/archs\.rs)'; then
  echo "FAIL: placement match arm outside rust/src/offload/ and" \
       "rust/src/model/archs.rs — use Placement::is_npu" >&2
  exit 1
fi

# Diagnostics gate: stderr chatter goes through the leveled obs::diag!
# macro (gated by --verbose / NEURAL_PIM_LOG), never raw eprintln!.
# Only the macro's own expansion site (obs/) and the CLI's final error
# reporter (main.rs) may call it directly.
if grep -rn --include='*.rs' 'eprintln!' rust/src \
    | grep -vE '^rust/src/(obs/|main\.rs)'; then
  echo "FAIL: raw eprintln! outside rust/src/obs/ and main.rs —" \
       "use crate::diag!(level, ...)" >&2
  exit 1
fi

echo "verify OK"
