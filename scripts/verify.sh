#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md): build, test, format check.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
# Advisory until the tree has been run through rustfmt once (the seed
# predates the gate); flip to a hard failure after that cleanup PR.
cargo fmt --check || echo "WARN: rustfmt differences (advisory for now)"
echo "verify OK"
