#!/usr/bin/env python3
"""Reference port of the analytical Fig. 12 pipeline (pre-`model` refactor).

Mirrors, expression for expression, the Rust chain
`workloads -> mapping -> energy budgets -> sim::simulate ->
SystemComparison::{energy,throughput}_ratio` as it stood before the
trait-based `model` subsystem was extracted, and prints the four headline
geomeans. `rust/tests/golden_fig12.rs` pins the refactored simulator to
these values within 1e-9 relative tolerance, so any behavioural drift in
the refactor (as opposed to a pure reorganization) fails the suite.

Every arithmetic expression keeps the operand order of the Rust original:
Python floats are the same IEEE-754 doubles, so faithful transcription
agrees to far better than the 1e-9 gate.
"""

import math

# --- energy/constants.rs ---------------------------------------------------
ADC_E_CONV_8B = 1.5625e-12
def adc_e_conv(bits):
    return ADC_E_CONV_8B * 2.0 ** (bits - 8)
CASCADE_ADC_E_CONV = ADC_E_CONV_8B
ADC_AREA_8B = 0.0015
def adc_area(bits):
    return ADC_AREA_8B * 2.0 ** (bits - 8)
NNADC_E_CONV = 1.25e-12
NNADC_AREA = 1.2e-3
DAC_E_CYCLE_1B = 0.39e-12
def dac_e_cycle(bits):
    return DAC_E_CYCLE_1B * 2.0 ** (0.55 * (bits - 1.0))
DAC_AREA_1B = 5.25e-7 / 3.14
def dac_area(bits):
    return DAC_AREA_1B * 2.0 ** (0.55 * (bits - 1.0))
XBAR_E_CYCLE_128 = 30e-12
def xbar_e_cycle(size, _pd):
    return XBAR_E_CYCLE_128 * (size / 128.0) ** 2
def xbar_area(size):
    return 2.5e-5 * (size / 128.0) ** 2
SA_DIGITAL_E_OP = 0.156e-12
SA_DIGITAL_AREA = 0.00024
NNSA_E_OP = 3.7e-12
NNSA_AREA = 6.9e-4
SH_E_OP = 0.09e-15
SH_AREA = 3.2e-4 / 9216.0
TIA_E_CYCLE = 2e-12
TIA_AREA = 0.0002
BUFFER_WRITE_E = 0.3e-12
BUFFER_ARRAYS_PER_XBAR = 4
SUMAMP_E_CYCLE = 0.5e-12
SUMAMP_AREA = 0.0001
EDRAM_E_BYTE = 1.0e-12
EDRAM_AREA_64KB = 0.083
SRAM_E_BYTE = 0.3e-12
IR_AREA = 0.0021
NP_IR_AREA = 2.4e-2
NOC_E_BYTE = 1.7e-12
ROUTER_AREA = 0.151
HT_POWER = 10.4
HT_AREA = 22.88
HT_E_BYTE = 1.6e-12
ACT_E_OP = 0.05e-12
ACT_AREA = 0.0006
TILE_CTRL_POWER = 0.5e-3
TILE_CTRL_AREA = 0.00145
ISAAC_CYCLE_NS = 100.0
CASCADE_CYCLE_NS = 50.0
NEURAL_PIM_CYCLE_NS = 100.0


def ceil_div(a, b):
    return -(-a // b)


# --- config ----------------------------------------------------------------
class Cfg:
    def __init__(self, arch, p_d, adcs_per_pe, sa_per_array):
        self.arch = arch
        self.p_i, self.p_w, self.p_o, self.p_r, self.p_d = 8, 8, 8, 1, p_d
        self.xbar_size = 128
        self.arrays_per_pe = 64
        self.adcs_per_pe = adcs_per_pe
        self.sa_per_array = sa_per_array
        self.pes_per_tile = 4
        self.tiles = 280
        self.cycle_ns = 100.0
        self.edram_bytes = 64 * 1024
        self.noc_concentration = 4

    def input_cycles(self):
        return ceil_div(self.p_i, self.p_d)

    def weight_cols(self):
        return ceil_div(self.p_w, self.p_r)

    def n_log2(self):
        return self.xbar_size.bit_length() - 1

    def groups_per_array(self):
        return self.xbar_size // (2 * self.weight_cols())

    def total_arrays(self):
        return self.tiles * self.pes_per_tile * self.arrays_per_pe


def for_arch(arch):
    if arch == "isaac":
        return Cfg(arch, 1, 64, 0)
    if arch == "cascade":
        return Cfg(arch, 1, 3, 0)
    return Cfg(arch, 4, 4, 1)


def cycle_seconds(cfg):
    ns = {"isaac": ISAAC_CYCLE_NS, "cascade": CASCADE_CYCLE_NS,
          "np": NEURAL_PIM_CYCLE_NS}[cfg.arch]
    return ns * 1e-9


# --- dataflow equations ----------------------------------------------------
def adc_resolution_a(cfg, n):
    if cfg.p_r > 1 and cfg.p_d > 1:
        return cfg.p_r + cfg.p_d + n
    return cfg.p_r + cfg.p_d - 1 + n


def adc_resolution_b(cfg, n):
    return adc_resolution_a(cfg, n) + math.ceil(math.log2(float(cfg.input_cycles())))


def conversions_a(cfg):
    return cfg.input_cycles() * cfg.weight_cols()


def conversions_b(cfg):
    return cfg.input_cycles() + cfg.weight_cols() - 1


# --- energy budgets (areas only feed the iso-area rule) --------------------
def pe_area(cfg):
    m = cfg.arrays_per_pe
    size = cfg.xbar_size
    wl = size
    cyc = cycle_seconds(cfg)
    comps = [m * xbar_area(size), m * wl * dac_area(cfg.p_d)]
    if cfg.arch == "isaac":
        bits = adc_resolution_a(cfg, cfg.n_log2())
        comps += [cfg.adcs_per_pe * adc_area(bits),
                  m * SA_DIGITAL_AREA,
                  1 * IR_AREA * m / 8.0]
    elif cfg.arch == "cascade":
        bits = adc_resolution_b(cfg, cfg.n_log2())
        comps += [cfg.adcs_per_pe * adc_area(bits),
                  m * BUFFER_ARRAYS_PER_XBAR * xbar_area(size),
                  m * TIA_AREA,
                  m * BUFFER_ARRAYS_PER_XBAR * SUMAMP_AREA,
                  m * SA_DIGITAL_AREA,
                  1 * IR_AREA * m / 8.0]
    else:
        sa_count = max(m * cfg.sa_per_array, 1)
        comps += [cfg.adcs_per_pe * NNADC_AREA,
                  sa_count * NNSA_AREA,
                  (sa_count * 144 // 64) * SH_AREA,
                  1 * NP_IR_AREA * (m / 64.0)]
    _ = cyc
    return sum(comps)


def tile_area(cfg):
    extra = (EDRAM_AREA_64KB * (cfg.edram_bytes / (64.0 * 1024.0))
             + ACT_AREA * cfg.pes_per_tile
             + TILE_CTRL_AREA
             + ROUTER_AREA / cfg.noc_concentration)
    return pe_area(cfg) * cfg.pes_per_tile + extra


def chip_area(cfg):
    return tile_area(cfg) * cfg.tiles + HT_AREA


def iso_area_tiles(cfg, target_area):
    return max(int(math.floor((target_area - HT_AREA) / tile_area(cfg))), 1)


# --- workloads -------------------------------------------------------------
class Layer:
    def __init__(self, kh, kw, cin, cout, out_h, out_w, stride):
        self.kh, self.kw, self.cin, self.cout = kh, kw, cin, cout
        self.out_h, self.out_w, self.stride = out_h, out_w, stride

    def k_dim(self):
        return self.kh * self.kw * self.cin

    def positions(self):
        return self.out_h * self.out_w

    def weights(self):
        return self.k_dim() * self.cout

    def macs(self):
        return self.weights() * self.positions()


def conv(kh, cin, cout, out, stride):
    return Layer(kh, kh, cin, cout, out, out, stride)


def fc(cin, cout):
    return Layer(1, 1, cin, cout, 1, 1, 1)


def lstm(inp, hidden, steps):
    return Layer(1, 1, inp + hidden, 4 * hidden, steps, 1, 1)


def alexnet():
    return [Layer(11, 11, 3, 96, 55, 55, 4),
            Layer(5, 5, 48, 256, 27, 27, 1),
            conv(3, 256, 384, 13, 1),
            conv(3, 192, 384, 13, 1),
            conv(3, 192, 256, 13, 1),
            fc(256 * 6 * 6, 4096), fc(4096, 4096), fc(4096, 1000)]


def vgg(blocks):
    l = []
    chans = [(3, 64, 224), (64, 128, 112), (128, 256, 56), (256, 512, 28),
             (512, 512, 14)]
    for n, (cin, cout, out) in zip(blocks, chans):
        for i in range(n):
            l.append(conv(3, cin if i == 0 else cout, cout, out, 1))
    l += [fc(512 * 7 * 7, 4096), fc(4096, 4096), fc(4096, 1000)]
    return l


def resnet(stage_blocks):
    l = [Layer(7, 7, 3, 64, 112, 112, 2)]
    stages = [(stage_blocks[0], 64, 64, 56, 1),
              (stage_blocks[1], 256, 128, 28, 2),
              (stage_blocks[2], 512, 256, 14, 2),
              (stage_blocks[3], 1024, 512, 7, 2)]
    for blocks, cin, c, out, first_stride in stages:
        cout = 4 * c
        for b in range(blocks):
            ci = cin if b == 0 else cout
            s = first_stride if b == 0 else 1
            l.append(conv(1, ci, c, out, s))
            l.append(conv(3, c, c, out, 1))
            l.append(conv(1, c, cout, out, 1))
            if b == 0:
                l.append(conv(1, ci, cout, out, s))
    l.append(fc(2048, 1000))
    return l


def googlenet():
    l = [Layer(7, 7, 3, 64, 112, 112, 2),
         conv(1, 64, 64, 56, 1), conv(3, 64, 192, 56, 1)]

    def inception(cin, out, c1, c3r, c3, c5r, c5, pp):
        l.append(conv(1, cin, c1, out, 1))
        l.append(conv(1, cin, c3r, out, 1))
        l.append(conv(3, c3r, c3, out, 1))
        l.append(conv(1, cin, c5r, out, 1))
        l.append(Layer(5, 5, c5r, c5, out, out, 1))
        l.append(conv(1, cin, pp, out, 1))

    inception(192, 28, 64, 96, 128, 16, 32, 32)
    inception(256, 28, 128, 128, 192, 32, 96, 64)
    inception(480, 14, 192, 96, 208, 16, 48, 64)
    inception(512, 14, 160, 112, 224, 24, 64, 64)
    inception(512, 14, 128, 128, 256, 24, 64, 64)
    inception(512, 14, 112, 144, 288, 32, 64, 64)
    inception(528, 14, 256, 160, 320, 32, 128, 128)
    inception(832, 7, 256, 160, 320, 32, 128, 128)
    inception(832, 7, 384, 192, 384, 48, 128, 128)
    l.append(fc(1024, 1000))
    return l


def inception_v3():
    l = [conv(3, 3, 32, 149, 2), conv(3, 32, 32, 147, 1),
         conv(3, 32, 64, 147, 1), conv(1, 64, 80, 73, 1),
         conv(3, 80, 192, 71, 1)]
    for i, cin in enumerate([192, 256, 288]):
        l.append(conv(1, cin, 64, 35, 1))
        l.append(conv(1, cin, 48, 35, 1))
        l.append(Layer(5, 5, 48, 64, 35, 35, 1))
        l.append(conv(1, cin, 64, 35, 1))
        l.append(conv(3, 64, 96, 35, 1))
        l.append(conv(3, 96, 96, 35, 1))
        l.append(conv(1, cin, 32 if i == 0 else 64, 35, 1))
    l.append(conv(3, 288, 384, 17, 2))
    l.append(conv(1, 288, 64, 35, 1))
    l.append(conv(3, 64, 96, 35, 1))
    l.append(conv(3, 96, 96, 17, 2))
    for c7 in [128, 160, 160, 192]:
        l.append(conv(1, 768, 192, 17, 1))
        l.append(conv(1, 768, c7, 17, 1))
        l.append(Layer(1, 7, c7, c7, 17, 17, 1))
        l.append(Layer(7, 1, c7, 192, 17, 17, 1))
        l.append(conv(1, 768, c7, 17, 1))
        l.append(Layer(7, 1, c7, c7, 17, 17, 1))
        l.append(Layer(1, 7, c7, c7, 17, 17, 1))
        l.append(Layer(7, 1, c7, c7, 17, 17, 1))
        l.append(Layer(1, 7, c7, 192, 17, 17, 1))
        l.append(conv(1, 768, 192, 17, 1))
    l.append(conv(1, 768, 192, 17, 1))
    l.append(conv(3, 192, 320, 8, 2))
    for cin in [1280, 2048]:
        l.append(conv(1, cin, 320, 8, 1))
        l.append(conv(1, cin, 384, 8, 1))
        l.append(Layer(1, 3, 384, 384, 8, 8, 1))
        l.append(Layer(3, 1, 384, 384, 8, 8, 1))
        l.append(conv(1, cin, 448, 8, 1))
        l.append(conv(3, 448, 384, 8, 1))
        l.append(Layer(1, 3, 384, 384, 8, 8, 1))
        l.append(Layer(3, 1, 384, 384, 8, 8, 1))
        l.append(conv(1, cin, 192, 8, 1))
    l.append(fc(2048, 1000))
    return l


def mobilenet_v2():
    l = [conv(3, 3, 32, 112, 2)]
    cfg = [(1, 16, 1, 112, 1), (6, 24, 2, 56, 2), (6, 32, 3, 28, 2),
           (6, 64, 4, 14, 2), (6, 96, 3, 14, 1), (6, 160, 3, 7, 2),
           (6, 320, 1, 7, 1)]
    cin = 32
    for t, cout, n, out, s in cfg:
        for b in range(n):
            stride = s if b == 0 else 1
            hidden = cin * t
            if t != 1:
                l.append(conv(1, cin, hidden, out, 1))
            l.append(Layer(3, 3, 1, hidden, out, out, stride))
            l.append(conv(1, hidden, cout, out, 1))
            cin = cout
    l.append(conv(1, 320, 1280, 7, 1))
    l.append(fc(1280, 1000))
    return l


def neuraltalk():
    return [fc(4096, 512), lstm(512, 512, 20), fc(512, 8791)]


BENCHMARKS = [("AlexNet", alexnet()), ("VGG-16", vgg([2, 2, 3, 3, 3])),
              ("VGG-19", vgg([2, 2, 4, 4, 4])),
              ("ResNet-50", resnet([3, 4, 6, 3])),
              ("ResNet-101", resnet([3, 4, 23, 3])),
              ("GoogLeNet", googlenet()), ("Inception-v3", inception_v3()),
              ("MobileNet-V2", mobilenet_v2()), ("NeuralTalk", neuraltalk())]


# --- mapping ---------------------------------------------------------------
class LayerMapping:
    def __init__(self, layer, cfg):
        rows = cfg.xbar_size
        groups = cfg.groups_per_array()
        self.layer = layer
        self.k_chunks = ceil_div(layer.k_dim(), rows)
        self.c_chunks = ceil_div(layer.cout, groups)
        self.arrays_per_copy = self.k_chunks * self.c_chunks
        self.replication = 1

    def stage_cycles(self, ic):
        return ceil_div(self.layer.positions(), self.replication) * ic


def map_network(layers, cfg):
    ms = [LayerMapping(l, cfg) for l in layers]
    per_chip = cfg.total_arrays()
    base = sum(m.arrays_per_copy for m in ms)
    chips = max(ceil_div(base, per_chip), 1)
    budget = chips * per_chip
    used = base
    ic = cfg.input_cycles()
    while True:
        # Rust max_by_key keeps the LAST maximal element
        idx, best = 0, -1
        for i, m in enumerate(ms):
            v = m.stage_cycles(ic)
            if v >= best:
                idx, best = i, v
        if ms[idx].stage_cycles(ic) <= ic:
            break
        cost = ms[idx].arrays_per_copy
        if used + cost > budget:
            break
        ms[idx].replication += 1
        used += cost
    return ms, chips


# --- sim::layer_energy / simulate ------------------------------------------
def layer_energy(lm, cfg, multi_chip):
    cycles = cfg.input_cycles()
    rows = cfg.xbar_size
    groups_per_array = cfg.groups_per_array()
    n = cfg.n_log2()
    l = lm.layer
    positions = l.positions()
    k_dim = l.k_dim()
    k_chunks = lm.k_chunks
    c_chunks = ceil_div(l.cout, groups_per_array)
    array_cycles = positions * k_chunks * c_chunks * cycles
    group_chunks = positions * l.cout * k_chunks

    e = {k: 0.0 for k in ("adc", "dac", "sa", "xbar", "memory", "noc",
                          "digital")}
    e["dac"] = float(positions * cycles * k_dim * c_chunks) * dac_e_cycle(cfg.p_d)
    e["xbar"] = (float(array_cycles) * xbar_e_cycle(cfg.xbar_size, cfg.p_d)
                 * (float(min(k_dim, rows)) / float(rows)))

    if cfg.arch == "isaac":
        bits = adc_resolution_a(cfg, n)
        convs = 2 * group_chunks * conversions_a(cfg)
        e["adc"] = float(convs) * adc_e_conv(bits)
        e["sa"] = float(convs) * SA_DIGITAL_E_OP
        e["memory"] = float(convs) * 2.0 * SRAM_E_BYTE
    elif cfg.arch == "cascade":
        writes = group_chunks * cycles * cfg.weight_cols()
        convs = group_chunks * conversions_b(cfg)
        e["sa"] = (float(writes) * BUFFER_WRITE_E
                   + float(array_cycles) * TIA_E_CYCLE
                   + float(convs) * SA_DIGITAL_E_OP)
        e["adc"] = float(convs) * CASCADE_ADC_E_CONV
        e["digital"] += float(convs) * SUMAMP_E_CYCLE
    else:
        sa_ops = group_chunks * cycles
        e["sa"] = float(sa_ops) * (NNSA_E_OP + 2.0 * SH_E_OP)
        e["adc"] = float(group_chunks) * NNADC_E_CONV
        e["digital"] += float(max(group_chunks - positions * l.cout, 0)) \
            * SA_DIGITAL_E_OP

    unique_in = float(positions * l.stride * l.stride * l.cin)
    replay = float(positions) * float(k_dim)
    out_bytes = float(positions) * float(l.cout)
    e["memory"] += ((unique_in + out_bytes) * EDRAM_E_BYTE
                    + (replay + out_bytes) * SRAM_E_BYTE)
    e["noc"] = out_bytes * NOC_E_BYTE
    if multi_chip:
        e["noc"] += out_bytes * HT_E_BYTE
    e["digital"] += out_bytes * ACT_E_OP
    return e


def simulate(name, layers, cfg):
    ms, chips = map_network(layers, cfg)
    tot = {k: 0.0 for k in ("adc", "dac", "sa", "xbar", "memory", "noc",
                            "digital")}
    for lm in ms:
        le = layer_energy(lm, cfg, chips > 1)
        for k in tot:
            tot[k] += le[k]
    energy = (tot["adc"] + tot["dac"] + tot["sa"] + tot["xbar"]
              + tot["memory"] + tot["noc"] + tot["digital"])
    t_cycle = cycle_seconds(cfg)
    ic = cfg.input_cycles()
    stage_overhead = 9.0 / 8.0
    bottleneck = float(max(m.stage_cycles(ic) for m in ms))
    per_inference_s = bottleneck * t_cycle * stage_overhead
    inferences_per_s = 1.0 / per_inference_s
    macs = sum(l.macs() for l in layers)
    gops = (2.0 * float(macs) / 1e9) * inferences_per_s
    return {"name": name, "arch": cfg.arch, "energy": energy,
            "throughput": gops}


def geomean(v):
    return math.exp(sum(math.log(x) for x in v) / len(v))


def main():
    # sanity: mirror the workloads unit tests
    for name, lo, hi, key in [("AlexNet", 55e6, 65e6, "w"),
                              ("VGG-16", 132e6, 144e6, "w"),
                              ("ResNet-50", 22e6, 28e6, "w")]:
        layers = dict(BENCHMARKS)[name]
        w = sum(l.weights() for l in layers)
        assert lo < w < hi, (name, key, w)

    np_cfg = for_arch("np")
    ref_area = chip_area(np_cfg)
    results = []
    for name, layers in BENCHMARKS:
        for arch in ("isaac", "cascade", "np"):
            cfg = for_arch(arch)
            cfg.tiles = iso_area_tiles(cfg, ref_area)
            results.append(simulate(name, layers, cfg))

    def ratio(vs, f):
        out = []
        for name, _ in BENCHMARKS:
            np_r = next(r for r in results
                        if r["name"] == name and r["arch"] == "np")
            base = next(r for r in results
                        if r["name"] == name and r["arch"] == vs)
            out.append(f(np_r) / f(base))
        return geomean(out)

    e_i = ratio("isaac", lambda r: 1.0 / r["energy"])
    e_c = ratio("cascade", lambda r: 1.0 / r["energy"])
    t_i = ratio("isaac", lambda r: r["throughput"])
    t_c = ratio("cascade", lambda r: r["throughput"])
    print(f"reference_area_mm2 = {ref_area!r}")
    print(f"energy_vs_isaac    = {e_i!r}")
    print(f"energy_vs_cascade  = {e_c!r}")
    print(f"throughput_vs_isaac   = {t_i!r}")
    print(f"throughput_vs_cascade = {t_c!r}")


if __name__ == "__main__":
    main()
