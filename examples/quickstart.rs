//! Quickstart: the three layers in one page.
//!
//! 1. load the AOT-compiled Pallas crossbar kernel (L1) and run one
//!    Strategy-C dot-product batch through PJRT;
//! 2. check it against the native Rust behavioural model (L3's golden
//!    reference);
//! 3. run the §3 analytical framework for the same configuration.
//!
//! Run: `cargo run --release --example quickstart` (needs `make artifacts`).

use neural_pim::arch::{self, crossbar::Group};
use neural_pim::config::Precision;
use neural_pim::dataflow;
use neural_pim::runtime;
use neural_pim::serve::open_runtime;
use neural_pim::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let rt = open_runtime(&neural_pim::artifact_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // ---- L1: the Pallas kernel, AOT-lowered to HLO, executed from Rust
    let exe = rt.load("crossbar")?;
    let (b, k, c) = (64usize, 256usize, 32usize);
    let mut rng = Pcg::new(7);
    let x: Vec<f32> = (0..b * k).map(|_| rng.below(256) as f32).collect();
    let wp: Vec<f32> = (0..k * c).map(|_| rng.below(128) as f32).collect();
    let wn: Vec<f32> = (0..k * c).map(|_| rng.below(128) as f32).collect();
    let out = exe.run(&[
        runtime::lit_f32(&x, &[b as i64, k as i64])?,
        runtime::lit_f32(&wp, &[k as i64, c as i64])?,
        runtime::lit_f32(&wn, &[k as i64, c as i64])?,
    ])?;
    let acc = runtime::to_f32_vec(&out[0])?;
    println!("kernel output: {} analog accumulator values", acc.len());

    // ---- L3 golden reference: decode one output and compare
    let pd = 4;
    let kdec = arch::sa_unrolled_scale(2, pd);
    let col = 0usize;
    // rebuild the same dot product natively: column `col` of batch row 0,
    // split into the two 128-row K-chunks the kernel's BlockSpec walks
    let mut d_native = 0f64;
    for chunk in 0..2usize {
        let rows = 128usize;
        let w: Vec<i32> = (0..rows)
            .map(|r| {
                let idx = (chunk * rows + r) * c + col;
                wp[idx] as i32 - wn[idx] as i32
            })
            .collect();
        let xr: Vec<u32> =
            (0..rows).map(|r| x[chunk * rows + r] as u32).collect();
        d_native += Group { w }.dot(&xr) as f64;
    }
    let d_kernel = acc[col] as f64 * kdec;
    println!(
        "dot[0]: kernel {:.1} vs native {:.1} (diff {:.4}%)",
        d_kernel,
        d_native,
        100.0 * (d_kernel - d_native).abs() / d_native.abs().max(1.0)
    );
    assert!((d_kernel - d_native).abs() <= d_native.abs() * 1e-3 + 8.0);

    // ---- the §3 analytical framework for this configuration
    let p = Precision { p_d: pd, ..Default::default() };
    println!(
        "\nStrategy C at P_D={}: {} conversion/group (A needs {}, B needs {}), \
         {} input cycles",
        pd,
        dataflow::conversions_c(),
        dataflow::conversions_a(&p),
        dataflow::conversions_b(&p),
        dataflow::latency_cycles(&p)
    );
    let e_a = dataflow::group_energy(dataflow::Strategy::A, &p, 7).total();
    let e_c = dataflow::group_energy(dataflow::Strategy::C, &p, 7).total();
    println!(
        "array-level energy per group: A {:.1} pJ, C {:.1} pJ ({:.1}x)",
        e_a * 1e12,
        e_c * 1e12,
        e_a / e_c
    );
    println!("\nquickstart OK");
    Ok(())
}
