//! §7.1 design-space exploration (Fig. 11): sweep (N, M, A, S, D), print
//! the efficiency frontier, and compare the found optimum against the
//! paper's N128-D4-A4-S64 M64 configuration.
//!
//! Run: `cargo run --release --example design_space_exploration [--top 20]`

use neural_pim::config::AcceleratorConfig;
use neural_pim::dse;
use neural_pim::report;
use neural_pim::util::cli::Args;
use neural_pim::util::table::Table;

fn main() {
    let args = Args::from_env();
    neural_pim::util::pool::set_threads(args.threads());
    let top = args.get_usize("top", 15);

    report::fig11_table(top).print();

    let pts = dse::sweep();
    println!("feasible design points: {}", pts.len());

    // the efficiency frontier per crossbar size (Fig. 11's grouping)
    let mut t = Table::new(
        "best point per crossbar size",
        &["xbar", "config", "GOPS/s/mm²", "GOPS/s/W"],
    );
    for size in [32u32, 64, 128, 256] {
        if let Some(best) = pts
            .iter()
            .filter(|p| p.cfg.xbar_size == size)
            .max_by(|a, b| {
                a.compute_efficiency.partial_cmp(&b.compute_efficiency).unwrap()
            })
        {
            t.row(&[
                size.to_string(),
                best.label.clone(),
                format!("{:.1}", best.compute_efficiency),
                format!("{:.1}", best.energy_efficiency),
            ]);
        }
    }
    t.print();

    let paper = dse::evaluate(&AcceleratorConfig::neural_pim()).unwrap();
    let best = dse::best();
    println!(
        "paper's Table-2 config: {} -> {:.1} GOPS/s/mm² (paper reports 1904.0)",
        paper.label, paper.compute_efficiency
    );
    println!(
        "sweep optimum: {} -> {:.1} GOPS/s/mm² ({:+.1}% vs paper's choice)",
        best.label,
        best.compute_efficiency,
        100.0 * (best.compute_efficiency / paper.compute_efficiency - 1.0)
    );
}
