//! §3 characterization walk-through: Eqs. (2)–(8) for arbitrary
//! precision settings, the Fig. 4(b) DAC sweep, and the Fig. 4(c)
//! breakdown — all analytical, no artifacts needed.
//!
//! Run: `cargo run --release --example characterize_dataflows`
//!      [--pi 8 --pw 8 --pr 1 --n 7]

use neural_pim::config::Precision;
use neural_pim::dataflow::{self, Strategy};
use neural_pim::report;
use neural_pim::util::cli::Args;
use neural_pim::util::table::{eng, Table};

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 7) as u32;
    let base = Precision {
        p_i: args.get_usize("pi", 8) as u32,
        p_w: args.get_usize("pw", 8) as u32,
        p_o: args.get_usize("po", 8) as u32,
        p_r: args.get_usize("pr", 1) as u32,
        p_d: 1,
    };

    let mut t = Table::new(
        &format!("Eqs. 2-8 at N={n}, P_I={}, P_W={}, P_R={}",
                 base.p_i, base.p_w, base.p_r),
        &["P_D", "P_A^A", "P_B^A", "P_C^A", "conv A", "conv B", "conv C",
          "cycles", "B feasible"],
    );
    for pd in [1u32, 2, 4, 8] {
        if pd > base.p_i {
            continue;
        }
        let p = Precision { p_d: pd, ..base };
        t.row(&[
            pd.to_string(),
            dataflow::adc_resolution_a(&p, n).to_string(),
            dataflow::adc_resolution_b(&p, n).to_string(),
            dataflow::adc_resolution_c(&p).to_string(),
            dataflow::conversions_a(&p).to_string(),
            dataflow::conversions_b(&p).to_string(),
            dataflow::conversions_c().to_string(),
            dataflow::latency_cycles(&p).to_string(),
            dataflow::strategy_b_feasible(&p, n).to_string(),
        ]);
    }
    t.print();

    report::fig4b_table().print();
    report::fig4c_table().print();

    // per-strategy scaling with array size: the N-dependence of Eq. 2
    let mut t = Table::new("ADC energy per group vs array size (P_D = 1)",
                           &["N (2^N rows)", "A", "B", "C"]);
    for nn in [5u32, 6, 7, 8] {
        let p = Precision { p_d: 1, ..base };
        t.row(&[
            format!("{nn} ({})", 1u64 << nn),
            eng(dataflow::group_energy(Strategy::A, &p, nn).adc),
            eng(dataflow::group_energy(Strategy::B, &p, nn).adc),
            eng(dataflow::group_energy(Strategy::C, &p, nn).adc),
        ]);
    }
    t.print();
}
