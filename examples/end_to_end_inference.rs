//! End-to-end driver (the DESIGN.md "end-to-end validation" example):
//! exercises every layer of the system on the real workload —
//!
//!  1. loads the AOT artifacts (trained CNN + trained NeuralPeriph);
//!  2. serves the full 512-image test set through the coordinator
//!     (dynamic batching, PJRT execution), reporting latency/throughput;
//!  3. sweeps the Fig. 4(a) accuracy-vs-ADC-resolution experiment for all
//!     three accumulation strategies via the bit-exact dataflow models;
//!  4. sweeps the Fig. 10 SINAD-vs-accuracy curve and marks each
//!     dataflow's measured SINAD (Fig. 9 MC for Neural-PIM, native
//!     behavioural models for the baselines);
//!  5. runs the architecture simulator for the headline Fig. 12 ratios.
//!
//! Run: `cargo run --release --example end_to_end_inference`
//! (add `--quick` to shrink the sweeps). Results land in EXPERIMENTS.md.

use neural_pim::config::Architecture;
use neural_pim::runtime;
use neural_pim::serve::{open_runtime, Coordinator, PjrtBackend,
                        ServeOptions};
use neural_pim::util::cli::Args;
use neural_pim::util::stats;
use neural_pim::util::table::Table;
use neural_pim::{noise, report, sim, workloads};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    neural_pim::util::pool::set_threads(args.threads());
    let quick = args.flag("quick");
    let dir = neural_pim::artifact_dir();
    let ts = runtime::TestSet::load(std::path::Path::new(&dir))?;
    let (h, w, c) = ts.dims;

    // ---------------------------------------------------------------- 2.
    println!("== serving the test set through the coordinator ==");
    let coord = Coordinator::start(
        PjrtBackend::new(dir.clone(), "cnn_ideal", h * w * c),
        ServeOptions::default(),
    )?;
    let t0 = std::time::Instant::now();
    let stride = h * w * c;
    let mut pending = Vec::new();
    for i in 0..ts.n {
        pending.push((
            coord
                .submit(ts.images[i * stride..(i + 1) * stride].to_vec())?
                .accepted()?,
            ts.labels[i],
        ));
    }
    let mut correct = 0usize;
    let mut lat = Vec::new();
    for (rx, label) in pending {
        let r = rx.recv()?;
        if let Some(e) = &r.error {
            anyhow::bail!("request {} failed in its batch: {e}", r.id);
        }
        lat.push((r.queue_us + r.exec_us) as f64 / 1000.0);
        let pred = r.logits.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32;
        correct += (pred == label) as usize;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} requests in {:.2}s = {:.0} req/s, accuracy {:.4}, p50 {:.1} ms, \
         p99 {:.1} ms\n{}",
        ts.n, dt, ts.n as f64 / dt,
        correct as f64 / ts.n as f64,
        stats::percentile(&lat, 50.0), stats::percentile(&lat, 99.0),
        coord.metrics.snapshot()
    );
    coord.shutdown();

    // ---------------------------------------------------------------- 3.
    println!("\n== Fig 4a: accuracy vs A/D resolution (bit-exact dataflows) ==");
    let rt = open_runtime(&dir)?;
    let bits_list: &[usize] =
        if quick { &[4, 8] } else { &[2, 3, 4, 5, 6, 7, 8, 10] };
    let mut t = Table::new("accuracy (512 images; strategy C uses 4-bit DACs)",
                           &["ADC bits", "Strategy A", "Strategy B",
                             "Strategy C"]);
    for &bits in bits_list {
        let mut row = vec![bits.to_string()];
        for s in ["A", "B", "C"] {
            let exe = rt.load(&format!("cnn_strat{s}"))?;
            let levels = (1u64 << bits) as f32 - 1.0;
            let mut correct = 0usize;
            let batches = if quick { 1 } else { ts.n / 128 };
            for b in 0..batches.max(1) {
                let mut inputs = vec![
                    ts.batch_literal(b * 128, 128)?,
                    runtime::lit_scalar_f32(levels),
                ];
                if s != "A" {
                    inputs.push(runtime::lit_key(42 + b as u64)?);
                }
                let out = exe.run(&inputs)?;
                let logits = runtime::to_f32_vec(&out[0])?;
                let acc = runtime::accuracy(&logits,
                                            &ts.batch_labels(b * 128, 128), 10);
                correct += (acc * 128.0).round() as usize;
            }
            row.push(format!("{:.3}",
                             correct as f64 / (128 * batches.max(1)) as f64));
        }
        t.row(&row);
    }
    t.print();

    // ---------------------------------------------------------------- 4.
    println!("== Fig 9/10: measured dataflow SINADs + accuracy vs SINAD ==");
    let exe = rt.load("mc_opt")?;
    let mut hw = Vec::new();
    let mut sw = Vec::new();
    for t in 0..2u64 {
        let out = exe.run(&[runtime::lit_key(42 + t)?])?;
        hw.extend(runtime::to_f32_vec(&out[0])?.iter().map(|&v| v as f64));
        sw.extend(runtime::to_f32_vec(&out[1])?.iter().map(|&v| v as f64));
    }
    let np_sinad = stats::sinad_db(&hw, &sw);
    let a_sinad = noise::strategy_sinad('A', 512, 1);
    let b_sinad = noise::strategy_sinad('B', 512, 1);
    println!("measured dataflow SINADs: Neural-PIM {:.1} dB, ISAAC-style \
              {:.1} dB, CASCADE-style {:.1} dB", np_sinad, a_sinad, b_sinad);

    let noisy = rt.load("cnn_noisy")?;
    let sweep: &[f64] = if quick { &[20.0, 40.0] } else {
        &[10.0, 15.0, 20.0, 25.0, 30.0, 35.0, 40.0, 50.0, 60.0]
    };
    let mut t = Table::new("Fig 10: accuracy under Eq.-(13) noise injection",
                           &["SINAD (dB)", "accuracy"]);
    for &s in sweep {
        let out = noisy.run(&[
            ts.batch_literal(0, 128)?,
            runtime::lit_key(7)?,
            runtime::lit_scalar_f32(s as f32),
        ])?;
        let logits = runtime::to_f32_vec(&out[0])?;
        let acc = runtime::accuracy(&logits, &ts.batch_labels(0, 128), 10);
        t.row(&[format!("{s:.0}"), format!("{acc:.3}")]);
    }
    t.print();

    // ---------------------------------------------------------------- 5.
    println!("== Fig 12 headline (architecture simulator) ==");
    let nets = if quick {
        vec![workloads::alexnet()]
    } else {
        workloads::all_benchmarks()
    };
    let r = report::system_report(&nets);
    println!("{}", r.headline);
    let cmp = sim::run_system_comparison(&nets);
    println!(
        "iso-area reference: {:.1} mm²; Neural-PIM peak {:.0} GOPS on {}",
        cmp.reference_area,
        cmp.results
            .iter()
            .filter(|x| x.arch == Architecture::NeuralPim)
            .map(|x| x.throughput_gops)
            .fold(0.0, f64::max),
        nets.last().unwrap().name
    );
    println!("\nend_to_end_inference OK");
    Ok(())
}
