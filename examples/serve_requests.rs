//! Serving-scenario example: open-loop load against the backend-generic
//! coordinator at a configured arrival rate, with the noisy-dataflow
//! artifact standing in for the real analog chip (each batch sees the
//! measured Neural-PIM SINAD). Reports throughput, latency percentiles,
//! batch fill, accuracy under analog noise, and — when `--depth` bounds
//! the admission queue — the shed rate.
//!
//! Run: `cargo run --release --example serve_requests`
//!      [--rate 2000] [--requests 1024] [--sinad 30] [--depth 0]
//!
//! Swap `--backend sim` to drive the same loop against the simulated
//! backend (no artifacts needed).

use neural_pim::config::AcceleratorConfig;
use neural_pim::runtime::TestSet;
use neural_pim::serve::{Coordinator, ExtraInput, PjrtBackend, ServeOptions,
                        SimBackend, Submission};
use neural_pim::util::cli::Args;
use neural_pim::util::rng::Pcg;
use neural_pim::util::stats;
use neural_pim::workloads;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rate = args.get_f64("rate", 2000.0); // requests/s
    let n_req = args.get_usize("requests", 1024);
    let sinad = args.get_f64("sinad", 30.0);
    let depth = args.get_usize("depth", 0);
    let seed = args.get_u64("seed", 42);

    let opts = ServeOptions {
        max_wait: Duration::from_millis(
            args.get_usize("max-wait-ms", 4) as u64
        ),
        max_queue_depth: if depth == 0 { None } else { Some(depth) },
        ..Default::default()
    };
    // the serving loop below never mentions which backend executes
    let (coord, images, labels): (Coordinator, Vec<Vec<f32>>, Vec<i32>) =
        if args.get_or("backend", "pjrt") == "sim" {
            let net = workloads::synthetic_cnn();
            let cfg = AcceleratorConfig::neural_pim();
            let backend = SimBackend::new(&net, &cfg, 128, 32 * 32 * 3, seed);
            let classes = backend.classes();
            let mut rng = Pcg::new(seed);
            let images = (0..n_req)
                .map(|_| {
                    (0..32 * 32 * 3).map(|_| rng.below(256) as f32).collect()
                })
                .collect();
            let labels =
                (0..n_req).map(|_| rng.below(classes) as i32).collect();
            (Coordinator::start(backend, opts)?, images, labels)
        } else {
            let dir = neural_pim::artifact_dir();
            let ts = TestSet::load(std::path::Path::new(&dir))?;
            let (h, w, c) = ts.dims;
            let stride = h * w * c;
            // cnn_noisy takes (images, key, sinad)
            let backend = PjrtBackend {
                artifact: "cnn_noisy".into(),
                extra_inputs: vec![
                    ExtraInput::KeyU32(seed),
                    ExtraInput::ScalarF32(sinad as f32),
                ],
                ..PjrtBackend::new(dir, "", stride)
            };
            let images = (0..n_req)
                .map(|i| {
                    let idx = i % ts.n;
                    ts.images[idx * stride..(idx + 1) * stride].to_vec()
                })
                .collect();
            let labels = (0..n_req).map(|i| ts.labels[i % ts.n]).collect();
            (Coordinator::start(backend, opts)?, images, labels)
        };
    println!(
        "open-loop load: {rate:.0} req/s, {n_req} requests, analog SINAD \
         {sinad:.0} dB"
    );

    let gap = Duration::from_secs_f64(1.0 / rate);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut shed = 0usize;
    for (i, (img, label)) in images.into_iter().zip(labels).enumerate() {
        // open-loop pacing
        let target = t0 + gap * i as u32;
        if let Some(sleep) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(sleep);
        }
        match coord.submit(img)? {
            Submission::Accepted(rx) => pending.push((rx, label)),
            Submission::Rejected(_) => shed += 1,
        }
    }
    let served = pending.len();
    let mut correct = 0usize;
    let mut lat = Vec::new();
    let mut fills = Vec::new();
    for (rx, label) in pending {
        let r = rx.recv()?;
        if let Some(e) = &r.error {
            anyhow::bail!("request {} failed in its batch: {e}", r.id);
        }
        lat.push((r.queue_us + r.exec_us) as f64 / 1000.0);
        fills.push(r.batch_size as f64);
        let pred = r.logits.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32;
        correct += (pred == label) as usize;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {served} in {:.2}s -> {:.0} req/s sustained",
        dt, served as f64 / dt
    );
    if shed > 0 {
        println!("admission shed {shed} of {n_req} (depth limit {depth})");
    }
    println!(
        "latency: p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms; mean batch fill \
         {:.1}",
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 95.0),
        stats::percentile(&lat, 99.0),
        stats::mean(&fills)
    );
    println!(
        "accuracy under {:.0} dB analog noise: {:.4}",
        sinad,
        correct as f64 / served.max(1) as f64
    );
    println!("{}", coord.metrics.snapshot());
    coord.shutdown();
    Ok(())
}
