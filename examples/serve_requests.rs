//! Serving-scenario example: open-loop load against the coordinator at a
//! configured arrival rate, with the noisy-dataflow artifact standing in
//! for the real analog chip (each batch sees the measured Neural-PIM
//! SINAD). Reports throughput, latency percentiles, batch fill, and
//! accuracy under analog noise.
//!
//! Run: `cargo run --release --example serve_requests`
//!      [--rate 2000] [--requests 1024] [--sinad 30]

use neural_pim::coordinator::{Coordinator, CoordinatorConfig, ExtraInput};
use neural_pim::runtime::TestSet;
use neural_pim::util::cli::Args;
use neural_pim::util::stats;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rate = args.get_f64("rate", 2000.0); // requests/s
    let n_req = args.get_usize("requests", 1024);
    let sinad = args.get_f64("sinad", 30.0);

    let dir = neural_pim::artifact_dir();
    let ts = TestSet::load(std::path::Path::new(&dir))?;
    let (h, w, c) = ts.dims;
    let coord = Coordinator::start(
        CoordinatorConfig {
            artifact_dir: dir,
            artifact: "cnn_noisy".into(),
            // cnn_noisy takes (images, key, sinad)
            extra_inputs: vec![
                ExtraInput::KeyU32(args.get_u64("seed", 42)),
                ExtraInput::ScalarF32(sinad as f32),
            ],
            max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 4) as u64),
            ..Default::default()
        },
        h * w * c,
    )?;
    println!("open-loop load: {rate:.0} req/s, {n_req} requests, \
              analog SINAD {sinad:.0} dB");

    let stride = h * w * c;
    let gap = Duration::from_secs_f64(1.0 / rate);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_req {
        // open-loop pacing
        let target = t0 + gap * i as u32;
        if let Some(sleep) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(sleep);
        }
        let idx = i % ts.n;
        pending.push((
            coord.submit(ts.images[idx * stride..(idx + 1) * stride].to_vec())?,
            ts.labels[idx],
        ));
    }
    let mut correct = 0usize;
    let mut lat = Vec::new();
    let mut fills = Vec::new();
    for (rx, label) in pending {
        let r = rx.recv()?;
        if let Some(e) = &r.error {
            anyhow::bail!("request {} failed in its batch: {e}", r.id);
        }
        lat.push((r.queue_us + r.exec_us) as f64 / 1000.0);
        fills.push(r.batch_size as f64);
        let pred = r.logits.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32;
        correct += (pred == label) as usize;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {n_req} in {:.2}s -> {:.0} req/s sustained",
        dt, n_req as f64 / dt
    );
    println!(
        "latency: p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms; mean batch fill \
         {:.1}",
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 95.0),
        stats::percentile(&lat, 99.0),
        stats::mean(&fills)
    );
    println!(
        "accuracy under {:.0} dB analog noise: {:.4}",
        sinad,
        correct as f64 / n_req as f64
    );
    println!("{}", coord.metrics.summary());
    coord.shutdown();
    Ok(())
}
