"""L2 model tests: quantized CNN, strategy dataflows, noise model."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import common, data, model, train_cnn

hypothesis.settings.register_profile(
    "model", max_examples=8, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("model")


@pytest.fixture(scope="module")
def tiny_qmodel():
    params, acc = train_cnn.train(steps=250, n_train=2048)
    (xtr, _), (xte, yte) = data.make_splits(n_train=2048)
    qm = train_cnn.quantize(params, xtr[:256])
    x_u8 = jnp.asarray(np.round(xte[:64] * 255.0), jnp.float32)
    return qm, x_u8, yte[:64], acc


def rand_mat(seed, m, k, c):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, (m, k)), jnp.float32)
    w = jnp.asarray(rng.integers(-127, 128, (k, c)), jnp.float32)
    return x, w


class TestStrategyMatmuls:
    @hypothesis.given(seed=st.integers(0, 2**31), m=st.integers(1, 32),
                      k=st.integers(1, 300), c=st.integers(1, 16))
    def test_strategy_a_near_exact_at_fine_resolution(self, seed, m, k, c):
        x, w = rand_mat(seed, m, k, c)
        d = np.array(x) @ np.array(w)
        # levels == full scale -> unit-step quantizer -> exact
        fs_levels = float(model.K_CHUNK * 1)
        got = np.array(model.strategy_a_matmul(x, w, fs_levels, 1))
        assert_allclose(got, d, atol=0.5)

    @hypothesis.given(seed=st.integers(0, 2**31))
    def test_strategy_a_error_monotone_in_resolution(self, seed):
        x, w = rand_mat(seed, 16, 256, 8)
        d = np.array(x) @ np.array(w)
        errs = []
        for bits in (3, 5, 7):
            got = np.array(model.strategy_a_matmul(x, w, float(2**bits - 1), 1))
            errs.append(np.abs(got - d).mean())
        assert errs[0] >= errs[1] >= errs[2], errs

    @hypothesis.given(seed=st.integers(0, 2**31))
    def test_strategy_b_clean_buffer_recovers_dot(self, seed):
        x, w = rand_mat(seed, 8, 128, 4)
        d = np.array(x) @ np.array(w)
        got = np.array(model.strategy_b_matmul(
            x, w, float(2**14 - 1), jax.random.PRNGKey(0), 1,
            buffer_bits=16, buffer_sigma=0.0))
        # only fine quantizers left: absolute error bounded by the summed
        # per-diagonal quantization steps (~2 * FS * 2^15 / 2^14)
        assert np.abs(got - d).max() < 300.0, np.abs(got - d).max()

    @hypothesis.given(seed=st.integers(0, 2**31),
                      pd=st.sampled_from([1, 2, 4]))
    def test_strategy_c_noiseless_tracks_dot(self, seed, pd):
        x, w = rand_mat(seed, 8, 200, 4)
        d = np.array(x) @ np.array(w)
        # the converter range must cover the *per-chunk* partial sums
        # (chunks can exceed the cancelled total) — which is exactly what
        # calibrate_d_max measures on the real model
        chunk_max = max(
            float(np.abs(np.array(x[:, lo:hi]) @ np.array(w[lo:hi])).max())
            for lo, hi in model._chunks(x.shape[1]))
        d_max = max(float(np.abs(d).max()), chunk_max) + 1.0
        got = np.array(model.strategy_c_matmul(
            x, w, float(2**16 - 1), jax.random.PRNGKey(0), d_max, pd,
            analog_sigma_v=0.0))
        # error bounded by the 16-bit conversion step per K-chunk (full
        # scale d_max), plus f32 accumulation noise
        tol = 4.0 * d_max / 2**15 + 2.0
        assert np.abs(got - d).max() < tol, (np.abs(got - d).max(), tol)

    def test_strategy_c_8bit_quantization_bounds_error(self):
        x, w = rand_mat(5, 16, 256, 8)
        d = np.array(x) @ np.array(w)
        d_max = float(np.abs(d).max())
        got = np.array(model.strategy_c_matmul(
            x, w, 255.0, jax.random.PRNGKey(0), d_max, 4,
            analog_sigma_v=0.0))
        # one 8-bit conversion per chunk: error <= chunks * d_max/255
        assert np.abs(got - d).max() <= 2 * d_max / 255.0 + 1.0


class TestModelLevel:
    def test_quantized_close_to_float(self, tiny_qmodel):
        qm, x_u8, y, float_acc = tiny_qmodel
        logits = train_cnn.quantized_forward(qm, x_u8)
        acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))
        assert acc > float_acc - 0.08, (acc, float_acc)

    def test_noisy_forward_high_sinad_matches_ideal(self, tiny_qmodel):
        qm, x_u8, _, _ = tiny_qmodel
        ideal = train_cnn.quantized_forward(qm, x_u8)
        noisy = model.noisy_forward(qm, x_u8, jax.random.PRNGKey(1), 80.0)
        assert np.mean(np.argmax(np.array(ideal), 1)
                       == np.argmax(np.array(noisy), 1)) > 0.95

    def test_noisy_forward_low_sinad_degrades(self, tiny_qmodel):
        qm, x_u8, y, _ = tiny_qmodel
        noisy = model.noisy_forward(qm, x_u8, jax.random.PRNGKey(1), 3.0)
        acc = float(jnp.mean(jnp.argmax(noisy, -1) == jnp.asarray(y)))
        assert acc < 0.7

    def test_calibrate_d_max_positive_per_layer(self, tiny_qmodel):
        qm, x_u8, _, _ = tiny_qmodel
        d_max = model.calibrate_d_max(qm, x_u8)
        assert len(d_max) == len(qm["layers"])
        assert all(v > 0 for v in d_max)

    def test_strategy_forward_c_matches_ideal_at_8bit(self, tiny_qmodel):
        qm, x_u8, _, _ = tiny_qmodel
        d_max = model.calibrate_d_max(qm, x_u8)
        ideal = train_cnn.quantized_forward(qm, x_u8)
        c = model.strategy_forward(qm, x_u8, "C", 255.0,
                                   key=jax.random.PRNGKey(0), d_max=d_max)
        agree = np.mean(np.argmax(np.array(ideal), 1)
                        == np.argmax(np.array(c), 1))
        assert agree > 0.9, agree


class TestMcDataflow:
    @pytest.fixture(scope="class")
    def periph(self):
        from compile import train_periph
        sa_opt, _ = train_periph.train_nns_a(4, steps=800)
        sa_msb, _ = train_periph.train_nns_a(4, steps=800,
                                             hardware_aware=False,
                                             carry_w=1.0, seed=2)
        adc_opt, _ = train_periph.train_nnadc(steps=100)
        adc_nv, _ = train_periph.train_nnadc(steps=100, hardware_aware=False,
                                             seed=3)
        return {"nns_a_opt": sa_opt, "nns_a_msb": sa_msb,
                "nnadc_opt": adc_opt, "nnadc_naive": adc_nv}

    def test_optimized_beats_naive(self, periph):
        key = jax.random.PRNGKey(0)
        d_hw, d_sw = model.mc_dot_products(key, periph, n=256)
        s_opt = float(model.sinad_db(d_hw, d_sw))
        d_hw, d_sw = model.mc_dot_products(key, periph, n=256,
                                           lsb_first=False, range_aware=False)
        s_naive = float(model.sinad_db(d_hw, d_sw))
        assert s_opt > s_naive + 5.0, (s_opt, s_naive)

    def test_dot_products_span_range(self, periph):
        key = jax.random.PRNGKey(1)
        _, d_sw = model.mc_dot_products(key, periph, n=256)
        # the correlated draw must exercise the converter's range
        assert float(jnp.std(d_sw)) > 1e5


class TestDataset:
    def test_splits_deterministic(self):
        (a, ya), _ = data.make_splits(n_train=64, n_test=16)
        (b, yb), _ = data.make_splits(n_train=64, n_test=16)
        assert np.array_equal(a, b) and np.array_equal(ya, yb)

    def test_images_in_unit_range(self):
        (x, y), _ = data.make_splits(n_train=64, n_test=16)
        assert x.min() >= 0.0 and x.max() <= 1.0
        assert set(np.unique(y)) <= set(range(10))
