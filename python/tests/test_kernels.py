"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes and bit-widths with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import common
from compile.kernels import crossbar, nnadc, nns_a, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=12, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand_case(seed, b, k, c):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, (b, k)))
    wp = jnp.asarray(rng.integers(0, 128, (k, c)))
    wn = jnp.asarray(rng.integers(0, 128, (k, c)))
    return x, wp, wn


class TestCrossbarKernel:
    @hypothesis.given(seed=st.integers(0, 2**31), b=st.integers(1, 16),
                      k=st.integers(1, 300), c=st.integers(1, 24),
                      pd=st.sampled_from([1, 2, 4, 8]))
    def test_matches_oracle(self, seed, b, k, c, pd):
        x, wp, wn = rand_case(seed, b, k, c)
        got = crossbar.strategy_c_dot(x, wp, wn, pd)
        want = ref.strategy_c_dot_ref(x, wp, wn, pd)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    @hypothesis.given(seed=st.integers(0, 2**31), pd=st.sampled_from([1, 2, 4]))
    def test_decodes_to_integer_dot_product(self, seed, pd):
        x, wp, wn = rand_case(seed, 8, 200, 12)
        dec = crossbar.strategy_c_dot_decoded(x, wp, wn, pd)
        want = ref.dot_product_int_ref(x, wp, wn)
        assert_allclose(np.asarray(dec), np.asarray(want),
                        rtol=1e-4, atol=0.5)

    def test_k_tiling_boundary(self):
        # exactly one tile, one tile + 1 row, two tiles
        for k in (128, 129, 256):
            x, wp, wn = rand_case(k, 4, k, 8)
            got = crossbar.strategy_c_dot(x, wp, wn, 4)
            want = ref.strategy_c_dot_ref(x, wp, wn, 4)
            assert_allclose(np.asarray(got), np.asarray(want),
                            rtol=1e-5, atol=1e-5)

    def test_zero_inputs_give_zero(self):
        x = jnp.zeros((4, 64), jnp.int32)
        w = jnp.asarray(np.random.default_rng(0).integers(0, 128, (64, 4)))
        got = crossbar.strategy_c_dot(x, w, w, 1)
        assert_allclose(np.asarray(got), 0.0, atol=1e-6)


class TestNnsAKernel:
    @hypothesis.given(seed=st.integers(0, 2**31), s=st.integers(1, 8),
                      b=st.integers(1, 32), h=st.integers(4, 24))
    def test_matches_oracle(self, seed, s, b, h):
        rng = np.random.default_rng(seed)
        w1 = jnp.asarray(rng.normal(0, 0.05, (9, h)), jnp.float32)
        b1 = jnp.asarray(rng.normal(0.6, 0.05, (h,)), jnp.float32)
        w2 = jnp.asarray(rng.normal(0, 0.05, (h, 1)), jnp.float32)
        b2 = jnp.asarray(rng.normal(0, 0.01, (1,)), jnp.float32)
        vs = jnp.asarray(rng.uniform(-0.25, 0.25, (s, b, 8)), jnp.float32)
        got = nns_a.nns_a_cyclic(vs, w1, b1, w2, b2)
        want = ref.nns_a_cyclic_ref(vs, w1, b1, w2, b2,
                                    common.VDD / 2, 25.0)
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_single_cycle_is_plain_mlp(self):
        rng = np.random.default_rng(7)
        h = 12
        w1 = jnp.asarray(rng.normal(0, 0.05, (9, h)), jnp.float32)
        b1 = jnp.asarray(rng.normal(0.6, 0.05, (h,)), jnp.float32)
        w2 = jnp.asarray(rng.normal(0, 0.05, (h, 1)), jnp.float32)
        b2 = jnp.zeros((1,), jnp.float32)
        vs = jnp.asarray(rng.uniform(-0.2, 0.2, (1, 5, 8)), jnp.float32)
        got = nns_a.nns_a_cyclic(vs, w1, b1, w2, b2)
        vin = jnp.concatenate([vs[0], jnp.zeros((5, 1))], axis=-1)
        want = ref.mlp_vtc_ref(vin, w1, b1, w2, b2, common.VDD / 2, 25.0)[:, 0]
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


class TestNnadcKernel:
    @hypothesis.given(seed=st.integers(0, 2**31), b=st.integers(1, 600),
                      h=st.integers(8, 255))
    def test_matches_oracle(self, seed, b, h):
        rng = np.random.default_rng(seed)
        w1 = jnp.asarray(rng.uniform(0.5, 1.0, (h,)), jnp.float32)
        b1 = jnp.asarray(rng.uniform(-0.4, 0.6, (h,)), jnp.float32)
        w2 = jnp.asarray(np.full(h, 1.0 / h), jnp.float32)
        v = jnp.asarray(rng.uniform(0, 1, (b,)), jnp.float32)
        got_codes, got_soft = nnadc.nnadc_convert(v, w1, b1, w2)
        want_codes, want_soft = ref.nnadc_flash_ref(
            v, w1, b1, w2, common.VDD / 2, common.VTC_GAIN_LATCH)
        assert_allclose(np.asarray(got_soft), np.asarray(want_soft), atol=1e-5)
        # codes may differ where soft sits exactly on a rounding edge, but
        # never by more than one code
        diff = np.abs(np.asarray(got_codes) - np.asarray(want_codes))
        assert diff.max() <= 1.0, diff.max()

    def test_monotone_on_ideal_bank(self):
        levels = 255
        t = (np.arange(1, levels + 1) - 0.5) / levels
        w1 = jnp.asarray(np.full(levels, 0.9), jnp.float32)
        b1 = jnp.asarray(common.VDD / 2 - 0.9 * t, jnp.float32)
        w2 = jnp.asarray(np.full(levels, 1.0 / levels), jnp.float32)
        v = jnp.linspace(0, 1, 2048)
        codes, _ = nnadc.nnadc_convert(v, w1, b1, w2)
        codes = np.asarray(codes)
        assert np.all(np.diff(codes) >= 0)
        assert codes[0] == 0 and codes[-1] == 255


class TestVoltageHelpers:
    @hypothesis.given(seed=st.integers(0, 2**31),
                      pd=st.sampled_from([1, 2, 4, 8]))
    def test_bit_slices_reassemble(self, seed, pd):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(0, 256, (3, 17)))
        xs = common.input_bit_slices(x, pd)
        back = sum(2.0 ** (pd * i) * xs[i] for i in range(xs.shape[0]))
        assert_allclose(np.asarray(back), np.asarray(x).astype(np.float32))

    @hypothesis.given(seed=st.integers(0, 2**31))
    def test_weight_planes_reassemble(self, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.integers(0, 256, (9, 5)))
        planes = common.weight_bit_planes(w)
        back = sum(2.0**j * planes[j] for j in range(planes.shape[0]))
        assert_allclose(np.asarray(back), np.asarray(w).astype(np.float32))

    @hypothesis.given(s=st.integers(1, 8), pd=st.sampled_from([1, 2, 4]))
    def test_unrolled_scale_identity(self, s, pd):
        # the exactness property the whole Strategy-C design rests on:
        # unrolled recursion == D / K for any partial-sum pattern
        rng = np.random.default_rng(s * 10 + pd)
        partial = jnp.asarray(rng.integers(-100, 100, (s, 8, 2, 3)),
                              jnp.float32)
        acc = ref.strategy_c_accumulate_ref(partial, pd)
        d = sum(2.0 ** (pd * i + j) * np.asarray(partial)[i, j]
                for i in range(s) for j in range(8))
        k = common.sa_alpha(pd) * 2.0 ** (pd * (s - 1))
        assert_allclose(np.asarray(acc) * k, d, rtol=1e-4, atol=1e-3)
