"""NeuralPeriph training framework: convergence, constraints, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, train_periph
from compile.kernels import ref


@pytest.fixture(scope="module")
def quick_sa():
    params, info = train_periph.train_nns_a(4, steps=1200, seed=0)
    return params, info


@pytest.fixture(scope="module")
def quick_adc():
    params, info = train_periph.train_nnadc(steps=200, seed=1)
    return params, info


class TestNnsATraining:
    def test_converges(self, quick_sa):
        _, info = quick_sa
        assert info["mse"] < 5e-4, info

    def test_respects_crossbar_constraints(self, quick_sa):
        params, _ = quick_sa
        # Eq. (11): per-column L1 <= 1 on both crossbar layers
        assert np.all(np.sum(np.abs(params["w1"]), axis=0) <= 1.0 + 1e-5)
        assert np.all(np.sum(np.abs(params["w2"]), axis=0) <= 1.0 + 1e-5)
        # pseudo-differential entry headroom
        assert np.max(np.abs(params["w1"])) <= 0.2 + 1e-6

    def test_weights_are_ar_bit_quantized(self, quick_sa):
        params, _ = quick_sa
        for name in ("w1", "w2"):
            w = params[name]
            scale = np.max(np.abs(w))
            levels = 2 ** (common.AR_BITS - 1) - 1
            grid = np.round(w / scale * levels)
            assert np.allclose(w, grid / levels * scale, atol=1e-6)

    def test_cyclic_accumulation_tracks_ground_truth(self, quick_sa):
        params, _ = quick_sa
        rng = np.random.default_rng(3)
        vs = jnp.asarray(rng.uniform(-0.2, 0.2, (2, 64, 8)), jnp.float32)
        got = ref.nns_a_cyclic_ref(vs, jnp.asarray(params["w1"]),
                                   jnp.asarray(params["b1"]),
                                   jnp.asarray(params["w2"]),
                                   jnp.asarray(params["b2"]),
                                   common.VDD / 2, common.VTC_GAIN_TT)
        want = common.sa_unroll_ground_truth(jnp.transpose(vs, (0, 1, 2)), 4)
        err = np.asarray(got) - np.asarray(want)
        assert np.max(np.abs(err)) < 0.08  # two chained cycles, volts

    def test_msb_variant_uses_unity_carry(self):
        params, info = train_periph.train_nns_a(
            4, steps=800, hardware_aware=False, carry_w=1.0, seed=2)
        assert info["mse"] < 5e-4
        # carry weight 1: output responds ~1:1 to the 9th input
        v0 = jnp.zeros((1, 9), jnp.float32)
        v1 = v0.at[0, 8].set(0.1)
        f = lambda v: ref.mlp_vtc_ref(v, *(jnp.asarray(params[k]) for k in
                                           ("w1", "b1", "w2", "b2")),
                                      common.VDD / 2, common.VTC_GAIN_TT)[0, 0]
        gain = (float(f(v1)) - float(f(v0))) / 0.1
        assert 0.8 < gain < 1.2


class TestNnadcTraining:
    def test_transfer_is_monotone_and_complete(self, quick_adc):
        params, _ = quick_adc
        v, codes = train_periph.adc_transfer(params)
        assert np.all(np.diff(codes) >= 0)
        dnl, inl, missing = train_periph.dnl_inl(v, codes, 8)
        assert missing <= 3
        assert np.max(np.abs(inl)) < 2.0

    def test_enob_near_8_bits(self, quick_adc):
        params, _ = quick_adc
        enob, sinad = train_periph.enob(params)
        assert enob > 7.0, (enob, sinad)

    def test_instance_corners_shipped(self, quick_adc):
        params, _ = quick_adc
        assert params["vm"].shape == params["b1"].shape
        assert np.all(np.abs(params["vm"] - common.VDD / 2)
                      <= 0.02 * common.VDD + 1e-6)

    def test_unit_summing_column(self, quick_adc):
        params, _ = quick_adc
        assert np.sum(np.abs(params["w2"])) <= 1.0 + 1e-4

    def test_naive_variant_trains(self):
        params, info = train_periph.train_nnadc(steps=100, seed=3,
                                                hardware_aware=False)
        v, codes = train_periph.adc_transfer(params)
        assert np.all(np.diff(codes) >= 0)


class TestLinearityMetrics:
    def test_dnl_inl_of_ideal_staircase(self):
        # perfect Eq.-(12) quantizer -> DNL = INL = 0
        v = np.linspace(0, 1, 1 << 14)
        codes = np.clip(np.round(v * 255), 0, 255)
        dnl, inl, missing = train_periph.dnl_inl(v, codes, 8)
        assert missing == 0
        assert np.max(np.abs(dnl)) < 0.02
        assert np.max(np.abs(inl)) < 0.02

    def test_dnl_detects_wide_code(self):
        v = np.linspace(0, 1, 1 << 14)
        # stretch code 100 by one LSB
        edges = (np.arange(1, 256) - 0.5) / 255.0
        edges[100:] += 1.0 / 255.0
        codes = np.searchsorted(edges, v)
        dnl, inl, missing = train_periph.dnl_inl(v, codes, 8)
        assert dnl.max() > 0.8

    def test_enob_of_ideal_quantizer(self):
        ideal = {
            "w1": np.full(255, 0.9, np.float32),
            "b1": (common.VDD / 2 -
                   0.9 * (np.arange(1, 256) - 0.5) / 255).astype(np.float32),
            "w2": np.full(255, 1 / 255, np.float32),
        }
        enob, sinad = train_periph.enob(ideal)
        assert 7.7 < enob < 8.3


class TestHardwareView:
    def test_quantize_ste_levels(self):
        w = jnp.asarray(np.linspace(-1, 1, 41), jnp.float32)
        q = np.asarray(train_periph._quantize_ste(w, 3))
        assert len(np.unique(np.round(q, 6))) <= 7  # 2*(2^2-1)+1

    def test_noise_is_multiplicative_lognormal(self):
        key = jax.random.PRNGKey(0)
        params = {"w1": jnp.ones((64, 64)), "b1": jnp.zeros((64,))}
        out, _ = train_periph.hardware_view(params, key, 8, 0.05, True)
        w = np.asarray(out["w1"])
        assert np.all(w > 0)
        assert abs(np.std(np.log(w)) - 0.05) < 0.01
        # biases untouched
        assert np.all(np.asarray(out["b1"]) == 0)
