"""Synthetic image-classification dataset (build-time).

The paper's accuracy experiments (Fig. 4a, Fig. 10) run ImageNet-trained
8-bit DNNs. ImageNet and its trained checkpoints are not available in this
environment, so we substitute a compact structured dataset whose *accuracy
degradation mechanism* under the analog dataflows is identical: quantized
activations/weights flow through the same bit-sliced crossbar pipeline, and
noise enters in the same places (per-BL quantization, buffer-cell writes,
lumped analog noise). See DESIGN.md §1 for the substitution argument.

Ten classes, each defined by a smooth random template; samples are drawn by
randomly shifting, scaling, and corrupting the template. The task is easy
enough for a ~15k-parameter CNN to exceed 95% accuracy but hard enough that
dataflow-induced noise measurably degrades it — the regime Fig. 4(a) and
Fig. 10 live in.
"""

from __future__ import annotations

import numpy as np

IMG = 12  # image side
CH = 3  # channels
N_CLASSES = 10


def _smooth_noise(rng: np.random.Generator, size: int, ch: int) -> np.ndarray:
    """Low-frequency random field: upsampled coarse noise."""
    coarse = rng.normal(0.0, 1.0, size=(4, 4, ch))
    # bilinear upsample 4x4 -> size x size
    xi = np.linspace(0, 3, size)
    x0 = np.floor(xi).astype(int)
    x1 = np.minimum(x0 + 1, 3)
    fx = xi - x0
    rows = (1 - fx)[:, None, None] * coarse[x0] + fx[:, None, None] * coarse[x1]
    cols = (1 - fx)[None, :, None] * rows[:, x0] + fx[None, :, None] * rows[:, x1]
    return cols


def class_templates(seed: int = 3) -> np.ndarray:
    """(N_CLASSES, IMG, IMG, CH) smooth templates, unit-normalized."""
    rng = np.random.default_rng(seed)
    t = np.stack([_smooth_noise(rng, IMG, CH) for _ in range(N_CLASSES)])
    t -= t.mean(axis=(1, 2, 3), keepdims=True)
    t /= t.std(axis=(1, 2, 3), keepdims=True) + 1e-9
    return t


def sample_batch(templates: np.ndarray, n: int, rng: np.random.Generator,
                 noise: float = 0.55):
    """Draw n labelled samples: shifted/scaled template + distractor + noise.

    Returns (images float32 in [0, 1], labels int32).
    """
    labels = rng.integers(0, N_CLASSES, size=n)
    distract = (labels + rng.integers(1, N_CLASSES, size=n)) % N_CLASSES
    imgs = np.empty((n, IMG, IMG, CH), dtype=np.float32)
    for i in range(n):
        base = templates[labels[i]]
        dx, dy = rng.integers(-2, 3, size=2)
        base = np.roll(np.roll(base, dx, axis=0), dy, axis=1)
        amp = rng.uniform(0.8, 1.2)
        img = amp * base + 0.35 * templates[distract[i]] + rng.normal(0, noise, base.shape)
        imgs[i] = img
    # map to [0, 1] with a fixed affine so quantization scales are stable
    imgs = np.clip(imgs / 8.0 + 0.5, 0.0, 1.0)
    return imgs.astype(np.float32), labels.astype(np.int32)


def make_splits(seed: int = 3, n_train: int = 8192, n_test: int = 512):
    """Deterministic train/test splits."""
    templates = class_templates(seed)
    rng_tr = np.random.default_rng(seed + 1)
    rng_te = np.random.default_rng(seed + 2)
    xtr, ytr = sample_batch(templates, n_train, rng_tr)
    xte, yte = sample_batch(templates, n_test, rng_te)
    return (xtr, ytr), (xte, yte)
