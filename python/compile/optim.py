"""Minimal Adam optimizer (optax is not available in this environment).

Matches the paper's training setup (§6.2: SGD with Adam optimizer).
Operates on pytrees of jnp arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1**tf)
    vhat_scale = 1.0 / (1 - b2**tf)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}
