"""Offline hardware-aware training of the NeuralPeriph circuits (§4).

Two circuits are trained, exactly following the paper's four-step framework
(§4.1.2) and the input-range-aware NNADC technique (§4.2):

NNS+A  — a 10-input (8 BL pairs + carried sum + bias) x H x 1 MLP with
         inverter-VTC activations approximating the cyclic shift-and-add
         ground truth of common.sa_ground_truth.
NNADC  — an 8-stage pipelined quantizer; each stage is a tiny MLP with VTC
         activations approximating the 1-bit MDAC function
         (bit = v > 1/2, residue = 2v - bit), trained per-stage with
         teacher forcing. Three range-aware variants (V_max = 0.5, 0.25,
         0.125 of VDD) plus one naively-trained variant for the Fig. 9(b)
         ablation.

Hardware-aware ingredients (§4.1.2 step 4), all implemented:
  - per-neuron VTC corners sampled from the A_VTC bank every minibatch
    (PVT variation of the CMOS inverters);
  - A_R = 3-bit weight quantization via straight-through estimator;
  - lognormal conductance perturbation W <- W * e^theta, theta~N(0, 0.025);
  - weight clipping to the passive-crossbar constraint (Eq. 11): entries
    within +-2/fan_in (the pseudo-differential pair gives 2x the
    single-device headroom) and column L1 norms <= 1;
  - Gaussian input noise modelling S/H thermal noise.

The "naive" variants skip all of the above — they are the paper's
"without circuit-level optimization" ablation (Fig. 9b).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import common, optim
from compile.kernels import ref


# ---------------------------------------------------------------------------
# Hardware-aware parameter transforms
# ---------------------------------------------------------------------------


def _clip_columns(w, entry_max):
    """Eq. (11): per-entry clip and per-column (output neuron) L1 <= 1."""
    w = jnp.clip(w, -entry_max, entry_max)
    col = jnp.sum(jnp.abs(w), axis=0, keepdims=True)
    return w * jnp.minimum(1.0, 1.0 / (col + 1e-9))


def _quantize_ste(w, bits):
    """A_R-bit symmetric weight quantization, straight-through gradient."""
    scale = jnp.max(jnp.abs(w)) + 1e-9
    levels = 2 ** (bits - 1) - 1
    q = jnp.round(w / scale * levels) / levels * scale
    return w + jax.lax.stop_gradient(q - w)


def hardware_view(params, key, ar_bits, sigma, hardware_aware: bool):
    """The parameters the circuit actually realizes for one minibatch:
    quantized to RRAM precision and perturbed by device variation."""
    if not hardware_aware:
        return params, key
    out = {}
    for name, w in params.items():
        key, sub = jax.random.split(key)
        if name.startswith("w"):
            wq = _quantize_ste(w, ar_bits)
            noise = jnp.exp(sigma * jax.random.normal(sub, w.shape))
            out[name] = wq * noise
        else:
            out[name] = w
    return out, key


# ---------------------------------------------------------------------------
# NNS+A training (§4.1.2)
# ---------------------------------------------------------------------------


def init_sa_params(key, hidden: int, n_dac: int, carry_w=None):
    """Analytic-linear initialization: every hidden neuron points along the
    target linear map t = [2^0..2^7, alpha*2^-N_DAC] / alpha, with biases
    spread around Vm so the inverter bank covers the operating range
    piecewise-linearly; w2 starts at the least-squares slope of the tt VTC.
    Training then only has to absorb the hardware constraints."""
    k1, k2 = jax.random.split(key)
    cw = 2.0 ** (-n_dac) if carry_w is None else carry_w
    t = np.concatenate([2.0 ** np.arange(8), [common.sa_alpha(n_dac) * cw]])
    t = t / common.sa_alpha(n_dac)  # effective weights of the ground truth
    # choose the crossbar attenuation c so the output layer only needs
    # sum|w2| ~ 0.8 < 1 (Eq. 11 headroom) given the tt VTC slope.
    slope_mag = common.VDD * common.VTC_GAIN_TT / 4.0
    c = 1.0 / (slope_mag * 0.8)
    w1 = np.tile((c * t)[:, None], (1, hidden))
    w1 = w1 * (1.0 + 0.05 * np.asarray(jax.random.normal(k1, w1.shape)))
    # differential inputs are zero-centered, so the neurons sit at Vm with
    # a spread that linearizes the VTC over the whole input range.
    b1 = common.VDD / 2 + np.linspace(-0.03, 0.03, hidden)
    slope = -slope_mag  # VTC derivative at Vm (falling inverter curve)
    w2 = np.full((hidden, 1), 1.0 / (slope * c * hidden))
    w2 = w2 * (1.0 + 0.05 * np.asarray(jax.random.normal(k2, w2.shape)))
    # output bias compensates the VTC midpoint VDD/2 through w2
    b2 = -float(np.sum(w2) * common.VDD / 2)
    return {
        "w1": jnp.asarray(w1, jnp.float32),
        "b1": jnp.asarray(b1, jnp.float32),
        "w2": jnp.asarray(w2, jnp.float32),
        "b2": jnp.asarray([b2], jnp.float32),
    }


def _project_sa(params, hidden: int):
    params["w1"] = _clip_columns(params["w1"], 2.0 / 10.0)
    params["w2"] = _clip_columns(params["w2"], 2.0 / hidden)
    params["b1"] = jnp.clip(params["b1"], 0.0, common.VDD)
    params["b2"] = jnp.clip(params["b2"], -common.VDD, common.VDD)
    return params


def sa_batch(key, batch: int, n_dac: int, carry_w=None):
    """Ground-truth pairs for one NNS+A cycle (§4.1.2 step 3).

    BL voltages are drawn uniformly over the analog range; the carried sum
    over its own (bounded) range. Returns (v_in (B, 9), v_gt (B,))."""
    k1, k2 = jax.random.split(key)
    # BL voltages are *differential* (the W+/W- pseudo-differential pairs of
    # Fig. 7c reject the common mode), so they are signed and span half the
    # analog range on each side of zero; same for the carried sum.
    half = common.V_RANGE / 2
    v_bl = jax.random.uniform(k1, (batch, 8), minval=-half, maxval=half)
    v_prev = jax.random.uniform(k2, (batch,), minval=-half, maxval=half)
    v_gt = common.sa_ground_truth(v_bl, v_prev, n_dac, carry_w)
    return jnp.concatenate([v_bl, v_prev[:, None]], axis=-1), v_gt


def train_nns_a(n_dac: int, hidden: int = 12, steps: int = 4000, batch: int = 512,
                lr: float = 3e-3, seed: int = 0, hardware_aware: bool = True,
                n_vtc: int = 16, input_noise: float = 5e-4,
                ar_bits: int = common.AR_BITS, sigma: float = common.RRAM_SIGMA,
                carry_w=None):
    """Train one NNS+A model. Returns (params, info dict).

    carry_w = None trains the LSB-first radix carry (2^-N_DAC); the
    MSB-first ablation trains carry_w = 1.0 (DAC-side attenuation carries
    the radix instead; see model.mc_dot_products)."""
    vtc_bank = jnp.asarray(vtc_bank_np := common.vtc_corner_bank(n_vtc))
    key = jax.random.PRNGKey(seed)
    key, kp = jax.random.split(key)
    params = _project_sa(init_sa_params(kp, hidden, n_dac, carry_w), hidden)
    opt = optim.adam_init(params)

    def loss_fn(p, key):
        p_hw, key = hardware_view(p, key, ar_bits, sigma, hardware_aware)
        key, kb, kn, kv = jax.random.split(key, 4)
        v_in, v_gt = sa_batch(kb, batch, n_dac, carry_w)
        if hardware_aware:
            v_in = v_in + input_noise * jax.random.normal(kn, v_in.shape)
            idx = jax.random.randint(kv, (hidden,), 0, n_vtc)
            vm, gain = vtc_bank[idx, 0], vtc_bank[idx, 1]
        else:
            vm, gain = vtc_bank[0, 0], vtc_bank[0, 1]
        pred = ref.mlp_vtc_ref(v_in, p_hw["w1"], p_hw["b1"], p_hw["w2"], p_hw["b2"],
                               vm, gain)[:, 0]
        return jnp.mean((pred - v_gt) ** 2)

    @jax.jit
    def step(params, opt, key, lr_t):
        key, kl = jax.random.split(key)
        loss, grads = jax.value_and_grad(loss_fn)(params, kl)
        params, opt = optim.adam_update(grads, opt, params, lr=lr_t)
        params = _project_sa(params, hidden)
        return params, opt, key, loss

    loss = jnp.inf
    for i in range(steps):
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * i / steps))  # cosine decay
        params, opt, key, loss = step(params, opt, key, lr_t)

    # Final hardware instantiation: quantize once (the one-time programming
    # of the RRAM conductances, §5.1 footnote 4).
    final = dict(params)
    if hardware_aware:
        final["w1"] = _quantize_ste(final["w1"], ar_bits)
        final["w2"] = _quantize_ste(final["w2"], ar_bits)

    # Evaluate approximation error at the tt corner (Table 1 row).
    v_in, v_gt = sa_batch(jax.random.PRNGKey(seed + 99), 8192, n_dac, carry_w)
    pred = ref.mlp_vtc_ref(v_in, final["w1"], final["b1"], final["w2"], final["b2"],
                           vtc_bank_np[0, 0], vtc_bank_np[0, 1])[:, 0]
    err = np.asarray(pred - v_gt)
    info = {
        "mse": float(np.mean(err**2)),
        "max_error_v": float(np.max(err)),
        "min_error_v": float(np.min(err)),
        "final_train_loss": float(loss),
        "n_dac": n_dac,
        "hidden": hidden,
        "hardware_aware": hardware_aware,
    }
    return {k: np.asarray(v) for k, v in final.items()}, info


# ---------------------------------------------------------------------------
# NNADC training (§4.2): flash-style threshold bank (architecture of [34])
#
# The NNADC of ref [34] achieves multi-bit quantization with a single
# hidden layer of threshold inverters: neuron i fires when w1_i*v + b1_i
# crosses the inverter switching point, and a unit-budget passive output
# column sums the fired thermometer steps, so the analog sum *is* the code
# (regenerated by the output latch). An MDAC-style 1-bit/stage pipeline is
# NOT realizable with passive output crossbars (the x2 residue slope
# violates Eq. 11), which is precisely why [34] uses the flash structure.
#
# Training per §4.2: noisy inputs (the real NNS+A output distribution) with
# ideal Eq.-(12) labels; per-neuron VTC corners; lognormal threshold
# variation; 3-bit STE quantization of w1/w2 (thresholds realize
# super-resolution through the trained w1/b1 ratio, the point of [34]).
# Three range-aware variants (V_max/VDD = 0.5, 0.25, 0.125) plus a naive
# full-range variant for the Fig. 9(b) ablation.
# ---------------------------------------------------------------------------


def init_adc_params(n_bits: int, hidden: int = 0, seed: int = 0):
    """One threshold inverter per code transition: neuron k fires when v
    crosses the Eq.-(12) rounding boundary (k - 0.5)/(2^n - 1); the summing
    column adds exactly one LSB per fired neuron (unit budget: L1 = 1)."""
    del seed
    levels = 2**n_bits - 1
    hidden = hidden or levels
    assert hidden == levels, "flash bank is one neuron per code transition"
    t = (np.arange(1, levels + 1) - 0.5) / levels
    w1 = np.full((hidden,), 0.9)
    b1 = common.VDD / 2 - w1 * t
    w2 = np.full((hidden,), 1.0 / levels)  # each fired step adds one LSB
    return {
        "w1": jnp.asarray(w1, jnp.float32),
        "b1": jnp.asarray(b1, jnp.float32),
        "w2": jnp.asarray(w2, jnp.float32),
    }


def _project_adc(params):
    params["w1"] = jnp.clip(params["w1"], -1.0, 1.0)
    # output column is passive: entries bounded, L1 <= 1 (Eq. 11)
    w2 = jnp.clip(params["w2"], -0.1, 0.1)
    tot = jnp.sum(jnp.abs(w2))
    params["w2"] = w2 * jnp.minimum(1.0, 1.0 / (tot + 1e-9))
    params["b1"] = jnp.clip(params["b1"], -common.VDD, 2 * common.VDD)
    return params


def train_nnadc(n_bits: int = 8, hidden: int = 0, steps: int = 1500,
                batch: int = 2048, lr: float = 3e-5, seed: int = 1,
                hardware_aware: bool = True, input_noise: float = 1e-3,
                n_vtc: int = 16, ar_bits: int = common.AR_BITS,
                sigma: float = 0.002):
    """Train/calibrate one flash NNADC. Returns (params, info).

    Two-phase procedure mirroring how [34]/[38] program a real die:

    1. *Analytic calibration*: the per-comparator PVT corners (vm_i) are
       measured at programming time, and the threshold biases are
       write-verify-programmed ([38]) to the closed-form optimum
       b1_i = vm_i - w1_i * t_i of the Eq.-(12) learning objective.
    2. *Noise-aware fine-tune*: a short keep-best SGD pass with noisy
       inputs (the NNS+A output distribution, §4.2), RRAM *read*
       fluctuation (sigma = 0.2%; programming variation is already
       compensated by write-verify) and 3-bit STE weight quantization.
       Hard-forward/soft-backward: the latched transfer is optimized with
       the pre-latch analog curve as surrogate gradient. The best-so-far
       parameters on a clean validation ramp are kept, so fine-tuning can
       only improve on the calibrated starting point.

    ``input_noise`` is in normalized-range units: a range-aware variant for
    V_max = 0.125*VDD sees the same absolute NNS+A noise scaled by 1/V_max,
    which the caller folds in.
    """
    vtc_bank_np = common.vtc_corner_bank(n_vtc, seed=11,
                                         gain_tt=common.VTC_GAIN_ADC)
    levels = 2**n_bits - 1
    hidden = hidden or levels
    key = jax.random.PRNGKey(seed)
    params = init_adc_params(n_bits, hidden, seed)

    # chip instance: one fixed PVT corner per comparator
    if hardware_aware:
        inst_rng = np.random.default_rng(seed + 77)
        idx = inst_rng.integers(0, n_vtc, size=hidden)
        vm_inst = jnp.asarray(vtc_bank_np[idx, 0], jnp.float32)
        gain_inst = jnp.asarray(vtc_bank_np[idx, 1], jnp.float32)
    else:
        vm_inst = jnp.full((hidden,), common.VDD / 2, jnp.float32)
        gain_inst = jnp.full((hidden,), common.VTC_GAIN_ADC, jnp.float32)

    # phase 1: analytic write-verify calibration of the threshold biases
    t = (np.arange(1, hidden + 1) - 0.5) / levels
    params["b1"] = vm_inst - params["w1"] * jnp.asarray(t, jnp.float32)
    params = _project_adc(params)
    opt = optim.adam_init(params)

    def latched_codes(p, vm):
        vval = jnp.linspace(0.0, 1.0, 4096)
        pre = vval[:, None] * p["w1"][None, :] + p["b1"][None, :]
        u = 1.0 - common.vtc_apply(pre, vm, common.VTC_GAIN_LATCH) / common.VDD
        return jnp.mean((u @ p["w2"] - jnp.round(vval * levels) / levels) ** 2)

    val_loss = jax.jit(functools.partial(latched_codes, vm=vm_inst))

    def loss_fn(p, key):
        p_hw, key = hardware_view(p, key, ar_bits, sigma, hardware_aware)
        key, kb, kn = jax.random.split(key, 3)
        v = jax.random.uniform(kb, (batch,))
        code_gt = jnp.round(v * levels) / levels  # Eq. (12) label, normalized
        v_obs = v + (input_noise * jax.random.normal(kn, v.shape)
                     if hardware_aware else 0.0)
        pre = v_obs[:, None] * p_hw["w1"][None, :] + p_hw["b1"][None, :]
        u_soft = 1.0 - common.vtc_apply(pre, vm_inst, gain_inst) / common.VDD
        u_hard = 1.0 - common.vtc_apply(pre, vm_inst,
                                        common.VTC_GAIN_LATCH) / common.VDD
        u = u_soft + jax.lax.stop_gradient(u_hard - u_soft)
        soft = u @ p_hw["w2"]
        return jnp.mean((soft - code_gt) ** 2)

    @jax.jit
    def step(params, opt, key, lr_t):
        key, kl = jax.random.split(key)
        loss, grads = jax.value_and_grad(loss_fn)(params, kl)
        params, opt = optim.adam_update(grads, opt, params, lr=lr_t)
        params = _project_adc(params)
        return params, opt, key, loss

    best = {k: np.asarray(v) for k, v in params.items()}
    best_val = float(val_loss(params))
    loss = best_val
    for i in range(steps):
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * i / steps))
        params, opt, key, loss = step(params, opt, key, lr_t)
        if (i + 1) % 250 == 0:
            vl = float(val_loss(params))
            if vl < best_val:
                best_val = vl
                best = {k: np.asarray(v) for k, v in params.items()}

    final = {k: jnp.asarray(v) for k, v in best.items()}
    if hardware_aware:
        # w1 is programmed at RRAM precision; the summing column w2 uses one
        # repeated device value (one LSB per fired step) so A_R covers it.
        # b1 keeps its write-verified value up to the residual tuning error
        # of the write-verify loop ([38] reports sub-percent precision) —
        # modelled as a 0.2-LSB threshold placement jitter.
        final["w1"] = _quantize_ste(final["w1"], ar_bits)
        final["w2"] = _quantize_ste(final["w2"], ar_bits)
        jit_rng = np.random.default_rng(seed + 177)
        write_jitter = 0.2 / levels  # input-referred, normalized units
        final["b1"] = final["b1"] + jnp.asarray(
            jit_rng.normal(0.0, write_jitter, hidden) * np.asarray(final["w1"]),
            jnp.float32)
    out = {k: np.asarray(v) for k, v in final.items()}
    out["vm"] = np.asarray(vm_inst)
    out["gain"] = np.asarray(gain_inst)
    info = {"final_train_loss": float(loss), "val_loss": best_val,
            "n_bits": n_bits, "hidden": hidden,
            "hardware_aware": hardware_aware}
    return out, info


# ---------------------------------------------------------------------------
# NNADC linearity metrics (Table 1): DNL / INL / ENOB
# ---------------------------------------------------------------------------


def adc_transfer(params, n_points: int = 1 << 13, vm=None, gain=None,
                 n_bits: int = 8):
    """Evaluate the NNADC over a fine input ramp. Returns (v, codes).

    Comparator offsets (per-neuron vm) come from the chip instance stored
    with the params; the latch makes every decision full-swing, so the
    effective gain is VTC_GAIN_LATCH regardless of the analog pre-gain."""
    vm = params.get("vm", common.VDD / 2) if vm is None else vm
    gain = common.VTC_GAIN_LATCH if gain is None else gain
    v = jnp.linspace(0.0, 1.0, n_points)
    codes, _ = ref.nnadc_flash_ref(v, jnp.asarray(params["w1"]),
                                   jnp.asarray(params["b1"]),
                                   jnp.asarray(params["w2"]),
                                   jnp.asarray(vm), gain, n_bits)
    return np.asarray(v), np.asarray(codes)


def dnl_inl(v, codes, n_bits: int = 8):
    """Code-transition DNL/INL in LSB from a ramp sweep."""
    n_codes = 2**n_bits
    lsb = 1.0 / (n_codes - 1)
    transitions = np.full(n_codes - 1, np.nan)
    for i in range(1, len(codes)):
        if codes[i] > codes[i - 1]:
            lo = int(codes[i - 1])
            hi = int(codes[i])
            for c in range(max(lo, 0), min(hi, n_codes - 1)):
                if np.isnan(transitions[c]):
                    transitions[c] = v[i]
    valid = ~np.isnan(transitions)
    # Eq.-(12) rounding transitions sit at (k - 0.5) * lsb
    ideal = (np.arange(1, n_codes) - 0.5) * lsb
    dnl = np.diff(transitions) / lsb - 1.0
    dnl = dnl[valid[1:] & valid[:-1]]
    inl = (transitions[valid] - ideal[valid]) / lsb
    missing = int(np.sum(~valid))
    return dnl, inl, missing


def enob(params, n_samples: int = 1 << 13, n_bits: int = 8):
    """Sine-test ENOB: quantize a full-scale sine, reconstruct, measure
    SINAD, ENOB = (SINAD - 1.76) / 6.02."""
    t = np.arange(n_samples, dtype=np.float64)
    vsig = 0.5 + 0.4999 * np.sin(2 * np.pi * 127 * t / n_samples)
    codes, _ = ref.nnadc_flash_ref(jnp.asarray(vsig, jnp.float32),
                                   jnp.asarray(params["w1"]),
                                   jnp.asarray(params["b1"]),
                                   jnp.asarray(params["w2"]),
                                   jnp.asarray(params.get("vm", common.VDD / 2)),
                                   common.VTC_GAIN_LATCH, n_bits)
    recon = np.asarray(codes, np.float64) / (2**n_bits - 1)
    err = recon - vsig
    p_sig = np.mean((vsig - vsig.mean()) ** 2)
    p_noise = np.mean((err - err.mean()) ** 2)
    sinad = 10 * np.log10(p_sig / p_noise)
    return (sinad - 1.76) / 6.02, sinad
