"""Shared numeric models for the Neural-PIM compile path.

Everything here is *build-time* Python: it defines the voltage-domain
behavioural models (inverter VTC, quantizers, bit slicing) that both the
training scripts and the AOT-lowered inference graphs share.

Conventions
-----------
- Voltages are normalized to VDD = 1.0 (the paper's 1.2 V rail). The analog
  signal range used by the NeuralPeriph circuits is [0, V_RANGE] with
  V_RANGE = 0.5 (paper Table 1: input range [0, 0.5] V of a 1.2 V rail,
  i.e. ~0.417*VDD; we keep the paper's 0.5 figure in volts and normalize
  the rail to 1.2 so the numbers below read like the paper's).
- Digital values: inputs are PI-bit unsigned, weights PW-bit signed
  (stored as W+ / W- unsigned pairs), outputs PO-bit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Global hardware constants (paper §3.3, §6.2, Table 1)
# ---------------------------------------------------------------------------

VDD = 1.2  # volts
V_RANGE = 0.5  # analog full-scale of NeuralPeriph inputs/outputs, volts
PI = 8  # input (activation) precision, bits
PW = 8  # weight precision, bits
PO = 8  # output precision, bits
PR = 1  # RRAM cell precision in VMM computing arrays, bits
N_ROWS = 128  # crossbar rows (2^N with N = 7)
AR_BITS = 3  # RRAM precision available to NeuralPeriph weights (Table 1)
RRAM_SIGMA = 0.025  # lognormal conductance variation (Table 1)


@dataclasses.dataclass(frozen=True)
class DataflowConfig:
    """Array-level dataflow parameters (paper §3.2)."""

    pi: int = PI  # input precision
    pw: int = PW  # weight precision
    po: int = PO  # output precision
    pr: int = PR  # RRAM cell precision
    pd: int = 1  # DAC resolution
    rows: int = N_ROWS  # crossbar rows used by one dot-product group

    @property
    def n_slices(self) -> int:
        """Input cycles: ceil(PI / PD) (Eq. 8)."""
        return -(-self.pi // self.pd)

    @property
    def n_weight_cols(self) -> int:
        """RRAM columns per (unsigned) weight: ceil(PW / PR)."""
        return -(-self.pw // self.pr)


# ---------------------------------------------------------------------------
# Inverter VTC (the CMOS analog neuron, §4.1.1 footnote 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VtcParams:
    """A CMOS inverter voltage-transfer curve under one PVT corner.

    Modelled as a falling logistic: out = VDD / (1 + exp(gain * (v - vm))).
    ``vm`` is the switching threshold, ``gain`` the small-signal gain at vm
    (in 1/V). PVT variation moves both.
    """

    vm: float
    gain: float

    def __call__(self, v):
        return vtc_apply(v, self.vm, self.gain)


VTC_GAIN_TT = 25.0  # single-inverter small-signal gain at Vm, 1/V
VTC_GAIN_ADC = 120.0  # cascaded-inverter (2-stage) neuron used by the NNADC
# The NNADC threshold columns end in a regenerative latch (a 3-inverter
# chain) that snaps the comparator decision to the rails before the summing
# column — modelled as a very steep VTC. Training uses VTC_GAIN_ADC (the
# pre-latch analog gain, so gradients flow); the instantiated converter is
# evaluated at the latch gain.
VTC_GAIN_LATCH = 2400.0


def vtc_corner_bank(n_vtc: int, seed: int = 7, gain_tt: float = VTC_GAIN_TT) -> np.ndarray:
    """A_VTC: a bank of inverter VTCs across PVT corners (§4.1.2 step 4).

    Returns an (n_vtc, 2) array of (vm, gain). The tt corner sits at
    vm = VDD/2; corners move vm by +-2% VDD (~+-24 mV threshold mismatch,
    the 130 nm-class spread) and gain by +-10%.
    """
    rng = np.random.default_rng(seed)
    vm = VDD / 2 + rng.uniform(-0.02, 0.02, size=n_vtc) * VDD
    gain = gain_tt * (1.0 + rng.uniform(-0.1, 0.1, size=n_vtc))
    out = np.stack([vm, gain], axis=1)
    out[0] = (VDD / 2, gain_tt)  # index 0 is always the typical-typical corner
    return out


def vtc_apply(v, vm, gain):
    """Vectorized VTC evaluation (works per-neuron with broadcast params).

    Uses the numerically-stable sigmoid: the naive 1/(1+exp(x)) form
    produces NaN gradients once gain*(v-vm) overflows f32.
    """
    return VDD * jax.nn.sigmoid(-gain * (v - vm))


# ---------------------------------------------------------------------------
# Quantizers
# ---------------------------------------------------------------------------


def quantize_uniform(v, levels, full_scale):
    """Ideal uniform quantizer: round v in [0, full_scale] to ``levels``
    steps and return the *dequantized* value (same units as v).

    ``levels`` = 2^bits - 1 may be a traced scalar so one lowered module
    serves every A/D resolution in a sweep (Fig. 4a).
    """
    v = jnp.clip(v, 0.0, full_scale)
    code = jnp.round(v / full_scale * levels)
    return code / levels * full_scale


def quantize_signed(v, levels, full_scale):
    """Uniform quantizer for signed values in [-full_scale, full_scale]."""
    v = jnp.clip(v, -full_scale, full_scale)
    code = jnp.round(v / full_scale * levels)
    return code / levels * full_scale


def adc_code(v, bits, v_max):
    """Eq. (12): range-aware digital code of an analog value."""
    levels = 2**bits - 1
    return jnp.clip(jnp.round(v / v_max * levels), 0, levels)


# ---------------------------------------------------------------------------
# Bit slicing (wordline side) and weight decomposition (array side)
# ---------------------------------------------------------------------------


def input_bit_slices(x_u8, pd: int, pi: int = PI):
    """Split PI-bit unsigned ints into ceil(PI/PD) PD-bit slices, LSB first.

    x_u8: integer array with values in [0, 2^PI). Returns float32 array of
    shape (n_slices,) + x.shape with each slice in [0, 2^PD).
    LSB-first ordering is the paper's streaming order (§4.1.2 step 3).
    """
    n = -(-pi // pd)
    x = x_u8.astype(jnp.int32)
    slices = []
    for i in range(n):
        slices.append(((x >> (pd * i)) & ((1 << pd) - 1)).astype(jnp.float32))
    return jnp.stack(slices, axis=0)


def weight_bit_planes(w_u8, pr: int = PR, pw: int = PW):
    """Split PW-bit unsigned weights into ceil(PW/PR) PR-bit planes, LSB first.

    w_u8: integer array in [0, 2^PW). Returns float32 (n_planes,) + w.shape.
    """
    n = -(-pw // pr)
    w = w_u8.astype(jnp.int32)
    planes = []
    for j in range(n):
        planes.append(((w >> (pr * j)) & ((1 << pr) - 1)).astype(jnp.float32))
    return jnp.stack(planes, axis=0)


def split_signed_weight(w_int, pw: int = PW):
    """W = W+ - W- decomposition (§5.2.1). w_int in [-(2^(PW-1)), 2^(PW-1))."""
    w = w_int.astype(jnp.int32)
    return jnp.maximum(w, 0), jnp.maximum(-w, 0)


# ---------------------------------------------------------------------------
# Ideal NNS+A ground truth (§4.1.2 step 3)
#
# The paper writes the per-cycle ground truth as
#     V_o,GT = (2^-N_DAC * V_o,i-1 + sum_j 2^j V_in,j) / alpha,
#     alpha = 2^-N_DAC + sum_j 2^j,
# but applying the alpha division to the *carried* term every cycle breaks
# the radix relationship between input cycles (cycle i+1 would end up
# weighted alpha*2^N_DAC relative to cycle i instead of 2^N_DAC), i.e. the
# unrolled accumulator would no longer be a scaled version of the digital
# dot product. A physical S+A must preserve the radix, so we use the
# exactness-preserving reading of the same equation:
#     V_o,i = 2^-N_DAC * V_o,i-1 + (sum_j 2^j V_in,j) / alpha
# with alpha chosen so the accumulator never exceeds the input full-scale:
#     alpha = 2^N_DAC * (2^8 - 1) / (2^N_DAC - 1).
# Then V_o,S = D / (alpha * 2^(N_DAC*(S-1))) exactly, where D is the
# integer dot product with BL voltages in unit encoding. The trained NNS+A
# approximates this function; the distinction from the paper's literal
# formula is only which linear map the network is asked to learn, and this
# one makes Strategy C compute a true dot product (see DESIGN.md §5).
# ---------------------------------------------------------------------------


def sa_alpha(n_dac: int, n_bl: int = PW) -> float:
    """Input-sum normalization keeping the cyclic accumulator in range."""
    return 2.0**n_dac * float(2**n_bl - 1) / (2.0**n_dac - 1.0)


def sa_ground_truth(v_in, v_prev, n_dac: int, carry_w: float | None = None):
    """One NNS+A cycle: V_o = carry_w * V_prev + (sum_j 2^j V_in[j]) / alpha.

    v_in: (..., 8) BL voltages; v_prev: (...,) carried intermediate sum.
    carry_w defaults to 2^-N_DAC (the LSB-first radix carry); the MSB-first
    schedule uses carry_w = 1 with DAC-side input attenuation instead.
    """
    if carry_w is None:
        carry_w = 2.0 ** (-n_dac)
    n_bl = v_in.shape[-1]
    weights = 2.0 ** jnp.arange(n_bl, dtype=jnp.float32)
    s = jnp.sum(v_in * weights, axis=-1) / sa_alpha(n_dac, n_bl)
    return carry_w * v_prev + s


def sa_unroll_ground_truth(v_slices, n_dac: int):
    """Ideal Strategy-C analog accumulation over all input cycles.

    v_slices: (n_slices, ..., 8) per-cycle BL voltages, LSB-first.
    Returns the final normalized analog sum (...,).
    """
    acc = jnp.zeros(v_slices.shape[1:-1], dtype=jnp.float32)
    for i in range(v_slices.shape[0]):
        acc = sa_ground_truth(v_slices[i], acc, n_dac)
    return acc


def sa_unrolled_scale(n_slices: int, n_dac: int, n_bl: int = PW) -> float:
    """K such that the final accumulator V_o,S = D / K, with D the digital
    dot product sum_{i,j} 2^(N_DAC*i + j) p_ij and BL voltages encoding
    p_ij in unit steps. K = alpha * 2^(N_DAC*(S-1))."""
    return sa_alpha(n_dac, n_bl) * 2.0 ** (n_dac * (n_slices - 1))
