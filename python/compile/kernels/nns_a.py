"""L1 Pallas kernel: the trained NNS+A applied cyclically.

The grid dimension is the input bit-slice cycle; the output block is
revisited every step and carries the intermediate analog sum — exactly the
S/H feedback loop of Fig. 5(a). The 3-layer MLP (crossbar-VMM -> inverter
VTC -> crossbar-VMM) runs entirely inside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import common


def _kernel(v_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, vm: float, gain: float):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = v_ref[0]  # (B, 8) this cycle's BL voltages
    vin = jnp.concatenate([v, o_ref[...]], axis=-1)  # (B, 9): 9th = carried sum
    pre = jnp.dot(vin, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = common.vtc_apply(pre, vm, gain)
    o_ref[...] = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]


def nns_a_cyclic(v_slices, w1, b1, w2, b2, vm: float = common.VDD / 2,
                 gain: float = 25.0, interpret: bool = True):
    """Apply the trained NNS+A over all input cycles.

    v_slices: (S, B, 8) per-cycle BL voltages (LSB first).
    w1: (9, H); b1: (H,); w2: (H, 1); b2: (1,). Returns (B,) final output.
    """
    n_slices, b, n_bl = v_slices.shape
    assert n_bl == 8 and w1.shape[0] == 9
    h = w1.shape[1]
    kernel = functools.partial(_kernel, vm=vm, gain=gain)
    out = pl.pallas_call(
        kernel,
        grid=(n_slices,),
        in_specs=[
            pl.BlockSpec((1, b, n_bl), lambda s: (s, 0, 0)),
            pl.BlockSpec((9, h), lambda s: (0, 0)),
            pl.BlockSpec((h,), lambda s: (0,)),
            pl.BlockSpec((h, 1), lambda s: (0, 0)),
            pl.BlockSpec((1,), lambda s: (0,)),
        ],
        out_specs=pl.BlockSpec((b, 1), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(v_slices, w1, b1, w2, b2)
    return out[:, 0]
