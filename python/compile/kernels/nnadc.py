"""L1 Pallas kernel: the flash-style NNADC forward.

A bank of H threshold inverters evaluated against a batch of analog
inputs; the unit-budget output column sums the fired thermometer steps and
the output latch regenerates the digital code. The grid tiles the batch;
each step holds the full (small) threshold bank in VMEM. Per-comparator
switching points (the chip instance's PVT corners) ride along as an input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import common

B_TILE = 256


def _kernel(v_ref, w1_ref, b1_ref, w2_ref, vm_ref, soft_ref, *, gain: float):
    v = v_ref[...]  # (B_TILE, 1)
    pre = v * w1_ref[...][None, :] + b1_ref[...][None, :]  # (B_TILE, H)
    u = 1.0 - common.vtc_apply(pre, vm_ref[...][None, :], gain) / common.VDD
    soft_ref[...] = jnp.dot(u, w2_ref[...][:, None],
                            preferred_element_type=jnp.float32)


def nnadc_convert(v, w1, b1, w2, vm=None, gain: float = common.VTC_GAIN_LATCH,
                  n_bits: int = 8, interpret: bool = True):
    """Convert analog values in [0, 1] to digital codes.

    v: (B,); w1/b1/w2: (H,); vm: scalar or (H,) comparator switching points.
    Returns (codes (B,), soft (B,)).
    """
    b = v.shape[0]
    h = w1.shape[0]
    if vm is None:
        vm = common.VDD / 2
    vm = jnp.broadcast_to(jnp.asarray(vm, jnp.float32), (h,))
    b_pad = -(-b // B_TILE) * B_TILE
    vp = jnp.pad(v, (0, b_pad - b))[:, None]
    kernel = functools.partial(_kernel, gain=float(gain))
    soft = pl.pallas_call(
        kernel,
        grid=(b_pad // B_TILE,),
        in_specs=[
            pl.BlockSpec((B_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((B_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, 1), jnp.float32),
        interpret=interpret,
    )(vp, w1, b1, w2, vm)[:b, 0]
    levels = 2**n_bits - 1
    codes = jnp.clip(jnp.round(soft * levels), 0, levels)
    return codes, soft
