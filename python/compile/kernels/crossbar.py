"""L1 Pallas kernel: the Strategy-C crossbar dot-product hot path.

This kernel *is* the paper's analog dataflow (Fig. 3c) expressed as a TPU
schedule (DESIGN.md §Hardware-Adaptation):

- the grid's outer dimension is the input bit-slice cycle ``s`` (the analog
  input cycle driven by the N_DAC-bit DACs, LSB first);
- the grid's inner dimension ``t`` walks 128-row K-tiles — the physical
  crossbar row limit becomes the BlockSpec K-tile;
- the output block is revisited on every grid step and carries the NNS+A
  analog accumulator: at the start of each input cycle the carried value is
  scaled by 2^-N_DAC (the S/H + NNS+A recursion), then the per-tile partial
  sums are accumulated in place — the VMEM-resident software analogue of the
  sample-and-hold capacitor.

Run under ``interpret=True`` on CPU; on a real TPU the inner matmul maps to
the MXU with the accumulator resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import common

K_TILE = 128  # physical crossbar rows (2^N, N = 7)


def _kernel(x_ref, w_ref, o_ref, *, pd: int, alpha: float):
    s = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((s == 0) & (t == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when((s > 0) & (t == 0))
    def _carry():
        # NNS+A recursion: the carried intermediate sum from input cycle
        # s-1 is attenuated by 2^-N_DAC before this cycle's partial sums
        # are accumulated (LSB-first streaming, §4.1.2 step 3).
        o_ref[...] = o_ref[...] * (2.0 ** (-pd))

    x = x_ref[0]  # (B, K_TILE) this cycle's bit-slice, this K-tile
    w = w_ref[...]  # (K_TILE, C) radix-weighted differential conductances
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32) / alpha


def radix_weights(w_pos_u8, w_neg_u8, pw: int = 8):
    """Fold the 8 one-bit W+/W- column pairs into their radix-combined
    differential value sum_j 2^j (w+_j - w-_j) = W+ - W-. The per-BL analog
    partial sums recombine linearly through the ideal NNS+A, so the fused
    kernel carries the combined value; the per-BL (non-ideal) path lives in
    dataflow.py / nns_a.py."""
    del pw
    return (w_pos_u8.astype(jnp.int32) - w_neg_u8.astype(jnp.int32)).astype(jnp.float32)


def strategy_c_dot(x_u8, w_pos_u8, w_neg_u8, pd: int, pi: int = 8, pw: int = 8,
                   interpret: bool = True):
    """Ideal Strategy-C dot product via the Pallas schedule.

    x_u8: (B, K) unsigned ints; w_*_u8: (K, C). Returns (B, C) f32 analog
    accumulator in unit encoding — equal to ref.strategy_c_dot_ref and to
    dot_product_int_ref / sa_unrolled_scale(S, pd).
    """
    n_slices = -(-pi // pd)
    b, k = x_u8.shape
    c = w_pos_u8.shape[1]
    k_pad = -(-k // K_TILE) * K_TILE

    xs = common.input_bit_slices(x_u8, pd, pi)  # (S, B, K) f32
    xs = jnp.pad(xs, ((0, 0), (0, 0), (0, k_pad - k)))
    w = radix_weights(w_pos_u8, w_neg_u8, pw)
    w = jnp.pad(w, ((0, k_pad - k), (0, 0)))

    n_tiles = k_pad // K_TILE
    alpha = common.sa_alpha(pd, pw)
    kernel = functools.partial(_kernel, pd=pd, alpha=alpha)
    return pl.pallas_call(
        kernel,
        grid=(n_slices, n_tiles),
        in_specs=[
            pl.BlockSpec((1, b, K_TILE), lambda s, t: (s, 0, t)),
            pl.BlockSpec((K_TILE, c), lambda s, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((b, c), lambda s, t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(xs, w)


def strategy_c_dot_decoded(x_u8, w_pos_u8, w_neg_u8, pd: int, pi: int = 8,
                           pw: int = 8, interpret: bool = True):
    """Strategy-C dot product decoded back to the integer domain: the analog
    accumulator times K = sa_unrolled_scale. Equals X . (W+ - W-) exactly
    (up to f32 rounding)."""
    n_slices = -(-pi // pd)
    acc = strategy_c_dot(x_u8, w_pos_u8, w_neg_u8, pd, pi, pw, interpret)
    return acc * common.sa_unrolled_scale(n_slices, pd, pw)
