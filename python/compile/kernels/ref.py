"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: slow, obvious implementations with
no tiling, no scratch, no grid. pytest (python/tests/) asserts the Pallas
kernels match these to float tolerance across hypothesis-driven shape and
bit-width sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import common


def crossbar_partial_sums_ref(x_u8, w_pos_u8, w_neg_u8, pd: int, pi: int = 8, pw: int = 8):
    """Bit-sliced crossbar partial sums, the analog quantities on the BLs.

    x_u8:      (B, K) unsigned inputs in [0, 2^pi).
    w_pos_u8:  (K, C) unsigned W+ in [0, 2^pw).
    w_neg_u8:  (K, C) unsigned W-.
    Returns (n_slices, n_planes, B, C) float32 *differential* partial sums
    p+ - p-, i.e. what the W+/W- pseudo-differential BL pairs feed the
    NNS+A (Fig. 7c). Values are integers in [-K*(2^pd-1), K*(2^pd-1)].
    """
    xs = common.input_bit_slices(x_u8, pd, pi)  # (S, B, K)
    wp = common.weight_bit_planes(w_pos_u8, 1, pw)  # (J, K, C)
    wn = common.weight_bit_planes(w_neg_u8, 1, pw)
    wdiff = wp - wn
    # p[s, j, b, c] = sum_k xs[s, b, k] * wdiff[j, k, c]
    return jnp.einsum("sbk,jkc->sjbc", xs, wdiff)


def dot_product_int_ref(x_u8, w_pos_u8, w_neg_u8):
    """The exact integer dot product X . (W+ - W-) the dataflows must equal."""
    x = x_u8.astype(jnp.int32)
    w = w_pos_u8.astype(jnp.int32) - w_neg_u8.astype(jnp.int32)
    return (x @ w).astype(jnp.float32)


def strategy_c_accumulate_ref(partial, pd: int):
    """Ideal Strategy-C analog accumulation of differential partial sums.

    partial: (S, J, B, C) from crossbar_partial_sums_ref (J = 8 bit planes).
    Returns (B, C) final analog value in *unit BL encoding* (i.e. the same
    units as the partial sums), normalized by the cyclic NNS+A schedule:
        out = D / K,   K = sa_unrolled_scale(S, pd),
    where D is the exact integer dot product. The identity out * K == D is
    asserted by tests (the whole point of the Strategy-C dataflow).
    """
    s_cycles, n_planes = partial.shape[0], partial.shape[1]
    weights = 2.0 ** jnp.arange(n_planes, dtype=jnp.float32)
    alpha = common.sa_alpha(pd, n_planes)
    acc = jnp.zeros(partial.shape[2:], dtype=jnp.float32)
    for i in range(s_cycles):
        s = jnp.einsum("jbc,j->bc", partial[i], weights) / alpha
        acc = 2.0 ** (-pd) * acc + s
    return acc


def strategy_c_dot_ref(x_u8, w_pos_u8, w_neg_u8, pd: int, pi: int = 8, pw: int = 8):
    """End-to-end ideal Strategy-C dot product (analog value, unit encoding)."""
    partial = crossbar_partial_sums_ref(x_u8, w_pos_u8, w_neg_u8, pd, pi, pw)
    return strategy_c_accumulate_ref(partial, pd)


def mlp_vtc_ref(v_in, w1, b1, w2, b2, vm, gain):
    """NeuralPeriph 3-layer forward: v_out = W2 . VTC(W1 . v_in + b1) + b2.

    v_in: (B, I); w1: (I, H); b1: (H,); w2: (H, O); b2: (O,).
    vm/gain: scalar or (H,) inverter VTC parameters.
    """
    pre = v_in @ w1 + b1
    h = common.vtc_apply(pre, vm, gain)
    return h @ w2 + b2


def nns_a_cyclic_ref(v_slices, w1, b1, w2, b2, vm, gain):
    """Trained NNS+A applied cyclically (the S/H feedback loop, Fig. 5a).

    v_slices: (S, B, 8) per-cycle BL voltages. Returns (B,) final output.
    The 9th input is the carried intermediate sum, initialized to 0.
    """
    batch = v_slices.shape[1]
    acc = jnp.zeros((batch,), dtype=jnp.float32)
    for i in range(v_slices.shape[0]):
        vin = jnp.concatenate([v_slices[i], acc[:, None]], axis=-1)  # (B, 9)
        acc = mlp_vtc_ref(vin, w1, b1, w2, b2, vm, gain)[:, 0]
    return acc


def nnadc_flash_ref(v, w1, b1, w2, vm, gain, n_bits: int = 8):
    """Flash-style NNADC forward (the architecture of ref [34]): a bank of
    H threshold inverters, each firing when w1_i * v + b1_i crosses Vm,
    summed by a unit-budget output column; the summed analog level is
    regenerated (rounded) into the final code by the output latch stage.

    v: (B,) analog inputs in [0, 1] (already normalized by the selected
    V_max range). w1: (H,); b1: (H,); w2: (H,).
    Returns (codes (B,), soft (B,)) with codes in [0, 2^n_bits - 1] and
    soft the pre-regeneration analog sum in [0, 1].
    """
    from compile import common as _c

    pre = v[:, None] * w1[None, :] + b1[None, :]  # (B, H)
    u = 1.0 - _c.vtc_apply(pre, vm, gain) / _c.VDD  # rising unit steps
    soft = u @ w2  # (B,)
    levels = 2**n_bits - 1
    codes = jnp.clip(jnp.round(soft * levels), 0, levels)
    return codes, soft
