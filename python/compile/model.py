"""L2: the quantized CNN lowered through the three accumulation dataflows.

This module is the heart of the accuracy experiments:

- ``strategy_{a,b,c}_matmul`` are drop-in integer-matmul replacements that
  route every layer's dot products through the bit-sliced crossbar pipeline
  of Fig. 3 (a/b/c), with the quantization/noise happening exactly where
  each accumulation strategy puts it:
    A: per-(input-cycle, bit-line) A/D conversion, digital S+A (ISAAC);
    B: analog partial sums written to buffer-array cells (write
       quantization + device noise), analog accumulation along the
       radix-aligned buffer BLs, one conversion per BL, digital S+A
       across BLs (CASCADE);
    C: fully-analog accumulation (the proposed dataflow), one range-aware
       conversion of the final analog sum (+ lumped analog noise).
- ``noisy_forward`` is the Eq.-(13) lumped-noise model used by Fig. 10.
- ``mc_dot_products`` is the Fig. 9 Monte-Carlo experiment: a batch of
  random kernels/inputs pushed through the *trained* NNS+A and NNADC
  (the L1 Pallas kernels), returning (D_hw, D_sw).

Everything is a pure jax function of traced parameters (ADC levels, PRNG
key, SINAD), so each variant lowers to one HLO artifact the Rust runtime
sweeps at request time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import common, train_cnn
from compile.kernels import nnadc as nnadc_kernel
from compile.kernels import nns_a as nns_a_kernel
from compile.kernels import ref

K_CHUNK = 128  # physical crossbar rows


def _chunks(k: int):
    return [(i, min(i + K_CHUNK, k)) for i in range(0, k, K_CHUNK)]


# ---------------------------------------------------------------------------
# Strategy A (ISAAC-style): quantize every BL partial sum, digital S+A
# ---------------------------------------------------------------------------


def strategy_a_matmul(x_u8, w_int, adc_levels, pd: int = 1):
    """x_u8: (M, K) uint8-valued; w_int: (K, C) int8-valued. adc_levels is a
    traced scalar (2^bits - 1). Returns the reconstructed integer product.

    The ADC full scale is fixed by the array: 2^N rows x (2^PD - 1) DAC
    levels x (2^PR - 1) cell level (Eq. 2).

    Implementation note (§Perf L2): all (cycle, bit-plane) partial sums
    are produced by ONE batched einsum and quantized in one fused
    elementwise pass — the per-(s, j) matmul-chain formulation emitted
    ~256 tiny dots per layer that XLA:CPU executed serially (65 s compile,
    minutes per batch); the batched form compiles in seconds and runs
    ~20x faster with identical numerics (pytest asserts equality)."""
    k = x_u8.shape[1]
    fs = float(K_CHUNK * (2**pd - 1))
    xs = common.input_bit_slices(x_u8, pd)  # (S, M, K)
    wp, wn = jnp.maximum(w_int, 0.0), jnp.maximum(-w_int, 0.0)
    bp = common.weight_bit_planes(wp.astype(jnp.int32))  # (J, K, C)
    bn = common.weight_bit_planes(wn.astype(jnp.int32))
    s_cycles, j_planes = xs.shape[0], bp.shape[0]
    radix = (2.0 ** (pd * jnp.arange(s_cycles)))[:, None, None, None] \
        * (2.0 ** jnp.arange(j_planes))[None, :, None, None]
    total = 0.0
    for lo, hi in _chunks(k):
        pp = jnp.einsum("smk,jkc->sjmc", xs[:, :, lo:hi], bp[:, lo:hi])
        pn = jnp.einsum("smk,jkc->sjmc", xs[:, :, lo:hi], bn[:, lo:hi])
        qp = common.quantize_uniform(pp, adc_levels, fs)
        qn = common.quantize_uniform(pn, adc_levels, fs)
        total = total + jnp.sum(radix * (qp - qn), axis=(0, 1))
    return jnp.round(total)


# ---------------------------------------------------------------------------
# Strategy B (CASCADE-style): buffer-array accumulation, then quantize
# ---------------------------------------------------------------------------


def strategy_b_matmul(x_u8, w_int, adc_levels, key, pd: int = 1,
                      buffer_bits: int = 6, buffer_sigma: float = 0.025):
    """CASCADE dataflow: per-cycle BL partial sums are written into RRAM
    buffer cells (``buffer_bits`` precision + lognormal write variation),
    radix-aligned by column so each buffer BL analog-accumulates the
    entries sharing one exponent, then one A/D conversion per buffer BL
    and digital S+A across BLs (Eq. 3/6)."""
    k = x_u8.shape[1]
    fs = float(K_CHUNK * (2**pd - 1))
    buf_levels = float(2**buffer_bits - 1)
    xs = common.input_bit_slices(x_u8, pd)
    wp, wn = jnp.maximum(w_int, 0.0), jnp.maximum(-w_int, 0.0)
    bp = common.weight_bit_planes(wp.astype(jnp.int32))
    bn = common.weight_bit_planes(wn.astype(jnp.int32))
    s_cycles, j_planes = xs.shape[0], bp.shape[0]
    n_exp = pd * (s_cycles - 1) + j_planes  # radix diagonals
    # radix-diagonal membership: one-hot (S, J, E) selector so the whole
    # buffer-array accumulation is a single einsum (see strategy_a note)
    e_idx = pd * np.arange(s_cycles)[:, None] + np.arange(j_planes)[None, :]
    onehot = jnp.asarray(
        (e_idx[:, :, None] == np.arange(n_exp)[None, None, :]).astype(np.float32))
    counts = onehot.sum(axis=(0, 1))  # (E,) entries per diagonal
    total = 0.0
    for lo, hi in _chunks(k):
        pp = jnp.einsum("smk,jkc->sjmc", xs[:, :, lo:hi], bp[:, lo:hi])
        pn = jnp.einsum("smk,jkc->sjmc", xs[:, :, lo:hi], bn[:, lo:hi])
        key, k1, k2 = jax.random.split(key, 3)
        # buffer write: cell precision + device variation
        sp = common.quantize_uniform(pp, buf_levels, fs) \
            * jnp.exp(buffer_sigma * jax.random.normal(k1, pp.shape))
        sn = common.quantize_uniform(pn, buf_levels, fs) \
            * jnp.exp(buffer_sigma * jax.random.normal(k2, pn.shape))
        accp = jnp.einsum("sjmc,sje->emc", sp, onehot)
        accn = jnp.einsum("sjmc,sje->emc", sn, onehot)
        fs_bl = (fs * counts)[:, None, None]  # BL range grows (Eq. 3)
        qp = jnp.clip(accp, 0.0, fs_bl)
        qp = jnp.round(qp / fs_bl * adc_levels) / adc_levels * fs_bl
        qn = jnp.clip(accn, 0.0, fs_bl)
        qn = jnp.round(qn / fs_bl * adc_levels) / adc_levels * fs_bl
        radix_e = (2.0 ** jnp.arange(n_exp))[:, None, None]
        total = total + jnp.sum(radix_e * (qp - qn), axis=0)
    return jnp.round(total)


# ---------------------------------------------------------------------------
# Strategy C (Neural-PIM): fully-analog accumulation, one conversion
# ---------------------------------------------------------------------------


def strategy_c_matmul(x_u8, w_int, adc_levels, key, d_max, pd: int = 4,
                      analog_sigma_v: float = 0.0055):
    """The proposed dataflow at the behavioural level: ideal analog
    accumulation (the NNS+A recursion; the trained-circuit non-ideality is
    the lumped ``analog_sigma_v``, measured from the Fig. 9 MC experiment),
    then ONE range-aware conversion of the final sum per output.

    d_max: per-layer calibrated |D| maximum — the NNADC range selection
    (§4.2, V_max in {0.5, 0.25, 0.125} VDD). adc_levels traced.
    """
    k = x_u8.shape[1]
    wp, wn = jnp.maximum(w_int, 0.0), jnp.maximum(-w_int, 0.0)
    total = 0.0
    n_slices = -(-8 // pd)
    kdec = common.sa_unrolled_scale(n_slices, pd)
    for lo, hi in _chunks(k):
        acc = ref.strategy_c_dot_ref(x_u8[:, lo:hi], wp[lo:hi], wn[lo:hi], pd)
        # lumped analog dataflow noise, in volts referred to the NNS+A
        # output, mapped into D units via the layer's analog full scale.
        key, kn = jax.random.split(key)
        sigma_d = analog_sigma_v / common.V_RANGE * d_max / kdec
        acc = acc + sigma_d * jax.random.normal(kn, acc.shape)
        # one signed range-aware conversion over [-d_max, d_max]
        q = common.quantize_signed(acc * kdec, adc_levels, d_max)
        total = total + q
    return jnp.round(total)


# ---------------------------------------------------------------------------
# Model-level forwards
# ---------------------------------------------------------------------------


def calibrate_d_max(qmodel, calib_x_u8):
    """Per-layer max |integer accumulator| over a calibration batch — the
    range-aware NNADC scale selection (Fig. 6). Returns list of floats."""
    d_max = []

    def spy(x, w, i):
        acc = x @ w
        d_max.append(float(jnp.max(jnp.abs(acc))))
        return acc

    train_cnn.quantized_forward(qmodel, calib_x_u8, matmul_fn=spy)
    return d_max


def ideal_forward(qmodel, x_u8):
    return train_cnn.quantized_forward(qmodel, x_u8)


def strategy_forward(qmodel, x_u8, strategy: str, adc_levels, key=None,
                     d_max=None, pd=None):
    """Run the quantized CNN with every layer's matmul routed through one
    accumulation strategy. adc_levels is traced; strategy/pd are static."""
    if strategy == "A":
        pd = 1 if pd is None else pd
        fn = lambda x, w, i: strategy_a_matmul(x, w, adc_levels, pd)
    elif strategy == "B":
        pd = 1 if pd is None else pd
        keys = jax.random.split(key, len(qmodel["layers"]))
        fn = lambda x, w, i: strategy_b_matmul(x, w, adc_levels, keys[i], pd)
    elif strategy == "C":
        pd = 4 if pd is None else pd
        keys = jax.random.split(key, len(qmodel["layers"]))
        fn = lambda x, w, i: strategy_c_matmul(x, w, adc_levels, keys[i],
                                               d_max[i], pd)
    else:
        raise ValueError(strategy)
    return train_cnn.quantized_forward(qmodel, x_u8, matmul_fn=fn)


def noisy_forward(qmodel, x_u8, key, sinad_db):
    """Eq. (13): additive Gaussian activation noise at a given SINAD.

    sigma_i = max|x_i| / 10^(SINAD/20), injected into every layer's
    pre-requantization accumulator (the hardware's analog output)."""
    keys = jax.random.split(key, len(qmodel["layers"]))

    def fn(x, w, i):
        acc = x @ w
        sigma = jnp.max(jnp.abs(acc)) / 10.0 ** (sinad_db / 20.0)
        return acc + sigma * jax.random.normal(keys[i], acc.shape)

    return train_cnn.quantized_forward(qmodel, x_u8, matmul_fn=fn)


# ---------------------------------------------------------------------------
# Fig. 9 Monte-Carlo: the trained NeuralPeriph dataflow, end to end
# ---------------------------------------------------------------------------


def mc_dot_products(key, periph, n: int = 1024, pd: int = 4, rows: int = 128,
                    lsb_first: bool = True, range_aware: bool = True,
                    read_sigma: float = 0.002, sh_sigma_v: float = 5e-4,
                    sh_loss: float = 0.003, interpret: bool = True,
                    x=None, w=None):
    """Random-kernel MC through the *trained* NNS+A + NNADC (Fig. 9).

    periph: dict with "nns_a_opt"/"nns_a_msb" {w1,b1,w2,b2} and
    "nnadc_opt"/"nnadc_naive" {w1,b1,w2,vm} numpy params.
    Returns (d_hw, d_sw) in integer dot-product units.

    Two realizable schedules (both decode with K = sa_unrolled_scale):

    - LSB-first (the paper's optimization): radix carried by the 2^-N_DAC
      NNS+A carry weight; the MSB slice is fed last and suffers zero S/H
      charge-transfer losses.
    - MSB-first (the Fig. 9b ablation): carry weight 1, radix carried by
      DAC-side attenuation 2^(-N_DAC*i) of later slices; the MSB slice is
      fed first and is attenuated by (1 - sh_loss)^(S-1).

    ``sh_loss`` is the fractional charge lost per sample-and-hold transfer
    (incomplete charge transfer, §5.3.1); ``sh_sigma_v`` its thermal noise;
    ``read_sigma`` the RRAM read fluctuation applied to the NeuralPeriph
    conductances per trial.

    x (n, rows) / w (rows, 1): the workload. When omitted, a *correlated*
    draw is used (inputs biased along the kernel's sign pattern) so the dot
    products exercise the converter's dynamic range the way real post-ReLU
    activations against a trained kernel do — fully random signs cancel to
    a few LSBs of signal, which no accumulation scheme could distinguish.
    """
    kx, kw, kr, ks, kc, kcal = jax.random.split(key, 6)
    if w is None:
        w = jax.random.randint(kw, (rows, 1), -128, 128).astype(jnp.float32)
    if x is None:
        base = jax.random.randint(kx, (n, rows), 0, 128).astype(jnp.float32)
        corr = jax.random.uniform(kc, (n, 1), minval=-1.0, maxval=1.0)
        x = jnp.clip(jnp.round(base + corr * 127.0 * jnp.sign(w)[None, :, 0]),
                     0, 255)
    wp, wn = jnp.maximum(w, 0.0), jnp.maximum(-w, 0.0)

    d_sw = ref.dot_product_int_ref(x, wp, wn)[:, 0]  # (n,)

    partial = ref.crossbar_partial_sums_ref(x, wp, wn, pd)[:, :, :, 0]  # (S,J,n)
    s_cycles = partial.shape[0]

    # differential voltage encoding: the W+/W- BL pair rejects the common
    # mode, so the NNS+A sees a signed value within +-V_RANGE/2 (Fig. 7c)
    fs = float(rows * (2**pd - 1))
    diffscale = (common.V_RANGE / 2.0) / fs

    if lsb_first:
        feed = list(range(s_cycles))  # radix order, carry does the shifting
        dac_scale = [1.0] * s_cycles
        sa = periph["nns_a_opt"]
    else:
        feed = list(range(s_cycles - 1, -1, -1))  # MSB slice first
        dac_scale = [2.0 ** (-pd * i) for i in range(s_cycles)]
        sa = periph["nns_a_msb"]

    w1 = jnp.asarray(sa["w1"])
    b1 = jnp.asarray(sa["b1"])
    w2 = jnp.asarray(sa["w2"])
    b2 = jnp.asarray(sa["b2"])
    kr1, kr2 = jax.random.split(kr)
    w1 = w1 * jnp.exp(read_sigma * jax.random.normal(kr1, w1.shape))
    w2 = w2 * jnp.exp(read_sigma * jax.random.normal(kr2, w2.shape))

    acc = jnp.zeros((n,), dtype=jnp.float32)
    sh_keys = jax.random.split(ks, s_cycles)
    for i, m in enumerate(feed):
        v_bl = partial[m].T * (diffscale * dac_scale[i])
        vin = jnp.concatenate([v_bl, acc[:, None]], axis=-1)
        acc = ref.mlp_vtc_ref(vin, w1, b1, w2, b2,
                              common.VDD / 2, common.VTC_GAIN_TT)[:, 0]
        if i < s_cycles - 1:  # held for the next cycle
            acc = acc * (1.0 - sh_loss)
            acc = acc + sh_sigma_v * jax.random.normal(sh_keys[i], acc.shape)

    # decode: for both schedules the ideal circuit satisfies
    #   acc = diffscale * D / K,  K = alpha * 2^(pd*(S-1))
    # (no offset: the differential encoding is zero-centered).
    alpha = common.sa_alpha(pd)
    kdec = alpha * 2.0 ** (pd * (s_cycles - 1))

    # NNADC conversion of the signed accumulator: range-aware picks the
    # smallest 2^-k * VDD bank covering the observed swing (§4.2); the
    # naive variant burns codes on the full rail. The selection is traced
    # (a runtime mux over the three pre-trained banks).
    if range_aware:
        swing = jnp.max(jnp.abs(acc))
        v_max = jnp.where(
            swing <= 0.125 * common.VDD, 0.125 * common.VDD,
            jnp.where(swing <= 0.25 * common.VDD, 0.25 * common.VDD,
                      jnp.where(swing <= 0.5 * common.VDD, 0.5 * common.VDD,
                                common.VDD)))
        adc = periph["nnadc_opt"]
    else:
        v_max = common.VDD
        adc = periph["nnadc_naive"]
    codes, _ = nnadc_kernel.nnadc_convert(
        jnp.clip((acc / v_max + 1.0) / 2.0, 0.0, 1.0),
        jnp.asarray(adc["w1"]), jnp.asarray(adc["b1"]), jnp.asarray(adc["w2"]),
        vm=jnp.asarray(adc.get("vm", common.VDD / 2)),
        gain=common.VTC_GAIN_LATCH, interpret=interpret)
    acc_q = (codes / 255.0 * 2.0 - 1.0) * v_max

    d_hw = acc_q / diffscale * kdec

    if range_aware:
        # §4.2 compensation: the range-aware NNADC is trained on *actual*
        # (noisy) NNS+A outputs with ideal Eq.-(12) labels, i.e. it learns
        # to invert the systematic NNS+A transfer error. Behaviourally this
        # is an affine recalibration of the decode, fitted at programming
        # time on an independent calibration draw.
        xc = jax.random.randint(kcal, (256, rows), 0, 256)
        kc2 = jax.random.fold_in(kcal, 1)
        corr_c = jax.random.uniform(kc2, (256, 1), minval=-1.0, maxval=1.0)
        xc = jnp.clip(jnp.round(xc * 0.5 + corr_c * 127.0 *
                                jnp.sign(w)[None, :, 0]), 0, 255)
        dc_hw, dc_sw = _mc_raw(xc, w, periph, pd, rows, lsb_first, False,
                               read_sigma, sh_sigma_v, sh_loss,
                               jax.random.fold_in(kcal, 2), v_max, adc,
                               diffscale, kdec, interpret)
        cov = jnp.mean((dc_hw - jnp.mean(dc_hw)) * (dc_sw - jnp.mean(dc_sw)))
        var = jnp.mean((dc_hw - jnp.mean(dc_hw)) ** 2) + 1e-9
        gain_cal = cov / var
        off_cal = jnp.mean(dc_sw) - gain_cal * jnp.mean(dc_hw)
        d_hw = gain_cal * d_hw + off_cal
    return d_hw, d_sw


def _mc_raw(x, w, periph, pd, rows, lsb_first, range_aware, read_sigma,
            sh_sigma_v, sh_loss, key, v_max, adc, diffscale, kdec, interpret):
    """Single raw pass of the trained dataflow (no recalibration): used by
    mc_dot_products to fit the programming-time compensation."""
    wp, wn = jnp.maximum(w, 0.0), jnp.maximum(-w, 0.0)
    d_sw = ref.dot_product_int_ref(x, wp, wn)[:, 0]
    partial = ref.crossbar_partial_sums_ref(x, wp, wn, pd)[:, :, :, 0]
    s_cycles = partial.shape[0]
    if lsb_first:
        feed = list(range(s_cycles))
        dac_scale = [1.0] * s_cycles
        sa = periph["nns_a_opt"]
    else:
        feed = list(range(s_cycles - 1, -1, -1))
        dac_scale = [2.0 ** (-pd * i) for i in range(s_cycles)]
        sa = periph["nns_a_msb"]
    kr, ks = jax.random.split(key)
    kr1, kr2 = jax.random.split(kr)
    w1 = jnp.asarray(sa["w1"]) * jnp.exp(
        read_sigma * jax.random.normal(kr1, np.shape(sa["w1"])))
    b1 = jnp.asarray(sa["b1"])
    w2 = jnp.asarray(sa["w2"]) * jnp.exp(
        read_sigma * jax.random.normal(kr2, np.shape(sa["w2"])))
    b2 = jnp.asarray(sa["b2"])
    acc = jnp.zeros((x.shape[0],), dtype=jnp.float32)
    sh_keys = jax.random.split(ks, s_cycles)
    for i, m in enumerate(feed):
        v_bl = partial[m].T * (diffscale * dac_scale[i])
        vin = jnp.concatenate([v_bl, acc[:, None]], axis=-1)
        acc = ref.mlp_vtc_ref(vin, w1, b1, w2, b2,
                              common.VDD / 2, common.VTC_GAIN_TT)[:, 0]
        if i < s_cycles - 1:
            acc = acc * (1.0 - sh_loss)
            acc = acc + sh_sigma_v * jax.random.normal(sh_keys[i], acc.shape)
    codes, _ = nnadc_kernel.nnadc_convert(
        jnp.clip((acc / v_max + 1.0) / 2.0, 0.0, 1.0),
        jnp.asarray(adc["w1"]), jnp.asarray(adc["b1"]), jnp.asarray(adc["w2"]),
        vm=jnp.asarray(adc.get("vm", common.VDD / 2)),
        gain=common.VTC_GAIN_LATCH, interpret=interpret)
    acc_q = (codes / 255.0 * 2.0 - 1.0) * v_max
    return acc_q / diffscale * kdec, d_sw


def sinad_db(d_hw, d_sw):
    """§5.3.1: SINAD = 10 log10((P_sig + P_noise) / P_noise)."""
    err = d_hw - d_sw
    p_noise = jnp.mean(err**2)
    p_sig = jnp.mean((d_sw - jnp.mean(d_sw)) ** 2)
    return 10.0 * jnp.log10((p_sig + p_noise) / p_noise)
