"""AOT compile path: train everything once, lower every inference variant
to HLO text, and dump the weights/datasets the Rust coordinator needs.

Python runs ONLY here (``make artifacts``). The Rust binary loads
``artifacts/*.hlo.txt`` via PJRT and never imports Python again.

Interchange format is HLO *text*, not serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts
---------
  periph.json          trained NeuralPeriph weights + Table-1 metrics
  cnn.json             quantized CNN (weights, scales, d_max, accuracy)
  testset.bin/json     512 test images (u8) + labels for the Rust side
  cnn_ideal.hlo.txt    f(images)                       -> logits
  cnn_noisy.hlo.txt    f(images, key, sinad_db)        -> logits   (Fig 10)
  cnn_strat{A,B,C}.hlo.txt f(images, adc_levels, key)  -> logits   (Fig 4a)
  mc_opt.hlo.txt       f(key) -> (d_hw, d_sw)                      (Fig 9a)
  mc_naive.hlo.txt     f(key) -> (d_hw, d_sw)                      (Fig 9b)
  nns_a.hlo.txt        f(v[B,9]) -> v_o[B]        (periph microbench)
  nnadc.hlo.txt        f(v[B]) -> codes[B]        (periph microbench)
  crossbar.hlo.txt     f(x, w+, w-) -> analog acc (pallas quickstart)
  manifest.json        shapes + dtypes of every artifact entry point
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import common, data, model, train_cnn, train_periph
from compile.kernels import crossbar, nnadc as nnadc_kernel, nns_a as nns_a_kernel

BATCH = 128  # fixed inference batch of every lowered CNN variant
MC_N = 1024  # Monte-Carlo trials per mc_* execution


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # CRITICAL: the default printer elides big weight tensors as '{...}',
    # which the HLO text parser silently reads back as zeros — print with
    # large constants included so the artifacts carry the trained weights.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's text parser predates the source_end_* metadata
    # attributes jax now emits — strip metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower(fn, *example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def _np_json(obj):
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    raise TypeError(type(obj))


def train_all_periph(quick: bool = False):
    """Train the four NeuralPeriph models + Table-1 metrics."""
    steps_sa = 1500 if quick else 6000
    steps_adc = 300 if quick else 1500
    t0 = time.time()
    sa_opt, sa_opt_info = train_periph.train_nns_a(4, steps=steps_sa)
    sa_msb, sa_msb_info = train_periph.train_nns_a(
        4, steps=steps_sa, hardware_aware=False, carry_w=1.0, seed=2)
    adc_opt, adc_opt_info = train_periph.train_nnadc(steps=steps_adc)
    adc_nv, adc_nv_info = train_periph.train_nnadc(
        steps=steps_adc, hardware_aware=False, seed=3)

    v, codes = train_periph.adc_transfer(adc_opt)
    dnl, inl, missing = train_periph.dnl_inl(v, codes, 8)
    enob, sinad = train_periph.enob(adc_opt)
    metrics = {
        "nns_a": sa_opt_info,
        "nns_a_msb": sa_msb_info,
        "nnadc": {
            **adc_opt_info,
            "dnl_min": float(dnl.min()), "dnl_max": float(dnl.max()),
            "inl_min": float(inl.min()), "inl_max": float(inl.max()),
            "missing_codes": missing, "enob": float(enob),
            "sinad_db": float(sinad),
        },
        "nnadc_naive": adc_nv_info,
        "train_seconds": time.time() - t0,
    }
    periph = {"nns_a_opt": sa_opt, "nns_a_msb": sa_msb,
              "nnadc_opt": adc_opt, "nnadc_naive": adc_nv}
    return periph, metrics


def write_testset(outdir: str, xte: np.ndarray, yte: np.ndarray):
    """Raw little-endian binary + JSON header (Rust has no npz reader)."""
    imgs = np.round(xte * 255.0).astype(np.uint8)
    with open(os.path.join(outdir, "testset.bin"), "wb") as f:
        f.write(imgs.tobytes())
        f.write(yte.astype(np.int32).tobytes())
    with open(os.path.join(outdir, "testset.json"), "w") as f:
        json.dump({"n": int(imgs.shape[0]), "height": data.IMG,
                   "width": data.IMG, "channels": data.CH,
                   "label_dtype": "i32", "image_dtype": "u8",
                   "layout": "images then labels, C-order"}, f, indent=1)
    return imgs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="reduced training budgets (CI smoke)")
    args = ap.parse_args()
    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)
    manifest = {"batch": BATCH, "mc_n": MC_N, "entries": {}}

    # ------------------------------------------------------------------ 1.
    print("[aot] training NeuralPeriph circuits ...", flush=True)
    periph, periph_metrics = train_all_periph(quick=args.quick)
    pj = {k: {n: v.tolist() for n, v in p.items()} for k, p in periph.items()}
    pj["metrics"] = periph_metrics
    pj["constants"] = {
        "vdd": common.VDD, "v_range": common.V_RANGE,
        "vtc_gain_tt": common.VTC_GAIN_TT,
        "vtc_gain_adc": common.VTC_GAIN_ADC,
        "vtc_gain_latch": common.VTC_GAIN_LATCH,
        "ar_bits": common.AR_BITS, "rram_sigma": common.RRAM_SIGMA,
    }
    with open(os.path.join(outdir, "periph.json"), "w") as f:
        json.dump(pj, f)

    # ------------------------------------------------------------------ 2.
    print("[aot] training + quantizing the CNN ...", flush=True)
    cnn_steps = 300 if args.quick else 1500
    params, float_acc = train_cnn.train(steps=cnn_steps)
    (xtr, _), (xte, yte) = data.make_splits()
    qmodel = train_cnn.quantize(params, xtr[:512])
    x_cal = jnp.asarray(np.round(xtr[:BATCH] * 255.0), jnp.float32)
    d_max = model.calibrate_d_max(qmodel, x_cal)

    xte_u8 = write_testset(outdir, xte, yte)
    x_eval = jnp.asarray(xte_u8[:BATCH], jnp.float32)
    logits = jax.jit(lambda x: model.ideal_forward(qmodel, x))(x_eval)
    q_acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte[:BATCH])))
    print(f"[aot] float acc {float_acc:.4f}, int8 acc (first batch) {q_acc:.4f}")

    cj = {"layers": [], "d_max": d_max, "float_acc": float_acc,
          "int8_acc_first_batch": q_acc, "batch": BATCH}
    for layer in qmodel["layers"]:
        cj["layers"].append({k: (v.tolist() if isinstance(v, np.ndarray) else v)
                             for k, v in layer.items()})
    with open(os.path.join(outdir, "cnn.json"), "w") as f:
        json.dump(cj, f)

    # ------------------------------------------------------------------ 3.
    print("[aot] lowering HLO artifacts ...", flush=True)
    img_spec = jax.ShapeDtypeStruct((BATCH, data.IMG, data.IMG, data.CH),
                                    jnp.float32)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def wrap_key(key_data):
        return jax.random.wrap_key_data(key_data, impl="threefry2x32")

    def f_ideal(images):
        return (model.ideal_forward(qmodel, images),)

    def f_noisy(images, key_data, sinad_db):
        return (model.noisy_forward(qmodel, images, wrap_key(key_data),
                                    sinad_db),)

    def f_strat(strategy):
        # Strategy A is deterministic: giving it a PRNG parameter would be
        # dead-code-eliminated from the lowered HLO (changing the
        # executable's arity), so A takes (images, adc_levels) only.
        if strategy == "A":
            def f(images, adc_levels):
                return (model.strategy_forward(qmodel, images, "A",
                                               adc_levels, d_max=d_max),)
        else:
            def f(images, adc_levels, key_data):
                return (model.strategy_forward(qmodel, images, strategy,
                                               adc_levels,
                                               key=wrap_key(key_data),
                                               d_max=d_max),)
        return f

    def f_mc(lsb_first, range_aware):
        def f(key_data):
            return model.mc_dot_products(wrap_key(key_data), periph, n=MC_N,
                                         lsb_first=lsb_first,
                                         range_aware=range_aware)
        return f

    sa = periph["nns_a_opt"]
    adc = periph["nnadc_opt"]

    def f_nns_a(v):
        return (ref_mlp(v),)

    def ref_mlp(v):
        from compile.kernels import ref
        return ref.mlp_vtc_ref(v, jnp.asarray(sa["w1"]), jnp.asarray(sa["b1"]),
                               jnp.asarray(sa["w2"]), jnp.asarray(sa["b2"]),
                               common.VDD / 2, common.VTC_GAIN_TT)[:, 0]

    def f_nnadc(v):
        codes, _ = nnadc_kernel.nnadc_convert(
            v, jnp.asarray(adc["w1"]), jnp.asarray(adc["b1"]),
            jnp.asarray(adc["w2"]), vm=jnp.asarray(adc["vm"]),
            gain=common.VTC_GAIN_LATCH)
        return (codes,)

    def f_crossbar(x, wp, wn):
        return (crossbar.strategy_c_dot(x, wp, wn, pd=4),)

    xb_spec = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    wb_spec = jax.ShapeDtypeStruct((256, 32), jnp.float32)

    entries = {
        "cnn_ideal": (f_ideal, (img_spec,),
                      {"params": ["images[B,12,12,3]f32(u8-valued)"],
                       "returns": "logits[B,10]f32"}),
        "cnn_noisy": (f_noisy, (img_spec, key_spec, scalar),
                      {"params": ["images", "key[2]u32", "sinad_db f32"],
                       "returns": "logits[B,10]f32"}),
        "cnn_stratA": (f_strat("A"), (img_spec, scalar),
                       {"params": ["images", "adc_levels f32"],
                        "returns": "logits[B,10]f32"}),
        "cnn_stratB": (f_strat("B"), (img_spec, scalar, key_spec),
                       {"params": ["images", "adc_levels f32", "key[2]u32"],
                        "returns": "logits[B,10]f32"}),
        "cnn_stratC": (f_strat("C"), (img_spec, scalar, key_spec),
                       {"params": ["images", "adc_levels f32", "key[2]u32"],
                        "returns": "logits[B,10]f32"}),
        "mc_opt": (f_mc(True, True), (key_spec,),
                   {"params": ["key[2]u32"], "returns": "(d_hw[N], d_sw[N])"}),
        "mc_naive": (f_mc(False, False), (key_spec,),
                     {"params": ["key[2]u32"], "returns": "(d_hw[N], d_sw[N])"}),
        "nns_a": (f_nns_a, (jax.ShapeDtypeStruct((1024, 9), jnp.float32),),
                  {"params": ["v[1024,9]f32"], "returns": "v_o[1024]f32"}),
        "nnadc": (f_nnadc, (jax.ShapeDtypeStruct((1024,), jnp.float32),),
                  {"params": ["v[1024]f32 in [0,1]"],
                   "returns": "codes[1024]f32"}),
        "crossbar": (f_crossbar, (xb_spec, wb_spec, wb_spec),
                     {"params": ["x[64,256]", "w+[256,32]", "w-[256,32]"],
                      "returns": "acc[64,32]f32 (analog units)"}),
    }

    for name, (fn, specs, meta) in entries.items():
        t0 = time.time()
        text = lower(fn, *specs)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {**meta, "chars": len(text)}
        print(f"[aot]   {name}: {len(text) / 1e6:.2f} MB HLO "
              f"({time.time() - t0:.1f}s)", flush=True)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # stamp: Makefile freshness marker
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print("[aot] done.")


if __name__ == "__main__":
    main()
