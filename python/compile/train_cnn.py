"""Build-time training + post-training quantization of the benchmark CNN.

The CNN is the workload for the accuracy experiments (Fig. 4a, Fig. 10):
a compact conv net on the synthetic dataset (see data.py for the
substitution argument). Training is plain float32; afterwards the model is
post-training-quantized to the paper's 8-bit format:

  - weights:  per-tensor symmetric int8 (stored as W+/W- like §5.2.1),
  - activations: uint8 with per-layer calibrated scales (inputs included),
  - biases: int32 in the accumulator domain.

The quantized forward is *integer-exact* in f32 arithmetic (all
accumulators < 2^24), so the Rust side and the bit-sliced dataflow models
reproduce it bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, optim

LAYERS = (
    # (kind, kh, kw, cin, cout, stride, pad)
    ("conv", 3, 3, data.CH, 16, 1, "SAME"),
    ("conv", 3, 3, 16, 24, 2, "SAME"),
    ("conv", 3, 3, 24, 32, 1, "SAME"),
    ("fc", 1, 1, 32, data.N_CLASSES, 1, "VALID"),  # after global avg pool
)


def init_params(seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = []
    for (kind, kh, kw, cin, cout, _s, _p) in LAYERS:
        key, kw_key = jax.random.split(key)
        fan_in = kh * kw * cin
        w = jax.random.normal(kw_key, (kh, kw, cin, cout)) * np.sqrt(2.0 / fan_in)
        b = jnp.zeros((cout,))
        params.append({"w": w, "b": b})
    return params


def float_forward(params, x):
    """Float reference forward. x: (B, H, W, C) in [0, 1]."""
    h = x
    for i, (kind, _kh, _kw, _cin, _cout, stride, pad) in enumerate(LAYERS):
        w, b = params[i]["w"], params[i]["b"]
        if kind == "conv":
            h = jax.lax.conv_general_dilated(
                h, w, (stride, stride), pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jax.nn.relu(h + b)
        else:  # fc after global average pool
            h = jnp.mean(h, axis=(1, 2))  # (B, C)
            h = h @ w[0, 0] + b
    return h  # logits


def train(seed: int = 0, steps: int = 1200, batch: int = 128, lr: float = 2e-3,
          n_train: int = 8192, verbose: bool = False):
    """Train the float model; returns (params, test_accuracy)."""
    (xtr, ytr), (xte, yte) = data.make_splits(seed=3, n_train=n_train)
    params = init_params(seed)
    opt = optim.adam_init(params)

    def loss_fn(p, xb, yb):
        logits = float_forward(p, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(xb.shape[0]), yb])

    @jax.jit
    def step(params, opt, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, opt = optim.adam_update(grads, opt, params, lr=lr)
        return params, opt, loss

    rng = np.random.default_rng(seed + 5)
    for i in range(steps):
        idx = rng.integers(0, xtr.shape[0], batch)
        params, opt, loss = step(params, opt, jnp.asarray(xtr[idx]),
                                 jnp.asarray(ytr[idx]))
        if verbose and i % 200 == 0:
            print(f"step {i}: loss {float(loss):.4f}")

    logits = jax.jit(float_forward)(params, jnp.asarray(xte))
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))
    return params, acc


# ---------------------------------------------------------------------------
# Post-training quantization
# ---------------------------------------------------------------------------


def quantize(params, calib_x):
    """PTQ to the paper's 8-bit format. Returns a qmodel dict:

    per layer: w_int (int8 values, stored as float), b_int (int32-valued),
    m (the requant multiplier s_x*s_w/s_y), s_x/s_w/s_y scales.
    Activations (and the input) are uint8 with scale s: real = q * s.
    """
    # calibrate activation scales on the float model
    acts = [jnp.asarray(calib_x)]
    h = acts[0]
    for i, (kind, _kh, _kw, _cin, _cout, stride, pad) in enumerate(LAYERS):
        w, b = params[i]["w"], params[i]["b"]
        if kind == "conv":
            h = jax.lax.conv_general_dilated(
                h, w, (stride, stride), pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jax.nn.relu(h + b)
        else:
            h = jnp.mean(h, axis=(1, 2))
            h = h @ w[0, 0] + b
        acts.append(h)

    qmodel = {"layers": []}
    s_in = 1.0 / 255.0  # inputs are [0,1] -> uint8
    s_x = s_in
    for i, (kind, kh, kw_, cin, cout, stride, pad) in enumerate(LAYERS):
        w = np.asarray(params[i]["w"])
        b = np.asarray(params[i]["b"])
        s_w = float(np.max(np.abs(w)) / 127.0 + 1e-12)
        w_int = np.clip(np.round(w / s_w), -127, 127).astype(np.float32)
        # output scale: calibrated 99.9th percentile of the float activation
        a = np.asarray(acts[i + 1])
        a_hi = float(np.percentile(np.maximum(a, 0.0), 99.9)) + 1e-9
        if kind == "fc":
            # logits keep a symmetric signed range
            a_hi = float(np.percentile(np.abs(a), 100.0)) + 1e-9
            s_y = a_hi / 127.0
        else:
            s_y = a_hi / 255.0
        b_int = np.round(b / (s_x * s_w)).astype(np.float32)
        qmodel["layers"].append({
            "kind": kind, "kh": kh, "kw": kw_, "cin": cin, "cout": cout,
            "stride": stride, "pad": pad,
            "w_int": w_int, "b_int": b_int,
            "s_x": float(s_x), "s_w": s_w, "s_y": float(s_y),
            "m": float(s_x * s_w / s_y),
        })
        s_x = s_y
    return qmodel


def quantized_forward(qmodel, x_u8, matmul_fn=None):
    """Integer-exact quantized forward.

    x_u8: (B, H, W, C) uint8-valued float array. ``matmul_fn(x_u8, w_int,
    layer_idx)``, when given, replaces the exact integer matmul — this is
    the hook the strategy-A/B/C dataflow models plug into (model.py).
    Returns logits (B, 10) in the *real* domain.
    """
    h = x_u8
    for i, layer in enumerate(qmodel["layers"]):
        if layer["kind"] == "conv":
            patches, out_hw = im2col(h, layer["kh"], layer["kw"], layer["stride"],
                                     layer["pad"])
            wmat = layer["w_int"].reshape(-1, layer["cout"])  # (K, Co)
            if matmul_fn is None:
                acc = patches @ wmat
            else:
                acc = matmul_fn(patches, wmat, i)
            acc = acc + layer["b_int"]
            acc = jnp.maximum(acc, 0.0)
            y = jnp.clip(jnp.round(acc * layer["m"]), 0, 255)
            b = h.shape[0]
            h = y.reshape(b, out_hw[0], out_hw[1], layer["cout"])
        else:
            # global average pool in the integer domain: mean then round
            hp = jnp.round(jnp.mean(h, axis=(1, 2)))  # (B, C) still uint8-ish
            wmat = layer["w_int"][0, 0]
            if matmul_fn is None:
                acc = hp @ wmat
            else:
                acc = matmul_fn(hp, wmat, i)
            acc = acc + layer["b_int"]
            # logits: dequantize, no relu/requant
            h = acc * (layer["s_x"] * layer["s_w"])
    return h


def im2col(x, kh, kw, stride, pad):
    """(B, H, W, C) -> (B*OH*OW, kh*kw*C) patches + (OH, OW)."""
    b, hh, ww, c = x.shape
    if pad == "SAME":
        oh = -(-hh // stride)
        ow = -(-ww // stride)
        ph = max((oh - 1) * stride + kh - hh, 0)
        pw = max((ow - 1) * stride + kw - ww, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2),
                        (0, 0)))
    else:
        oh = (hh - kh) // stride + 1
        ow = (ww - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            cols.append(x[:, di:di + stride * oh:stride, dj:dj + stride * ow:stride, :])
    patches = jnp.stack(cols, axis=3)  # (B, OH, OW, kh*kw, C)
    patches = patches.reshape(b, oh, ow, kh * kw * c)
    return patches.reshape(b * oh * ow, kh * kw * c), (oh, ow)


def split_pos_neg(w_int):
    """W = W+ - W- (§5.2.1), both uint8-valued."""
    return np.maximum(w_int, 0.0).astype(np.float32), np.maximum(-w_int, 0.0).astype(np.float32)
