//! Bench: the §Perf hot paths — the numbers EXPERIMENTS.md §Perf tracks
//! before/after each optimization iteration.
//!
//! L3 hot paths: simulator sweep, mapping allocator, behavioural
//! strategy models, NNS+A/NNADC native forwards, coordinator round-trip.
//! PJRT path: executable compile + execute latency per artifact.

mod bench_util;

use bench_util::{bench, try_or_skip};
use neural_pim::arch::crossbar::Group;
use neural_pim::config::AcceleratorConfig;
use neural_pim::event::{self, Engine};
use neural_pim::obs::{NullRecorder, Recorder, Registry, TraceRecorder};
use neural_pim::runtime;
use neural_pim::scenario::{self, suite};
use neural_pim::serve::{fleet, loadgen, open_runtime, Coordinator,
                        PjrtBackend, ServeOptions};
use neural_pim::util::json::Json;
use neural_pim::util::pool;
use neural_pim::util::rng::Pcg;
use neural_pim::{dse, mapping, model, noise, offload, sim, workloads};
use std::time::Instant;

/// Mean wall-clock seconds of `iters` runs (1 warmup).
fn time_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Time `f` sequentially (1 thread) vs on the full pool and report the
/// wall-clock speedup — the §Perf number the parallel evaluation engine
/// is judged by.
fn speedup<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    pool::set_threads(1);
    let seq = time_secs(iters, &mut f);
    pool::set_threads(0);
    let par = time_secs(iters, &mut f);
    println!(
        "[bench] {name}: seq {:.1} ms, par {:.1} ms -> {:.2}x speedup \
         with {} threads",
        seq * 1e3,
        par * 1e3,
        seq / par.max(1e-12),
        pool::threads()
    );
}

/// Raw schedule/pop churn generic over the queue backend: a large
/// resident set (the regime where the binary heap's scattered sift-downs
/// cache-miss ~log2(n) levels per pop while the ladder streams whole
/// buckets) with every pop rescheduling itself at a wide pseudorandom
/// offset, so the queue stays at its working size for the whole
/// measurement.
fn churn<Q: event::EventQueue + Default>(resident: u64, total: u64) -> u64 {
    let mut eng: Engine<u64, Q> = Engine::new();
    for i in 0..resident {
        eng.schedule_at(i.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 44, i);
    }
    let mut done = 0u64;
    while let Some((t, ev)) = eng.pop() {
        done += 1;
        if done + eng.pending() as u64 >= total {
            continue; // drain the rest without refilling
        }
        let off = 1 + ((ev ^ t).wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 44);
        eng.schedule_at(t + off, ev.wrapping_mul(31).wrapping_add(1));
    }
    done
}

/// [`churn`] with the observability hooks the pipeline run loop uses:
/// a queue-depth sample every 64 pops and a guarded per-pop instant.
/// With [`NullRecorder`] every recorder call monomorphizes to nothing —
/// the residue is the stride check and the `is_enabled()` branch, which
/// is exactly the off-path cost `BENCH_obs.json` budgets at <= 2%.
fn churn_obs<Q: event::EventQueue + Default, R: Recorder>(
    resident: u64, total: u64, rec: &mut R) -> u64 {
    let mut eng: Engine<u64, Q> = Engine::new();
    for i in 0..resident {
        eng.schedule_at(i.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 44, i);
    }
    let mut done = 0u64;
    while let Some((t, ev)) = eng.pop() {
        done += 1;
        if done % 64 == 0 {
            rec.sample(t, "engine.queue_depth", eng.pending() as f64);
        }
        if rec.is_enabled() {
            rec.instant(t, "engine", "churn.pop");
        }
        if done + eng.pending() as u64 >= total {
            continue; // drain the rest without refilling
        }
        let off = 1 + ((ev ^ t).wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 44);
        eng.schedule_at(t + off, ev.wrapping_mul(31).wrapping_add(1));
    }
    done
}

/// High-density same-bucket storm: bursts of thousands of events at
/// identical timestamps (the FIFO tie-break stress), scheduled up front
/// and drained.
fn storm<Q: event::EventQueue + Default>(groups: u64, per: u64) -> u64 {
    let mut eng: Engine<u64, Q> = Engine::new();
    for g in 0..groups {
        for j in 0..per {
            eng.schedule_at((g + 1) * 1_000_000, j);
        }
    }
    let mut done = 0u64;
    while eng.pop().is_some() {
        done += 1;
    }
    done
}

/// The event-throughput suite (ISSUE 6's headline artifact): engine
/// churn ladder-vs-reference, same-bucket storms, the NoC-contended
/// request pipeline, and the loadgen sweep — written to
/// `BENCH_event.json` (gitignored, uploaded by CI next to the suite
/// artifact). Runs standalone via `--only-event`.
fn event_suite() -> anyhow::Result<()> {
    println!("### event-throughput suite\n");
    let mut pairs: Vec<(String, Json)> = Vec::new();
    let put = |pairs: &mut Vec<(String, Json)>, k: &str, v: f64| {
        pairs.push((k.to_string(), Json::Num(v)));
    };

    // 1. engine churn: the >= 10x acceptance number vs the retained
    // binary-heap reference queue
    let resident = 1u64 << 20;
    let total = 3_000_000u64;
    let t0 = Instant::now();
    let done = churn::<event::LadderQueue>(resident, total);
    let ladder_eps = done as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let done_ref = churn::<event::BinaryHeapQueue>(resident, total);
    let ref_eps = done_ref as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(done, done_ref, "queue backends diverged on event count");
    let ratio = ladder_eps / ref_eps.max(1.0);
    println!(
        "[bench] event churn ({}k resident): ladder {:.2}M ev/s vs \
         reference heap {:.2}M ev/s -> {:.1}x",
        resident >> 10,
        ladder_eps / 1e6,
        ref_eps / 1e6,
        ratio
    );
    put(&mut pairs, "event.churn_ladder_events_per_sec", ladder_eps);
    put(&mut pairs, "event.churn_binheap_events_per_sec", ref_eps);
    put(&mut pairs, "event.churn_speedup_vs_binheap", ratio);

    // 2. same-bucket storms: thousands of simultaneous events per
    // timestamp, the pure tie-break/sort path
    let t0 = Instant::now();
    let n = storm::<event::LadderQueue>(64, 4_096);
    let storm_ladder = n as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let n_ref = storm::<event::BinaryHeapQueue>(64, 4_096);
    let storm_ref = n_ref as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(n, n_ref);
    println!(
        "[bench] event storm (64 x 4096 ties): ladder {:.2}M ev/s vs \
         reference heap {:.2}M ev/s",
        storm_ladder / 1e6,
        storm_ref / 1e6
    );
    put(&mut pairs, "event.storm_ladder_events_per_sec", storm_ladder);
    put(&mut pairs, "event.storm_binheap_events_per_sec", storm_ref);

    // 3. the full pipeline under overload: engine + contended NoC +
    // finite buffers (events/sec of the request-sim hot path)
    let alex = workloads::alexnet();
    let cfg = AcceleratorConfig::neural_pim();
    let load = event::RequestLoad {
        requests: 256,
        replicas: 8,
        utilization: 1.1, // overload: queueing + back-pressure on
        seed: 42,
        shards: 1,
    };
    let t0 = Instant::now();
    let prof = event::request_profile(&alex, &cfg, &load);
    let sim_eps = prof.events as f64 / t0.elapsed().as_secs_f64();
    println!(
        "[bench] request sim (AlexNet, overload): {:.2}M ev/s, p99 \
         {:.1} µs, peak queue {}, clamped {}",
        sim_eps / 1e6,
        prof.p99_s * 1e6,
        prof.peak_queue,
        prof.clamped
    );
    assert_eq!(prof.clamped, 0, "pipeline scheduled into the past");
    put(&mut pairs, "event.request_sim_events_per_sec", sim_eps);

    // 4. loadgen sweep, unsharded vs sharded fleet slices
    let lg = loadgen::LoadGenConfig {
        requests: 65_536,
        ..Default::default()
    };
    let loads = [0.7, 1.0, 1.3];
    let t0 = Instant::now();
    let pts = loadgen::sweep(&lg, &loads).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    let arrivals = (lg.requests * loads.len() as u64) as f64;
    println!(
        "[bench] loadgen sweep (3 x 65536): {:.2}M arrivals/s \
         ({} points)",
        arrivals / dt / 1e6,
        pts.len()
    );
    put(&mut pairs, "event.loadgen_arrivals_per_sec", arrivals / dt);
    let sharded = loadgen::LoadGenConfig { shards: 8, ..lg };
    let t0 = Instant::now();
    let _ = loadgen::sweep(&sharded, &loads).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "[bench] loadgen sweep, 8 shards: {:.2}M arrivals/s",
        arrivals / dt / 1e6
    );
    put(&mut pairs, "event.loadgen_sharded_arrivals_per_sec",
        arrivals / dt);

    let mut bench_json =
        Json::Obj(pairs.into_iter().collect()).to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_event.json", bench_json)?;
    println!("[bench] wrote BENCH_event.json");
    Ok(())
}

/// The observability-overhead suite (the ISSUE 7 acceptance artifact):
/// the 1M-resident churn bench plain (pre-obs code), with the hooks
/// compiled in but NullRecorder'd off (budget: <= 2% regression), and
/// with a live filtered TraceRecorder — written to `BENCH_obs.json`.
/// The budget is *recorded*, not asserted: a loaded CI runner must not
/// fail the build on a noisy timing, the trajectory file is the judge.
fn obs_suite() -> anyhow::Result<()> {
    println!("### observability overhead suite\n");
    let resident = 1u64 << 20;
    let total = 3_000_000u64;

    let t0 = Instant::now();
    let done_plain = churn::<event::LadderQueue>(resident, total);
    let plain_eps = done_plain as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let done_null = churn_obs::<event::LadderQueue, _>(
        resident, total, &mut NullRecorder);
    let null_eps = done_null as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(done_plain, done_null, "recorder hooks changed the schedule");

    // live recorder, filtered to the stride samples so the trace stays
    // ~total/64 events instead of one allocation per pop
    let mut rec = TraceRecorder::with_filter(Some("engine.queue_depth"));
    let t0 = Instant::now();
    let done_traced =
        churn_obs::<event::LadderQueue, _>(resident, total, &mut rec);
    let traced_eps = done_traced as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(done_plain, done_traced, "tracing changed the schedule");
    assert!(!rec.is_empty(), "live recorder captured nothing");

    let budget_frac = 0.02;
    let overhead = 1.0 - null_eps / plain_eps.max(1.0);
    println!(
        "[bench] obs churn ({}k resident): plain {:.2}M ev/s, null-recorder \
         {:.2}M ev/s ({:+.2}% overhead, budget {:.0}%), traced {:.2}M ev/s \
         ({} trace events)",
        resident >> 10,
        plain_eps / 1e6,
        null_eps / 1e6,
        overhead * 100.0,
        budget_frac * 100.0,
        traced_eps / 1e6,
        rec.len()
    );

    let pairs: Vec<(String, Json)> = vec![
        ("obs.plain_events_per_sec".into(), Json::Num(plain_eps)),
        ("obs.null_events_per_sec".into(), Json::Num(null_eps)),
        ("obs.null_overhead_frac".into(), Json::Num(overhead)),
        ("obs.traced_events_per_sec".into(), Json::Num(traced_eps)),
        ("obs.trace_events".into(), Json::Num(rec.len() as f64)),
        ("obs.budget_frac".into(), Json::Num(budget_frac)),
        ("obs.within_budget".into(), Json::Bool(overhead <= budget_frac)),
    ];
    let mut bench_json =
        Json::Obj(pairs.into_iter().collect()).to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_obs.json", bench_json)?;
    println!("[bench] wrote BENCH_obs.json");
    Ok(())
}

/// The parallel-runtime suite (ISSUE 8's headline artifact): the
/// million-point `dse --fine` sweep on the persistent pool vs the
/// retained spawn-per-call engine, feasible-list byte-identity at 1/2/8
/// threads, per-call pool overhead, cold-vs-warm `network_cost` through
/// the sharded cache, and nested suite throughput — written to
/// `BENCH_pool.json`. Runs standalone via `--only-pool`.
fn pool_suite() -> anyhow::Result<()> {
    println!("### parallel-runtime suite\n");
    let mut pairs: Vec<(String, Json)> = Vec::new();
    let put = |pairs: &mut Vec<(String, Json)>, k: &str, v: f64| {
        pairs.push((k.to_string(), Json::Num(v)));
    };

    // 1. headline: the ~1M-candidate fine DSE sweep at 8 threads,
    // persistent pool vs spawn-per-call. batch 512 keeps per-submission
    // overhead in play (~1.9k pool calls over the grid) — the regime the
    // 19 `pool::map` call sites put the old engine in, where every call
    // paid thread spawns; the work per point is sub-µs analytic math.
    pool::set_threads(8);
    let spec = dse::FineSpec { batch: 512, ..Default::default() };
    let t0 = Instant::now();
    let fine = dse::fine_sweep(&spec); // first call also warms the pool
    let persistent_s = t0.elapsed().as_secs_f64();
    pool::set_spawn_baseline(true);
    let t0 = Instant::now();
    let base = dse::fine_sweep(&spec);
    let spawn_s = t0.elapsed().as_secs_f64();
    pool::set_spawn_baseline(false);
    assert_eq!(fine.feasible_fp, base.feasible_fp,
               "pool engines diverged on the feasible list");
    let fine_speedup = spawn_s / persistent_s.max(1e-12);
    println!(
        "[bench] fine DSE sweep ({} candidates, {} batches, 8 threads): \
         persistent {:.2}s vs spawn-per-call {:.2}s -> {:.1}x",
        fine.candidates, fine.batches, persistent_s, spawn_s, fine_speedup
    );
    put(&mut pairs, "pool.fine_sweep_candidates", fine.candidates as f64);
    put(&mut pairs, "pool.fine_sweep_feasible", fine.feasible as f64);
    put(&mut pairs, "pool.fine_sweep_batches", fine.batches as f64);
    put(&mut pairs, "pool.fine_sweep_persistent_s", persistent_s);
    put(&mut pairs, "pool.fine_sweep_spawn_s", spawn_s);
    put(&mut pairs, "pool.fine_sweep_speedup_vs_spawn", fine_speedup);

    // 2. the acceptance anchor: the full-grid feasible-point list is
    // byte-identical at --threads 1/2/8 (FNV-1a over the (index,
    // eff-bit-pattern) list in index order)
    let mut fps: Vec<(usize, u64, u64)> = Vec::new();
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let s = dse::fine_sweep(&dse::FineSpec::default());
        fps.push((t, s.feasible_fp, s.feasible));
    }
    assert!(
        fps.windows(2).all(|w| w[0].1 == w[1].1 && w[0].2 == w[1].2),
        "fine sweep diverged across thread counts: {fps:?}"
    );
    println!(
        "[bench] fine sweep fp {:016x} byte-identical at threads 1/2/8 \
         ({} feasible points)",
        fps[0].1, fps[0].2
    );
    pairs.push(("pool.fine_sweep_fp".into(),
                Json::Str(format!("{:016x}", fps[0].1))));
    pairs.push(("pool.fine_sweep_fp_threads_invariant".into(),
                Json::Bool(true)));

    // 3. per-call overhead: a 64-item map whose work is ~free prices the
    // submission machinery itself (parked-worker wake vs 8 thread spawns)
    pool::set_threads(8);
    let items: Vec<u64> = (0..64).collect();
    let tiny = |x: &u64| x.wrapping_mul(0x9e37_79b9) ^ 7;
    let call_persistent = time_secs(2_000, || {
        std::hint::black_box(pool::map(&items, tiny));
    });
    pool::set_spawn_baseline(true);
    let call_spawn = time_secs(200, || {
        std::hint::black_box(pool::map(&items, tiny));
    });
    pool::set_spawn_baseline(false);
    println!(
        "[bench] 64-item map call: persistent {:.1} µs vs spawn {:.1} µs \
         ({:.0}x)",
        call_persistent * 1e6,
        call_spawn * 1e6,
        call_spawn / call_persistent.max(1e-12)
    );
    put(&mut pairs, "pool.call_persistent_us", call_persistent * 1e6);
    put(&mut pairs, "pool.call_spawn_us", call_spawn * 1e6);

    // 4. cold-vs-warm `network_cost` under 8 threads: 64 concurrent
    // replicas each price all 9 benchmarks; cold pays the compute (one
    // toucher per key) + write locks, warm is the sharded read-mostly
    // fast path. Counters come back through the obs Registry export.
    let nets = workloads::all_benchmarks();
    let cfg = AcceleratorConfig::neural_pim();
    let reps: Vec<u32> = (0..64).collect();
    let price_all = |_: &u32| {
        let mut acc = 0.0;
        for n in &nets {
            acc += model::network_cost(n, &cfg).total.total();
        }
        acc
    };
    model::clear_cost_cache();
    let t0 = Instant::now();
    let cold_sum: f64 = pool::map(&reps, price_all).iter().sum();
    let cold_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let warm_sum: f64 = pool::map(&reps, price_all).iter().sum();
    let warm_s = t0.elapsed().as_secs_f64();
    assert_eq!(cold_sum.to_bits(), warm_sum.to_bits(),
               "cache replay changed the priced costs");
    let mut reg = Registry::new();
    model::fill_cache_registry(&mut reg);
    println!(
        "[bench] network_cost x64 replicas x{} nets (8 threads): cold \
         {:.1} ms, warm {:.1} ms ({:.0}x); memo.hits {} memo.misses {} \
         memo.evictions {} memo.entries {}",
        nets.len(),
        cold_s * 1e3,
        warm_s * 1e3,
        cold_s / warm_s.max(1e-12),
        reg.counter("memo.hits"),
        reg.counter("memo.misses"),
        reg.counter("memo.evictions"),
        reg.gauge("memo.entries")
    );
    put(&mut pairs, "pool.network_cost_cold_ms", cold_s * 1e3);
    put(&mut pairs, "pool.network_cost_warm_ms", warm_s * 1e3);
    put(&mut pairs, "memo.hits", reg.counter("memo.hits") as f64);
    put(&mut pairs, "memo.misses", reg.counter("memo.misses") as f64);
    put(&mut pairs, "memo.evictions", reg.counter("memo.evictions") as f64);
    put(&mut pairs, "memo.entries", reg.gauge("memo.entries") as f64);

    // 5. nested suite throughput: the suite fans scenarios across the
    // pool and every scenario's own sweeps nest. Persistent engine runs
    // nested maps inline; the spawn baseline reproduces the old
    // oversubscription (scoped workers are not flagged in-pool, so inner
    // maps spawn their own threads under the outer ones).
    let spec = suite::SuiteSpec::from_json(
        &Json::parse(
            r#"{"name": "pool-bench", "scenarios": [
                {"scenario": "dse"},
                {"scenario": "characterize"},
                {"scenario": "table2"},
                {"scenario": "table3"}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let opts = scenario::ExecOptions::default(); // cache off: compute every run
    let suite_persistent = time_secs(3, || {
        let r = suite::run_spec(&spec, &opts);
        assert_eq!(r.failures(), 0);
    });
    pool::set_spawn_baseline(true);
    let suite_spawn = time_secs(3, || {
        let r = suite::run_spec(&spec, &opts);
        assert_eq!(r.failures(), 0);
    });
    pool::set_spawn_baseline(false);
    println!(
        "[bench] nested suite (4 scenarios over the pool): persistent \
         {:.1} ms vs spawn {:.1} ms",
        suite_persistent * 1e3,
        suite_spawn * 1e3
    );
    put(&mut pairs, "pool.suite_persistent_ms", suite_persistent * 1e3);
    put(&mut pairs, "pool.suite_spawn_ms", suite_spawn * 1e3);
    put(&mut pairs, "pool.workers_spawned_total",
        pool::spawned_workers() as f64);
    pool::set_threads(0);

    let mut bench_json =
        Json::Obj(pairs.into_iter().collect()).to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_pool.json", bench_json)?;
    println!("[bench] wrote BENCH_pool.json");
    Ok(())
}

/// The fleet-serving suite (ISSUE 9's headline artifact): 1M+ virtual
/// arrivals routed across a 16-chip heterogeneous fleet, sequential vs
/// 8-thread wall clock (simulated-arrivals/sec and the parallel
/// speedup), plus the bit-identity fingerprint at threads 1/2/8 —
/// written to `BENCH_fleet.json`. Runs standalone via `--only-fleet`.
fn fleet_suite() -> anyhow::Result<()> {
    println!("### fleet-serving suite\n");
    let mut pairs: Vec<(String, Json)> = Vec::new();
    let put = |pairs: &mut Vec<(String, Json)>, k: &str, v: f64| {
        pairs.push((k.to_string(), Json::Num(v)));
    };

    let net = workloads::synthetic_cnn();
    let mix = fleet::parse_fleet("neural-pim:8,isaac:4,cascade:2,lowres:2")
        .expect("bench fleet spec");
    let classes = fleet::build_classes(&net, &mix, 64);
    let chips: usize = classes.iter().map(|c| c.count).sum();
    let cfg = fleet::FleetConfig {
        arrivals: 1 << 20,
        policy: fleet::RouterPolicy::LatencyAware,
        ..Default::default()
    };

    // 1. headline: simulated-arrivals/sec, sequential vs 8 threads (the
    // detail pass fans per-chip replays over the pool; routing is the
    // sequential fraction)
    pool::set_threads(1);
    let t0 = Instant::now();
    let seq = fleet::run_fleet(&cfg, &classes);
    let seq_s = t0.elapsed().as_secs_f64();
    pool::set_threads(8);
    let t0 = Instant::now();
    let par = fleet::run_fleet(&cfg, &classes);
    let par_s = t0.elapsed().as_secs_f64();
    let speedup_par8 = seq_s / par_s.max(1e-12);
    println!(
        "[bench] fleet {} arrivals x {chips} chips ({}): seq {:.2}s \
         ({:.2}M arrivals/s) vs 8 threads {:.2}s ({:.2}M arrivals/s) -> \
         {:.2}x",
        cfg.arrivals,
        cfg.policy.name(),
        seq_s,
        cfg.arrivals as f64 / seq_s / 1e6,
        par_s,
        cfg.arrivals as f64 / par_s / 1e6,
        speedup_par8
    );
    put(&mut pairs, "fleet.arrivals", cfg.arrivals as f64);
    put(&mut pairs, "fleet.chips", chips as f64);
    put(&mut pairs, "fleet.arrivals_per_s_seq",
        cfg.arrivals as f64 / seq_s.max(1e-12));
    put(&mut pairs, "fleet.arrivals_per_s_par8",
        cfg.arrivals as f64 / par_s.max(1e-12));
    put(&mut pairs, "fleet.speedup_par8", speedup_par8);
    put(&mut pairs, "fleet.p99_ms", par.p99_ms);
    put(&mut pairs, "fleet.shed_rate", par.shed_rate);

    // 2. the acceptance anchor: bit-identical per-chip tallies at
    // --threads 1/2/8 (seq/par runs above cover 1 and 8; 2 runs here)
    pool::set_threads(2);
    let two = fleet::run_fleet(&cfg, &classes);
    let fps = [
        (1usize, fleet::fingerprint(&seq)),
        (2, fleet::fingerprint(&two)),
        (8, fleet::fingerprint(&par)),
    ];
    assert!(
        fps.windows(2).all(|w| w[0].1 == w[1].1),
        "fleet run diverged across thread counts: {fps:?}"
    );
    println!(
        "[bench] fleet fingerprint {:016x} bit-identical at threads 1/2/8",
        fps[0].1
    );
    pairs.push(("fleet.fingerprint".into(),
                Json::Str(format!("{:016x}", fps[0].1))));
    pairs.push(("fleet.fp_threads_invariant".into(), Json::Bool(true)));
    pool::set_threads(0);

    let mut bench_json =
        Json::Obj(pairs.into_iter().collect()).to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_fleet.json", bench_json)?;
    println!("[bench] wrote BENCH_fleet.json");
    Ok(())
}

/// The hybrid-placement suite (ISSUE 10's headline artifact): the
/// exhaustive 2^16 VGG-16 mask sweep sequential vs the pool (masks/sec
/// and the parallel speedup), the MobileNet-V2 hill-climb and bandit
/// end-to-end, and the placement bit-identity at threads 1/2/8 —
/// written to `BENCH_offload.json`. Runs standalone via
/// `--only-offload`.
fn offload_suite() -> anyhow::Result<()> {
    println!("### hybrid-placement suite\n");
    let mut pairs: Vec<(String, Json)> = Vec::new();
    let put = |pairs: &mut Vec<(String, Json)>, k: &str, v: f64| {
        pairs.push((k.to_string(), Json::Num(v)));
    };

    let cfg_pim = AcceleratorConfig::neural_pim();
    let cfg_npu = offload::default_npu_config();

    // 1. headline: the exhaustive 2^16-mask VGG-16 sweep, sequential vs
    // the pool (fixed 4096-mask chunks reduced in index order, so the
    // winner is bit-identical either way)
    let vgg = workloads::vgg16();
    let pim = model::network_cost(&vgg, &cfg_pim);
    let npu = model::network_cost(&vgg, &cfg_npu);
    let table = offload::LayerTable::build(&cfg_pim, &pim, &cfg_npu, &npu);
    pool::set_threads(1);
    let t0 = Instant::now();
    let seq = offload::search::run(&table, offload::Strategy::Exhaustive, 42);
    let seq_s = t0.elapsed().as_secs_f64();
    pool::set_threads(8);
    let t0 = Instant::now();
    let par = offload::search::run(&table, offload::Strategy::Exhaustive, 42);
    let par_s = t0.elapsed().as_secs_f64();
    assert_eq!(seq.placement, par.placement,
               "exhaustive winner diverged across thread counts");
    assert_eq!(seq.edp.to_bits(), par.edp.to_bits());
    let speedup_par8 = seq_s / par_s.max(1e-12);
    println!(
        "[bench] offload exhaustive (VGG-16, {} masks): seq {:.0} ms \
         ({:.2}M masks/s) vs 8 threads {:.0} ms ({:.2}M masks/s) -> \
         {:.2}x",
        seq.evals,
        seq_s * 1e3,
        seq.evals as f64 / seq_s / 1e6,
        par_s * 1e3,
        par.evals as f64 / par_s / 1e6,
        speedup_par8
    );
    put(&mut pairs, "offload.exhaustive_masks", seq.evals as f64);
    put(&mut pairs, "offload.exhaustive_masks_per_s_seq",
        seq.evals as f64 / seq_s.max(1e-12));
    put(&mut pairs, "offload.exhaustive_masks_per_s_par8",
        par.evals as f64 / par_s.max(1e-12));
    put(&mut pairs, "offload.exhaustive_speedup_par8", speedup_par8);
    put(&mut pairs, "offload.vgg16_hybrid_edp", seq.edp);

    // 2. the heuristic tier end-to-end on the widest catalog net: the
    // MobileNet-V2 hill-climb and bandit through `offload::optimize`
    // (mapping + both cost tables + search), with the EDP win over the
    // best pure deployment
    let mob = workloads::by_name("MobileNet-V2").expect("catalog net");
    for (strategy, tag) in [(offload::Strategy::HillClimb, "hillclimb"),
                            (offload::Strategy::Bandit, "bandit")] {
        let t0 = Instant::now();
        let r = offload::optimize(&mob, &cfg_pim, &cfg_npu, strategy, 42);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "[bench] offload {tag} (MobileNet-V2): {:.1} ms, {} evals, \
             {} NPU layers, {:.2}% EDP win",
            dt * 1e3,
            r.evals,
            r.npu_layers(),
            r.edp_win() * 100.0
        );
        put(&mut pairs, &format!("offload.{tag}_ms"), dt * 1e3);
        put(&mut pairs, &format!("offload.{tag}_evals"), r.evals as f64);
        put(&mut pairs, &format!("offload.{tag}_edp_win"), r.edp_win());
    }

    // 3. the acceptance anchor: the hill-climb placement and EDP are
    // bit-identical at --threads 1/2/8 (restart streams are forked
    // sequentially before the parallel fan-out)
    let mut picks = Vec::new();
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let r = offload::optimize(&mob, &cfg_pim, &cfg_npu,
                                  offload::Strategy::HillClimb, 42);
        picks.push((t, r.placement.clone(), r.hybrid.edp.to_bits()));
    }
    assert!(
        picks.windows(2).all(|w| w[0].1 == w[1].1 && w[0].2 == w[1].2),
        "hill-climb diverged across thread counts"
    );
    println!(
        "[bench] offload hill-climb placement bit-identical at threads \
         1/2/8 ({} NPU layers)",
        picks[0].1.iter().filter(|p| p.is_npu()).count()
    );
    pairs.push(("offload.placement_threads_invariant".into(),
                Json::Bool(true)));
    pool::set_threads(0);

    let mut bench_json =
        Json::Obj(pairs.into_iter().collect()).to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_offload.json", bench_json)?;
    println!("[bench] wrote BENCH_offload.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // CI runs `-- --only-event` / `-- --only-obs` / `-- --only-pool` /
    // `-- --only-fleet` / `-- --only-offload` to produce the matching
    // BENCH_*.json without the rest of the suite (and without needing
    // PJRT artifacts)
    if std::env::args().any(|a| a == "--only-event") {
        return event_suite();
    }
    if std::env::args().any(|a| a == "--only-obs") {
        return obs_suite();
    }
    if std::env::args().any(|a| a == "--only-pool") {
        return pool_suite();
    }
    if std::env::args().any(|a| a == "--only-fleet") {
        return fleet_suite();
    }
    if std::env::args().any(|a| a == "--only-offload") {
        return offload_suite();
    }
    println!("### §Perf hot paths\n");

    // L3: simulator — sequential vs parallel across the pool
    let nets = workloads::all_benchmarks();
    speedup("simulate all 9 benchmarks x 3 archs (iso-area)", 5, || {
        let _ = sim::run_system_comparison(&nets);
    });
    speedup("full DSE sweep (360 grid points)", 5, || {
        let _ = dse::sweep();
    });
    speedup("strategy-B noise MC (1024 trials)", 3, || {
        let _ = noise::strategy_sinad('B', 1024, 2);
    });
    bench("simulate all 9 benchmarks x 3 archs (iso-area)", 1, 10, || {
        let _ = sim::run_system_comparison(&nets);
    });
    let vgg = workloads::vgg16();
    let cfg = AcceleratorConfig::neural_pim();
    bench("map_network(VGG-16)", 2, 20, || {
        let _ = mapping::map_network(&vgg, &cfg);
    });

    // event core: churn / storm / pipeline / loadgen throughput, with
    // BENCH_event.json as the artifact (also reachable standalone via
    // `-- --only-event`)
    event_suite()?;
    obs_suite()?;
    pool_suite()?;
    fleet_suite()?;
    offload_suite()?;
    // pool scaling of the request sim (replicas fan out across threads)
    let alex = workloads::alexnet();
    let load = event::RequestLoad {
        requests: 512,
        replicas: 16,
        utilization: 0.8,
        seed: 42,
        shards: 1,
    };
    speedup("event request sim (AlexNet, 512 req x 16 replicas)", 3, || {
        let _ = event::request_profile(&alex, &cfg, &load);
    });

    // memoized LayerCost table vs recomputation — the event-sim request
    // path charges these per-stage costs; replicas now share one
    // memoized model::network_cost table instead of re-pricing every
    // layer per pipeline instance (the pre-`model` behaviour, mimicked
    // by the "recompute" case below)
    let alex_mapping = mapping::map_network(&alex, &cfg);
    let multi = alex_mapping.chips > 1;
    bench("layer costs: recompute full table (old event path)", 5, 200, || {
        let mut total = 0.0;
        for lm in &alex_mapping.layers {
            total += model::layer_cost(lm, &cfg, multi).compute_e;
        }
        std::hint::black_box(total);
    });
    let _warm = model::network_cost(&alex, &cfg);
    bench("layer costs: memoized network_cost hit", 5, 200, || {
        std::hint::black_box(model::network_cost(&alex, &cfg).total.total());
    });
    // end-to-end view of the same effect: request sim with a cold cache
    // every iteration vs the warm memoized path
    let small = event::RequestLoad {
        requests: 128,
        replicas: 8,
        utilization: 0.8,
        seed: 42,
        shards: 1,
    };
    bench("event request sim, cold cost cache each iter", 1, 5, || {
        model::clear_cost_cache();
        let _ = event::request_profile(&alex, &cfg, &small);
    });
    bench("event request sim, memoized cost table", 1, 5, || {
        let _ = event::request_profile(&alex, &cfg, &small);
    });

    // scenario layer: the content-addressed results store — a cold
    // suite computes every entry, a warm one replays the stored
    // outcomes (the `--cache` acceptance number: cached must be far
    // cheaper than computed)
    let store_root = std::env::temp_dir()
        .join(format!("np-bench-store-{}", std::process::id()));
    let spec = suite::SuiteSpec::from_json(
        &neural_pim::util::json::Json::parse(
            r#"{"name": "bench", "scenarios": [
                {"scenario": "table2"},
                {"scenario": "table3"},
                {"scenario": "budget"},
                {"scenario": "characterize"}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let opts = scenario::ExecOptions {
        cache: true,
        results_dir: store_root.to_string_lossy().into_owned(),
    };
    bench("suite x4 scenarios, cold store (computed)", 1, 5, || {
        let _ = std::fs::remove_dir_all(&store_root);
        let r = suite::run_spec(&spec, &opts);
        assert_eq!(r.failures(), 0);
    });
    // one priming run so the timed iterations all hit
    let _ = suite::run_spec(&spec, &opts);
    bench("suite x4 scenarios, warm store (cached replay)", 2, 20, || {
        let r = suite::run_spec(&spec, &opts);
        assert!(r.all_cached(), "warm suite recomputed");
    });
    let _ = std::fs::remove_dir_all(&store_root);

    // serve layer: the virtual-time load generator behind `serve-sim`
    // (throughput/p99/shed across an offered-load sweep, zero
    // artifacts). The headline numbers land in BENCH_serve.json — the
    // serving-layer perf trajectory, like BENCH_suite_* for scenarios.
    let syn = workloads::synthetic_cnn();
    let nc = model::network_cost(&syn, &cfg);
    let sp = event::service_profile(&cfg, &nc);
    let lg = loadgen::LoadGenConfig {
        requests: 8192,
        workers: 2,
        max_batch: 64,
        max_wait_us: 200,
        max_queue_depth: 256,
        batch_exec_us: sp.batch_us(64),
        seed: 42,
        shards: 1,
    };
    let lg_loads = [0.5, 0.8, 1.0, 1.2];
    bench("serve loadgen sweep (4 loads x 8192 arrivals)", 2, 10, || {
        let _ = loadgen::sweep(&lg, &lg_loads).unwrap();
    });
    let pts = loadgen::sweep(&lg, &lg_loads).unwrap();
    let mut bench_pairs: Vec<(String, Json)> = Vec::new();
    for pt in &pts {
        let tag = format!("{:.2}", pt.offered);
        println!(
            "[bench] serve-sim @{tag}: {:.0} req/s, p99 {:.3} ms, shed \
             {:.3}",
            pt.throughput_rps, pt.p99_ms, pt.shed_rate
        );
        bench_pairs.push((format!("serve.throughput_rps@{tag}"),
                          Json::Num(pt.throughput_rps)));
        bench_pairs.push((format!("serve.p99_ms@{tag}"),
                          Json::Num(pt.p99_ms)));
        bench_pairs.push((format!("serve.shed_rate@{tag}"),
                          Json::Num(pt.shed_rate)));
    }
    bench_pairs.push(("serve.batch_exec_us".into(),
                      Json::Num(lg.batch_exec_us as f64)));
    let mut bench_json = Json::Obj(bench_pairs.into_iter().collect())
        .to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_serve.json", bench_json)?;
    println!("[bench] wrote BENCH_serve.json");

    // L3: behavioural dataflow models (the MC inner loop)
    let mut rng = Pcg::new(1);
    let w: Vec<i32> = (0..128).map(|_| rng.below(255) as i32 - 127).collect();
    let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
    let g = Group { w };
    bench("strategy_a dot (native, pd=1)", 5, 200, || {
        std::hint::black_box(g.strategy_a(&x, 1, 255.0, 128));
    });
    bench("strategy_c dot (native, pd=4)", 5, 200, || {
        std::hint::black_box(g.strategy_c(&x, 4, 255.0, 4.15e6));
    });

    // PJRT: compile + execute
    let Some(rt) =
        try_or_skip("runtime", open_runtime(&neural_pim::artifact_dir()))
    else {
        return Ok(());
    };
    let exe = rt.load("cnn_ideal")?;
    println!("[compile] cnn_ideal: {:.2}s", exe.compile_seconds);
    let ts = runtime::TestSet::load(rt.dir())?;
    let images = ts.batch_literal(0, 128)?;
    bench("cnn_ideal execute (batch 128)", 2, 20, || {
        let _ = exe.run_refs(&[&images]).unwrap();
    });

    // coordinator round-trip (queue + batch + execute + demux)
    let (h, w_, c) = ts.dims;
    let coord = Coordinator::start(
        PjrtBackend::new(neural_pim::artifact_dir(), "cnn_ideal",
                         h * w_ * c),
        ServeOptions { max_wait: std::time::Duration::from_millis(1),
                       ..Default::default() },
    )?;
    let stride = h * w_ * c;
    bench("coordinator round-trip (128 requests)", 1, 10, || {
        let mut pending = Vec::new();
        for i in 0..128 {
            let idx = i % ts.n;
            pending.push(
                coord
                    .submit(ts.images[idx * stride..(idx + 1) * stride].to_vec())
                    .unwrap()
                    .accepted()
                    .unwrap(),
            );
        }
        for rx in pending {
            let _ = rx.recv().unwrap();
        }
    });
    println!("{}", coord.metrics.snapshot());
    coord.shutdown();
    Ok(())
}
