//! Bench: the §Perf hot paths — the numbers EXPERIMENTS.md §Perf tracks
//! before/after each optimization iteration.
//!
//! L3 hot paths: simulator sweep, mapping allocator, behavioural
//! strategy models, NNS+A/NNADC native forwards, coordinator round-trip.
//! PJRT path: executable compile + execute latency per artifact.

mod bench_util;

use bench_util::{bench, try_or_skip};
use neural_pim::arch::crossbar::Group;
use neural_pim::config::AcceleratorConfig;
use neural_pim::event::{self, Engine};
use neural_pim::runtime;
use neural_pim::scenario::{self, suite};
use neural_pim::serve::{loadgen, open_runtime, Coordinator, PjrtBackend,
                        ServeOptions};
use neural_pim::util::json::Json;
use neural_pim::util::pool;
use neural_pim::util::rng::Pcg;
use neural_pim::{dse, mapping, model, noise, sim, workloads};
use std::time::Instant;

/// Mean wall-clock seconds of `iters` runs (1 warmup).
fn time_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Time `f` sequentially (1 thread) vs on the full pool and report the
/// wall-clock speedup — the §Perf number the parallel evaluation engine
/// is judged by.
fn speedup<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    pool::set_threads(1);
    let seq = time_secs(iters, &mut f);
    pool::set_threads(0);
    let par = time_secs(iters, &mut f);
    println!(
        "[bench] {name}: seq {:.1} ms, par {:.1} ms -> {:.2}x speedup \
         with {} threads",
        seq * 1e3,
        par * 1e3,
        seq / par.max(1e-12),
        pool::threads()
    );
}

fn main() -> anyhow::Result<()> {
    println!("### §Perf hot paths\n");

    // L3: simulator — sequential vs parallel across the pool
    let nets = workloads::all_benchmarks();
    speedup("simulate all 9 benchmarks x 3 archs (iso-area)", 5, || {
        let _ = sim::run_system_comparison(&nets);
    });
    speedup("full DSE sweep (~600 grid points)", 5, || {
        let _ = dse::sweep();
    });
    speedup("strategy-B noise MC (1024 trials)", 3, || {
        let _ = noise::strategy_sinad('B', 1024, 2);
    });
    bench("simulate all 9 benchmarks x 3 archs (iso-area)", 1, 10, || {
        let _ = sim::run_system_comparison(&nets);
    });
    let vgg = workloads::vgg16();
    let cfg = AcceleratorConfig::neural_pim();
    bench("map_network(VGG-16)", 2, 20, || {
        let _ = mapping::map_network(&vgg, &cfg);
    });

    // event engine: raw schedule/pop churn (the event-sim hot loop).
    // Each pop reschedules itself at a pseudorandom offset, so the heap
    // stays at its working size for the whole measurement.
    let churn = |seed: u64, total: u64| -> u64 {
        let mut eng: Engine<u64> = Engine::new();
        for i in 0..64u64 {
            eng.schedule_at(seed.wrapping_add(i) % 1000, i);
        }
        let mut done = 0u64;
        while let Some((t, ev)) = eng.pop() {
            done += 1;
            if done + eng.pending() as u64 >= total {
                continue; // drain the remaining 64 without refilling
            }
            eng.schedule_at(t + 1 + (ev ^ t) % 97, ev.wrapping_mul(31).wrapping_add(1));
        }
        done
    };
    let n_ev = 400_000u64;
    let t0 = Instant::now();
    let done = churn(1, n_ev);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "[bench] event engine churn: {:.2}M events/s ({} events, 1 thread)",
        done as f64 / dt / 1e6,
        done
    );
    // replica fan-out: 16 independent engines across the pool, 1 vs N
    // threads (events/sec is the BENCH number the event subsystem is
    // judged by)
    let reps: Vec<u64> = (0..16).collect();
    for t in [1usize, pool::threads()] {
        let t0 = Instant::now();
        let total: u64 = pool::map_with(t, &reps, |&s| churn(s, 100_000))
            .iter()
            .sum();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "[bench] event engine x16 replicas @ {t} threads: \
             {:.2}M events/s",
            total as f64 / dt / 1e6
        );
    }
    // the full event pipeline under request load (engine + NoC + buffers)
    let alex = workloads::alexnet();
    let load = event::RequestLoad {
        requests: 512,
        replicas: 16,
        utilization: 0.8,
        seed: 42,
    };
    speedup("event request sim (AlexNet, 512 req x 16 replicas)", 3, || {
        let _ = event::request_profile(&alex, &cfg, &load);
    });
    let prof = event::request_profile(&alex, &cfg, &load);
    println!(
        "[bench] event pipeline: {} events -> p50 {:.1} µs, p99 {:.1} µs, \
         NoC wait {:.2} µs total",
        prof.events,
        prof.p50_s * 1e6,
        prof.p99_s * 1e6,
        prof.noc_wait_s * 1e6
    );

    // memoized LayerCost table vs recomputation — the event-sim request
    // path charges these per-stage costs; replicas now share one
    // memoized model::network_cost table instead of re-pricing every
    // layer per pipeline instance (the pre-`model` behaviour, mimicked
    // by the "recompute" case below)
    let alex_mapping = mapping::map_network(&alex, &cfg);
    let multi = alex_mapping.chips > 1;
    bench("layer costs: recompute full table (old event path)", 5, 200, || {
        let mut total = 0.0;
        for lm in &alex_mapping.layers {
            total += model::layer_cost(lm, &cfg, multi).compute_e;
        }
        std::hint::black_box(total);
    });
    let _warm = model::network_cost(&alex, &cfg);
    bench("layer costs: memoized network_cost hit", 5, 200, || {
        std::hint::black_box(model::network_cost(&alex, &cfg).total.total());
    });
    // end-to-end view of the same effect: request sim with a cold cache
    // every iteration vs the warm memoized path
    let small = event::RequestLoad {
        requests: 128,
        replicas: 8,
        utilization: 0.8,
        seed: 42,
    };
    bench("event request sim, cold cost cache each iter", 1, 5, || {
        model::clear_cost_cache();
        let _ = event::request_profile(&alex, &cfg, &small);
    });
    bench("event request sim, memoized cost table", 1, 5, || {
        let _ = event::request_profile(&alex, &cfg, &small);
    });

    // scenario layer: the content-addressed results store — a cold
    // suite computes every entry, a warm one replays the stored
    // outcomes (the `--cache` acceptance number: cached must be far
    // cheaper than computed)
    let store_root = std::env::temp_dir()
        .join(format!("np-bench-store-{}", std::process::id()));
    let spec = suite::SuiteSpec::from_json(
        &neural_pim::util::json::Json::parse(
            r#"{"name": "bench", "scenarios": [
                {"scenario": "table2"},
                {"scenario": "table3"},
                {"scenario": "budget"},
                {"scenario": "characterize"}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let opts = scenario::ExecOptions {
        cache: true,
        results_dir: store_root.to_string_lossy().into_owned(),
    };
    bench("suite x4 scenarios, cold store (computed)", 1, 5, || {
        let _ = std::fs::remove_dir_all(&store_root);
        let r = suite::run_spec(&spec, &opts);
        assert_eq!(r.failures(), 0);
    });
    // one priming run so the timed iterations all hit
    let _ = suite::run_spec(&spec, &opts);
    bench("suite x4 scenarios, warm store (cached replay)", 2, 20, || {
        let r = suite::run_spec(&spec, &opts);
        assert!(r.all_cached(), "warm suite recomputed");
    });
    let _ = std::fs::remove_dir_all(&store_root);

    // serve layer: the virtual-time load generator behind `serve-sim`
    // (throughput/p99/shed across an offered-load sweep, zero
    // artifacts). The headline numbers land in BENCH_serve.json — the
    // serving-layer perf trajectory, like BENCH_suite_* for scenarios.
    let syn = workloads::synthetic_cnn();
    let nc = model::network_cost(&syn, &cfg);
    let sp = event::service_profile(&cfg, &nc);
    let lg = loadgen::LoadGenConfig {
        requests: 8192,
        workers: 2,
        max_batch: 64,
        max_wait_us: 200,
        max_queue_depth: 256,
        batch_exec_us: sp.batch_us(64),
        seed: 42,
    };
    let lg_loads = [0.5, 0.8, 1.0, 1.2];
    bench("serve loadgen sweep (4 loads x 8192 arrivals)", 2, 10, || {
        let _ = loadgen::sweep(&lg, &lg_loads);
    });
    let pts = loadgen::sweep(&lg, &lg_loads);
    let mut bench_pairs: Vec<(String, Json)> = Vec::new();
    for pt in &pts {
        let tag = format!("{:.2}", pt.offered);
        println!(
            "[bench] serve-sim @{tag}: {:.0} req/s, p99 {:.3} ms, shed \
             {:.3}",
            pt.throughput_rps, pt.p99_ms, pt.shed_rate
        );
        bench_pairs.push((format!("serve.throughput_rps@{tag}"),
                          Json::Num(pt.throughput_rps)));
        bench_pairs.push((format!("serve.p99_ms@{tag}"),
                          Json::Num(pt.p99_ms)));
        bench_pairs.push((format!("serve.shed_rate@{tag}"),
                          Json::Num(pt.shed_rate)));
    }
    bench_pairs.push(("serve.batch_exec_us".into(),
                      Json::Num(lg.batch_exec_us as f64)));
    let mut bench_json = Json::Obj(bench_pairs.into_iter().collect())
        .to_pretty_string();
    bench_json.push('\n');
    std::fs::write("BENCH_serve.json", bench_json)?;
    println!("[bench] wrote BENCH_serve.json");

    // L3: behavioural dataflow models (the MC inner loop)
    let mut rng = Pcg::new(1);
    let w: Vec<i32> = (0..128).map(|_| rng.below(255) as i32 - 127).collect();
    let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
    let g = Group { w };
    bench("strategy_a dot (native, pd=1)", 5, 200, || {
        std::hint::black_box(g.strategy_a(&x, 1, 255.0, 128));
    });
    bench("strategy_c dot (native, pd=4)", 5, 200, || {
        std::hint::black_box(g.strategy_c(&x, 4, 255.0, 4.15e6));
    });

    // PJRT: compile + execute
    let Some(rt) =
        try_or_skip("runtime", open_runtime(&neural_pim::artifact_dir()))
    else {
        return Ok(());
    };
    let exe = rt.load("cnn_ideal")?;
    println!("[compile] cnn_ideal: {:.2}s", exe.compile_seconds);
    let ts = runtime::TestSet::load(rt.dir())?;
    let images = ts.batch_literal(0, 128)?;
    bench("cnn_ideal execute (batch 128)", 2, 20, || {
        let _ = exe.run_refs(&[&images]).unwrap();
    });

    // coordinator round-trip (queue + batch + execute + demux)
    let (h, w_, c) = ts.dims;
    let coord = Coordinator::start(
        PjrtBackend::new(neural_pim::artifact_dir(), "cnn_ideal",
                         h * w_ * c),
        ServeOptions { max_wait: std::time::Duration::from_millis(1),
                       ..Default::default() },
    )?;
    let stride = h * w_ * c;
    bench("coordinator round-trip (128 requests)", 1, 10, || {
        let mut pending = Vec::new();
        for i in 0..128 {
            let idx = i % ts.n;
            pending.push(
                coord
                    .submit(ts.images[idx * stride..(idx + 1) * stride].to_vec())
                    .unwrap()
                    .accepted()
                    .unwrap(),
            );
        }
        for rx in pending {
            let _ = rx.recv().unwrap();
        }
    });
    println!("{}", coord.metrics.snapshot());
    coord.shutdown();
    Ok(())
}
