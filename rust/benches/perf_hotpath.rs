//! Bench: the §Perf hot paths — the numbers EXPERIMENTS.md §Perf tracks
//! before/after each optimization iteration.
//!
//! L3 hot paths: simulator sweep, mapping allocator, behavioural
//! strategy models, NNS+A/NNADC native forwards, coordinator round-trip.
//! PJRT path: executable compile + execute latency per artifact.

mod bench_util;

use bench_util::{bench, try_or_skip};
use neural_pim::arch::crossbar::Group;
use neural_pim::config::AcceleratorConfig;
use neural_pim::coordinator::{Coordinator, CoordinatorConfig};
use neural_pim::runtime::{self, Runtime};
use neural_pim::util::pool;
use neural_pim::util::rng::Pcg;
use neural_pim::{dse, mapping, noise, sim, workloads};
use std::time::Instant;

/// Mean wall-clock seconds of `iters` runs (1 warmup).
fn time_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Time `f` sequentially (1 thread) vs on the full pool and report the
/// wall-clock speedup — the §Perf number the parallel evaluation engine
/// is judged by.
fn speedup<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    pool::set_threads(1);
    let seq = time_secs(iters, &mut f);
    pool::set_threads(0);
    let par = time_secs(iters, &mut f);
    println!(
        "[bench] {name}: seq {:.1} ms, par {:.1} ms -> {:.2}x speedup \
         with {} threads",
        seq * 1e3,
        par * 1e3,
        seq / par.max(1e-12),
        pool::threads()
    );
}

fn main() -> anyhow::Result<()> {
    println!("### §Perf hot paths\n");

    // L3: simulator — sequential vs parallel across the pool
    let nets = workloads::all_benchmarks();
    speedup("simulate all 9 benchmarks x 3 archs (iso-area)", 5, || {
        let _ = sim::run_system_comparison(&nets);
    });
    speedup("full DSE sweep (~600 grid points)", 5, || {
        let _ = dse::sweep();
    });
    speedup("strategy-B noise MC (1024 trials)", 3, || {
        let _ = noise::strategy_sinad('B', 1024, 2);
    });
    bench("simulate all 9 benchmarks x 3 archs (iso-area)", 1, 10, || {
        let _ = sim::run_system_comparison(&nets);
    });
    let vgg = workloads::vgg16();
    let cfg = AcceleratorConfig::neural_pim();
    bench("map_network(VGG-16)", 2, 20, || {
        let _ = mapping::map_network(&vgg, &cfg);
    });

    // L3: behavioural dataflow models (the MC inner loop)
    let mut rng = Pcg::new(1);
    let w: Vec<i32> = (0..128).map(|_| rng.below(255) as i32 - 127).collect();
    let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
    let g = Group { w };
    bench("strategy_a dot (native, pd=1)", 5, 200, || {
        std::hint::black_box(g.strategy_a(&x, 1, 255.0, 128));
    });
    bench("strategy_c dot (native, pd=4)", 5, 200, || {
        std::hint::black_box(g.strategy_c(&x, 4, 255.0, 4.15e6));
    });

    // PJRT: compile + execute
    let Some(rt) = try_or_skip("runtime", Runtime::new(&neural_pim::artifact_dir()))
    else {
        return Ok(());
    };
    let exe = rt.load("cnn_ideal")?;
    println!("[compile] cnn_ideal: {:.2}s", exe.compile_seconds);
    let ts = runtime::TestSet::load(rt.dir())?;
    let images = ts.batch_literal(0, 128)?;
    bench("cnn_ideal execute (batch 128)", 2, 20, || {
        let _ = exe.run_refs(&[&images]).unwrap();
    });

    // coordinator round-trip (queue + batch + execute + demux)
    let (h, w_, c) = ts.dims;
    let coord = Coordinator::start(
        CoordinatorConfig { artifact_dir: neural_pim::artifact_dir(),
                            max_wait: std::time::Duration::from_millis(1),
                            ..Default::default() },
        h * w_ * c,
    )?;
    let stride = h * w_ * c;
    bench("coordinator round-trip (128 requests)", 1, 10, || {
        let mut pending = Vec::new();
        for i in 0..128 {
            let idx = i % ts.n;
            pending.push(
                coord
                    .submit(ts.images[idx * stride..(idx + 1) * stride].to_vec())
                    .unwrap(),
            );
        }
        for rx in pending {
            let _ = rx.recv().unwrap();
        }
    });
    println!("{}", coord.metrics.summary());
    coord.shutdown();
    Ok(())
}
