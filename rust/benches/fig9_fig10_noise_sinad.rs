//! Bench: Fig. 9 (Monte-Carlo error of the trained analog dataflow, with
//! and without circuit-level optimizations) and Fig. 10 (inference
//! accuracy vs injected SINAD with the per-dataflow markers), plus the
//! Fig. 6(a) NNS+A output-range distribution.

mod bench_util;

use bench_util::{bench, try_or_skip};
use neural_pim::runtime;
use neural_pim::serve::open_runtime;
use neural_pim::util::stats;
use neural_pim::util::table::Table;
use neural_pim::{noise, workloads};

fn main() -> anyhow::Result<()> {
    println!("### Fig 9 / Fig 10 — noise and SINAD\n");
    let Some(rt) = try_or_skip("runtime", open_runtime(&neural_pim::artifact_dir()))
    else {
        return Ok(());
    };

    // ---- Fig 9: MC through the trained NeuralPeriph circuits
    let mut t = Table::new(
        "Fig 9: D_hw - D_sw statistics (trained NNS+A + NNADC, PJRT MC)",
        &["variant", "SINAD (dB)", "err rms", "bias", "min", "max"],
    );
    let mut np_sinad = 0.0;
    for (name, artifact) in [("9a optimized", "mc_opt"),
                             ("9b no optimizations", "mc_naive")] {
        let exe = rt.load(artifact)?;
        let mut hw = Vec::new();
        let mut sw = Vec::new();
        for k in 0..4u64 {
            let out = exe.run(&[runtime::lit_key(42 + k)?])?;
            hw.extend(runtime::to_f32_vec(&out[0])?.iter().map(|&v| v as f64));
            sw.extend(runtime::to_f32_vec(&out[1])?.iter().map(|&v| v as f64));
        }
        let r = noise::mc_result(&hw, &sw);
        if artifact == "mc_opt" {
            np_sinad = r.sinad_db;
        }
        t.row(&[
            name.into(),
            format!("{:.1}", r.sinad_db),
            format!("{:.0}", r.err_rms),
            format!("{:.0}", r.err_mean),
            format!("{:.0}", r.err_min),
            format!("{:.0}", r.err_max),
        ]);
        let key = runtime::lit_key(3)?;
        bench(&format!("{artifact} MC batch (1024 dot products)"), 1, 5, || {
            let _ = exe.run_refs(&[&key]).unwrap();
        });
    }
    t.print();

    // baseline dataflow markers (native behavioural models)
    let a = noise::strategy_sinad('A', 1024, 1);
    let b = noise::strategy_sinad('B', 1024, 1);
    println!(
        "Fig 10 markers: Neural-PIM {np_sinad:.1} dB, ISAAC-style {a:.1} dB, \
         CASCADE-style {b:.1} dB (paper ordering: CASCADE lowest)\n"
    );
    bench("native strategy-B SINAD (1024 dots)", 1, 5, || {
        let _ = noise::strategy_sinad('B', 1024, 2);
    });

    // ---- Fig 10: accuracy vs injected SINAD (Eq. 13)
    let ts = runtime::TestSet::load(rt.dir())?;
    let exe = rt.load("cnn_noisy")?;
    let mut t = Table::new(
        "Fig 10: accuracy vs SINAD (Eq. 13 noise injection, 512 images)",
        &["SINAD (dB)", "accuracy"],
    );
    let mut sinad_min = f64::NAN;
    let mut ideal_acc = 0.0;
    for s in [5.0f64, 10.0, 15.0, 20.0, 25.0, 30.0, 35.0, 40.0, 50.0, 60.0] {
        let mut correct = 0usize;
        for bidx in 0..(ts.n / 128) {
            let out = exe.run(&[
                ts.batch_literal(bidx * 128, 128)?,
                runtime::lit_key(7 + bidx as u64)?,
                runtime::lit_scalar_f32(s as f32),
            ])?;
            let logits = runtime::to_f32_vec(&out[0])?;
            correct += (runtime::accuracy(&logits,
                                          &ts.batch_labels(bidx * 128, 128), 10)
                * 128.0)
                .round() as usize;
        }
        let acc = correct as f64 / ts.n as f64;
        if s == 60.0 {
            ideal_acc = acc;
        }
        t.row(&[format!("{s:.0}"), format!("{acc:.4}")]);
        if sinad_min.is_nan() && acc > 0.99 * 0.996 {
            sinad_min = s;
        }
    }
    t.print();
    println!(
        "SINAD_min (software-equivalent accuracy) ≈ {sinad_min:.0} dB; \
         measured Neural-PIM dataflow SINAD {np_sinad:.1} dB -> {}",
        if np_sinad >= sinad_min {
            "no accuracy loss (paper's conclusion reproduced)"
        } else {
            "accuracy at risk"
        }
    );
    let _ = ideal_acc;

    // ---- Fig 6a: distribution of layer output ranges (d_max calibration)
    let cnn_text = std::fs::read_to_string(rt.dir().join("cnn.json"))?;
    let cnn = neural_pim::util::json::Json::parse(&cnn_text)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(d_max) = cnn.get("d_max").and_then(|d| d.as_arr()) {
        let vals: Vec<f64> = d_max.iter().filter_map(|v| v.as_f64()).collect();
        let worst = 128.0 * 255.0 * 127.0; // array-max dot product
        let mut t = Table::new(
            "Fig 6a: per-layer analog swing vs full scale (range-aware NNADC)",
            &["layer", "max |D|", "fraction of array max", "selected V_max"],
        );
        let nets = workloads::synthetic_cnn();
        for (i, v) in vals.iter().enumerate() {
            let frac = v / worst;
            let sel = if frac <= 0.125 {
                "0.125 VDD"
            } else if frac <= 0.25 {
                "0.25 VDD"
            } else if frac <= 0.5 {
                "0.5 VDD"
            } else {
                "VDD"
            };
            t.row(&[
                nets.layers.get(i).map(|l| l.name.clone())
                    .unwrap_or_else(|| format!("layer{i}")),
                format!("{v:.0}"),
                format!("{:.3}", frac),
                sel.into(),
            ]);
        }
        t.print();
        println!("spread of max swings: {:.3} (min) .. {:.3} (max) of full \
                  scale — the Fig. 6a motivation for range-aware NNADCs",
                 stats::min(&vals) / worst, stats::max(&vals) / worst);
    }
    Ok(())
}
