//! Shared timing harness for the `harness = false` benches (criterion is
//! unavailable offline). Warmup + N timed iterations + robust stats.

use neural_pim::util::stats::Samples;
use std::time::Instant;

pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!("[bench] {name}: {}", s.summary("ms"));
}

/// Time a fallible setup once, reporting failures without panicking the
/// whole bench binary (artifacts may be missing in some environments).
pub fn try_or_skip<T>(what: &str, r: anyhow::Result<T>) -> Option<T> {
    match r {
        Ok(v) => Some(v),
        Err(e) => {
            println!("[bench] SKIP {what}: {e:#}");
            None
        }
    }
}
