//! Bench: regenerates Fig. 3(d)/Fig. 4 — the §3 dataflow characterization
//! (analytical) plus the Fig. 4(a) accuracy-vs-ADC-resolution sweep
//! through the AOT dataflow artifacts when present.

mod bench_util;

use bench_util::{bench, try_or_skip};
use neural_pim::report;
use neural_pim::runtime;
use neural_pim::serve::open_runtime;
use neural_pim::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("### Fig 3d / Fig 4 — dataflow characterization\n");
    report::characterization_table().print();
    report::fig4b_table().print();
    report::fig4c_table().print();

    bench("analytical framework (full Fig4b+4c recompute)", 3, 50, || {
        let _ = report::fig4b_table();
        let _ = report::fig4c_table();
    });

    // Fig 4a through PJRT (needs artifacts)
    let Some(rt) = try_or_skip("runtime", open_runtime(&neural_pim::artifact_dir()))
    else {
        return Ok(());
    };
    let ts = runtime::TestSet::load(rt.dir())?;
    let mut t = Table::new(
        "Fig 4a: inference accuracy vs A/D resolution (128 images/point)",
        &["ADC bits", "Strategy A", "Strategy B", "Strategy C"],
    );
    for bits in [2u32, 4, 6, 8, 10] {
        let mut row = vec![bits.to_string()];
        for s in ["A", "B", "C"] {
            let exe = rt.load(&format!("cnn_strat{s}"))?;
            let mut inputs = vec![
                ts.batch_literal(0, 128)?,
                runtime::lit_scalar_f32((1u64 << bits) as f32 - 1.0),
            ];
            if s != "A" {
                inputs.push(runtime::lit_key(42)?);
            }
            let out = exe.run(&inputs)?;
            let logits = runtime::to_f32_vec(&out[0])?;
            let acc =
                runtime::accuracy(&logits, &ts.batch_labels(0, 128), 10);
            row.push(format!("{acc:.3}"));
        }
        t.row(&row);
    }
    t.print();

    // end-to-end execute latency of each strategy artifact at 8 bits
    for s in ["A", "B", "C"] {
        let exe = rt.load(&format!("cnn_strat{s}"))?;
        let images = ts.batch_literal(0, 128)?;
        let levels = runtime::lit_scalar_f32(255.0);
        let key = runtime::lit_key(1)?;
        let mut inputs = vec![&images, &levels];
        if s != "A" {
            inputs.push(&key);
        }
        bench(&format!("cnn_strat{s} execute (batch 128)"), 1, 3, || {
            let _ = exe.run_refs(&inputs).expect("execute failed");
        });
    }
    Ok(())
}
