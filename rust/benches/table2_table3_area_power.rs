//! Bench: Table 2 (Neural-PIM tile parameters) and Table 3 (PE-level
//! architecture comparison incl. density).

mod bench_util;

use bench_util::bench;
use neural_pim::report;

fn main() {
    println!("### Table 2 / Table 3 — area & power budgets\n");
    report::table2().print();
    report::table3().print();

    bench("tile+chip budget assembly (all 3 architectures)", 3, 100, || {
        let _ = report::table2();
        let _ = report::table3();
    });
}
