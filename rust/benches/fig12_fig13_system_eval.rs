//! Bench: Fig. 12 (energy + throughput, 9 benchmarks x 3 architectures at
//! iso-area) and Fig. 13 (system energy breakdown) — the headline
//! 5.36x / 1.73x / 3.43x / 1.59x experiment.

mod bench_util;

use bench_util::bench;
use neural_pim::report;
use neural_pim::workloads;

fn main() {
    println!("### Fig 12 / Fig 13 — full-system evaluation\n");
    let nets = workloads::all_benchmarks();
    let r = report::system_report(&nets);
    r.table_energy.print();
    r.table_throughput.print();
    r.table_breakdown.print();
    r.table_latency.print();
    println!("{}\n", r.headline);

    bench("full 9-benchmark x 3-architecture simulation", 1, 10, || {
        let _ = neural_pim::sim::run_system_comparison(&nets);
    });
}
