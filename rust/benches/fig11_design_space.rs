//! Bench: Fig. 11 — the (N, M, A, S, D) design-space sweep and the
//! optimum it selects, with timing of the sweep itself.

mod bench_util;

use bench_util::bench;
use neural_pim::config::AcceleratorConfig;
use neural_pim::dse;
use neural_pim::report;

fn main() {
    println!("### Fig 11 — design-space exploration\n");
    report::fig11_table(15).print();

    let pts = dse::sweep();
    println!("feasible points: {}", pts.len());
    let best = dse::best();
    let paper = dse::evaluate(&AcceleratorConfig::neural_pim()).unwrap();
    println!(
        "optimum: {} at {:.1} GOPS/s/mm²; paper's choice {} at {:.1} \
         (paper reports 1904.0)",
        best.label, best.compute_efficiency,
        paper.label, paper.compute_efficiency
    );

    bench("full DSE sweep", 1, 10, || {
        let _ = dse::sweep();
    });
}
