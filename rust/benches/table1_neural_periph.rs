//! Bench: Table 1 — the trained NeuralPeriph circuits, measured natively
//! (Rust forward) and through the PJRT artifacts, plus conversion-rate
//! microbenchmarks.

mod bench_util;

use bench_util::{bench, try_or_skip};
use neural_pim::periph::{self, Periph};
use neural_pim::runtime;
use neural_pim::serve::open_runtime;
use neural_pim::util::rng::Pcg;
use neural_pim::util::stats;
use neural_pim::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("### Table 1 — NeuralPeriph circuits\n");
    let dir = neural_pim::artifact_dir();
    let Some(p) = try_or_skip("periph.json",
                              Periph::load(&format!("{dir}/periph.json")))
    else {
        return Ok(());
    };

    let (mse, emax, emin) = p.nns_a_error_stats(16384, 42);
    let tr = p.nnadc.transfer(1 << 13);
    let (dnl, inl, missing) = periph::dnl_inl(&tr, 8);
    let (enob, sinad) = periph::enob(&p.nnadc, 1 << 13);
    let tr_nv = p.nnadc_naive.transfer(1 << 13);
    let (dnl_nv, inl_nv, _) = periph::dnl_inl(&tr_nv, 8);

    let mut t = Table::new(
        "Table 1 (measured vs paper)",
        &["metric", "measured", "paper"],
    );
    t.row(&["NNS+A MSE (V²)".into(), format!("{mse:.2e}"), "< 1e-5".into()]);
    t.row(&["NNS+A max err (mV)".into(), format!("{:.1}", emax * 1e3),
            "4-5".into()]);
    t.row(&["NNS+A min err (mV)".into(), format!("{:.1}", emin * 1e3),
            "-3..-4".into()]);
    t.row(&["NNADC DNL (LSB)".into(),
            format!("{:.2}/{:.2}", stats::min(&dnl), stats::max(&dnl)),
            "-0.25/0.55".into()]);
    t.row(&["NNADC INL (LSB)".into(),
            format!("{:.2}/{:.2}", stats::min(&inl), stats::max(&inl)),
            "-0.56/0.62".into()]);
    t.row(&["NNADC missing codes".into(), missing.to_string(), "0".into()]);
    t.row(&["NNADC ENOB (bits)".into(), format!("{enob:.2}"), "7.88".into()]);
    t.row(&["NNADC SINAD (dB)".into(), format!("{sinad:.1}"), "~49".into()]);
    t.row(&["naive NNADC DNL".into(),
            format!("{:.2}/{:.2}", stats::min(&dnl_nv), stats::max(&dnl_nv)),
            "(ablation)".into()]);
    t.row(&["naive NNADC INL".into(),
            format!("{:.2}/{:.2}", stats::min(&inl_nv), stats::max(&inl_nv)),
            "(ablation)".into()]);
    t.print();

    // native forward microbenchmarks (the simulator's hot inner loops)
    let mut rng = Pcg::new(0);
    let mut vin = [0.0f64; 9];
    bench("NNS+A native forward x1024", 3, 50, || {
        let mut acc = 0.0;
        for _ in 0..1024 {
            for v in vin.iter_mut() {
                *v = rng.range(-0.25, 0.25);
            }
            acc += p.nns_a.forward(&vin, 0.6);
        }
        std::hint::black_box(acc);
    });
    bench("NNADC native convert x1024", 3, 50, || {
        let mut acc = 0u32;
        for i in 0..1024 {
            acc = acc.wrapping_add(p.nnadc.convert(i as f64 / 1024.0));
        }
        std::hint::black_box(acc);
    });

    // PJRT artifact path
    if let Some(rt) = try_or_skip("runtime", open_runtime(&dir)) {
        let exe = rt.load("nns_a")?;
        let v: Vec<f32> = (0..1024 * 9).map(|i| (i % 97) as f32 * 0.002).collect();
        let lit = runtime::lit_f32(&v, &[1024, 9])?;
        bench("NNS+A PJRT execute (batch 1024)", 2, 20, || {
            let _ = exe.run_refs(&[&lit]).unwrap();
        });
        // cross-check PJRT vs native on the first row
        let out = exe.run_refs(&[&lit])?;
        let got = runtime::to_f32_vec(&out[0])?[0] as f64;
        let mut row = [0.0f64; 9];
        for (k, r) in row.iter_mut().enumerate() {
            *r = v[k] as f64;
        }
        let want = p.nns_a.forward(&row, 0.6);
        println!("[check] PJRT vs native NNS+A: {got:.6} vs {want:.6} \
                  (diff {:.2e})", (got - want).abs());
        assert!((got - want).abs() < 1e-4);

        let adc_exe = rt.load("nnadc")?;
        let v: Vec<f32> = (0..1024).map(|i| i as f32 / 1023.0).collect();
        let lit = runtime::lit_f32(&v, &[1024])?;
        bench("NNADC PJRT execute (batch 1024)", 2, 20, || {
            let _ = adc_exe.run_refs(&[&lit]).unwrap();
        });
    }
    Ok(())
}
