//! Acceptance gates for the `offload/` subsystem (the PIM + NPU hybrid
//! placement search):
//!
//! 1. **Never-worse property**: on every catalog network the hybrid EDP
//!    is `<= min(all-PIM, all-NPU)`, with a strict win on at least one
//!    network (both strategies evaluate the pure extremes).
//! 2. **Strategy ordering**: exhaustive (the true optimum) lower-bounds
//!    hill-climb, which lower-bounds the pure floor, on every network
//!    small enough to enumerate.
//! 3. **Determinism**: the `offload` scenario's outcome JSON is
//!    byte-identical at `--threads 1/2/8` and on `--cache` replay, and
//!    a fixed `(network, seed)` pair reproduces bit-identically.

use neural_pim::config::AcceleratorConfig;
use neural_pim::model;
use neural_pim::offload::{self, LayerTable, Strategy};
use neural_pim::scenario::{self, ExecOptions, Params, Scenario};
use neural_pim::util::json::Json;
use neural_pim::util::pool;
use neural_pim::workloads;

fn offload_params(json: &str) -> (&'static dyn Scenario, Params) {
    let sc = scenario::find("offload").expect("offload is registered");
    let p = scenario::params_from_json(&sc.param_specs(),
                                       &Json::parse(json).unwrap())
        .unwrap();
    (sc, p)
}

fn run_offload(json: &str) -> scenario::Outcome {
    let (sc, p) = offload_params(json);
    sc.run(&p).unwrap()
}

// ---------------------------------------------------------------------------
// the never-worse property, over the whole catalog
// ---------------------------------------------------------------------------

#[test]
fn hybrid_never_loses_on_any_catalog_network() {
    let cfg_pim = AcceleratorConfig::neural_pim();
    let cfg_npu = offload::default_npu_config();
    let mut strict = Vec::new();
    for net in workloads::all_benchmarks() {
        let r = offload::optimize(&net, &cfg_pim, &cfg_npu, Strategy::Auto,
                                  42);
        assert!(
            r.hybrid.edp <= r.best_pure_edp(),
            "{}: hybrid {} > pure floor {}",
            net.name, r.hybrid.edp, r.best_pure_edp()
        );
        assert_eq!(r.placement.len(), net.layers.len(), "{}", net.name);
        if r.hybrid.edp < r.best_pure_edp() {
            strict.push(net.name.to_string());
        }
    }
    assert!(!strict.is_empty(),
            "the hybrid must strictly beat both pure extremes somewhere");
}

// ---------------------------------------------------------------------------
// strategy ordering on exhaustively-enumerable networks
// ---------------------------------------------------------------------------

#[test]
fn exhaustive_bounds_hillclimb_which_bounds_the_pure_floor() {
    let cfg_pim = AcceleratorConfig::neural_pim();
    let cfg_npu = offload::default_npu_config();
    for name in ["AlexNet", "VGG-16", "NeuralTalk", "SyntheticCNN"] {
        let net = workloads::by_name(name).unwrap();
        assert!(net.layers.len() <= offload::search::EXHAUSTIVE_MAX,
                "{name} grew past the exhaustive cap");
        let pim = model::network_cost(&net, &cfg_pim);
        let npu = model::network_cost(&net, &cfg_npu);
        let table = LayerTable::build(&cfg_pim, &pim, &cfg_npu, &npu);
        let n = table.len();
        let floor = table.eval(&vec![false; n]).2
            .min(table.eval(&vec![true; n]).2);
        let ex = offload::search::run(&table, Strategy::Exhaustive, 42);
        let hc = offload::search::run(&table, Strategy::HillClimb, 42);
        let bd = offload::search::run(&table, Strategy::Bandit, 42);
        // the true optimum lower-bounds every heuristic, and every
        // strategy includes both pure extremes
        assert!(ex.edp.total_cmp(&hc.edp).is_le(),
                "{name}: exhaustive {} > hillclimb {}", ex.edp, hc.edp);
        assert!(ex.edp.total_cmp(&bd.edp).is_le(),
                "{name}: exhaustive {} > bandit {}", ex.edp, bd.edp);
        assert!(hc.edp.total_cmp(&floor).is_le(),
                "{name}: hillclimb {} > pure floor {floor}", hc.edp);
        assert!(bd.edp.total_cmp(&floor).is_le(),
                "{name}: bandit {} > pure floor {floor}", bd.edp);
        assert_eq!(ex.evals, 1u64 << n, "{name}");
    }
}

#[test]
fn vgg16_hybrid_strictly_beats_both_extremes() {
    // the calibration anchor: short-K conv1_1 moves to the NPU while
    // the dense stack stays on PIM
    let net = workloads::by_name("VGG-16").unwrap();
    let r = offload::optimize(&net, &AcceleratorConfig::neural_pim(),
                              &offload::default_npu_config(),
                              Strategy::Exhaustive, 42);
    assert!(r.hybrid.edp < r.best_pure_edp());
    assert!(r.npu_layers() >= 1);
    assert!(r.edp_win() > 0.0);
}

// ---------------------------------------------------------------------------
// determinism: seed pin, thread invariance, cache replay
// ---------------------------------------------------------------------------

#[test]
fn golden_alexnet_seed_pair_reproduces_bit_identically() {
    // desk-validated pin: at the shipped NPU constants AlexNet is
    // all-PIM optimal (its conv layers are long-K and dense), so the
    // exhaustive winner is the all-PIM mask with zero strict wins
    let net = workloads::by_name("AlexNet").unwrap();
    let cfg_pim = AcceleratorConfig::neural_pim();
    let cfg_npu = offload::default_npu_config();
    let r = offload::optimize(&net, &cfg_pim, &cfg_npu,
                              Strategy::Exhaustive, 42);
    assert!(r.placement.iter().all(|p| !p.is_npu()),
            "AlexNet should stay all-PIM: {:?}", r.placement);
    assert_eq!(r.improved, 0);
    assert_eq!(r.hybrid.edp.to_bits(), r.all_pim.edp.to_bits(),
               "the all-PIM winner must price identically to the pure \
                extreme (same eval path)");
    // and the pair (network, seed) reproduces bit-for-bit
    let r2 = offload::optimize(&net, &cfg_pim, &cfg_npu,
                               Strategy::Exhaustive, 42);
    assert_eq!(r.placement, r2.placement);
    assert_eq!(r.hybrid.edp.to_bits(), r2.hybrid.edp.to_bits());
    assert_eq!(r.evals, r2.evals);
}

#[test]
fn outcome_json_is_thread_count_invariant() {
    // hillclimb and bandit both derive randomness from forked streams
    // laid out before the parallel fan-out; exhaustive reduces fixed
    // mask chunks in index order — all must be byte-identical at any
    // thread count
    for params in [
        r#"{"network": "SyntheticCNN", "search": "exhaustive"}"#,
        r#"{"network": "MobileNet-V2", "search": "hillclimb", "seed": 7}"#,
        r#"{"network": "MobileNet-V2", "search": "bandit", "seed": 7}"#,
    ] {
        let mut renders = Vec::new();
        for t in [1usize, 2, 8] {
            pool::set_threads(t);
            renders.push(run_offload(params).to_json().to_string());
        }
        pool::set_threads(0);
        assert_eq!(renders[0], renders[1], "{params}: threads 1 vs 2");
        assert_eq!(renders[0], renders[2], "{params}: threads 1 vs 8");
    }
}

#[test]
fn cached_offload_replays_byte_identically() {
    let root = std::env::temp_dir()
        .join(format!("np-offload-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let (sc, p) = offload_params(r#"{"network": "AlexNet"}"#);
    let opts = ExecOptions {
        cache: true,
        results_dir: root.to_string_lossy().into_owned(),
    };
    let first = scenario::execute(sc, &p, &opts).unwrap();
    assert!(!first.cached);
    let second = scenario::execute(sc, &p, &opts).unwrap();
    assert!(second.cached, "second run must replay from the store");
    assert_eq!(first.outcome.to_json(), second.outcome.to_json());
    assert_eq!(first.outcome.render_text(), second.outcome.render_text());
    std::fs::remove_dir_all(&root).unwrap();
}

// ---------------------------------------------------------------------------
// scenario surface
// ---------------------------------------------------------------------------

#[test]
fn scenario_reports_split_metrics_and_search_counters() {
    let o = run_offload(r#"{"network": "VGG-16"}"#);
    // summary table + per-layer split table (single-network run)
    assert_eq!(o.tables.len(), 2);
    let win = o.get_metric("edp_win/VGG-16").expect("win metric");
    assert!(win > 0.0, "VGG-16 must report a strict hybrid win");
    assert!(o.get_metric("npu_layers/VGG-16").unwrap() >= 1.0);
    let edp = o.get_metric("edp/VGG-16").unwrap();
    let pim = o.get_metric("edp_all_pim/VGG-16").unwrap();
    let npu = o.get_metric("edp_all_npu/VGG-16").unwrap();
    assert!(edp <= pim.min(npu));
    assert!(o.get_metric("obs/offload.evals").unwrap() >= (1 << 16) as f64);
    assert!(o.get_metric("npu_tops_peak").unwrap() > 0.0);
    // the strategy param is a closed choice: typos die at parse time
    let sc = scenario::find("offload").unwrap();
    let err = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(r#"{"search": "exhaustiv"}"#).unwrap(),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("did you mean 'exhaustive'"),
            "{err:#}");
}
