//! Nested `pool::map` semantics, end to end: the suite runner fans
//! scenarios across the worker pool while every scenario's own sweeps
//! (`dse::sweep`, the characterization tables) issue their own
//! `pool::map` calls from inside pool tasks. The persistent-pool
//! contract says those inner calls run inline on the participant — so a
//! nested suite is (a) bit-identical to a sequential run at any thread
//! count and (b) never spawns workers beyond the pool's configured size.
//!
//! This lives in its own integration binary with a single #[test] so the
//! `spawned_workers()` bookkeeping can't race a concurrently-running
//! test's pool resize.

use neural_pim::scenario::{self, suite};
use neural_pim::util::json::Json;
use neural_pim::util::pool;

fn spec() -> suite::SuiteSpec {
    suite::SuiteSpec::from_json(
        &Json::parse(
            r#"{"name": "nested", "scenarios": [
                {"scenario": "dse"},
                {"scenario": "characterize"},
                {"scenario": "table2"},
                {"scenario": "table3"}]}"#,
        )
        .unwrap(),
    )
    .unwrap()
}

/// Scenario name + rendered text of every entry: the byte-identity
/// anchor (render_text covers tables, notes, and metric formatting).
fn render(r: &suite::SuiteReport) -> Vec<(String, String)> {
    r.entries
        .iter()
        .map(|e| {
            let body = match &e.result {
                Ok(o) => o.render_text(),
                Err(err) => format!("FAILED: {err}"),
            };
            (e.scenario.clone(), body)
        })
        .collect()
}

#[test]
fn nested_suite_is_deterministic_and_spawns_no_nested_workers() {
    let spec = spec();
    let opts = scenario::ExecOptions::default(); // no store: compute live

    // sequential baseline: pool fully bypassed
    pool::set_threads(1);
    let seq = suite::run_spec(&spec, &opts);
    assert_eq!(seq.failures(), 0, "sequential suite failed");
    let baseline = render(&seq);

    for t in [2usize, 8] {
        pool::set_threads(t);
        // warm the pool to its size for this thread count, so any
        // further spawn during the nested suite would be a nested worker
        let warm: Vec<u64> = (0..64).collect();
        let _ = pool::map(&warm, |&x| x + 1);
        let before = pool::spawned_workers();
        let got = render(&suite::run_spec(&spec, &opts));
        let after = pool::spawned_workers();
        assert_eq!(got, baseline, "suite output diverged at {t} threads");
        assert_eq!(
            after, before,
            "nested suite spawned extra workers at {t} threads"
        );
    }
    pool::set_threads(0);
}
