//! Integration gates for `serve::fleet` (the fleet-scale serving
//! simulator):
//!
//! 1. **Determinism**: a fleet run — and the whole rendered `fleet-sim`
//!    outcome — is bit-identical at `--threads 1/2/8` (the chunked
//!    two-pass merge contract).
//! 2. **Conservation**: every generated arrival is either served or
//!    shed, at every router policy.
//! 3. **Scenario surface**: `fleet-sim` emits the typed knee point and
//!    the per-class energy metrics through the generic JSON path.

use neural_pim::scenario::{self, Scenario};
use neural_pim::serve::fleet;
use neural_pim::util::json::Json;
use neural_pim::util::pool;
use neural_pim::workloads;

fn classes() -> Vec<fleet::ChipClass> {
    let net = workloads::synthetic_cnn();
    let mix = fleet::parse_fleet("neural-pim:4,isaac:2,cascade:1,lowres:1")
        .unwrap();
    fleet::build_classes(&net, &mix, 32)
}

fn cfg() -> fleet::FleetConfig {
    fleet::FleetConfig { arrivals: 16_384, ..Default::default() }
}

#[test]
fn fleet_run_is_bit_identical_at_threads_1_2_8() {
    let classes = classes();
    let mut fps = Vec::new();
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let r = fleet::run_fleet(&cfg(), &classes);
        fps.push((t, fleet::fingerprint(&r), r.per_chip.clone()));
        pool::set_threads(0);
    }
    assert_eq!(fps[0].1, fps[1].1,
               "diverged at 2 threads: {:?} vs {:?}", fps[0].2, fps[1].2);
    assert_eq!(fps[0].1, fps[2].1,
               "diverged at 8 threads: {:?} vs {:?}", fps[0].2, fps[2].2);
}

#[test]
fn fleet_sim_outcome_is_thread_count_invariant() {
    // the scenario-level bar: every table cell and metric bit of the
    // rendered outcome identical at any --threads (knee sweep included)
    let run = |threads: usize| {
        pool::set_threads(threads);
        let sc = scenario::find("fleet-sim").unwrap();
        let p = scenario::params_from_json(
            &sc.param_specs(),
            &Json::parse(
                r#"{"arrivals": 8192, "sweep-arrivals": 2048,
                    "fleet": "neural-pim:2,isaac:1"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let o = sc.run(&p).unwrap();
        pool::set_threads(0);
        o.to_json().to_string()
    };
    let one = run(1);
    assert_eq!(one, run(2), "fleet-sim diverged at 2 threads");
    assert_eq!(one, run(8), "fleet-sim diverged at 8 threads");
}

#[test]
fn every_policy_conserves_arrivals_and_routes_work() {
    let classes = classes();
    for policy in ["round-robin", "join-shortest-queue", "latency-aware"] {
        let cfg = fleet::FleetConfig {
            policy: fleet::RouterPolicy::parse(policy).unwrap(),
            ..cfg()
        };
        let r = fleet::run_fleet(&cfg, &classes);
        assert_eq!(r.served + r.shed, r.arrivals, "{policy}");
        assert!(r.served > 0, "{policy}: nothing served");
        // at offered 0.9 with balancing policies, every chip does work
        let idle = r.per_chip.iter().filter(|c| c.0 == 0).count();
        assert_eq!(idle, 0, "{policy}: {idle} chips never served");
        assert!(r.p99_ms >= r.p50_ms, "{policy}: percentile order");
    }
}

#[test]
fn fleet_sim_scenario_emits_knee_and_energy_metrics() {
    let sc = scenario::find("fleet-sim").unwrap();
    let p = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(
            r#"{"arrivals": 8192, "sweep-arrivals": 2048,
                "fleet": "neural-pim:2,isaac:1"}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let o = sc.run(&p).unwrap();
    // typed knee point from the chip-count sweep
    let knee = o.get_metric("knee_chips").expect("knee_chips metric");
    assert!(knee >= 1.0, "degenerate knee {knee}");
    // per-class energy per inference, priced from the model registry
    assert!(o.get_metric("energy_uj_per_inf@Neural-PIM").unwrap() > 0.0);
    assert!(o.get_metric("energy_uj_per_inf@ISAAC-like").unwrap() > 0.0);
    // conservation through the obs counters
    let served = o.get_metric("obs/fleet.served").unwrap();
    let shed = o.get_metric("obs/fleet.shed").unwrap();
    assert_eq!(served + shed, 8192.0);
    // two tables: per-class stats + the chip-count sweep
    assert_eq!(o.tables.len(), 2);
}

#[test]
fn bad_fleet_specs_and_policies_fail_loudly() {
    let sc = scenario::find("fleet-sim").unwrap();
    let bad_fleet = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(r#"{"fleet": "neural-pimm:2"}"#).unwrap(),
    )
    .unwrap();
    let err = format!("{:#}", sc.run(&bad_fleet).unwrap_err());
    assert!(err.contains("did you mean"), "{err}");
    let bad_policy = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(r#"{"policy": "shortest"}"#).unwrap(),
    )
    .unwrap();
    assert!(sc.run(&bad_policy).is_err());
}
