//! Behaviour-preservation gates for the trait-based `model` refactor.
//!
//! The Fig. 12 headline geomeans are pinned to their pre-refactor
//! values (regenerated independently by `scripts/golden_fig12.py`, a
//! line-for-line port of the pre-`model` analytical chain) within 1e-9
//! relative tolerance: the refactor is a reorganization, not a model
//! change, and any arithmetic drift fails here. The registry tests
//! assert that EVERY registered architecture — including ones added
//! after this PR — satisfies the structural invariants the comparisons
//! rely on (breakdown closure, iso-area budget), and that the
//! RAELLA-style `LowResolution` arch flows end-to-end through
//! `simulate --all`, `table3`, iso-area comparisons and `event-sim`
//! without any call-site edits.

use neural_pim::config::{AcceleratorConfig, Architecture};
use neural_pim::{energy, event, model, report, sim, workloads};

/// Relative tolerance on the pinned geomeans.
const REL_TOL: f64 = 1e-9;

/// Pre-refactor golden values (scripts/golden_fig12.py).
const GOLDEN_ENERGY_VS_ISAAC: f64 = 7.337388417092984;
const GOLDEN_ENERGY_VS_CASCADE: f64 = 2.504888027946908;
const GOLDEN_THROUGHPUT_VS_ISAAC: f64 = 4.311839456831666;
const GOLDEN_THROUGHPUT_VS_CASCADE: f64 = 1.5862749137996275;
const GOLDEN_REFERENCE_AREA_MM2: f64 = 146.0951439526401;

#[test]
fn fig12_headline_geomeans_match_pre_refactor_golden() {
    let nets = workloads::all_benchmarks();
    let cmp = sim::run_system_comparison(&nets);
    let cases = [
        ("energy vs ISAAC", cmp.energy_ratio(Architecture::IsaacLike),
         GOLDEN_ENERGY_VS_ISAAC),
        ("energy vs CASCADE", cmp.energy_ratio(Architecture::CascadeLike),
         GOLDEN_ENERGY_VS_CASCADE),
        ("throughput vs ISAAC",
         cmp.throughput_ratio(Architecture::IsaacLike),
         GOLDEN_THROUGHPUT_VS_ISAAC),
        ("throughput vs CASCADE",
         cmp.throughput_ratio(Architecture::CascadeLike),
         GOLDEN_THROUGHPUT_VS_CASCADE),
    ];
    for (what, got, want) in cases {
        assert!(
            (got - want).abs() <= REL_TOL * want,
            "{what} geomean drifted from the pre-refactor golden: \
             got {got:.15}, want {want:.15}"
        );
    }
}

#[test]
fn iso_area_reference_matches_pre_refactor_golden() {
    let area = energy::chip_budget(&AcceleratorConfig::neural_pim()).area();
    assert!(
        (area - GOLDEN_REFERENCE_AREA_MM2).abs()
            <= REL_TOL * GOLDEN_REFERENCE_AREA_MM2,
        "Fig. 12 reference area drifted: {area:.12}"
    );
}

#[test]
fn every_registered_arch_breakdown_sums_to_total() {
    let net = workloads::alexnet();
    for arch in model::archs() {
        let cfg = AcceleratorConfig::for_arch(arch);
        let r = sim::simulate(&net, &cfg);
        let cat_sum: f64 = r.breakdown.categories().iter().map(|(_, v)| v).sum();
        let total = r.breakdown.total();
        assert!(total > 0.0 && total.is_finite(), "{arch:?}: total {total}");
        assert!(
            (cat_sum - total).abs() <= 1e-12 * total.max(1.0),
            "{arch:?}: categories sum {cat_sum} != total {total}"
        );
    }
}

#[test]
fn every_registered_arch_fits_the_iso_area_budget() {
    let reference = energy::chip_budget(&AcceleratorConfig::neural_pim()).area();
    for arch in model::archs() {
        let cfg = sim::iso_area_config(arch, reference);
        cfg.validate().unwrap();
        let area = energy::chip_budget(&cfg).area();
        assert!(
            area <= reference * (1.0 + 1e-9),
            "{arch:?} exceeds the Fig. 12 area budget: {area} > {reference}"
        );
        // and the tile count fills the budget to within one tile
        let tile = energy::tile_budget(&cfg).area();
        assert!(
            area + tile > reference - 1e-9,
            "{arch:?} under-fills the budget: {area} + {tile} < {reference}"
        );
    }
}

#[test]
fn low_resolution_arch_runs_end_to_end_without_call_site_edits() {
    // registered + parseable
    assert!(model::archs().contains(&Architecture::LowResolution));
    assert_eq!(Architecture::parse("raella").unwrap(),
               Architecture::LowResolution);

    // `simulate --all` path: the iso-area comparison includes it
    let nets = vec![workloads::alexnet()];
    let cmp = sim::run_system_comparison(&nets);
    let e = |a: Architecture| {
        cmp.results
            .iter()
            .find(|r| r.arch == a)
            .unwrap()
            .energy_per_inference
    };
    // the RAELLA story: low-resolution conversion beats the ISAAC-style
    // baseline on energy but not the fully-analog Neural-PIM dataflow
    assert!(e(Architecture::LowResolution) < e(Architecture::IsaacLike));
    assert!(e(Architecture::NeuralPim) < e(Architecture::LowResolution));

    // table3 renders a column for it
    let t3 = report::table3().render();
    assert!(t3.contains("RAELLA-like"), "{t3}");

    // event-sim: cross-validation replays it within tolerance
    let rows = event::cross_validate(&nets);
    let row = rows
        .iter()
        .find(|r| r.arch == Architecture::LowResolution)
        .expect("event-sim skipped the registered arch");
    assert!(row.energy_rel_err <= event::ENERGY_TOLERANCE,
            "rel err {}", row.energy_rel_err);

    // and the Fig. 12a/b report tables grew its columns
    let r = report::system_report(&nets);
    assert!(r.table_energy.render().contains("RAELLA-like"));
    assert!(r.table_throughput.render().contains("vs RAELLA-like"));
    assert!(r.table_latency.render().contains("RAELLA-like"));
}
