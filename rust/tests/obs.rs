//! Observability integration tests: trace/registry determinism across
//! thread and shard counts, Chrome-JSON round-tripping, and the golden
//! check that the per-architecture ADC-conversion counters a traced
//! event-driven run reports reproduce the analytical dataflow counts
//! (Eq. 5/6/7 × dot-product groups) exactly.

use neural_pim::config::AcceleratorConfig;
use neural_pim::event::{self, PipelineSim, RequestLoad};
use neural_pim::obs::TraceRecorder;
use neural_pim::serve::loadgen::{self, LoadGenConfig};
use neural_pim::util::json::Json;
use neural_pim::util::pool;
use neural_pim::{mapping, model, workloads};

fn small_load() -> RequestLoad {
    // 8 jobs per (replica, shard): enough engine pops per shard that the
    // strided engine.queue_depth sampling is guaranteed to fire
    RequestLoad { requests: 32, replicas: 2, shards: 2, ..Default::default() }
}

/// Everything the byte-identity tests compare: the exported trace, the
/// merged registry, and the headline result the profile reports.
fn traced_fingerprint(
    profile: &event::LatencyProfile,
    trace: &TraceRecorder,
) -> (String, String, u64, u64) {
    (
        trace.to_chrome_string(),
        profile.registry.snapshot_string(),
        profile.p99_s.to_bits(),
        profile.events,
    )
}

/// The pool size is process-global, so every thread-count variation
/// lives in this one test function (and restores the default before
/// returning) — the other tests run at whatever the ambient pool size
/// is, which the determinism contract makes irrelevant.
#[test]
fn traced_profile_is_byte_identical_across_thread_counts() {
    let net = workloads::alexnet();
    let cfg = AcceleratorConfig::neural_pim();
    let load = small_load();

    let mut outs = Vec::new();
    for threads in [1usize, 2, 8] {
        pool::set_threads(threads);
        let (p, t) = event::request_profile_traced(&net, &cfg, &load, None);
        outs.push(traced_fingerprint(&p, &t));
    }
    pool::set_threads(0);

    assert_eq!(outs[0], outs[1], "threads 1 vs 2");
    assert_eq!(outs[0], outs[2], "threads 1 vs 8");

    // the explicit sequential variant is the same bytes again
    let (p, t) = event::request_profile_traced_sequential(&net, &cfg, &load, None);
    assert_eq!(traced_fingerprint(&p, &t), outs[0], "pooled vs sequential");

    assert!(!t.is_empty(), "trace captured nothing");
    assert!(!p.registry.is_empty(), "registry captured nothing");
}

/// `shards = 1` and `shards = 8` are different experiments (per-shard
/// arrival streams), so the contract is reproducibility *within* a shard
/// count: repeated runs at the same count are byte-identical, and both
/// counts serve the full request total.
#[test]
fn sharded_traces_are_reproducible_at_shards_1_and_8() {
    let net = workloads::alexnet();
    let cfg = AcceleratorConfig::neural_pim();
    for shards in [1usize, 8] {
        let load = RequestLoad { shards, ..small_load() };
        let (pa, ta) = event::request_profile_traced(&net, &cfg, &load, None);
        let (pb, tb) = event::request_profile_traced(&net, &cfg, &load, None);
        assert_eq!(
            traced_fingerprint(&pa, &ta),
            traced_fingerprint(&pb, &tb),
            "shards = {shards}"
        );
        assert_eq!(
            pa.registry.counter("pipeline.completed"),
            load.requests,
            "shards = {shards}"
        );
    }
}

#[test]
fn serve_sweep_trace_is_reproducible() {
    let cfg = LoadGenConfig { requests: 256, shards: 2, ..Default::default() };
    let loads = [0.7, 1.3];
    let (pa, ta) = loadgen::sweep_traced(&cfg, &loads, None).unwrap();
    let (pb, tb) = loadgen::sweep_traced(&cfg, &loads, None).unwrap();
    assert_eq!(pa, pb); // LoadPoint includes its registry
    assert_eq!(ta.to_chrome_string(), tb.to_chrome_string());
    // every arrival leaves exactly one admission-decision instant
    let decisions = ta
        .events()
        .iter()
        .filter(|e| e.name.ends_with("serve.admit") || e.name.ends_with("serve.shed"))
        .count() as u64;
    assert_eq!(decisions, cfg.requests * loads.len() as u64);
}

/// The exported trace is real JSON: `util::json::parse` round-trips it
/// byte-for-byte, and the document carries the Chrome trace-event
/// structure Perfetto expects (thread-name metadata, X spans, i
/// instants, C counter samples with µs timestamps).
#[test]
fn trace_round_trips_through_util_json_parse() {
    let net = workloads::alexnet();
    let cfg = AcceleratorConfig::neural_pim();
    let (_, trace) =
        event::request_profile_traced_sequential(&net, &cfg, &small_load(), None);

    let s = trace.to_chrome_string();
    let j = Json::parse(&s).expect("trace is not valid JSON");
    assert_eq!(j.to_string() + "\n", s, "round-trip changed the bytes");

    let evs = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    assert!(!evs.is_empty());
    let phase_count = |ph: &str| {
        evs.iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
            .count()
    };
    assert!(phase_count("M") > 0, "no thread_name metadata");
    assert!(phase_count("X") > 0, "no spans");
    assert!(phase_count("C") > 0, "no counter samples");
    // spans carry µs timestamps and durations (virtual ps / 1e6)
    let span = evs
        .iter()
        .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .unwrap();
    assert!(span.get("ts").and_then(Json::as_f64).is_some());
    assert!(span.get("dur").and_then(Json::as_f64).unwrap() > 0.0);
}

#[test]
fn trace_filter_drops_everything_outside_the_prefix() {
    let net = workloads::alexnet();
    let cfg = AcceleratorConfig::neural_pim();
    let load = small_load();
    let (_, full) =
        event::request_profile_traced_sequential(&net, &cfg, &load, None);
    let (_, stages) = event::request_profile_traced_sequential(
        &net, &cfg, &load, Some("stage."),
    );
    assert!(!stages.is_empty());
    assert!(stages.len() < full.len());
    // absorb() prefixes track names but never event names, so the
    // filtered recorder's own invariant holds after merging too
    assert!(stages.events().iter().all(|e| e.name.starts_with("stage.")));
}

/// Acceptance: the per-arch ADC-conversion counters in a run's registry
/// reproduce the analytical dataflow conversion counts exactly — the
/// count is computed independently here from the mapping (sliding-window
/// positions × output channels × K-chunks per layer) and each cost
/// model's Eq. 5/6/7 conversions-per-group, never read back from the
/// cost table the simulator itself consumed.
#[test]
fn per_arch_adc_counters_match_the_analytical_dataflow_counts() {
    let net = workloads::alexnet();
    const JOBS: u64 = 3;
    for arch in model::archs() {
        let cfg = AcceleratorConfig::for_arch(arch);
        let m = mapping::map_network(&net, &cfg);
        let convs_per_group =
            model::cost_model(arch).conversions_per_group(&cfg.precision);
        let per_inference: u64 = m
            .layers
            .iter()
            .map(|lm| {
                lm.layer.positions() * lm.layer.cout as u64 * lm.k_chunks
                    * convs_per_group
            })
            .sum();
        assert!(per_inference > 0, "{arch:?}");

        let nc = model::network_cost(&net, &cfg);
        let mut ps = PipelineSim::with_costs(&cfg, &nc)
            .with_recorder(TraceRecorder::new());
        let period = ps.bottleneck_period_ps().max(1);
        ps.inject_paced(JOBS, period);
        let (run, trace) = ps.run_traced();

        assert_eq!(run.completed, JOBS, "{arch:?}");
        assert_eq!(run.adc_convs, JOBS * per_inference, "{arch:?}");
        let key = format!("adc.convs.{}", arch.name());
        assert_eq!(
            run.registry.counter(&key),
            JOBS * per_inference,
            "registry {key}"
        );
        // the shift-and-add counter is arch-keyed and populated too
        assert!(run.registry.counter(&format!("sa.ops.{}", arch.name())) > 0);
        assert!(!trace.is_empty(), "{arch:?} traced run recorded nothing");
    }
}
