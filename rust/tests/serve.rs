//! Integration gates for the `serve` layer (the api_redesign contract):
//!
//! 1. **Shutdown drains deterministically**: after `shutdown()` returns,
//!    every submitted request has a `Response` or a disconnect — never a
//!    receiver left hanging (the old `Drop` closed the queue but never
//!    joined workers).
//! 2. **Zero-artifact serving**: the full coordinator pipeline
//!    (admission -> batcher -> backend -> demux) runs end-to-end on the
//!    simulated backend, deterministically.
//! 3. **`serve-sim` determinism**: the offered-load sweep is
//!    bit-identical at `--threads 1/2/8` and replays byte-identically
//!    from the results store.

use neural_pim::config::AcceleratorConfig;
use neural_pim::scenario::{self, ExecOptions, Scenario};
use neural_pim::serve::{BackendWorker, BatchInput, BatchResult, Coordinator,
                        InferenceBackend, ServeOptions, SimBackend};
use neural_pim::util::json::Json;
use neural_pim::util::pool;
use neural_pim::workloads;
use std::sync::mpsc::TryRecvError;
use std::time::Duration;

/// A backend whose execution stalls on the wall clock, so requests are
/// genuinely in flight when shutdown begins.
struct SlowBackend;

impl InferenceBackend for SlowBackend {
    fn name(&self) -> &'static str {
        "slow"
    }
    fn batch(&self) -> usize {
        4
    }
    fn classes(&self) -> usize {
        2
    }
    fn image_len(&self) -> usize {
        2
    }
    fn worker(&self) -> anyhow::Result<Box<dyn BackendWorker>> {
        Ok(Box::new(SlowWorker))
    }
}

struct SlowWorker;

impl BackendWorker for SlowWorker {
    fn execute(&mut self, input: &BatchInput) -> anyhow::Result<BatchResult> {
        std::thread::sleep(Duration::from_millis(5));
        let slots = input.data.len() / input.image_len;
        Ok(BatchResult { logits: vec![0.5; slots * 2], exec_us: 7 })
    }
}

#[test]
fn shutdown_drains_every_in_flight_request() {
    let n = 40usize;
    let coord = Coordinator::start(
        SlowBackend,
        ServeOptions {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let mut pending = Vec::new();
    for _ in 0..n {
        pending.push(coord.submit(vec![0.0; 2]).unwrap().accepted().unwrap());
    }
    // shutdown must close admission, drain the queue, and join workers;
    // once it returns, no receiver may still be waiting on anything
    coord.shutdown();
    let (mut answered, mut disconnected) = (0usize, 0usize);
    for rx in pending {
        match rx.try_recv() {
            Ok(r) => {
                assert!(r.error.is_none(), "drained request errored: {r:?}");
                answered += 1;
            }
            Err(TryRecvError::Disconnected) => disconnected += 1,
            Err(TryRecvError::Empty) => {
                panic!("receiver left hanging after shutdown")
            }
        }
    }
    assert_eq!(answered + disconnected, n);
    // no worker died, so the drain answered everything
    assert_eq!(disconnected, 0, "requests dropped during drain");
}

#[test]
fn simulated_backend_serves_end_to_end_without_artifacts() {
    let backend = SimBackend::new(
        &workloads::synthetic_cnn(),
        &AcceleratorConfig::neural_pim(),
        8,
        12,
        1,
    );
    let exec_us = backend.exec_us();
    let coord = Coordinator::start(
        backend,
        ServeOptions {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let image: Vec<f32> = (0..12).map(|i| i as f32).collect();
    let a = coord.submit(image.clone()).unwrap().accepted().unwrap()
        .recv().unwrap();
    let b = coord.submit(image).unwrap().accepted().unwrap().recv().unwrap();
    assert!(a.error.is_none() && b.error.is_none());
    // logits are a pure function of image content: same image, same
    // answer, whatever batch it rode in
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.logits.len(), 10);
    assert_eq!(a.exec_us, exec_us, "exec time is the priced batch time");
    let c = coord.submit(vec![9.0; 12]).unwrap().accepted().unwrap()
        .recv().unwrap();
    assert_ne!(a.logits, c.logits);
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.requests, 3);
    assert_eq!(snap.failed + snap.shed, 0);
    coord.shutdown();
}

fn serve_sim_outcome(threads: usize) -> String {
    pool::set_threads(threads);
    let sc = scenario::find("serve-sim").unwrap();
    let p = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(
            r#"{"requests": 512, "loads": "0.4,0.9,1.3", "depth": 64}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let o = sc.run(&p).unwrap();
    pool::set_threads(0);
    o.to_json().to_string()
}

#[test]
fn serve_sim_is_thread_count_invariant() {
    // the acceptance bar: the whole rendered outcome — every table cell,
    // every metric bit — identical at any --threads (same contract as
    // sim/dse/noise/event)
    let one = serve_sim_outcome(1);
    assert_eq!(one, serve_sim_outcome(2), "diverged at 2 threads");
    assert_eq!(one, serve_sim_outcome(8), "diverged at 8 threads");
}

#[test]
fn serve_sim_replays_byte_identical_from_the_store() {
    let root = std::env::temp_dir()
        .join(format!("np-serve-sim-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let sc = scenario::find("serve-sim").unwrap();
    let p = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(r#"{"requests": 256, "loads": "0.6,1.1"}"#).unwrap(),
    )
    .unwrap();
    let opts = ExecOptions {
        cache: true,
        results_dir: root.to_string_lossy().into_owned(),
    };
    let first = scenario::execute(sc, &p, &opts).unwrap();
    assert!(!first.cached);
    let second = scenario::execute(sc, &p, &opts).unwrap();
    assert!(second.cached, "second run must replay from the store");
    assert_eq!(second.outcome.to_json().to_string(),
               first.outcome.to_json().to_string());
    assert_eq!(second.outcome.render_text(), first.outcome.render_text());
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn serve_and_infer_scenarios_run_on_the_sim_backend() {
    // the serving scenarios work in a bare checkout when --backend sim
    let sc = scenario::find("serve").unwrap();
    let p = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(
            r#"{"backend": "sim", "requests": 96, "workers": 2,
                "max-wait-ms": 1}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let o = sc.run(&p).unwrap();
    assert!(o.get_metric("req_per_s").unwrap() > 0.0);
    assert_eq!(o.get_metric("shed"), Some(0.0));
    assert!(o.get_metric("latency_p99_ms").unwrap()
            >= o.get_metric("latency_p50_ms").unwrap());

    let sc = scenario::find("infer").unwrap();
    let p = scenario::params_from_json(
        &sc.param_specs(),
        &Json::parse(r#"{"backend": "sim"}"#).unwrap(),
    )
    .unwrap();
    let o = sc.run(&p).unwrap();
    assert!(o.get_metric("sim_exec_ms").unwrap() > 0.0);
    assert!(o.notes.iter().any(|n| n.contains("sim first-batch")), "{o:?}");
}
