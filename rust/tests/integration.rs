//! Integration tests across the full stack: artifacts -> PJRT runtime ->
//! coordinator, PJRT vs native golden reference, and simulator vs
//! analytical-framework cross-checks.
//!
//! Tests that need artifacts skip (with a message) when `make artifacts`
//! has not run, so `cargo test` stays meaningful in a fresh checkout.

use neural_pim::arch::{self, crossbar::Group};
use neural_pim::config::{AcceleratorConfig, Architecture, Precision};
use neural_pim::periph::Periph;
use neural_pim::runtime::{self, Runtime};
use neural_pim::serve::{open_runtime, Coordinator, ExtraInput, PjrtBackend,
                        ServeOptions};
use neural_pim::util::pool;
use neural_pim::util::rng::Pcg;
use neural_pim::util::stats;
use neural_pim::{dataflow, dse, event, mapping, noise, sim, workloads};

fn runtime_or_skip() -> Option<Runtime> {
    match open_runtime(&neural_pim::artifact_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT <-> native golden reference
// ---------------------------------------------------------------------------

#[test]
fn crossbar_artifact_matches_native_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("crossbar").unwrap();
    let (b, k, c) = (64usize, 256usize, 32usize);
    let mut rng = Pcg::new(11);
    let x: Vec<f32> = (0..b * k).map(|_| rng.below(256) as f32).collect();
    let wp: Vec<f32> = (0..k * c).map(|_| rng.below(128) as f32).collect();
    let wn: Vec<f32> = (0..k * c).map(|_| rng.below(128) as f32).collect();
    let out = exe
        .run(&[
            runtime::lit_f32(&x, &[b as i64, k as i64]).unwrap(),
            runtime::lit_f32(&wp, &[k as i64, c as i64]).unwrap(),
            runtime::lit_f32(&wn, &[k as i64, c as i64]).unwrap(),
        ])
        .unwrap();
    let acc = runtime::to_f32_vec(&out[0]).unwrap();
    let kdec = arch::sa_unrolled_scale(2, 4);
    // check a handful of (row, col) pairs against the native integer model
    for (row, col) in [(0usize, 0usize), (3, 7), (63, 31), (17, 13)] {
        let mut d_native = 0f64;
        for chunk in 0..2usize {
            let w: Vec<i32> = (0..128)
                .map(|r| {
                    let idx = (chunk * 128 + r) * c + col;
                    wp[idx] as i32 - wn[idx] as i32
                })
                .collect();
            let xr: Vec<u32> = (0..128)
                .map(|r| x[row * k + chunk * 128 + r] as u32)
                .collect();
            d_native += Group { w }.dot(&xr) as f64;
        }
        let d_kernel = acc[row * c + col] as f64 * kdec;
        assert!(
            (d_kernel - d_native).abs() <= d_native.abs() * 1e-3 + 8.0,
            "({row},{col}): kernel {d_kernel} vs native {d_native}"
        );
    }
}

#[test]
fn nns_a_artifact_matches_native_forward() {
    let Some(rt) = runtime_or_skip() else { return };
    let p = Periph::load(&format!("{}/periph.json", neural_pim::artifact_dir()))
        .unwrap();
    let exe = rt.load("nns_a").unwrap();
    let mut rng = Pcg::new(3);
    let v: Vec<f32> = (0..1024 * 9).map(|_| rng.range(-0.25, 0.25) as f32).collect();
    let out = exe
        .run(&[runtime::lit_f32(&v, &[1024, 9]).unwrap()])
        .unwrap();
    let got = runtime::to_f32_vec(&out[0]).unwrap();
    for i in (0..1024).step_by(97) {
        let mut vin = [0.0f64; 9];
        for k in 0..9 {
            vin[k] = v[i * 9 + k] as f64;
        }
        let want = p.nns_a.forward(&vin, arch::VDD / 2.0);
        assert!(
            (got[i] as f64 - want).abs() < 1e-4,
            "row {i}: {} vs {want}", got[i]
        );
    }
}

#[test]
fn ideal_cnn_artifact_reaches_training_accuracy() {
    let Some(rt) = runtime_or_skip() else { return };
    let ts = runtime::TestSet::load(rt.dir()).unwrap();
    let exe = rt.load("cnn_ideal").unwrap();
    let mut correct = 0usize;
    for b in 0..(ts.n / 128) {
        let out = exe.run(&[ts.batch_literal(b * 128, 128).unwrap()]).unwrap();
        let logits = runtime::to_f32_vec(&out[0]).unwrap();
        correct += (runtime::accuracy(&logits, &ts.batch_labels(b * 128, 128),
                                      10) * 128.0)
            .round() as usize;
    }
    let acc = correct as f64 / ts.n as f64;
    assert!(acc > 0.95, "ideal int8 accuracy {acc}");
}

#[test]
fn strategy_c_at_8_bits_matches_ideal_accuracy() {
    let Some(rt) = runtime_or_skip() else { return };
    let ts = runtime::TestSet::load(rt.dir()).unwrap();
    let ideal = rt.load("cnn_ideal").unwrap();
    let strat = rt.load("cnn_stratC").unwrap();
    let images = ts.batch_literal(0, 128).unwrap();
    let out_i = ideal.run_refs(&[&images]).unwrap();
    let acc_i = runtime::accuracy(&runtime::to_f32_vec(&out_i[0]).unwrap(),
                                  &ts.batch_labels(0, 128), 10);
    let out_c = strat
        .run(&[
            ts.batch_literal(0, 128).unwrap(),
            runtime::lit_scalar_f32(255.0),
            runtime::lit_key(42).unwrap(),
        ])
        .unwrap();
    let acc_c = runtime::accuracy(&runtime::to_f32_vec(&out_c[0]).unwrap(),
                                  &ts.batch_labels(0, 128), 10);
    // Eq. 4: P_O-bit conversion suffices — no accuracy loss
    assert!(acc_c >= acc_i - 0.02, "C {acc_c} vs ideal {acc_i}");
}

#[test]
fn mc_optimized_beats_naive_sinad() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut sinads = Vec::new();
    for artifact in ["mc_opt", "mc_naive"] {
        let exe = rt.load(artifact).unwrap();
        let out = exe.run(&[runtime::lit_key(42).unwrap()]).unwrap();
        let hw: Vec<f64> = runtime::to_f32_vec(&out[0]).unwrap()
            .iter().map(|&v| v as f64).collect();
        let sw: Vec<f64> = runtime::to_f32_vec(&out[1]).unwrap()
            .iter().map(|&v| v as f64).collect();
        sinads.push(stats::sinad_db(&hw, &sw));
    }
    // Fig. 9: the optimization bundle buys >= 8 dB
    assert!(sinads[0] > sinads[1] + 8.0, "opt {} vs naive {}", sinads[0],
            sinads[1]);
}

// ---------------------------------------------------------------------------
// coordinator end-to-end
// ---------------------------------------------------------------------------

#[test]
fn coordinator_serves_correct_results() {
    if open_runtime(&neural_pim::artifact_dir()).is_err() {
        eprintln!("SKIP (no artifacts)");
        return;
    }
    let dir = neural_pim::artifact_dir();
    let ts = runtime::TestSet::load(std::path::Path::new(&dir)).unwrap();
    let (h, w, c) = ts.dims;
    let coord = Coordinator::start(
        PjrtBackend::new(dir, "cnn_ideal", h * w * c),
        ServeOptions {
            max_wait: std::time::Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let stride = h * w * c;
    let n = 200usize; // not a multiple of the batch -> exercises padding
    let mut pending = Vec::new();
    for i in 0..n {
        let idx = i % ts.n;
        pending.push((
            coord
                .submit(ts.images[idx * stride..(idx + 1) * stride].to_vec())
                .unwrap()
                .accepted()
                .unwrap(),
            ts.labels[idx],
        ));
    }
    let mut correct = 0usize;
    for (rx, label) in pending {
        let r = rx.recv().unwrap();
        assert_eq!(r.logits.len(), 10);
        let pred = r.logits.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32;
        correct += (pred == label) as usize;
    }
    assert!(correct as f64 / n as f64 > 0.95);
    coord.shutdown();
}

#[test]
fn coordinator_with_extra_inputs_noisy_model() {
    if open_runtime(&neural_pim::artifact_dir()).is_err() {
        eprintln!("SKIP (no artifacts)");
        return;
    }
    let dir = neural_pim::artifact_dir();
    let ts = runtime::TestSet::load(std::path::Path::new(&dir)).unwrap();
    let (h, w, c) = ts.dims;
    let backend = PjrtBackend {
        artifact: "cnn_noisy".into(),
        extra_inputs: vec![ExtraInput::KeyU32(1), ExtraInput::ScalarF32(60.0)],
        ..PjrtBackend::new(dir, "", h * w * c)
    };
    let coord = Coordinator::start(
        backend,
        ServeOptions {
            max_wait: std::time::Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let rx = coord
        .submit(ts.images[..h * w * c].to_vec())
        .unwrap()
        .accepted()
        .unwrap();
    let r = rx.recv().unwrap();
    assert_eq!(r.logits.len(), 10);
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// simulator vs analytical cross-checks (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn simulator_conversion_counts_match_framework() {
    // single-layer network: the simulator's ADC energy must equal
    // (groups x Eq.-5 conversions x 2) x per-conversion energy
    let net = workloads::Network {
        name: "single".into(),
        layers: vec![workloads::Layer::fc("fc", 128, 8)],
    };
    let cfg = AcceleratorConfig::isaac_like();
    let m = mapping::map_network(&net, &cfg);
    let e = sim::energy_per_inference(&net, &cfg, &m);
    let p = Precision::default();
    let convs = 2 * 8 * dataflow::conversions_a(&p); // 8 output channels
    let expected = convs as f64
        * neural_pim::energy::constants::adc_e_conv(
            dataflow::adc_resolution_a(&p, 7));
    assert!(
        (e.adc - expected).abs() < 1e-18 + expected * 1e-9,
        "sim {} vs analytical {}", e.adc, expected
    );
}

#[test]
fn neural_pim_wins_headline_metrics_full_suite() {
    let nets = workloads::all_benchmarks();
    let cmp = sim::run_system_comparison(&nets);
    let e_i = cmp.energy_ratio(Architecture::IsaacLike);
    let e_c = cmp.energy_ratio(Architecture::CascadeLike);
    let t_i = cmp.throughput_ratio(Architecture::IsaacLike);
    let t_c = cmp.throughput_ratio(Architecture::CascadeLike);
    // the paper's ordering and rough magnitudes (see EXPERIMENTS.md for
    // exact measured values): 5.36x / 1.73x / 3.43x / 1.59x
    assert!(e_i > 2.0, "energy vs ISAAC {e_i}");
    assert!(e_c > 1.0, "energy vs CASCADE {e_c}");
    assert!(t_i > 1.5, "throughput vs ISAAC {t_i}");
    assert!(t_c > 1.0, "throughput vs CASCADE {t_c}");
    assert!(e_i > e_c && t_i > t_c, "ISAAC must be the weaker baseline");
}

// ---------------------------------------------------------------------------
// event-driven microsimulator vs analytical model
// ---------------------------------------------------------------------------

#[test]
fn event_energy_cross_validates_analytical_on_two_networks() {
    // the event model replays the iso-area Fig. 12 scenarios with
    // per-event energy charging; totals must agree with the analytical
    // simulator within the documented tolerance (the only modelling
    // difference is exact NoC hop counts vs the 1-hop average)
    let nets = vec![workloads::alexnet(), workloads::vgg16()];
    let rows = event::cross_validate(&nets);
    // 2 networks x every registered architecture
    assert_eq!(rows.len(), 2 * neural_pim::model::archs().len());
    for r in &rows {
        assert!(
            r.energy_rel_err <= event::ENERGY_TOLERANCE,
            "{}/{:?}: energy rel err {:.4} exceeds tolerance {} \
             (event {:.3e} vs analytical {:.3e})",
            r.network, r.arch, r.energy_rel_err, event::ENERGY_TOLERANCE,
            r.event_energy_j, r.analytical_energy_j
        );
        // hop-count refinement only adds energy, never removes it
        assert!(
            r.event_energy_j >= r.analytical_energy_j * (1.0 - 1e-9),
            "{}/{:?}: event energy below analytical", r.network, r.arch
        );
        // interconnect + queueing only add latency
        assert!(
            r.contention_delta_s >= -1e-15,
            "{}/{:?}: contention delta {}", r.network, r.arch,
            r.contention_delta_s
        );
    }
}

#[test]
fn event_percentiles_are_thread_count_invariant() {
    // request-level mode: per-replica Pcg::fork streams are derived
    // sequentially before the pool fans out, so p50/p95/p99 are
    // bit-identical at any --threads (the acceptance bar for the event
    // subsystem, same contract as sim/dse/noise)
    let net = workloads::alexnet();
    let cfg = AcceleratorConfig::neural_pim();
    let load = event::RequestLoad {
        requests: 64,
        replicas: 8,
        utilization: 0.9,
        seed: 7,
        shards: 1,
    };
    // and the same bar with per-replica engine sharding engaged: shard
    // fork streams are also derived sequentially up front
    let sharded = event::RequestLoad { shards: 3, ..load.clone() };
    let mut base: Option<[(u64, u64, u64, u64, u64); 2]> = None;
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let p = event::request_profile(&net, &cfg, &load);
        let s = event::request_profile(&net, &cfg, &sharded);
        pool::set_threads(0);
        let fp_of = |p: &event::LatencyProfile| {
            (
                p.p50_s.to_bits(),
                p.p95_s.to_bits(),
                p.p99_s.to_bits(),
                p.mean_s.to_bits(),
                p.energy_j_per_inference.to_bits(),
            )
        };
        let fp = [fp_of(&p), fp_of(&s)];
        match &base {
            None => base = Some(fp),
            Some(b) => assert_eq!(&fp, b, "diverged at {t} threads"),
        }
    }
}

#[test]
fn event_cross_validation_is_thread_count_invariant() {
    let nets = vec![workloads::alexnet()];
    let mut base: Option<Vec<(String, u64, u64)>> = None;
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let fp: Vec<(String, u64, u64)> = event::cross_validate(&nets)
            .iter()
            .map(|r| {
                (
                    format!("{}/{:?}", r.network, r.arch),
                    r.event_energy_j.to_bits(),
                    r.event_latency_s.to_bits(),
                )
            })
            .collect();
        pool::set_threads(0);
        match &base {
            None => base = Some(fp),
            Some(b) => assert_eq!(&fp, b, "diverged at {t} threads"),
        }
    }
}

// ---------------------------------------------------------------------------
// parallel evaluation engine: thread-count invariance
//
// These three tests mutate the process-global pool size and run
// concurrently in this binary; that is safe *because* the property they
// assert is exactly that outputs are identical at any thread count — an
// interleaved set_threads can change where work runs, never its result.
// ---------------------------------------------------------------------------

/// Fingerprint of a full system comparison, bit-exact.
fn sim_fingerprint(cmp: &sim::SystemComparison) -> Vec<(String, u64, u64, u64)> {
    cmp.results
        .iter()
        .map(|r| {
            (
                format!("{}/{:?}", r.network, r.arch),
                r.energy_per_inference.to_bits(),
                r.throughput_gops.to_bits(),
                r.latency_s.to_bits(),
            )
        })
        .collect()
}

#[test]
fn system_comparison_is_thread_count_invariant() {
    let nets = workloads::all_benchmarks();
    let mut base = None;
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let fp = sim_fingerprint(&sim::run_system_comparison(&nets));
        pool::set_threads(0);
        match &base {
            None => base = Some(fp),
            Some(b) => assert_eq!(&fp, b, "diverged at {t} threads"),
        }
    }
}

#[test]
fn dse_sweep_is_thread_count_invariant() {
    let mut base: Option<Vec<(String, u64, u64)>> = None;
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let fp: Vec<(String, u64, u64)> = dse::sweep()
            .iter()
            .map(|p| {
                (
                    p.label.clone(),
                    p.compute_efficiency.to_bits(),
                    p.energy_efficiency.to_bits(),
                )
            })
            .collect();
        pool::set_threads(0);
        match &base {
            None => {
                assert!(fp.len() > 50, "sweep too small: {}", fp.len());
                base = Some(fp);
            }
            Some(b) => assert_eq!(&fp, b, "diverged at {t} threads"),
        }
    }
}

#[test]
fn dse_fine_sweep_is_thread_count_invariant() {
    // the streamed fine grid's acceptance anchor: the feasible-point
    // fingerprint (FNV-1a over the (index, eff-bits) list in index
    // order) is byte-identical at any thread count; a stride subsamples
    // the ~1M grid so the test stays fast while batching still spans
    // many pool submissions
    let spec = dse::FineSpec { stride: 487, batch: 128, top: 6 };
    let mut base: Option<(u64, u64, Vec<String>)> = None;
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let s = dse::fine_sweep(&spec);
        pool::set_threads(0);
        let labels = s.top.iter().map(|p| p.label.clone()).collect();
        let fp = (s.feasible_fp, s.feasible, labels);
        match &base {
            None => {
                assert!(s.feasible > 0, "no feasible point in the sample");
                base = Some(fp);
            }
            Some(b) => assert_eq!(&fp, b, "diverged at {t} threads"),
        }
    }
}

#[test]
fn noise_mc_is_thread_count_invariant() {
    let mut base = None;
    for t in [1usize, 2, 8] {
        pool::set_threads(t);
        let fp: Vec<u64> = ['A', 'B', 'C']
            .iter()
            .map(|&s| noise::strategy_sinad(s, 128, 5).to_bits())
            .collect();
        pool::set_threads(0);
        match &base {
            None => base = Some(fp),
            Some(b) => assert_eq!(&fp, b, "diverged at {t} threads"),
        }
    }
}

#[test]
fn native_mc_strategy_ordering() {
    // CASCADE's buffered dataflow (6-bit cells + write noise) must sit
    // below ISAAC's quantization-only dataflow (Fig. 10's marker order)
    let a = noise::strategy_sinad('A', 512, 9);
    let b = noise::strategy_sinad('B', 512, 9);
    assert!(a > b + 3.0, "A {a} vs B {b}");
}
