//! Acceptance gates for the scenario layer (the `multi_layer_refactor`
//! contract):
//!
//! 1. **Golden text**: `simulate --all`, `table2`, `table3`, `dse`, and
//!    `characterize` render byte-identical to the pre-scenario CLI
//!    print sequence (the hand-rolled `report::*().print()` arms the
//!    old `main.rs` carried, reproduced literally here).
//! 2. **Generic JSON path**: every registered scenario runs through
//!    `params_from_json` + `run` and emits schema-validated JSON that
//!    round-trips exactly (artifact-backed scenarios skip cleanly in a
//!    bare checkout).
//! 3. **Results store**: a second `--cache` execution replays the
//!    stored outcome without recompute, identically; suites report
//!    all-cached on their second invocation.

use neural_pim::scenario::{self, store, suite, ExecOptions, Outcome, Params,
                           Scenario};
use neural_pim::util::cli::Args;
use neural_pim::util::json::Json;
use neural_pim::{dse, report, workloads};

fn params(sc: &dyn Scenario, json: &str) -> Params {
    scenario::params_from_json(&sc.param_specs(), &Json::parse(json).unwrap())
        .unwrap_or_else(|e| panic!("params {json} for {}: {e:#}", sc.name()))
}

fn run(name: &str, json_params: &str) -> Outcome {
    let sc = scenario::find(name).unwrap_or_else(|| panic!("no {name}"));
    sc.run(&params(sc, json_params))
        .unwrap_or_else(|e| panic!("{name} failed: {e:#}"))
}

fn tmp_dir(tag: &str) -> String {
    let d = std::env::temp_dir()
        .join(format!("np-scenario-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------------------
// golden text: byte-identical to the pre-scenario print sequence
// ---------------------------------------------------------------------------

#[test]
fn golden_simulate_all_text_byte_identical() {
    // the old `simulate` arm: four `Table::print`s then the headline
    let nets = workloads::all_benchmarks();
    let r = report::system_report(&nets);
    let mut expected = String::new();
    for t in [&r.table_energy, &r.table_throughput, &r.table_breakdown,
              &r.table_latency] {
        expected.push_str(&t.render());
        expected.push('\n');
    }
    expected.push_str(&r.headline);
    expected.push('\n');
    let got = run("simulate", r#"{"all": true}"#).render_text();
    assert_eq!(got, expected, "simulate --all text drifted");
}

#[test]
fn golden_table2_table3_characterize_text_byte_identical() {
    assert_eq!(run("table2", "{}").render_text(),
               report::table2().render() + "\n");
    assert_eq!(run("table3", "{}").render_text(),
               report::table3().render() + "\n");
    let expected = report::characterization_table().render() + "\n"
        + &report::fig4b_table().render() + "\n"
        + &report::fig4c_table().render() + "\n";
    assert_eq!(run("characterize", "{}").render_text(), expected);
}

#[test]
fn golden_dse_text_byte_identical() {
    // the old `dse` arm: fig11 table then the "best: ..." line
    let best = dse::best();
    let expected = report::fig11_table(12).render() + "\n"
        + &format!(
            "best: {} at {:.1} GOPS/s/mm² (paper: N128-D4-A4-S64 M64 at \
             1904.0)\n",
            best.label, best.compute_efficiency
        );
    assert_eq!(run("dse", "{}").render_text(), expected);
}

// ---------------------------------------------------------------------------
// generic JSON path over the whole registry
// ---------------------------------------------------------------------------

/// Cheap parameter overrides so the registry-wide sweep stays fast.
fn cheap_params(name: &str) -> &'static str {
    match name {
        "simulate" => r#"{"network": "AlexNet"}"#,
        "event-sim" => r#"{"network": "AlexNet", "requests": 16,
                           "replicas": 2}"#,
        "dse" => r#"{"top": 5}"#,
        "noise" => r#"{"samples": 64}"#,
        "serve-sim" => r#"{"requests": 128, "loads": "0.6,1.1"}"#,
        "fleet-sim" => r#"{"arrivals": 8192, "sweep-arrivals": 2048,
                           "fleet": "neural-pim:2,isaac:1"}"#,
        "offload" => r#"{"network": "AlexNet"}"#,
        _ => "{}",
    }
}

fn validate_outcome_json(name: &str, j: &Json) {
    assert_eq!(j.get("kind").and_then(Json::as_str),
               Some(scenario::OUTCOME_KIND), "{name}: kind");
    assert_eq!(j.get("schema").and_then(Json::as_f64),
               Some(scenario::OUTCOME_SCHEMA as f64), "{name}: schema");
    assert_eq!(j.get("scenario").and_then(Json::as_str), Some(name));
    assert!(j.get("params").and_then(Json::as_obj).is_some(),
            "{name}: params must be an object");
    for m in j.get("metrics").unwrap().as_arr().unwrap() {
        let v = m.get("value").and_then(Json::as_f64).unwrap();
        assert!(v.is_finite(), "{name}: non-finite metric {m}");
        assert!(m.get("name").and_then(Json::as_str).is_some());
    }
    for t in j.get("tables").unwrap().as_arr().unwrap() {
        let headers = t.get("headers").unwrap().as_arr().unwrap();
        for row in t.get("rows").unwrap().as_arr().unwrap() {
            assert_eq!(row.as_arr().unwrap().len(), headers.len(),
                       "{name}: ragged table row");
        }
    }
    // exact round-trip: the stored form decodes and re-encodes to itself
    let back = Outcome::from_json(j)
        .unwrap_or_else(|e| panic!("{name}: from_json: {e:#}"));
    assert_eq!(&back.to_json(), j, "{name}: JSON round-trip drifted");
}

#[test]
fn every_scenario_runs_via_generic_json_path() {
    let mut ran = 0;
    for sc in scenario::scenarios() {
        let p = params(*sc, cheap_params(sc.name()));
        match sc.run(&p) {
            // artifact-backed scenarios skip cleanly in a bare checkout
            Err(e) => eprintln!("SKIP {} (no artifacts?): {e:#}", sc.name()),
            Ok(o) => {
                assert_eq!(o.scenario, sc.name());
                validate_outcome_json(sc.name(), &o.to_json());
                assert!(!o.render_text().is_empty());
                ran += 1;
            }
        }
    }
    // the analytical half of the registry plus serve-sim must always run
    assert!(ran >= 9, "only {ran} scenarios ran");
}

#[test]
fn event_sim_outcome_exports_latency_metrics() {
    let o = run("event-sim",
                r#"{"network": "AlexNet", "requests": 16, "replicas": 2}"#);
    assert_eq!(o.tables.len(), 2);
    let rel = o.get_metric("max_energy_rel_err").unwrap();
    assert!((0.0..=neural_pim::event::ENERGY_TOLERANCE).contains(&rel));
    assert!(o
        .metrics
        .iter()
        .any(|m| m.name.starts_with("p99_s/AlexNet/")));
}

// ---------------------------------------------------------------------------
// results store: cached replay
// ---------------------------------------------------------------------------

#[test]
fn cached_execution_skips_recompute_and_replays_identically() {
    let root = tmp_dir("cache");
    let sc = scenario::find("budget").unwrap();
    let p = params(sc, r#"{"arch": "isaac"}"#);
    let opts = ExecOptions { cache: true, results_dir: root.clone() };

    let first = scenario::execute(sc, &p, &opts).unwrap();
    assert!(!first.cached, "cold store must compute");
    let stored = first.stored.clone().expect("cache run persists");
    assert!(stored.exists());

    let second = scenario::execute(sc, &p, &opts).unwrap();
    assert!(second.cached, "second run must hit the store");
    assert_eq!(second.fingerprint, first.fingerprint);
    assert_eq!(second.outcome.to_json(), first.outcome.to_json());
    assert_eq!(second.outcome.render_text(), first.outcome.render_text());

    // different params → different address → miss
    let p2 = params(sc, r#"{"arch": "neural-pim"}"#);
    let other = scenario::execute(sc, &p2, &opts).unwrap();
    assert!(!other.cached);
    assert_ne!(other.fingerprint, first.fingerprint);

    // without --cache the store is bypassed entirely
    let opts_off = ExecOptions { cache: false, results_dir: root.clone() };
    let third = scenario::execute(sc, &p, &opts_off).unwrap();
    assert!(!third.cached && third.stored.is_none());

    // a kind-valid but undecodable entry is a miss (recompute +
    // overwrite), not a hard failure — the documented corrupt policy
    std::fs::write(
        &stored,
        r#"{"kind": "neural-pim.outcome", "schema": 999}"#,
    )
    .unwrap();
    let healed = scenario::execute(sc, &p, &opts).unwrap();
    assert!(!healed.cached, "undecodable entry must not serve");
    let after = scenario::execute(sc, &p, &opts).unwrap();
    assert!(after.cached, "recompute must overwrite the bad entry");

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn network_file_content_is_part_of_the_fingerprint() {
    let root = tmp_dir("netfile");
    let path = format!("{root}/net.json");
    let spec = |cout: u32| {
        format!(
            r#"{{"name": "tiny", "layers": [{{"kind": "fc", "cin": 64,
                 "cout": {cout}}}]}}"#
        )
    };
    std::fs::write(&path, spec(10)).unwrap();
    let sc = scenario::find("simulate").unwrap();
    let p = params(sc, &format!(r#"{{"network-file": "{path}"}}"#));
    let fp1 = store::fingerprint(sc.name(), &p,
                                 &sc.fingerprint_extra(&p).unwrap());
    // same content → same address; changed content → new address
    let fp1b = store::fingerprint(sc.name(), &p,
                                  &sc.fingerprint_extra(&p).unwrap());
    assert_eq!(fp1, fp1b);
    std::fs::write(&path, spec(20)).unwrap();
    let fp2 = store::fingerprint(sc.name(), &p,
                                 &sc.fingerprint_extra(&p).unwrap());
    assert_ne!(fp1, fp2, "stale cache would survive a spec edit");
    std::fs::remove_dir_all(&root).unwrap();
}

// ---------------------------------------------------------------------------
// suite runner
// ---------------------------------------------------------------------------

const SUITE_SPEC: &str = r#"{
    "name": "test",
    "scenarios": [
        {"scenario": "table2"},
        {"scenario": "budget", "params": {"arch": "isaac"}},
        {"scenario": "budget", "params": {"arch": "neural-pim"}},
        {"scenario": "characterize"}
    ]
}"#;

#[test]
fn suite_second_invocation_is_fully_cached() {
    let root = tmp_dir("suite");
    let spec = suite::SuiteSpec::from_json(&Json::parse(SUITE_SPEC).unwrap())
        .unwrap();
    let opts = ExecOptions { cache: true, results_dir: root.clone() };

    let r1 = suite::run_spec(&spec, &opts);
    assert_eq!(r1.failures(), 0);
    assert!(!r1.all_cached(), "cold suite must compute");

    let j = r1.to_json();
    assert_eq!(j.get("kind").and_then(Json::as_str),
               Some(suite::SUITE_KIND));
    let bench = j.get("bench").unwrap().as_obj().unwrap();
    assert!(bench.contains_key("suite.wall_ms_total"));
    assert!(bench.contains_key("table2.chip_power_w"), "{j}");
    assert!(bench.len() > spec.entries.len(), "bench too thin");
    // repeated scenarios are keyed by param fingerprint, never by
    // order-dependent bare names (reordering must not remap a series)
    assert!(!bench.contains_key("budget.chip_power_w"), "{j}");
    let fp_keyed = bench
        .keys()
        .filter(|k| k.starts_with("budget[") && k.ends_with(".chip_power_w"))
        .count();
    assert_eq!(fp_keyed, 2, "{j}");

    let r2 = suite::run_spec(&spec, &opts);
    assert_eq!(r2.failures(), 0);
    assert!(r2.all_cached(), "second suite run must skip recompute");
    for (a, b) in r1.entries.iter().zip(&r2.entries) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.result.as_ref().unwrap().to_json(),
                   b.result.as_ref().unwrap().to_json(),
                   "{}: cached replay differs", a.scenario);
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn suite_spec_rejects_unknown_scenarios_and_params() {
    let bad_scenario =
        Json::parse(r#"{"scenarios": [{"scenario": "nope"}]}"#).unwrap();
    assert!(suite::SuiteSpec::from_json(&bad_scenario).is_err());
    let bad_param = Json::parse(
        r#"{"scenarios": [{"scenario": "dse", "params": {"tops": 5}}]}"#,
    )
    .unwrap();
    let err = suite::SuiteSpec::from_json(&bad_param).unwrap_err();
    assert!(format!("{err:#}").contains("did you mean 'top'"), "{err:#}");
}

// ---------------------------------------------------------------------------
// dispatch-level flag hygiene
// ---------------------------------------------------------------------------

fn argv(s: &[&str]) -> Args {
    Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
}

#[test]
fn dispatch_suggests_on_command_and_flag_typos() {
    let err = scenario::dispatch(&argv(&["simulte"])).unwrap_err();
    assert!(format!("{err:#}").contains("did you mean 'simulate'"),
            "{err:#}");
    // an unknown flag fails fast (before any compute) with a suggestion
    let err = scenario::dispatch(&argv(&["dse", "--tops", "5"])).unwrap_err();
    assert!(format!("{err:#}").contains("did you mean --top"), "{err:#}");
    let err =
        scenario::dispatch(&argv(&["simulate", "--thread", "8"])).unwrap_err();
    assert!(format!("{err:#}").contains("did you mean --threads"), "{err:#}");
    // a stray positional would otherwise be ignored and the run would
    // fall back to all nine benchmarks
    let err = scenario::dispatch(&argv(&["simulate", "AlexNet"])).unwrap_err();
    assert!(format!("{err:#}").contains("unexpected argument 'AlexNet'"),
            "{err:#}");
    // a global value option given as a bare flag fails fast too
    let err = scenario::dispatch(&argv(&["dse", "--out", "--cache"]))
        .unwrap_err();
    assert!(format!("{err:#}").contains("--out needs a value"), "{err:#}");
}
