//! Layer → crossbar mapping and the §5.2.4 weight-replication allocator.
//!
//! Mapping rules (§5.2.1): an 8-bit signed weight occupies 16 adjacent
//! 1-bit columns (8 W+ + 8 W-) of the same array; kernels taller than the
//! array split across K-chunks of `xbar_size` rows; a 128x128 array holds
//! 8 output channels (groups) per K-chunk.
//!
//! Replication (§5.2.4): early layers with many sliding-window positions
//! are replicated so every pipeline stage produces at the rate its
//! consumer needs; the allocator spends the remaining on-chip arrays
//! greedily on the current bottleneck, exactly the "weights replication
//! strategy proposed in [1]" the paper adopts.

use crate::config::AcceleratorConfig;
use crate::workloads::{Layer, Network};

#[derive(Debug, Clone)]
pub struct LayerMapping {
    pub layer: Layer,
    /// K-dimension chunks (rows)
    pub k_chunks: u64,
    /// output-channel chunks (groups of `groups_per_array` columns)
    pub c_chunks: u64,
    /// crossbar arrays for ONE copy of the weights
    pub arrays_per_copy: u64,
    /// replication factor r_i
    pub replication: u64,
}

impl LayerMapping {
    pub fn total_arrays(&self) -> u64 {
        self.arrays_per_copy * self.replication
    }

    /// Input cycles this layer needs per inference (its pipeline-stage
    /// occupancy): positions / replication, each costing `input_cycles`.
    pub fn stage_cycles(&self, input_cycles: u64) -> u64 {
        self.layer.positions().div_ceil(self.replication) * input_cycles
    }

    /// Output activation bytes this stage produces per inference (8-bit
    /// activations) — what crosses the NoC to the next stage.
    pub fn out_bytes(&self) -> u64 {
        self.layer.positions() * self.layer.cout as u64
    }
}

/// Which side of a PIM + NPU hybrid executes one layer. Pure mappings
/// (everything [`map_network`] builds) are all-PIM; only the `offload`
/// subsystem's hybrid assembly writes `Npu` entries.
///
/// Code outside `offload/` and `model/archs.rs` must not dispatch on
/// the variants (grep-enforced) — use [`Placement::is_npu`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Placement {
    Pim,
    Npu,
}

impl Placement {
    pub fn is_npu(self) -> bool {
        !matches!(self, Placement::Pim)
    }
}

#[derive(Debug, Clone)]
pub struct NetworkMapping {
    pub layers: Vec<LayerMapping>,
    /// chips needed to hold one copy of all weights
    pub chips: u64,
    /// per-layer execution side, parallel to `layers`; all-PIM for pure
    /// mappings
    pub placement: Vec<Placement>,
}

impl NetworkMapping {
    pub fn total_arrays(&self) -> u64 {
        self.layers.iter().map(LayerMapping::total_arrays).sum()
    }

    /// The pipeline bottleneck stage's cycle count.
    pub fn bottleneck_cycles(&self, input_cycles: u64) -> u64 {
        self.layers
            .iter()
            .map(|l| l.stage_cycles(input_cycles))
            .max()
            .unwrap_or(0)
    }

    /// Home tile of every layer stage. Layers occupy consecutive arrays
    /// in mapping order (copies included), so a layer's home tile is
    /// where its first array lands, wrapped modulo the chip for
    /// multi-chip mappings. The event simulator routes inter-stage NoC
    /// traffic between these tiles.
    pub fn layer_tiles(&self, cfg: &AcceleratorConfig) -> Vec<u32> {
        let per_tile =
            (cfg.pes_per_tile as u64 * cfg.arrays_per_pe as u64).max(1);
        let tiles = cfg.tiles.max(1) as u64;
        let mut cum = 0u64;
        self.layers
            .iter()
            .map(|lm| {
                let t = ((cum / per_tile) % tiles) as u32;
                cum += lm.total_arrays();
                t
            })
            .collect()
    }

    /// Inter-stage buffer capacity, in whole inferences, of the buffer
    /// feeding `layers[stage]` (stage ≥ 1): the consumer tile's eDRAM
    /// budget divided by the producer's per-inference output, clamped to
    /// `[1, max_infs]`. This finite capacity is what gives the event
    /// simulator back-pressure — the analytical model implicitly assumes
    /// it is infinite.
    pub fn buffer_capacity_infs(&self, stage: usize, edram_bytes: u64,
                                max_infs: u64) -> u64 {
        assert!(stage >= 1 && stage < self.layers.len());
        let out = self.layers[stage - 1].out_bytes().max(1);
        (edram_bytes / out).clamp(1, max_infs.max(1))
    }
}

/// Map one layer (single copy).
pub fn map_layer(layer: &Layer, cfg: &AcceleratorConfig) -> LayerMapping {
    let rows = cfg.xbar_size as u64;
    let groups = cfg.groups_per_array(); // output channels per array chunk
    let k_chunks = layer.k_dim().div_ceil(rows);
    let c_chunks = (layer.cout as u64).div_ceil(groups);
    LayerMapping {
        layer: layer.clone(),
        k_chunks,
        c_chunks,
        arrays_per_copy: k_chunks * c_chunks,
        replication: 1,
    }
}

/// Map a network with replication under the chip's array budget.
pub fn map_network(net: &Network, cfg: &AcceleratorConfig) -> NetworkMapping {
    let mut layers: Vec<LayerMapping> =
        net.layers.iter().map(|l| map_layer(l, cfg)).collect();
    let per_chip = cfg.total_arrays();
    let base: u64 = layers.iter().map(|l| l.arrays_per_copy).sum();
    let chips = base.div_ceil(per_chip).max(1);
    let budget = chips * per_chip;
    let mut used = base;

    // greedy: always replicate the current bottleneck stage (most cycles)
    let input_cycles = cfg.precision.input_cycles() as u64;
    loop {
        let (idx, _) = match layers
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| l.stage_cycles(input_cycles))
        {
            Some(x) => x,
            None => break,
        };
        let cost = layers[idx].arrays_per_copy;
        if layers[idx].stage_cycles(input_cycles) <= input_cycles {
            break; // bottleneck already at one position per stage slot
        }
        if used + cost > budget {
            break;
        }
        layers[idx].replication += 1;
        used += cost;
    }
    let placement = vec![Placement::Pim; layers.len()];
    NetworkMapping { layers, chips, placement }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::util::prop;
    use crate::workloads::{alexnet, Layer};

    #[test]
    fn single_layer_shapes() {
        let cfg = AcceleratorConfig::neural_pim();
        // 3x3x128 kernel, 64 outputs: K = 1152 -> 9 chunks; 64/8 = 8
        let l = Layer::conv("t", 3, 128, 64, 14, 1);
        let m = map_layer(&l, &cfg);
        assert_eq!(m.k_chunks, 9);
        assert_eq!(m.c_chunks, 8);
        assert_eq!(m.arrays_per_copy, 72);
    }

    #[test]
    fn small_kernel_uses_one_array() {
        let cfg = AcceleratorConfig::neural_pim();
        let l = Layer::conv("t", 3, 3, 8, 12, 1); // K = 27, cout 8
        let m = map_layer(&l, &cfg);
        assert_eq!(m.arrays_per_copy, 1);
    }

    #[test]
    fn replication_reduces_bottleneck() {
        let cfg = AcceleratorConfig::neural_pim();
        let net = alexnet();
        let m = map_network(&net, &cfg);
        let ic = cfg.precision.input_cycles() as u64;
        // with the budget of a 280-tile chip the bottleneck must improve
        // over the unreplicated mapping
        let unrep: u64 = net
            .layers
            .iter()
            .map(|l| map_layer(l, &cfg).stage_cycles(ic))
            .max()
            .unwrap();
        assert!(m.bottleneck_cycles(ic) < unrep);
        // conv1 (3025 positions) must be replicated more than fc8
        let r_conv1 = m.layers[0].replication;
        let r_fc8 = m.layers.last().unwrap().replication;
        assert!(r_conv1 > r_fc8, "conv1 r={r_conv1}, fc8 r={r_fc8}");
    }

    #[test]
    fn budget_never_exceeded() {
        prop::check("mapping stays within array budget", 60, |g| {
            let mut cfg = AcceleratorConfig::neural_pim();
            cfg.tiles = g.usize_in(1, 64) as u32;
            let n_layers = g.usize_in(1, 8);
            let mut layers = Vec::new();
            for i in 0..n_layers {
                let cin = g.usize_in(1, 512) as u32;
                let cout = g.usize_in(1, 512) as u32;
                let out = g.usize_in(1, 56) as u32;
                layers.push(Layer::conv(&format!("l{i}"), 3, cin, cout, out, 1));
            }
            let net = crate::workloads::Network { name: "prop".into(), layers };
            let m = map_network(&net, &cfg);
            let budget = m.chips * cfg.total_arrays();
            crate::prop_assert!(
                m.total_arrays() <= budget,
                "used {} > budget {}", m.total_arrays(), budget
            );
            // every layer keeps at least one copy
            crate::prop_assert!(
                m.layers.iter().all(|l| l.replication >= 1),
                "lost a layer copy"
            );
            Ok(())
        });
    }

    #[test]
    fn layer_tiles_are_in_range_and_monotone_until_wrap() {
        let cfg = AcceleratorConfig::neural_pim();
        let net = alexnet();
        let m = map_network(&net, &cfg);
        let tiles = m.layer_tiles(&cfg);
        assert_eq!(tiles.len(), m.layers.len());
        let mut wrapped = false;
        for w in tiles.windows(2) {
            assert!(w[0] < cfg.tiles && w[1] < cfg.tiles);
            if w[1] < w[0] {
                assert!(!wrapped, "tile assignment wrapped twice");
                wrapped = true;
            }
        }
    }

    #[test]
    fn buffer_capacity_clamps_to_range() {
        let cfg = AcceleratorConfig::neural_pim();
        let net = alexnet();
        let m = map_network(&net, &cfg);
        for s in 1..m.layers.len() {
            let cap = m.buffer_capacity_infs(s, cfg.edram_bytes, 8);
            assert!((1..=8).contains(&cap), "stage {s}: cap {cap}");
            // big producer outputs pin the buffer at one inference
            if m.layers[s - 1].out_bytes() > cfg.edram_bytes {
                assert_eq!(cap, 1, "stage {s}");
            }
        }
        // out_bytes is positions x cout
        let l = &m.layers[0];
        assert_eq!(l.out_bytes(), l.layer.positions() * l.layer.cout as u64);
    }

    #[test]
    fn conservation_no_weights_lost() {
        prop::check("mapping conserves weight capacity", 60, |g| {
            let cfg = AcceleratorConfig::neural_pim();
            let cin = g.usize_in(1, 1024) as u32;
            let cout = g.usize_in(1, 1024) as u32;
            let l = Layer::conv("c", 3, cin, cout, 7, 1);
            let m = map_layer(&l, &cfg);
            // capacity of the allocated arrays covers the layer's weights
            let cap = m.arrays_per_copy
                * cfg.xbar_size as u64
                * cfg.groups_per_array();
            crate::prop_assert!(
                cap >= l.weights(),
                "capacity {} < weights {}", cap, l.weights()
            );
            // and not absurdly over-allocated (< 1 full chunk of waste in
            // each dimension)
            let min_arrays = (l.k_dim().div_ceil(cfg.xbar_size as u64))
                * (l.cout as u64).div_ceil(cfg.groups_per_array());
            crate::prop_assert!(m.arrays_per_copy == min_arrays, "over-alloc");
            Ok(())
        });
    }
}
