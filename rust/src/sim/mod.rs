//! Full-system simulator: the two-stage coarse-grained tile pipeline of
//! §5.2.4 with per-component energy integration — the tool behind
//! Fig. 12 (energy + throughput), Fig. 13 (energy breakdown) and the
//! headline 5.36x / 1.73x / 3.43x / 1.59x comparisons.
//!
//! The model is phase-accurate: per layer it counts input cycles, A/D
//! conversions, S+A/NNS+A operations, buffer writes, memory and NoC
//! traffic, then multiplies by the per-op energies of
//! `energy::constants`. Latency follows the replicated pipeline: the
//! slowest stage paces the whole chip (plus the 9/8 two-stage overhead of
//! Fig. 8), which is how the authors' simulator works too.

use crate::config::{AcceleratorConfig, Architecture};
use crate::energy;
use crate::mapping::NetworkMapping;
use crate::model;
use crate::util::pool;
use crate::workloads::Network;
use std::sync::Arc;

/// Re-exported from the `model` subsystem, which owns the per-layer cost
/// computation; existing `sim::EnergyBreakdown` paths keep working.
pub use crate::model::EnergyBreakdown;

/// Simulation result for one (network, architecture) pair.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub network: Arc<str>,
    pub arch: Architecture,
    pub energy_per_inference: f64,
    pub breakdown: EnergyBreakdown,
    pub latency_s: f64,
    /// pipelined inferences per second
    pub inferences_per_s: f64,
    pub throughput_gops: f64,
    /// GOPS/W
    pub energy_efficiency: f64,
    /// GOPS/mm²
    pub compute_efficiency: f64,
    pub chips: u64,
    pub arrays_used: u64,
    pub chip_area_mm2: f64,
}

/// Simulate one network on one accelerator configuration. The mapping
/// and per-layer energies come from the memoized
/// [`model::network_cost`] table, so repeated evaluations of the same
/// `(network, config)` pair — across the report tables, the event
/// simulator's scenarios, and the golden tests — price the layers once.
pub fn simulate(net: &Network, cfg: &AcceleratorConfig) -> SimResult {
    let nc = model::network_cost(net, cfg);
    let m = &nc.mapping;
    let e = nc.total.clone();
    let t_cycle = energy::cycle_seconds(cfg);
    let input_cycles = cfg.precision.input_cycles() as u64;

    // two-stage pipeline (Fig. 8): analog VMM stage + digital stage; the
    // paper charges 9 input cycles per 8-cycle pipeline step.
    let stage_overhead = 9.0 / 8.0;
    let bottleneck = m.bottleneck_cycles(input_cycles) as f64;
    let per_inference_s = bottleneck * t_cycle * stage_overhead;
    // fill latency: sum of all stages once
    let fill: u64 = m
        .layers
        .iter()
        .map(|l| l.stage_cycles(input_cycles))
        .sum();
    let latency_s = fill as f64 * t_cycle * stage_overhead;

    let inferences_per_s = 1.0 / per_inference_s;
    let gops = net.gops() * inferences_per_s;
    let chip = energy::chip_budget(cfg);
    let area = chip.area() * m.chips as f64;
    // dynamic power = energy/inference x inference rate; energy
    // efficiency (GOPS/W) is then ops/s over watts = ops/J
    let power = e.total() * inferences_per_s;
    SimResult {
        network: net.name.clone(),
        arch: cfg.arch,
        energy_per_inference: e.total(),
        breakdown: e,
        latency_s,
        inferences_per_s,
        throughput_gops: gops,
        energy_efficiency: gops / power.max(1e-30),
        compute_efficiency: gops / area,
        chips: m.chips,
        arrays_used: m.total_arrays(),
        chip_area_mm2: area,
    }
}

/// Per-inference energy with the Fig. 13 component resolution.
pub fn energy_per_inference(_net: &Network, cfg: &AcceleratorConfig,
                            m: &NetworkMapping) -> EnergyBreakdown {
    let mut out = EnergyBreakdown::default();
    for lm in &m.layers {
        out.add(&layer_energy(lm, cfg, m.chips > 1));
    }
    out
}

/// Per-inference energy of ONE mapped layer — the unit the event-driven
/// simulator charges at stage granularity;
/// [`energy_per_inference`] is exactly the sum of these over the layers.
///
/// Thin dispatch over [`model::layer_cost`]: the architecture-common
/// terms and the per-architecture interface energy both live in the
/// `model` subsystem now, and the memoized
/// [`model::network_cost`] table stores exactly these values.
pub fn layer_energy(lm: &crate::mapping::LayerMapping,
                    cfg: &AcceleratorConfig,
                    multi_chip: bool) -> EnergyBreakdown {
    model::layer_cost(lm, cfg, multi_chip).energy
}

/// The configuration the Fig. 12 fairness rule evaluates: `arch`'s
/// default config with its tile count scaled to `reference_area`. The
/// single source of truth for iso-area scenario construction — the
/// event-driven cross-validation and latency tables rebuild scenarios
/// through this same helper so both simulators always see one chip.
pub fn iso_area_config(arch: Architecture, reference_area: f64)
                       -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::for_arch(arch);
    cfg.tiles = energy::iso_area_tiles(&cfg, reference_area);
    cfg
}

/// Iso-area variant of [`simulate`]: scale the config's tile count so all
/// architectures occupy the reference area (the Fig. 12 fairness rule).
pub fn simulate_iso_area(net: &Network, arch: Architecture,
                         reference_area: f64) -> SimResult {
    simulate(net, &iso_area_config(arch, reference_area))
}

/// The Fig. 12 experiment: all 9 benchmarks x every registered
/// architecture at equal chip area, plus geomean ratios (the headline
/// numbers).
pub struct SystemComparison {
    pub results: Vec<SimResult>,
    pub reference_area: f64,
}

pub fn run_system_comparison(nets: &[Network]) -> SystemComparison {
    let np = AcceleratorConfig::neural_pim();
    let reference_area = energy::chip_budget(&np).area();
    // every (network, architecture) pair is independent: evaluate them
    // across the worker pool, in the same order the sequential loop used
    // (pool::map reassembles by index, so results are identical at any
    // thread count); the architectures come from the model registry, so
    // newly registered ones appear here with no edits
    let pairs: Vec<(&Network, Architecture)> = nets
        .iter()
        .flat_map(|net| model::archs().into_iter().map(move |a| (net, a)))
        .collect();
    let results = pool::map(&pairs, |&(net, arch)| {
        simulate_iso_area(net, arch, reference_area)
    });
    SystemComparison { results, reference_area }
}

impl SystemComparison {
    fn metric_ratio<F: Fn(&SimResult) -> f64>(&self, vs: Architecture,
                                              f: F) -> f64 {
        let mut ratios = Vec::new();
        let nets: Vec<&str> = {
            let mut v: Vec<&str> =
                self.results.iter().map(|r| r.network.as_ref()).collect();
            v.dedup();
            v
        };
        let reference = model::reference();
        for net in nets {
            let np = self
                .results
                .iter()
                .find(|r| r.network.as_ref() == net && r.arch == reference)
                .unwrap();
            let base = self
                .results
                .iter()
                .find(|r| r.network.as_ref() == net && r.arch == vs)
                .unwrap();
            ratios.push(f(np) / f(base));
        }
        crate::util::stats::geomean(&ratios)
    }

    /// Geomean energy-efficiency improvement of Neural-PIM over `vs`
    /// (paper: 5.36x vs ISAAC, 1.73x vs CASCADE).
    pub fn energy_ratio(&self, vs: Architecture) -> f64 {
        // efficiency ratio == inverse energy-per-inference ratio at equal
        // work, scaled by relative throughput... the paper reports
        // energy-per-benchmark (Fig. 12a), so compare 1/energy.
        self.metric_ratio(vs, |r| 1.0 / r.energy_per_inference)
    }

    /// Geomean throughput improvement (paper: 3.43x / 1.59x).
    pub fn throughput_ratio(&self, vs: Architecture) -> f64 {
        self.metric_ratio(vs, |r| r.throughput_gops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn neural_pim_wins_both_metrics_on_alexnet() {
        let net = workloads::alexnet();
        let cmp = run_system_comparison(&[net]);
        let e_isaac = cmp.energy_ratio(Architecture::IsaacLike);
        let t_isaac = cmp.throughput_ratio(Architecture::IsaacLike);
        let e_cascade = cmp.energy_ratio(Architecture::CascadeLike);
        let t_cascade = cmp.throughput_ratio(Architecture::CascadeLike);
        assert!(e_isaac > 1.5, "energy vs ISAAC {e_isaac}");
        // single-benchmark throughput is dominated by replication
        // discreteness (chip quantization); the geomean across the nine
        // benchmarks is the headline metric (integration test) — here we
        // only require a win
        assert!(t_isaac > 1.0, "throughput vs ISAAC {t_isaac}");
        assert!(e_cascade > 1.0, "energy vs CASCADE {e_cascade}");
        // per-benchmark throughput vs CASCADE swings with replication
        // granularity (Fig. 12b's bars vary per network too); the 1.59x
        // geomean is asserted by the integration suite
        let _ = t_cascade;
        // and ISAAC is the weaker baseline on energy (paper ordering)
        assert!(e_isaac > e_cascade);
    }

    #[test]
    fn isaac_breakdown_is_adc_dominated() {
        // Fig. 13 / §1: 58% of ISAAC's energy is ADC
        let net = workloads::alexnet();
        let cfg = AcceleratorConfig::isaac_like();
        let r = simulate(&net, &cfg);
        let share = r.breakdown.adc / r.breakdown.total();
        assert!(share > 0.4 && share < 0.8, "adc share {share}");
    }

    #[test]
    fn neural_pim_sa_far_cheaper_than_isaac_adc() {
        // Fig. 13: NNS+A consumes 33x less than ISAAC's ADCs
        let net = workloads::alexnet();
        let isaac = simulate(&net, &AcceleratorConfig::isaac_like());
        let np = simulate(&net, &AcceleratorConfig::neural_pim());
        let ratio = isaac.breakdown.adc / np.breakdown.sa;
        assert!(ratio > 10.0, "ratio {ratio}");
    }

    #[test]
    fn energy_scales_with_network_size() {
        let cfg = AcceleratorConfig::neural_pim();
        let small = simulate(&workloads::mobilenet_v2(), &cfg);
        let big = simulate(&workloads::vgg16(), &cfg);
        assert!(big.energy_per_inference > 5.0 * small.energy_per_inference);
    }

    #[test]
    fn latency_at_least_one_stage() {
        let cfg = AcceleratorConfig::neural_pim();
        for net in workloads::all_benchmarks() {
            let r = simulate(&net, &cfg);
            assert!(r.latency_s > 0.0 && r.latency_s.is_finite(), "{}", net.name);
            assert!(r.inferences_per_s > 0.0);
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let cfg = AcceleratorConfig::cascade_like();
        let net = workloads::alexnet();
        let m = crate::mapping::map_network(&net, &cfg);
        let e = energy_per_inference(&net, &cfg, &m);
        let cat_sum: f64 = e.categories().iter().map(|(_, v)| v).sum();
        assert!((cat_sum - e.total()).abs() < 1e-12 * e.total().max(1.0));
    }
}
