//! Area / power budgeting per PE, tile, and chip (Tables 2 and 3).
//!
//! The budget is assembled bottom-up from `constants.rs`, per
//! architecture: each [`ComponentBudget`] row lists a component, its
//! count, per-unit power (at full activity) and area. The same rows feed
//! Table 2 (Neural-PIM tile parameters), Table 3 (PE-level comparison +
//! density), and the iso-area normalization of the Fig. 12 system
//! comparison.

pub mod constants;

use crate::config::{AcceleratorConfig, Architecture};
use constants as k;

#[derive(Debug, Clone)]
pub struct ComponentBudget {
    pub name: &'static str,
    pub count: u64,
    /// W per unit at full activity
    pub unit_power: f64,
    /// mm² per unit
    pub unit_area: f64,
}

impl ComponentBudget {
    pub fn power(&self) -> f64 {
        self.count as f64 * self.unit_power
    }

    pub fn area(&self) -> f64 {
        self.count as f64 * self.unit_area
    }
}

#[derive(Debug, Clone)]
pub struct PeBudget {
    pub arch: Architecture,
    pub components: Vec<ComponentBudget>,
}

impl PeBudget {
    pub fn power(&self) -> f64 {
        self.components.iter().map(|c| c.power()).sum()
    }

    pub fn area(&self) -> f64 {
        self.components.iter().map(|c| c.area()).sum()
    }

    /// Table 3's density proxy: VMM-array area / total PE area.
    pub fn compute_density(&self) -> f64 {
        let xbar: f64 = self
            .components
            .iter()
            .filter(|c| c.name == "crossbar")
            .map(|c| c.area())
            .sum();
        xbar / self.area()
    }

    /// RRAM cells per mm² (the parenthesized Table 3 metric).
    pub fn cells_per_mm2(&self, cfg: &AcceleratorConfig) -> f64 {
        let cells = cfg.arrays_per_pe as f64
            * (cfg.xbar_size as f64) * (cfg.xbar_size as f64);
        cells / self.area()
    }
}

/// Build the PE-level budget for a configuration: the crossbar + WL-DAC
/// rows common to every analog architecture, plus whatever periphery the
/// architecture's registered cost model declares
/// ([`crate::model::CostModel::peripheral_components`]). Models that
/// report [`crate::model::CostModel::analog_frontend`] `false` (the
/// digital NPU) get no crossbar/DAC rows — their compute front-end is
/// already in their peripheral component list.
pub fn pe_budget(cfg: &AcceleratorConfig) -> PeBudget {
    let p = &cfg.precision;
    let cyc = cycle_seconds(cfg);
    let m = cfg.arrays_per_pe as u64;
    let size = cfg.xbar_size;
    let wl = size as u64; // wordlines per array
    let model = crate::model::cost_model(cfg.arch);
    let mut comps = if model.analog_frontend() {
        vec![
            ComponentBudget {
                name: "crossbar",
                count: m,
                unit_power: k::xbar_e_cycle(size, p.p_d) / cyc,
                unit_area: k::xbar_area(size),
            },
            ComponentBudget {
                name: "dac",
                count: m * wl,
                unit_power: k::dac_e_cycle(p.p_d) / cyc,
                unit_area: k::dac_area(p.p_d),
            },
        ]
    } else {
        Vec::new()
    };
    comps.extend(model.peripheral_components(cfg));
    PeBudget { arch: cfg.arch, components: comps }
}

/// Tile = PEs + eDRAM + post-processing + control.
#[derive(Debug, Clone)]
pub struct TileBudget {
    pub pe: PeBudget,
    pub pes: u32,
    pub extra: Vec<ComponentBudget>,
}

impl TileBudget {
    pub fn power(&self) -> f64 {
        self.pe.power() * self.pes as f64
            + self.extra.iter().map(|c| c.power()).sum::<f64>()
    }

    pub fn area(&self) -> f64 {
        self.pe.area() * self.pes as f64
            + self.extra.iter().map(|c| c.area()).sum::<f64>()
    }
}

pub fn tile_budget(cfg: &AcceleratorConfig) -> TileBudget {
    let cyc = cycle_seconds(cfg);
    let extra = vec![
        ComponentBudget {
            name: "edram",
            count: 1,
            unit_power: k::EDRAM_E_BYTE
                * (cfg.xbar_size as u64 * cfg.arrays_per_pe as u64
                    * cfg.pes_per_tile as u64) as f64
                / cyc
                / 8.0,
            unit_area: k::EDRAM_AREA_64KB
                * (cfg.edram_bytes as f64 / (64.0 * 1024.0)),
        },
        ComponentBudget {
            name: "post-proc",
            count: 1,
            unit_power: k::ACT_E_OP
                * (cfg.arrays_per_pe * cfg.pes_per_tile) as f64
                / cyc
                / 8.0,
            unit_area: k::ACT_AREA * cfg.pes_per_tile as f64,
        },
        ComponentBudget {
            name: "control",
            count: 1,
            unit_power: k::TILE_CTRL_POWER,
            unit_area: k::TILE_CTRL_AREA,
        },
        ComponentBudget {
            name: "router(1/4)",
            count: 1,
            unit_power: k::NOC_E_BYTE * 3.2e9 / cfg.noc_concentration as f64
                / 8.0,
            unit_area: k::ROUTER_AREA / cfg.noc_concentration as f64,
        },
    ];
    TileBudget { pe: pe_budget(cfg), pes: cfg.pes_per_tile, extra }
}

/// Whole chip: tiles + HyperTransport (Table 2's bottom rows).
#[derive(Debug, Clone)]
pub struct ChipBudget {
    pub tile: TileBudget,
    pub tiles: u32,
}

impl ChipBudget {
    pub fn power(&self) -> f64 {
        self.tile.power() * self.tiles as f64 + k::HT_POWER
    }

    pub fn area(&self) -> f64 {
        self.tile.area() * self.tiles as f64 + k::HT_AREA
    }
}

pub fn chip_budget(cfg: &AcceleratorConfig) -> ChipBudget {
    ChipBudget { tile: tile_budget(cfg), tiles: cfg.tiles }
}

/// Architecture-specific input-cycle time in seconds, from the
/// registered cost model (see the `*_CYCLE_NS` constants).
pub fn cycle_seconds(cfg: &AcceleratorConfig) -> f64 {
    crate::model::cost_model(cfg.arch).cycle_ns() * 1e-9
}

/// Iso-area tile count: scale an architecture's tile count so its chip
/// area matches the reference chip area (the Fig. 12 fairness rule:
/// "all three architectures have the same area").
pub fn iso_area_tiles(cfg: &AcceleratorConfig, target_area: f64) -> u32 {
    let tile_area = tile_budget(cfg).area();
    (((target_area - k::HT_AREA) / tile_area).floor() as u32).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neural_pim_pe_budget_matches_table2_scale() {
        let cfg = AcceleratorConfig::neural_pim();
        let pe = pe_budget(&cfg);
        // Table 2: 1 PE ~ 0.18 W, 0.084 mm² (the paper's own component rows
        // sum to 0.26 W; we accept [0.1, 0.4] W and [0.05, 0.2] mm²)
        let p = pe.power();
        let a = pe.area();
        assert!(p > 0.05 && p < 0.4, "PE power {p}");
        assert!(a > 0.05 && a < 0.2, "PE area {a}");
    }

    #[test]
    fn isaac_pe_is_adc_dominated() {
        let cfg = AcceleratorConfig::isaac_like();
        let pe = pe_budget(&cfg);
        let adc_area: f64 = pe.components.iter()
            .filter(|c| c.name == "adc").map(|c| c.area()).sum();
        // 64 8-bit ADCs dwarf everything else in ISAAC's PE (§1: 98% of a
        // scientific accelerator's area; here a large majority of PE area)
        assert!(adc_area / pe.area() > 0.4, "{}", adc_area / pe.area());
    }

    #[test]
    fn density_ordering() {
        // ISAAC's per-array ADCs must make it the least dense (Table 3's
        // qualitative point); our component-level area model exaggerates
        // CASCADE's buffer-array overhead relative to the paper's layout
        // numbers, so we assert ISAAC-lowest plus a same-order band (see
        // EXPERIMENTS.md Table 3 notes).
        let d_isaac = {
            let c = AcceleratorConfig::isaac_like();
            pe_budget(&c).cells_per_mm2(&c)
        };
        let d_cascade = {
            let c = AcceleratorConfig::cascade_like();
            pe_budget(&c).cells_per_mm2(&c)
        };
        let d_np = {
            let c = AcceleratorConfig::neural_pim();
            pe_budget(&c).cells_per_mm2(&c)
        };
        assert!(d_np > d_isaac, "np {d_np} isaac {d_isaac}");
        assert!(d_cascade > d_isaac, "cascade {d_cascade} isaac {d_isaac}");
        assert!(d_cascade / d_np < 10.0 && d_np / d_cascade < 10.0);
    }

    #[test]
    fn chip_budget_total_scale() {
        let cfg = AcceleratorConfig::neural_pim();
        let chip = chip_budget(&cfg);
        // Table 2 reports 67.7 W / 86.4 mm² — but its own component rows
        // sum to ~0.26 W/PE (= 290 W/chip), so the paper's total is not
        // self-consistent. Our bottom-up sum must land between the two.
        assert!(chip.power() > 30.0 && chip.power() < 320.0,
                "chip power {}", chip.power());
        assert!(chip.area() > 40.0 && chip.area() < 240.0,
                "chip area {}", chip.area());
    }

    #[test]
    fn iso_area_roundtrip() {
        let np = AcceleratorConfig::neural_pim();
        let area = chip_budget(&np).area();
        let tiles = iso_area_tiles(&np, area);
        assert!((tiles as i64 - np.tiles as i64).abs() <= 1,
                "tiles {tiles} vs {}", np.tiles);
    }
}
