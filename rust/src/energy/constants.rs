//! Per-operation energy / power / area constants at 32 nm.
//!
//! Every constant is anchored to a published number and the derivation is
//! given inline. Sources:
//!   [T2]   this paper, Table 2 (Neural-PIM tile parameters, 32 nm)
//!   [T1]   this paper, Table 1 (NeuralPeriph circuit measurements,
//!          130 nm, conservatively scaled to 32 nm by the authors)
//!   [I]    ISAAC, Table 6 (IMA component breakdown, 32 nm)
//!   [C]    CASCADE, §5 (TIA / buffer-array costs)
//!   [S]    Saberi et al., capacitive-DAC energy analysis
//!
//! Units: energy J, power W, area mm², time s.

/// Input cycle time [I]/[T2]: both ISAAC and Neural-PIM run 100 ns input
/// cycles (§5.2.4: "each input cycle is 100 ns as proposed by [1]").
pub const CYCLE_NS: f64 = 100.0;

// ---------------------------------------------------------------------------
// ADCs
// ---------------------------------------------------------------------------

/// SAR ADC energy per conversion at 8 bits [I]: 2 mW at 1.28 GS/s
/// -> 2e-3 / 1.28e9 = 1.5625 pJ.
pub const ADC_E_CONV_8B: f64 = 1.5625e-12;

/// ADC conversion energy doubles per bit (the exponential scaling law the
/// paper cites for Fig. 4b; Murmann's survey supports ~2x/bit for SAR in
/// the 6-10 bit regime).
pub fn adc_e_conv(bits: u32) -> f64 {
    ADC_E_CONV_8B * 2f64.powi(bits as i32 - 8)
}

/// CASCADE's shared ADCs run at ~1/20th of ISAAC's aggregate conversion
/// rate (3 converters for 15 conversions per 8-cycle window vs 64
/// always-on), so CASCADE provisions 8-bit-energy-class converters even
/// at 10-bit nominal resolution (the accuracy cost is visible as the
/// lowest dataflow SINAD in Fig. 10 — CASCADE trades precision for
/// energy). Charged per conversion:
pub const CASCADE_ADC_E_CONV: f64 = ADC_E_CONV_8B;

/// SAR ADC area at 8 bits: 0.0015 mm². ISAAC's table lists 0.0096 mm²
/// (a 2015-era design); the paper's Table-3 densities (4.5e6 vs 4.6e6
/// cells/mm² for ISAAC vs Neural-PIM, i.e. near-equal PE areas) are only
/// reachable with a modern 32 nm SAR footprint, so we fit this anchor to
/// Table 3 and note the deviation in EXPERIMENTS.md.
pub const ADC_AREA_8B: f64 = 0.0015;

pub fn adc_area(bits: u32) -> f64 {
    ADC_AREA_8B * 2f64.powi(bits as i32 - 8)
}

/// NNADC energy per conversion [T2]: 4 NNADCs = 6.0e-3 W at 1.2 GS/s
/// -> 1.5e-3 / 1.2e9 = 1.25 pJ per 8-bit conversion.
pub const NNADC_E_CONV: f64 = 1.25e-12;

/// NNADC area [T2]: 4.8e-3 mm² / 4 = 1.2e-3 mm² each — 8x smaller than
/// the SAR ADC (the RRAM-substrate area claim of §4.3).
pub const NNADC_AREA: f64 = 1.2e-3;

// ---------------------------------------------------------------------------
// DACs
// ---------------------------------------------------------------------------

/// 1-bit DAC (wordline driver) energy per cycle [I]: DAC array of 8x128
/// 1-bit drivers = 4 mW -> per driver 3.9 uW; per 100 ns cycle
/// -> 3.9e-6 * 1e-7 = 0.39 pJ. We use 0.39 pJ per WL per cycle.
pub const DAC_E_CYCLE_1B: f64 = 0.39e-12;

/// Capacitive-DAC energy grows "weakly exponentially" with resolution
/// ([S]; the paper's §3.3 wording). Fitted through BOTH published
/// anchors: ISAAC's 1-bit driver (0.39 pJ) and the paper's own Table-2
/// 4-bit DAC (0.1 W / 8192 DACs -> 1.22 pJ per 100 ns cycle):
///   E(b) = E1 * 2^(0.55 * (b - 1))   [0.39 -> 1.22 pJ at b = 4].
pub fn dac_e_cycle(bits: u32) -> f64 {
    DAC_E_CYCLE_1B * 2f64.powf(0.55 * (bits as f64 - 1.0))
}

/// WL driver area anchored to [T2]: 8192 4-bit DACs occupy 4.3e-3 mm²
/// -> 5.25e-7 mm² each; scaled back to 1-bit with the same weak
/// exponent as the energy law.
#[allow(clippy::approx_constant)] // 3.14 is 2^(0.55*3) rounded, not pi
pub const DAC_AREA_1B: f64 = 5.25e-7 / 3.14; // 2^(0.55*3) = 3.14

/// DAC area scaling: same weak exponential as dac_e_cycle (capacitor
/// array dominated).
pub fn dac_area(bits: u32) -> f64 {
    DAC_AREA_1B * 2f64.powf(0.55 * (bits as f64 - 1.0))
}

/// [T2] Neural-PIM 4-bit DAC: 0.1 W / 8192 = 12.2 uW -> 1.22 pJ / 100 ns.
pub const NP_DAC4_E_CYCLE: f64 = 1.22e-12;

// ---------------------------------------------------------------------------
// Crossbar arrays
// ---------------------------------------------------------------------------

/// 128x128 VMM array read energy per cycle [I]: 0.3 mW per active array
/// at 100 ns -> 30 pJ per array-cycle (1-bit DAC read voltages).
pub const XBAR_E_CYCLE_128: f64 = 30e-12;

/// Array read energy per cycle is resolution-independent to first order:
/// a multi-bit DAC drives the same voltage range with finer steps, so the
/// I*V*t read energy stays ~constant. ([T2]'s 1.5 mW crossbar row at
/// 4-bit folds WL-driver overhead into the array; we attribute all
/// resolution dependence to the DAC row so Fig. 4(b)'s trade-off is
/// modelled once, not twice.)
pub fn xbar_e_cycle(size: u32, _p_d: u32) -> f64 {
    XBAR_E_CYCLE_128 * (size as f64 / 128.0).powi(2)
}

/// 128x128 array area [I]: 25 um² per cell pitch region incl. drivers
/// -> 0.0025 mm²... [T2] gives 1.6e-3 mm² for the array proper; we use
/// [T2] (the paper's own number).
pub const XBAR_AREA_128: f64 = 1.6e-3 / 64.0 * 64.0 / 64.0; // 2.5e-5 per array

pub fn xbar_area(size: u32) -> f64 {
    2.5e-5 * (size as f64 / 128.0).powi(2)
}

// ---------------------------------------------------------------------------
// Accumulation circuits
// ---------------------------------------------------------------------------

/// Digital shift-and-add energy per operation [I]: S+A unit 0.2 mW at
/// 1.28 GHz serving one array -> 0.156 pJ per S+A op.
pub const SA_DIGITAL_E_OP: f64 = 0.156e-12;

/// Digital S+A area [I]: 0.00024 mm².
pub const SA_DIGITAL_AREA: f64 = 0.00024;

/// NNS+A energy per accumulation cycle [T2]: 64 units at 80 MHz = 1.9e-2 W
/// -> 0.297 mW each -> 3.7 pJ per op (one op = 8 BL pairs + carry).
pub const NNSA_E_OP: f64 = 3.7e-12;

/// NNS+A area [T2]: 4.4e-2 mm² / 64 = 6.9e-4 mm².
pub const NNSA_AREA: f64 = 6.9e-4;

/// Sample-and-hold [T2]: 64x144 units = 6.4e-5 W -> 6.9 nW each
/// -> 0.09 fJ per 80 MHz op; area 3.2e-4 mm² / 9216.
pub const SH_E_OP: f64 = 0.09e-15;
pub const SH_AREA: f64 = 3.2e-4 / 9216.0;

/// TIA (CASCADE BL receiver) [C]: CASCADE's TIA performs the W+/W-
/// differential subtraction in the analog domain and drives the buffer
/// write; ~0.02 mW per array per cycle -> 2 pJ per 100 ns window.
pub const TIA_E_CYCLE: f64 = 2e-12;
pub const TIA_AREA: f64 = 0.0002;

/// RRAM buffer-cell write energy [C]: CASCADE uses short unverified
/// pulses for the (single-ended, post-TIA) partial sums — ~0.3 pJ/write;
/// the precision penalty shows up as the Fig. 10 SINAD loss instead.
pub const BUFFER_WRITE_E: f64 = 0.3e-12;

/// Buffer array area: same cell pitch as VMM arrays; CASCADE allocates
/// 4 buffer arrays per computing array.
pub const BUFFER_ARRAYS_PER_XBAR: u32 = 4;

/// CASCADE analog summing amplifier per buffer array [C].
pub const SUMAMP_E_CYCLE: f64 = 0.5e-12;
pub const SUMAMP_AREA: f64 = 0.0001;

// ---------------------------------------------------------------------------
// Memory + interconnect [I]
// ---------------------------------------------------------------------------

/// eDRAM read/write energy per byte [I]: 20.7 mW for 64 KB at 2 GB/s
/// -> ~1.04 pJ/B... ISAAC table: eDRAM 20.7 mW; we charge 1 pJ/B.
pub const EDRAM_E_BYTE: f64 = 1.0e-12;
pub const EDRAM_AREA_64KB: f64 = 0.083;

/// SRAM IR/OR access energy per byte [I]: IR 2 KB = 1.24 mW; ~0.3 pJ/B.
pub const SRAM_E_BYTE: f64 = 0.3e-12;
pub const IR_AREA: f64 = 0.0021;
pub const OR_AREA: f64 = 0.00077;

/// [T2] Neural-PIM IR: 4e-2 W per PE at 100 ns cycles, 2.4e-2 mm².
pub const NP_IR_AREA: f64 = 2.4e-2;

/// c-mesh router: energy per byte routed + leakage [I]: router 42 mW
/// shared by 4 tiles at 3.2 GB/s -> ~1.7 pJ/B incl. links.
pub const NOC_E_BYTE: f64 = 1.7e-12;
pub const ROUTER_AREA: f64 = 0.151;

/// HyperTransport off-chip link [T2]: 10.4 W, 22.88 mm² per chip; charged
/// per byte at 6.4 GB/s -> 1.6 nJ/KB.
pub const HT_POWER: f64 = 10.4;
pub const HT_AREA: f64 = 22.88;
pub const HT_E_BYTE: f64 = 1.6e-12;

/// Digital post-processing (activation, pooling, EM ops) per output [I]:
/// sigmoid unit 0.52 mW; ~0.05 pJ per activation op.
pub const ACT_E_OP: f64 = 0.05e-12;
pub const ACT_AREA: f64 = 0.0006;

/// Tile controller + decoder leakage share per tile.
pub const TILE_CTRL_POWER: f64 = 0.5e-3;
pub const TILE_CTRL_AREA: f64 = 0.00145;

// ---------------------------------------------------------------------------
// Architecture-specific cycle times (the throughput mechanism of Fig. 12b)
// ---------------------------------------------------------------------------

/// ISAAC's 100 ns input cycle is ADC-rate-bound: one 1.28 GS/s ADC must
/// cover 128 BLs per cycle [I].
pub const ISAAC_CYCLE_NS: f64 = 100.0;

/// CASCADE's VMM cycle is TIA/buffer-write-bound, not ADC-bound: the
/// conversion happens off the critical path on the buffer array. The
/// fitted cycle reproducing the paper's throughput ratios (3.43/1.59 ->
/// CASCADE ~2.2x ISAAC at iso-area) under our pipeline model is 40 ns.
pub const CASCADE_CYCLE_NS: f64 = 50.0;

/// Neural-PIM keeps the 100 ns input cycle [T2]: one NNS+A at 80 MHz
/// serves its array's 8 groups sequentially (8 x 12.5 ns = 100 ns), and
/// the 4-bit DACs halve the number of input cycles instead.
pub const NEURAL_PIM_CYCLE_NS: f64 = 100.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_energy_is_exponential_in_bits() {
        assert!((adc_e_conv(8) - ADC_E_CONV_8B).abs() < 1e-20);
        assert!((adc_e_conv(10) / adc_e_conv(8) - 4.0).abs() < 1e-9);
        assert!(adc_e_conv(7) < adc_e_conv(8));
    }

    #[test]
    fn nnadc_cheaper_and_smaller_than_sar() {
        // the §4.3 claim: neural peripherals beat conventional ones
        assert!(NNADC_E_CONV < adc_e_conv(8));
        assert!(NNADC_AREA < adc_area(8));
    }

    #[test]
    fn nnsa_cheaper_than_adc_conversion_chain() {
        // Fig. 13: "S+A in Neural-PIM consumes 33x less than ISAAC's ADCs".
        // Per dot-product group: ISAAC does S*J = 64 conversions; Neural-PIM
        // does S = 2 NNS+A ops + 1 conversion.
        let isaac = 64.0 * 2.0 * adc_e_conv(8);
        let np = 2.0 * NNSA_E_OP + NNADC_E_CONV;
        assert!(isaac / np > 20.0, "ratio {}", isaac / np);
    }

    #[test]
    fn dac_energy_monotone_and_anchored() {
        assert!(dac_e_cycle(2) > dac_e_cycle(1));
        assert!(dac_e_cycle(4) > dac_e_cycle(2));
        assert!(dac_e_cycle(8) > dac_e_cycle(4));
        // fitted through the paper's own Table-2 4-bit anchor (1.22 pJ)
        assert!((dac_e_cycle(4) - NP_DAC4_E_CYCLE).abs() < 0.1e-12,
                "dac4 = {}", dac_e_cycle(4));
    }

    #[test]
    fn xbar_energy_scales_with_size_only() {
        assert!((xbar_e_cycle(128, 4) - xbar_e_cycle(128, 1)).abs() < 1e-18);
        assert!(xbar_e_cycle(256, 1) > xbar_e_cycle(128, 1));
        assert!((xbar_e_cycle(128, 1) - 30e-12).abs() < 1e-15);
    }
}
