//! §7.1 design-space exploration over the five hyper-parameters
//! (N, M, A, S, D), regenerating Fig. 11's computation-efficiency sweep
//! and finding the optimal PE configuration.

use crate::config::{AcceleratorConfig, Precision};
use crate::energy;
use crate::model;
use crate::util::pool;

#[derive(Debug, Clone)]
pub struct DsePoint {
    pub cfg: AcceleratorConfig,
    /// peak GOPS/s/mm² (Fig. 11's y-axis)
    pub compute_efficiency: f64,
    /// peak GOPS/s/W
    pub energy_efficiency: f64,
    pub label: String,
}

/// Fig. 11's label format: N<size>-D<dac>-A<adcs>-S<sas> M<arrays>.
fn label(cfg: &AcceleratorConfig) -> String {
    format!(
        "N{}-D{}-A{}-S{} M{}",
        cfg.xbar_size,
        cfg.precision.p_d,
        cfg.adcs_per_pe,
        cfg.arrays_per_pe * cfg.sa_per_array,
        cfg.arrays_per_pe
    )
}

/// Peak efficiencies assuming full PE utilization (§7.1: "assumes that
/// all PEs can be somehow utilized in every cycle").
pub fn evaluate(cfg: &AcceleratorConfig) -> Option<DsePoint> {
    cfg.validate().ok()?;
    let m = model::cost_model(cfg.arch);
    // the shared converters must keep up: groups needing conversion per
    // input-period <= conversion slots (rate from the cost model)
    let groups = cfg.arrays_per_pe as u64 * cfg.groups_per_array();
    let period_s =
        cfg.precision.input_cycles() as f64 * energy::cycle_seconds(cfg);
    let adc_slots = cfg.adcs_per_pe as f64 * m.adc_samples_per_s() * period_s;
    if (groups as f64) > adc_slots {
        return None; // conversion-starved: not a usable design point
    }
    // analog accumulator service rate (e.g. each NNS+A serves its
    // array's groups sequentially inside one input cycle at 80 MHz);
    // digital accumulators impose no such limit
    if let Some(sa_rate) = m.sa_ops_per_s() {
        if (cfg.groups_per_array() as f64)
            > sa_rate * energy::cycle_seconds(cfg) * cfg.sa_per_array as f64
        {
            return None;
        }
    }
    // I/O bandwidth limit (§7.1: "the I/O bandwidth limits the number of
    // RRAM arrays"): the IR bus can feed at most 8192 wordline bytes per
    // input cycle per PE — the paper's peak sits exactly at this edge
    // (64 arrays x 128 rows).
    if cfg.arrays_per_pe as u64 * cfg.xbar_size as u64 > 8192 {
        return None;
    }
    // accuracy limit: beyond 128 rows the per-cell analog swing halves
    // while the NeuralPeriph voltage-noise floor stays fixed, pushing the
    // dataflow SINAD ~6 dB/doubling below the Fig.-10 SINAD_min — the
    // reason §5.1 fixes 128x128 despite 256x256 being fabricable (§2.2).
    if cfg.xbar_size > 128 {
        return None;
    }

    let pe = energy::pe_budget(cfg);
    let gops_per_pe = cfg.peak_gops()
        / (cfg.tiles as f64 * cfg.pes_per_tile as f64);
    Some(DsePoint {
        compute_efficiency: gops_per_pe / pe.area(),
        energy_efficiency: gops_per_pe / pe.power(),
        label: label(cfg),
        cfg: cfg.clone(),
    })
}

/// The Fig. 11 sweep: N in {32..256}, D in {1,2,4}, M in {16..128},
/// A in {1..8}, S derived (1 NNS+A per array or shared).
pub fn sweep() -> Vec<DsePoint> {
    // materialize the ~600-point grid in sequential order, then partition
    // the evaluations across the worker pool; pool::map preserves index
    // order, so the feasible-point list is identical at any thread count
    let mut grid = Vec::new();
    for &xbar in &[32u32, 64, 128, 256] {
        for &pd in &[1u32, 2, 4] {
            for &m in &[16u32, 32, 64, 96, 128] {
                for &a in &[1u32, 2, 4, 8] {
                    for &s in &[1u32, 2] {
                        let mut cfg = AcceleratorConfig::neural_pim();
                        cfg.xbar_size = xbar;
                        cfg.precision = Precision { p_d: pd, ..Default::default() };
                        cfg.arrays_per_pe = m;
                        cfg.adcs_per_pe = a;
                        cfg.sa_per_array = s;
                        grid.push(cfg);
                    }
                }
            }
        }
    }
    pool::map(&grid, evaluate).into_iter().flatten().collect()
}

/// Best point among already-computed sweep results (callers that also
/// render the Fig. 11 table share one sweep instead of re-running it).
pub fn best_of(points: &[DsePoint]) -> &DsePoint {
    points
        .iter()
        .max_by(|a, b| {
            a.compute_efficiency
                .partial_cmp(&b.compute_efficiency)
                .unwrap()
        })
        .expect("sweep produced no feasible points")
}

/// Best point of the sweep (the paper's N128-D4-A4-S64 M64 at
/// 1904 GOPS/s/mm²).
pub fn best() -> DsePoint {
    best_of(&sweep()).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_nonempty_and_finite() {
        let pts = sweep();
        assert!(pts.len() > 50, "only {} points", pts.len());
        for p in &pts {
            assert!(p.compute_efficiency.is_finite()
                && p.compute_efficiency > 0.0);
        }
    }

    #[test]
    fn paper_optimum_is_competitive() {
        // the paper's chosen config should be within 25% of our sweep's
        // best compute efficiency (Fig. 11's peak)
        let paper = evaluate(&AcceleratorConfig::neural_pim()).unwrap();
        let best = best();
        assert!(
            paper.compute_efficiency >= 0.5 * best.compute_efficiency,
            "paper {} vs best {} ({})",
            paper.compute_efficiency,
            best.compute_efficiency,
            best.label
        );
    }

    #[test]
    fn bigger_arrays_help_until_periphery_dominates() {
        // Fig. 11's first-order trend: 128 beats 32 at fixed D/M/A
        let eff = |xbar: u32| {
            let mut cfg = AcceleratorConfig::neural_pim();
            cfg.xbar_size = xbar;
            evaluate(&cfg).map(|p| p.compute_efficiency)
        };
        let e32 = eff(32).unwrap();
        let e128 = eff(128).unwrap();
        assert!(e128 > e32, "128: {e128}, 32: {e32}");
    }

    #[test]
    fn starved_adc_config_rejected() {
        let mut cfg = AcceleratorConfig::neural_pim();
        cfg.adcs_per_pe = 1;
        cfg.arrays_per_pe = 128;
        cfg.precision.p_d = 8; // one-cycle inputs: 1024 groups / period
        // 1 NNADC at 1.2 GS/s in a 100 ns period = 120 slots < 1024 groups
        assert!(evaluate(&cfg).is_none());
    }
}
