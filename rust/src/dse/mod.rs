//! §7.1 design-space exploration over the five hyper-parameters
//! (N, M, A, S, D), regenerating Fig. 11's computation-efficiency sweep
//! and finding the optimal PE configuration.
//!
//! Two grids share one evaluator:
//!
//! - the **coarse** Fig. 11 grid ([`sweep`], ~360 points) — materialized
//!   up front, filtered at construction so every generated point reaches
//!   the cost-model feasibility stage (the pre-PR-8 grid carried xbar=256
//!   configs the accuracy cutoff rejected unconditionally);
//! - the **fine** grid ([`fine_sweep`], ~1M candidates for `dse --fine`)
//!   — never materialized: points are decoded from a mixed-radix index
//!   ([`fine_cfg`]) and streamed through the worker pool in fixed-size
//!   batches, so memory stays flat at any grid size. The summary carries
//!   a running FNV-1a fingerprint of the feasible-point list
//!   (index order), the byte-identity anchor the `--threads 1/2/8`
//!   determinism tests assert on.
//!
//! [`evaluate_checked`] reports *why* a candidate fails ([`Rejection`]):
//! the fine sweep tallies per-guard rejection counts, and the grid
//! constructors are tested against ever emitting an unconditionally-dead
//! point (`Invalid` / `XbarTooLarge`).

use crate::config::{AcceleratorConfig, Precision};
use crate::energy;
use crate::model;
use crate::util::num::{fnv1a64_step, FNV1A64_OFFSET};
use crate::util::pool;

#[derive(Debug, Clone)]
pub struct DsePoint {
    pub cfg: AcceleratorConfig,
    /// peak GOPS/s/mm² (Fig. 11's y-axis)
    pub compute_efficiency: f64,
    /// peak GOPS/s/W
    pub energy_efficiency: f64,
    pub label: String,
}

/// Why [`evaluate_checked`] rejected a candidate. `Invalid` and
/// `XbarTooLarge` are properties of the config alone (grid constructors
/// must never emit them); the other three are cost-model feasibility
/// verdicts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// fails `AcceleratorConfig::validate`
    Invalid,
    /// groups per input period exceed the shared converters' slots
    AdcStarved,
    /// analog accumulator service rate can't cover its array's groups
    SaStarved,
    /// IR bus limit: `arrays_per_pe * xbar_size > 8192` wordline bytes
    IoBandwidth,
    /// accuracy cutoff: beyond 128 rows the dataflow SINAD drops below
    /// the Fig.-10 floor (§5.1)
    XbarTooLarge,
}

/// Fig. 11's label format: N<size>-D<dac>-A<adcs>-S<sas> M<arrays>.
fn label(cfg: &AcceleratorConfig) -> String {
    format!(
        "N{}-D{}-A{}-S{} M{}",
        cfg.xbar_size,
        cfg.precision.p_d,
        cfg.adcs_per_pe,
        cfg.arrays_per_pe * cfg.sa_per_array,
        cfg.arrays_per_pe
    )
}

/// The feasibility gauntlet + peak efficiencies, label-free: the fine
/// sweep scores ~1M candidates and only materializes labels for the
/// handful it reports.
fn score(cfg: &AcceleratorConfig) -> Result<(f64, f64), Rejection> {
    if cfg.validate().is_err() {
        return Err(Rejection::Invalid);
    }
    let m = model::cost_model(cfg.arch);
    // the shared converters must keep up: groups needing conversion per
    // input-period <= conversion slots (rate from the cost model)
    let groups = cfg.arrays_per_pe as u64 * cfg.groups_per_array();
    let period_s =
        cfg.precision.input_cycles() as f64 * energy::cycle_seconds(cfg);
    let adc_slots = cfg.adcs_per_pe as f64 * m.adc_samples_per_s() * period_s;
    if (groups as f64) > adc_slots {
        return Err(Rejection::AdcStarved);
    }
    // analog accumulator service rate (e.g. each NNS+A serves its
    // array's groups sequentially inside one input cycle at 80 MHz);
    // digital accumulators impose no such limit
    if let Some(sa_rate) = m.sa_ops_per_s() {
        if (cfg.groups_per_array() as f64)
            > sa_rate * energy::cycle_seconds(cfg) * cfg.sa_per_array as f64
        {
            return Err(Rejection::SaStarved);
        }
    }
    // I/O bandwidth limit (§7.1: "the I/O bandwidth limits the number of
    // RRAM arrays"): the IR bus can feed at most 8192 wordline bytes per
    // input cycle per PE — the paper's peak sits exactly at this edge
    // (64 arrays x 128 rows).
    if cfg.arrays_per_pe as u64 * cfg.xbar_size as u64 > 8192 {
        return Err(Rejection::IoBandwidth);
    }
    // accuracy limit: beyond 128 rows the per-cell analog swing halves
    // while the NeuralPeriph voltage-noise floor stays fixed, pushing the
    // dataflow SINAD ~6 dB/doubling below the Fig.-10 SINAD_min — the
    // reason §5.1 fixes 128x128 despite 256x256 being fabricable (§2.2).
    if cfg.xbar_size > 128 {
        return Err(Rejection::XbarTooLarge);
    }

    let pe = energy::pe_budget(cfg);
    let gops_per_pe = cfg.peak_gops()
        / (cfg.tiles as f64 * cfg.pes_per_tile as f64);
    Ok((gops_per_pe / pe.area(), gops_per_pe / pe.power()))
}

/// [`evaluate`] with the rejection reason preserved.
pub fn evaluate_checked(cfg: &AcceleratorConfig)
                        -> Result<DsePoint, Rejection> {
    let (ce, ee) = score(cfg)?;
    Ok(DsePoint {
        compute_efficiency: ce,
        energy_efficiency: ee,
        label: label(cfg),
        cfg: cfg.clone(),
    })
}

/// Peak efficiencies assuming full PE utilization (§7.1: "assumes that
/// all PEs can be somehow utilized in every cycle").
pub fn evaluate(cfg: &AcceleratorConfig) -> Option<DsePoint> {
    evaluate_checked(cfg).ok()
}

/// The materialized Fig. 11 grid: N in {32,64,128}, D in {1,2,4}, M in
/// {16..128}, A in {1..8}, S in {1,2}. Construction-filtered: the axes
/// contain no config that `validate` or the xbar accuracy cutoff would
/// reject unconditionally (xbar=256 used to be generated and always
/// discarded), which the tests assert via [`evaluate_checked`].
fn coarse_grid() -> Vec<AcceleratorConfig> {
    let mut grid = Vec::new();
    for &xbar in &[32u32, 64, 128] {
        for &pd in &[1u32, 2, 4] {
            for &m in &[16u32, 32, 64, 96, 128] {
                for &a in &[1u32, 2, 4, 8] {
                    for &s in &[1u32, 2] {
                        let mut cfg = AcceleratorConfig::neural_pim();
                        cfg.xbar_size = xbar;
                        cfg.precision = Precision { p_d: pd, ..Default::default() };
                        cfg.arrays_per_pe = m;
                        cfg.adcs_per_pe = a;
                        cfg.sa_per_array = s;
                        grid.push(cfg);
                    }
                }
            }
        }
    }
    grid
}

/// The Fig. 11 sweep over [`coarse_grid`]; `pool::map` preserves index
/// order, so the feasible-point list is identical at any thread count.
pub fn sweep() -> Vec<DsePoint> {
    pool::map(&coarse_grid(), evaluate).into_iter().flatten().collect()
}

// ----------------------------------------------------- fine-grained DSE --

/// Fine-grid axes (mixed radix, fastest axis last in [`fine_cfg`]):
/// N {32,64,128} x D 1..=8 x M 1..=160 x A 1..=32 x S {1,2,4,8}
/// = 983,040 candidates. Every combination passes `validate` and the
/// xbar accuracy cutoff by construction; ADC/SA/IO feasibility is the
/// sweep's business.
const FINE_XBAR: [u32; 3] = [32, 64, 128];
const FINE_PD: u64 = 8;
const FINE_ARRAYS: u64 = 160;
const FINE_ADCS: u64 = 32;
const FINE_SA: [u32; 4] = [1, 2, 4, 8];

/// Number of candidate configs in the fine grid (~1M).
pub fn fine_grid_len() -> u64 {
    FINE_XBAR.len() as u64
        * FINE_PD
        * FINE_ARRAYS
        * FINE_ADCS
        * FINE_SA.len() as u64
}

/// Decode candidate `i` (row-major over the axes above). The grid is
/// never materialized: batches of indices stream through the pool and
/// each worker decodes its own configs, keeping the sweep's memory flat
/// at any grid size.
pub fn fine_cfg(i: u64) -> AcceleratorConfig {
    debug_assert!(i < fine_grid_len());
    let sa = FINE_SA[(i % FINE_SA.len() as u64) as usize];
    let i = i / FINE_SA.len() as u64;
    let adcs = (i % FINE_ADCS) as u32 + 1;
    let i = i / FINE_ADCS;
    let arrays = (i % FINE_ARRAYS) as u32 + 1;
    let i = i / FINE_ARRAYS;
    let pd = (i % FINE_PD) as u32 + 1;
    let i = i / FINE_PD;
    let xbar = FINE_XBAR[i as usize];
    let mut cfg = AcceleratorConfig::neural_pim();
    cfg.xbar_size = xbar;
    cfg.precision = Precision { p_d: pd, ..Default::default() };
    cfg.arrays_per_pe = arrays;
    cfg.adcs_per_pe = adcs;
    cfg.sa_per_array = sa;
    cfg
}

/// Parameters of the streamed fine sweep. `batch` and the thread count
/// are pure scheduling knobs — every field of the summary except
/// `batches` is invariant to them; `stride > 1` subsamples the grid
/// (index 0, stride, 2*stride, ...) so tests can exercise the full
/// machinery in milliseconds.
#[derive(Debug, Clone)]
pub struct FineSpec {
    /// indices evaluated per pool submission (memory high-water mark)
    pub batch: usize,
    /// grid subsampling step (1 = the full grid)
    pub stride: usize,
    /// feasible points to materialize as labeled [`DsePoint`]s
    pub top: usize,
}

impl Default for FineSpec {
    fn default() -> Self {
        FineSpec { batch: 4096, stride: 1, top: 12 }
    }
}

/// What a fine sweep returns: tallies, the top-K points, and the
/// feasible-list fingerprint (FNV-1a over `(index, eff-bit-patterns)` in
/// index order — byte-identical across thread counts and batch sizes).
#[derive(Debug, Clone)]
pub struct FineSummary {
    /// candidates evaluated (grid length / stride, rounded up)
    pub candidates: u64,
    pub feasible: u64,
    pub rejected_adc: u64,
    pub rejected_sa: u64,
    pub rejected_io: u64,
    /// FNV-1a over every feasible `(index, compute-eff bits,
    /// energy-eff bits)` triple in index order
    pub feasible_fp: u64,
    /// pool submissions issued (the only batch-dependent field)
    pub batches: u64,
    /// best-first by compute efficiency (ties: lower index)
    pub top: Vec<DsePoint>,
}

/// Insert `(idx, ce, ee)` into the running top-K (descending compute
/// efficiency, ties broken toward the lower index so the result is a
/// pure function of the feasible set).
fn push_top(top: &mut Vec<(u64, f64, f64)>, k: usize, cand: (u64, f64, f64)) {
    if k == 0 {
        return;
    }
    let pos = top
        .iter()
        .position(|&(idx, ce, _)| {
            cand.1 > ce || (cand.1 == ce && cand.0 < idx)
        })
        .unwrap_or(top.len());
    if pos < k {
        top.insert(pos, cand);
        top.truncate(k);
    }
}

/// The streamed fine sweep: decode-evaluate batches of indices across
/// the pool, folding tallies, the top-K, and the feasible fingerprint in
/// index order. Memory stays at O(batch) regardless of grid size.
pub fn fine_sweep(spec: &FineSpec) -> FineSummary {
    let stride = spec.stride.max(1) as u64;
    let batch = spec.batch.max(1);
    let len = fine_grid_len();
    let mut s = FineSummary {
        candidates: 0,
        feasible: 0,
        rejected_adc: 0,
        rejected_sa: 0,
        rejected_io: 0,
        feasible_fp: FNV1A64_OFFSET,
        batches: 0,
        top: Vec::new(),
    };
    let mut top: Vec<(u64, f64, f64)> = Vec::new();
    let mut idx: Vec<u64> = Vec::with_capacity(batch);
    let mut next = 0u64;
    while next < len {
        idx.clear();
        while next < len && idx.len() < batch {
            idx.push(next);
            next += stride;
        }
        let scored = pool::map(&idx, |&i| score(&fine_cfg(i)));
        s.batches += 1;
        s.candidates += idx.len() as u64;
        for (&i, r) in idx.iter().zip(&scored) {
            match r {
                Ok((ce, ee)) => {
                    s.feasible += 1;
                    let mut h = s.feasible_fp;
                    for b in i
                        .to_le_bytes()
                        .into_iter()
                        .chain(ce.to_bits().to_le_bytes())
                        .chain(ee.to_bits().to_le_bytes())
                    {
                        h = fnv1a64_step(h, b);
                    }
                    s.feasible_fp = h;
                    push_top(&mut top, spec.top, (i, *ce, *ee));
                }
                Err(Rejection::AdcStarved) => s.rejected_adc += 1,
                Err(Rejection::SaStarved) => s.rejected_sa += 1,
                Err(Rejection::IoBandwidth) => s.rejected_io += 1,
                // construction invariant (tested): the fine grid holds
                // no unconditionally-dead candidate
                Err(r) => unreachable!(
                    "fine grid emitted a dead point {i}: {r:?}"
                ),
            }
        }
    }
    s.top = top
        .into_iter()
        .map(|(i, _, _)| {
            evaluate(&fine_cfg(i)).expect("top point must re-evaluate")
        })
        .collect();
    s
}

/// Best point among already-computed sweep results (callers that also
/// render the Fig. 11 table share one sweep instead of re-running it).
pub fn best_of(points: &[DsePoint]) -> &DsePoint {
    points
        .iter()
        .max_by(|a, b| {
            a.compute_efficiency
                .partial_cmp(&b.compute_efficiency)
                .unwrap()
        })
        .expect("sweep produced no feasible points")
}

/// Best point of the sweep (the paper's N128-D4-A4-S64 M64 at
/// 1904 GOPS/s/mm²).
pub fn best() -> DsePoint {
    best_of(&sweep()).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_nonempty_and_finite() {
        let pts = sweep();
        assert!(pts.len() > 50, "only {} points", pts.len());
        for p in &pts {
            assert!(p.compute_efficiency.is_finite()
                && p.compute_efficiency > 0.0);
        }
    }

    #[test]
    fn every_coarse_point_reaches_the_cost_model_stage() {
        // the grid must carry no unconditionally-dead candidate: any
        // rejection has to be a cost-model feasibility verdict, never
        // validate() or the config-only accuracy cutoff
        let grid = coarse_grid();
        assert_eq!(grid.len(), 3 * 3 * 5 * 4 * 2);
        for cfg in &grid {
            match evaluate_checked(cfg) {
                Ok(_)
                | Err(Rejection::AdcStarved)
                | Err(Rejection::SaStarved)
                | Err(Rejection::IoBandwidth) => {}
                Err(r) => panic!("dead grid point {}: {r:?}", label(cfg)),
            }
        }
    }

    #[test]
    fn paper_optimum_is_competitive() {
        // the paper's chosen config should be within 25% of our sweep's
        // best compute efficiency (Fig. 11's peak)
        let paper = evaluate(&AcceleratorConfig::neural_pim()).unwrap();
        let best = best();
        assert!(
            paper.compute_efficiency >= 0.5 * best.compute_efficiency,
            "paper {} vs best {} ({})",
            paper.compute_efficiency,
            best.compute_efficiency,
            best.label
        );
    }

    #[test]
    fn bigger_arrays_help_until_periphery_dominates() {
        // Fig. 11's first-order trend: 128 beats 32 at fixed D/M/A
        let eff = |xbar: u32| {
            let mut cfg = AcceleratorConfig::neural_pim();
            cfg.xbar_size = xbar;
            evaluate(&cfg).map(|p| p.compute_efficiency)
        };
        let e32 = eff(32).unwrap();
        let e128 = eff(128).unwrap();
        assert!(e128 > e32, "128: {e128}, 32: {e32}");
    }

    #[test]
    fn starved_adc_config_rejected() {
        let mut cfg = AcceleratorConfig::neural_pim();
        cfg.adcs_per_pe = 1;
        cfg.arrays_per_pe = 128;
        cfg.precision.p_d = 8; // one-cycle inputs: 1024 groups / period
        // 1 NNADC at 1.2 GS/s in a 100 ns period = 120 slots < 1024 groups
        assert_eq!(evaluate_checked(&cfg).unwrap_err(),
                   Rejection::AdcStarved);
    }

    #[test]
    fn rejection_reasons_name_the_failing_guard() {
        let mut cfg = AcceleratorConfig::neural_pim();
        cfg.xbar_size = 33; // not a power of two
        assert_eq!(evaluate_checked(&cfg).unwrap_err(), Rejection::Invalid);
        let mut cfg = AcceleratorConfig::neural_pim();
        cfg.xbar_size = 256; // doubles groups_per_array to 16...
        cfg.sa_per_array = 2; // ...so 2 NNS+As keep the SA guard happy
        cfg.arrays_per_pe = 16; // under the IO limit, over the accuracy one
        assert_eq!(evaluate_checked(&cfg).unwrap_err(),
                   Rejection::XbarTooLarge);
        let mut cfg = AcceleratorConfig::neural_pim();
        cfg.arrays_per_pe = 128; // 128 * 128 rows > 8192 wordline bytes
        cfg.adcs_per_pe = 32;
        assert_eq!(evaluate_checked(&cfg).unwrap_err(),
                   Rejection::IoBandwidth);
    }

    #[test]
    fn fine_grid_decodes_to_valid_candidates() {
        let len = fine_grid_len();
        assert_eq!(len, 983_040);
        // distinct indices decode to distinct configs at the corners
        // and a pseudo-random sample never yields a dead point
        assert_ne!(fine_cfg(0), fine_cfg(len - 1));
        for i in (0..len).step_by(9973) {
            let cfg = fine_cfg(i);
            cfg.validate()
                .unwrap_or_else(|e| panic!("index {i} invalid: {e}"));
            assert!(cfg.xbar_size <= 128, "index {i} past accuracy cutoff");
        }
    }

    #[test]
    fn fine_cfg_is_a_bijection_on_a_sample() {
        // re-encode by scanning the axes: every sampled config must
        // round-trip through its own index (guards radix-order bugs)
        for i in (0..fine_grid_len()).step_by(12_007) {
            let cfg = fine_cfg(i);
            let sa_i = FINE_SA.iter().position(|&s| s == cfg.sa_per_array)
                .unwrap() as u64;
            let xbar_i = FINE_XBAR.iter().position(|&x| x == cfg.xbar_size)
                .unwrap() as u64;
            let enc = (((xbar_i * FINE_PD + (cfg.precision.p_d as u64 - 1))
                * FINE_ARRAYS
                + (cfg.arrays_per_pe as u64 - 1))
                * FINE_ADCS
                + (cfg.adcs_per_pe as u64 - 1))
                * FINE_SA.len() as u64
                + sa_i;
            assert_eq!(enc, i);
        }
    }

    #[test]
    fn fine_sweep_summary_is_batch_invariant() {
        // batch size (and the thread count, covered by the integration
        // suite) is a scheduling knob: every summary field except
        // `batches` must be identical
        let spec = FineSpec { stride: 1009, batch: 64, top: 5 };
        let a = fine_sweep(&spec);
        let b = fine_sweep(&FineSpec { batch: 251, ..spec.clone() });
        assert_eq!(a.candidates, b.candidates);
        assert_eq!(a.feasible, b.feasible);
        assert_eq!(a.feasible_fp, b.feasible_fp);
        assert_eq!(a.rejected_adc, b.rejected_adc);
        assert_eq!(a.rejected_sa, b.rejected_sa);
        assert_eq!(a.rejected_io, b.rejected_io);
        assert!(a.batches > b.batches);
        assert_eq!(a.top.len(), b.top.len());
        for (x, y) in a.top.iter().zip(&b.top) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.compute_efficiency.to_bits(),
                       y.compute_efficiency.to_bits());
        }
        // tallies cover every candidate
        assert_eq!(
            a.feasible + a.rejected_adc + a.rejected_sa + a.rejected_io,
            a.candidates
        );
        assert!(a.feasible > 0, "sampled grid found no feasible point");
    }

    #[test]
    fn fine_sweep_top_is_sorted_and_labeled() {
        let s = fine_sweep(&FineSpec { stride: 2003, batch: 512, top: 8 });
        assert!(!s.top.is_empty());
        for w in s.top.windows(2) {
            assert!(w[0].compute_efficiency >= w[1].compute_efficiency);
        }
        for p in &s.top {
            assert!(p.label.starts_with('N'), "unlabeled point {:?}", p.label);
        }
    }
}
