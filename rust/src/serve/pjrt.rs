//! The PJRT backend: the compiled-artifact execution path, behind the
//! [`InferenceBackend`] trait.
//!
//! PJRT objects are thread-local (`Rc` + raw pointers inside the xla
//! crate), so every worker owns its *own* client + executable, built by
//! [`PjrtBackend::worker`] on the worker thread; only plain `Vec<f32>`
//! data crosses threads.
//!
//! [`open_runtime`] is the one sanctioned PJRT construction site outside
//! `runtime/` itself — `scripts/verify.sh` grep-bans direct
//! `Runtime::new` calls elsewhere so no layer quietly re-welds itself to
//! the XLA artifacts (the open-closed discipline the backend trait
//! exists to enforce).

use super::{BackendWorker, BatchInput, BatchResult, InferenceBackend};
use crate::runtime::{self, Executable, Runtime};
use anyhow::Result;
use std::rc::Rc;
use std::time::Instant;

/// Open the PJRT runtime over an artifact directory. All non-`serve`
/// code (scenarios, benches, examples) goes through here.
pub fn open_runtime(artifact_dir: &str) -> Result<Runtime> {
    Runtime::new(artifact_dir)
}

/// Thread-safe description of a non-image executable input; each worker
/// materializes the literal locally.
#[derive(Debug, Clone)]
pub enum ExtraInput {
    ScalarF32(f32),
    KeyU32(u64),
}

impl ExtraInput {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            ExtraInput::ScalarF32(v) => Ok(runtime::lit_scalar_f32(*v)),
            ExtraInput::KeyU32(seed) => runtime::lit_key(*seed),
        }
    }
}

/// Exact integer side length of a square HWC image with 3 channels.
/// Float sqrt alone can truncate (e.g. yield 223 for a 224x224 image), so
/// round then verify, and reject non-square inputs with a clear error.
fn image_side(image_len: usize) -> Result<i64> {
    anyhow::ensure!(
        image_len > 0 && image_len % 3 == 0,
        "image length {image_len} is not HWC with 3 channels"
    );
    let pixels = (image_len / 3) as u64;
    let mut s = (pixels as f64).sqrt().round() as u64;
    while s > 0 && s * s > pixels {
        s -= 1;
    }
    while (s + 1) * (s + 1) <= pixels {
        s += 1;
    }
    anyhow::ensure!(
        s * s == pixels,
        "non-square image: {image_len} values = {pixels} pixels/channel"
    );
    Ok(s as i64)
}

/// The compiled-artifact backend (shared across worker threads; each
/// thread compiles its own executable in [`PjrtBackend::worker`]).
#[derive(Debug, Clone)]
pub struct PjrtBackend {
    pub artifact_dir: String,
    pub artifact: String,
    pub batch: usize,
    pub classes: usize,
    pub image_len: usize,
    /// extra inputs appended after (or before) the image batch
    pub extra_inputs: Vec<ExtraInput>,
    /// true: images are the first executable parameter
    pub image_param_first: bool,
}

impl PjrtBackend {
    /// The standard CNN-serving shape: batch 128, 10 classes, images
    /// first, no extra inputs.
    pub fn new(artifact_dir: impl Into<String>, artifact: impl Into<String>,
               image_len: usize) -> PjrtBackend {
        PjrtBackend {
            artifact_dir: artifact_dir.into(),
            artifact: artifact.into(),
            batch: 128,
            classes: 10,
            image_len,
            extra_inputs: Vec::new(),
            image_param_first: true,
        }
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn worker(&self) -> Result<Box<dyn BackendWorker>> {
        // built on the calling (worker) thread: Runtime is not Send
        let rt = open_runtime(&self.artifact_dir)?;
        let exe = rt.load(&self.artifact)?;
        let extra: Vec<xla::Literal> = self
            .extra_inputs
            .iter()
            .map(|e| e.to_literal())
            .collect::<Result<_>>()?;
        let side = image_side(self.image_len)?;
        Ok(Box::new(PjrtWorker {
            exe,
            extra,
            _rt: rt,
            side,
            batch: self.batch,
            image_param_first: self.image_param_first,
        }))
    }
}

/// One worker thread's PJRT state (non-`Send` by design).
struct PjrtWorker {
    exe: Rc<Executable>,
    extra: Vec<xla::Literal>,
    /// keeps the client alive for as long as the executable
    _rt: Runtime,
    side: i64,
    batch: usize,
    image_param_first: bool,
}

impl BackendWorker for PjrtWorker {
    fn execute(&mut self, input: &BatchInput) -> Result<BatchResult> {
        // exec_us covers the whole batch execution a caller waits on —
        // literal assembly, the PJRT run, and logits readback — so
        // queue_us (ends at exec start) + exec_us spans the full
        // enqueued -> response window with nothing attributed to neither
        let t0 = Instant::now();
        let images = runtime::lit_f32(
            input.data,
            &[self.batch as i64, self.side, self.side, 3],
        )?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        if self.image_param_first {
            inputs.push(&images);
            inputs.extend(self.extra.iter());
        } else {
            inputs.extend(self.extra.iter());
            inputs.push(&images);
        }
        let out = self.exe.run_refs(&inputs)?;
        let logits = runtime::to_f32_vec(&out[0])?;
        let exec_us = t0.elapsed().as_micros() as u64;
        Ok(BatchResult { logits, exec_us })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_side_is_exact() {
        // the float-truncation regression: 224*224*3 must give 224
        for side in [1u64, 3, 28, 32, 223, 224, 225, 1024] {
            let len = (side * side * 3) as usize;
            assert_eq!(image_side(len).unwrap(), side as i64, "side {side}");
        }
    }

    #[test]
    fn image_side_rejects_bad_shapes() {
        assert!(image_side(0).is_err());
        assert!(image_side(4).is_err()); // not divisible by 3
        assert!(image_side(3 * 5).is_err()); // 5 pixels: not square
        assert!(image_side((224 * 224 - 1) * 3).is_err());
    }

    #[test]
    fn extra_input_literals() {
        let k = ExtraInput::KeyU32(7).to_literal().unwrap();
        assert_eq!(k.element_count(), 2);
        let s = ExtraInput::ScalarF32(255.0).to_literal().unwrap();
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn backend_declares_its_shape() {
        let b = PjrtBackend::new("artifacts", "cnn_ideal", 32 * 32 * 3);
        assert_eq!(b.name(), "pjrt");
        assert_eq!(b.batch(), 128);
        assert_eq!(b.classes(), 10);
        assert_eq!(b.image_len(), 3072);
    }
}
