//! The simulated backend: serving with **zero artifacts**.
//!
//! Mirrors RAPIDNN's decoupling of the neural workload from the
//! substrate executing it: the serving path talks to the
//! [`InferenceBackend`] trait, and this implementation stands in for the
//! analog chip by *pricing* each batch instead of executing it —
//! per-batch latency comes from the event pipeline's service-time model
//! ([`event::service_profile`]) over the memoized
//! [`model::network_cost`] table, and logits are a deterministic hash of
//! each image's content. Every quantity it reports is simulated chip
//! time, so serving scenarios (CI, the suite runner, `serve-sim`) run
//! end-to-end — batching, padding, admission control, metrics — with no
//! XLA artifacts present and reproduce bit-identically.

use super::{BackendWorker, BatchInput, BatchResult, InferenceBackend};
use crate::config::AcceleratorConfig;
use crate::util::num::{fnv1a64_step, FNV1A64_OFFSET};
use crate::util::rng::Pcg;
use crate::workloads::Network;
use crate::{event, model};
use anyhow::Result;

/// FNV-1a (the store's canonical hash, streamed via
/// `util::num::fnv1a64_step`) over an image's raw f32 bits, with the
/// backend seed mixed into the offset basis — the deterministic
/// identity a simulated inference answers for.
fn image_hash(img: &[f32], seed: u64) -> u64 {
    let mut h = FNV1A64_OFFSET ^ seed;
    for v in img {
        for b in v.to_bits().to_le_bytes() {
            h = fnv1a64_step(h, b);
        }
    }
    h
}

/// The simulated chip backend (shared across worker threads; workers
/// are stateless copies of the priced shape).
#[derive(Debug, Clone)]
pub struct SimBackend {
    network: String,
    batch: usize,
    classes: usize,
    image_len: usize,
    seed: u64,
    /// simulated execution time of one (padded) batch, µs
    exec_us: u64,
}

impl SimBackend {
    /// Price a serving backend for `net` on `cfg`: the executable batch
    /// costs `fill + (batch-1) x bottleneck` of simulated chip time
    /// (padding executes like the PJRT path — the full batch runs
    /// regardless of fill). Classes come from the network's final layer.
    pub fn new(net: &Network, cfg: &AcceleratorConfig, batch: usize,
               image_len: usize, seed: u64) -> SimBackend {
        let nc = model::network_cost(net, cfg);
        let sp = event::service_profile(cfg, &nc);
        let classes = net
            .layers
            .last()
            .expect("network has no layers")
            .cout as usize;
        SimBackend {
            network: net.name.to_string(),
            batch: batch.max(1),
            classes,
            image_len,
            seed,
            exec_us: sp.batch_us(batch.max(1) as u64),
        }
    }

    /// The priced per-batch execution time, µs (simulated).
    pub fn exec_us(&self) -> u64 {
        self.exec_us
    }

    /// The network this backend simulates.
    pub fn network(&self) -> &str {
        &self.network
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn worker(&self) -> Result<Box<dyn BackendWorker>> {
        Ok(Box::new(SimWorker {
            classes: self.classes,
            seed: self.seed,
            exec_us: self.exec_us,
        }))
    }
}

struct SimWorker {
    classes: usize,
    seed: u64,
    exec_us: u64,
}

impl BackendWorker for SimWorker {
    fn execute(&mut self, input: &BatchInput) -> Result<BatchResult> {
        let slots = input.data.len() / input.image_len;
        let mut logits = Vec::with_capacity(slots * self.classes);
        for img in input.data.chunks_exact(input.image_len) {
            let mut rng = Pcg::new(image_hash(img, self.seed));
            for _ in 0..self.classes {
                logits.push(rng.uniform() as f32);
            }
        }
        Ok(BatchResult { logits, exec_us: self.exec_us })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn backend(batch: usize) -> SimBackend {
        SimBackend::new(
            &workloads::synthetic_cnn(),
            &AcceleratorConfig::neural_pim(),
            batch,
            12,
            42,
        )
    }

    #[test]
    fn declares_the_networks_shape() {
        let b = backend(64);
        assert_eq!(b.name(), "sim");
        assert_eq!(b.batch(), 64);
        // SyntheticCNN ends in fc -> 10
        assert_eq!(b.classes(), 10);
        assert_eq!(b.image_len(), 12);
        assert!(b.exec_us() >= 1);
    }

    #[test]
    fn batch_time_grows_with_batch_and_is_deterministic() {
        assert!(backend(128).exec_us() > backend(1).exec_us());
        assert_eq!(backend(64).exec_us(), backend(64).exec_us());
    }

    #[test]
    fn logits_are_a_deterministic_function_of_image_and_seed() {
        let b = backend(2);
        let mut w = b.worker().unwrap();
        let data: Vec<f32> = (0..24).map(|i| (i % 7) as f32).collect();
        let a1 = w.execute(&BatchInput { data: &data, n: 2, image_len: 12 })
            .unwrap();
        let a2 = w.execute(&BatchInput { data: &data, n: 2, image_len: 12 })
            .unwrap();
        assert_eq!(a1.logits, a2.logits);
        assert_eq!(a1.logits.len(), 2 * 10);
        assert_eq!(a1.exec_us, b.exec_us());
        // a different image produces different logits...
        let mut other = data.clone();
        other[0] += 1.0;
        let a3 = w.execute(&BatchInput { data: &other, n: 2, image_len: 12 })
            .unwrap();
        assert_ne!(a1.logits[..10], a3.logits[..10]);
        // ...and so does a different backend seed
        let b2 = SimBackend::new(
            &workloads::synthetic_cnn(),
            &AcceleratorConfig::neural_pim(),
            2,
            12,
            43,
        );
        let a4 = b2.worker().unwrap()
            .execute(&BatchInput { data: &data, n: 2, image_len: 12 })
            .unwrap();
        assert_ne!(a1.logits, a4.logits);
    }
}
