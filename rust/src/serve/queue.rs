//! Multi-consumer request queue: `Mutex<VecDeque>` + `Condvar`, the
//! replacement for the old `Mutex<mpsc::Receiver>` hand-off that
//! serialized every worker on one batch collection (the lock used to be
//! held across a blocking `recv()` *and* the whole `max_wait` fill
//! window; here the lock is released whenever a consumer waits).
//!
//! Fairness rule: consumers waiting for their *first* item (idle workers)
//! have priority over consumers filling a partial batch — a filling
//! worker only absorbs *surplus* items beyond what the idle waiters will
//! take. Under load batches fill instantly; under light load arrivals
//! start new batches on idle workers instead of queueing behind one
//! worker's fill window, which is what lets N workers collect and execute
//! concurrently.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

pub struct SharedQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    /// consumers currently blocked in [`SharedQueue::pop_wait`]
    idle_waiters: usize,
}

/// Outcome of a fill-window pop.
pub enum FillPop<T> {
    Item(T),
    TimedOut,
    Closed,
}

impl<T> Default for SharedQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> SharedQueue<T> {
    pub fn new() -> Self {
        SharedQueue {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
                idle_waiters: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue an item; `Err(item)` once the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(item);
        }
        g.q.push_back(item);
        drop(g);
        // notify_all: a notify_one could land on a filling worker that the
        // fairness rule forbids from taking the item
        self.cv.notify_all();
        Ok(())
    }

    /// Close the queue: producers fail, consumers drain what is left.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Consumers currently blocked waiting for a first item (exposed for
    /// the multi-worker progress tests and metrics).
    pub fn idle_waiters(&self) -> usize {
        self.inner.lock().unwrap().idle_waiters
    }

    /// Block until an item is available (a batch's first request) or the
    /// queue is closed and drained (`None` = shutdown).
    pub fn pop_wait(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g.idle_waiters += 1;
            g = self.cv.wait(g).unwrap();
            g.idle_waiters -= 1;
        }
    }

    /// Pop an item for a partial batch, waiting until `deadline`. Only
    /// takes *surplus* items (beyond the idle waiters' claim — see the
    /// module fairness rule). `Closed` means the batch should be flushed
    /// as-is.
    pub fn pop_surplus_until(&self, deadline: Instant) -> FillPop<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.q.len() > g.idle_waiters {
                return FillPop::Item(g.q.pop_front().unwrap());
            }
            if g.closed {
                return FillPop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return FillPop::TimedOut;
            }
            let (g2, _timeout) =
                self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn push_pop_fifo() {
        let q = SharedQueue::new();
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop_wait(), Some(i));
        }
    }

    #[test]
    fn close_rejects_push_and_drains() {
        let q = SharedQueue::new();
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(2));
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), None);
    }

    #[test]
    fn pop_wait_unblocks_on_close() {
        let q = Arc::new(SharedQueue::<u32>::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_wait());
        while q.idle_waiters() == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn surplus_pop_respects_idle_waiters() {
        let q = Arc::new(SharedQueue::<u32>::new());
        let q2 = q.clone();
        // one idle consumer waiting for its first item
        let h = std::thread::spawn(move || q2.pop_wait());
        while q.idle_waiters() == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // a single queued item is reserved for the idle waiter
        q.push(7).unwrap();
        // the idle waiter must get it (a filler would see no surplus);
        // wait for the hand-off to complete
        assert_eq!(h.join().unwrap(), Some(7));
        // with no idle waiters, a filler takes items immediately
        q.push(8).unwrap();
        match q.pop_surplus_until(Instant::now()) {
            FillPop::Item(v) => assert_eq!(v, 8),
            _ => panic!("expected surplus item"),
        }
        // empty queue + passed deadline -> timeout
        match q.pop_surplus_until(Instant::now()) {
            FillPop::TimedOut => {}
            _ => panic!("expected timeout"),
        }
    }
}
