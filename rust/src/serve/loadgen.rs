//! Virtual-time load generator: the serving layer's admission queue +
//! dynamic batcher + batch engine, simulated deterministically.
//!
//! `serve-sim` must be bit-identical at any `--threads` count and replay
//! byte-identically from the results store, which rules out driving real
//! worker threads against the wall clock. Instead the generator replays
//! the serving discipline in integer virtual microseconds: Poisson
//! arrivals at the offered load, a bounded admission queue that sheds
//! (the [`super::ServeOptions::max_queue_depth`] semantics), batch
//! collection with the [`super::BatchPolicy`] fill window, and a padded
//! batch execution time priced by the same
//! [`event::service_profile`](crate::event::service_profile) model the
//! [`super::SimBackend`] reports through. One simplification vs the live
//! queue: arrivals inside an open fill window stream into that batch
//! rather than starting a second one on an idle worker — under load the
//! two disciplines coincide (batches fill instantly), and under light
//! load the delta is bounded by one fill window.
//!
//! Load points are independent — each runs on its own `Pcg::fork` stream
//! derived sequentially up front — so [`sweep`] fans them out over
//! `util::pool` with bit-identical results at any thread count (the same
//! contract as `sim`/`dse`/`noise`/`event`). A load point can further
//! split into [`LoadGenConfig::shards`] independent fleet slices (each
//! with its own worker pool and arrival stream at the same offered
//! utilization), so one point's simulation can occupy several pool
//! workers; totals sum and percentiles pool across slices, reassembled
//! in shard order — still bit-identical at any thread count.

use crate::obs::{Hist, NullRecorder, Recorder, Registry, TraceRecorder};
use crate::util::pool;
use crate::util::rng::{self, Pcg};
use crate::util::stats;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::fmt;

/// Trace timestamps are virtual picoseconds everywhere in the crate;
/// the load generator's clock is virtual microseconds.
const US_TO_PS: u64 = 1_000_000;

/// The serving shape one sweep simulates (shared by every load point).
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// arrivals offered per load point
    pub requests: u64,
    pub workers: usize,
    /// executable batch (padded; the batcher's fill cap)
    pub max_batch: usize,
    /// fill window after a batch's first request, virtual µs
    pub max_wait_us: u64,
    /// admission bound: an arrival finding this many pending is shed
    pub max_queue_depth: usize,
    /// simulated execution time of one padded batch, µs
    /// (`ServiceProfile::batch_us(max_batch)`)
    pub batch_exec_us: u64,
    pub seed: u64,
    /// independent fleet slices per load point (min 1): each slice gets
    /// `workers` workers and an equal share of `requests` (the first
    /// `requests % shards` slices take one extra) on its own fork
    /// stream (fork index = `point * shards + shard`). `shards = 1`
    /// reproduces the unsharded sweep exactly; higher counts are a new
    /// experiment (per-slice arrival streams), deterministic at any
    /// thread count.
    pub shards: usize,
}

impl Default for LoadGenConfig {
    /// Mirrors the `serve-sim` scenario's defaults.
    fn default() -> Self {
        LoadGenConfig {
            requests: 2_048,
            workers: 2,
            max_batch: 64,
            max_wait_us: 200,
            max_queue_depth: 256,
            batch_exec_us: 1_000,
            seed: 42,
            shards: 1,
        }
    }
}

/// A sweep input the generator refuses to simulate. Offered loads are
/// fractions of the service rate; a non-finite or non-positive value
/// used to be silently clamped to `1e-3` deep in the shard runner,
/// which turned caller bugs (NaN from a bad division, a negated load)
/// into a plausible-looking near-idle load point.
#[derive(Debug, Clone, PartialEq)]
pub enum LoadGenError {
    /// `loads[index]` is NaN, infinite, or `<= 0`.
    BadOffered { index: usize, value: f64 },
}

impl fmt::Display for LoadGenError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LoadGenError::BadOffered { index, value } => write!(
                f,
                "offered load [{index}] = {value} is not a positive finite \
                 fraction of the service rate"
            ),
        }
    }
}

impl std::error::Error for LoadGenError {}

/// One offered-load point of the sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadPoint {
    /// offered load as a fraction of the padded-batch service rate
    pub offered: f64,
    pub served: u64,
    pub shed: u64,
    pub shed_rate: f64,
    pub batches: u64,
    pub avg_batch: f64,
    /// served throughput over the virtual makespan
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// nearest-rank tail percentile (`stats::tail_percentile`); `None`
    /// below the 1000-sample guard rather than the max dressed up as a
    /// tail
    pub p999_ms: Option<f64>,
    /// observability tallies for this point, merged in shard order
    /// (admission counts, peak pending depth, sojourn histogram)
    pub registry: Registry,
}

/// Validate a sweep's offered loads up front — the typed rejection that
/// replaced the silent `max(1e-3)` clamp in the shard runner.
fn validate_loads(loads: &[f64]) -> Result<(), LoadGenError> {
    for (index, &value) in loads.iter().enumerate() {
        if !value.is_finite() || value <= 0.0 {
            return Err(LoadGenError::BadOffered { index, value });
        }
    }
    Ok(())
}

/// Run every (offered-load point, shard) across the worker pool;
/// bit-identical at any thread count (`Pcg::fork` streams derived
/// sequentially up front, results reassembled by index, shard partials
/// merged in shard order).
pub fn sweep(cfg: &LoadGenConfig, loads: &[f64])
             -> Result<Vec<LoadPoint>, LoadGenError> {
    validate_loads(loads)?;
    let shards = cfg.shards.max(1);
    let inputs = sweep_inputs(cfg, loads);
    let runs = pool::map(&inputs, |(l, jobs, rng)| {
        run_shard(cfg, *l, *jobs, rng.clone(), &mut NullRecorder)
    });
    Ok(runs
        .chunks(shards)
        .zip(loads)
        .map(|(chunk, &l)| merge(l, chunk))
        .collect())
}

/// [`sweep`] with a live [`TraceRecorder`] per (load point, shard):
/// admission/shed instants, batch fill/exec spans and queue-depth
/// samples stamped in virtual picoseconds (µs x 10⁶), absorbed in
/// input order under `load{offered}/s{shard}/` prefixes. Load-point
/// numbers are bit-identical to the untraced sweep: the recorder only
/// observes the replay, it never steers it.
pub fn sweep_traced(cfg: &LoadGenConfig, loads: &[f64],
                    filter: Option<&str>)
                    -> Result<(Vec<LoadPoint>, TraceRecorder), LoadGenError> {
    validate_loads(loads)?;
    let shards = cfg.shards.max(1);
    let inputs = sweep_inputs(cfg, loads);
    let traced = pool::map(&inputs, |(l, jobs, rng)| {
        let mut rec = TraceRecorder::with_filter(filter);
        let run = run_shard(cfg, *l, *jobs, rng.clone(), &mut rec);
        (run, rec)
    });
    let mut combined = TraceRecorder::new();
    let mut runs = Vec::with_capacity(traced.len());
    for (idx, (run, rec)) in traced.into_iter().enumerate() {
        let (l, _, _) = &inputs[idx];
        combined.absorb(&format!("load{l:.2}/s{}/", idx % shards), rec);
        runs.push(run);
    }
    let pts = runs
        .chunks(shards)
        .zip(loads)
        .map(|(chunk, &l)| merge(l, chunk))
        .collect();
    Ok((pts, combined))
}

/// The (offered load, job count, fork stream) grid both sweep variants
/// run: streams forked sequentially up front in the loadgen namespace
/// (fork index = `FORK_NS_LOADGEN | (point * shards + shard)` — see
/// `util::rng` for the cross-subsystem disjointness contract), job
/// counts splitting `requests` exactly.
fn sweep_inputs(cfg: &LoadGenConfig, loads: &[f64]) -> Vec<(f64, u64, Pcg)> {
    let shards = cfg.shards.max(1);
    let base = cfg.requests / shards as u64;
    let extra = cfg.requests % shards as u64;
    let mut root = Pcg::new(cfg.seed);
    let mut inputs: Vec<(f64, u64, Pcg)> =
        Vec::with_capacity(loads.len() * shards);
    for (i, &l) in loads.iter().enumerate() {
        for s in 0..shards as u64 {
            let local = i as u64 * shards as u64 + s;
            inputs.push((
                l,
                base + u64::from(s < extra),
                root.fork(rng::fork_idx(rng::FORK_NS_LOADGEN, local)),
            ));
        }
    }
    inputs
}

/// One fleet slice of one load point: `jobs` Poisson arrivals at the
/// offered utilization, replayed through the serving discipline.
fn run_shard<R: Recorder>(cfg: &LoadGenConfig, offered: f64, jobs: u64,
                          mut rng: Pcg, rec: &mut R) -> ShardRun {
    // `offered` is validated positive and finite at sweep entry
    // (`validate_loads`) — no silent clamp here
    debug_assert!(offered.is_finite() && offered > 0.0);
    // padded-batch service rate across all workers, requests per µs
    let rate_per_us = cfg.workers.max(1) as f64 * cfg.max_batch.max(1) as f64
        / cfg.batch_exec_us.max(1) as f64;
    let mean_gap_us = 1.0 / (offered * rate_per_us);
    let mut arrivals = Vec::with_capacity(jobs as usize);
    let mut t = 0u64;
    for _ in 0..jobs {
        let u = rng.uniform();
        let gap = (-mean_gap_us * (1.0 - u).max(f64::MIN_POSITIVE).ln())
            .round() as u64;
        t += gap;
        arrivals.push(t);
    }
    simulate(cfg, &arrivals, rec)
}

/// One shard's raw tallies, before cross-shard aggregation.
struct ShardRun {
    served: u64,
    shed: u64,
    batches: u64,
    makespan_us: u64,
    lat_ms: Vec<f64>,
    /// high-water mark of the pending admission queue
    peak_pending: u64,
    /// per-request sojourn times in µs (log2 buckets)
    sojourn_us: Hist,
}

/// Aggregate shard partials into the published load point: counts sum,
/// the makespan is the slowest slice (slices run concurrently), and
/// latency samples pool in shard order (percentiles over the union).
/// With one shard this reproduces the unsharded numbers exactly.
fn merge(offered: f64, runs: &[ShardRun]) -> LoadPoint {
    let served: u64 = runs.iter().map(|r| r.served).sum();
    let shed: u64 = runs.iter().map(|r| r.shed).sum();
    let batches: u64 = runs.iter().map(|r| r.batches).sum();
    let makespan = runs.iter().map(|r| r.makespan_us).max().unwrap_or(0);
    let mut lat_ms: Vec<f64> = runs
        .iter()
        .flat_map(|r| r.lat_ms.iter().copied())
        .collect();
    // one sort for every percentile read below (incl. the tail)
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut registry = Registry::new();
    registry.add("serve.served", served);
    registry.add("serve.shed", shed);
    registry.add("serve.batches", batches);
    let mut sojourn = Hist::new();
    for r in runs {
        registry.gauge_max("serve.peak_pending", r.peak_pending);
        sojourn.merge(&r.sojourn_us);
    }
    registry.merge_hist("serve.sojourn_us", &sojourn);
    LoadPoint {
        offered,
        served,
        shed,
        shed_rate: shed as f64 / (served + shed).max(1) as f64,
        batches,
        avg_batch: served as f64 / batches.max(1) as f64,
        throughput_rps: served as f64 / (makespan.max(1) as f64 * 1e-6),
        mean_ms: stats::mean(&lat_ms),
        p50_ms: stats::percentile_sorted(&lat_ms, 50.0),
        p95_ms: stats::percentile_sorted(&lat_ms, 95.0),
        p99_ms: stats::percentile_sorted(&lat_ms, 99.0),
        p999_ms: stats::tail_percentile_sorted(&lat_ms, 99.9),
        registry,
    }
}

/// Replay the serving discipline over pre-generated arrivals. The
/// recorder sees admission decisions as instants (`serve.admit` /
/// `serve.shed`), each batch as a fill span + exec span, and the
/// pending-queue depth as a counter sampled at every batch open — all
/// stamped in virtual picoseconds.
fn simulate<R: Recorder>(cfg: &LoadGenConfig, arrivals: &[u64],
                         rec: &mut R) -> ShardRun {
    let max_batch = cfg.max_batch.max(1);
    let depth = cfg.max_queue_depth.max(1);
    let mut free: BinaryHeap<Reverse<u64>> =
        (0..cfg.workers.max(1)).map(|_| Reverse(0u64)).collect();
    let mut pending: VecDeque<u64> = VecDeque::new();
    let mut i = 0usize;
    let mut shed = 0u64;
    let mut batches = 0u64;
    let mut served = 0u64;
    let mut makespan = 0u64;
    let mut peak_pending = 0u64;
    let mut sojourn_us = Hist::new();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(arrivals.len());
    loop {
        if pending.is_empty() {
            // the next chronological event is an arrival; an empty queue
            // always admits (every bound is >= 1)
            let Some(&a) = arrivals.get(i) else { break };
            pending.push_back(a);
            rec.instant(a * US_TO_PS, "serve", "serve.admit");
            i += 1;
            continue;
        }
        // the earliest-free worker opens a batch on the oldest pending
        let Reverse(f) = free.pop().expect("worker heap never empties");
        let start = f.max(*pending.front().expect("pending non-empty"));
        // arrivals up to the collection start join the queue one at a
        // time against the admission bound
        while i < arrivals.len() && arrivals[i] <= start {
            if pending.len() >= depth {
                shed += 1;
                rec.instant(arrivals[i] * US_TO_PS, "serve", "serve.shed");
            } else {
                pending.push_back(arrivals[i]);
                rec.instant(arrivals[i] * US_TO_PS, "serve", "serve.admit");
            }
            i += 1;
        }
        peak_pending = peak_pending.max(pending.len() as u64);
        rec.sample(start * US_TO_PS, "serve.queue_depth",
                   pending.len() as f64);
        // backlog fills first (FIFO), then the fill window streams
        // later arrivals straight into the open batch
        let mut batch: Vec<u64> = Vec::new();
        while batch.len() < max_batch {
            match pending.pop_front() {
                Some(a) => batch.push(a),
                None => break,
            }
        }
        let mut exec_start = start;
        if batch.len() < max_batch {
            let deadline = start + cfg.max_wait_us;
            while batch.len() < max_batch
                && i < arrivals.len()
                && arrivals[i] <= deadline
            {
                // fill-window arrivals stream straight into the open
                // batch (admitted, never queued)
                rec.instant(arrivals[i] * US_TO_PS, "serve", "serve.admit");
                batch.push(arrivals[i]);
                i += 1;
            }
            exec_start = if batch.len() == max_batch {
                start.max(*batch.last().expect("full batch"))
            } else {
                deadline
            };
        }
        let done = exec_start + cfg.batch_exec_us;
        rec.span(start * US_TO_PS, (exec_start - start) * US_TO_PS,
                 "serve.batch", "serve.batch.fill");
        rec.span(exec_start * US_TO_PS, cfg.batch_exec_us * US_TO_PS,
                 "serve.batch", "serve.batch.exec");
        batches += 1;
        served += batch.len() as u64;
        for &a in &batch {
            lat_ms.push((done - a) as f64 / 1000.0);
            sojourn_us.observe(done - a);
        }
        makespan = makespan.max(done);
        free.push(Reverse(done));
    }
    ShardRun { served, shed, batches, makespan_us: makespan, lat_ms,
               peak_pending, sojourn_us }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LoadGenConfig {
        LoadGenConfig {
            requests: 512,
            workers: 2,
            max_batch: 16,
            max_wait_us: 200,
            max_queue_depth: 64,
            batch_exec_us: 1_000,
            seed: 42,
            shards: 1,
        }
    }

    fn fingerprint(pts: &[LoadPoint]) -> Vec<(u64, u64, u64, u64)> {
        pts.iter()
            .map(|p| {
                (p.served, p.shed, p.p99_ms.to_bits(),
                 p.throughput_rps.to_bits())
            })
            .collect()
    }

    #[test]
    fn conserves_every_arrival_and_respects_the_batch_cap() {
        for load in [0.2, 0.8, 1.5] {
            let p = &sweep(&cfg(), &[load]).unwrap()[0];
            assert_eq!(p.served + p.shed, 512, "load {load}");
            assert!(p.avg_batch <= 16.0 + 1e-9, "load {load}");
            assert!(p.batches >= p.served / 16, "load {load}");
            assert!(p.throughput_rps > 0.0);
            assert!(p.p50_ms <= p.p95_ms && p.p95_ms <= p.p99_ms);
            assert!(p.mean_ms >= cfg().batch_exec_us as f64 / 1000.0 - 1e-9,
                    "sojourn below the batch execution time");
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let loads = [0.5, 0.9, 1.2];
        assert_eq!(fingerprint(&sweep(&cfg(), &loads).unwrap()),
                   fingerprint(&sweep(&cfg(), &loads).unwrap()));
        // a different seed is a different experiment
        let other = LoadGenConfig { seed: 43, ..cfg() };
        assert_ne!(fingerprint(&sweep(&cfg(), &loads).unwrap()),
                   fingerprint(&sweep(&other, &loads).unwrap()));
    }

    #[test]
    fn light_load_never_sheds_and_overload_does() {
        let light = &sweep(&cfg(), &[0.2]).unwrap()[0];
        assert_eq!(light.shed, 0, "{light:?}");
        // a tiny admission bound under 3x overload must shed
        let tight = LoadGenConfig { max_queue_depth: 4, ..cfg() };
        let over = &sweep(&tight, &[3.0]).unwrap()[0];
        assert!(over.shed > 0, "{over:?}");
        assert!(over.shed_rate > 0.0 && over.shed_rate < 1.0);
    }

    #[test]
    fn sharded_sweep_conserves_arrivals_and_is_deterministic() {
        // 512 requests over 4 slices: every arrival is still served or
        // shed, and the merged point is reproducible
        let sharded = LoadGenConfig { shards: 4, ..cfg() };
        let loads = [0.8, 1.2];
        let pts = sweep(&sharded, &loads).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert_eq!(p.served + p.shed, 512);
            assert!(p.avg_batch <= 16.0 + 1e-9);
            assert!(p.p50_ms <= p.p95_ms && p.p95_ms <= p.p99_ms);
            assert!(p.throughput_rps > 0.0);
        }
        assert_eq!(fingerprint(&sweep(&sharded, &loads).unwrap()),
                   fingerprint(&pts));
        // an uneven split (512 = 5*102 + 2) still conserves
        let uneven = LoadGenConfig { shards: 5, ..cfg() };
        let p = &sweep(&uneven, &[1.0]).unwrap()[0];
        assert_eq!(p.served + p.shed, 512);
    }

    #[test]
    fn traced_sweep_matches_plain_and_tallies_every_arrival() {
        let sharded = LoadGenConfig { shards: 2, ..cfg() };
        let loads = [0.8, 1.4];
        let plain = sweep(&sharded, &loads).unwrap();
        let (traced, trace) =
            sweep_traced(&sharded, &loads, None).unwrap();
        // the recorder observes, never steers: identical points
        assert_eq!(fingerprint(&plain), fingerprint(&traced));
        assert_eq!(plain, traced);
        // every offered arrival shows up in the registry as served or
        // shed, and the sojourn histogram counts the served ones
        for p in &traced {
            assert_eq!(p.registry.counter("serve.served")
                           + p.registry.counter("serve.shed"), 512);
            assert_eq!(p.registry.counter("serve.served"), p.served);
            let h = p.registry.hist("serve.sojourn_us").expect("hist");
            assert_eq!(h.count, p.served);
        }
        // each (point, shard) traces under its own prefix, and the
        // trace carries all three phases
        for prefix in ["load0.80/s0/", "load0.80/s1/", "load1.40/s0/"] {
            assert!(trace.tracks().iter().any(|t| t.starts_with(prefix)),
                    "missing {prefix} in {:?}", trace.tracks());
        }
        assert!(!trace.is_empty());
        // a filter narrows the trace to matching event names
        let (_, filtered) =
            sweep_traced(&sharded, &loads, Some("serve.batch")).unwrap();
        assert!(filtered.len() < trace.len());
        assert!(!filtered.is_empty());
    }

    #[test]
    fn tail_latency_grows_with_offered_load() {
        // no shedding (huge bound): an overloaded queue must show up as
        // a heavier tail, not vanish into rejections
        let open = LoadGenConfig { max_queue_depth: 1 << 20, ..cfg() };
        let pts = sweep(&open, &[0.3, 1.4]).unwrap();
        assert_eq!(pts[0].shed + pts[1].shed, 0);
        assert!(
            pts[1].p99_ms > pts[0].p99_ms,
            "p99 {} vs {}", pts[0].p99_ms, pts[1].p99_ms
        );
    }

    #[test]
    fn bad_offered_loads_are_rejected_up_front() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.0] {
            let err = sweep(&cfg(), &[0.5, bad]).unwrap_err();
            assert_eq!(
                err,
                LoadGenError::BadOffered { index: 1, value: bad },
                "{bad} not rejected"
            );
        }
        // the traced variant shares the same entry gate
        assert!(sweep_traced(&cfg(), &[-0.25], None).is_err());
        // the index and value show up in the message
        let msg = sweep(&cfg(), &[0.0]).unwrap_err().to_string();
        assert!(msg.contains("[0]") && msg.contains("0"), "{msg}");
    }

    #[test]
    fn p999_respects_the_sample_guard_and_orders_after_p99() {
        // 512 requests < the 1000-sample guard: the tail must be absent,
        // not the max dressed up as a p99.9
        let small = LoadGenConfig { requests: 512, ..cfg() };
        assert_eq!(sweep(&small, &[0.8]).unwrap()[0].p999_ms, None);
        // 4096 served samples clear the guard; nearest-rank tails nest
        let big = LoadGenConfig {
            requests: 4_096,
            max_queue_depth: 1 << 20,
            ..cfg()
        };
        let p = &sweep(&big, &[0.9]).unwrap()[0];
        assert_eq!(p.served, 4_096);
        let p999 = p.p999_ms.expect("guard cleared");
        assert!(p999 >= p.p99_ms, "p99.9 {} < p99 {}", p999, p.p99_ms);
    }
}
