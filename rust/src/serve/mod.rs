//! The serving layer: a backend-agnostic inference service.
//!
//! Requests (single images) arrive on a shared multi-consumer queue
//! behind an **admission controller** (bounded queue depth with typed
//! load-shedding); a dynamic [`batcher`] groups them up to the backend's
//! fixed batch (padding the tail), worker threads execute the batch on a
//! pluggable [`InferenceBackend`], and responses fan back out to the
//! callers. std::thread based (the offline registry has no tokio); the
//! architecture mirrors a vLLM-style router: admission queue -> batcher
//! -> execution engine -> response demux.
//!
//! **What executes a batch is a trait, not a hard-coded runtime.** The
//! coordinator used to construct the PJRT runtime inside its worker
//! threads, welding every serving scenario to compiled XLA artifacts.
//! Now [`InferenceBackend`] declares the executable's shape (batch /
//! classes / flattened image length) and a per-worker-thread setup hook,
//! and two implementations are registered ([`BACKENDS`]):
//!
//! - [`pjrt::PjrtBackend`] — the compiled-artifact path (PJRT objects
//!   are thread-local `Rc`s, so every worker builds its own client +
//!   executable inside [`InferenceBackend::worker`]);
//! - [`sim::SimBackend`] — logits synthesized deterministically from the
//!   image content and per-batch latency priced by
//!   `model::network_cost` + the `event` pipeline's service-time model,
//!   so serving runs end-to-end with **zero artifacts** (CI, the suite
//!   runner, `serve-sim`).
//!
//! N workers collect and execute batches concurrently: the queue
//! releases its lock while a worker waits (see [`queue`]), so one
//! worker's fill window never blocks the others. [`metrics::Metrics`]
//! reduces to a typed [`metrics::MetricsSnapshot`] (no stringly
//! `summary()`), and [`loadgen`] drives the service model in virtual
//! time for the deterministic `serve-sim` offered-load sweep.
//! [`fleet`] scales that to a routed datacenter of priced chips (the
//! `fleet-sim` scenario).

pub mod batcher;
pub mod fleet;
pub mod loadgen;
pub mod metrics;
pub mod pjrt;
pub mod queue;
pub mod sim;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{Metrics, MetricsSnapshot, LATENCY_WINDOW};
pub use pjrt::{open_runtime, ExtraInput, PjrtBackend};
pub use queue::SharedQueue;
pub use sim::SimBackend;

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// The registered backends: `(name, description)`. Scenario `--backend`
/// parsing and help text iterate this list; construction stays with the
/// caller because each backend has its own inputs (artifact directory
/// vs network + chip config).
pub const BACKENDS: [(&str, &str); 2] = [
    ("pjrt", "compiled XLA artifacts via PJRT (needs `make artifacts`)"),
    ("sim", "simulated chip: deterministic logits + model/event latency, \
             zero artifacts"),
];

/// The registered backend names, in registry order.
pub fn backend_names() -> Vec<&'static str> {
    BACKENDS.iter().map(|(n, _)| *n).collect()
}

/// One inference backend: what executes a padded batch of images. The
/// object itself is shared across worker threads (`Send + Sync`); all
/// thread-local execution state lives in the [`BackendWorker`] each
/// thread builds for itself.
pub trait InferenceBackend: Send + Sync {
    /// Registry name ("pjrt", "sim", ...).
    fn name(&self) -> &'static str;

    /// The executable's fixed batch; partial batches are padded to it.
    fn batch(&self) -> usize;

    /// Logit classes per image.
    fn classes(&self) -> usize;

    /// Flattened image length (h * w * c) a request must match.
    fn image_len(&self) -> usize;

    /// Per-worker-thread setup, called **on the worker thread itself**
    /// so non-`Send` state (PJRT `Rc`s) never crosses threads. Errors
    /// surface through the coordinator's ready barrier.
    fn worker(&self) -> Result<Box<dyn BackendWorker>>;
}

/// Thread-local execution state of one worker.
pub trait BackendWorker {
    /// Execute one padded batch; `input.data` holds `batch * image_len`
    /// floats (live requests first, tail padded by repetition).
    fn execute(&mut self, input: &BatchInput) -> Result<BatchResult>;
}

/// One assembled batch, ready to execute.
pub struct BatchInput<'a> {
    /// `batch * image_len` floats
    pub data: &'a [f32],
    /// live requests at the front (the rest is padding)
    pub n: usize,
    pub image_len: usize,
}

/// What a backend returns for one batch.
pub struct BatchResult {
    /// `batch * classes` logits
    pub logits: Vec<f32>,
    /// execution time attributed to the batch, µs — wall-clock for the
    /// PJRT backend, simulated chip time for [`sim::SimBackend`]
    pub exec_us: u64,
}

/// One inference request: a single image (u8-valued f32 HWC).
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub respond: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_us: u64,
    pub exec_us: u64,
    pub batch_size: usize,
    /// `Some(cause)` when the batch this request rode in failed; `logits`
    /// is empty then. Lets callers distinguish batch failure (an error
    /// response arrives) from shutdown (the response channel disconnects).
    pub error: Option<String>,
}

/// The admission controller's typed refusal: the bounded queue was full
/// at submission time, so the request was shed instead of enqueued.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rejection {
    /// queue depth observed at admission
    pub depth: usize,
    /// the configured [`ServeOptions::max_queue_depth`]
    pub limit: usize,
}

/// Outcome of [`Coordinator::submit`]: admitted (await the response on
/// the receiver) or shed by the admission controller.
pub enum Submission {
    Accepted(mpsc::Receiver<Response>),
    Rejected(Rejection),
}

impl Submission {
    /// Unwrap an admission the caller did not configure to shed (no
    /// `max_queue_depth`): rejection becomes an error.
    pub fn accepted(self) -> Result<mpsc::Receiver<Response>> {
        match self {
            Submission::Accepted(rx) => Ok(rx),
            Submission::Rejected(r) => Err(anyhow!(
                "request shed: queue depth {} at limit {}",
                r.depth,
                r.limit
            )),
        }
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, Submission::Rejected(_))
    }
}

/// Serving-side knobs, independent of which backend executes batches.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub workers: usize,
    /// batching window: how long a partial batch waits after its first
    /// request
    pub max_wait: Duration,
    /// batcher fill cap; 0 (the default) = the backend's executable
    /// batch. A smaller cap trades padding for latency.
    pub max_batch: usize,
    /// admission control: shed a submission (typed [`Rejection`]) when
    /// the shared queue already holds this many pending requests;
    /// `None` = never shed. The bound is checked against the
    /// instantaneous depth, so concurrent submitters can overshoot by
    /// their in-flight count — a safety valve, not an exact semaphore.
    pub max_queue_depth: Option<usize>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 1,
            max_wait: Duration::from_millis(5),
            max_batch: 0,
            max_queue_depth: None,
        }
    }
}

impl ServeOptions {
    /// The pluggable batch-policy construction: explicit cap if given,
    /// else the backend's executable batch (never above it — slots past
    /// the executable batch could not execute).
    fn policy_for(&self, backend: &dyn InferenceBackend) -> BatchPolicy {
        let cap = backend.batch();
        BatchPolicy {
            max_batch: if self.max_batch == 0 {
                cap
            } else {
                self.max_batch.min(cap)
            },
            max_wait: self.max_wait,
        }
    }
}

/// Handle the caller keeps: submit images, await logits. Generic over
/// the [`InferenceBackend`] that executes batches.
pub struct Coordinator {
    backend: Arc<dyn InferenceBackend>,
    queue: Arc<SharedQueue<Request>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    max_queue_depth: Option<usize>,
}

impl Coordinator {
    /// Start worker threads over an owned backend.
    pub fn start<B: InferenceBackend + 'static>(
        backend: B, opts: ServeOptions,
    ) -> Result<Coordinator> {
        Self::start_dyn(Arc::new(backend), opts)
    }

    /// Start worker threads over a shared backend handle.
    pub fn start_dyn(backend: Arc<dyn InferenceBackend>,
                     opts: ServeOptions) -> Result<Coordinator> {
        let queue = Arc::new(SharedQueue::new());
        let metrics = Arc::new(Metrics::default());
        let policy = opts.policy_for(backend.as_ref());
        let (batch, classes) = (backend.batch(), backend.classes());
        // ready-barrier: surface backend setup errors (missing
        // artifacts, compile failures) to the caller
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::new();
        for _ in 0..opts.workers.max(1) {
            let backend = backend.clone();
            let queue = queue.clone();
            let metrics = metrics.clone();
            let policy = policy.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                // backend worker state lives and dies on this thread
                let mut worker = match backend.worker() {
                    Ok(w) => {
                        let _ = ready.send(Ok(()));
                        w
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                let batcher = Batcher::new(policy);
                loop {
                    let Some(reqs) = batcher.collect(&queue) else { break };
                    if reqs.is_empty() {
                        continue;
                    }
                    run_batch(worker.as_mut(), reqs, batch, classes,
                              &metrics);
                }
            }));
        }
        drop(ready_tx);
        for _ in 0..opts.workers.max(1) {
            let ready = ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during setup"))
                .and_then(|r| r);
            if let Err(e) = ready {
                // release the workers that did come up, and join them so
                // no thread outlives the failed start
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
                return Err(e);
            }
        }
        Ok(Coordinator {
            backend,
            queue,
            next_id: AtomicU64::new(0),
            metrics,
            workers,
            max_queue_depth: opts.max_queue_depth,
        })
    }

    /// Submit one image. `Ok(Submission::Accepted)` carries the response
    /// receiver; `Ok(Submission::Rejected)` is the admission controller
    /// shedding load (counted in [`Metrics::shed`]); `Err` means a
    /// malformed image or a stopped coordinator.
    pub fn submit(&self, image: Vec<f32>) -> Result<Submission> {
        anyhow::ensure!(
            image.len() == self.backend.image_len(),
            "bad image size {} (backend wants {})",
            image.len(),
            self.backend.image_len()
        );
        if let Some(limit) = self.max_queue_depth {
            let depth = self.queue.len();
            if depth >= limit {
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                return Ok(Submission::Rejected(Rejection { depth, limit }));
            }
        }
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue
            .push(Request { id, image, respond: rtx, enqueued: Instant::now() })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(Submission::Accepted(rrx))
    }

    /// The backend executing batches (shape queries, registry name).
    pub fn backend(&self) -> &dyn InferenceBackend {
        self.backend.as_ref()
    }

    pub fn classes(&self) -> usize {
        self.backend.classes()
    }

    pub fn image_len(&self) -> usize {
        self.backend.image_len()
    }

    /// Requests admitted but not yet collected into a batch.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Stop accepting work, drain what was admitted, and join every
    /// worker. Deterministic contract: every in-flight request gets a
    /// [`Response`] (workers drain the closed queue) or — if its worker
    /// died — a channel disconnect; a caller blocked on `recv()` never
    /// hangs past this call returning.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.queue.close();
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // same drain-and-join as shutdown(): dropping the handle (e.g. a
        // panicking test) used to close the queue but leave workers
        // running, racing pending callers against process teardown
        self.close_and_join();
    }
}

/// Assemble, execute, and demux one batch. Queue time is attributed per
/// rider as `enqueued -> execution start` — the previous
/// `total - exec_us` form charged the whole batch execution window to
/// every rider and saturated to zero for requests that arrived mid-fill
/// (or whenever a backend reports simulated `exec_us` larger than wall
/// time). Failures answer every caller with the cause and land on
/// [`Metrics::note_error`] instead of stderr.
fn run_batch(worker: &mut dyn BackendWorker, reqs: Vec<Request>,
             batch: usize, classes: usize, metrics: &Metrics) {
    let n = reqs.len();
    let image_len = reqs[0].image.len();
    let mut data = Vec::with_capacity(batch * image_len);
    for r in &reqs {
        data.extend_from_slice(&r.image);
    }
    // pad the tail by repeating the last image (results discarded)
    for _ in n..batch {
        data.extend_from_slice(&reqs[n - 1].image);
    }
    let exec_start = Instant::now();
    let result = worker
        .execute(&BatchInput { data: &data, n, image_len })
        .and_then(|r| {
            anyhow::ensure!(
                r.logits.len() == batch * classes,
                "bad logits size {} (want {})",
                r.logits.len(),
                batch * classes
            );
            Ok(r)
        });
    match result {
        Ok(BatchResult { logits, exec_us }) => {
            metrics.requests.fetch_add(n as u64, Ordering::Relaxed);
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics
                .padded_slots
                .fetch_add((batch - n) as u64, Ordering::Relaxed);
            metrics.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
            for (i, r) in reqs.into_iter().enumerate() {
                let queue_us = exec_start
                    .saturating_duration_since(r.enqueued)
                    .as_micros() as u64;
                metrics.queue_us_total.fetch_add(queue_us, Ordering::Relaxed);
                metrics.record_latency_us(queue_us + exec_us);
                let _ = r.respond.send(Response {
                    id: r.id,
                    logits: logits[i * classes..(i + 1) * classes].to_vec(),
                    queue_us,
                    exec_us,
                    batch_size: n,
                    error: None,
                });
            }
        }
        Err(e) => {
            // don't drop the requests: answer every caller with the
            // cause and count the failures
            metrics.failed.fetch_add(n as u64, Ordering::Relaxed);
            let msg = format!("{e:#}");
            metrics.note_error(&msg);
            for r in reqs {
                let queue_us = r.enqueued.elapsed().as_micros() as u64;
                let _ = r.respond.send(Response {
                    id: r.id,
                    logits: Vec::new(),
                    queue_us,
                    exec_us: 0,
                    batch_size: n,
                    error: Some(msg.clone()),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal in-process backend: one-hot logits keyed off each image's
    /// first value, configurable simulated `exec_us`, wall-clock stall,
    /// and failure injection.
    struct TestBackend {
        batch: usize,
        classes: usize,
        image_len: usize,
        exec_us: u64,
        stall: Duration,
        fail: bool,
    }

    impl TestBackend {
        fn quick(batch: usize) -> TestBackend {
            TestBackend {
                batch,
                classes: 4,
                image_len: 3,
                exec_us: 10,
                stall: Duration::ZERO,
                fail: false,
            }
        }
    }

    impl InferenceBackend for TestBackend {
        fn name(&self) -> &'static str {
            "test"
        }

        fn batch(&self) -> usize {
            self.batch
        }

        fn classes(&self) -> usize {
            self.classes
        }

        fn image_len(&self) -> usize {
            self.image_len
        }

        fn worker(&self) -> Result<Box<dyn BackendWorker>> {
            Ok(Box::new(TestWorker {
                classes: self.classes,
                exec_us: self.exec_us,
                stall: self.stall,
                fail: self.fail,
            }))
        }
    }

    struct TestWorker {
        classes: usize,
        exec_us: u64,
        stall: Duration,
        fail: bool,
    }

    impl BackendWorker for TestWorker {
        fn execute(&mut self, input: &BatchInput) -> Result<BatchResult> {
            if self.fail {
                anyhow::bail!("injected failure");
            }
            if !self.stall.is_zero() {
                std::thread::sleep(self.stall);
            }
            let slots = input.data.len() / input.image_len;
            let mut logits = vec![0.0f32; slots * self.classes];
            for i in 0..slots {
                let class =
                    input.data[i * input.image_len] as usize % self.classes;
                logits[i * self.classes + class] = 1.0;
            }
            Ok(BatchResult { logits, exec_us: self.exec_us })
        }
    }

    fn image(class: usize) -> Vec<f32> {
        vec![class as f32, 0.0, 0.0]
    }

    fn argmax(logits: &[f32]) -> usize {
        let mut best = 0;
        for (j, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = j;
            }
        }
        best
    }

    #[test]
    fn serves_demuxed_logits_through_a_test_backend() {
        let coord = Coordinator::start(
            TestBackend::quick(4),
            ServeOptions {
                workers: 2,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let mut pending = Vec::new();
        for i in 0..10usize {
            let rx = coord.submit(image(i % 4)).unwrap().accepted().unwrap();
            pending.push((rx, i % 4));
        }
        for (rx, want) in pending {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.logits.len(), 4);
            assert_eq!(argmax(&r.logits), want);
            assert!(r.batch_size >= 1 && r.batch_size <= 4);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 10);
        assert_eq!(snap.failed, 0);
        assert!(snap.batches >= 3, "{snap:?}"); // 10 requests, batch cap 4
        // every batch pads to 4 slots exactly
        assert_eq!(snap.requests + snap.padded_slots, snap.batches * 4);
        coord.shutdown();
    }

    #[test]
    fn submit_rejects_wrong_image_size() {
        let coord =
            Coordinator::start(TestBackend::quick(2), ServeOptions::default())
                .unwrap();
        assert!(coord.submit(vec![0.0; 5]).is_err());
        coord.shutdown();
    }

    /// Satellite regression: queue time is `enqueued -> exec start`, not
    /// `total - exec_us`. The backend reports a *simulated* exec_us far
    /// larger than wall time; the old attribution saturated every
    /// rider's queue_us to zero and charged followers the full window.
    #[test]
    fn queue_time_is_enqueue_to_exec_start() {
        let backend = TestBackend {
            exec_us: 1_000_000, // 1 s of simulated chip time, ~0 wall
            ..TestBackend::quick(2)
        };
        let coord = Coordinator::start(
            backend,
            ServeOptions {
                workers: 1,
                max_wait: Duration::from_secs(5),
                ..Default::default()
            },
        )
        .unwrap();
        let rx1 = coord.submit(image(0)).unwrap().accepted().unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let rx2 = coord.submit(image(1)).unwrap().accepted().unwrap();
        let (r1, r2) = (rx1.recv().unwrap(), rx2.recv().unwrap());
        assert_eq!(r1.exec_us, 1_000_000);
        // the first request waited out the fill window (~40 ms); the old
        // `total - exec_us` form would have reported 0 here
        assert!(
            r1.queue_us >= 20_000,
            "first rider's fill wait lost: queue_us {}",
            r1.queue_us
        );
        // the mid-fill arrival waited less than the batch opener — it
        // must not be charged the opener's window
        assert!(
            r2.queue_us < r1.queue_us,
            "rider charged the opener's wait: {} vs {}",
            r2.queue_us,
            r1.queue_us
        );
        // recorded latency is queue + exec, coherently
        assert_eq!(
            coord.metrics.queue_us_total.load(Ordering::Relaxed),
            r1.queue_us + r2.queue_us
        );
        coord.shutdown();
    }

    #[test]
    fn batch_failure_answers_callers_and_lands_on_the_snapshot() {
        let backend = TestBackend { fail: true, ..TestBackend::quick(4) };
        let coord = Coordinator::start(
            backend,
            ServeOptions {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let mut pending = Vec::new();
        for i in 0..3usize {
            pending.push(coord.submit(image(i)).unwrap().accepted().unwrap());
        }
        for rx in pending {
            let r = rx.recv().unwrap();
            assert!(r.logits.is_empty());
            assert!(r.error.as_deref().unwrap().contains("injected failure"));
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.failed, 3);
        assert_eq!(snap.requests, 0);
        assert!(
            snap.last_error.as_deref().unwrap().contains("injected failure"),
            "{snap:?}"
        );
        coord.shutdown();
    }

    #[test]
    fn admission_control_sheds_beyond_the_depth_bound() {
        // batch 1 + a long stall: the worker takes the first request and
        // blocks in execute, so subsequent submissions pile up against
        // the depth bound deterministically
        let backend = TestBackend {
            stall: Duration::from_millis(150),
            ..TestBackend::quick(1)
        };
        let coord = Coordinator::start(
            backend,
            ServeOptions {
                workers: 1,
                max_wait: Duration::from_millis(1),
                max_queue_depth: Some(2),
                ..Default::default()
            },
        )
        .unwrap();
        let first = coord.submit(image(0)).unwrap().accepted().unwrap();
        // wait until the worker has pulled the first request off the
        // queue and is stalled inside execute
        while coord.queue_depth() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let a = coord.submit(image(1)).unwrap();
        let b = coord.submit(image(2)).unwrap();
        let c = coord.submit(image(3)).unwrap();
        assert!(!a.is_rejected() && !b.is_rejected());
        match &c {
            Submission::Rejected(r) => {
                assert_eq!((r.depth, r.limit), (2, 2));
            }
            Submission::Accepted(_) => panic!("third submission not shed"),
        }
        assert_eq!(coord.metrics.shed.load(Ordering::Relaxed), 1);
        // the admitted requests all complete
        assert!(first.recv().unwrap().error.is_none());
        for s in [a, b] {
            assert!(s.accepted().unwrap().recv().unwrap().error.is_none());
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.shed, 1);
        assert!(snap.to_string().contains("shed=1"));
        coord.shutdown();
    }

    #[test]
    fn worker_setup_failure_surfaces_and_joins() {
        struct BadBackend;
        impl InferenceBackend for BadBackend {
            fn name(&self) -> &'static str {
                "bad"
            }
            fn batch(&self) -> usize {
                1
            }
            fn classes(&self) -> usize {
                1
            }
            fn image_len(&self) -> usize {
                1
            }
            fn worker(&self) -> Result<Box<dyn BackendWorker>> {
                anyhow::bail!("no runtime here")
            }
        }
        let err = Coordinator::start(
            BadBackend,
            ServeOptions { workers: 3, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no runtime here"));
    }
}
