//! Fleet-scale serving simulator: a virtual datacenter of PIM chips.
//!
//! `serve::loadgen` simulates one coordinator; this module simulates N
//! priced chips behind a router. Each chip class comes from the `model`
//! registry (heterogeneous mixes via a `--fleet` spec such as
//! `neural-pim:8,isaac:4`), priced by the same
//! [`event::service_profile`](crate::event::service_profile) batch
//! table the single-chip paths use, so fleet numbers are commensurable
//! with `serve-sim` and the event scenarios. The router is pluggable
//! ([`RouterPolicy`]): round-robin, join-shortest-queue, or
//! latency-aware (per-chip EWMA sojourn plus queued work). Each chip
//! has a bounded admission queue; an arrival routed to a full chip is
//! shed and tallied per chip class.
//!
//! Arrivals go beyond homogeneous Poisson: a deterministic
//! diurnal/bursty generator ([`ArrivalGen`]) thins a peak-rate Poisson
//! stream against a piecewise-constant diurnal profile times a
//! two-state Markov burst chain, all on `Pcg` fork streams in the
//! `FORK_NS_FLEET` namespace. Arrivals stream one at a time — millions
//! of virtual users never materialize as an event vector.
//!
//! # Determinism and the two-pass chunk discipline
//!
//! Routing is inherently global (JSQ reads every queue), so the router
//! pass is sequential: it advances every chip's [`ChipCore`] state
//! machine to each arrival, picks a chip, and applies bounded
//! admission, appending admitted arrivals to per-chip chunk buffers
//! (bounded by [`CHUNK`] — the streaming guarantee). The expensive
//! per-request accounting (sojourn histograms, latency samples, trace
//! spans) happens in a second pass that replays each chip's admitted
//! stream through an identical `ChipCore`, fanned out over the
//! persistent `util::pool` — each chip's evolution depends only on its
//! own stream, so any thread count produces bit-identical results, and
//! per-chip partials merge in chip-index order. The two passes run the
//! same machine; [`run_fleet`] asserts their per-chip served/batch
//! counts agree exactly.

use crate::config::{AcceleratorConfig, Architecture};
use crate::event;
use crate::model;
use crate::obs::{Hist, Recorder, Registry, TraceRecorder};
use crate::util::pool;
use crate::util::rng::{self, Pcg};
use crate::util::stats;
use crate::util::{cli, json};
use crate::workloads::Network;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::Mutex;

/// Trace timestamps are virtual picoseconds; the fleet clock is
/// virtual microseconds (same convention as `serve::loadgen`).
const US_TO_PS: u64 = 1_000_000;

/// Router-pass arrivals per parallel detail flush: bounds per-chip
/// buffer memory no matter how many arrivals stream through, and sets
/// the fan-out granularity of the detail pass.
const CHUNK: usize = 32_768;

/// EWMA smoothing for the latency-aware policy's per-chip sojourn
/// estimate.
const EWMA_ALPHA: f64 = 0.2;

// ------------------------------------------------------------ fleet spec --

/// Parse a `--fleet` spec: comma-separated `arch:count` entries against
/// the `model` registry (names and aliases), e.g. `neural-pim:8,isaac:4`.
pub fn parse_fleet(spec: &str) -> Result<Vec<(Architecture, usize)>> {
    let mut mix = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, count) = match part.split_once(':') {
            Some((n, c)) => {
                let count: usize = c.trim().parse().map_err(|_| {
                    anyhow::anyhow!("--fleet: '{c}' is not a chip count \
                                     (in '{part}')")
                })?;
                (n.trim(), count)
            }
            None => (part, 1),
        };
        if count == 0 {
            bail!("--fleet: '{part}' asks for zero chips");
        }
        let arch = model::parse_arch(name).map_err(|e| {
            let known: Vec<&str> = model::models()
                .iter()
                .flat_map(|m| {
                    std::iter::once(m.name()).chain(m.aliases().iter().copied())
                })
                .collect();
            match cli::suggest(name, &known) {
                Some(s) => anyhow::anyhow!("{e} (did you mean '{s}'?)"),
                None => e,
            }
        })?;
        mix.push((arch, count));
    }
    if mix.is_empty() {
        bail!("--fleet needs at least one arch:count entry");
    }
    Ok(mix)
}

/// One chip class of the fleet: an architecture priced for `net`, its
/// batch service-time table and per-inference energy shared by every
/// chip of the class.
#[derive(Debug, Clone)]
pub struct ChipClass {
    pub arch: Architecture,
    /// registry display name (`model::cost_model(arch).name()`)
    pub name: &'static str,
    pub count: usize,
    /// service time of a batch of `n`, µs, at index `n - 1`
    pub batch_us: Vec<u64>,
    /// per-inference energy, joules (`model::network_cost` total)
    pub energy_j_per_inf: f64,
    /// steady-state per-request service time at full batch, µs — the
    /// latency-aware policy's queued-work estimate
    pub svc_per_req_us: f64,
}

/// Price a fleet mix for one network: per class, the batch table from
/// the service profile and the energy from the memoized cost table.
pub fn build_classes(net: &Network, mix: &[(Architecture, usize)],
                     max_batch: usize) -> Vec<ChipClass> {
    let max_batch = max_batch.max(1);
    mix.iter()
        .map(|&(arch, count)| {
            let cfg = AcceleratorConfig::for_arch(arch);
            let nc = model::network_cost(net, &cfg);
            let sp = event::service_profile(&cfg, &nc);
            let batch_us: Vec<u64> =
                (1..=max_batch as u64).map(|n| sp.batch_us(n)).collect();
            let full = batch_us[max_batch - 1];
            ChipClass {
                arch,
                name: model::cost_model(arch).name(),
                count,
                svc_per_req_us: full as f64 / max_batch as f64,
                energy_j_per_inf: nc.total.total(),
                batch_us,
            }
        })
        .collect()
}

/// Fleet service capacity, requests per virtual µs, at full batches —
/// the rate the offered load is expressed against.
pub fn capacity_per_us(classes: &[ChipClass]) -> f64 {
    classes
        .iter()
        .map(|c| {
            let full = *c.batch_us.last().expect("non-empty batch table");
            c.count as f64 * c.batch_us.len() as f64 / full.max(1) as f64
        })
        .sum()
}

// --------------------------------------------------------------- router --

/// Chip-selection policy. All selection logic lives here (verify.sh
/// gates `RouterPolicy::` match arms to this file): scenarios and
/// benches only name a policy, they never route.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// cycle through chips in index order (exactly fair per cycle)
    RoundRobin,
    /// least work-in-system (queued + in-flight), ties to lowest index
    JoinShortestQueue,
    /// least estimated sojourn: per-chip EWMA of batch sojourn plus
    /// queued work times the class service rate
    LatencyAware,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Result<RouterPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Ok(RouterPolicy::RoundRobin),
            "join-shortest-queue" | "jsq" => Ok(RouterPolicy::JoinShortestQueue),
            "latency-aware" | "ewma" => Ok(RouterPolicy::LatencyAware),
            other => {
                let known = ["round-robin", "join-shortest-queue",
                             "latency-aware"];
                match cli::suggest(other, &known) {
                    Some(sug) => bail!("unknown router policy '{other}' \
                                        (did you mean '{sug}'?)"),
                    None => bail!("unknown router policy '{other}'"),
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::JoinShortestQueue => "join-shortest-queue",
            RouterPolicy::LatencyAware => "latency-aware",
        }
    }
}

/// JSQ selection: the first index of minimum depth. Pure so the
/// property tests can drive it directly.
pub fn pick_shortest(depths: &[usize]) -> usize {
    let mut best = 0;
    for (i, &d) in depths.iter().enumerate().skip(1) {
        if d < depths[best] {
            best = i;
        }
    }
    best
}

/// Latency-aware selection: the first index of minimum estimated
/// sojourn. Pure for the same reason.
pub fn pick_cheapest(est_us: &[f64]) -> usize {
    let mut best = 0;
    for (i, &e) in est_us.iter().enumerate().skip(1) {
        if e < est_us[best] {
            best = i;
        }
    }
    best
}

// ------------------------------------------------------------- arrivals --

/// Streaming diurnal/bursty arrival generator: a peak-rate exponential
/// clock thinned against `rate(t) = base x diurnal(t) x burst(t)`.
///
/// The diurnal profile is a fixed 16-slot piecewise-constant shape
/// scaled by `diurnal_amp` over `diurnal_period_us`; the burst factor
/// is a two-state Markov chain (enter/exit probabilities clocked per
/// candidate event) multiplying the rate by `burst_mult` while on.
/// Three `Pcg` streams (gaps, thinning, bursts) are forked in the
/// `FORK_NS_FLEET` namespace, so the process is deterministic per seed
/// and never collides with loadgen/event streams sharing the seed.
pub struct ArrivalGen {
    t_us: f64,
    base_rate_per_us: f64,
    peak_rate_per_us: f64,
    diurnal_amp: f64,
    diurnal_period_us: f64,
    burst_mult: f64,
    burst_enter: f64,
    burst_exit: f64,
    bursting: bool,
    gap_rng: Pcg,
    thin_rng: Pcg,
    burst_rng: Pcg,
}

/// Zero-ish-mean day shape sampled at 16 slots (trough, ramp, double
/// peak, decay) — multiplied by `diurnal_amp` and shifted around 1.
const DIURNAL_SHAPE: [f64; 16] = [
    -1.0, -0.9, -0.75, -0.45, -0.1, 0.3, 0.6, 0.85,
    1.0, 0.9, 0.7, 0.8, 0.5, 0.1, -0.4, -0.8,
];

impl ArrivalGen {
    /// `base_rate_per_us` is the diurnal-average arrival rate (offered
    /// load times fleet capacity). `diurnal_amp` is clamped to
    /// `[0, 0.95]` so the rate stays positive; `burst_mult < 1` is
    /// clamped to 1 (bursts only ever add load).
    pub fn new(seed: u64, base_rate_per_us: f64, diurnal_amp: f64,
               diurnal_period_us: u64, burst_mult: f64, burst_enter: f64,
               burst_exit: f64) -> ArrivalGen {
        let amp = diurnal_amp.clamp(0.0, 0.95);
        let mult = burst_mult.max(1.0);
        let mut root = Pcg::new(seed);
        ArrivalGen {
            t_us: 0.0,
            base_rate_per_us,
            peak_rate_per_us: base_rate_per_us * (1.0 + amp) * mult,
            diurnal_amp: amp,
            diurnal_period_us: diurnal_period_us.max(1) as f64,
            burst_mult: mult,
            burst_enter: burst_enter.clamp(0.0, 1.0),
            burst_exit: burst_exit.clamp(0.0, 1.0),
            bursting: false,
            gap_rng: root.fork(rng::fork_idx(rng::FORK_NS_FLEET, 0)),
            thin_rng: root.fork(rng::fork_idx(rng::FORK_NS_FLEET, 1)),
            burst_rng: root.fork(rng::fork_idx(rng::FORK_NS_FLEET, 2)),
        }
    }

    /// Instantaneous rate multiplier from the diurnal profile at `t`.
    fn diurnal(&self, t_us: f64) -> f64 {
        let phase = (t_us / self.diurnal_period_us).fract();
        let slot = ((phase * DIURNAL_SHAPE.len() as f64) as usize)
            .min(DIURNAL_SHAPE.len() - 1);
        1.0 + self.diurnal_amp * DIURNAL_SHAPE[slot]
    }

    /// Next arrival time, virtual µs (non-decreasing). Streaming: O(1)
    /// state regardless of how many arrivals have been drawn.
    pub fn next(&mut self) -> u64 {
        loop {
            // candidate from the peak-rate Poisson clock
            let u = self.gap_rng.uniform();
            let gap = -(1.0 - u).max(f64::MIN_POSITIVE).ln()
                / self.peak_rate_per_us;
            self.t_us += gap;
            // burst chain clocks on candidates, so dwell times scale
            // with the peak rate, not the accepted rate
            self.bursting = if self.bursting {
                self.burst_rng.uniform() >= self.burst_exit
            } else {
                self.burst_rng.uniform() < self.burst_enter
            };
            let rate = self.base_rate_per_us
                * self.diurnal(self.t_us)
                * if self.bursting { self.burst_mult } else { 1.0 };
            if self.thin_rng.uniform() < rate / self.peak_rate_per_us {
                return self.t_us as u64;
            }
        }
    }
}

// ------------------------------------------------------------ chip core --

/// One chip's serving state machine, shared verbatim by the router pass
/// and the detail replay so the two cannot drift.
///
/// Discipline: an idle chip starts a batch of 1 the instant an arrival
/// is admitted (no fill window — fleet chips are assumed saturated
/// enough that waiting buys nothing; the single-coordinator fill-window
/// dynamics stay in `serve::loadgen`); on completion it drains up to
/// `max_batch` pending arrivals into the next batch. Admission is
/// bounded: an arrival finding `depth` pending is shed by the caller.
struct ChipCore {
    max_batch: usize,
    depth: usize,
    /// service time of a batch of `n` at index `n - 1`, µs
    batch_us: Vec<u64>,
    /// admitted arrival times waiting for a batch slot
    pending: VecDeque<u64>,
    /// arrival times of the in-flight batch (empty when idle)
    busy_arr: Vec<u64>,
    /// completion time of the in-flight batch
    busy_done: Option<u64>,
}

impl ChipCore {
    fn new(class: &ChipClass, depth: usize) -> ChipCore {
        ChipCore {
            max_batch: class.batch_us.len(),
            depth: depth.max(1),
            batch_us: class.batch_us.clone(),
            pending: VecDeque::new(),
            busy_arr: Vec::new(),
            busy_done: None,
        }
    }

    /// Work in system: queued plus in-flight requests (the JSQ depth).
    fn depth_now(&self) -> usize {
        self.pending.len() + self.busy_arr.len()
    }

    fn service_us(&self, n: usize) -> u64 {
        self.batch_us[n - 1]
    }

    /// Retire every batch completing at or before `now`, reporting each
    /// as `(arrivals, exec_start, done)` to `on_batch`, and start the
    /// next batch from the backlog.
    fn advance<F: FnMut(&[u64], u64, u64)>(&mut self, now: u64,
                                           on_batch: &mut F) {
        while let Some(done) = self.busy_done {
            if done > now {
                return;
            }
            let finished = std::mem::take(&mut self.busy_arr);
            let start = done - self.service_us(finished.len());
            on_batch(&finished, start, done);
            if self.pending.is_empty() {
                self.busy_done = None;
            } else {
                let n = self.pending.len().min(self.max_batch);
                self.busy_arr.extend(self.pending.drain(..n));
                self.busy_done = Some(done + self.service_us(n));
            }
        }
    }

    /// Bounded admission at time `t` (callers advance to `t` first).
    /// An idle chip starts a batch of 1 immediately; a busy chip queues
    /// up to `depth`; beyond that the arrival is shed (`false`).
    fn try_admit(&mut self, t: u64) -> bool {
        if self.busy_done.is_none() {
            debug_assert!(self.pending.is_empty(),
                          "idle chip with a backlog");
            self.busy_arr.push(t);
            self.busy_done = Some(t + self.service_us(1));
            true
        } else if self.pending.len() < self.depth {
            self.pending.push_back(t);
            true
        } else {
            false
        }
    }
}

// ----------------------------------------------------------- detail pass --

/// One chip's replay state for the parallel detail pass: the same core
/// machine plus the per-request accounting the router pass skips.
struct ChipDetail {
    core: ChipCore,
    class: usize,
    served: u64,
    batches: u64,
    makespan_us: u64,
    peak_pending: u64,
    sojourn_us: Hist,
    lat_ms: Vec<f64>,
    trace: Option<TraceRecorder>,
}

impl ChipDetail {
    /// Replay one chunk of this chip's admitted arrivals. Every arrival
    /// was admitted by the router pass running the identical machine,
    /// so admission cannot fail here.
    fn replay(&mut self, arrivals: &[u64]) {
        let Self { core, served, batches, makespan_us, peak_pending,
                   sojourn_us, lat_ms, trace, .. } = self;
        for &t in arrivals {
            core.advance(t, &mut |batch, start, done| {
                *batches += 1;
                *served += batch.len() as u64;
                *makespan_us = (*makespan_us).max(done);
                for &a in batch {
                    sojourn_us.observe(done - a);
                    lat_ms.push((done - a) as f64 / 1000.0);
                }
                if let Some(rec) = trace.as_mut() {
                    rec.span(start * US_TO_PS, (done - start) * US_TO_PS,
                             "chip", "fleet.batch.exec");
                }
            });
            let admitted = core.try_admit(t);
            debug_assert!(admitted, "router admitted, replay must too");
            if let Some(rec) = trace.as_mut() {
                rec.instant(t * US_TO_PS, "chip", "fleet.admit");
                rec.sample(t * US_TO_PS, "fleet.queue_depth",
                           core.pending.len() as f64);
            }
            *peak_pending = (*peak_pending).max(core.pending.len() as u64);
        }
    }

    /// Drain every remaining in-flight/pending batch (replaying past
    /// the end of time with no further arrivals).
    fn flush(&mut self) {
        let Self { core, served, batches, makespan_us, sojourn_us, lat_ms,
                   trace, .. } = self;
        core.advance(u64::MAX, &mut |batch, start, done| {
            *batches += 1;
            *served += batch.len() as u64;
            *makespan_us = (*makespan_us).max(done);
            for &a in batch {
                sojourn_us.observe(done - a);
                lat_ms.push((done - a) as f64 / 1000.0);
            }
            if let Some(rec) = trace {
                rec.span(start * US_TO_PS, (done - start) * US_TO_PS,
                         "chip", "fleet.batch.exec");
            }
        });
    }
}

// --------------------------------------------------------------- results --

/// Per-class aggregation of the fleet run (merged in class order).
#[derive(Debug, Clone)]
pub struct ClassStats {
    pub name: &'static str,
    pub chips: usize,
    pub served: u64,
    pub shed: u64,
    pub batches: u64,
    pub avg_batch: f64,
    pub p99_ms: f64,
    /// per-inference energy of this class, joules
    pub energy_j_per_inf: f64,
    /// `served x energy_j_per_inf`, joules
    pub energy_j_total: f64,
}

/// One fleet simulation's typed outcome.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub policy: RouterPolicy,
    pub chips: usize,
    pub arrivals: u64,
    pub served: u64,
    pub shed: u64,
    pub batches: u64,
    pub shed_rate: f64,
    pub makespan_us: u64,
    /// served throughput over the virtual makespan
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// nearest-rank tail; `None` below the 1000-sample guard
    pub p999_ms: Option<f64>,
    pub per_class: Vec<ClassStats>,
    /// per-chip (served, shed, batches, peak_pending) in chip order —
    /// the determinism fingerprint material
    pub per_chip: Vec<(u64, u64, u64, u64)>,
    pub registry: Registry,
}

/// Order- and thread-invariant digest of a fleet run: fold the exact
/// integer per-chip tallies in chip order. Equal fingerprints at
/// different `--threads` counts is the determinism contract.
pub fn fingerprint(r: &FleetResult) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(r.arrivals);
    mix(r.served);
    mix(r.shed);
    mix(r.batches);
    mix(r.makespan_us);
    for &(served, shed, batches, peak) in &r.per_chip {
        mix(served);
        mix(shed);
        mix(batches);
        mix(peak);
    }
    h
}

/// Fleet run shape (the chip mix is priced separately by
/// [`build_classes`] so sweeps can re-scale it).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// virtual arrivals to stream through the router
    pub arrivals: u64,
    /// diurnal-average offered load as a fraction of fleet capacity
    pub offered: f64,
    pub policy: RouterPolicy,
    /// per-chip admission bound (pending requests)
    pub max_queue_depth: usize,
    pub seed: u64,
    /// diurnal amplitude in [0, 0.95]; 0 disables the profile
    pub diurnal_amp: f64,
    pub diurnal_period_us: u64,
    /// burst rate multiplier (>= 1; 1 disables bursts)
    pub burst_mult: f64,
    /// per-candidate probability of entering a burst
    pub burst_enter: f64,
    /// per-candidate probability of leaving a burst
    pub burst_exit: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            arrivals: 1 << 20,
            offered: 0.9,
            policy: RouterPolicy::LatencyAware,
            max_queue_depth: 256,
            seed: 42,
            diurnal_amp: 0.3,
            diurnal_period_us: 200_000,
            burst_mult: 3.0,
            burst_enter: 0.0005,
            burst_exit: 0.02,
        }
    }
}

/// Simulate the fleet (untraced). See [`run_fleet_traced`] for the
/// recorded variant; both produce identical numbers.
pub fn run_fleet(cfg: &FleetConfig, classes: &[ChipClass]) -> FleetResult {
    run_fleet_inner(cfg, classes, None).0
}

/// [`run_fleet`] with per-chip trace recording: each chip's admission
/// instants, batch spans and queue-depth samples land on its own
/// `chip{i}/{class}/` track prefix, absorbed in chip order (canonical
/// merged trace at any thread count).
pub fn run_fleet_traced(cfg: &FleetConfig, classes: &[ChipClass],
                        filter: Option<&str>)
                        -> (FleetResult, TraceRecorder) {
    let (r, t) = run_fleet_inner(cfg, classes, Some(filter));
    (r, t.expect("traced run returns a recorder"))
}

fn run_fleet_inner(cfg: &FleetConfig, classes: &[ChipClass],
                   trace: Option<Option<&str>>)
                   -> (FleetResult, Option<TraceRecorder>) {
    assert!(cfg.offered.is_finite() && cfg.offered > 0.0,
            "offered load must be positive and finite (got {})",
            cfg.offered);
    assert!(!classes.is_empty(), "fleet needs at least one chip class");
    // chips laid out class-major: chip index -> class index
    let chip_class: Vec<usize> = classes
        .iter()
        .enumerate()
        .flat_map(|(ci, c)| std::iter::repeat_n(ci, c.count))
        .collect();
    let n_chips = chip_class.len();

    // router-pass state: one core per chip + the EWMA sojourn estimate
    // the latency-aware policy reads (initialized to the class's
    // batch-of-1 latency so cold chips look fast, not free)
    let mut cores: Vec<ChipCore> = chip_class
        .iter()
        .map(|&ci| ChipCore::new(&classes[ci], cfg.max_queue_depth))
        .collect();
    let mut ewma_us: Vec<f64> = chip_class
        .iter()
        .map(|&ci| classes[ci].batch_us[0] as f64)
        .collect();
    let mut router_served = vec![0u64; n_chips];
    let mut router_batches = vec![0u64; n_chips];
    let mut shed = vec![0u64; n_chips];

    // detail-pass state: one replay slot per chip, locked only at chunk
    // granularity (each index is touched by exactly one pool closure)
    let details: Vec<Mutex<ChipDetail>> = chip_class
        .iter()
        .map(|&ci| {
            Mutex::new(ChipDetail {
                core: ChipCore::new(&classes[ci], cfg.max_queue_depth),
                class: ci,
                served: 0,
                batches: 0,
                makespan_us: 0,
                peak_pending: 0,
                sojourn_us: Hist::new(),
                lat_ms: Vec::new(),
                trace: trace.map(TraceRecorder::with_filter),
            })
        })
        .collect();

    let mut gen = ArrivalGen::new(
        cfg.seed,
        cfg.offered * capacity_per_us(classes),
        cfg.diurnal_amp,
        cfg.diurnal_period_us,
        cfg.burst_mult,
        cfg.burst_enter,
        cfg.burst_exit,
    );

    let mut bufs: Vec<Vec<u64>> = vec![Vec::new(); n_chips];
    let mut scratch = Vec::with_capacity(n_chips);
    let mut rr: u64 = 0;
    let mut produced = 0u64;
    while produced < cfg.arrivals {
        let n = CHUNK.min((cfg.arrivals - produced) as usize);
        for _ in 0..n {
            let t = gen.next();
            // advance every chip so queue depths and EWMAs are current
            // at the routing instant
            for (i, core) in cores.iter_mut().enumerate() {
                core.advance(t, &mut |batch, _start, done| {
                    router_batches[i] += 1;
                    router_served[i] += batch.len() as u64;
                    let mean_arr = batch.iter().sum::<u64>() as f64
                        / batch.len() as f64;
                    ewma_us[i] = EWMA_ALPHA * (done as f64 - mean_arr)
                        + (1.0 - EWMA_ALPHA) * ewma_us[i];
                });
            }
            let pick = match cfg.policy {
                RouterPolicy::RoundRobin => {
                    let p = (rr % n_chips as u64) as usize;
                    rr += 1;
                    p
                }
                RouterPolicy::JoinShortestQueue => {
                    scratch.clear();
                    scratch.extend(cores.iter().map(|c| c.depth_now() as f64));
                    pick_shortest_f64(&scratch)
                }
                RouterPolicy::LatencyAware => {
                    scratch.clear();
                    scratch.extend(cores.iter().enumerate().map(|(i, c)| {
                        ewma_us[i]
                            + c.depth_now() as f64
                                * classes[chip_class[i]].svc_per_req_us
                    }));
                    pick_cheapest(&scratch)
                }
            };
            if cores[pick].try_admit(t) {
                bufs[pick].push(t);
            } else {
                shed[pick] += 1;
            }
        }
        produced += n as u64;
        // fan the chunk out: chip i replays only its own stream, so any
        // thread count produces the same per-chip evolution
        pool::for_each_indexed(&details, |i, slot| {
            let mut d = slot.lock().expect("chip slot poisoned");
            d.replay(&bufs[i]);
        });
        for b in &mut bufs {
            b.clear();
        }
    }
    // drain the routers' in-flight work so both passes end at the same
    // final state, then flush the replays
    for (i, core) in cores.iter_mut().enumerate() {
        core.advance(u64::MAX, &mut |batch, _s, _d| {
            router_batches[i] += 1;
            router_served[i] += batch.len() as u64;
        });
    }
    pool::for_each_indexed(&details, |_i, slot| {
        slot.lock().expect("chip slot poisoned").flush();
    });

    merge(cfg, classes, &chip_class, details, &router_served,
          &router_batches, &shed, trace.is_some())
}

/// JSQ over f64 depths (shares the scratch buffer with latency-aware);
/// semantics match [`pick_shortest`].
fn pick_shortest_f64(depths: &[f64]) -> usize {
    pick_cheapest(depths)
}

/// Merge per-chip partials in chip order and cross-check the router
/// pass against the replay (the two ran the same machine; any drift is
/// a bug, not noise).
#[allow(clippy::too_many_arguments)]
fn merge(cfg: &FleetConfig, classes: &[ChipClass], chip_class: &[usize],
         details: Vec<Mutex<ChipDetail>>, router_served: &[u64],
         router_batches: &[u64], shed: &[u64], traced: bool)
         -> (FleetResult, Option<TraceRecorder>) {
    let mut per_chip = Vec::with_capacity(details.len());
    let mut class_served = vec![0u64; classes.len()];
    let mut class_shed = vec![0u64; classes.len()];
    let mut class_batches = vec![0u64; classes.len()];
    let mut class_lat: Vec<Vec<f64>> = vec![Vec::new(); classes.len()];
    let mut lat_ms: Vec<f64> = Vec::new();
    let mut sojourn = Hist::new();
    let mut makespan = 0u64;
    let mut combined = traced.then(TraceRecorder::new);
    for (i, slot) in details.into_iter().enumerate() {
        let mut d = slot.into_inner().expect("chip slot poisoned");
        assert_eq!(
            (d.served, d.batches), (router_served[i], router_batches[i]),
            "chip {i}: replay diverged from the router pass"
        );
        let ci = d.class;
        class_served[ci] += d.served;
        class_shed[ci] += shed[i];
        class_batches[ci] += d.batches;
        class_lat[ci].extend_from_slice(&d.lat_ms);
        lat_ms.append(&mut d.lat_ms);
        sojourn.merge(&d.sojourn_us);
        makespan = makespan.max(d.makespan_us);
        per_chip.push((d.served, shed[i], d.batches, d.peak_pending));
        if let (Some(acc), Some(rec)) = (&mut combined, d.trace.take()) {
            acc.absorb(&format!("chip{i}/{}/", classes[ci].name), rec);
        }
    }
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served: u64 = per_chip.iter().map(|c| c.0).sum();
    let shed_total: u64 = shed.iter().sum();
    let batches: u64 = per_chip.iter().map(|c| c.2).sum();

    let mut registry = Registry::new();
    registry.add("fleet.served", served);
    registry.add("fleet.shed", shed_total);
    registry.add("fleet.batches", batches);
    registry.merge_hist("fleet.sojourn_us", &sojourn);
    for &(_, _, _, peak) in &per_chip {
        registry.gauge_max("fleet.peak_pending", peak);
    }
    let per_class: Vec<ClassStats> = classes
        .iter()
        .enumerate()
        .map(|(ci, c)| {
            // typed per-class shed counters (the admission metrics the
            // router's bounded queues produce)
            registry.add(&format!("fleet.shed.{}", c.name), class_shed[ci]);
            let mut l = std::mem::take(&mut class_lat[ci]);
            l.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ClassStats {
                name: c.name,
                chips: c.count,
                served: class_served[ci],
                shed: class_shed[ci],
                batches: class_batches[ci],
                avg_batch: class_served[ci] as f64
                    / class_batches[ci].max(1) as f64,
                p99_ms: stats::percentile_sorted(&l, 99.0),
                energy_j_per_inf: c.energy_j_per_inf,
                energy_j_total: class_served[ci] as f64 * c.energy_j_per_inf,
            }
        })
        .collect();
    let result = FleetResult {
        policy: cfg.policy,
        chips: chip_class.len(),
        arrivals: cfg.arrivals,
        served,
        shed: shed_total,
        batches,
        shed_rate: shed_total as f64 / (served + shed_total).max(1) as f64,
        makespan_us: makespan,
        throughput_rps: served as f64 / (makespan.max(1) as f64 * 1e-6),
        mean_ms: stats::mean(&lat_ms),
        p50_ms: stats::percentile_sorted(&lat_ms, 50.0),
        p99_ms: stats::percentile_sorted(&lat_ms, 99.0),
        p999_ms: stats::tail_percentile_sorted(&lat_ms, 99.9),
        per_class,
        per_chip,
        registry,
    };
    (result, combined)
}

// ------------------------------------------------------------ knee sweep --

/// One point of the chip-count sweep.
#[derive(Debug, Clone)]
pub struct KneePoint {
    /// total chips at this scale
    pub chips: usize,
    /// mix scale factor applied to the base fleet
    pub scale: f64,
    /// offered load rescaled so the absolute arrival rate matches the
    /// base fleet's
    pub offered: f64,
    pub p99_ms: f64,
    pub shed_rate: f64,
}

/// Sweep the fleet size at a fixed absolute arrival rate (the base
/// mix's `offered x capacity`), scaling every class count by the fixed
/// factors below, and report the knee: the smallest fleet whose p99 is
/// within 5% of the largest fleet's. Adding chips past the knee stops
/// buying tail latency.
pub fn knee_sweep(cfg: &FleetConfig, net: &Network,
                  mix: &[(Architecture, usize)], max_batch: usize,
                  arrivals: u64) -> (Vec<KneePoint>, usize) {
    const SCALES: [f64; 6] = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5];
    let base = build_classes(net, mix, max_batch);
    let base_rate = cfg.offered * capacity_per_us(&base);
    let mut points: Vec<KneePoint> = SCALES
        .iter()
        .map(|&scale| {
            let scaled: Vec<(Architecture, usize)> = mix
                .iter()
                .map(|&(a, c)| {
                    (a, ((c as f64 * scale).round() as usize).max(1))
                })
                .collect();
            let classes = build_classes(net, &scaled, max_batch);
            let offered = base_rate / capacity_per_us(&classes);
            let r = run_fleet(
                &FleetConfig { arrivals, offered, ..cfg.clone() },
                &classes,
            );
            KneePoint {
                chips: r.chips,
                scale,
                offered,
                p99_ms: r.p99_ms,
                shed_rate: r.shed_rate,
            }
        })
        .collect();
    points.sort_by_key(|p| p.chips);
    points.dedup_by_key(|p| p.chips);
    let floor = points.last().expect("non-empty sweep").p99_ms;
    let knee = points
        .iter()
        .find(|p| p.p99_ms <= floor * 1.05)
        .expect("the largest fleet is within 5% of itself")
        .chips;
    (points, knee)
}

/// The `--fleet` spec rendered back in registry names (stable JSON
/// surface for outcomes and benches).
pub fn mix_string(mix: &[(Architecture, usize)]) -> String {
    mix.iter()
        .map(|&(a, c)| format!("{}:{c}", model::cost_model(a).name()))
        .collect::<Vec<_>>()
        .join(",")
}

/// `BENCH_fleet.json`-shaped summary of one run (shared by the bench
/// and ad-hoc tooling).
pub fn result_json(r: &FleetResult) -> json::Json {
    json::obj(vec![
        ("policy", json::Json::Str(r.policy.name().into())),
        ("chips", json::Json::Num(r.chips as f64)),
        ("arrivals", json::Json::Num(r.arrivals as f64)),
        ("served", json::Json::Num(r.served as f64)),
        ("shed", json::Json::Num(r.shed as f64)),
        ("shed_rate", json::Json::Num(r.shed_rate)),
        ("throughput_rps", json::Json::Num(r.throughput_rps)),
        ("p50_ms", json::Json::Num(r.p50_ms)),
        ("p99_ms", json::Json::Num(r.p99_ms)),
        ("p999_ms", match r.p999_ms {
            Some(v) => json::Json::Num(v),
            None => json::Json::Null,
        }),
        ("fingerprint", json::Json::Str(format!("{:016x}", fingerprint(r)))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::workloads;

    fn mix() -> Vec<(Architecture, usize)> {
        parse_fleet("neural-pim:2,isaac:1").unwrap()
    }

    fn small_cfg() -> FleetConfig {
        FleetConfig { arrivals: 4_096, ..Default::default() }
    }

    #[test]
    fn parse_fleet_accepts_aliases_and_rejects_garbage() {
        let m = parse_fleet("neural-pim:8, isaac:4,cascade:2,lowres:2")
            .unwrap();
        assert_eq!(m.len(), 4);
        assert_eq!(m[0], (Architecture::NeuralPim, 8));
        assert_eq!(m[1], (Architecture::IsaacLike, 4));
        // a bare name means one chip
        assert_eq!(parse_fleet("pim").unwrap(),
                   vec![(Architecture::NeuralPim, 1)]);
        assert!(parse_fleet("").is_err());
        assert!(parse_fleet("neural-pim:0").is_err());
        assert!(parse_fleet("neural-pim:x").is_err());
        let err = parse_fleet("isac:4").unwrap_err().to_string();
        assert!(err.contains("did you mean"), "{err}");
    }

    #[test]
    fn router_policy_parses_names_and_aliases() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(),
                   RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("JSQ").unwrap(),
                   RouterPolicy::JoinShortestQueue);
        assert_eq!(RouterPolicy::parse("latency-aware").unwrap(),
                   RouterPolicy::LatencyAware);
        let err = RouterPolicy::parse("latency-awar").unwrap_err()
            .to_string();
        assert!(err.contains("did you mean"), "{err}");
    }

    #[test]
    fn jsq_never_picks_deeper_than_the_minimum() {
        prop::check("jsq_picks_a_minimum", 500, |g| {
            let n = g.usize_in(1, 32);
            let depths = g.vec_usize(n, 0, 512);
            let pick = pick_shortest(&depths);
            let min = *depths.iter().min().unwrap();
            crate::prop_assert!(
                depths[pick] == min,
                "picked depth {} but the minimum is {min} ({depths:?})",
                depths[pick]
            );
            // ties break to the lowest index (determinism, not luck)
            crate::prop_assert!(
                depths[..pick].iter().all(|&d| d > min),
                "skipped an earlier minimum in {depths:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn cheapest_pick_is_a_minimum_with_low_index_ties() {
        prop::check("cheapest_picks_a_minimum", 500, |g| {
            let n = g.usize_in(1, 32);
            let est = g.vec_f64(n, 0.0, 1e6);
            let pick = pick_cheapest(&est);
            crate::prop_assert!(
                est.iter().all(|&e| e >= est[pick]),
                "pick {pick} is not a minimum of {est:?}"
            );
            crate::prop_assert!(
                est[..pick].iter().all(|&e| e > est[pick]),
                "pick {pick} skipped an earlier minimum in {est:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn round_robin_is_exactly_fair_over_full_cycles() {
        // huge depth: nothing sheds, so assignment counts are pure
        // policy behaviour; arrivals = k x chips for whole cycles
        let net = workloads::synthetic_cnn();
        let classes = build_classes(&net, &mix(), 8);
        let chips: usize = classes.iter().map(|c| c.count).sum();
        let cfg = FleetConfig {
            arrivals: (chips * 64) as u64,
            policy: RouterPolicy::RoundRobin,
            max_queue_depth: 1 << 20,
            ..small_cfg()
        };
        let r = run_fleet(&cfg, &classes);
        assert_eq!(r.shed, 0);
        for &(served, _, _, _) in &r.per_chip {
            assert_eq!(served, 64, "round-robin skew: {:?}", r.per_chip);
        }
    }

    #[test]
    fn fleet_conserves_every_arrival() {
        for policy in [RouterPolicy::RoundRobin,
                       RouterPolicy::JoinShortestQueue,
                       RouterPolicy::LatencyAware] {
            let net = workloads::synthetic_cnn();
            let classes = build_classes(&net, &mix(), 16);
            let cfg = FleetConfig { policy, ..small_cfg() };
            let r = run_fleet(&cfg, &classes);
            assert_eq!(r.served + r.shed, r.arrivals, "{policy:?}");
            let chip_served: u64 = r.per_chip.iter().map(|c| c.0).sum();
            assert_eq!(chip_served, r.served, "{policy:?}");
            let class_served: u64 =
                r.per_class.iter().map(|c| c.served).sum();
            assert_eq!(class_served, r.served, "{policy:?}");
        }
    }

    #[test]
    fn identical_chips_match_independent_single_chip_runs() {
        // N identical chips under round-robin at offered load L vs one
        // chip at L: per-chip behaviour must agree within tolerance
        // (streams differ, physics must not)
        let net = workloads::synthetic_cnn();
        let n = 4;
        let one = build_classes(
            &net, &[(Architecture::NeuralPim, 1)], 16);
        let many = build_classes(
            &net, &[(Architecture::NeuralPim, n)], 16);
        let cfg = FleetConfig {
            arrivals: 8_192,
            offered: 0.7,
            policy: RouterPolicy::RoundRobin,
            diurnal_amp: 0.0,
            burst_mult: 1.0,
            ..small_cfg()
        };
        let rn = run_fleet(&cfg, &many);
        let r1 = run_fleet(
            &FleetConfig { arrivals: cfg.arrivals / n as u64, ..cfg },
            &one,
        );
        // same offered utilization per chip: mean sojourn within 20%
        let rel = (rn.mean_ms - r1.mean_ms).abs() / r1.mean_ms.max(1e-9);
        assert!(rel < 0.2,
                "fleet mean {} vs single-chip mean {} ({rel:.3} apart)",
                rn.mean_ms, r1.mean_ms);
        // and per-chip served counts split evenly under round-robin
        for &(served, _, _, _) in &rn.per_chip {
            let want = rn.served as f64 / n as f64;
            assert!((served as f64 - want).abs() <= want * 0.01 + 1.0,
                    "uneven split: {:?}", rn.per_chip);
        }
    }

    #[test]
    fn traced_run_matches_plain_and_prefixes_per_chip_tracks() {
        let net = workloads::synthetic_cnn();
        let classes = build_classes(&net, &mix(), 8);
        let cfg = small_cfg();
        let plain = run_fleet(&cfg, &classes);
        let (traced, trace) = run_fleet_traced(&cfg, &classes, None);
        assert_eq!(fingerprint(&plain), fingerprint(&traced));
        assert!(!trace.is_empty());
        assert!(trace.tracks().iter().any(|t| t.starts_with("chip0/")),
                "{:?}", trace.tracks());
        // filtered tracing also leaves numbers untouched
        let (filtered, ft) =
            run_fleet_traced(&cfg, &classes, Some("fleet.batch"));
        assert_eq!(fingerprint(&plain), fingerprint(&filtered));
        assert!(ft.len() < trace.len());
        assert!(!ft.is_empty());
    }

    #[test]
    fn arrival_gen_is_deterministic_and_monotonic() {
        let mut a = ArrivalGen::new(7, 0.01, 0.3, 200_000, 3.0, 0.001, 0.02);
        let mut b = ArrivalGen::new(7, 0.01, 0.3, 200_000, 3.0, 0.001, 0.02);
        let mut last = 0;
        for _ in 0..2_000 {
            let t = a.next();
            assert_eq!(t, b.next());
            assert!(t >= last, "arrivals went backwards");
            last = t;
        }
        // a different seed is a different trace
        let mut c = ArrivalGen::new(8, 0.01, 0.3, 200_000, 3.0, 0.001, 0.02);
        let same = (0..64).all(|_| {
            ArrivalGen::next(&mut c) == ArrivalGen::next(&mut a)
        });
        assert!(!same);
    }

    #[test]
    fn knee_sweep_reports_a_knee_inside_the_sweep() {
        let net = workloads::synthetic_cnn();
        let cfg = FleetConfig { arrivals: 2_048, ..small_cfg() };
        let (points, knee) = knee_sweep(&cfg, &net, &mix(), 8, 2_048);
        assert!(points.len() >= 3, "degenerate sweep: {points:?}");
        assert!(points.windows(2).all(|w| w[0].chips < w[1].chips));
        assert!(points.iter().any(|p| p.chips == knee),
                "knee {knee} not a sweep point: {points:?}");
        // more chips at a fixed absolute rate never raises the shed
        // rate beyond noise at the small end
        let first = points.first().unwrap();
        let last = points.last().unwrap();
        assert!(last.shed_rate <= first.shed_rate + 1e-9,
                "shedding grew with fleet size: {points:?}");
    }
}
