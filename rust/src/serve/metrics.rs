//! Serving metrics: lock-light atomic counters on the hot path, reduced
//! on demand into a typed [`MetricsSnapshot`] — counters, batch-fill and
//! pad fraction, mean exec/queue latency, and p50/p95/p99 over a bounded
//! latency window — with `Display` (the exact one-line summary the CLI
//! has always printed) and [`MetricsSnapshot::to_json`] for the scenario
//! layer's typed outcomes. The old `summary() -> String` API is gone:
//! renderers format the snapshot, machines read its fields.

use crate::util::json::{self, Json};
use crate::util::stats;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Sliding window of per-request latencies retained for the percentile
/// summary (bounds memory on long-running deployments).
pub const LATENCY_WINDOW: usize = 16_384;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    /// requests whose batch execution failed (error responses sent)
    pub failed: AtomicU64,
    /// requests refused at admission (bounded queue depth exceeded)
    pub shed: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    pub exec_us_total: AtomicU64,
    pub queue_us_total: AtomicU64,
    /// most recent per-request total latencies (µs), capped at
    /// [`LATENCY_WINDOW`]; powers the snapshot percentiles — the same
    /// `util::stats::percentile` path the event simulator's
    /// request-level mode reports through
    pub lat_us: Mutex<VecDeque<u64>>,
    /// most recent batch-failure cause, surfaced on the snapshot instead
    /// of stderr chatter interleaving with suite/JSON output
    last_error: Mutex<Option<String>>,
}

impl Metrics {
    /// Record one served request's total (queue + exec) latency.
    pub fn record_latency_us(&self, us: u64) {
        if let Ok(mut w) = self.lat_us.lock() {
            if w.len() == LATENCY_WINDOW {
                w.pop_front();
            }
            w.push_back(us);
        }
    }

    /// Record a batch-failure cause (kept: the most recent one).
    pub fn note_error(&self, msg: &str) {
        if let Ok(mut e) = self.last_error.lock() {
            *e = Some(msg.to_string());
        }
    }

    /// Sorted snapshot of the latency window, in milliseconds (one lock
    /// acquisition + one sort, however many percentiles are read off it).
    fn latency_snapshot_ms(&self) -> Vec<f64> {
        let mut lat: Vec<f64> = match self.lat_us.lock() {
            Ok(w) => w.iter().map(|&u| u as f64 / 1000.0).collect(),
            Err(_) => return Vec::new(),
        };
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        lat
    }

    /// Percentile over the retained latency window, in milliseconds.
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        stats::percentile_sorted(&self.latency_snapshot_ms(), p)
    }

    /// Reduce the live counters into one coherent typed view.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let padded_slots = self.padded_slots.load(Ordering::Relaxed);
        let slots = requests + padded_slots;
        let lat = self.latency_snapshot_ms();
        MetricsSnapshot {
            requests,
            failed: self.failed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches,
            padded_slots,
            avg_batch: requests as f64 / batches.max(1) as f64,
            pad_frac: if slots == 0 {
                0.0
            } else {
                padded_slots as f64 / slots as f64
            },
            avg_exec_ms: self.exec_us_total.load(Ordering::Relaxed) as f64
                / batches.max(1) as f64
                / 1000.0,
            avg_queue_ms: self.queue_us_total.load(Ordering::Relaxed) as f64
                / requests.max(1) as f64
                / 1000.0,
            lat_p50_ms: stats::percentile_sorted(&lat, 50.0),
            lat_p95_ms: stats::percentile_sorted(&lat, 95.0),
            lat_p99_ms: stats::percentile_sorted(&lat, 99.0),
            lat_p999_ms: stats::tail_percentile_sorted(&lat, 99.9),
            last_error: self.last_error.lock().ok().and_then(|e| e.clone()),
        }
    }
}

/// One coherent read of the serving metrics. `Display` renders the
/// historical one-line summary (byte-identical when nothing was shed, so
/// the PJRT `serve` scenario text stays golden); `to_json` is the typed
/// form the scenario layer embeds in outcomes.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub failed: u64,
    pub shed: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub avg_batch: f64,
    pub pad_frac: f64,
    pub avg_exec_ms: f64,
    pub avg_queue_ms: f64,
    pub lat_p50_ms: f64,
    pub lat_p95_ms: f64,
    pub lat_p99_ms: f64,
    /// nearest-rank p99.9 over the latency window; `None` below the
    /// `stats::tail_min_samples` guard (JSON/field only — `Display`
    /// keeps the historical line)
    pub lat_p999_ms: Option<f64>,
    /// most recent batch-failure cause (JSON/field only — never printed
    /// by `Display`, so stdout stays renderable)
    pub last_error: Option<String>,
}

impl fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "requests={} failed={} batches={} avg_batch={:.1} \
             pad_frac={:.3} avg_exec={:.2}ms avg_queue={:.2}ms \
             lat_p50={:.2}ms lat_p99={:.2}ms",
            self.requests,
            self.failed,
            self.batches,
            self.avg_batch,
            self.pad_frac,
            self.avg_exec_ms,
            self.avg_queue_ms,
            self.lat_p50_ms,
            self.lat_p99_ms,
        )?;
        if self.shed > 0 {
            write!(f, " shed={}", self.shed)?;
        }
        Ok(())
    }
}

impl MetricsSnapshot {
    /// Fold the snapshot's integer tallies into an observability
    /// registry under the `serve.live.` namespace (the live
    /// coordinator's counterpart of the virtual-time load generator's
    /// `serve.*` keys).
    pub fn fill_registry(&self, reg: &mut crate::obs::Registry) {
        reg.add("serve.live.requests", self.requests);
        reg.add("serve.live.failed", self.failed);
        reg.add("serve.live.shed", self.shed);
        reg.add("serve.live.batches", self.batches);
        reg.add("serve.live.padded_slots", self.padded_slots);
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("padded_slots", Json::Num(self.padded_slots as f64)),
            ("avg_batch", Json::Num(self.avg_batch)),
            ("pad_frac", Json::Num(self.pad_frac)),
            ("avg_exec_ms", Json::Num(self.avg_exec_ms)),
            ("avg_queue_ms", Json::Num(self.avg_queue_ms)),
            ("lat_p50_ms", Json::Num(self.lat_p50_ms)),
            ("lat_p95_ms", Json::Num(self.lat_p95_ms)),
            ("lat_p99_ms", Json::Num(self.lat_p99_ms)),
        ];
        if let Some(p) = self.lat_p999_ms {
            pairs.push(("lat_p999_ms", Json::Num(p)));
        }
        if let Some(e) = &self.last_error {
            pairs.push(("last_error", Json::Str(e.clone())));
        }
        json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_display_formats_like_the_old_summary() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        let s = m.snapshot().to_string();
        assert!(s.contains("requests=10"));
        assert!(s.contains("avg_batch=5.0"));
        assert!(s.contains("failed=0"));
        // nothing shed, nothing failed: the historical format exactly
        assert!(!s.contains("shed="), "{s}");
        assert!(s.ends_with("ms"), "{s}");
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::default();
        // empty window: percentiles report 0 (callers see an idle server)
        assert_eq!(m.latency_percentile_ms(50.0), 0.0);
        for us in [1000u64, 2000, 3000, 4000] {
            m.record_latency_us(us);
        }
        assert!((m.latency_percentile_ms(50.0) - 2.5).abs() < 1e-9);
        assert!((m.latency_percentile_ms(100.0) - 4.0).abs() < 1e-9);
        let snap = m.snapshot();
        assert!((snap.lat_p50_ms - 2.5).abs() < 1e-9);
        assert!(snap.lat_p50_ms <= snap.lat_p95_ms);
        assert!(snap.lat_p95_ms <= snap.lat_p99_ms);
        let s = snap.to_string();
        assert!(s.contains("lat_p50=2.50ms"), "{s}");
        assert!(s.contains("lat_p99="), "{s}");
    }

    #[test]
    fn latency_window_is_bounded() {
        let m = Metrics::default();
        for us in 0..(LATENCY_WINDOW as u64 + 100) {
            m.record_latency_us(us);
        }
        let w = m.lat_us.lock().unwrap();
        assert_eq!(w.len(), LATENCY_WINDOW);
        // the oldest 100 samples were evicted
        assert_eq!(*w.front().unwrap(), 100);
    }

    #[test]
    fn pad_frac_zero_when_unserved() {
        // regression: the old max(1) clamp reported a bogus fraction for
        // an idle coordinator
        let m = Metrics::default();
        assert_eq!(m.snapshot().pad_frac, 0.0);
        m.padded_slots.store(3, Ordering::Relaxed);
        m.requests.store(1, Ordering::Relaxed);
        assert!((m.snapshot().pad_frac - 0.75).abs() < 1e-12);
        assert!(m.snapshot().to_string().contains("pad_frac=0.750"));
    }

    #[test]
    fn snapshot_folds_into_a_registry() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.shed.store(1, Ordering::Relaxed);
        let mut reg = crate::obs::Registry::new();
        m.snapshot().fill_registry(&mut reg);
        assert_eq!(reg.counter("serve.live.requests"), 10);
        assert_eq!(reg.counter("serve.live.batches"), 2);
        assert_eq!(reg.counter("serve.live.shed"), 1);
        // zero tallies still materialize (snapshots are comparable)
        assert_eq!(reg.counter("serve.live.failed"), 0);
        assert!(reg.counters().any(|(n, _)| n == "serve.live.failed"));
    }

    #[test]
    fn shed_and_last_error_surface_on_the_snapshot() {
        let m = Metrics::default();
        m.shed.store(7, Ordering::Relaxed);
        m.note_error("boom");
        let snap = m.snapshot();
        assert_eq!(snap.shed, 7);
        assert_eq!(snap.last_error.as_deref(), Some("boom"));
        // shed shows in Display, the error only in the typed forms
        let s = snap.to_string();
        assert!(s.contains("shed=7"), "{s}");
        assert!(!s.contains("boom"), "{s}");
        let j = snap.to_json();
        assert_eq!(j.get("shed").and_then(Json::as_f64), Some(7.0));
        assert_eq!(j.get("last_error").and_then(Json::as_str), Some("boom"));
        // absent error omits the key (readers ignore unknown keys anyway)
        assert!(Metrics::default().snapshot().to_json().get("last_error")
            .is_none());
    }
}
