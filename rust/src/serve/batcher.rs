//! Dynamic batcher: collect up to `max_batch` requests, waiting at most
//! `max_wait` after the first arrival — the standard serving trade-off
//! between batch efficiency and tail latency.
//!
//! Collection runs against the multi-consumer [`SharedQueue`], so N
//! workers can each be inside `collect` at once: the first-request wait
//! and the fill window both release the queue lock while blocked (the old
//! `Mutex<mpsc::Receiver>` design held the lock across both, serializing
//! every worker on one batch collection).

use super::queue::{FillPop, SharedQueue};
use super::Request;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

pub struct Batcher {
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy }
    }

    /// Collect one batch. Returns None when the queue is closed and fully
    /// drained (shutdown).
    pub fn collect(&self, q: &SharedQueue<Request>) -> Option<Vec<Request>> {
        let first = q.pop_wait()?;
        let mut out = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while out.len() < self.policy.max_batch {
            match q.pop_surplus_until(deadline) {
                FillPop::Item(r) => out.push(r),
                FillPop::TimedOut | FillPop::Closed => break,
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use std::sync::{mpsc, Arc, Mutex};

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            image: vec![0.0; 4],
            respond: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn batch_respects_capacity() {
        let q = SharedQueue::new();
        for i in 0..10 {
            assert!(q.push(req(i)).is_ok());
        }
        let b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        });
        let got = b.collect(&q).unwrap();
        assert_eq!(got.len(), 4);
        // FIFO order preserved
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn drains_remaining_after_close() {
        let q = SharedQueue::new();
        for i in 0..3 {
            assert!(q.push(req(i)).is_ok());
        }
        q.close();
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        });
        assert_eq!(b.collect(&q).unwrap().len(), 3);
        assert!(b.collect(&q).is_none());
    }

    #[test]
    fn property_never_exceeds_capacity_and_fifo() {
        prop::check("batcher capacity + FIFO", 50, |g| {
            let cap = g.usize_in(1, 16);
            let n = g.usize_in(1, 64);
            let q = SharedQueue::new();
            for i in 0..n {
                crate::prop_assert!(q.push(req(i as u64)).is_ok(), "closed");
            }
            q.close();
            let b = Batcher::new(BatchPolicy {
                max_batch: cap,
                max_wait: Duration::from_millis(0),
            });
            let mut seen = Vec::new();
            while let Some(batch) = b.collect(&q) {
                crate::prop_assert!(batch.len() <= cap, "over capacity");
                crate::prop_assert!(!batch.is_empty(), "empty batch");
                seen.extend(batch.iter().map(|r| r.id));
            }
            crate::prop_assert!(
                seen == (0..n as u64).collect::<Vec<_>>(),
                "lost or reordered requests: {:?}", seen
            );
            Ok(())
        });
    }

    /// BatchPolicy invariants under *concurrent* pushers (the satellite
    /// property suite): `collect` never exceeds `max_batch`, never
    /// returns an empty batch while the queue is open, loses nothing,
    /// and preserves each pusher's submission order (the shared queue is
    /// FIFO in push order, so a pusher's requests fill batches oldest
    /// first — the `max_wait` window widens a batch, never reorders it).
    /// Schedules are seeded via `Pcg::fork`, and every asserted property
    /// is interleaving-independent, so the verdict is identical however
    /// the threads race (`--threads 1/2/8` alike).
    #[test]
    fn property_invariants_hold_under_concurrent_pushers() {
        prop::check("batcher under concurrent pushers", 12, |g| {
            let cap = g.usize_in(1, 9);
            let pushers = g.usize_in(1, 4);
            let per = g.usize_in(3, 40);
            let mut root = Pcg::new(g.u64());
            let q = Arc::new(SharedQueue::new());
            let mut handles = Vec::new();
            for pu in 0..pushers {
                let q = q.clone();
                let mut rng = root.fork(pu as u64);
                handles.push(std::thread::spawn(move || {
                    for k in 0..per {
                        // id encodes (pusher, sequence) for order checks
                        let id = (pu * 1_000_000 + k) as u64;
                        if rng.below(3) == 0 {
                            std::thread::yield_now();
                        }
                        assert!(q.push(req(id)).is_ok(), "queue closed early");
                    }
                }));
            }
            let b = Batcher::new(BatchPolicy {
                max_batch: cap,
                max_wait: Duration::from_millis(1),
            });
            let mut seen: Vec<u64> = Vec::new();
            while seen.len() < pushers * per {
                // the queue is open, so collect must yield a batch
                let batch = match b.collect(&q) {
                    Some(batch) => batch,
                    None => return Err("collect None on open queue".into()),
                };
                crate::prop_assert!(!batch.is_empty(),
                                    "empty batch from open queue");
                crate::prop_assert!(batch.len() <= cap, "over capacity");
                seen.extend(batch.iter().map(|r| r.id));
            }
            for h in handles {
                h.join().unwrap();
            }
            q.close();
            crate::prop_assert!(seen.len() == pushers * per, "lost requests");
            for pu in 0..pushers as u64 {
                let mine: Vec<u64> = seen
                    .iter()
                    .copied()
                    .filter(|id| id / 1_000_000 == pu)
                    .collect();
                crate::prop_assert!(
                    mine == (0..per as u64).map(|k| pu * 1_000_000 + k)
                        .collect::<Vec<_>>(),
                    "pusher {pu} reordered: {mine:?}"
                );
            }
            Ok(())
        });
    }

    /// Regression for the multi-worker scaling bug: with the old
    /// `Mutex<Receiver>` hand-off, worker A held the lock for its whole
    /// `max_wait` fill window, absorbed every arrival, and worker B never
    /// collected a batch. With the shared queue + idle-waiter priority,
    /// a request arriving during A's fill window starts a batch on B.
    #[test]
    fn two_workers_collect_concurrently_under_light_load() {
        let q = Arc::new(SharedQueue::new());
        let per_worker: Arc<Mutex<Vec<Vec<u64>>>> =
            Arc::new(Mutex::new(vec![Vec::new(), Vec::new()]));
        let mut handles = Vec::new();
        for w in 0..2usize {
            let q = q.clone();
            let per_worker = per_worker.clone();
            handles.push(std::thread::spawn(move || {
                let b = Batcher::new(BatchPolicy {
                    max_batch: 8,
                    // long window: if collection serialized, the second
                    // request would be absorbed into the first batch
                    max_wait: Duration::from_secs(10),
                });
                while let Some(batch) = b.collect(&q) {
                    per_worker.lock().unwrap()[w]
                        .extend(batch.iter().map(|r| r.id));
                }
            }));
        }
        let wait_for_idle = |n: usize| {
            while q.idle_waiters() != n {
                std::thread::sleep(Duration::from_millis(1));
            }
        };
        wait_for_idle(2); // both workers waiting for a first request
        assert!(q.push(req(0)).is_ok());
        wait_for_idle(1); // one worker took it and is now filling
        assert!(q.push(req(1)).is_ok()); // must go to the *idle* worker
        wait_for_idle(0);
        q.close(); // flush both partial batches
        for h in handles {
            h.join().unwrap();
        }
        let got = per_worker.lock().unwrap();
        assert_eq!(got[0].len(), 1, "worker 0 got {:?}", got);
        assert_eq!(got[1].len(), 1, "worker 1 got {:?}", got);
    }
}
