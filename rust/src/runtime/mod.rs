//! PJRT runtime: load AOT-compiled HLO text artifacts, compile them once
//! on the CPU PJRT client, and execute them from the request path.
//!
//! Interchange is HLO *text* (aot.py's output): xla_extension 0.5.1
//! rejects jax>=0.5 serialized protos (64-bit instruction ids), while the
//! text parser reassigns ids cleanly. All artifacts were lowered with
//! `return_tuple=True`, so every result is a tuple literal.
//!
//! Construction discipline: code outside this module and `serve/` opens
//! runtimes through `serve::open_runtime` (grep-gated by
//! `scripts/verify.sh`), so the serving stack never re-welds itself to
//! direct PJRT construction behind the `InferenceBackend` trait's back.

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// wall time spent compiling, for the perf log
    pub compile_seconds: f64,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?
            .to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Like [`Executable::run`] but borrowing the inputs.
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?
            .to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Artifact directory + PJRT client + compiled-executable cache.
///
/// PJRT objects are thread-local (Rc-based in the xla crate): a Runtime
/// must be created and used on one thread. The coordinator gives every
/// worker thread its own Runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: &str) -> Result<Runtime> {
        let dir = PathBuf::from(artifact_dir);
        if !dir.join("manifest.json").exists() {
            anyhow::bail!(
                "no artifacts at {}; run `make artifacts` first",
                dir.display()
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.artifact_path(name);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = Rc::new(Executable {
            name: name.to_string(),
            exe,
            compile_seconds: t0.elapsed().as_secs_f64(),
        });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_key(seed: u64) -> Result<xla::Literal> {
    let data = [(seed >> 32) as u32, seed as u32];
    Ok(xla::Literal::vec1(&data[..]).reshape(&[2])?)
}

pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// The test set dumped by aot.py (testset.bin + testset.json).
pub struct TestSet {
    pub images: Vec<f32>, // n * h * w * c, u8-valued
    pub labels: Vec<i32>,
    pub n: usize,
    pub dims: (usize, usize, usize),
}

impl TestSet {
    pub fn load(dir: &Path) -> Result<TestSet> {
        let meta_text = std::fs::read_to_string(dir.join("testset.json"))?;
        let meta = crate::util::json::Json::parse(&meta_text)
            .map_err(|e| anyhow!("{e}"))?;
        let n = meta.get("n").and_then(|v| v.as_usize()).unwrap_or(0);
        let h = meta.get("height").and_then(|v| v.as_usize()).unwrap_or(0);
        let w = meta.get("width").and_then(|v| v.as_usize()).unwrap_or(0);
        let c = meta.get("channels").and_then(|v| v.as_usize()).unwrap_or(0);
        let raw = std::fs::read(dir.join("testset.bin"))?;
        let n_img = n * h * w * c;
        anyhow::ensure!(raw.len() == n_img + 4 * n, "testset.bin size mismatch");
        let images: Vec<f32> = raw[..n_img].iter().map(|&b| b as f32).collect();
        let labels: Vec<i32> = raw[n_img..]
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(TestSet { images, labels, n, dims: (h, w, c) })
    }

    /// One batch of `batch` images as a literal (padded by repetition).
    pub fn batch_literal(&self, start: usize, batch: usize) -> Result<xla::Literal> {
        let (h, w, c) = self.dims;
        let stride = h * w * c;
        let mut data = Vec::with_capacity(batch * stride);
        for i in 0..batch {
            let idx = (start + i) % self.n;
            data.extend_from_slice(&self.images[idx * stride..(idx + 1) * stride]);
        }
        lit_f32(&data, &[batch as i64, h as i64, w as i64, c as i64])
    }

    pub fn batch_labels(&self, start: usize, batch: usize) -> Vec<i32> {
        (0..batch).map(|i| self.labels[(start + i) % self.n]).collect()
    }
}

/// Accuracy of logits against labels.
pub fn accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * classes);
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        // first-maximum argmax (ties resolve to the lower class index)
        let mut pred = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[pred] {
                pred = j;
            }
        }
        if pred as i32 == label {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = vec![0.1, 0.9, 0.8, 0.2, 0.5, 0.5];
        let labels = vec![1, 0, 0];
        // row 2 ties -> argmax picks first (0), counts as correct
        assert!((accuracy(&logits, &labels, 2) - 1.0).abs() < 1e-12);
        let labels = vec![0, 0, 1];
        // row0 pred=1, row1 pred=0 (correct), row2 pred=0 -> 1/3
        assert!((accuracy(&logits, &labels, 2) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn key_literal_shape() {
        let k = lit_key(0xdead_beef_cafe_f00d).unwrap();
        assert_eq!(k.element_count(), 2);
        let v = k.to_vec::<u32>().unwrap();
        assert_eq!(v, vec![0xdead_beef, 0xcafe_f00d]);
    }
}
