//! Content-addressed results store: `results/<scenario>/<fingerprint>.json`.
//!
//! The fingerprint covers everything that determines a scenario's
//! output — scenario name, canonical (fully-defaulted) params, the
//! crate version, and any extra content the scenario declares (e.g. the
//! bytes of a `--network-file` spec) — so a hit can be replayed without
//! recompute and a stale entry can never be served after the model
//! changes. Writes are atomic (temp file + rename), so concurrent suite
//! entries with the same fingerprint cannot tear each other's files.

use super::outcome::Outcome;
use super::Params;
use crate::util::json::Json;
use crate::util::num::fnv1a64;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Default store root: `$NEURAL_PIM_RESULTS` or `results/` in the CWD.
pub fn default_root() -> String {
    std::env::var("NEURAL_PIM_RESULTS").unwrap_or_else(|_| "results".into())
}

/// The content address of one scenario invocation.
pub fn fingerprint(scenario: &str, params: &Params, extra: &str) -> String {
    let key = format!(
        "{}|{}|{}|{}",
        scenario,
        crate::version(),
        params.canonical(),
        extra
    );
    format!("{:016x}", fnv1a64(key.as_bytes()))
}

pub struct Store {
    root: PathBuf,
}

impl Store {
    pub fn new(root: &str) -> Store {
        Store { root: PathBuf::from(root) }
    }

    pub fn path_for(&self, scenario: &str, fp: &str) -> PathBuf {
        self.root.join(scenario).join(format!("{fp}.json"))
    }

    /// Stored outcome for `(scenario, fp)`, or `None` on a miss. A
    /// corrupt or foreign file is treated as a miss (recompute and
    /// overwrite), never as an error.
    pub fn load(&self, scenario: &str, fp: &str) -> Option<Json> {
        let text = std::fs::read_to_string(self.path_for(scenario, fp)).ok()?;
        let j = Json::parse(&text).ok()?;
        // cheap validity probe; full decoding happens in Outcome::from_json
        (j.get("kind").and_then(Json::as_str)
            == Some(super::outcome::OUTCOME_KIND))
        .then_some(j)
    }

    /// Persist `outcome` under `(scenario, fp)`, atomically.
    pub fn save(&self, scenario: &str, fp: &str,
                outcome: &Outcome) -> Result<PathBuf> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let path = self.path_for(scenario, fp);
        let dir = path.parent().expect("store path has a parent");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let tmp = dir.join(format!(
            ".{fp}.{}.{}.tmp",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut text = outcome.to_json().to_pretty_string();
        text.push('\n');
        std::fs::write(&tmp, text)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ParamValue;

    fn params(pairs: &[(&str, ParamValue)]) -> Params {
        let mut p = Params::default();
        for (k, v) in pairs {
            p.set(k, v.clone());
        }
        p
    }

    fn tmp_root(tag: &str) -> String {
        let d = std::env::temp_dir()
            .join(format!("np-store-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.to_string_lossy().into_owned()
    }

    #[test]
    fn fingerprint_is_deterministic_and_param_sensitive() {
        let a = params(&[("top", ParamValue::U64(12))]);
        let b = params(&[("top", ParamValue::U64(13))]);
        assert_eq!(fingerprint("dse", &a, ""), fingerprint("dse", &a, ""));
        assert_ne!(fingerprint("dse", &a, ""), fingerprint("dse", &b, ""));
        assert_ne!(fingerprint("dse", &a, ""), fingerprint("sim", &a, ""));
        assert_ne!(fingerprint("dse", &a, ""), fingerprint("dse", &a, "x"));
    }

    #[test]
    fn save_then_load_round_trips() {
        let root = tmp_root("roundtrip");
        let st = Store::new(&root);
        let p = params(&[("k", ParamValue::Str("v".into()))]);
        let fp = fingerprint("demo", &p, "");
        assert!(st.load("demo", &fp).is_none(), "cold store must miss");
        let mut o = Outcome::new("demo", p.to_json());
        o.metric("m", 2.0, "");
        let path = st.save("demo", &fp, &o).unwrap();
        assert!(path.ends_with(format!("{fp}.json")));
        let j = st.load("demo", &fp).expect("hit after save");
        let back = Outcome::from_json(&j).unwrap();
        assert_eq!(back.get_metric("m"), Some(2.0));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn corrupt_entries_read_as_misses() {
        let root = tmp_root("corrupt");
        let st = Store::new(&root);
        let path = st.path_for("demo", "deadbeef");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, "{not json").unwrap();
        assert!(st.load("demo", "deadbeef").is_none());
        std::fs::write(&path, r#"{"kind":"other"}"#).unwrap();
        assert!(st.load("demo", "deadbeef").is_none());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
