//! The unified scenario layer: every experiment the crate can run —
//! the §3 characterization, the Fig. 4/11/12 sweeps, Tables 1/2/3, the
//! event microsimulation, the noise MC, the PJRT serving paths — is a
//! registered [`Scenario`] behind one generic dispatch.
//!
//! A scenario declares its typed parameters ([`ParamSpec`]) once;
//! params then parse uniformly from CLI flags ([`params_from_args`]) or
//! a JSON spec ([`params_from_json`], the [`suite`] runner's input) and
//! canonicalize into the content-address the [`store`] caches results
//! under. Running produces a typed [`Outcome`] (metric records + tables
//! + notes) that renders either as the exact text the pre-scenario CLI
//! printed (golden-tested byte-identical) or as schema-versioned JSON
//! (`--format json`, `--out <path>`).
//!
//! `main.rs` contains **no per-scenario match arms** — it hands the
//! whole argv to [`dispatch`], which resolves the command against the
//! [`registry`] (grep-enforced in `scripts/verify.sh`, like the model
//! layer's architecture rule). Registering a new experiment is one impl
//! plus one registry entry; it immediately gains `run <name>`,
//! `--format json`, `--cache`, suite membership, and help text.

mod analytic;
pub mod outcome;
mod pjrt;
pub mod registry;
mod serve;
pub mod store;
pub mod suite;

pub use outcome::{Metric, Outcome, OUTCOME_KIND, OUTCOME_SCHEMA};
pub use registry::{find, scenarios};

use crate::util::cli::{self, Args};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// One typed parameter value. `U64` doubles for usize-shaped counts.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Bool(bool),
    U64(u64),
    F64(f64),
    Str(String),
}

impl ParamValue {
    fn kind(&self) -> &'static str {
        match self {
            ParamValue::Bool(_) => "bool",
            ParamValue::U64(_) => "integer",
            ParamValue::F64(_) => "number",
            ParamValue::Str(_) => "string",
        }
    }

    fn to_json(&self) -> Json {
        match self {
            ParamValue::Bool(b) => Json::Bool(*b),
            ParamValue::U64(v) => Json::Num(*v as f64),
            ParamValue::F64(v) => Json::Num(*v),
            ParamValue::Str(s) => Json::Str(s.clone()),
        }
    }
}

/// Declaration of one scenario parameter: its name (`--name` on the
/// CLI, `"name"` in a JSON spec), default value (which fixes its type),
/// and help text. String params may additionally declare a closed list
/// of allowed values ([`ParamSpec::choice`]); both parse paths then
/// reject anything else with the allowed list (and a did-you-mean) in
/// the error, replacing the ad-hoc string validation scenarios used to
/// do after parsing.
pub struct ParamSpec {
    pub name: &'static str,
    pub default: ParamValue,
    pub help: &'static str,
    /// closed value list for string params (`None` = free-form)
    pub allowed: Option<&'static [&'static str]>,
}

impl ParamSpec {
    pub fn flag(name: &'static str, help: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            default: ParamValue::Bool(false),
            help,
            allowed: None,
        }
    }

    pub fn u64(name: &'static str, default: u64,
               help: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            default: ParamValue::U64(default),
            help,
            allowed: None,
        }
    }

    pub fn f64(name: &'static str, default: f64,
               help: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            default: ParamValue::F64(default),
            help,
            allowed: None,
        }
    }

    pub fn str(name: &'static str, default: &str,
               help: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            default: ParamValue::Str(default.into()),
            help,
            allowed: None,
        }
    }

    /// Enum-valued string param: only `allowed` values parse (the
    /// default must be one of them; accepted aliases belong in the list
    /// too). Help text renders the list as `one of a|b|c`.
    pub fn choice(name: &'static str, default: &str,
                  allowed: &'static [&'static str],
                  help: &'static str) -> ParamSpec {
        debug_assert!(allowed.contains(&default),
                      "choice param '{name}': default '{default}' not in \
                       its allowed list");
        ParamSpec {
            name,
            default: ParamValue::Str(default.into()),
            help,
            allowed: Some(allowed),
        }
    }

    /// Enforce the allowed list (no-op for free-form params).
    fn check_allowed(&self, v: &str) -> Result<()> {
        let Some(allowed) = self.allowed else { return Ok(()) };
        if allowed.contains(&v) {
            return Ok(());
        }
        let hint = cli::suggest(v, allowed)
            .map(|s| format!("; did you mean '{s}'?"))
            .unwrap_or_default();
        bail!("--{} must be one of {} (got '{v}'{hint})", self.name,
              allowed.join("|"))
    }
}

/// A fully-resolved parameter set: every declared spec is present
/// (defaults filled in), so the canonical JSON form — and therefore the
/// store fingerprint — does not depend on which spelling supplied it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Params(BTreeMap<String, ParamValue>);

impl Params {
    pub fn set(&mut self, name: &str, v: ParamValue) {
        self.0.insert(name.to_string(), v);
    }

    fn expect(&self, name: &str) -> &ParamValue {
        self.0
            .get(name)
            .unwrap_or_else(|| panic!("param '{name}' not declared"))
    }

    /// Typed accessors. Panicking on a missing/mistyped name is
    /// deliberate: params always come through [`params_from_args`] /
    /// [`params_from_json`] against the scenario's own specs, so a
    /// failure here is a bug in the scenario's declaration, not input.
    pub fn get_bool(&self, name: &str) -> bool {
        match self.expect(name) {
            ParamValue::Bool(b) => *b,
            v => panic!("param '{name}' is {}, not bool", v.kind()),
        }
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        match self.expect(name) {
            ParamValue::U64(v) => *v,
            v => panic!("param '{name}' is {}, not integer", v.kind()),
        }
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get_u64(name) as usize
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        match self.expect(name) {
            ParamValue::F64(v) => *v,
            v => panic!("param '{name}' is {}, not number", v.kind()),
        }
    }

    pub fn get_str(&self, name: &str) -> &str {
        match self.expect(name) {
            ParamValue::Str(s) => s,
            v => panic!("param '{name}' is {}, not string", v.kind()),
        }
    }

    /// Canonical JSON object (BTreeMap keeps keys sorted).
    pub fn to_json(&self) -> Json {
        Json::Obj(self.0.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
    }

    /// Canonical serialization the fingerprint hashes.
    pub fn canonical(&self) -> String {
        self.to_json().to_string()
    }
}

/// One registered experiment. Implementations live in
/// `scenario/analytic.rs` (pure model/simulator scenarios) and
/// `scenario/pjrt.rs` (artifact-backed PJRT scenarios); `registry.rs`
/// is the only list that knows them all.
pub trait Scenario: Sync {
    /// Canonical CLI name (kebab-case).
    fn name(&self) -> &'static str;

    /// Alternate spellings; matching is case- and `-`/`_`-insensitive.
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `help` and the suite report.
    fn description(&self) -> &'static str;

    /// Declared parameters (defaults fix each one's type).
    fn param_specs(&self) -> Vec<ParamSpec> {
        Vec::new()
    }

    /// Execute with fully-resolved params.
    fn run(&self, p: &Params) -> Result<Outcome>;

    /// Extra content the fingerprint must cover beyond name + params +
    /// crate version (e.g. the bytes of a `--network-file` spec).
    fn fingerprint_extra(&self, _p: &Params) -> Result<String> {
        Ok(String::new())
    }
}

/// Parse `specs` from CLI arguments, defaulting absent ones.
pub fn params_from_args(specs: &[ParamSpec], args: &Args) -> Result<Params> {
    let mut p = Params::default();
    for spec in specs {
        // a value-typed option given as a bare flag means its value was
        // omitted (or swallowed by a following `--flag`) — error rather
        // than silently falling back to the default
        if !matches!(spec.default, ParamValue::Bool(_)) && args.flag(spec.name)
        {
            bail!("--{} needs a value", spec.name);
        }
        let v = match &spec.default {
            ParamValue::Bool(d) => match args.get(spec.name) {
                Some(s) => ParamValue::Bool(parse_bool(spec.name, s)?),
                None => ParamValue::Bool(*d || args.flag(spec.name)),
            },
            ParamValue::U64(d) => ParamValue::U64(match args.get(spec.name) {
                Some(s) => {
                    let v: u64 = s.parse().with_context(|| {
                        format!("--{} must be an integer (got '{s}')",
                                spec.name)
                    })?;
                    // same JSON-safe bound params_from_json enforces:
                    // canonical params (and so the fingerprint) go
                    // through f64, which is exact only up to 2^53
                    if v > (1u64 << 53) {
                        bail!("--{} must be <= 2^53 (got {v})", spec.name);
                    }
                    v
                }
                None => *d,
            }),
            ParamValue::F64(d) => ParamValue::F64(match args.get(spec.name) {
                Some(s) => {
                    let v: f64 = s.parse().with_context(|| {
                        format!("--{} must be a number (got '{s}')",
                                spec.name)
                    })?;
                    // JSON has no NaN/inf: a non-finite value would
                    // serialize into params the store can never re-parse
                    if !v.is_finite() {
                        bail!("--{} must be finite (got {v})", spec.name);
                    }
                    v
                }
                None => *d,
            }),
            ParamValue::Str(d) => {
                let s = args.get(spec.name).unwrap_or(d);
                spec.check_allowed(s)?;
                ParamValue::Str(s.to_string())
            }
        };
        p.set(spec.name, v);
    }
    Ok(p)
}

fn parse_bool(name: &str, s: &str) -> Result<bool> {
    match s {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => bail!("--{name} must be true/false (got '{other}')"),
    }
}

/// Parse `specs` from a JSON object (a suite entry's `"params"`),
/// defaulting absent keys and rejecting unknown ones with a suggestion.
pub fn params_from_json(specs: &[ParamSpec], j: &Json) -> Result<Params> {
    let empty = BTreeMap::new();
    let map = match j {
        Json::Null => &empty,
        Json::Obj(m) => m,
        other => bail!("params must be a JSON object (got {other})"),
    };
    let known: Vec<&str> = specs.iter().map(|s| s.name).collect();
    for key in map.keys() {
        if !known.contains(&key.as_str()) {
            match cli::suggest(key, &known) {
                Some(s) => bail!("unknown param '{key}' (did you mean '{s}'?)"),
                None => bail!("unknown param '{key}'"),
            }
        }
    }
    let mut p = Params::default();
    for spec in specs {
        let v = match (map.get(spec.name), &spec.default) {
            (None, d) => d.clone(),
            (Some(Json::Bool(b)), ParamValue::Bool(_)) => ParamValue::Bool(*b),
            (Some(Json::Num(n)), ParamValue::U64(_)) => {
                let max = (1u64 << 53) as f64;
                if n.fract() != 0.0 || !(0.0..=max).contains(n) {
                    bail!("param '{}' must be a non-negative integer \
                           (got {n})", spec.name);
                }
                ParamValue::U64(*n as u64)
            }
            (Some(Json::Num(n)), ParamValue::F64(_)) => ParamValue::F64(*n),
            (Some(Json::Str(s)), ParamValue::Str(_)) => {
                spec.check_allowed(s)?;
                ParamValue::Str(s.clone())
            }
            (Some(other), d) => bail!(
                "param '{}' must be a {} (got {other})",
                spec.name,
                d.kind()
            ),
        };
        p.set(spec.name, v);
    }
    Ok(p)
}

// ---------------------------------------------------------------- exec --

/// Options shared by every scenario invocation (not fingerprinted).
pub struct ExecOptions {
    /// consult/populate the results store
    pub cache: bool,
    /// store root (`--results-dir`, default [`store::default_root`])
    pub results_dir: String,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { cache: false, results_dir: store::default_root() }
    }
}

impl ExecOptions {
    pub fn from_args(args: &Args) -> ExecOptions {
        ExecOptions {
            // `--cache` is a bare flag, but the parser captures a
            // following bare word as its value — accept the explicit
            // boolean spellings too (one truth table: parse_bool;
            // dispatch has already rejected anything else)
            cache: args.flag("cache")
                || args
                    .get("cache")
                    .map(|v| parse_bool("cache", v).unwrap_or(false))
                    .unwrap_or(false),
            results_dir: args
                .get("results-dir")
                .map(str::to_string)
                .unwrap_or_else(store::default_root),
        }
    }
}

/// The result of [`execute`]: the outcome plus cache provenance.
pub struct Execution {
    pub outcome: Outcome,
    pub fingerprint: String,
    /// served from the results store without recompute
    pub cached: bool,
    /// where the outcome lives on disk (when caching)
    pub stored: Option<std::path::PathBuf>,
}

/// Run a scenario through the store: on a `--cache` hit the stored
/// outcome is replayed (no recompute); on a miss (or without `--cache`)
/// the scenario runs, and with `--cache` the result is persisted.
pub fn execute(sc: &dyn Scenario, p: &Params,
               opts: &ExecOptions) -> Result<Execution> {
    let extra = sc.fingerprint_extra(p)?;
    let fp = store::fingerprint(sc.name(), p, &extra);
    let st = store::Store::new(&opts.results_dir);
    if opts.cache {
        if let Some(j) = st.load(sc.name(), &fp) {
            // an undecodable entry is a miss like any other corrupt
            // file (recompute and overwrite), never a hard failure
            match Outcome::from_json(&j) {
                Ok(outcome) => {
                    return Ok(Execution {
                        outcome,
                        stored: Some(st.path_for(sc.name(), &fp)),
                        fingerprint: fp,
                        cached: true,
                    });
                }
                // level 0: a corrupt store entry is worth a warning
                // even without --verbose
                Err(e) => crate::diag!(
                    0,
                    "[cache] ignoring undecodable {}: {e:#}",
                    st.path_for(sc.name(), &fp).display()
                ),
            }
        }
    }
    let outcome = sc.run(p)?;
    let stored = if opts.cache {
        Some(st.save(sc.name(), &fp, &outcome)?)
    } else {
        None
    };
    Ok(Execution { outcome, fingerprint: fp, cached: false, stored })
}

// ------------------------------------------------------------ dispatch --

/// Options every invocation understands, beyond the scenario's own.
/// Like `--out`, the observability options (`trace`, `trace-filter`,
/// `verbose`) are not fingerprinted: they change what gets *recorded*,
/// never what gets *computed* (tracing is result-identical by the
/// recorder contract in `obs/`).
const GLOBAL_OPTIONS: [&str; 8] =
    ["threads", "format", "out", "cache", "results-dir", "trace",
     "trace-filter", "verbose"];

/// The CLI entry point `main.rs` delegates to: resolve the command
/// against the registry, validate flags, parse params, execute through
/// the cache, render text or JSON. No scenario name appears here.
pub fn dispatch(args: &Args) -> Result<()> {
    // Bare flags capture a following bare word as their value, so a
    // leading flag (`--cache simulate`, `--all simulate`) eats the
    // command: the positional list ends up empty and the run would fall
    // through to the help screen with exit 0. Error instead — silently
    // doing nothing is the failure mode this layer exists to kill.
    if args.positional.is_empty()
        && !(args.options.is_empty() && args.flags.is_empty())
    {
        if let Some((k, v)) =
            args.options.iter().find(|(_, v)| find(v).is_some())
        {
            bail!(
                "--{k} is a flag and swallowed the command '{v}' as its \
                 value; put --{k} after the command"
            );
        }
        bail!("options given but no command; run `neural-pim help`");
    }
    // `--cache` anywhere else: a trailing bare word is equally silent
    // (`simulate --cache extra` would disable caching). Only boolean
    // spellings are valid values.
    if let Some(v) = args.get("cache") {
        if parse_bool("cache", v).is_err() {
            bail!(
                "--cache is a flag and swallowed '{v}' as its value; put \
                 --cache after the command (or spell it --cache=true)"
            );
        }
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    if cmd == "help" {
        if let Some(n) = args.positional.get(1) {
            // `help <typo>` must not silently fall back to the generic
            // usage screen — same did-you-mean path as command position
            let Some(sc) = find(n) else {
                let names: Vec<&str> = command_names();
                bail!(
                    "unknown scenario '{n}'{} — run `neural-pim help`",
                    cli::suggest(n, &names)
                        .map(|s| format!(" (did you mean '{s}'?)"))
                        .unwrap_or_default()
                );
            };
            println!("{}", scenario_help(sc));
        } else {
            println!("{}", usage());
        }
        return Ok(());
    }
    if cmd == "suite" {
        return suite::run_cli(args);
    }
    let name = if cmd == "run" {
        args.positional
            .get(1)
            .map(String::as_str)
            .context("usage: neural-pim run <scenario> [options]")?
    } else {
        cmd
    };
    let Some(sc) = find(name) else {
        let names: Vec<&str> = command_names();
        bail!(
            "unknown command '{name}'{} — run `neural-pim help`",
            cli::suggest(name, &names)
                .map(|s| format!(" (did you mean '{s}'?)"))
                .unwrap_or_default()
        );
    };
    // the command takes no positional arguments of its own — a stray
    // one (`simulate AlexNet`) would otherwise be ignored and the run
    // would silently fall back to defaults (all nine benchmarks)
    let n_expected = if cmd == "run" { 2 } else { 1 };
    if let Some(extra) = args.positional.get(n_expected) {
        bail!(
            "unexpected argument '{extra}' — scenario parameters are \
             passed as --options (e.g. --network {extra}); see \
             `neural-pim help {name}`"
        );
    }
    let specs = sc.param_specs();
    let mut known: Vec<&str> = GLOBAL_OPTIONS.to_vec();
    known.extend(specs.iter().map(|s| s.name));
    args.reject_unknown(&known).map_err(|e| anyhow!("{e}"))?;
    reject_valueless(args, &["format", "out", "results-dir", "threads",
                             "trace", "trace-filter"])?;
    let format = args.get_or("format", "text");
    if format != "text" && format != "json" {
        bail!("--format must be text or json (got '{format}')");
    }
    // observability wiring: `--verbose` raises the diag level (also
    // settable via NEURAL_PIM_LOG), `--trace` arms the thread-local
    // trace spec the tracing-aware scenarios consult. Both are
    // deliberately set on every dispatch — clearing the spec when the
    // flag is absent keeps repeated in-process dispatches independent.
    if args.flag("verbose")
        || args
            .get("verbose")
            .map(|v| parse_bool("verbose", v))
            .transpose()?
            .unwrap_or(false)
    {
        crate::obs::diag::raise_verbosity(1);
    }
    if args.get("trace-filter").is_some() && args.get("trace").is_none() {
        bail!("--trace-filter requires --trace <path>");
    }
    crate::obs::set_trace_spec(args.get("trace").map(|p| {
        crate::obs::TraceSpec {
            path: p.to_string(),
            filter: args.get("trace-filter").map(str::to_string),
        }
    }));
    let p = params_from_args(&specs, args)?;
    let ex = execute(sc, &p, &ExecOptions::from_args(args))?;
    if ex.cached {
        // stderr (and --verbose-gated), so text output stays
        // byte-identical to an uncached run
        crate::diag!(
            1,
            "[cache] {} served from {}",
            sc.name(),
            ex.stored.as_ref().expect("cached implies stored").display()
        );
    }
    let rendered = if format == "json" {
        let mut s = ex.outcome.to_json().to_pretty_string();
        s.push('\n');
        s
    } else {
        ex.outcome.render_text()
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, rendered)
                .with_context(|| format!("writing {path}"))?;
            println!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

/// Bail when a value-typed global option was given as a bare flag (its
/// value forgotten or swallowed by a following `--flag`) — the same
/// guard `params_from_args` applies to scenario params.
fn reject_valueless(args: &Args, names: &[&str]) -> Result<()> {
    for &n in names {
        if args.flag(n) {
            bail!("--{n} needs a value");
        }
    }
    Ok(())
}

/// Every spelling `dispatch` accepts in command position.
fn command_names() -> Vec<&'static str> {
    let mut names = vec!["run", "suite", "help"];
    for sc in scenarios() {
        names.push(sc.name());
        names.extend(sc.aliases().iter().copied());
    }
    names
}

/// Top-level usage text, generated from the registry.
pub fn usage() -> String {
    let mut out = String::from(
        "neural-pim — Neural-PIM (IEEE TC 2022) reproduction\n\n\
         USAGE:\n  \
         neural-pim <scenario> [--param value ...] [global options]\n  \
         neural-pim run <scenario> [...]     the same, explicit form\n  \
         neural-pim suite <spec.json> [--cache] [--bench-out FILE]\n  \
         neural-pim help [scenario]\n\n\
         SCENARIOS:\n",
    );
    let width = scenarios().iter().map(|s| s.name().len()).max().unwrap_or(0);
    for sc in scenarios() {
        out.push_str(&format!(
            "  {:width$}  {}\n",
            sc.name(),
            sc.description()
        ));
    }
    out.push_str(
        "\nGLOBAL OPTIONS:\n  \
         --format text|json   render outcome as text tables (default) or\n  \
         \x20                    schema-versioned JSON\n  \
         --out FILE           write the rendering to FILE instead of stdout\n  \
         --cache              reuse/populate the content-addressed results\n  \
         \x20                    store (results/<scenario>/<fingerprint>.json)\n  \
         --results-dir DIR    store root (default: results, or\n  \
         \x20                    $NEURAL_PIM_RESULTS)\n  \
         --threads N          worker threads for the parallel sweeps\n  \
         --trace FILE         write a Chrome trace-event JSON of the run\n  \
         \x20                    (virtual time; open in Perfetto) — honored\n  \
         \x20                    by event-sim and serve-sim\n  \
         --trace-filter PFX   keep only trace events whose name starts\n  \
         \x20                    with PFX\n  \
         --verbose            print informational diagnostics to stderr\n  \
         \x20                    (also: NEURAL_PIM_LOG=1)\n\n\
         `neural-pim help <scenario>` lists a scenario's parameters.\n",
    );
    out
}

/// Per-scenario help: description, aliases, declared params.
pub fn scenario_help(sc: &dyn Scenario) -> String {
    let mut out = format!("{} — {}\n", sc.name(), sc.description());
    if !sc.aliases().is_empty() {
        out.push_str(&format!("aliases: {}\n", sc.aliases().join(", ")));
    }
    let specs = sc.param_specs();
    if specs.is_empty() {
        out.push_str("no parameters\n");
    } else {
        out.push_str("parameters:\n");
        let width = specs.iter().map(|s| s.name.len()).max().unwrap_or(0);
        for s in &specs {
            let default = match (&s.default, s.allowed) {
                (ParamValue::Str(v), Some(allowed)) => {
                    format!("one of {}; default {v}", allowed.join("|"))
                }
                (ParamValue::Bool(_), _) => "flag".to_string(),
                (ParamValue::U64(v), _) => format!("default {v}"),
                (ParamValue::F64(v), _) => format!("default {v}"),
                (ParamValue::Str(v), _) if v.is_empty() => "optional".into(),
                (ParamValue::Str(v), _) => format!("default {v}"),
            };
            out.push_str(&format!(
                "  --{:width$}  {} ({default})\n",
                s.name, s.help
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::str("network", "", "benchmark name"),
            ParamSpec::flag("all", "all benchmarks"),
            ParamSpec::u64("requests", 256, "total requests"),
            ParamSpec::f64("load", 0.8, "offered load"),
        ]
    }

    fn argv(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn args_and_json_agree_on_the_same_input() {
        let from_args = params_from_args(
            &specs(),
            &argv(&["--network", "AlexNet", "--requests", "64", "--all"]),
        )
        .unwrap();
        let from_json = params_from_json(
            &specs(),
            &Json::parse(
                r#"{"network":"AlexNet","requests":64,"all":true}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(from_args, from_json);
        assert_eq!(from_args.get_str("network"), "AlexNet");
        assert_eq!(from_args.get_u64("requests"), 64);
        assert!(from_args.get_bool("all"));
        assert_eq!(from_args.get_f64("load"), 0.8);
    }

    #[test]
    fn defaults_make_params_canonical() {
        let a = params_from_args(&specs(), &argv(&[])).unwrap();
        let b = params_from_json(&specs(), &Json::Null).unwrap();
        assert_eq!(a.canonical(), b.canonical());
        assert!(a.canonical().contains("\"requests\":256"), "{}", a.canonical());
    }

    #[test]
    fn json_params_reject_unknown_and_mistyped() {
        let err = params_from_json(
            &specs(),
            &Json::parse(r#"{"request":64}"#).unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("did you mean 'requests'"), "{err}");
        for bad in [
            r#"{"requests":1.5}"#,
            r#"{"requests":-1}"#,
            r#"{"requests":"many"}"#,
            r#"{"all":"yes"}"#,
        ] {
            assert!(
                params_from_json(&specs(), &Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn choice_params_enforce_their_allowed_list_on_both_paths() {
        let specs = vec![ParamSpec::choice(
            "search",
            "auto",
            &["auto", "exhaustive", "hillclimb", "bandit"],
            "placement search strategy",
        )];
        // valid values (and the default) pass on both parse paths
        let p = params_from_args(&specs, &argv(&["--search", "bandit"]))
            .unwrap();
        assert_eq!(p.get_str("search"), "bandit");
        let p = params_from_json(&specs, &Json::Null).unwrap();
        assert_eq!(p.get_str("search"), "auto");
        // rejections name the allowed list and suggest near-misses
        let err = params_from_args(&specs, &argv(&["--search", "hillclimD"]))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("one of auto|exhaustive|hillclimb|bandit"),
                "{msg}");
        assert!(msg.contains("did you mean 'hillclimb'"), "{msg}");
        let err = params_from_json(
            &specs,
            &Json::parse(r#"{"search":"greedy"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("must be one of"), "{err}");
        // the help line renders the closed list
        let spec = &specs[0];
        assert_eq!(spec.allowed.unwrap().len(), 4);
    }

    #[test]
    fn cli_params_parse_errors_are_errors_not_panics() {
        assert!(params_from_args(&specs(), &argv(&["--requests", "x"]))
            .is_err());
        assert!(params_from_args(&specs(), &argv(&["--load", "fast"]))
            .is_err());
        assert!(params_from_args(&specs(), &argv(&["--all", "maybe"]))
            .is_err());
    }

    #[test]
    fn value_option_without_a_value_is_an_error() {
        // `--network` at the end (or before another --flag) parses as a
        // bare flag; silently running all nine benchmarks would hide
        // the mistake
        let err = params_from_args(&specs(), &argv(&["--network"]))
            .unwrap_err();
        assert!(err.to_string().contains("--network needs a value"), "{err}");
        let err =
            params_from_args(&specs(), &argv(&["--requests", "--all"]))
                .unwrap_err();
        assert!(err.to_string().contains("--requests needs a value"),
                "{err}");
    }

    #[test]
    fn leading_flag_swallowing_the_command_is_an_error() {
        // `--cache simulate` / `--all simulate` would otherwise lose the
        // command and fall through to the help screen with exit 0
        for flag in ["--cache", "--all"] {
            let err = dispatch(&argv(&[flag, "simulate"])).unwrap_err();
            assert!(
                format!("{err:#}").contains("swallowed the command \
                                             'simulate'"),
                "{flag}: {err:#}"
            );
        }
        // options with no command at all error too (no silent help)
        assert!(dispatch(&argv(&["--format", "json"])).is_err());
        // a trailing bare word after --cache is equally rejected
        let err = dispatch(&argv(&["dse", "--cache", "extra"])).unwrap_err();
        assert!(format!("{err:#}").contains("swallowed 'extra'"), "{err:#}");
    }

    #[test]
    fn trace_filter_without_trace_is_an_error() {
        let err =
            dispatch(&argv(&["table2", "--trace-filter", "noc."])).unwrap_err();
        assert!(format!("{err:#}").contains("--trace-filter requires"),
                "{err:#}");
        // and the value-typed observability options reject bare use
        let err = dispatch(&argv(&["table2", "--trace"])).unwrap_err();
        assert!(format!("{err:#}").contains("--trace needs a value"),
                "{err:#}");
    }

    #[test]
    fn dispatch_arms_and_clears_the_trace_spec() {
        // scenarios that ignore tracing still leave the spec armed
        // during their run; a later dispatch without --trace must clear
        // it (thread-local, so this test is race-free under the
        // parallel test harness)
        let tmp = std::env::temp_dir().join("np_spec_probe.json");
        let tmp = tmp.to_string_lossy().to_string();
        dispatch(&argv(&["table2", "--trace", &tmp, "--out",
                         &format!("{tmp}.txt")]))
            .unwrap();
        dispatch(&argv(&["table2", "--out", &format!("{tmp}.txt")])).unwrap();
        assert!(crate::obs::trace_spec().is_none(),
                "spec must clear on a traceless dispatch");
        let _ = std::fs::remove_file(format!("{tmp}.txt"));
    }

    #[test]
    fn help_with_a_typo_suggests_instead_of_generic_usage() {
        let err = dispatch(&argv(&["help", "simulte"])).unwrap_err();
        assert!(format!("{err:#}").contains("did you mean 'simulate'"),
                "{err:#}");
    }

    #[test]
    fn non_finite_cli_floats_are_rejected() {
        // NaN/inf would serialize into params JSON the store can never
        // re-parse (silent permanent cache miss)
        for bad in ["nan", "inf", "-inf"] {
            assert!(
                params_from_args(&specs(), &argv(&["--load", bad])).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn cli_u64_params_share_the_json_safe_bound() {
        // fingerprints canonicalize through f64; a u64 beyond 2^53 would
        // silently collide with its neighbours
        let err = params_from_args(
            &specs(),
            &argv(&["--requests", "18446744073709551615"]),
        )
        .unwrap_err();
        assert!(err.to_string().contains("2^53"), "{err}");
    }

    #[test]
    fn registry_names_are_unique_and_findable() {
        let mut seen = std::collections::BTreeSet::new();
        for sc in scenarios() {
            assert!(seen.insert(sc.name()), "duplicate {}", sc.name());
            let found = find(sc.name()).expect("own name resolves");
            assert_eq!(found.name(), sc.name());
            for a in sc.aliases() {
                assert_eq!(find(a).expect("alias resolves").name(), sc.name());
            }
        }
        // alias + case/punctuation-insensitive lookup
        assert!(find("EVENT_SIM").is_some());
        assert!(find("EventSim").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn usage_lists_every_scenario() {
        let u = usage();
        for sc in scenarios() {
            assert!(u.contains(sc.name()), "usage missing {}", sc.name());
        }
        let h = scenario_help(find("event-sim").unwrap());
        assert!(h.contains("--requests"), "{h}");
    }
}
