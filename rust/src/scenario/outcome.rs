//! Typed scenario outcomes: named metric records, text tables, and
//! notes, rendering either as the exact plain-text stream the CLI has
//! always printed or as schema-versioned JSON for the results store.

use crate::util::json::{self, Json};
use crate::util::table::Table;
use anyhow::{bail, Context, Result};

/// Bumped only when the JSON layout changes incompatibly (fields
/// renamed/removed or their meaning changed). Additive fields do NOT
/// bump it — readers must ignore keys they don't know. See DESIGN.md
/// §2b for the policy.
pub const OUTCOME_SCHEMA: u32 = 1;

/// The `kind` tag stored outcomes are recognized by.
pub const OUTCOME_KIND: &str = "neural-pim.outcome";

/// One named result quantity — the machine-readable counterpart of a
/// table cell or headline phrase.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    /// free-form unit label ("J", "x", "dB", ""), for display only
    pub unit: String,
}

impl Metric {
    pub fn new(name: impl Into<String>, value: f64, unit: &str) -> Metric {
        Metric { name: name.into(), value, unit: unit.to_string() }
    }
}

/// What running a scenario produces: tables and notes for humans (the
/// text rendering is byte-identical to the pre-scenario CLI output),
/// metric records for machines, and the resolved params for provenance.
#[derive(Debug)]
pub struct Outcome {
    pub scenario: String,
    /// the fully-defaulted params the run resolved to (canonical JSON)
    pub params: Json,
    pub metrics: Vec<Metric>,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl Outcome {
    pub fn new(scenario: &str, params: Json) -> Outcome {
        Outcome {
            scenario: scenario.to_string(),
            params,
            metrics: Vec::new(),
            tables: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn table(&mut self, t: Table) -> &mut Self {
        self.tables.push(t);
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    pub fn metric(&mut self, name: impl Into<String>, value: f64,
                  unit: &str) -> &mut Self {
        self.metrics.push(Metric::new(name, value, unit));
        self
    }

    pub fn get_metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|m| m.name == name).map(|m| m.value)
    }

    /// The plain-text rendering: every table exactly as `Table::print`
    /// emitted it (render + trailing blank line), then the notes —
    /// byte-identical to the hand-rolled pre-scenario `main.rs` arms.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(n);
            out.push('\n');
        }
        out
    }

    /// Schema-versioned JSON form (see [`OUTCOME_SCHEMA`]).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", Json::Str(OUTCOME_KIND.into())),
            ("schema", Json::Num(OUTCOME_SCHEMA as f64)),
            ("crate_version", Json::Str(crate::version().into())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("params", self.params.clone()),
            ("metrics",
             Json::Arr(
                 self.metrics
                     .iter()
                     .map(|m| {
                         json::obj(vec![
                             ("name", Json::Str(m.name.clone())),
                             ("value", Json::Num(m.value)),
                             ("unit", Json::Str(m.unit.clone())),
                         ])
                     })
                     .collect(),
             )),
            ("tables",
             Json::Arr(self.tables.iter().map(Table::to_json).collect())),
            ("notes",
             Json::Arr(
                 self.notes.iter().cloned().map(Json::Str).collect(),
             )),
        ])
    }

    /// Rebuild an outcome from its [`Outcome::to_json`] form — how the
    /// results store replays cached runs through the same renderers.
    pub fn from_json(j: &Json) -> Result<Outcome> {
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != OUTCOME_KIND {
            bail!("not a stored outcome (kind '{kind}')");
        }
        let schema = j.get("schema").and_then(Json::as_f64).unwrap_or(0.0) as u32;
        if schema != OUTCOME_SCHEMA {
            bail!("outcome schema {schema} != supported {OUTCOME_SCHEMA}");
        }
        let mut out = Outcome::new(
            j.get("scenario")
                .and_then(Json::as_str)
                .context("outcome missing 'scenario'")?,
            j.get("params").cloned().unwrap_or(Json::Null),
        );
        for mj in j.get("metrics").and_then(Json::as_arr).unwrap_or(&[]) {
            out.metrics.push(Metric {
                name: mj
                    .get("name")
                    .and_then(Json::as_str)
                    .context("metric missing 'name'")?
                    .to_string(),
                value: mj
                    .get("value")
                    .and_then(Json::as_f64)
                    .context("metric missing 'value'")?,
                unit: mj
                    .get("unit")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            });
        }
        for tj in j.get("tables").and_then(Json::as_arr).unwrap_or(&[]) {
            out.tables.push(
                Table::from_json(tj).context("malformed stored table")?,
            );
        }
        for nj in j.get("notes").and_then(Json::as_arr).unwrap_or(&[]) {
            out.notes
                .push(nj.as_str().context("note is not a string")?.to_string());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::table::Cell;

    fn sample() -> Outcome {
        let mut o = Outcome::new(
            "demo",
            json::obj(vec![("top", Json::Num(3.0))]),
        );
        let mut t = Table::new("T", &["k", "v"]);
        t.cells(vec![Cell::s("alpha"), Cell::num(1.5, "1.500")]);
        o.table(t);
        o.metric("best", 1.5, "x").note("done");
        o
    }

    #[test]
    fn text_rendering_matches_print_sequence() {
        let o = sample();
        let s = o.render_text();
        // table render + blank line + note line
        assert!(s.starts_with("== T ==\n"));
        assert!(s.contains("\n\ndone\n"), "{s:?}");
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let o = sample();
        let j = o.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some(OUTCOME_KIND));
        assert_eq!(j.get("schema").unwrap().as_f64(),
                   Some(OUTCOME_SCHEMA as f64));
        let back = Outcome::from_json(&j).unwrap();
        assert_eq!(back.scenario, o.scenario);
        assert_eq!(back.params, o.params);
        assert_eq!(back.metrics, o.metrics);
        assert_eq!(back.notes, o.notes);
        assert_eq!(back.render_text(), o.render_text());
    }

    #[test]
    fn from_json_rejects_wrong_kind_and_schema() {
        assert!(Outcome::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = sample().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Num(999.0));
        }
        assert!(Outcome::from_json(&j).is_err());
    }
}
