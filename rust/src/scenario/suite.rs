//! Suite runner: execute a JSON-defined list of scenarios across
//! `util::pool`, consolidate one report, and emit a BENCH-shaped
//! perf/metrics JSON for the performance trajectory.
//!
//! Spec format (see `examples/suite_smoke.json`):
//!
//! ```json
//! {
//!   "name": "smoke",
//!   "scenarios": [
//!     {"scenario": "table2"},
//!     {"scenario": "simulate", "params": {"network": "AlexNet"}}
//!   ]
//! }
//! ```
//!
//! Every entry resolves against the registry up front (unknown names or
//! params fail before anything runs), executes through the results
//! store when `--cache` is set, and is timed individually. A failed
//! entry is recorded in the report instead of aborting the suite.

use super::{execute, find, params_from_json, ExecOptions, Outcome, Params,
            Scenario};
use crate::util::cli::Args;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::table::{Cell, Table};
use anyhow::{anyhow, bail, Context, Result};

/// Schema tag of the consolidated suite report.
pub const SUITE_KIND: &str = "neural-pim.suite-report";
pub const SUITE_SCHEMA: u32 = 1;

pub struct SuiteEntry {
    pub scenario: &'static dyn Scenario,
    pub params: Params,
}

pub struct SuiteSpec {
    pub name: String,
    pub entries: Vec<SuiteEntry>,
}

impl SuiteSpec {
    /// Parse and fully resolve a spec: every scenario found in the
    /// registry, every param set validated against its specs.
    pub fn from_json(j: &Json) -> Result<SuiteSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("suite")
            .to_string();
        let list = j
            .get("scenarios")
            .and_then(Json::as_arr)
            .context("suite spec needs a 'scenarios' array")?;
        if list.is_empty() {
            bail!("suite spec has no scenarios");
        }
        let mut entries = Vec::new();
        for (i, ej) in list.iter().enumerate() {
            let sc_name = ej
                .get("scenario")
                .and_then(Json::as_str)
                .with_context(|| format!("entry {i}: missing 'scenario'"))?;
            let scenario = find(sc_name)
                .ok_or_else(|| anyhow!("entry {i}: unknown scenario \
                                        '{sc_name}'"))?;
            let params = params_from_json(
                &scenario.param_specs(),
                ej.get("params").unwrap_or(&Json::Null),
            )
            .with_context(|| format!("entry {i} ({sc_name})"))?;
            entries.push(SuiteEntry { scenario, params });
        }
        Ok(SuiteSpec { name, entries })
    }

    pub fn load(path: &str) -> Result<SuiteSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading suite spec {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&j).with_context(|| format!("parsing suite spec \
                                                     {path}"))
    }
}

/// One executed suite entry.
pub struct EntryResult {
    pub scenario: String,
    pub fingerprint: String,
    pub cached: bool,
    /// Wall-clock of this entry *under suite-level concurrency*:
    /// entries fan out across the pool (each scenario's own `pool::map`
    /// calls nest inline on the participant running it), so absolute
    /// values include suite contention — compare wall_ms within like
    /// suites (cold vs cached, PR vs PR on the same spec), not across
    /// suite compositions.
    pub wall_ms: f64,
    pub result: Result<Outcome, String>,
}

pub struct SuiteReport {
    pub name: String,
    pub entries: Vec<EntryResult>,
}

/// Run every entry across the worker pool. Entry order is preserved
/// (`pool::map` reassembles by index), failures are captured per entry
/// — including panics, which would otherwise kill the pool worker and
/// abort the whole suite with no report written.
pub fn run_spec(spec: &SuiteSpec, opts: &ExecOptions) -> SuiteReport {
    let items: Vec<&SuiteEntry> = spec.entries.iter().collect();
    let entries = pool::map(&items, |e| {
        let t0 = std::time::Instant::now();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || execute(e.scenario, &e.params, opts),
        ))
        .unwrap_or_else(|payload| {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            Err(anyhow!("scenario panicked: {msg}"))
        });
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        match run {
            Ok(ex) => EntryResult {
                scenario: e.scenario.name().to_string(),
                fingerprint: ex.fingerprint,
                cached: ex.cached,
                wall_ms,
                result: Ok(ex.outcome),
            },
            Err(err) => EntryResult {
                scenario: e.scenario.name().to_string(),
                fingerprint: String::new(),
                cached: false,
                wall_ms,
                result: Err(format!("{err:#}")),
            },
        }
    });
    SuiteReport { name: spec.name.clone(), entries }
}

impl SuiteReport {
    pub fn failures(&self) -> usize {
        self.entries.iter().filter(|e| e.result.is_err()).count()
    }

    /// Did every entry come straight from the results store?
    pub fn all_cached(&self) -> bool {
        self.entries.iter().all(|e| e.cached)
    }

    /// The consolidated human-readable view.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("suite '{}': {} scenarios, {} failed", self.name,
                     self.entries.len(), self.failures()),
            &["scenario", "status", "cached", "wall (ms)", "metrics",
              "fingerprint"],
        );
        for e in &self.entries {
            let (status, n_metrics) = match &e.result {
                Ok(o) => ("ok", o.metrics.len()),
                Err(_) => ("FAILED", 0),
            };
            t.cells(vec![
                Cell::s(e.scenario.clone()),
                Cell::s(status),
                Cell::s(if e.cached { "yes" } else { "no" }),
                Cell::num(e.wall_ms, format!("{:.1}", e.wall_ms)),
                Cell::num(n_metrics as f64, n_metrics.to_string()),
                Cell::s(e.fingerprint.clone()),
            ]);
        }
        t
    }

    /// Consolidated report: per-entry provenance plus the full outcome
    /// (or the error) of every scenario.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", Json::Str(SUITE_KIND.into())),
            ("schema", Json::Num(SUITE_SCHEMA as f64)),
            ("crate_version", Json::Str(crate::version().into())),
            ("suite", Json::Str(self.name.clone())),
            ("entries",
             Json::Arr(
                 self.entries
                     .iter()
                     .map(|e| {
                         json::obj(vec![
                             ("scenario", Json::Str(e.scenario.clone())),
                             ("fingerprint",
                              Json::Str(e.fingerprint.clone())),
                             ("cached", Json::Bool(e.cached)),
                             ("wall_ms", Json::Num(e.wall_ms)),
                             ("ok", Json::Bool(e.result.is_ok())),
                             match &e.result {
                                 Ok(o) => ("outcome", o.to_json()),
                                 Err(err) => ("error",
                                              Json::Str(err.clone())),
                             },
                         ])
                     })
                     .collect(),
             )),
            ("bench", self.bench_json()),
        ])
    }

    /// BENCH-shaped flat metric map (`<scenario>.<metric>` → number):
    /// the perf/metrics trajectory the CI artifact tracks across PRs.
    pub fn bench_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = Vec::new();
        let mut total_ms = 0.0;
        // a scenario that appears once keeps its bare name (the stable
        // trajectory key); repeated scenarios are keyed by their param
        // fingerprint, so reordering or inserting suite entries can
        // never silently remap an existing series onto different params
        let mut count: std::collections::BTreeMap<&str, usize> =
            std::collections::BTreeMap::new();
        for e in &self.entries {
            *count.entry(e.scenario.as_str()).or_insert(0) += 1;
        }
        let mut used = std::collections::BTreeSet::new();
        for (i, e) in self.entries.iter().enumerate() {
            total_ms += e.wall_ms;
            let mut prefix = if count[e.scenario.as_str()] == 1 {
                e.scenario.clone()
            } else if e.fingerprint.len() >= 8 {
                format!("{}[{}]", e.scenario, &e.fingerprint[..8])
            } else {
                // failed entry with no fingerprint: fall back to index
                format!("{}[entry{}]", e.scenario, i)
            };
            // byte-identical repeats (same scenario AND params — e.g. a
            // cold-vs-warm probe listing one entry twice) would collide
            // in the flat map and silently drop the first series
            if !used.insert(prefix.clone()) {
                prefix = format!("{}[entry{}]", e.scenario, i);
            }
            pairs.push((format!("{prefix}.wall_ms"), Json::Num(e.wall_ms)));
            if let Ok(o) = &e.result {
                for m in &o.metrics {
                    pairs.push((format!("{prefix}.{}", m.name),
                                Json::Num(m.value)));
                }
            }
        }
        pairs.push(("suite.wall_ms_total".into(), Json::Num(total_ms)));
        pairs.push(("suite.failures".into(),
                    Json::Num(self.failures() as f64)));
        Json::Obj(pairs.into_iter().collect())
    }
}

/// The `neural-pim suite <spec.json>` CLI entry.
pub fn run_cli(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("usage: neural-pim suite <spec.json> [--cache] \
                  [--results-dir D] [--out F] [--bench-out F] \
                  [--format text|json]")?;
    if let Some(extra) = args.positional.get(2) {
        bail!("unexpected argument '{extra}' after the suite spec");
    }
    let mut known: Vec<&str> = super::GLOBAL_OPTIONS.to_vec();
    known.push("bench-out");
    args.reject_unknown(&known).map_err(|e| anyhow!("{e}"))?;
    super::reject_valueless(
        args,
        &["format", "out", "bench-out", "results-dir", "threads"],
    )?;
    let format = args.get_or("format", "text");
    if format != "text" && format != "json" {
        bail!("--format must be text or json (got '{format}')");
    }
    let spec = SuiteSpec::load(path)?;
    let opts = ExecOptions::from_args(args);
    let report = run_spec(&spec, &opts);

    std::fs::create_dir_all(&opts.results_dir)
        .with_context(|| format!("creating {}", opts.results_dir))?;
    let out_path = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}/suite-{}.json", opts.results_dir,
                                   spec.name));
    let bench_path = args
        .get("bench-out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}/BENCH_suite_{}.json",
                                   opts.results_dir, spec.name));
    let mut consolidated = report.to_json().to_pretty_string();
    consolidated.push('\n');
    std::fs::write(&out_path, &consolidated)
        .with_context(|| format!("writing {out_path}"))?;
    let mut bench = report.bench_json().to_pretty_string();
    bench.push('\n');
    std::fs::write(&bench_path, bench)
        .with_context(|| format!("writing {bench_path}"))?;

    if format == "json" {
        print!("{consolidated}");
    } else {
        report.table().print();
        println!("consolidated report: {out_path}");
        println!("bench metrics:       {bench_path}");
    }
    if report.failures() > 0 {
        bail!("{} of {} suite entries failed (see {})",
              report.failures(), report.entries.len(), out_path);
    }
    Ok(())
}
