//! Scenario impls over the `serve` layer: the backend-parameterized
//! `serve`/`infer` paths (PJRT artifacts or the simulated backend) and
//! the artifact-free `serve-sim` offered-load sweep.
//!
//! `--backend pjrt` (the default) keeps the historical behaviour and
//! byte-identical text output; `--backend sim` runs the same serving
//! machinery against [`crate::serve::SimBackend`], so every serving
//! scenario works in a bare checkout. `serve-sim` never touches the
//! wall clock: it replays the serving discipline in virtual time
//! ([`crate::serve::loadgen`]), making its outcome bit-identical at any
//! `--threads` count and byte-identical on cached replay.

use super::pjrt::{artifacts_dir, artifacts_extra, artifacts_spec};
use super::{Outcome, ParamSpec, Params, Scenario};
use crate::config::{AcceleratorConfig, Architecture};
use crate::serve::{self, fleet, loadgen, Coordinator, PjrtBackend,
                   ServeOptions, SimBackend, Submission};
use crate::util::cli;
use crate::util::rng::Pcg;
use crate::util::stats;
use crate::util::table::{Cell, Table};
use crate::workloads::{self, Network};
use crate::{event, model, runtime};
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

fn backend_spec() -> ParamSpec {
    ParamSpec::str("backend", "pjrt",
                   "inference backend: pjrt | sim (serve::BACKENDS)")
}

/// Validate `--backend` against the registered backend list, with the
/// usual did-you-mean suggestion.
fn parse_backend(p: &Params) -> Result<String> {
    let name = p.get_str("backend").to_ascii_lowercase();
    let known = serve::backend_names();
    if !known.contains(&name.as_str()) {
        match cli::suggest(&name, &known) {
            Some(s) => bail!("unknown backend '{name}' (did you mean \
                              '{s}'?)"),
            None => bail!("unknown backend '{name}'"),
        }
    }
    Ok(name)
}

fn sim_network(p: &Params) -> Result<Network> {
    let name = p.get_str("network");
    workloads::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown network {name}"))
}

fn sim_config(p: &Params) -> Result<AcceleratorConfig> {
    Ok(AcceleratorConfig::for_arch(Architecture::parse(p.get_str("arch"))?))
}

/// Synthetic image side for the simulated backends (CIFAR-shaped).
const SIM_SIDE: usize = 32;
const SIM_IMAGE_LEN: usize = SIM_SIDE * SIM_SIDE * 3;

// --------------------------------------------------------------- serve --

pub struct Serve;

impl Scenario for Serve {
    fn name(&self) -> &'static str {
        "serve"
    }

    fn description(&self) -> &'static str {
        "drive the serving coordinator on a pluggable backend \
         (pjrt needs artifacts; sim runs anywhere)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::u64("requests", 512, "requests to drive"),
            ParamSpec::str("artifact", "cnn_ideal", "model artifact (pjrt)"),
            ParamSpec::u64("max-wait-ms", 2, "batching window"),
            ParamSpec::u64("workers", 1, "coordinator workers"),
            ParamSpec::u64("depth", 0,
                           "admission queue bound; 0 = never shed"),
            backend_spec(),
            ParamSpec::str("network", "SyntheticCNN",
                           "simulated network (sim backend)"),
            ParamSpec::str("arch", "neural-pim",
                           "simulated chip architecture (sim backend)"),
            ParamSpec::u64("seed", 42, "PRNG seed (sim backend)"),
            artifacts_spec(),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let backend_name = parse_backend(p)?;
        let n_req = p.get_usize("requests");
        let depth = p.get_usize("depth");
        let opts = ServeOptions {
            workers: p.get_usize("workers"),
            max_wait: Duration::from_millis(p.get_u64("max-wait-ms")),
            max_batch: 0,
            max_queue_depth: if depth == 0 { None } else { Some(depth) },
        };
        // backend + request stream: the serving loop below is identical
        // for both; only construction differs
        let (coord, images, labels) = match backend_name.as_str() {
            "pjrt" => {
                let dir = artifacts_dir(p);
                let ts =
                    runtime::TestSet::load(std::path::Path::new(&dir))?;
                let (h, w, c) = ts.dims;
                let stride = h * w * c;
                let backend = PjrtBackend::new(
                    dir,
                    p.get_str("artifact"),
                    stride,
                );
                let images: Vec<Vec<f32>> = (0..n_req)
                    .map(|i| {
                        let idx = i % ts.n;
                        ts.images[idx * stride..(idx + 1) * stride].to_vec()
                    })
                    .collect();
                let labels: Vec<i32> =
                    (0..n_req).map(|i| ts.labels[i % ts.n]).collect();
                (Coordinator::start(backend, opts)?, images, labels)
            }
            "sim" => {
                let net = sim_network(p)?;
                let cfg = sim_config(p)?;
                let seed = p.get_u64("seed");
                let backend =
                    SimBackend::new(&net, &cfg, 128, SIM_IMAGE_LEN, seed);
                let classes = backend.classes();
                // synthetic u8-valued images + random labels (accuracy
                // against a hash-logit backend is a determinism probe,
                // not a model quality number)
                let mut rng = Pcg::new(seed);
                let images: Vec<Vec<f32>> = (0..n_req)
                    .map(|_| {
                        (0..SIM_IMAGE_LEN)
                            .map(|_| rng.below(256) as f32)
                            .collect()
                    })
                    .collect();
                let labels: Vec<i32> =
                    (0..n_req).map(|_| rng.below(classes) as i32).collect();
                (Coordinator::start(backend, opts)?, images, labels)
            }
            // a backend registered in serve::BACKENDS but not given a
            // construction arm here must fail loudly, never silently
            // fall back to another backend's results
            other => bail!("backend '{other}' has no construction path in \
                            the serve scenario"),
        };
        // progress on stderr (behind --verbose): stdout carries only
        // the rendered outcome
        crate::diag!(1, "coordinator up — driving {n_req} requests");

        let t0 = Instant::now();
        let mut pending = Vec::new();
        let mut shed = 0usize;
        for (img, label) in images.into_iter().zip(labels) {
            match coord.submit(img)? {
                Submission::Accepted(rx) => pending.push((rx, label)),
                Submission::Rejected(_) => shed += 1,
            }
        }
        let served = pending.len();
        let mut correct = 0usize;
        let mut lat_ms = Vec::new();
        for (rx, label) in pending {
            let resp = rx.recv()?;
            if let Some(err) = &resp.error {
                bail!("request {} failed in its batch: {err}", resp.id);
            }
            lat_ms.push((resp.queue_us + resp.exec_us) as f64 / 1000.0);
            let pred = resp
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j as i32)
                .unwrap();
            if pred == label {
                correct += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let acc = correct as f64 / served.max(1) as f64;
        let p50 = stats::percentile(&lat_ms, 50.0);
        let p99 = stats::percentile(&lat_ms, 99.0);
        let mut o = Outcome::new(self.name(), p.to_json());
        o.note(format!(
            "served {served} requests in {dt:.2}s ({:.0} req/s), accuracy \
             {acc:.4}",
            served as f64 / dt
        ));
        if shed > 0 {
            o.note(format!(
                "admission shed {shed} of {n_req} offered (queue depth \
                 limit {depth})"
            ));
        }
        let snapshot = coord.metrics.snapshot();
        o.note(format!(
            "latency p50 {p50:.1} ms, p99 {p99:.1} ms | {snapshot}"
        ));
        o.metric("req_per_s", served as f64 / dt, "req/s")
            .metric("accuracy", acc, "")
            .metric("latency_p50_ms", p50, "ms")
            .metric("latency_p99_ms", p99, "ms")
            .metric("shed", shed as f64, "");
        // the coordinator's live tallies, in registry form (JSON-only
        // metric records; text rendering is tables + notes)
        let mut registry = crate::obs::Registry::new();
        snapshot.fill_registry(&mut registry);
        for (name, v) in registry.counters() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        coord.shutdown();
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        // only the pjrt path reads the artifact directory (matching
        // run()'s case-insensitive backend resolution)
        if p.get_str("backend").eq_ignore_ascii_case("pjrt") {
            artifacts_extra(p)
        } else {
            Ok(String::new())
        }
    }
}

// --------------------------------------------------------------- infer --

pub struct Infer;

impl Scenario for Infer {
    fn name(&self) -> &'static str {
        "infer"
    }

    fn description(&self) -> &'static str {
        "single-batch smoke inference on a pluggable backend"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            backend_spec(),
            ParamSpec::str("network", "SyntheticCNN",
                           "simulated network (sim backend)"),
            ParamSpec::str("arch", "neural-pim",
                           "simulated chip architecture (sim backend)"),
            ParamSpec::u64("seed", 42, "PRNG seed (sim backend)"),
            artifacts_spec(),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let backend_name = parse_backend(p)?;
        let mut o = Outcome::new(self.name(), p.to_json());
        match backend_name.as_str() {
            "pjrt" => {
                let dir = artifacts_dir(p);
                let ts =
                    runtime::TestSet::load(std::path::Path::new(&dir))?;
                let (h, w, c) = ts.dims;
                let stride = h * w * c;
                let backend = PjrtBackend::new(dir, "cnn_ideal", stride);
                let mut worker = backend.worker()?;
                let data: Vec<f32> = (0..128)
                    .flat_map(|i| {
                        let idx = i % ts.n;
                        ts.images[idx * stride..(idx + 1) * stride].to_vec()
                    })
                    .collect();
                let r = worker.execute(&crate::serve::BatchInput {
                    data: &data,
                    n: 128,
                    image_len: stride,
                })?;
                let acc = runtime::accuracy(
                    &r.logits,
                    &ts.batch_labels(0, 128),
                    10,
                );
                o.note(format!("cnn_ideal first-batch accuracy: {acc:.4}"));
                o.metric("accuracy", acc, "");
            }
            "sim" => {
                let net = sim_network(p)?;
                let cfg = sim_config(p)?;
                let seed = p.get_u64("seed");
                let backend =
                    SimBackend::new(&net, &cfg, 128, SIM_IMAGE_LEN, seed);
                let mut worker = backend.worker()?;
                let mut rng = Pcg::new(seed);
                let data: Vec<f32> = (0..128 * SIM_IMAGE_LEN)
                    .map(|_| rng.below(256) as f32)
                    .collect();
                let r = worker.execute(&crate::serve::BatchInput {
                    data: &data,
                    n: 128,
                    image_len: SIM_IMAGE_LEN,
                })?;
                anyhow::ensure!(
                    r.logits.len() == 128 * backend.classes(),
                    "sim backend returned {} logits",
                    r.logits.len()
                );
                let exec_ms = r.exec_us as f64 / 1000.0;
                o.note(format!(
                    "sim first-batch: 128 images through {} in {exec_ms:.3} \
                     ms (simulated)",
                    backend.network()
                ));
                o.metric("sim_exec_ms", exec_ms, "ms");
            }
            other => bail!("backend '{other}' has no construction path in \
                            the infer scenario"),
        }
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        if p.get_str("backend").eq_ignore_ascii_case("pjrt") {
            artifacts_extra(p)
        } else {
            Ok(String::new())
        }
    }
}

// ----------------------------------------------------------- serve-sim --

pub struct ServeSim;

impl Scenario for ServeSim {
    fn name(&self) -> &'static str {
        "serve-sim"
    }

    fn description(&self) -> &'static str {
        "offered-load sweep of the serving layer on the simulated \
         backend (no artifacts)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::str("network", "SyntheticCNN", "simulated network"),
            ParamSpec::str("arch", "neural-pim",
                           "simulated chip architecture"),
            ParamSpec::str("loads", "0.5,0.8,1.0,1.2",
                           "offered loads vs padded-batch service rate"),
            ParamSpec::u64("requests", 2048, "arrivals per load point"),
            ParamSpec::u64("workers", 2, "serving workers"),
            ParamSpec::u64("max-batch", 64, "executable batch"),
            ParamSpec::u64("max-wait-us", 200,
                           "batching fill window (virtual µs)"),
            ParamSpec::u64("depth", 256, "admission queue bound"),
            ParamSpec::u64("seed", 42, "PRNG seed"),
            ParamSpec::u64("shards", 1,
                           "independent fleet slices per load point"),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let net = sim_network(p)?;
        let cfg = sim_config(p)?;
        let loads = parse_loads(p.get_str("loads"))?;
        let max_batch = p.get_usize("max-batch").max(1);
        let nc = model::network_cost(&net, &cfg);
        let sp = event::service_profile(&cfg, &nc);
        let lg = loadgen::LoadGenConfig {
            requests: p.get_u64("requests"),
            workers: p.get_usize("workers"),
            max_batch,
            max_wait_us: p.get_u64("max-wait-us"),
            max_queue_depth: p.get_usize("depth"),
            batch_exec_us: sp.batch_us(max_batch as u64),
            seed: p.get_u64("seed"),
            shards: p.get_usize("shards").max(1),
        };
        // `--trace` (dispatch-armed thread-local spec): the traced
        // sweep emits admission/batch/queue-depth events in virtual
        // picoseconds; point numbers are bit-identical on both paths
        let spec = crate::obs::trace_spec();
        let points = match &spec {
            Some(spec) => {
                let (points, trace) =
                    loadgen::sweep_traced(&lg, &loads,
                                          spec.filter.as_deref())?;
                trace.write_file(&spec.path)?;
                crate::diag!(
                    1,
                    "serve-sim: wrote {} trace events to {}",
                    trace.len(), spec.path
                );
                points
            }
            None => loadgen::sweep(&lg, &loads)?,
        };

        let arch_name = model::cost_model(cfg.arch).name();
        let mut t = Table::new(
            &format!(
                "serve-sim: {} on {arch_name}, batch {max_batch} x {} \
                 workers (depth {})",
                net.name,
                lg.workers,
                lg.max_queue_depth
            ),
            &["offered", "served", "shed", "shed rate", "req/s",
              "p50 (ms)", "p95 (ms)", "p99 (ms)", "avg batch"],
        );
        for pt in &points {
            t.cells(vec![
                Cell::num(pt.offered, format!("{:.2}", pt.offered)),
                Cell::num(pt.served as f64, pt.served.to_string()),
                Cell::num(pt.shed as f64, pt.shed.to_string()),
                Cell::num(pt.shed_rate, format!("{:.3}", pt.shed_rate)),
                Cell::num(pt.throughput_rps,
                          format!("{:.0}", pt.throughput_rps)),
                Cell::num(pt.p50_ms, format!("{:.3}", pt.p50_ms)),
                Cell::num(pt.p95_ms, format!("{:.3}", pt.p95_ms)),
                Cell::num(pt.p99_ms, format!("{:.3}", pt.p99_ms)),
                Cell::num(pt.avg_batch, format!("{:.1}", pt.avg_batch)),
            ]);
        }
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(t);
        o.note(format!(
            "simulated backend: batch {max_batch} executes in {:.3} ms \
             (fill {:.3} ms + {} x {:.4} ms bottleneck); no artifacts \
             required",
            lg.batch_exec_us as f64 / 1000.0,
            sp.fill_ps() as f64 / 1e9,
            max_batch - 1,
            sp.bottleneck_ps() as f64 / 1e9,
        ));
        // the typed-rejection satellite's runtime half: a load point
        // where every arrival was shed is a saturated (degenerate)
        // operating point, not a latency measurement
        let saturated: Vec<String> = points
            .iter()
            .filter(|pt| pt.shed_rate >= 1.0)
            .map(|pt| format!("{:.2}", pt.offered))
            .collect();
        if !saturated.is_empty() {
            o.note(format!(
                "warning: offered load(s) {} saturated the admission \
                 queue (shed rate 1.0) — latency columns there describe \
                 no served traffic",
                saturated.join(", ")
            ));
        }
        o.metric("batch_exec_ms", lg.batch_exec_us as f64 / 1000.0, "ms");
        for pt in &points {
            let tag = format!("{:.2}", pt.offered);
            o.metric(format!("throughput_rps@{tag}"), pt.throughput_rps,
                     "req/s")
                .metric(format!("p99_ms@{tag}"), pt.p99_ms, "ms")
                .metric(format!("shed_rate@{tag}"), pt.shed_rate, "");
            if let Some(p999) = pt.p999_ms {
                o.metric(format!("p999_ms@{tag}"), p999, "ms");
            }
        }
        // registry totals across load points (merged in point order) as
        // namespaced metric records — JSON-only surface
        let mut registry = crate::obs::Registry::new();
        for pt in &points {
            registry.merge(&pt.registry);
        }
        for (name, v) in registry.counters() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        for (name, v) in registry.gauges() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        Ok(o)
    }
}

// ----------------------------------------------------------- fleet-sim --

pub struct FleetSim;

impl Scenario for FleetSim {
    fn name(&self) -> &'static str {
        "fleet-sim"
    }

    fn description(&self) -> &'static str {
        "virtual datacenter: route a diurnal/bursty arrival stream \
         across a heterogeneous fleet of priced PIM chips"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::str("network", "SyntheticCNN", "simulated network"),
            ParamSpec::str("fleet", "neural-pim:8,isaac:4,cascade:2,lowres:2",
                           "chip mix as arch:count (model registry names)"),
            ParamSpec::choice("policy", "latency-aware",
                              &["round-robin", "rr", "join-shortest-queue",
                                "jsq", "latency-aware", "ewma"],
                              "router policy"),
            ParamSpec::u64("arrivals", 1 << 20,
                           "virtual arrivals to stream through the router"),
            ParamSpec::f64("offered", 0.9,
                           "diurnal-average offered load vs fleet capacity"),
            ParamSpec::u64("max-batch", 64, "executable batch per chip"),
            ParamSpec::u64("depth", 256, "per-chip admission queue bound"),
            ParamSpec::u64("seed", 42, "PRNG seed"),
            ParamSpec::f64("diurnal", 0.3,
                           "diurnal amplitude in [0, 0.95]; 0 disables"),
            ParamSpec::u64("diurnal-period-us", 200_000,
                           "diurnal period (virtual µs)"),
            ParamSpec::f64("burst-mult", 3.0,
                           "burst rate multiplier (1 disables bursts)"),
            ParamSpec::f64("burst-enter", 0.0005,
                           "per-candidate burst entry probability"),
            ParamSpec::f64("burst-exit", 0.02,
                           "per-candidate burst exit probability"),
            ParamSpec::u64("sweep-arrivals", 1 << 18,
                           "arrivals per chip-count sweep point; 0 skips \
                            the knee sweep"),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let net = sim_network(p)?;
        let mix = fleet::parse_fleet(p.get_str("fleet"))?;
        let policy = fleet::RouterPolicy::parse(p.get_str("policy"))?;
        let offered = p.get_f64("offered");
        if !offered.is_finite() || offered <= 0.0 {
            bail!("--offered must be positive and finite (got {offered})");
        }
        let max_batch = p.get_usize("max-batch").max(1);
        let classes = fleet::build_classes(&net, &mix, max_batch);
        let cfg = fleet::FleetConfig {
            arrivals: p.get_u64("arrivals"),
            offered,
            policy,
            max_queue_depth: p.get_usize("depth").max(1),
            seed: p.get_u64("seed"),
            diurnal_amp: p.get_f64("diurnal"),
            diurnal_period_us: p.get_u64("diurnal-period-us").max(1),
            burst_mult: p.get_f64("burst-mult"),
            burst_enter: p.get_f64("burst-enter"),
            burst_exit: p.get_f64("burst-exit"),
        };
        crate::diag!(
            1,
            "fleet-sim: {} arrivals over {} chips ({})",
            cfg.arrivals,
            classes.iter().map(|c| c.count).sum::<usize>(),
            fleet::mix_string(&mix)
        );
        // `--trace`: per-chip track prefixes (`chip{i}/{class}/...`),
        // absorbed in chip order; numbers identical on both paths
        let spec = crate::obs::trace_spec();
        let r = match &spec {
            Some(spec) => {
                let (r, trace) = fleet::run_fleet_traced(
                    &cfg, &classes, spec.filter.as_deref());
                trace.write_file(&spec.path)?;
                crate::diag!(
                    1,
                    "fleet-sim: wrote {} trace events to {}",
                    trace.len(), spec.path
                );
                r
            }
            None => fleet::run_fleet(&cfg, &classes),
        };

        let mut t = Table::new(
            &format!(
                "fleet-sim: {} arrivals on {} ({} policy, depth {})",
                r.arrivals, net.name, policy.name(), cfg.max_queue_depth
            ),
            &["class", "chips", "served", "shed", "avg batch", "p99 (ms)",
              "energy/inf (uJ)", "energy (J)"],
        );
        for c in &r.per_class {
            t.cells(vec![
                Cell::s(c.name),
                Cell::num(c.chips as f64, c.chips.to_string()),
                Cell::num(c.served as f64, c.served.to_string()),
                Cell::num(c.shed as f64, c.shed.to_string()),
                Cell::num(c.avg_batch, format!("{:.1}", c.avg_batch)),
                Cell::num(c.p99_ms, format!("{:.3}", c.p99_ms)),
                Cell::num(c.energy_j_per_inf * 1e6,
                          format!("{:.2}", c.energy_j_per_inf * 1e6)),
                Cell::num(c.energy_j_total,
                          format!("{:.3}", c.energy_j_total)),
            ]);
        }
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(t);
        let p999 = match r.p999_ms {
            Some(v) => format!("{v:.3} ms"),
            None => "n/a (under the 1000-sample guard)".to_string(),
        };
        o.note(format!(
            "fleet served {} of {} arrivals ({:.0} req/s virtual), p50 \
             {:.3} ms, p99 {:.3} ms, p99.9 {p999}, shed rate {:.4}",
            r.served, r.arrivals, r.throughput_rps, r.p50_ms, r.p99_ms,
            r.shed_rate
        ));
        if r.shed_rate >= 1.0 {
            o.note("warning: the fleet saturated (shed rate 1.0) — \
                    latency numbers describe no served traffic"
                .to_string());
        }
        o.metric("chips", r.chips as f64, "")
            .metric("throughput_rps", r.throughput_rps, "req/s")
            .metric("p50_ms", r.p50_ms, "ms")
            .metric("p99_ms", r.p99_ms, "ms")
            .metric("shed_rate", r.shed_rate, "");
        if let Some(v) = r.p999_ms {
            o.metric("p999_ms", v, "ms");
        }
        for c in &r.per_class {
            o.metric(format!("energy_uj_per_inf@{}", c.name),
                     c.energy_j_per_inf * 1e6, "uJ");
        }

        // chip-count sweep at the same absolute arrival rate: where
        // does adding chips stop buying tail latency?
        let sweep_arrivals = p.get_u64("sweep-arrivals");
        if sweep_arrivals > 0 {
            let (points, knee) = fleet::knee_sweep(
                &cfg, &net, &mix, max_batch, sweep_arrivals);
            let mut ts = Table::new(
                "chip-count sweep (fixed absolute arrival rate)",
                &["chips", "mix scale", "offered", "p99 (ms)",
                  "shed rate"],
            );
            for kp in &points {
                ts.cells(vec![
                    Cell::num(kp.chips as f64, kp.chips.to_string()),
                    Cell::num(kp.scale, format!("{:.2}", kp.scale)),
                    Cell::num(kp.offered, format!("{:.2}", kp.offered)),
                    Cell::num(kp.p99_ms, format!("{:.3}", kp.p99_ms)),
                    Cell::num(kp.shed_rate, format!("{:.4}", kp.shed_rate)),
                ]);
            }
            o.table(ts);
            o.note(format!(
                "knee at {knee} chips: the smallest fleet within 5% of \
                 the largest fleet's p99 at this arrival rate"
            ));
            o.metric("knee_chips", knee as f64, "");
        }

        // registry totals (typed per-class shed counters included) as
        // namespaced metric records — JSON-only surface
        for (name, v) in r.registry.counters() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        for (name, v) in r.registry.gauges() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        Ok(o)
    }
}

/// Parse the `--loads` list: comma-separated positive finite fractions.
fn parse_loads(s: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let v: f64 = part
            .parse()
            .map_err(|_| anyhow::anyhow!("--loads: '{part}' is not a \
                                          number"))?;
        if !v.is_finite() || v <= 0.0 {
            bail!("--loads values must be positive and finite (got {v})");
        }
        out.push(v);
    }
    if out.is_empty() {
        bail!("--loads needs at least one offered-load value");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario;
    use crate::util::json::Json;

    #[test]
    fn parse_loads_accepts_lists_and_rejects_garbage() {
        assert_eq!(parse_loads("0.5, 1.0,1.5").unwrap(), vec![0.5, 1.0, 1.5]);
        assert!(parse_loads("").is_err());
        assert!(parse_loads("0.5,zoom").is_err());
        assert!(parse_loads("-1").is_err());
        assert!(parse_loads("inf").is_err());
    }

    #[test]
    fn fleet_policy_is_a_closed_choice_param() {
        let sc = scenario::find("fleet-sim").unwrap();
        // typos die at param parse time now, not inside the router
        let err = scenario::params_from_json(
            &sc.param_specs(),
            &Json::parse(r#"{"policy":"jsqq"}"#).unwrap(),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("must be one of"), "{msg}");
        assert!(msg.contains("did you mean 'jsq'"), "{msg}");
        // every allowed spelling still resolves in the router
        for s in ["round-robin", "rr", "join-shortest-queue", "jsq",
                  "latency-aware", "ewma"] {
            let p = scenario::params_from_json(
                &sc.param_specs(),
                &Json::parse(&format!(r#"{{"policy":"{s}"}}"#)).unwrap(),
            )
            .unwrap();
            assert!(fleet::RouterPolicy::parse(p.get_str("policy")).is_ok(),
                    "{s}");
        }
    }

    #[test]
    fn unknown_backend_suggests_a_registered_one() {
        let sc = scenario::find("serve").unwrap();
        let p = scenario::params_from_json(
            &sc.param_specs(),
            &Json::parse(r#"{"backend": "simm"}"#).unwrap(),
        )
        .unwrap();
        let err = sc.run(&p).unwrap_err();
        assert!(format!("{err:#}").contains("did you mean 'sim'"),
                "{err:#}");
    }
}
