//! The scenario registry: the one list that knows every experiment.
//!
//! `main.rs` dispatches through [`find`]; the suite runner, the CLI
//! usage text, and the integration tests iterate [`scenarios`]. Append
//! an entry here (plus its impl in `analytic.rs`/`pjrt.rs`) and the new
//! experiment is reachable as `neural-pim <name>`, `run <name>
//! --format json`, a cacheable store entry, and a suite member — with
//! zero call-site edits anywhere else.

use super::{analytic, pjrt, serve, Scenario};

/// Every registered scenario, in help/report order.
static SCENARIOS: [&dyn Scenario; 16] = [
    &analytic::Characterize,
    &analytic::Simulate,
    &analytic::EventSim,
    &analytic::Dse,
    &analytic::Table2,
    &analytic::Table3,
    &analytic::Budget,
    &analytic::Noise,
    &analytic::Offload,
    &serve::ServeSim,
    &serve::FleetSim,
    &pjrt::Accuracy,
    &pjrt::Mc,
    &pjrt::PeriphTable,
    &serve::Serve,
    &serve::Infer,
];

/// All registered scenarios, in registry order.
pub fn scenarios() -> &'static [&'static dyn Scenario] {
    &SCENARIOS
}

/// Normalized lookup key: case-insensitive, `-`/`_`/space-insensitive
/// (`event-sim` == `event_sim` == `EventSim`).
fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace(['-', '_', ' '], "")
}

/// Resolve a command spelling against every name and alias.
pub fn find(name: &str) -> Option<&'static dyn Scenario> {
    let want = normalize(name);
    SCENARIOS.iter().copied().find(|s| {
        normalize(s.name()) == want
            || s.aliases().iter().any(|a| normalize(a) == want)
    })
}
