//! Scenario impls over the analytical/simulator stack (`sim`, `event`,
//! `dse`, `noise`, `baselines` via `report`) — everything that runs
//! from a fresh checkout with no artifacts.
//!
//! Each impl is a thin shim: parameters declared once, the heavy
//! lifting delegated to `report`/`sim`/`event`/`dse`/`noise`, and the
//! result packaged as a typed [`Outcome`] whose text rendering is
//! byte-identical to the pre-scenario CLI arms (golden-tested).

use super::{Outcome, ParamSpec, Params, Scenario};
use crate::config::AcceleratorConfig;
use crate::dataflow;
use crate::util::num::fnv1a64;
use crate::workloads::{self, Network};
use crate::{dse, energy, event, noise, offload, report};
use anyhow::{Context, Result};

/// The `--network` / `--all` / `--network-file` triple shared by the
/// simulation scenarios (same semantics as the pre-scenario CLI: a
/// file wins, then an explicit name, else all nine benchmarks).
fn network_specs() -> Vec<ParamSpec> {
    vec![
        ParamSpec::str("network", "", "one benchmark by name"),
        ParamSpec::flag("all", "all nine benchmarks (the default)"),
        ParamSpec::str("network-file", "",
                       "runtime-defined network from a JSON spec"),
    ]
}

fn selected_networks(p: &Params) -> Result<Vec<Network>> {
    let file = p.get_str("network-file");
    if !file.is_empty() {
        return Ok(vec![workloads::load(file)?]);
    }
    let name = p.get_str("network");
    if p.get_bool("all") || name.is_empty() {
        Ok(workloads::all_benchmarks())
    } else {
        Ok(vec![workloads::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown network {name}"))?])
    }
}

/// Content hash of the `--network-file` spec (when present), so cached
/// results can never be served after the file changes.
fn network_file_extra(p: &Params) -> Result<String> {
    let file = p.get_str("network-file");
    if file.is_empty() {
        return Ok(String::new());
    }
    let text = std::fs::read_to_string(file)
        .with_context(|| format!("reading network spec {file}"))?;
    Ok(format!("netfile:{:016x}", fnv1a64(text.as_bytes())))
}

// -------------------------------------------------------- characterize --

pub struct Characterize;

impl Scenario for Characterize {
    fn name(&self) -> &'static str {
        "characterize"
    }

    fn description(&self) -> &'static str {
        "§3 dataflow framework (Eqs. 2-8, Fig. 3d/4b/4c)"
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(report::characterization_table())
            .table(report::fig4b_table())
            .table(report::fig4c_table());
        let default = Default::default();
        o.metric("conversions_per_group_A",
                 dataflow::conversions_a(&default) as f64, "")
            .metric("conversions_per_group_B",
                    dataflow::conversions_b(&default) as f64, "")
            .metric("conversions_per_group_C",
                    dataflow::conversions_c() as f64, "");
        Ok(o)
    }
}

// ------------------------------------------------------------ simulate --

pub struct Simulate;

impl Scenario for Simulate {
    fn name(&self) -> &'static str {
        "simulate"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["sim"]
    }

    fn description(&self) -> &'static str {
        "full-system simulation (Fig. 12/13 + headline)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        network_specs()
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let nets = selected_networks(p)?;
        let r = report::system_report(&nets);
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(r.table_energy)
            .table(r.table_throughput)
            .table(r.table_breakdown)
            .table(r.table_latency)
            .note(r.headline);
        o.metrics = r.metrics;
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        network_file_extra(p)
    }
}

// ------------------------------------------------------------ event-sim --

pub struct EventSim;

impl EventSim {
    fn load_from(p: &Params) -> event::RequestLoad {
        event::RequestLoad {
            requests: p.get_u64("requests"),
            replicas: p.get_usize("replicas"),
            utilization: p.get_f64("load"),
            seed: p.get_u64("seed"),
            shards: p.get_usize("shards").max(1),
        }
    }
}

impl Scenario for EventSim {
    fn name(&self) -> &'static str {
        "event-sim"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["event"]
    }

    fn description(&self) -> &'static str {
        "discrete-event cross-validation + tail latency under Poisson load"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        let mut specs = network_specs();
        specs.push(ParamSpec::u64("requests", 256, "total inferences"));
        specs.push(ParamSpec::u64("replicas", 4, "independent chip replicas"));
        specs.push(ParamSpec::f64("load", 0.8,
                                  "offered load vs bottleneck rate"));
        specs.push(ParamSpec::u64("seed", 42, "PRNG seed"));
        specs.push(ParamSpec::u64("shards", 1,
                                  "engine shards per replica"));
        specs
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let nets = selected_networks(p)?;
        let started = std::time::Instant::now();
        let rows = event::cross_validate(&nets);
        let load = Self::load_from(p);
        // `--trace` arms the thread-local spec (dispatch wires it):
        // profile numbers are bit-identical on both paths, the traced
        // one additionally emits the Perfetto-loadable virtual-time
        // trace. On a `--cache` hit run() never executes, so no trace
        // is produced — rerun without --cache to record one.
        let spec = crate::obs::trace_spec();
        let profiles = match &spec {
            Some(spec) => {
                let (profiles, trace) = report::event_latency_profiles_traced(
                    &nets, &load, spec.filter.as_deref());
                trace.write_file(&spec.path)?;
                crate::diag!(
                    1,
                    "event-sim: wrote {} trace events to {}",
                    trace.len(), spec.path
                );
                profiles
            }
            None => report::event_latency_profiles(&nets, &load),
        };
        let elapsed_s = started.elapsed().as_secs_f64();
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(report::event_cross_validation_table_from(&rows))
            .table(report::event_latency_table_from(&profiles, &load));
        let max_rel_err = rows
            .iter()
            .map(|r| r.energy_rel_err)
            .fold(0.0f64, f64::max);
        let events: u64 = rows.iter().map(|r| r.events).sum::<u64>()
            + profiles.iter().map(|p| p.events).sum::<u64>();
        // The Outcome (and therefore the stored/cached JSON) carries
        // only run-to-run-stable quantities; the wall-clock event rate
        // goes to stderr behind --verbose, where operational chatter
        // lives, so cached replays and any golden over metrics stay
        // byte-identical.
        crate::diag!(
            1,
            "event-sim: {events} events in {elapsed_s:.3}s ({:.0} events/s)",
            events as f64 / elapsed_s.max(1e-9)
        );
        let clamped: u64 = profiles.iter().map(|p| p.clamped).sum();
        if let Some(w) = event::clamped_warning(clamped) {
            // never fires on a healthy model (the pipeline cannot
            // schedule into the past), so golden text is unaffected
            o.note(w);
        }
        let peak_queue =
            profiles.iter().map(|p| p.peak_queue).max().unwrap_or(0);
        o.metric("max_energy_rel_err", max_rel_err, "")
            .metric("events", events as f64, "")
            .metric("clamped", clamped as f64, "")
            .metric("peak_queue", peak_queue as f64, "");
        for lp in &profiles {
            o.metric(
                format!("p99_s/{}/{}", lp.network, lp.arch.name()),
                lp.p99_s,
                "s",
            );
        }
        // registry totals (merged in profile order) ride along as
        // namespaced metric records — JSON-only surface, the text
        // rendering prints tables and notes exclusively
        let mut registry = crate::obs::Registry::new();
        for lp in &profiles {
            registry.merge(&lp.registry);
        }
        for (name, v) in registry.counters() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        for (name, v) in registry.gauges() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        network_file_extra(p)
    }
}

// ----------------------------------------------------------------- dse --

pub struct Dse;

impl Scenario for Dse {
    fn name(&self) -> &'static str {
        "dse"
    }

    fn description(&self) -> &'static str {
        "design-space exploration (Fig. 11)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::u64("top", 12, "design points to list"),
            ParamSpec::flag("fine",
                            "stream the ~1M-candidate fine grid instead \
                             of the ~360-point Fig. 11 grid"),
            ParamSpec::u64("batch", 4096,
                           "fine-grid indices per pool submission \
                            (memory knob; never changes results)"),
            ParamSpec::u64("stride", 1,
                           "fine-grid subsampling step (1 = full grid)"),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let top = p.get_usize("top");
        if p.get_bool("fine") {
            return run_fine(p, top);
        }
        // one sweep shared by the table and the best-point metrics (the
        // old CLI arm ran it twice)
        let pts = dse::sweep();
        let best = dse::best_of(&pts);
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(report::fig11_table_from(&pts, top)).note(format!(
            "best: {} at {:.1} GOPS/s/mm² (paper: N128-D4-A4-S64 M64 at \
             1904.0)",
            best.label, best.compute_efficiency
        ));
        o.metric("best_compute_efficiency", best.compute_efficiency,
                 "GOPS/s/mm²")
            .metric("best_energy_efficiency", best.energy_efficiency,
                    "GOPS/s/W");
        Ok(o)
    }
}

/// `dse --fine`: the streamed million-point sweep. Every value in the
/// outcome — tallies, top table, the feasible-list fingerprint — is
/// invariant to `--threads` and `--batch` (asserted by the integration
/// suite); only `--stride` changes what is explored.
fn run_fine(p: &Params, top: usize) -> Result<Outcome> {
    let spec = dse::FineSpec {
        batch: p.get_usize("batch").max(1),
        stride: p.get_usize("stride").max(1),
        top,
    };
    let s = dse::fine_sweep(&spec);
    let mut o = Outcome::new("dse", p.to_json());
    o.table(report::fig11_table_from(&s.top, top)).note(format!(
        "fine sweep: {} candidates ({} feasible; rejected: {} \
         ADC-starved, {} SA-starved, {} I/O-bound), feasible-list \
         fingerprint {:016x}",
        s.candidates,
        s.feasible,
        s.rejected_adc,
        s.rejected_sa,
        s.rejected_io,
        s.feasible_fp
    ));
    if let Some(best) = s.top.first() {
        o.note(format!(
            "best: {} at {:.1} GOPS/s/mm² (paper: N128-D4-A4-S64 M64 at \
             1904.0)",
            best.label, best.compute_efficiency
        ));
        o.metric("best_compute_efficiency", best.compute_efficiency,
                 "GOPS/s/mm²")
            .metric("best_energy_efficiency", best.energy_efficiency,
                    "GOPS/s/W");
    }
    o.metric("candidates", s.candidates as f64, "")
        .metric("feasible", s.feasible as f64, "")
        .metric("rejected_adc_starved", s.rejected_adc as f64, "")
        .metric("rejected_sa_starved", s.rejected_sa as f64, "")
        .metric("rejected_io_bound", s.rejected_io as f64, "");
    Ok(o)
}

// -------------------------------------------------------- table2/table3 --

pub struct Table2;

impl Scenario for Table2 {
    fn name(&self) -> &'static str {
        "table2"
    }

    fn description(&self) -> &'static str {
        "Table 2: Neural-PIM tile-level parameters"
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(report::table2());
        let chip = energy::chip_budget(&AcceleratorConfig::neural_pim());
        o.metric("chip_power_w", chip.power(), "W")
            .metric("chip_area_mm2", chip.area(), "mm²");
        Ok(o)
    }
}

pub struct Table3;

impl Scenario for Table3 {
    fn name(&self) -> &'static str {
        "table3"
    }

    fn description(&self) -> &'static str {
        "Table 3: PE-level architecture comparison"
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(report::table3());
        for r in crate::baselines::pe_comparison() {
            o.metric(format!("pe_power_w/{}", r.arch.name()), r.pe_power_w,
                     "W")
                .metric(format!("pe_area_mm2/{}", r.arch.name()),
                        r.pe_area_mm2, "mm²");
        }
        Ok(o)
    }
}

// -------------------------------------------------------------- budget --

pub struct Budget;

impl Scenario for Budget {
    fn name(&self) -> &'static str {
        "budget"
    }

    fn description(&self) -> &'static str {
        "PE/tile/chip power & area budget for one architecture"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![ParamSpec::str("arch", "neural-pim",
                            "architecture name or alias")]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let arch = crate::config::Architecture::parse(p.get_str("arch"))?;
        let cfg = AcceleratorConfig::for_arch(arch);
        let tile = energy::tile_budget(&cfg);
        let chip = energy::chip_budget(&cfg);
        let mut o = Outcome::new(self.name(), p.to_json());
        // table and metric records come from the same computed budgets
        o.table(report::budget_table_from(&cfg, &tile, &chip));
        o.metric("pe_power_w", tile.pe.power(), "W")
            .metric("pe_area_mm2", tile.pe.area(), "mm²")
            .metric("tile_power_w", tile.power(), "W")
            .metric("chip_power_w", chip.power(), "W")
            .metric("chip_area_mm2", chip.area(), "mm²");
        Ok(o)
    }
}

// --------------------------------------------------------------- noise --

pub struct Noise;

impl Scenario for Noise {
    fn name(&self) -> &'static str {
        "noise"
    }

    fn description(&self) -> &'static str {
        "native noise MC: per-strategy SINAD markers (Fig. 10)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::u64("samples", 400, "Monte-Carlo dot products"),
            ParamSpec::u64("seed", 42, "PRNG seed"),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let samples = p.get_usize("samples");
        let seed = p.get_u64("seed");
        let mut o = Outcome::new(self.name(), p.to_json());
        let mut t = crate::util::table::Table::new(
            &format!(
                "Fig 10: dataflow SINAD markers from the behavioural models \
                 ({samples} samples, seed {seed})"
            ),
            &["strategy", "SINAD (dB)"],
        );
        for (ch, label) in [
            ('A', "A (ISAAC-style digital acc.)"),
            ('B', "B (CASCADE-style buffered)"),
            ('C', "C (ideal fully-analog)"),
        ] {
            let sinad = noise::strategy_sinad(ch, samples, seed);
            t.cells(vec![
                crate::util::table::Cell::s(label),
                crate::util::table::Cell::num(sinad, format!("{sinad:.1}")),
            ]);
            o.metric(format!("sinad_db_{ch}"), sinad, "dB");
        }
        o.table(t);
        Ok(o)
    }
}

// ------------------------------------------------------------- offload --

pub struct Offload;

impl Scenario for Offload {
    fn name(&self) -> &'static str {
        "offload"
    }

    fn description(&self) -> &'static str {
        "PIM + NPU hybrid: deterministic per-layer placement search \
         minimizing EDP"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        let mut specs = network_specs();
        specs.push(ParamSpec::choice(
            "search",
            "auto",
            &offload::STRATEGY_CHOICES,
            "placement search strategy (auto: exhaustive for small nets, \
             hillclimb above)",
        ));
        specs.push(ParamSpec::u64("seed", 42, "PRNG seed"));
        specs
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        use crate::util::table::{Cell, Table};
        let nets = selected_networks(p)?;
        let strategy = offload::Strategy::parse(p.get_str("search"))?;
        let seed = p.get_u64("seed");
        let cfg_pim = AcceleratorConfig::neural_pim();
        let cfg_npu = offload::default_npu_config();
        // the searches parallelize internally (mask chunks / restarts /
        // arms over util::pool); networks run in declaration order so
        // tables, metrics and the memo cache fill deterministically
        let reports: Vec<offload::OffloadReport> = nets
            .iter()
            .map(|net| offload::optimize(net, &cfg_pim, &cfg_npu, strategy,
                                         seed))
            .collect();

        let npu = offload::NpuCost::of(&cfg_npu);
        let mut t = Table::new(
            &format!(
                "offload: per-layer PIM/NPU placement (search {}, seed \
                 {seed}; NPU {:.1} TOPS peak, {:.2} pJ/MAC)",
                p.get_str("search"),
                npu.tops_peak,
                npu.e_mac * 1e12
            ),
            &["network", "layers", "strategy", "NPU layers", "chips",
              "all-PIM EDP (J*s)", "all-NPU EDP (J*s)", "hybrid EDP (J*s)",
              "win %"],
        );
        let mut wins = 0usize;
        let mut registry = crate::obs::Registry::new();
        for r in &reports {
            let win_pct = r.edp_win() * 100.0;
            if r.hybrid.edp < r.best_pure_edp() {
                wins += 1;
            }
            t.cells(vec![
                Cell::s(&r.network),
                Cell::num(r.placement.len() as f64,
                          r.placement.len().to_string()),
                Cell::s(r.strategy),
                Cell::num(r.npu_layers() as f64, r.npu_layers().to_string()),
                Cell::num(r.hybrid.chips as f64, r.hybrid.chips.to_string()),
                Cell::num(r.all_pim.edp, format!("{:.3e}", r.all_pim.edp)),
                Cell::num(r.all_npu.edp, format!("{:.3e}", r.all_npu.edp)),
                Cell::num(r.hybrid.edp, format!("{:.3e}", r.hybrid.edp)),
                Cell::num(win_pct, format!("{win_pct:.2}")),
            ]);
            registry.add("offload.evals", r.evals);
            registry.add("offload.improved", r.improved);
            registry.add("offload.networks", 1);
        }
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(t);

        // single-network runs get the full per-layer split
        if let [r] = reports.as_slice() {
            let mut lt = Table::new(
                &format!("{}: per-layer placement ({})", r.network,
                         r.strategy),
                &["layer", "PIM (uJ)", "NPU (uJ)", "placed"],
            );
            for l in &r.layers {
                lt.cells(vec![
                    Cell::s(&l.name),
                    Cell::num(l.pim_e * 1e6, format!("{:.3}", l.pim_e * 1e6)),
                    Cell::num(l.npu_e * 1e6, format!("{:.3}", l.npu_e * 1e6)),
                    Cell::s(if l.placement.is_npu() { "NPU" } else { "PIM" }),
                ]);
            }
            o.table(lt);
        }

        o.note(format!(
            "hybrid placement strictly beats the best pure deployment on \
             {wins} of {} network(s); it is never worse (both extremes are \
             always evaluated)",
            reports.len()
        ));
        for r in &reports {
            o.metric(format!("edp/{}", r.network), r.hybrid.edp, "J*s")
                .metric(format!("edp_all_pim/{}", r.network), r.all_pim.edp,
                        "J*s")
                .metric(format!("edp_all_npu/{}", r.network), r.all_npu.edp,
                        "J*s")
                .metric(format!("edp_win/{}", r.network), r.edp_win(), "")
                .metric(format!("npu_layers/{}", r.network),
                        r.npu_layers() as f64, "");
        }
        o.metric("networks_with_strict_win", wins as f64, "")
            .metric("npu_tops_peak", npu.tops_peak, "TOPS")
            .metric("npu_e_mac_pj", npu.e_mac * 1e12, "pJ")
            .metric("npu_fill_drain_ns", npu.fill_drain_ns, "ns");
        // search-effort counters in registry form, like the other
        // scenario obs exports — JSON-only surface
        for (name, v) in registry.counters() {
            o.metric(format!("obs/{name}"), v as f64, "");
        }
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        network_file_extra(p)
    }
}
