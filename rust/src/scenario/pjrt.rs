//! Scenario impls over the PJRT runtime (`runtime`, `periph`, the
//! Fig. 9 MC artifacts) — everything that needs `make artifacts` first.
//! They fail with a clear error (and the suite records it per entry)
//! when the artifact directory is absent. The serving paths live in
//! `scenario/serve.rs`, parameterized over the `serve` backend registry;
//! runtimes here open through `serve::open_runtime` (the grep-gated
//! construction site).

use super::{Outcome, ParamSpec, Params, Scenario};
use crate::periph;
use crate::runtime;
use crate::serve::open_runtime;
use crate::util::stats;
use crate::util::table::Table;
use anyhow::{bail, Result};

pub(super) fn artifacts_spec() -> ParamSpec {
    ParamSpec::str("artifacts", "",
                   "artifact directory (default: ./artifacts)")
}

pub(super) fn artifacts_dir(p: &Params) -> String {
    let dir = p.get_str("artifacts");
    if dir.is_empty() {
        crate::artifact_dir()
    } else {
        dir.to_string()
    }
}

/// Fingerprint the *resolved* artifact directory: the param defaults to
/// "" and resolves through `$NEURAL_PIM_ARTIFACTS`/the manifest probe,
/// so two runs against different artifact sets must never share a cache
/// address. (Directory contents are not hashed — re-run without
/// `--cache` after `make artifacts`; see DESIGN.md §2b.)
pub(super) fn artifacts_extra(p: &Params) -> Result<String> {
    Ok(format!("artifacts:{}", artifacts_dir(p)))
}

// ------------------------------------------------------------ accuracy --

pub struct Accuracy;

impl Scenario for Accuracy {
    fn name(&self) -> &'static str {
        "accuracy"
    }

    fn description(&self) -> &'static str {
        "run the CNN through a dataflow via PJRT (needs artifacts)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::str("strategy", "C", "A | B | C | ideal | noisy"),
            ParamSpec::u64("adc-bits", 8, "ADC resolution for A/B/C"),
            ParamSpec::f64("sinad", 50.0, "injected SINAD for 'noisy' (dB)"),
            ParamSpec::u64("seed", 42, "PRNG seed"),
            artifacts_spec(),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let rt = open_runtime(&artifacts_dir(p))?;
        let ts = runtime::TestSet::load(rt.dir())?;
        let strategy = p.get_str("strategy").to_string();
        let seed = p.get_u64("seed");
        let batch = 128usize;
        let n_batches = (ts.n / batch).max(1);

        let (artifact, extra): (String, Vec<xla::Literal>) =
            match strategy.as_str() {
                "ideal" => ("cnn_ideal".into(), vec![]),
                "noisy" => {
                    let sinad = p.get_f64("sinad");
                    ("cnn_noisy".into(),
                     vec![runtime::lit_key(seed)?,
                          runtime::lit_scalar_f32(sinad as f32)])
                }
                s @ ("A" | "B" | "C") => {
                    let bits = p.get_usize("adc-bits");
                    if !(1..=16).contains(&bits) {
                        bail!("--adc-bits must be in [1, 16] (got {bits})");
                    }
                    let levels = (1u64 << bits) as f32 - 1.0;
                    let mut extra = vec![runtime::lit_scalar_f32(levels)];
                    if s != "A" {
                        // strategy A is deterministic; its HLO has no key
                        extra.push(runtime::lit_key(seed)?);
                    }
                    (format!("cnn_strat{s}"), extra)
                }
                other => bail!("unknown strategy {other}"),
            };
        let exe = rt.load(&artifact)?;
        let mut o = Outcome::new(self.name(), p.to_json());
        o.note(format!(
            "loaded {artifact} (compiled in {:.1}s) on {}",
            exe.compile_seconds,
            rt.platform()
        ));
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..n_batches {
            let images = ts.batch_literal(b * batch, batch)?;
            let mut inputs = vec![images];
            for e in &extra {
                inputs.push(clone_lit(e));
            }
            let out = exe.run(&inputs)?;
            let logits = runtime::to_f32_vec(&out[0])?;
            let labels = ts.batch_labels(b * batch, batch);
            correct += (runtime::accuracy(&logits, &labels, 10)
                * batch as f64)
                .round() as usize;
            total += batch;
        }
        let acc = correct as f64 / total as f64;
        o.note(format!(
            "strategy={strategy} accuracy={acc:.4} ({total} images)"
        ));
        o.metric("accuracy", acc, "").metric("images", total as f64, "");
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        artifacts_extra(p)
    }
}

fn clone_lit(l: &xla::Literal) -> xla::Literal {
    match l.ty().unwrap() {
        xla::ElementType::U32 => {
            let v = l.to_vec::<u32>().unwrap();
            xla::Literal::vec1(&v).reshape(&[v.len() as i64]).unwrap()
        }
        _ => {
            let v = l.to_vec::<f32>().unwrap();
            if l.element_count() == 1
                && l.array_shape().map(|s| s.dims().is_empty()).unwrap_or(false)
            {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&[v.len() as i64]).unwrap()
            }
        }
    }
}

// ---------------------------------------------------------------- mc --

pub struct Mc;

impl Scenario for Mc {
    fn name(&self) -> &'static str {
        "mc"
    }

    fn description(&self) -> &'static str {
        "Fig. 9 Monte-Carlo on the trained NeuralPeriph (needs artifacts)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::flag("naive", "run the unoptimized circuits (Fig. 9b)"),
            ParamSpec::u64("trials", 4, "Monte-Carlo keys"),
            ParamSpec::u64("seed", 42, "base PRNG seed"),
            artifacts_spec(),
        ]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let rt = open_runtime(&artifacts_dir(p))?;
        let naive = p.get_bool("naive");
        let trials = p.get_usize("trials");
        let artifact = if naive { "mc_naive" } else { "mc_opt" };
        let exe = rt.load(artifact)?;
        let mut all_hw = Vec::new();
        let mut all_sw = Vec::new();
        for t in 0..trials {
            let key = runtime::lit_key(p.get_u64("seed") + t as u64)?;
            let out = exe.run(&[key])?;
            all_hw.extend(
                runtime::to_f32_vec(&out[0])?.iter().map(|&v| v as f64),
            );
            all_sw.extend(
                runtime::to_f32_vec(&out[1])?.iter().map(|&v| v as f64),
            );
        }
        let r = crate::noise::mc_result(&all_hw, &all_sw);
        let mut o = Outcome::new(self.name(), p.to_json());
        o.note(format!(
            "Fig 9{}: {} trials x {} dot products -> SINAD {:.1} dB \
             (err rms {:.0}, bias {:.0}, range [{:.0}, {:.0}])",
            if naive { "b (no optimizations)" } else { "a (optimized)" },
            trials, r.n / trials, r.sinad_db, r.err_rms, r.err_mean,
            r.err_min, r.err_max
        ));
        o.metric("sinad_db", r.sinad_db, "dB")
            .metric("err_rms", r.err_rms, "")
            .metric("err_mean", r.err_mean, "")
            .metric("samples", r.n as f64, "");
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        artifacts_extra(p)
    }
}

// -------------------------------------------------------------- periph --

pub struct PeriphTable;

impl Scenario for PeriphTable {
    fn name(&self) -> &'static str {
        "periph"
    }

    fn description(&self) -> &'static str {
        "Table 1 metrics of the trained circuits (needs artifacts)"
    }

    fn param_specs(&self) -> Vec<ParamSpec> {
        vec![ParamSpec::u64("seed", 42, "PRNG seed"), artifacts_spec()]
    }

    fn run(&self, p: &Params) -> Result<Outcome> {
        let dir = artifacts_dir(p);
        let pr = periph::Periph::load(&format!("{dir}/periph.json"))?;
        let (mse, emax, emin) = pr.nns_a_error_stats(8192, p.get_u64("seed"));
        let tr = pr.nnadc.transfer(1 << 13);
        let (dnl, inl, missing) = periph::dnl_inl(&tr, 8);
        let (enob, sinad) = periph::enob(&pr.nnadc, 1 << 13);
        let mut t = Table::new(
            "Table 1: trained NeuralPeriph circuits (measured natively in \
             Rust)",
            &["metric", "NNS+A", "8-bit NNADC", "paper"],
        );
        t.row(&["approx. MSE (V²)".into(), format!("{mse:.2e}"), "-".into(),
                "<1e-5".into()]);
        t.row(&["max error (mV)".into(), format!("{:.1}", emax * 1e3),
                "-".into(), "4-5".into()]);
        t.row(&["min error (mV)".into(), format!("{:.1}", emin * 1e3),
                "-".into(), "-3..-4".into()]);
        t.row(&["DNL (LSB)".into(), "-".into(),
                format!("{:.2}/{:.2}", stats::min(&dnl), stats::max(&dnl)),
                "-0.25/0.55".into()]);
        t.row(&["INL (LSB)".into(), "-".into(),
                format!("{:.2}/{:.2}", stats::min(&inl), stats::max(&inl)),
                "-0.56/0.62".into()]);
        t.row(&["missing codes".into(), "-".into(), missing.to_string(),
                "0".into()]);
        t.row(&["ENOB (bits)".into(), "-".into(), format!("{enob:.2}"),
                "7.88".into()]);
        t.row(&["sine SINAD (dB)".into(), "-".into(), format!("{sinad:.1}"),
                "~49".into()]);
        let mut o = Outcome::new(self.name(), p.to_json());
        o.table(t);
        o.metric("nns_a_mse_v2", mse, "V²")
            .metric("nnadc_enob_bits", enob, "bits")
            .metric("nnadc_sinad_db", sinad, "dB")
            .metric("nnadc_missing_codes", missing as f64, "");
        Ok(o)
    }

    fn fingerprint_extra(&self, p: &Params) -> Result<String> {
        artifacts_extra(p)
    }
}

