//! `neural-pim` CLI — a thin shell over the scenario registry.
//!
//! Every subcommand (characterization, simulation, DSE, paper tables,
//! the event microsimulation, the PJRT-backed paths) is a registered
//! `scenario::Scenario`; this binary only wires argv to
//! `scenario::dispatch`, which resolves the command generically,
//! validates flags, runs through the results store, and renders text
//! or JSON. No per-scenario match arms live here (grep-enforced by
//! `scripts/verify.sh`) — registering a scenario in
//! `scenario/registry.rs` is the whole job of adding a command.
//!
//! Run `neural-pim help` (or `help <scenario>`) for the generated
//! usage.

use neural_pim::util::cli::Args;

fn main() {
    let args = Args::from_env();
    neural_pim::util::pool::set_threads(args.threads());
    if let Err(e) = neural_pim::scenario::dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
