//! `neural-pim` CLI: characterization, simulation, DSE, paper
//! table/figure regeneration, and the PJRT-backed inference service.

use anyhow::{bail, Result};
use neural_pim::config::{AcceleratorConfig, Architecture};
use neural_pim::coordinator::{Coordinator, CoordinatorConfig};
use neural_pim::runtime::{self, Runtime};
use neural_pim::util::cli::Args;
use neural_pim::util::stats;
use neural_pim::util::table::Table;
use neural_pim::{noise, periph, report, workloads};

const USAGE: &str = "\
neural-pim — Neural-PIM (IEEE TC 2022) reproduction

USAGE: neural-pim <command> [options]

COMMANDS (analytical / simulator — no artifacts needed):
  characterize              §3 dataflow framework (Eqs. 2-8, Fig. 3d/4b/4c)
  simulate [--network N]    full-system simulation (Fig. 12/13 + headline)
            [--all]         all nine benchmarks
            [--network-file F]  a runtime-defined network from a JSON
                            spec (see workloads::from_spec; also accepted
                            by event-sim)
  event-sim [--network N|--all]
            [--requests N] [--replicas R] [--load F]
                            discrete-event microsimulation: cross-validate
                            the analytical energy model (per-scenario
                            tolerance check) and report contention-aware
                            p50/p95/p99 latency under Poisson load;
                            bit-identical at any --threads
  dse [--top K]             design-space exploration (Fig. 11)
  table2 | table3           paper tables
  budget [--arch A]         PE/tile/chip power & area budget

COMMANDS (need `make artifacts`):
  accuracy [--strategy A|B|C|ideal|noisy] [--adc-bits B] [--sinad DB]
                            run the CNN through a dataflow via PJRT
  mc [--naive] [--trials N] Fig. 9 Monte-Carlo (trained NeuralPeriph)
  periph                    Table 1 metrics of the trained circuits
  serve [--requests N]      start the inference coordinator, drive N
                            requests from the test set, report metrics
  infer                     single-batch smoke inference

OPTIONS:
  --artifacts DIR           artifact directory (default: ./artifacts)
  --seed S                  PRNG seed (default 42)
  --threads N               worker threads for the parallel sweeps
                            (simulate/event-sim/dse/mc; default: all cores)
";

fn main() {
    let args = Args::from_env();
    neural_pim::util::pool::set_threads(args.threads());
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "characterize" => characterize(),
        "simulate" => simulate(args),
        "event-sim" => event_sim(args),
        "dse" => dse_cmd(args),
        "table2" => {
            report::table2().print();
            Ok(())
        }
        "table3" => {
            report::table3().print();
            Ok(())
        }
        "budget" => budget(args),
        "accuracy" => accuracy(args),
        "mc" => mc(args),
        "periph" => periph_cmd(args),
        "serve" => serve(args),
        "infer" => infer(args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn characterize() -> Result<()> {
    report::characterization_table().print();
    report::fig4b_table().print();
    report::fig4c_table().print();
    Ok(())
}

fn selected_networks(args: &Args) -> Result<Vec<workloads::Network>> {
    if let Some(path) = args.get("network-file") {
        // runtime-defined network: a JSON layer spec (workloads::load)
        return Ok(vec![workloads::load(path)?]);
    }
    if args.flag("all") || args.get("network").is_none() {
        Ok(workloads::all_benchmarks())
    } else {
        let name = args.get("network").unwrap();
        Ok(vec![workloads::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown network {name}"))?])
    }
}

fn simulate(args: &Args) -> Result<()> {
    let nets = selected_networks(args)?;
    let r = report::system_report(&nets);
    r.table_energy.print();
    r.table_throughput.print();
    r.table_breakdown.print();
    r.table_latency.print();
    println!("{}", r.headline);
    Ok(())
}

fn event_sim(args: &Args) -> Result<()> {
    let nets = selected_networks(args)?;
    report::event_cross_validation_table(&nets).print();
    let load = neural_pim::event::RequestLoad {
        requests: args.get_u64("requests", 256),
        replicas: args.get_usize("replicas", 4),
        utilization: args.get_f64("load", 0.8),
        seed: args.get_u64("seed", 42),
    };
    report::event_latency_table(&nets, &load).print();
    Ok(())
}

fn dse_cmd(args: &Args) -> Result<()> {
    let top = args.get_usize("top", 12);
    report::fig11_table(top).print();
    let best = neural_pim::dse::best();
    println!(
        "best: {} at {:.1} GOPS/s/mm² (paper: N128-D4-A4-S64 M64 at 1904.0)",
        best.label, best.compute_efficiency
    );
    Ok(())
}

fn budget(args: &Args) -> Result<()> {
    let arch = Architecture::parse(args.get_or("arch", "neural-pim"))?;
    let cfg = AcceleratorConfig::for_arch(arch);
    let tile = neural_pim::energy::tile_budget(&cfg);
    let chip = neural_pim::energy::chip_budget(&cfg);
    let mut t = Table::new(
        &format!("{} budget", arch.name()),
        &["level", "power (W)", "area (mm²)"],
    );
    t.row(&["PE".into(), format!("{:.3}", tile.pe.power()),
            format!("{:.4}", tile.pe.area())]);
    t.row(&["tile".into(), format!("{:.3}", tile.power()),
            format!("{:.4}", tile.area())]);
    t.row(&[format!("chip ({} tiles)", cfg.tiles),
            format!("{:.1}", chip.power()), format!("{:.1}", chip.area())]);
    t.print();
    Ok(())
}

fn accuracy(args: &Args) -> Result<()> {
    let rt = Runtime::new(args.get_or("artifacts",
                                      &neural_pim::artifact_dir()))?;
    let ts = runtime::TestSet::load(rt.dir())?;
    let strategy = args.get_or("strategy", "C").to_string();
    let seed = args.get_u64("seed", 42);
    let batch = 128usize;
    let n_batches = (ts.n / batch).max(1);

    let (artifact, extra): (String, Vec<xla::Literal>) = match strategy.as_str() {
        "ideal" => ("cnn_ideal".into(), vec![]),
        "noisy" => {
            let sinad = args.get_f64("sinad", 50.0);
            ("cnn_noisy".into(),
             vec![runtime::lit_key(seed)?, runtime::lit_scalar_f32(sinad as f32)])
        }
        s @ ("A" | "B" | "C") => {
            let bits = args.get_usize("adc-bits", 8);
            let levels = (1u64 << bits) as f32 - 1.0;
            let mut extra = vec![runtime::lit_scalar_f32(levels)];
            if s != "A" {
                // strategy A is deterministic; its HLO has no key param
                extra.push(runtime::lit_key(seed)?);
            }
            (format!("cnn_strat{s}"), extra)
        }
        other => bail!("unknown strategy {other}"),
    };
    let exe = rt.load(&artifact)?;
    println!("loaded {artifact} (compiled in {:.1}s) on {}",
             exe.compile_seconds, rt.platform());
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..n_batches {
        let images = ts.batch_literal(b * batch, batch)?;
        let mut inputs = vec![images];
        for e in &extra {
            inputs.push(clone_lit(e));
        }
        let out = exe.run(&inputs)?;
        let logits = runtime::to_f32_vec(&out[0])?;
        let labels = ts.batch_labels(b * batch, batch);
        correct += (runtime::accuracy(&logits, &labels, 10) * batch as f64)
            .round() as usize;
        total += batch;
    }
    println!("strategy={strategy} accuracy={:.4} ({} images)",
             correct as f64 / total as f64, total);
    Ok(())
}

fn clone_lit(l: &xla::Literal) -> xla::Literal {
    match l.ty().unwrap() {
        xla::ElementType::U32 => {
            let v = l.to_vec::<u32>().unwrap();
            xla::Literal::vec1(&v).reshape(&[v.len() as i64]).unwrap()
        }
        _ => {
            let v = l.to_vec::<f32>().unwrap();
            if l.element_count() == 1
                && l.array_shape().map(|s| s.dims().is_empty()).unwrap_or(false)
            {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&[v.len() as i64]).unwrap()
            }
        }
    }
}

fn mc(args: &Args) -> Result<()> {
    let rt = Runtime::new(args.get_or("artifacts", &neural_pim::artifact_dir()))?;
    let naive = args.flag("naive");
    let trials = args.get_usize("trials", 4);
    let artifact = if naive { "mc_naive" } else { "mc_opt" };
    let exe = rt.load(artifact)?;
    let mut all_hw = Vec::new();
    let mut all_sw = Vec::new();
    for t in 0..trials {
        let key = runtime::lit_key(args.get_u64("seed", 42) + t as u64)?;
        let out = exe.run(&[key])?;
        let hw = runtime::to_f32_vec(&out[0])?;
        let sw = runtime::to_f32_vec(&out[1])?;
        all_hw.extend(hw.iter().map(|&v| v as f64));
        all_sw.extend(sw.iter().map(|&v| v as f64));
    }
    let r = noise::mc_result(&all_hw, &all_sw);
    println!(
        "Fig 9{}: {} trials x {} dot products -> SINAD {:.1} dB \
         (err rms {:.0}, bias {:.0}, range [{:.0}, {:.0}])",
        if naive { "b (no optimizations)" } else { "a (optimized)" },
        trials, r.n / trials, r.sinad_db, r.err_rms, r.err_mean,
        r.err_min, r.err_max
    );
    Ok(())
}

fn periph_cmd(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", &neural_pim::artifact_dir()).to_string();
    let p = periph::Periph::load(&format!("{dir}/periph.json"))?;
    let (mse, emax, emin) = p.nns_a_error_stats(8192, args.get_u64("seed", 42));
    let tr = p.nnadc.transfer(1 << 13);
    let (dnl, inl, missing) = periph::dnl_inl(&tr, 8);
    let (enob, sinad) = periph::enob(&p.nnadc, 1 << 13);
    let mut t = Table::new(
        "Table 1: trained NeuralPeriph circuits (measured natively in Rust)",
        &["metric", "NNS+A", "8-bit NNADC", "paper"],
    );
    t.row(&["approx. MSE (V²)".into(), format!("{mse:.2e}"), "-".into(),
            "<1e-5".into()]);
    t.row(&["max error (mV)".into(), format!("{:.1}", emax * 1e3), "-".into(),
            "4-5".into()]);
    t.row(&["min error (mV)".into(), format!("{:.1}", emin * 1e3), "-".into(),
            "-3..-4".into()]);
    t.row(&["DNL (LSB)".into(), "-".into(),
            format!("{:.2}/{:.2}", stats::min(&dnl), stats::max(&dnl)),
            "-0.25/0.55".into()]);
    t.row(&["INL (LSB)".into(), "-".into(),
            format!("{:.2}/{:.2}", stats::min(&inl), stats::max(&inl)),
            "-0.56/0.62".into()]);
    t.row(&["missing codes".into(), "-".into(), missing.to_string(),
            "0".into()]);
    t.row(&["ENOB (bits)".into(), "-".into(), format!("{enob:.2}"),
            "7.88".into()]);
    t.row(&["sine SINAD (dB)".into(), "-".into(), format!("{sinad:.1}"),
            "~49".into()]);
    t.print();
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", &neural_pim::artifact_dir()).to_string();
    let ts = runtime::TestSet::load(std::path::Path::new(&dir))?;
    let n_req = args.get_usize("requests", 512);
    let (h, w, c) = ts.dims;
    let cfg = CoordinatorConfig {
        artifact_dir: dir.clone(),
        artifact: args.get_or("artifact", "cnn_ideal").to_string(),
        batch: 128,
        classes: 10,
        max_wait: std::time::Duration::from_millis(
            args.get_usize("max-wait-ms", 2) as u64),
        workers: args.get_usize("workers", 1),
        extra_inputs: vec![],
        image_param_first: true,
    };
    let coord = Coordinator::start(cfg, h * w * c)?;
    println!("coordinator up — driving {n_req} requests");

    let t0 = std::time::Instant::now();
    let stride = h * w * c;
    let mut pending = Vec::new();
    for i in 0..n_req {
        let idx = i % ts.n;
        let img = ts.images[idx * stride..(idx + 1) * stride].to_vec();
        pending.push((coord.submit(img)?, ts.labels[idx]));
    }
    let mut correct = 0usize;
    let mut lat_ms = Vec::new();
    for (rx, label) in pending {
        let resp = rx.recv()?;
        if let Some(err) = &resp.error {
            bail!("request {} failed in its batch: {err}", resp.id);
        }
        lat_ms.push((resp.queue_us + resp.exec_us) as f64 / 1000.0);
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j as i32)
            .unwrap();
        if pred == label {
            correct += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {n_req} requests in {:.2}s ({:.0} req/s), accuracy {:.4}",
        dt, n_req as f64 / dt, correct as f64 / n_req as f64
    );
    println!(
        "latency p50 {:.1} ms, p99 {:.1} ms | {}",
        stats::percentile(&lat_ms, 50.0),
        stats::percentile(&lat_ms, 99.0),
        coord.metrics.summary()
    );
    coord.shutdown();
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let rt = Runtime::new(args.get_or("artifacts", &neural_pim::artifact_dir()))?;
    let ts = runtime::TestSet::load(rt.dir())?;
    let exe = rt.load("cnn_ideal")?;
    let images = ts.batch_literal(0, 128)?;
    let out = exe.run(&[images])?;
    let logits = runtime::to_f32_vec(&out[0])?;
    let acc = runtime::accuracy(&logits, &ts.batch_labels(0, 128), 10);
    println!("cnn_ideal first-batch accuracy: {acc:.4}");
    Ok(())
}
