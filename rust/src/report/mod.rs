//! Regenerates each paper table/figure as text (the bench targets call
//! these; `neural-pim <table|figure>` prints them directly).

use crate::baselines;
use crate::config::{AcceleratorConfig, Architecture, Precision};
use crate::dataflow::{self, Strategy};
use crate::dse;
use crate::energy;
use crate::event;
use crate::model;
use crate::scenario::Metric;
use crate::sim;
use crate::util::stats;
use crate::util::table::{eng, Cell, Table};
use crate::workloads;

/// `Table::new` over owned header strings (the registry-driven tables
/// build their column sets at runtime).
fn table_with_headers(title: &str, headers: &[String]) -> Table {
    let refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    Table::new(title, &refs)
}

/// §3.1 / Fig. 3(d): per-strategy step counts for the running example.
pub fn characterization_table() -> Table {
    let mut t = Table::new(
        "dataflow characterization (Eqs. 2-8), N=7, PR=1, PI=PW=PO=8",
        &["strategy", "P_D", "A/D bits", "conversions/group", "latency (cycles)",
          "feasible"],
    );
    for pd in [1u32, 2, 4] {
        let p = Precision { p_d: pd, ..Default::default() };
        for s in Strategy::all() {
            let (bits, convs, feasible) = match s {
                Strategy::A => (dataflow::adc_resolution_a(&p, 7),
                                dataflow::conversions_a(&p), true),
                Strategy::B => (dataflow::adc_resolution_b(&p, 7),
                                dataflow::conversions_b(&p),
                                dataflow::strategy_b_feasible(&p, 7)),
                Strategy::C => (dataflow::adc_resolution_c(&p),
                                dataflow::conversions_c(), true),
            };
            t.row(&[
                s.name().into(),
                pd.to_string(),
                bits.to_string(),
                convs.to_string(),
                dataflow::latency_cycles(&p).to_string(),
                if feasible { "yes".into() } else { "no (buffer cell)".into() },
            ]);
        }
    }
    t
}

/// Fig. 4(b): normalized energy efficiency vs DAC resolution.
pub fn fig4b_table() -> Table {
    let mut t = Table::new(
        "Fig 4b: VMM energy normalized to Strategy A @ 1-bit DAC (lower = better)",
        &["P_D", "Strategy A", "Strategy B", "Strategy C"],
    );
    for (pd, ea, ec, eb) in dataflow::fig4b_normalized_energy(&[1, 2, 4], 7) {
        t.row(&[
            pd.to_string(),
            format!("{ea:.3}"),
            eb.map(|v| format!("{v:.3}")).unwrap_or_else(|| "infeasible".into()),
            format!("{ec:.3}"),
        ]);
    }
    t
}

/// Fig. 4(c): array-level energy breakdown per strategy.
pub fn fig4c_table() -> Table {
    let mut t = Table::new(
        "Fig 4c: array-level energy breakdown (per dot-product group, J)",
        &["strategy", "ADC", "DAC", "S+A", "crossbar", "other", "total"],
    );
    for s in Strategy::all() {
        let p = Precision {
            p_d: if s == Strategy::C { 4 } else { 1 },
            ..Default::default()
        };
        let e = dataflow::group_energy(s, &p, 7);
        t.row(&[
            s.name().into(),
            eng(e.adc),
            eng(e.dac),
            eng(e.sa),
            eng(e.xbar),
            eng(e.other),
            eng(e.total()),
        ]);
    }
    t
}

/// Table 2: Neural-PIM tile-level parameters.
pub fn table2() -> Table {
    let cfg = AcceleratorConfig::neural_pim();
    let tile = energy::tile_budget(&cfg);
    let mut t = Table::new(
        "Table 2: Neural-PIM parameters at the tile level (4 PEs/tile)",
        &["component", "count/PE", "power (W)", "area (mm²)"],
    );
    for c in &tile.pe.components {
        t.row(&[
            c.name.into(),
            c.count.to_string(),
            format!("{:.2e}", c.power()),
            format!("{:.2e}", c.area()),
        ]);
    }
    t.row(&["1 PE".into(), "-".into(), format!("{:.2e}", tile.pe.power()),
            format!("{:.2e}", tile.pe.area())]);
    for c in &tile.extra {
        t.row(&[
            c.name.into(),
            "per tile".into(),
            format!("{:.2e}", c.power()),
            format!("{:.2e}", c.area()),
        ]);
    }
    let chip = energy::chip_budget(&cfg);
    t.row(&[format!("{} tiles", cfg.tiles), "-".into(),
            format!("{:.1}", chip.tile.power() * cfg.tiles as f64),
            format!("{:.1}", chip.tile.area() * cfg.tiles as f64)]);
    t.row(&["HyperTransport".into(), "-".into(),
            format!("{:.1}", energy::constants::HT_POWER),
            format!("{:.2}", energy::constants::HT_AREA)]);
    t.row(&["total".into(), "-".into(), format!("{:.1}", chip.power()),
            format!("{:.1}", chip.area())]);
    t
}

/// Table 3: PE-level architecture comparison, one column per registered
/// architecture (newly registered cost models appear automatically).
pub fn table3() -> Table {
    let rows = baselines::pe_comparison();
    let mut headers: Vec<String> = vec!["metric".into()];
    headers.extend(rows.iter().map(|r| r.arch.name().to_string()));
    let mut t = table_with_headers(
        "Table 3: PE-level comparison (128x128 arrays, 1-bit cells)",
        &headers,
    );
    let get = |f: &dyn Fn(&baselines::PeComparison) -> String| -> Vec<String> {
        rows.iter().map(|r| f(r)).collect()
    };
    let push = |t: &mut Table, name: &str, vals: Vec<String>| {
        let mut cells: Vec<String> = vec![name.into()];
        cells.extend(vals);
        t.row(&cells);
    };
    push(&mut t, "accumulation", get(&|r| r.accumulation.into()));
    push(&mut t, "interface", get(&|r| r.interface.into()));
    push(&mut t, "D/A resolution", get(&|r| format!("{}-bit", r.dac_bits)));
    push(&mut t, "A/D resolution", get(&|r| format!("{}-bit", r.adc_bits)));
    push(&mut t, "ADCs / 64 arrays", get(&|r| r.adcs_per_64_arrays.to_string()));
    push(&mut t, "density (%)", get(&|r| format!("{:.2}", r.density_pct)));
    push(&mut t, "cells/mm²", get(&|r| format!("{:.2e}", r.cells_per_mm2)));
    push(&mut t, "PE power (W)", get(&|r| format!("{:.3}", r.pe_power_w)));
    push(&mut t, "PE area (mm²)", get(&|r| format!("{:.3}", r.pe_area_mm2)));
    t
}

/// Fig. 11: top design points of the DSE sweep. Numeric columns carry
/// typed cells, so the JSON rendering keeps the unrounded values.
pub fn fig11_table(top: usize) -> Table {
    fig11_table_from(&dse::sweep(), top)
}

/// [`fig11_table`] over an already-computed sweep (the `dse` scenario
/// shares one sweep between the table and the best-point metrics).
pub fn fig11_table_from(points: &[dse::DsePoint], top: usize) -> Table {
    let mut pts: Vec<&dse::DsePoint> = points.iter().collect();
    pts.sort_by(|a, b| b.compute_efficiency.partial_cmp(&a.compute_efficiency)
        .unwrap());
    let mut t = Table::new(
        "Fig 11: computation efficiency across the design space (top points)",
        &["config", "GOPS/s/mm²", "GOPS/s/W"],
    );
    for p in pts.iter().take(top) {
        t.cells(vec![
            Cell::s(p.label.clone()),
            Cell::num(p.compute_efficiency, format!("{:.1}", p.compute_efficiency)),
            Cell::num(p.energy_efficiency, format!("{:.1}", p.energy_efficiency)),
        ]);
    }
    let paper = dse::evaluate(&AcceleratorConfig::neural_pim()).unwrap();
    t.cells(vec![
        Cell::s(format!("{} (paper Table 2)", paper.label)),
        Cell::num(paper.compute_efficiency,
                  format!("{:.1}", paper.compute_efficiency)),
        Cell::num(paper.energy_efficiency,
                  format!("{:.1}", paper.energy_efficiency)),
    ]);
    t
}

/// PE/tile/chip power & area budget for one architecture (the CLI's
/// `budget` scenario).
pub fn budget_table(cfg: &AcceleratorConfig) -> Table {
    budget_table_from(cfg, &energy::tile_budget(cfg),
                      &energy::chip_budget(cfg))
}

/// [`budget_table`] over already-computed budgets (the `budget`
/// scenario derives its metric records from the very same numbers the
/// table prints).
pub fn budget_table_from(cfg: &AcceleratorConfig,
                         tile: &energy::TileBudget,
                         chip: &energy::ChipBudget) -> Table {
    let mut t = Table::new(
        &format!("{} budget", cfg.arch.name()),
        &["level", "power (W)", "area (mm²)"],
    );
    t.cells(vec![
        Cell::s("PE"),
        Cell::num(tile.pe.power(), format!("{:.3}", tile.pe.power())),
        Cell::num(tile.pe.area(), format!("{:.4}", tile.pe.area())),
    ]);
    t.cells(vec![
        Cell::s("tile"),
        Cell::num(tile.power(), format!("{:.3}", tile.power())),
        Cell::num(tile.area(), format!("{:.4}", tile.area())),
    ]);
    t.cells(vec![
        Cell::s(format!("chip ({} tiles)", cfg.tiles)),
        Cell::num(chip.power(), format!("{:.1}", chip.power())),
        Cell::num(chip.area(), format!("{:.1}", chip.area())),
    ]);
    t
}

/// Event-vs-analytical cross-validation (the `event-sim` view): per
/// iso-area scenario, total-energy agreement and the contention-induced
/// latency delta the analytical model hides.
pub fn event_cross_validation_table(nets: &[workloads::Network]) -> Table {
    event_cross_validation_table_from(&event::cross_validate(nets))
}

/// [`event_cross_validation_table`] over already-computed rows (the
/// event-sim scenario shares one `cross_validate` run between its table
/// and its metric records).
pub fn event_cross_validation_table_from(rows: &[event::CrossValidation])
                                         -> Table {
    let mut t = Table::new(
        &format!(
            "event-driven cross-validation (energy tolerance {:.0}%, \
             {} scenarios)",
            event::ENERGY_TOLERANCE * 100.0,
            rows.len()
        ),
        &["network", "arch", "E/inf analytical", "E/inf event", "rel err",
          "latency analytical", "latency event", "contention Δ", "events"],
    );
    for r in rows {
        t.row(&[
            r.network.to_string(),
            r.arch.name().into(),
            eng(r.analytical_energy_j),
            eng(r.event_energy_j),
            format!("{:.2}%", 100.0 * r.energy_rel_err),
            format!("{:.1} µs", r.analytical_latency_s * 1e6),
            format!("{:.1} µs", r.event_latency_s * 1e6),
            format!("{:.2} µs", r.contention_delta_s * 1e6),
            r.events.to_string(),
        ]);
    }
    t
}

/// Event-mode tail latency under Poisson load, iso-area across the
/// three architectures (the request-level percentiles the serving-layer
/// SLO story needs; deterministic at any `--threads`).
pub fn event_latency_table(nets: &[workloads::Network],
                           load: &event::RequestLoad) -> Table {
    event_latency_table_from(&event_latency_profiles(nets, load), load)
}

/// The per-(network, arch) latency profiles behind
/// [`event_latency_table`]: one scenario per (network, registered
/// arch), fanned out over the pool (replicas run sequentially inside
/// each item — scenario-level parallelism already saturates the cores
/// without nested spawns).
pub fn event_latency_profiles(nets: &[workloads::Network],
                              load: &event::RequestLoad)
                              -> Vec<event::LatencyProfile> {
    let np = AcceleratorConfig::neural_pim();
    let reference_area = energy::chip_budget(&np).area();
    let scenarios: Vec<(&workloads::Network, Architecture)> = nets
        .iter()
        .flat_map(|net| model::archs().into_iter().map(move |a| (net, a)))
        .collect();
    crate::util::pool::map(&scenarios, |&(net, arch)| {
        let cfg = sim::iso_area_config(arch, reference_area);
        event::request_profile_sequential(net, &cfg, load)
    })
}

/// [`event_latency_profiles`] with a live trace: each (network, arch)
/// scenario records into its own `TraceRecorder` (replicas sequential
/// inside the item, so per-scenario traces are self-consistent), and
/// the scenario traces are absorbed in scenario order under
/// `{network}/{arch}/` prefixes. Profile numbers are bit-identical to
/// the untraced fan-out — the determinism tests pin this.
pub fn event_latency_profiles_traced(
    nets: &[workloads::Network], load: &event::RequestLoad,
    filter: Option<&str>)
    -> (Vec<event::LatencyProfile>, crate::obs::TraceRecorder) {
    let np = AcceleratorConfig::neural_pim();
    let reference_area = energy::chip_budget(&np).area();
    let scenarios: Vec<(&workloads::Network, Architecture)> = nets
        .iter()
        .flat_map(|net| model::archs().into_iter().map(move |a| (net, a)))
        .collect();
    let traced = crate::util::pool::map(&scenarios, |&(net, arch)| {
        let cfg = sim::iso_area_config(arch, reference_area);
        event::request_profile_traced_sequential(net, &cfg, load, filter)
    });
    let mut combined = crate::obs::TraceRecorder::new();
    let mut profiles = Vec::with_capacity(traced.len());
    for ((net, arch), (profile, rec)) in
        scenarios.iter().zip(traced.into_iter())
    {
        combined.absorb(&format!("{}/{}/", net.name, arch.name()), rec);
        profiles.push(profile);
    }
    (profiles, combined)
}

/// [`event_latency_table`] over already-computed profiles.
pub fn event_latency_table_from(profiles: &[event::LatencyProfile],
                                load: &event::RequestLoad) -> Table {
    let mut t = Table::new(
        &format!(
            "event-mode per-inference latency (Poisson load {:.0}% of \
             bottleneck rate, {} req x {} replicas, seed {})",
            load.utilization_clamped() * 100.0, load.requests, load.replicas,
            load.seed
        ),
        &["network", "arch", "p50", "p95", "p99", "mean", "NoC wait",
          "blocked starts"],
    );
    for p in profiles {
        let us = |s: f64| format!("{:.1} µs", s * 1e6);
        t.row(&[
            p.network.to_string(),
            p.arch.name().into(),
            us(p.p50_s),
            us(p.p95_s),
            us(p.p99_s),
            us(p.mean_s),
            us(p.noc_wait_s),
            p.blocked_starts.to_string(),
        ]);
    }
    t
}

/// Fig. 12 + headline ratios: full system comparison, plus the
/// event-mode latency percentiles sampled by the `event` subsystem.
pub struct SystemReport {
    pub table_energy: Table,
    pub table_throughput: Table,
    pub table_breakdown: Table,
    /// p50/p95/p99 per scenario from `event::request_profile`
    pub table_latency: Table,
    pub headline: String,
    /// the structured form of the headline (and more): geomean ratios
    /// vs every non-reference architecture, per-network energy and
    /// throughput — what the `simulate` scenario exports as records
    pub metrics: Vec<Metric>,
}

pub fn system_report(nets: &[workloads::Network]) -> SystemReport {
    let cmp = sim::run_system_comparison(nets);
    // columns come from the registry: one per architecture, plus one
    // ratio column per non-flagship architecture
    let archs = model::archs();
    let reference = model::reference();
    let others: Vec<Architecture> =
        archs.iter().copied().filter(|&a| a != reference).collect();
    let mut headers: Vec<String> = vec!["network".into()];
    headers.extend(archs.iter().map(|a| a.name().to_string()));
    headers.extend(others.iter().map(|a| format!("vs {}", a.name())));
    let mut te = table_with_headers(
        "Fig 12a: energy per inference (J), iso-area",
        &headers,
    );
    let mut tt = table_with_headers(
        "Fig 12b: throughput (GOPS), iso-area",
        &headers,
    );
    for net in nets {
        let find = |arch| {
            cmp.results
                .iter()
                .find(|r| r.network == net.name && r.arch == arch)
                .unwrap()
        };
        let flagship = find(reference);
        let mut erow: Vec<String> = vec![net.name.to_string()];
        let mut trow: Vec<String> = vec![net.name.to_string()];
        for &arch in &archs {
            let r = find(arch);
            erow.push(eng(r.energy_per_inference));
            trow.push(format!("{:.0}", r.throughput_gops));
        }
        for &arch in &others {
            let r = find(arch);
            erow.push(format!(
                "{:.2}x",
                r.energy_per_inference / flagship.energy_per_inference
            ));
            trow.push(format!(
                "{:.2}x",
                flagship.throughput_gops / r.throughput_gops
            ));
        }
        te.row(&erow);
        tt.row(&trow);
    }

    let mut tb = Table::new(
        "Fig 13: system energy breakdown (geomean shares across benchmarks)",
        &["arch", "ADC", "DAC", "S+A", "crossbar", "memory", "NoC+IO",
          "digital"],
    );
    for &arch in &archs {
        let mut shares = vec![Vec::new(); 7];
        for r in cmp.results.iter().filter(|r| r.arch == arch) {
            let tot = r.breakdown.total();
            for (i, (_, v)) in r.breakdown.categories().iter().enumerate() {
                shares[i].push(v / tot);
            }
        }
        let mut row = vec![arch.name().to_string()];
        for s in &shares {
            row.push(format!("{:.1}%", 100.0 * stats::mean(s)));
        }
        tb.row(&row);
    }

    let headline = format!(
        "geomean improvements of Neural-PIM: energy {:.2}x vs ISAAC-style \
         (paper: 5.36x), {:.2}x vs CASCADE-style (paper: 1.73x); throughput \
         {:.2}x vs ISAAC-style (paper: 3.43x), {:.2}x vs CASCADE-style \
         (paper: 1.59x)",
        cmp.energy_ratio(Architecture::IsaacLike),
        cmp.energy_ratio(Architecture::CascadeLike),
        cmp.throughput_ratio(Architecture::IsaacLike),
        cmp.throughput_ratio(Architecture::CascadeLike),
    );
    // structured counterpart of the tables: geomean ratios vs every
    // non-reference architecture plus per-(network, arch) energy and
    // throughput records (registry-generic — a newly registered
    // architecture grows metrics here with no edits)
    let mut metrics = vec![Metric::new(
        "reference_area_mm2",
        cmp.reference_area,
        "mm²",
    )];
    for &arch in &others {
        metrics.push(Metric::new(
            format!("energy_geomean_vs_{}", arch.name()),
            cmp.energy_ratio(arch),
            "x",
        ));
        metrics.push(Metric::new(
            format!("throughput_geomean_vs_{}", arch.name()),
            cmp.throughput_ratio(arch),
            "x",
        ));
    }
    for r in &cmp.results {
        metrics.push(Metric::new(
            format!("energy_per_inference/{}/{}", r.network, r.arch.name()),
            r.energy_per_inference,
            "J",
        ));
        metrics.push(Metric::new(
            format!("throughput_gops/{}/{}", r.network, r.arch.name()),
            r.throughput_gops,
            "GOPS",
        ));
    }

    // request-level event simulation: a modest fixed load keeps the
    // report fast while still exercising queueing (the `event-sim` CLI
    // exposes the knobs)
    let load = event::RequestLoad {
        requests: 96,
        replicas: 3,
        utilization: 0.8,
        seed: 42,
        shards: 1,
    };
    SystemReport {
        table_energy: te,
        table_throughput: tt,
        table_breakdown: tb,
        table_latency: event_latency_table(nets, &load),
        headline,
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        assert!(characterization_table().render().lines().count() > 9);
        assert!(fig4b_table().render().contains("infeasible"));
        assert!(fig4c_table().render().contains("Crossbar".to_lowercase().as_str())
                || fig4c_table().render().contains("crossbar"));
        assert!(table2().render().contains("total"));
        assert!(table3().render().contains("NNS+A"));
    }

    #[test]
    fn system_report_smoke() {
        let nets = vec![workloads::alexnet()];
        let r = system_report(&nets);
        assert!(r.headline.contains("geomean"));
        assert!(r.table_energy.render().contains("AlexNet"));
        // the event-mode latency table covers every scenario
        let lat = r.table_latency.render();
        assert!(lat.contains("AlexNet"));
        assert!(lat.contains("Neural-PIM"));
        assert!(lat.contains("p99"));
    }

    #[test]
    fn system_report_exports_structured_metrics() {
        let nets = vec![workloads::alexnet()];
        let r = system_report(&nets);
        // registry-generic: one pair of geomean metrics per
        // non-reference architecture, plus per-(network, arch) records
        let n_others = model::archs().len() - 1;
        let geo = r
            .metrics
            .iter()
            .filter(|m| m.name.starts_with("energy_geomean_vs_"))
            .count();
        assert_eq!(geo, n_others);
        assert!(r.metrics.iter().any(|m| m.name == "reference_area_mm2"));
        let e_np = r
            .metrics
            .iter()
            .find(|m| m.name == "energy_per_inference/AlexNet/Neural-PIM")
            .expect("per-scenario record");
        assert!(e_np.value > 0.0 && e_np.value.is_finite());
        assert_eq!(e_np.unit, "J");
    }

    #[test]
    fn event_cross_validation_table_renders() {
        let nets = vec![workloads::alexnet()];
        let t = event_cross_validation_table(&nets);
        let s = t.render();
        assert!(s.contains("cross-validation"));
        assert!(s.contains("ISAAC-like") && s.contains("Neural-PIM"));
    }
}
