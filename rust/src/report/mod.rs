//! Regenerates each paper table/figure as text (the bench targets call
//! these; `neural-pim <table|figure>` prints them directly).

use crate::baselines;
use crate::config::{AcceleratorConfig, Architecture, Precision};
use crate::dataflow::{self, Strategy};
use crate::dse;
use crate::energy;
use crate::sim;
use crate::util::stats;
use crate::util::table::{eng, Table};
use crate::workloads;

/// §3.1 / Fig. 3(d): per-strategy step counts for the running example.
pub fn characterization_table() -> Table {
    let mut t = Table::new(
        "dataflow characterization (Eqs. 2-8), N=7, PR=1, PI=PW=PO=8",
        &["strategy", "P_D", "A/D bits", "conversions/group", "latency (cycles)",
          "feasible"],
    );
    for pd in [1u32, 2, 4] {
        let p = Precision { p_d: pd, ..Default::default() };
        for s in Strategy::all() {
            let (bits, convs, feasible) = match s {
                Strategy::A => (dataflow::adc_resolution_a(&p, 7),
                                dataflow::conversions_a(&p), true),
                Strategy::B => (dataflow::adc_resolution_b(&p, 7),
                                dataflow::conversions_b(&p),
                                dataflow::strategy_b_feasible(&p, 7)),
                Strategy::C => (dataflow::adc_resolution_c(&p),
                                dataflow::conversions_c(), true),
            };
            t.row(&[
                s.name().into(),
                pd.to_string(),
                bits.to_string(),
                convs.to_string(),
                dataflow::latency_cycles(&p).to_string(),
                if feasible { "yes".into() } else { "no (buffer cell)".into() },
            ]);
        }
    }
    t
}

/// Fig. 4(b): normalized energy efficiency vs DAC resolution.
pub fn fig4b_table() -> Table {
    let mut t = Table::new(
        "Fig 4b: VMM energy normalized to Strategy A @ 1-bit DAC (lower = better)",
        &["P_D", "Strategy A", "Strategy B", "Strategy C"],
    );
    for (pd, ea, ec, eb) in dataflow::fig4b_normalized_energy(&[1, 2, 4], 7) {
        t.row(&[
            pd.to_string(),
            format!("{ea:.3}"),
            eb.map(|v| format!("{v:.3}")).unwrap_or_else(|| "infeasible".into()),
            format!("{ec:.3}"),
        ]);
    }
    t
}

/// Fig. 4(c): array-level energy breakdown per strategy.
pub fn fig4c_table() -> Table {
    let mut t = Table::new(
        "Fig 4c: array-level energy breakdown (per dot-product group, J)",
        &["strategy", "ADC", "DAC", "S+A", "crossbar", "other", "total"],
    );
    for s in Strategy::all() {
        let p = Precision {
            p_d: if s == Strategy::C { 4 } else { 1 },
            ..Default::default()
        };
        let e = dataflow::group_energy(s, &p, 7);
        t.row(&[
            s.name().into(),
            eng(e.adc),
            eng(e.dac),
            eng(e.sa),
            eng(e.xbar),
            eng(e.other),
            eng(e.total()),
        ]);
    }
    t
}

/// Table 2: Neural-PIM tile-level parameters.
pub fn table2() -> Table {
    let cfg = AcceleratorConfig::neural_pim();
    let tile = energy::tile_budget(&cfg);
    let mut t = Table::new(
        "Table 2: Neural-PIM parameters at the tile level (4 PEs/tile)",
        &["component", "count/PE", "power (W)", "area (mm²)"],
    );
    for c in &tile.pe.components {
        t.row(&[
            c.name.into(),
            c.count.to_string(),
            format!("{:.2e}", c.power()),
            format!("{:.2e}", c.area()),
        ]);
    }
    t.row(&["1 PE".into(), "-".into(), format!("{:.2e}", tile.pe.power()),
            format!("{:.2e}", tile.pe.area())]);
    for c in &tile.extra {
        t.row(&[
            c.name.into(),
            "per tile".into(),
            format!("{:.2e}", c.power()),
            format!("{:.2e}", c.area()),
        ]);
    }
    let chip = energy::chip_budget(&cfg);
    t.row(&[format!("{} tiles", cfg.tiles), "-".into(),
            format!("{:.1}", chip.tile.power() * cfg.tiles as f64),
            format!("{:.1}", chip.tile.area() * cfg.tiles as f64)]);
    t.row(&["HyperTransport".into(), "-".into(),
            format!("{:.1}", energy::constants::HT_POWER),
            format!("{:.2}", energy::constants::HT_AREA)]);
    t.row(&["total".into(), "-".into(), format!("{:.1}", chip.power()),
            format!("{:.1}", chip.area())]);
    t
}

/// Table 3: PE-level architecture comparison.
pub fn table3() -> Table {
    let mut t = Table::new(
        "Table 3: PE-level comparison (128x128 arrays, 1-bit cells)",
        &["metric", "ISAAC-style", "CASCADE-style", "Neural-PIM"],
    );
    let rows = baselines::pe_comparison();
    let get = |f: &dyn Fn(&baselines::PeComparison) -> String| -> Vec<String> {
        rows.iter().map(|r| f(r)).collect()
    };
    let push = |t: &mut Table, name: &str, vals: Vec<String>| {
        t.row(&[name.into(), vals[0].clone(), vals[1].clone(), vals[2].clone()]);
    };
    push(&mut t, "accumulation", get(&|r| r.accumulation.into()));
    push(&mut t, "interface", get(&|r| r.interface.into()));
    push(&mut t, "D/A resolution", get(&|r| format!("{}-bit", r.dac_bits)));
    push(&mut t, "A/D resolution", get(&|r| format!("{}-bit", r.adc_bits)));
    push(&mut t, "ADCs / 64 arrays", get(&|r| r.adcs_per_64_arrays.to_string()));
    push(&mut t, "density (%)", get(&|r| format!("{:.2}", r.density_pct)));
    push(&mut t, "cells/mm²", get(&|r| format!("{:.2e}", r.cells_per_mm2)));
    push(&mut t, "PE power (W)", get(&|r| format!("{:.3}", r.pe_power_w)));
    push(&mut t, "PE area (mm²)", get(&|r| format!("{:.3}", r.pe_area_mm2)));
    t
}

/// Fig. 11: top design points of the DSE sweep.
pub fn fig11_table(top: usize) -> Table {
    let mut pts = dse::sweep();
    pts.sort_by(|a, b| b.compute_efficiency.partial_cmp(&a.compute_efficiency)
        .unwrap());
    let mut t = Table::new(
        "Fig 11: computation efficiency across the design space (top points)",
        &["config", "GOPS/s/mm²", "GOPS/s/W"],
    );
    for p in pts.iter().take(top) {
        t.row(&[
            p.label.clone(),
            format!("{:.1}", p.compute_efficiency),
            format!("{:.1}", p.energy_efficiency),
        ]);
    }
    let paper = dse::evaluate(&AcceleratorConfig::neural_pim()).unwrap();
    t.row(&[
        format!("{} (paper Table 2)", paper.label),
        format!("{:.1}", paper.compute_efficiency),
        format!("{:.1}", paper.energy_efficiency),
    ]);
    t
}

/// Fig. 12 + headline ratios: full system comparison.
pub struct SystemReport {
    pub table_energy: Table,
    pub table_throughput: Table,
    pub table_breakdown: Table,
    pub headline: String,
}

pub fn system_report(nets: &[workloads::Network]) -> SystemReport {
    let cmp = sim::run_system_comparison(nets);
    let mut te = Table::new(
        "Fig 12a: energy per inference (J), iso-area",
        &["network", "ISAAC-style", "CASCADE-style", "Neural-PIM",
          "vs ISAAC", "vs CASCADE"],
    );
    let mut tt = Table::new(
        "Fig 12b: throughput (GOPS), iso-area",
        &["network", "ISAAC-style", "CASCADE-style", "Neural-PIM",
          "vs ISAAC", "vs CASCADE"],
    );
    for net in nets {
        let find = |arch| {
            cmp.results
                .iter()
                .find(|r| r.network == net.name && r.arch == arch)
                .unwrap()
        };
        let i = find(Architecture::IsaacLike);
        let c = find(Architecture::CascadeLike);
        let n = find(Architecture::NeuralPim);
        te.row(&[
            net.name.into(),
            eng(i.energy_per_inference),
            eng(c.energy_per_inference),
            eng(n.energy_per_inference),
            format!("{:.2}x", i.energy_per_inference / n.energy_per_inference),
            format!("{:.2}x", c.energy_per_inference / n.energy_per_inference),
        ]);
        tt.row(&[
            net.name.into(),
            format!("{:.0}", i.throughput_gops),
            format!("{:.0}", c.throughput_gops),
            format!("{:.0}", n.throughput_gops),
            format!("{:.2}x", n.throughput_gops / i.throughput_gops),
            format!("{:.2}x", n.throughput_gops / c.throughput_gops),
        ]);
    }

    let mut tb = Table::new(
        "Fig 13: system energy breakdown (geomean shares across benchmarks)",
        &["arch", "ADC", "DAC", "S+A", "crossbar", "memory", "NoC+IO",
          "digital"],
    );
    for arch in Architecture::all() {
        let mut shares = vec![Vec::new(); 7];
        for r in cmp.results.iter().filter(|r| r.arch == arch) {
            let tot = r.breakdown.total();
            for (i, (_, v)) in r.breakdown.categories().iter().enumerate() {
                shares[i].push(v / tot);
            }
        }
        let mut row = vec![arch.name().to_string()];
        for s in &shares {
            row.push(format!("{:.1}%", 100.0 * stats::mean(s)));
        }
        tb.row(&row);
    }

    let headline = format!(
        "geomean improvements of Neural-PIM: energy {:.2}x vs ISAAC-style \
         (paper: 5.36x), {:.2}x vs CASCADE-style (paper: 1.73x); throughput \
         {:.2}x vs ISAAC-style (paper: 3.43x), {:.2}x vs CASCADE-style \
         (paper: 1.59x)",
        cmp.energy_ratio(Architecture::IsaacLike),
        cmp.energy_ratio(Architecture::CascadeLike),
        cmp.throughput_ratio(Architecture::IsaacLike),
        cmp.throughput_ratio(Architecture::CascadeLike),
    );
    SystemReport {
        table_energy: te,
        table_throughput: tt,
        table_breakdown: tb,
        headline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        assert!(characterization_table().render().lines().count() > 9);
        assert!(fig4b_table().render().contains("infeasible"));
        assert!(fig4c_table().render().contains("Crossbar".to_lowercase().as_str())
                || fig4c_table().render().contains("crossbar"));
        assert!(table2().render().contains("total"));
        assert!(table3().render().contains("NNS+A"));
    }

    #[test]
    fn system_report_smoke() {
        let nets = vec![workloads::alexnet()];
        let r = system_report(&nets);
        assert!(r.headline.contains("geomean"));
        assert!(r.table_energy.render().contains("AlexNet"));
    }
}
