//! [`TraceRecorder`]: captures virtual-time spans/instants/counter
//! samples and exports Chrome trace-event JSON that Perfetto
//! (<https://ui.perfetto.dev>) loads directly.
//!
//! Timestamps are virtual picoseconds; the Chrome format wants
//! microsecond `ts`/`dur`, so export divides by 1e6 (a sub-cycle event
//! at 1 GHz still lands at distinct fractional µs). Tracks become
//! threads of one synthetic process, named via `thread_name` metadata.
//! Shard/replica recorders are merged with [`TraceRecorder::absorb`] in
//! shard order, which prefixes track and counter-series names — so the
//! merged trace is byte-identical no matter how worker threads
//! interleaved.

use super::Recorder;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};

/// Track index meaning "no track" (counter samples — Chrome counters
/// attach to the process, not a thread).
const NO_TRACK: u32 = u32::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Span,
    Instant,
    Counter,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub ph: Phase,
    pub ts_ps: u64,
    /// spans only; 0 otherwise
    pub dur_ps: u64,
    /// index into [`TraceRecorder::tracks`], or `u32::MAX` for counters
    pub track: u32,
    /// event label (spans/instants) or counter series name
    pub name: String,
    /// counters only; 0.0 otherwise
    pub value: f64,
}

/// A [`Recorder`] that keeps everything in memory, in emission order
/// (deterministic: each recorder is driven by exactly one virtual-time
/// simulation).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceRecorder {
    tracks: Vec<String>,
    events: Vec<TraceEvent>,
    filter: Option<String>,
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }

    /// Recorder that only keeps events whose name starts with `prefix`
    /// (the `--trace-filter` behaviour).
    pub fn with_filter(prefix: Option<&str>) -> TraceRecorder {
        TraceRecorder { filter: prefix.map(str::to_string), ..Default::default() }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn tracks(&self) -> &[String] {
        &self.tracks
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    fn passes(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.starts_with(f.as_str()),
            None => true,
        }
    }

    fn track_id(&mut self, name: &str) -> u32 {
        // linear scan: track counts are small (stages, ports, shards)
        if let Some(i) = self.tracks.iter().position(|t| t == name) {
            return i as u32;
        }
        self.tracks.push(name.to_string());
        (self.tracks.len() - 1) as u32
    }

    /// Append another recorder's events, prefixing its track and
    /// counter-series names with `prefix` (e.g. `"AlexNet/ISAAC/r0s1/"`).
    /// Call in shard order for the canonical merged trace.
    pub fn absorb(&mut self, prefix: &str, other: TraceRecorder) {
        let map: Vec<u32> = other
            .tracks
            .iter()
            .map(|t| self.track_id(&format!("{prefix}{t}")))
            .collect();
        for mut e in other.events {
            if e.track == NO_TRACK {
                e.name = format!("{prefix}{}", e.name);
            } else {
                e.track = map[e.track as usize];
            }
            self.events.push(e);
        }
    }

    /// The full Chrome trace-event document:
    /// `{"displayTimeUnit": "ms", "traceEvents": [...]}`.
    pub fn to_chrome_json(&self) -> Json {
        let mut evs: Vec<Json> =
            Vec::with_capacity(self.tracks.len() + self.events.len());
        for (i, t) in self.tracks.iter().enumerate() {
            evs.push(json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(i as f64)),
                ("args", json::obj(vec![("name", Json::Str(t.clone()))])),
            ]));
        }
        for e in &self.events {
            let ts = Json::Num(e.ts_ps as f64 / 1e6);
            evs.push(match e.ph {
                Phase::Span => json::obj(vec![
                    ("name", Json::Str(e.name.clone())),
                    ("cat", Json::Str("sim".into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", ts),
                    ("dur", Json::Num(e.dur_ps as f64 / 1e6)),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(e.track as f64)),
                ]),
                Phase::Instant => json::obj(vec![
                    ("name", Json::Str(e.name.clone())),
                    ("cat", Json::Str("sim".into())),
                    ("ph", Json::Str("i".into())),
                    ("s", Json::Str("t".into())),
                    ("ts", ts),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(e.track as f64)),
                ]),
                Phase::Counter => json::obj(vec![
                    ("name", Json::Str(e.name.clone())),
                    ("ph", Json::Str("C".into())),
                    ("ts", ts),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(0.0)),
                    ("args", json::obj(vec![("value", Json::Num(e.value))])),
                ]),
            });
        }
        json::obj(vec![
            ("displayTimeUnit", Json::Str("ms".into())),
            ("traceEvents", Json::Arr(evs)),
        ])
    }

    /// Compact single-line JSON + trailing newline — what `--trace`
    /// writes and the byte-identity tests compare.
    pub fn to_chrome_string(&self) -> String {
        let mut s = self.to_chrome_json().to_string();
        s.push('\n');
        s
    }

    pub fn write_file(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_chrome_string())
            .with_context(|| format!("writing trace to {path}"))
    }
}

impl Recorder for TraceRecorder {
    #[inline]
    fn is_enabled(&self) -> bool {
        true
    }

    fn span(&mut self, ts_ps: u64, dur_ps: u64, track: &str, name: &str) {
        if !self.passes(name) {
            return;
        }
        let track = self.track_id(track);
        self.events.push(TraceEvent {
            ph: Phase::Span,
            ts_ps,
            dur_ps,
            track,
            name: name.to_string(),
            value: 0.0,
        });
    }

    fn instant(&mut self, ts_ps: u64, track: &str, name: &str) {
        if !self.passes(name) {
            return;
        }
        let track = self.track_id(track);
        self.events.push(TraceEvent {
            ph: Phase::Instant,
            ts_ps,
            dur_ps: 0,
            track,
            name: name.to_string(),
            value: 0.0,
        });
    }

    fn sample(&mut self, ts_ps: u64, series: &str, value: f64) {
        if !self.passes(series) {
            return;
        }
        self.events.push(TraceEvent {
            ph: Phase::Counter,
            ts_ps,
            dur_ps: 0,
            track: NO_TRACK,
            name: series.to_string(),
            value,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_all_three_phases_and_exports_chrome_json() {
        let mut r = TraceRecorder::new();
        assert!(r.is_enabled());
        r.span(1_000_000, 2_000_000, "stage0", "stage.serve");
        r.instant(3_000_000, "stage0", "stage.blocked");
        r.sample(4_000_000, "engine.queue_depth", 7.0);
        assert_eq!(r.len(), 3);
        assert_eq!(r.tracks(), ["stage0"]);

        let j = r.to_chrome_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 thread_name metadata + 3 events
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[1].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[1].get("ts").unwrap().as_f64(), Some(1.0)); // µs
        assert_eq!(evs[1].get("dur").unwrap().as_f64(), Some(2.0));
        assert_eq!(evs[3].get("ph").unwrap().as_str(), Some("C"));
        assert_eq!(
            evs[3].get("args").unwrap().get("value").unwrap().as_f64(),
            Some(7.0)
        );
    }

    #[test]
    fn filter_keeps_only_matching_names() {
        let mut r = TraceRecorder::with_filter(Some("noc."));
        r.span(0, 1, "t", "noc.xfer");
        r.instant(0, "t", "stage.blocked");
        r.sample(0, "noc.depth", 1.0);
        r.sample(0, "engine.queue_depth", 1.0);
        let names: Vec<&str> =
            r.events().iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, ["noc.xfer", "noc.depth"]);
    }

    #[test]
    fn absorb_prefixes_tracks_and_series_in_order() {
        let mut a = TraceRecorder::new();
        a.span(0, 1, "stage0", "stage.serve");
        a.sample(2, "depth", 1.0);
        let mut b = TraceRecorder::new();
        b.span(5, 1, "stage0", "stage.serve");

        let mut merged = TraceRecorder::new();
        merged.absorb("s0/", a.clone());
        merged.absorb("s1/", b.clone());
        assert_eq!(merged.tracks(), ["s0/stage0", "s1/stage0"]);
        assert_eq!(merged.events()[1].name, "s0/depth");
        // same inputs, same order -> byte-identical export
        let mut again = TraceRecorder::new();
        again.absorb("s0/", a);
        again.absorb("s1/", b);
        assert_eq!(merged.to_chrome_string(), again.to_chrome_string());
    }

    #[test]
    fn chrome_string_round_trips_through_json_parse() {
        let mut r = TraceRecorder::new();
        r.span(1, 2, "t", "a");
        r.sample(3, "s", 0.5);
        let s = r.to_chrome_string();
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.to_string() + "\n", s);
    }
}
