//! Leveled stderr diagnostics: the `crate::diag!` macro and its
//! verbosity state.
//!
//! Levels: **0** = warning (always printed), **1** = informational
//! (printed with `--verbose` or `NEURAL_PIM_LOG=1`), **2+** = debug.
//! The verbosity is read from `NEURAL_PIM_LOG` on first use and raised
//! by `scenario::dispatch` when `--verbose` is passed. Stderr is used
//! so stdout stays a clean, renderable outcome stream (tables or JSON);
//! `verify.sh` bans raw `eprintln!` everywhere else in `rust/src`
//! except `main.rs`.

use std::sync::atomic::{AtomicU8, Ordering};

/// Sentinel: verbosity not yet initialized from the environment.
const UNINIT: u8 = u8::MAX;

static VERBOSITY: AtomicU8 = AtomicU8::new(UNINIT);

/// Current verbosity, initializing from `NEURAL_PIM_LOG` on first read.
pub fn verbosity() -> u8 {
    let v = VERBOSITY.load(Ordering::Relaxed);
    if v != UNINIT {
        return v;
    }
    let env = std::env::var("NEURAL_PIM_LOG")
        .ok()
        .and_then(|s| s.trim().parse::<u8>().ok())
        .unwrap_or(0)
        .min(UNINIT - 1);
    VERBOSITY.store(env, Ordering::Relaxed);
    env
}

/// Set the verbosity explicitly (e.g. from `--verbose`).
pub fn set_verbosity(v: u8) {
    VERBOSITY.store(v.min(UNINIT - 1), Ordering::Relaxed);
}

/// Raise verbosity to at least `v`, keeping a higher `NEURAL_PIM_LOG`.
pub fn raise_verbosity(v: u8) {
    set_verbosity(verbosity().max(v));
}

/// Would a `diag!` at this level print?
pub fn enabled(level: u8) -> bool {
    verbosity() >= level
}

/// Leveled stderr diagnostic. Level 0 always prints (warnings); level 1
/// needs `--verbose` / `NEURAL_PIM_LOG=1`; higher levels are debug.
///
/// ```ignore
/// crate::diag!(1, "event-sim: {n} events in {s:.3}s");
/// ```
#[macro_export]
macro_rules! diag {
    ($lvl:expr, $($arg:tt)*) => {
        if $crate::obs::diag::enabled($lvl) {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_gate_as_documented() {
        // note: global state — keep this the only test mutating it
        set_verbosity(0);
        assert!(enabled(0));
        assert!(!enabled(1));
        raise_verbosity(1);
        assert!(enabled(1));
        assert!(!enabled(2));
        raise_verbosity(0); // raise never lowers
        assert!(enabled(1));
        set_verbosity(0);
    }
}
