//! Named counters, max-gauges, and fixed-bucket log2 histograms with a
//! deterministic merge.
//!
//! The registry is an *aggregation-time* structure: hot loops keep
//! plain `u64` fields (or a local [`Hist`]) and fold them in when a run
//! finishes, so instrumentation never touches a map on the event path.
//! Merging is commutative (counter add, gauge max, bucket add) and the
//! key space comes from the instrumentation sites — not the data — so
//! per-shard registries merged in shard order produce byte-identical
//! [`Registry::snapshot_string`] output at any `--threads`/`--shards`.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// log2 buckets: index 0 holds value 0, index `b` (1..=64) holds values
/// with bit length `b`, i.e. `2^(b-1) ..= 2^b - 1`.
pub const HIST_BUCKETS: usize = 65;

/// Fixed-size log2 histogram, cheap enough to live inline in a stats
/// struct (`observe` is a shift + two adds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hist {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist { buckets: [0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Bucket index for a value: its bit length (0 for 0).
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    pub fn observe(&mut self, v: u64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn merge(&mut self, other: &Hist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// `{count, sum, buckets: [[index, count], ...]}` — only non-zero
    /// buckets, in index order (canonical).
    pub fn to_json(&self) -> Json {
        let nz: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)])
            })
            .collect();
        json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum as f64)),
            ("buckets", Json::Arr(nz)),
        ])
    }
}

/// Named counters (monotonic adds), gauges (running max), and log2
/// histograms. `BTreeMap` keys make every iteration order — and the
/// JSON snapshot — deterministic.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add to a counter. Zero deltas still materialize the key, so the
    /// snapshot key set reflects the instrumentation, not the data.
    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Raise a gauge to at least `v` (running maximum).
    pub fn gauge_max(&mut self, name: &str, v: u64) {
        let g = self.gauges.entry(name.to_string()).or_insert(0);
        *g = (*g).max(v);
    }

    /// Record one histogram observation.
    pub fn observe(&mut self, name: &str, v: u64) {
        self.hists.entry(name.to_string()).or_default().observe(v);
    }

    /// Fold a locally-accumulated [`Hist`] into the named histogram.
    pub fn merge_hist(&mut self, name: &str, h: &Hist) {
        self.hists.entry(name.to_string()).or_default().merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, u64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty()
            && self.hists.is_empty()
    }

    /// Merge another registry in: counters add, gauges max, histograms
    /// bucket-add. Commutative, so any merge order yields the same
    /// totals — merge in shard order anyway for a stable convention.
    pub fn merge(&mut self, other: &Registry) {
        for (k, &v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, &v) in &other.gauges {
            let g = self.gauges.entry(k.clone()).or_insert(0);
            *g = (*g).max(v);
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "hists",
                Json::Obj(
                    self.hists
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Canonical one-line snapshot — the byte-identity anchor the
    /// determinism tests compare.
    pub fn snapshot_string(&self) -> String {
        self.to_json().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_are_bit_lengths() {
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        assert_eq!(Hist::bucket_of(2), 2);
        assert_eq!(Hist::bucket_of(3), 2);
        assert_eq!(Hist::bucket_of(4), 3);
        assert_eq!(Hist::bucket_of(1023), 10);
        assert_eq!(Hist::bucket_of(1024), 11);
        assert_eq!(Hist::bucket_of(u64::MAX), 64);
        let mut h = Hist::new();
        for v in [0u64, 1, 3, 1024, u64::MAX] {
            h.observe(v);
        }
        assert_eq!(h.count, 5);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[2], 1);
        assert_eq!(h.buckets[64], 1);
        assert_eq!(h.sum, u64::MAX); // saturated
    }

    #[test]
    fn registry_ops_and_merge_are_order_independent() {
        let mut a = Registry::new();
        a.add("c.x", 2);
        a.gauge_max("g.y", 5);
        a.observe("h.z", 7);
        let mut b = Registry::new();
        b.add("c.x", 3);
        b.add("c.only_b", 0); // zero delta still creates the key
        b.gauge_max("g.y", 4);
        b.observe("h.z", 9);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.snapshot_string(), ba.snapshot_string());
        assert_eq!(ab.counter("c.x"), 5);
        assert_eq!(ab.counter("c.only_b"), 0);
        assert!(ab.snapshot_string().contains("c.only_b"));
        assert_eq!(ab.gauge("g.y"), 5);
        assert_eq!(ab.hist("h.z").unwrap().count, 2);
        assert_eq!(ab.hist("h.z").unwrap().sum, 16);
    }

    #[test]
    fn snapshot_round_trips_through_json_parse() {
        let mut r = Registry::new();
        r.add("a", 1);
        r.gauge_max("b", 2);
        r.observe("c", 300);
        let s = r.snapshot_string();
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.to_string(), s);
        assert_eq!(
            j.get("counters").unwrap().get("a").unwrap().as_f64(),
            Some(1.0)
        );
    }
}
