//! Observability substrate: virtual-time tracing, deterministic named
//! counters/gauges/histograms, and leveled stderr diagnostics.
//!
//! Three pieces, all stamped in **virtual picoseconds** (the event
//! engine's clock — never wall time, so instrumented runs stay
//! bit-identical at any `--threads`/`--shards`):
//!
//! - [`Recorder`] — the tracing hook the hot layers (`event::engine`,
//!   `event::noc`, `event::pipeline`, `serve::loadgen`) are generic
//!   over. The default impl on every method is a no-op and
//!   [`NullRecorder`] overrides nothing, so the off-path monomorphizes
//!   to exactly the un-instrumented code (`is_enabled()` is a constant
//!   `false` the optimizer folds; see `benches/perf_hotpath.rs`
//!   `--only-obs` for the ≤2% budget proof). [`TraceRecorder`] captures
//!   spans/instants/counter-samples and exports Chrome trace-event JSON
//!   loadable in Perfetto ([`trace`]).
//! - [`Registry`] — named monotonic counters, max-gauges, and log2
//!   histograms ([`Hist`]). Aggregation-time only: hot paths keep plain
//!   `u64` fields in their stats structs and dump them into a registry
//!   when a run finishes; per-shard registries merge in shard order
//!   (commutative ops, deterministic `BTreeMap` iteration), so
//!   snapshots are byte-identical regardless of worker scheduling.
//! - [`diag`] + the crate-root `diag!` macro — leveled stderr
//!   diagnostics gated by `--verbose`/`NEURAL_PIM_LOG`. Level 0 is for
//!   warnings (always printed), level 1+ is informational chatter.
//!   `verify.sh` bans raw `eprintln!` outside this module and
//!   `main.rs`.
//!
//! The `--trace <path>`/`--trace-filter <prefix>` CLI options arrive
//! here as a [`TraceSpec`] (thread-local, set by `scenario::dispatch`
//! on the dispatching thread — scenarios read it with [`trace_spec`];
//! worker threads never consult the global, they receive recorders
//! explicitly).

pub mod diag;
pub mod registry;
pub mod trace;

pub use registry::{Hist, Registry};
pub use trace::TraceRecorder;

/// Tracing hook for the virtual-time hot layers. All timestamps are
/// virtual picoseconds. `track` names a timeline row (a stage, a NoC
/// port, a shard); `name` is the event label `--trace-filter` matches
/// against (use dotted `subsystem.detail` names).
///
/// Every method defaults to a no-op so [`NullRecorder`] costs nothing;
/// implementors override what they capture. Callers guard any
/// formatting work behind `is_enabled()`.
pub trait Recorder {
    /// `true` only when recording actually happens — lets call sites
    /// skip `format!` and sampling work on the null path.
    #[inline(always)]
    fn is_enabled(&self) -> bool {
        false
    }

    /// A duration on a track: `[ts_ps, ts_ps + dur_ps]`.
    #[inline(always)]
    fn span(&mut self, _ts_ps: u64, _dur_ps: u64, _track: &str, _name: &str) {}

    /// A point event on a track.
    #[inline(always)]
    fn instant(&mut self, _ts_ps: u64, _track: &str, _name: &str) {}

    /// One sample of a named counter series (a timeline, not a total —
    /// totals belong in a [`Registry`]).
    #[inline(always)]
    fn sample(&mut self, _ts_ps: u64, _series: &str, _value: f64) {}
}

/// The zero-cost default recorder: records nothing, inlines to nothing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NullRecorder;

impl Recorder for NullRecorder {}

/// Where (and what) to trace, as parsed from `--trace <path>` and
/// `--trace-filter <prefix>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSpec {
    pub path: String,
    /// event-name prefix filter; `None` records everything
    pub filter: Option<String>,
}

thread_local! {
    static TRACE_SPEC: std::cell::RefCell<Option<TraceSpec>> =
        std::cell::RefCell::new(None);
}

/// Install (or clear, with `None`) the trace request for scenarios run
/// on this thread. Thread-local on purpose: concurrent in-process
/// dispatches (tests) cannot contaminate each other, and `--trace` is
/// an execution option like `--out` — it never enters the scenario
/// fingerprint, so cached replays simply skip trace generation.
pub fn set_trace_spec(spec: Option<TraceSpec>) {
    TRACE_SPEC.with(|s| *s.borrow_mut() = spec);
}

/// The trace request installed on this thread, if any.
pub fn trace_spec() -> Option<TraceSpec> {
    TRACE_SPEC.with(|s| s.borrow().clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_recorder_is_disabled_and_inert() {
        let mut r = NullRecorder;
        assert!(!r.is_enabled());
        r.span(0, 10, "t", "a");
        r.instant(5, "t", "b");
        r.sample(7, "s", 1.0);
    }

    #[test]
    fn trace_spec_is_thread_local() {
        set_trace_spec(Some(TraceSpec { path: "x.json".into(), filter: None }));
        assert_eq!(trace_spec().unwrap().path, "x.json");
        let other = crate::util::pool::on_fresh_thread(trace_spec);
        assert!(other.is_none(), "spec leaked across threads");
        set_trace_spec(None);
        assert!(trace_spec().is_none());
    }
}
