//! Deterministic discrete-event core: a binary-heap event queue with
//! stable FIFO tie-breaking, an integer picosecond clock, and the stats
//! counters the microarchitectural models hook into.
//!
//! Determinism contract: one [`Engine`] is strictly sequential — events
//! pop in `(time, schedule order)` and the clock never moves backwards —
//! so any model built on it reproduces bit-identically run to run.
//! Parallelism happens one level up: *independent* engines (replicas or
//! scenarios) fan out over `util::pool::map`, which reassembles results
//! by input index, keeping every aggregate bit-identical at any
//! `--threads` count (the same contract `sim`/`dse`/`noise` rely on).

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Simulation time in integer picoseconds. 2⁶⁴ ps ≈ 213 days of sim
/// time; an integer clock (not f64) is what makes the tie-breaking —
/// and therefore the whole simulation — exactly reproducible.
pub type Time = u64;

pub const PS_PER_NS: Time = 1_000;

/// Convert a (fractional) nanosecond quantity to the integer clock.
pub fn ns_to_ps(ns: f64) -> Time {
    (ns * PS_PER_NS as f64).round() as Time
}

/// Sim time back to seconds (for reporting next to analytical results).
pub fn ps_to_s(ps: Time) -> f64 {
    ps as f64 * 1e-12
}

/// Heap entry: ordered by `(time, seq)` so that simultaneous events pop
/// in the order they were scheduled (stable FIFO tie-breaking).
struct Scheduled<E> {
    time: Time,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time.cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Counters every run exposes (the "stats hooks" models aggregate from).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    pub scheduled: u64,
    pub processed: u64,
    /// high-water mark of the pending-event queue
    pub peak_queue: usize,
}

/// The event queue + clock. `E` is the model's event payload.
pub struct Engine<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    now: Time,
    seq: u64,
    pub stats: EngineStats,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Engine<E> {
        Engine {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
            stats: EngineStats::default(),
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute sim time `at` (clamped to `now`:
    /// scheduling into the past is a model bug, caught in debug builds).
    pub fn schedule_at(&mut self, at: Time, event: E) {
        debug_assert!(at >= self.now, "event scheduled into the past");
        self.heap.push(Reverse(Scheduled {
            time: at.max(self.now),
            seq: self.seq,
            event,
        }));
        self.seq += 1;
        self.stats.scheduled += 1;
        self.stats.peak_queue = self.stats.peak_queue.max(self.heap.len());
    }

    /// Schedule `event` `delay` picoseconds from now.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse(s) = self.heap.pop()?;
        self.now = s.time;
        self.stats.processed += 1;
        Some((s.time, s.event))
    }

    /// Drain the queue, handing each event (and the engine, so handlers
    /// can schedule follow-ups) to `handler`.
    pub fn run<F: FnMut(&mut Engine<E>, Time, E)>(&mut self, mut handler: F) {
        while let Some((t, e)) = self.pop() {
            handler(self, t, e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(5, 1);
        e.schedule_at(3, 2);
        e.schedule_at(5, 3); // same time as id 1 -> must pop after it
        e.schedule_at(0, 4);
        let order: Vec<(Time, u32)> = std::iter::from_fn(|| e.pop()).collect();
        assert_eq!(order, vec![(0, 4), (3, 2), (5, 1), (5, 3)]);
        assert_eq!(e.now(), 5);
    }

    #[test]
    fn fifo_ties_hold_for_many_events() {
        let mut e: Engine<usize> = Engine::new();
        for i in 0..500 {
            e.schedule_at(7, i);
        }
        for want in 0..500 {
            assert_eq!(e.pop(), Some((7, want)));
        }
    }

    #[test]
    fn handlers_can_reschedule_and_clock_is_monotone() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_in(10, 3);
        let mut seen = Vec::new();
        let mut last = 0;
        e.run(|eng, t, ev| {
            assert!(t >= last, "clock went backwards");
            last = t;
            seen.push((t, ev));
            if ev > 0 {
                eng.schedule_in(7, ev - 1);
            }
        });
        assert_eq!(seen, vec![(10, 3), (17, 2), (24, 1), (31, 0)]);
        assert_eq!(e.stats.processed, 4);
        assert_eq!(e.stats.scheduled, 4);
    }

    #[test]
    fn stats_track_queue_high_water() {
        let mut e: Engine<u8> = Engine::new();
        for i in 0..9 {
            e.schedule_at(i as Time, i);
        }
        assert_eq!(e.stats.peak_queue, 9);
        while e.pop().is_some() {}
        assert_eq!(e.pending(), 0);
        assert_eq!(e.stats.processed, 9);
    }

    #[test]
    fn time_conversions_round_trip() {
        assert_eq!(ns_to_ps(100.0), 100_000);
        assert_eq!(ns_to_ps(50.0), 50_000);
        assert_eq!(ns_to_ps(0.5), 500);
        assert!((ps_to_s(1_000_000) - 1e-6).abs() < 1e-20);
    }
}
