//! Deterministic discrete-event core: a two-tier calendar/ladder event
//! queue with stable FIFO tie-breaking, an integer picosecond clock, a
//! slab arena for event payloads, and the stats counters the
//! microarchitectural models hook into.
//!
//! Determinism contract: one [`Engine`] is strictly sequential — events
//! pop in `(time, schedule order)` and the clock never moves backwards —
//! so any model built on it reproduces bit-identically run to run.
//! Parallelism happens one level up: *independent* engines (replicas,
//! shards, or scenarios) fan out over `util::pool::map`, which
//! reassembles results by input index, keeping every aggregate
//! bit-identical at any `--threads` count (the same contract
//! `sim`/`dse`/`noise` rely on).
//!
//! Queue internals: scheduled entries are `(Time, seq, u32)` triples
//! ([`Entry`]) — the payload itself lives in a slab and never moves
//! through the queue. The default backend is [`LadderQueue`] (near-future
//! circular buckets + an overflow tier, O(1) amortized); the pre-ladder
//! binary-heap implementation is retained in [`super::refqueue`] as the
//! differential-testing reference. Both sit behind the [`EventQueue`]
//! trait, so a test can pin either backend explicitly:
//! `Engine::<Ev, BinaryHeapQueue>::new()`.

use crate::util::num::ceil_log2;

/// Simulation time in integer picoseconds. 2⁶⁴ ps ≈ 213 days of sim
/// time; an integer clock (not f64) is what makes the tie-breaking —
/// and therefore the whole simulation — exactly reproducible.
pub type Time = u64;

pub const PS_PER_NS: Time = 1_000;

/// Convert a (fractional) nanosecond quantity to the integer clock.
///
/// Rounding is round-half-up on the non-negative domain (`f64::round`
/// ties away from zero, and valid inputs are `>= 0`): `0.4995 ns` →
/// `500 ps`. Negative or non-finite inputs are a caller bug — the
/// `as Time` cast would silently saturate them to 0 — so debug builds
/// assert; release builds keep the historical saturating behavior.
pub fn ns_to_ps(ns: f64) -> Time {
    debug_assert!(
        ns.is_finite() && ns >= 0.0,
        "ns_to_ps: non-finite or negative input {ns}"
    );
    (ns * PS_PER_NS as f64).round() as Time
}

/// Sim time back to seconds (for reporting next to analytical results).
pub fn ps_to_s(ps: Time) -> f64 {
    ps as f64 * 1e-12
}

/// A scheduled entry: `(time, seq)` is the total pop order (seq is
/// unique per engine, so simultaneous events pop in schedule order —
/// stable FIFO tie-breaking), `idx` is the payload's slab slot. The
/// derived `Ord` is lexicographic over the field order, and since `seq`
/// is unique it never reaches `idx`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Entry {
    pub time: Time,
    pub seq: u64,
    pub idx: u32,
}

/// Priority-queue backend for [`Engine`]: pops entries in ascending
/// `(time, seq)` order.
///
/// Contract: every `push` carries a `time` no earlier than the last
/// popped entry's time (the engine clamps to `now`, and the clock is
/// monotone). [`LadderQueue`] relies on this to keep its bucket window
/// anchored at the clock.
pub trait EventQueue {
    fn push(&mut self, e: Entry);
    fn pop(&mut self) -> Option<Entry>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Backend-internal structural counters (ladder rebases etc.).
    /// Defaults to all-zero for backends with nothing to report.
    fn stats(&self) -> QueueStats {
        QueueStats::default()
    }
}

/// Structural counters a queue backend may expose — observability only,
/// never consulted by the simulation itself.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct QueueStats {
    /// window re-anchors onto the overflow tier ([`LadderQueue::rebase`])
    pub rebases: u64,
    /// entries migrated out of overflow across all rebases
    pub overflow_migrated: u64,
}

/// Number of near-future buckets (power of two so bucket→slot is a
/// mask). 1024 slots × adaptive width keeps the window covering twice
/// the resident-event span after a rebase.
const LADDER_BUCKETS: usize = 1024;
const LADDER_MASK: u64 = LADDER_BUCKETS as u64 - 1;
/// Occupancy bitmap words (64 slots per word).
const LADDER_WORDS: usize = LADDER_BUCKETS / 64;

/// Two-tier calendar/ladder queue: a circular window of
/// [`LADDER_BUCKETS`] buckets of `2^shift` ps each over the near future,
/// plus an unsorted overflow tier for entries beyond the window.
///
/// Ordering invariant: the window/overflow boundary is the *fixed*
/// `(window_start + LADDER_BUCKETS) << shift` — anchored when the
/// window is (re)based, not tracking the draining cursor — so every
/// overflow resident's time is strictly greater than every window
/// resident's and the window can always drain to empty before overflow
/// is consulted.
///
/// * `push` is O(1): append to the bucket (or overflow) the entry's
///   time falls in; only entries landing in the bucket currently being
///   drained pay a sorted insert.
/// * `pop` drains the current bucket (kept sorted descending, so the
///   minimum pops from the `Vec` tail), then scans the occupancy bitmap
///   for the next non-empty slot and sorts that bucket once.
/// * When the window is exhausted but overflow is not, `rebase` picks a
///   new `shift` so the full overflow span fits in half the window and
///   re-buckets every overflow entry — overflow drains completely, so
///   entries are never re-scanned across rebases.
///
/// The bucket width adapts upward from the configured floor (see
/// [`LadderQueue::with_granularity`]) only at rebase; a workload whose
/// event horizon stays inside the window never rebases again.
pub struct LadderQueue {
    /// log2 of the bucket width in ps (bucket index = `time >> shift`)
    shift: u32,
    /// lower bound on `shift`, from the configured floor granularity
    floor_shift: u32,
    /// absolute index of the window's base bucket: the window covers
    /// `[window_start, window_start + LADDER_BUCKETS)` and this base is
    /// FIXED between re-anchors/rebases. The window/overflow routing
    /// boundary hangs off this base, never off the advancing
    /// `cur_bucket` — otherwise a later push could land in the window
    /// *ahead* of an earlier-timed entry already parked in overflow and
    /// pop out of time order (the clock would move backwards).
    window_start: u64,
    /// absolute index of the bucket currently draining (advances within
    /// the window: `window_start <= cur_bucket < window_start +
    /// LADDER_BUCKETS`)
    cur_bucket: u64,
    /// entries of the current bucket, sorted descending so `Vec::pop`
    /// yields the `(time, seq)` minimum
    cur: Vec<Entry>,
    /// circular window; slot = bucket index & mask
    buckets: Vec<Vec<Entry>>,
    /// one bit per window slot with pending entries
    occupied: [u64; LADDER_WORDS],
    /// entries beyond the window, unsorted until the next rebase
    overflow: Vec<Entry>,
    /// time of the last popped entry: the anchor a re-filled empty
    /// queue restarts its window from (pushes are never earlier)
    horizon: Time,
    len: usize,
    qstats: QueueStats,
}

impl LadderQueue {
    /// Ladder with the finest bucket floor (1 ps). The width still
    /// adapts upward at rebase, so this is the right default when the
    /// event-time scale is unknown.
    pub fn new() -> LadderQueue {
        LadderQueue::with_granularity(1)
    }

    /// Ladder whose bucket width never drops below `floor_ps`
    /// (rounded up to a power of two). Callers that know their time
    /// quantum — e.g. a NoC cycle — can skip the fine-granularity
    /// warm-up before the first rebase adapts the width.
    pub fn with_granularity(floor_ps: Time) -> LadderQueue {
        let floor_shift = ceil_log2(floor_ps.max(1)).min(63);
        LadderQueue {
            shift: floor_shift,
            floor_shift,
            window_start: 0,
            cur_bucket: 0,
            cur: Vec::new(),
            buckets: (0..LADDER_BUCKETS).map(|_| Vec::new()).collect(),
            occupied: [0; LADDER_WORDS],
            overflow: Vec::new(),
            horizon: 0,
            len: 0,
            qstats: QueueStats::default(),
        }
    }

    /// Current bucket width in ps (adapts at rebase; for tests).
    pub fn granularity_ps(&self) -> Time {
        1u64 << self.shift
    }

    /// Resident entries in the overflow tier (for tests).
    pub fn overflow_len(&self) -> usize {
        self.overflow.len()
    }

    fn set_bit(&mut self, slot: usize) {
        self.occupied[slot >> 6] |= 1 << (slot & 63);
    }

    fn clear_bit(&mut self, slot: usize) {
        self.occupied[slot >> 6] &= !(1 << (slot & 63));
    }

    /// Absolute index of the first non-empty window bucket at or after
    /// `cur_bucket` (wrapped scan of the occupancy bitmap in window
    /// order, i.e. by increasing distance from `cur_bucket`).
    fn next_occupied(&self) -> Option<u64> {
        let s0 = (self.cur_bucket & LADDER_MASK) as usize;
        let (w0, b0) = (s0 >> 6, s0 & 63);
        let abs = |slot: usize| {
            let delta = (slot as u64).wrapping_sub(s0 as u64) & LADDER_MASK;
            self.cur_bucket + delta
        };
        // head of the starting word: slots >= s0
        let bits = self.occupied[w0] & (!0u64 << b0);
        if bits != 0 {
            return Some(abs((w0 << 6) + bits.trailing_zeros() as usize));
        }
        // remaining words in wrap order
        for i in 1..LADDER_WORDS {
            let w = (w0 + i) % LADDER_WORDS;
            let bits = self.occupied[w];
            if bits != 0 {
                return Some(abs((w << 6) + bits.trailing_zeros() as usize));
            }
        }
        // wrapped tail of the starting word: slots < s0
        let bits = self.occupied[w0] & !(!0u64 << b0);
        if bits != 0 {
            return Some(abs((w0 << 6) + bits.trailing_zeros() as usize));
        }
        None
    }

    /// Re-anchor the window on the overflow tier. Preconditions (both
    /// held at the single call site in `pop`): the window and current
    /// bucket are empty, and overflow is not — every resident entry is
    /// in `overflow`, so `shift` may be re-derived freely.
    ///
    /// The new width makes the overflow span fit in half the window,
    /// so *every* overflow entry re-buckets here (overflow drains to
    /// empty) and the remaining half-window absorbs near-future pushes
    /// without an immediate follow-up rebase.
    fn rebase(&mut self) {
        debug_assert!(!self.overflow.is_empty());
        debug_assert!(self.cur.is_empty());
        debug_assert!(self.occupied.iter().all(|w| *w == 0));
        let mut min_t = Time::MAX;
        let mut max_t = 0;
        for e in &self.overflow {
            min_t = min_t.min(e.time);
            max_t = max_t.max(e.time);
        }
        self.qstats.rebases += 1;
        self.qstats.overflow_migrated += self.overflow.len() as u64;
        let span_per_bucket = (max_t - min_t) / (LADDER_BUCKETS as u64 / 2) + 1;
        self.shift = ceil_log2(span_per_bucket).max(self.floor_shift);
        self.cur_bucket = min_t >> self.shift;
        self.window_start = self.cur_bucket;
        for e in std::mem::take(&mut self.overflow) {
            let b = e.time >> self.shift;
            debug_assert!(b.wrapping_sub(self.window_start) < LADDER_BUCKETS as u64);
            let slot = (b & LADDER_MASK) as usize;
            self.buckets[slot].push(e);
            self.set_bit(slot);
        }
    }
}

impl Default for LadderQueue {
    fn default() -> Self {
        LadderQueue::new()
    }
}

impl EventQueue for LadderQueue {
    fn push(&mut self, e: Entry) {
        if self.len == 0 {
            // Re-anchor an emptied queue at the clock horizon, NOT at
            // the pushed entry: a later push may carry a time >= the
            // horizon but < this entry's, and must not land behind the
            // window.
            debug_assert!(self.cur.is_empty() && self.overflow.is_empty());
            self.cur_bucket = self.horizon >> self.shift;
            self.window_start = self.cur_bucket;
        }
        self.len += 1;
        let b = e.time >> self.shift;
        if b <= self.cur_bucket {
            // Lands in (or, on a contract violation, behind) the bucket
            // being drained: sorted insert into the descending drain
            // list. `partition_point` keeps entries > e in front.
            let pos = self.cur.partition_point(|p| *p > e);
            self.cur.insert(pos, e);
        } else if b - self.window_start < LADDER_BUCKETS as u64 {
            let slot = (b & LADDER_MASK) as usize;
            self.buckets[slot].push(e);
            self.set_bit(slot);
        } else {
            // At or past the window's FIXED far edge. Every overflow
            // entry's time is >= `(window_start + LADDER_BUCKETS) <<
            // shift`, strictly above every window resident's, so pop
            // may fully drain the window before consulting overflow
            // (via rebase) without reordering.
            self.overflow.push(e);
        }
    }

    fn pop(&mut self) -> Option<Entry> {
        loop {
            if let Some(e) = self.cur.pop() {
                self.len -= 1;
                self.horizon = e.time;
                return Some(e);
            }
            if self.len == 0 {
                return None;
            }
            if let Some(b) = self.next_occupied() {
                self.cur_bucket = b;
                let slot = (b & LADDER_MASK) as usize;
                self.cur = std::mem::take(&mut self.buckets[slot]);
                self.clear_bit(slot);
                // seq is unique, so the (time, seq) key is total and
                // an unstable sort is still deterministic
                self.cur.sort_unstable_by(|a, b| b.cmp(a));
            } else {
                self.rebase();
            }
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn stats(&self) -> QueueStats {
        self.qstats
    }
}

/// Slab slot: vacant slots form an intrusive free list.
enum Slot<E> {
    Vacant { next: u32 },
    Occupied { seq: u64, ev: E },
}

const SLAB_NIL: u32 = u32::MAX;

/// Payload arena: events live here while scheduled, addressed by the
/// `u32` slot index carried in [`Entry`]. The schedule `seq` doubles as
/// the generation tag — it is unique per engine, so a stale index can
/// never alias a recycled slot undetected (checked in debug builds).
struct Slab<E> {
    slots: Vec<Slot<E>>,
    free_head: u32,
}

impl<E> Slab<E> {
    fn new() -> Slab<E> {
        Slab { slots: Vec::new(), free_head: SLAB_NIL }
    }

    fn insert(&mut self, seq: u64, ev: E) -> u32 {
        if self.free_head != SLAB_NIL {
            let idx = self.free_head;
            match std::mem::replace(
                &mut self.slots[idx as usize],
                Slot::Occupied { seq, ev },
            ) {
                Slot::Vacant { next } => self.free_head = next,
                Slot::Occupied { .. } => unreachable!("free list hit a live slot"),
            }
            idx
        } else {
            debug_assert!(self.slots.len() < SLAB_NIL as usize);
            self.slots.push(Slot::Occupied { seq, ev });
            (self.slots.len() - 1) as u32
        }
    }

    fn remove(&mut self, idx: u32, seq: u64) -> E {
        let slot = std::mem::replace(
            &mut self.slots[idx as usize],
            Slot::Vacant { next: self.free_head },
        );
        match slot {
            Slot::Occupied { seq: tag, ev } => {
                debug_assert_eq!(tag, seq, "slab generation mismatch");
                self.free_head = idx;
                ev
            }
            Slot::Vacant { .. } => panic!("slab remove of a vacant slot"),
        }
    }
}

/// Counters every run exposes (the "stats hooks" models aggregate from).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    pub scheduled: u64,
    pub processed: u64,
    /// high-water mark of resident events across every queue tier
    /// (current bucket + window + overflow)
    pub peak_queue: usize,
    /// events whose `schedule_at` time lay in the past and was clamped
    /// to `now` — tolerated (the clock never moves backwards) but
    /// counted, so scenarios can surface model bugs instead of hiding
    /// them in release builds
    pub clamped: u64,
}

/// The event queue + clock. `E` is the model's event payload, `Q` the
/// queue backend (default: [`LadderQueue`]; tests pin
/// [`super::refqueue::BinaryHeapQueue`] for differential runs).
pub struct Engine<E, Q: EventQueue = LadderQueue> {
    queue: Q,
    slab: Slab<E>,
    now: Time,
    seq: u64,
    pub stats: EngineStats,
}

impl<E, Q: EventQueue + Default> Default for Engine<E, Q> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E, Q: EventQueue + Default> Engine<E, Q> {
    pub fn new() -> Engine<E, Q> {
        Engine::with_queue(Q::default())
    }
}

impl<E, Q: EventQueue> Engine<E, Q> {
    /// Engine over an explicitly configured queue backend (e.g.
    /// `LadderQueue::with_granularity(NOC_CYCLE_PS)`).
    pub fn with_queue(queue: Q) -> Engine<E, Q> {
        Engine {
            queue,
            slab: Slab::new(),
            now: 0,
            seq: 0,
            stats: EngineStats::default(),
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Structural counters from the queue backend (see [`QueueStats`]).
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Schedule `event` at absolute sim time `at`. Scheduling into the
    /// past is clamped to `now` (the clock never moves backwards) and
    /// counted in [`EngineStats::clamped`] rather than asserted, so the
    /// rate is observable in release runs too.
    pub fn schedule_at(&mut self, at: Time, event: E) {
        let at = if at < self.now {
            self.stats.clamped += 1;
            self.now
        } else {
            at
        };
        let idx = self.slab.insert(self.seq, event);
        self.queue.push(Entry { time: at, seq: self.seq, idx });
        self.seq += 1;
        self.stats.scheduled += 1;
        self.stats.peak_queue = self.stats.peak_queue.max(self.queue.len());
    }

    /// Schedule `event` `delay` picoseconds from now.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let e = self.queue.pop()?;
        self.now = e.time;
        self.stats.processed += 1;
        Some((e.time, self.slab.remove(e.idx, e.seq)))
    }

    /// Drain the queue, handing each event (and the engine, so handlers
    /// can schedule follow-ups) to `handler`.
    pub fn run<F: FnMut(&mut Engine<E, Q>, Time, E)>(&mut self, mut handler: F) {
        while let Some((t, e)) = self.pop() {
            handler(self, t, e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(5, 1);
        e.schedule_at(3, 2);
        e.schedule_at(5, 3); // same time as id 1 -> must pop after it
        e.schedule_at(0, 4);
        let order: Vec<(Time, u32)> = std::iter::from_fn(|| e.pop()).collect();
        assert_eq!(order, vec![(0, 4), (3, 2), (5, 1), (5, 3)]);
        assert_eq!(e.now(), 5);
    }

    #[test]
    fn fifo_ties_hold_for_many_events() {
        let mut e: Engine<usize> = Engine::new();
        for i in 0..500 {
            e.schedule_at(7, i);
        }
        for want in 0..500 {
            assert_eq!(e.pop(), Some((7, want)));
        }
    }

    #[test]
    fn handlers_can_reschedule_and_clock_is_monotone() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_in(10, 3);
        let mut seen = Vec::new();
        let mut last = 0;
        e.run(|eng, t, ev| {
            assert!(t >= last, "clock went backwards");
            last = t;
            seen.push((t, ev));
            if ev > 0 {
                eng.schedule_in(7, ev - 1);
            }
        });
        assert_eq!(seen, vec![(10, 3), (17, 2), (24, 1), (31, 0)]);
        assert_eq!(e.stats.processed, 4);
        assert_eq!(e.stats.scheduled, 4);
    }

    #[test]
    fn stats_track_queue_high_water() {
        let mut e: Engine<u8> = Engine::new();
        for i in 0..9 {
            e.schedule_at(i as Time, i);
        }
        assert_eq!(e.stats.peak_queue, 9);
        while e.pop().is_some() {}
        assert_eq!(e.pending(), 0);
        assert_eq!(e.stats.processed, 9);
    }

    #[test]
    fn peak_queue_counts_residents_across_all_tiers() {
        // Spread entries over the current bucket, the window, and the
        // far-future overflow tier; the high-water mark must count all
        // of them, not just one bucket.
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(0, 0); // current bucket
        e.schedule_at(3, 1); // window
        e.schedule_at(u32::MAX as Time * 1_000, 2); // overflow tier
        assert_eq!(e.stats.peak_queue, 3);
        assert_eq!(e.pending(), 3);
        let mut n = 0;
        while e.pop().is_some() {
            n += 1;
        }
        assert_eq!(n, 3);
    }

    #[test]
    fn past_scheduling_is_clamped_and_counted() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(100, 1);
        assert_eq!(e.pop(), Some((100, 1)));
        e.schedule_at(40, 2); // past: clamps to now = 100
        e.schedule_at(100, 3); // exactly now: not a clamp
        assert_eq!(e.stats.clamped, 1);
        assert_eq!(e.pop(), Some((100, 2)));
        assert_eq!(e.pop(), Some((100, 3)));
        assert_eq!(e.now(), 100);
    }

    #[test]
    fn ladder_pops_across_window_wrap_and_rebase() {
        // Forces every queue path: current-bucket insert, window slots,
        // a window wrap, and an overflow rebase with shift adaptation.
        let mut q = LadderQueue::with_granularity(1);
        let times = [
            0u64,
            1,
            LADDER_BUCKETS as u64 / 2,
            LADDER_BUCKETS as u64 + 5, // beyond the window -> overflow
            1 << 40,                   // far tail -> coarse rebase
            (1 << 40) + 1,
        ];
        for (i, &t) in times.iter().enumerate() {
            q.push(Entry { time: t, seq: i as u64, idx: i as u32 });
        }
        assert!(q.overflow_len() > 0);
        let mut sorted = times;
        sorted.sort_unstable();
        let got: Vec<Time> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(got, sorted);
        assert!(q.granularity_ps() > 1, "rebase should have coarsened the width");
        assert!(q.is_empty());
    }

    #[test]
    fn ladder_overflow_boundary_is_fixed_not_cursor_relative() {
        // Regression: when the overflow boundary hung off the advancing
        // cur_bucket, t=1600 (pushed after popping 600 moved the
        // cursor) landed in the window while the earlier t=1500 sat in
        // overflow, so the drain yielded 600, 1600, 1500 — time order
        // violated. With the boundary fixed at window_start both far
        // pushes route to overflow and rebase restores order.
        let mut q = LadderQueue::with_granularity(1);
        q.push(Entry { time: 600, seq: 0, idx: 0 });
        q.push(Entry { time: 1500, seq: 1, idx: 1 });
        assert_eq!(q.overflow_len(), 1);
        assert_eq!(q.pop().map(|e| e.time), Some(600));
        q.push(Entry { time: 1600, seq: 2, idx: 2 });
        assert_eq!(q.overflow_len(), 2, "1600 must join 1500 in overflow");
        assert_eq!(q.pop().map(|e| e.time), Some(1500));
        assert_eq!(q.pop().map(|e| e.time), Some(1600));
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_stats_count_rebases_and_overflow_migration() {
        let mut e: Engine<u32> = Engine::new();
        assert_eq!(e.queue_stats(), QueueStats::default());
        e.schedule_at(0, 0);
        e.schedule_at(1 << 40, 1); // far past the window -> overflow
        e.schedule_at((1 << 40) + 1, 2);
        while e.pop().is_some() {}
        let qs = e.queue_stats();
        assert_eq!(qs.rebases, 1);
        assert_eq!(qs.overflow_migrated, 2);
        // the reference backend reports the zero default
        let mut r: Engine<u32, crate::event::BinaryHeapQueue> = Engine::new();
        r.schedule_at(1 << 40, 1);
        assert_eq!(r.queue_stats(), QueueStats::default());
    }

    #[test]
    fn ladder_window_wraps_around_the_slot_ring() {
        let mut q = LadderQueue::with_granularity(1);
        q.push(Entry { time: 1000, seq: 0, idx: 0 });
        assert_eq!(q.pop().map(|e| e.time), Some(1000));
        // slot(1500) = 476 sits behind slot(1000) in the ring: the
        // bitmap scan must map it back to absolute bucket 1500 via the
        // wrap, not surface it before bucket 1001
        q.push(Entry { time: 1500, seq: 1, idx: 1 });
        q.push(Entry { time: 1001, seq: 2, idx: 2 });
        assert_eq!(q.pop().map(|e| e.time), Some(1001));
        assert_eq!(q.pop().map(|e| e.time), Some(1500));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ladder_granularity_floor_is_honored() {
        let q = LadderQueue::with_granularity(1_000);
        assert_eq!(q.granularity_ps(), 1_024); // rounded up to a power of two
        let q = LadderQueue::with_granularity(1);
        assert_eq!(q.granularity_ps(), 1);
    }

    #[test]
    fn time_conversions_round_trip() {
        assert_eq!(ns_to_ps(100.0), 100_000);
        assert_eq!(ns_to_ps(50.0), 50_000);
        assert_eq!(ns_to_ps(0.5), 500);
        assert!((ps_to_s(1_000_000) - 1e-6).abs() < 1e-20);
    }

    #[test]
    fn prop_ns_to_ps_round_trips_against_the_f64_path() {
        // Forward: the integer result stays within half a picosecond of
        // the exact f64 product (round-half-up). Backward: an integer
        // picosecond count survives ps -> ns -> ps exactly while the
        // product is exactly representable (< 2^53 fits f64's mantissa).
        prop::check("ns_to_ps round-trips vs f64", 300, |g| {
            let ns = g.f64_in(0.0, 1e9);
            let ps = ns_to_ps(ns);
            let exact = ns * PS_PER_NS as f64;
            crate::prop_assert!(
                (ps as f64 - exact).abs() <= 0.5,
                "ns_to_ps({ns}) = {ps}, off from exact {exact}"
            );
            let ps_int = g.u64() % (1 << 40);
            let ns_back = ps_to_s(ps_int) * 1e9;
            crate::prop_assert!(
                ns_to_ps(ns_back) == ps_int,
                "{ps_int} ps -> {ns_back} ns -> {} ps",
                ns_to_ps(ns_back)
            );
            Ok(())
        });
    }
}
