//! Contention-aware c-mesh on top of `arch::CMesh`'s XY routes.
//!
//! The analytical model ([`CMesh::transfer_latency_ns`]) is explicitly
//! contention-free; here every directed link — and each router's local
//! ejection port, which covers the zero-hop convention — keeps a
//! busy-until timestamp, so overlapping transfers queue instead of
//! teleporting past each other.
//!
//! Timing model (head-flit cut-through at the 1 GHz NoC clock):
//! the head flit pays one cycle per router traversal and additionally
//! waits for each output port to free; the tail streams `ser` flits
//! (32 B each, min 1) behind it, and each port stays busy for those
//! `ser` cycles after the head departs. **Uncongested, a transfer
//! reproduces the analytical latency exactly** — `max(hops, 1) + ser`
//! cycles — which the property tests pin down; under load the extra
//! wait is precisely the queueing the analytical model hides.
//! Destination ejection contention is folded into the last link
//! (wormhole-style), so only same-router transfers touch the local port.
//!
//! Fast path: when the whole mesh is provably idle for a head flit
//! starting at `now` (`now + 1 cycle >= max_free`, the high-water mark
//! of every reservation ever made), the router-by-router walk is
//! skipped entirely — O(1) per transfer, no route materialization, no
//! per-port writes. The reservation is kept *pending* and only written
//! into the port table by the next contended send; a pending
//! reservation superseded by a later idle send is dropped outright,
//! which is sound because every one of its port claims is already at or
//! below `max_free` and therefore below any future head's ready time.
//! The differential property test pins this against the always-walk
//! reference.
//!
//! Energy reuses [`CMesh::transfer_energy`] (`energy::constants::
//! NOC_E_BYTE`, min-1-hop convention), charged per delivery.

use super::engine::{Time, PS_PER_NS};
use crate::arch::noc::CMesh;
use crate::obs::{NullRecorder, Recorder};

/// 1 GHz NoC clock — the unit `CMesh::transfer_latency_ns` counts in.
pub const NOC_CYCLE_PS: Time = PS_PER_NS;

/// Flit size in bytes (the 32 B/cycle serialization of `arch::noc`).
pub const FLIT_BYTES: u64 = 32;

/// E, W, S, N output links + the local ejection port.
const PORTS_PER_ROUTER: usize = 5;
const LOCAL_PORT: usize = 4;
/// Port-direction suffixes for trace track names (indexOf = dir).
const DIR_NAMES: [&str; PORTS_PER_ROUTER] = ["e", "w", "s", "n", "l"];

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct NocStats {
    pub packets: u64,
    pub flits: u64,
    pub hops_total: u64,
    /// total head-flit queueing (the contention component), ps
    pub queued_ps_total: u64,
    pub queued_ps_max: Time,
    pub energy_j: f64,
    /// packets that resolved via the O(1) idle-mesh fast path (always 0
    /// when a live recorder forces the walk — see [`NocModel::send_rec`])
    pub fast_path_hits: u64,
    /// packets whose head flit queued at least one cycle (contention)
    pub stalled_packets: u64,
}

/// One completed transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Delivery {
    /// sim time the tail flit reaches the destination
    pub arrive_ps: Time,
    /// how long the head flit sat in router queues (0 when uncongested)
    pub queued_ps: Time,
    pub energy_j: f64,
    pub hops: u32,
}

/// An idle-mesh transfer whose port claims have not been written into
/// the busy-until table yet (see the fast-path note in the module doc).
#[derive(Debug, Clone, Copy)]
struct Reservation {
    from: u32,
    to: u32,
    start: Time,
    hold: Time,
}

/// Per-port occupancy state for one mesh.
pub struct NocModel {
    pub mesh: CMesh,
    /// busy-until per (router, port); router index = y * side + x
    port_free: Vec<Time>,
    /// max busy-until over every reservation ever made, materialized or
    /// pending — the idle-mesh witness for the fast path
    max_free: Time,
    /// the one fast-path reservation not yet in `port_free`
    pending: Option<Reservation>,
    /// scratch route buffer, reused across walks
    route_buf: Vec<(u32, u32)>,
    pub stats: NocStats,
}

impl NocModel {
    pub fn new(mesh: CMesh) -> NocModel {
        let slots = (mesh.side as usize) * (mesh.side as usize);
        NocModel {
            port_free: vec![0; slots * PORTS_PER_ROUTER],
            max_free: 0,
            pending: None,
            route_buf: Vec::new(),
            stats: NocStats::default(),
            mesh,
        }
    }

    /// Route a `bytes`-byte packet from tile `from` to tile `to`,
    /// starting at `now`. Mutates the port busy-until state (this IS the
    /// contention) and returns when the packet lands, how long its head
    /// queued, and the energy charged.
    ///
    /// Calls must carry non-decreasing `now` (the engine clock, which
    /// is monotone) — the idle fast path relies on it.
    pub fn send(&mut self, now: Time, from: u32, to: u32, bytes: u64)
                -> Delivery {
        self.send_rec(now, from, to, bytes, &mut NullRecorder)
    }

    /// [`NocModel::send`] with a tracing hook. A live recorder
    /// (`rec.is_enabled()`) forces the full walk so every per-link
    /// reservation becomes a span on its port's track — the walk is
    /// result-identical to the fast path (pinned by
    /// `prop_fast_path_matches_always_walk_reference`), so timing and
    /// energy stay bit-identical; only `NocStats::fast_path_hits`
    /// differs between traced and untraced runs.
    pub fn send_rec<R: Recorder>(
        &mut self,
        now: Time,
        from: u32,
        to: u32,
        bytes: u64,
        rec: &mut R,
    ) -> Delivery {
        let hops = self.mesh.hops(from, to);
        let ser = bytes.div_ceil(FLIT_BYTES).max(1);
        let hold = ser * NOC_CYCLE_PS;
        let (arrive, queued) = if !rec.is_enabled()
            && now + NOC_CYCLE_PS >= self.max_free
        {
            // Provably idle: the head is ready at `now + 1 cycle`, at
            // or after every outstanding claim, so the walk would find
            // zero queueing at every port — reproduce its result in
            // O(1). Any previously pending reservation is likewise at
            // or below `max_free` and can never delay a future head;
            // drop it instead of materializing.
            let arrive = now + Time::from(hops.max(1)) * NOC_CYCLE_PS + hold;
            self.pending = Some(Reservation { from, to, start: now, hold });
            self.max_free = self.max_free.max(arrive);
            self.stats.fast_path_hits += 1;
            (arrive, 0)
        } else {
            if let Some(r) = self.pending.take() {
                // a pending reservation only exists after a fast-path
                // send, i.e. never under a live recorder — no spans lost
                let (_, q) =
                    self.walk(r.start, r.from, r.to, r.hold, &mut NullRecorder);
                debug_assert_eq!(
                    q, 0,
                    "pending fast-path reservation must be contention-free"
                );
            }
            self.walk(now, from, to, hold, rec)
        };
        let energy = self.mesh.transfer_energy(bytes, hops);
        self.stats.packets += 1;
        self.stats.flits += ser;
        self.stats.hops_total += hops as u64;
        self.stats.queued_ps_total += queued;
        self.stats.queued_ps_max = self.stats.queued_ps_max.max(queued);
        self.stats.energy_j += energy;
        if queued > 0 {
            self.stats.stalled_packets += 1;
        }
        Delivery { arrive_ps: arrive, queued_ps: queued, energy_j: energy, hops }
    }

    /// The full router-by-router walk: claim every output port along
    /// the XY route, accumulating head-flit queueing. Returns
    /// `(arrive, queued)`. A live recorder gets one reservation span
    /// per claimed port: `[depart, depart + hold]` on track
    /// `noc.r<router>.<dir>`.
    fn walk<R: Recorder>(
        &mut self,
        start: Time,
        from: u32,
        to: u32,
        hold: Time,
        rec: &mut R,
    ) -> (Time, Time) {
        let mut route = std::mem::take(&mut self.route_buf);
        self.mesh.route_into(from, to, &mut route);
        let side = self.mesh.side;
        let mut head = start;
        let mut queued: Time = 0;
        if route.len() == 1 {
            // same-router transfer: one pass through the local crossbar
            // (the min-1-hop convention of `arch::noc`)
            let p = port_index(side, route[0], LOCAL_PORT);
            head = claim(&mut self.port_free, p, head, hold, &mut queued);
            if rec.is_enabled() {
                rec.span(head, hold, &port_track(side, route[0], LOCAL_PORT),
                         "noc.link");
            }
        } else {
            for w in route.windows(2) {
                let dir = dir_of(w[0], w[1]);
                let p = port_index(side, w[0], dir);
                head = claim(&mut self.port_free, p, head, hold, &mut queued);
                if rec.is_enabled() {
                    rec.span(head, hold, &port_track(side, w[0], dir),
                             "noc.link");
                }
            }
        }
        let arrive = head + hold; // tail flits stream behind the head
        self.max_free = self.max_free.max(arrive);
        self.route_buf = route;
        (arrive, queued)
    }
}

fn port_index(side: u32, router: (u32, u32), dir: usize) -> usize {
    ((router.1 * side + router.0) as usize) * PORTS_PER_ROUTER + dir
}

/// Trace track name for a router output port, e.g. `noc.r5.e`.
fn port_track(side: u32, router: (u32, u32), dir: usize) -> String {
    format!("noc.r{}.{}", router.1 * side + router.0, DIR_NAMES[dir])
}

/// Claim one output port: 1-cycle traversal, wait for the port to
/// free, then hold it for the tail's serialization time.
fn claim(
    free: &mut [Time],
    port: usize,
    head: Time,
    hold: Time,
    queued: &mut Time,
) -> Time {
    let ready = head + NOC_CYCLE_PS;
    let depart = ready.max(free[port]);
    free[port] = depart + hold;
    *queued += depart - ready;
    depart
}

fn dir_of(a: (u32, u32), b: (u32, u32)) -> usize {
    if b.0 > a.0 {
        0 // east
    } else if b.0 < a.0 {
        1 // west
    } else if b.1 > a.1 {
        2 // south
    } else {
        3 // north
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn uncongested_transfer_matches_analytical_latency_exactly() {
        prop::check("idle mesh == transfer_latency_ns", 150, |g| {
            let tiles = g.usize_in(1, 300) as u32;
            let conc = *g.pick(&[1u32, 2, 4, 8]);
            let mut noc = NocModel::new(CMesh::new(tiles, conc));
            let a = g.usize_in(0, tiles as usize - 1) as u32;
            let b = g.usize_in(0, tiles as usize - 1) as u32;
            let bytes = g.usize_in(1, 4096) as u64;
            let t0 = 12_345;
            let d = noc.send(t0, a, b, bytes);
            let hops = noc.mesh.hops(a, b);
            let want =
                super::super::engine::ns_to_ps(
                    noc.mesh.transfer_latency_ns(bytes, hops));
            crate::prop_assert!(
                d.arrive_ps - t0 == want,
                "event {} vs analytical {} (hops {hops}, {bytes} B)",
                d.arrive_ps - t0, want
            );
            crate::prop_assert!(d.queued_ps == 0, "queued on an idle mesh");
            let e = noc.mesh.transfer_energy(bytes, hops);
            crate::prop_assert!((d.energy_j - e).abs() < 1e-30, "energy");
            Ok(())
        });
    }

    #[test]
    fn contention_delays_second_packet_by_its_hold_time() {
        // tiles 0 and 32 on a 64-tile/conc-4 mesh: same XY route
        let mut noc = NocModel::new(CMesh::new(64, 4));
        let (a, b) = (0u32, 32u32);
        assert!(noc.mesh.hops(a, b) >= 2);
        let d1 = noc.send(0, a, b, 64); // 2 flits
        let d2 = noc.send(0, a, b, 64);
        assert_eq!(d1.queued_ps, 0);
        // the second head waits exactly the first packet's 2-flit hold
        // on the first shared link
        assert_eq!(d2.queued_ps, 2 * NOC_CYCLE_PS);
        assert_eq!(d2.arrive_ps, d1.arrive_ps + 2 * NOC_CYCLE_PS);
        assert_eq!(noc.stats.packets, 2);
        assert_eq!(noc.stats.queued_ps_total, 2 * NOC_CYCLE_PS);
    }

    #[test]
    fn local_port_serializes_same_router_transfers() {
        let mut noc = NocModel::new(CMesh::new(64, 4));
        assert_eq!(noc.mesh.hops(0, 1), 0); // tiles 0,1 share router 0
        let d1 = noc.send(0, 0, 1, 32); // 1 flit
        let d2 = noc.send(0, 0, 1, 32);
        assert_eq!(d1.queued_ps, 0);
        assert_eq!(d1.arrive_ps, 2 * NOC_CYCLE_PS); // 1 traversal + 1 flit
        assert_eq!(d2.queued_ps, NOC_CYCLE_PS);
        assert_eq!(d2.arrive_ps, d1.arrive_ps + NOC_CYCLE_PS);
    }

    #[test]
    fn disjoint_routes_do_not_interact() {
        let mut noc = NocModel::new(CMesh::new(64, 4));
        // router grid is 4x4; pick two transfers in different rows
        let d1 = noc.send(0, 0, 12, 256); // row 0: r0 -> r3
        let d2 = noc.send(0, 16, 28, 256); // row 1: r4 -> r7
        assert_eq!(d1.queued_ps, 0);
        assert_eq!(d2.queued_ps, 0);
    }

    #[test]
    fn sends_are_deterministic() {
        let run = || {
            let mut noc = NocModel::new(CMesh::new(128, 4));
            let mut out = Vec::new();
            for i in 0..64u32 {
                let d = noc.send((i as Time) * 500, i % 128,
                                 (i * 37) % 128, 96 + (i as u64) * 8);
                out.push((d.arrive_ps, d.queued_ps, d.energy_j.to_bits()));
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fast_path_and_stall_counters_track_their_paths() {
        let mut noc = NocModel::new(CMesh::new(64, 4));
        let d1 = noc.send(0, 0, 32, 64); // idle mesh -> fast path
        let d2 = noc.send(0, 0, 32, 64); // contended -> walk, stalls
        assert_eq!(noc.stats.fast_path_hits, 1);
        assert_eq!(noc.stats.stalled_packets, 1);
        assert_eq!(d1.queued_ps, 0);
        assert!(d2.queued_ps > 0);
    }

    #[test]
    fn traced_send_matches_untraced_and_records_per_link_spans() {
        use crate::obs::{Recorder, TraceRecorder};
        let mut plain = NocModel::new(CMesh::new(64, 4));
        let mut traced = NocModel::new(CMesh::new(64, 4));
        let mut rec = TraceRecorder::new();
        assert!(rec.is_enabled());
        for (t, a, b) in [(0u64, 0u32, 32u32), (0, 0, 32), (90_000, 5, 60)] {
            let d1 = plain.send(t, a, b, 64);
            let d2 = traced.send_rec(t, a, b, 64, &mut rec);
            assert_eq!(d1, d2, "traced delivery diverged");
        }
        // one reservation span per hop of every send
        let hops: u64 = plain.stats.hops_total;
        assert_eq!(rec.len() as u64, hops);
        assert!(rec.tracks().iter().all(|t| t.starts_with("noc.r")), "{:?}",
                rec.tracks());
        // the recorder forces the walk: no fast-path hits on that side
        assert_eq!(traced.stats.fast_path_hits, 0);
        assert_eq!(plain.stats.fast_path_hits, 2);
        assert_eq!(traced.stats.queued_ps_total, plain.stats.queued_ps_total);
    }

    /// The pre-fast-path algorithm: walk every send unconditionally.
    /// Kept test-local as the oracle the reservation fast path must be
    /// indistinguishable from.
    fn ref_send(
        mesh: &CMesh,
        free: &mut [Time],
        now: Time,
        from: u32,
        to: u32,
        bytes: u64,
    ) -> (Time, Time) {
        let route = mesh.route(from, to);
        let ser = bytes.div_ceil(FLIT_BYTES).max(1);
        let hold = ser * NOC_CYCLE_PS;
        let mut head = now;
        let mut queued: Time = 0;
        if route.len() == 1 {
            let p = port_index(mesh.side, route[0], LOCAL_PORT);
            head = claim(free, p, head, hold, &mut queued);
        } else {
            for w in route.windows(2) {
                let p = port_index(mesh.side, w[0], dir_of(w[0], w[1]));
                head = claim(free, p, head, hold, &mut queued);
            }
        }
        (head + hold, queued)
    }

    #[test]
    fn prop_fast_path_matches_always_walk_reference() {
        prop::check("fast path == full walk", 80, |g| {
            let tiles = g.usize_in(2, 256) as u32;
            let conc = *g.pick(&[1u32, 2, 4]);
            let mesh = CMesh::new(tiles, conc);
            let mut noc = NocModel::new(CMesh::new(tiles, conc));
            let slots =
                (mesh.side as usize) * (mesh.side as usize) * PORTS_PER_ROUTER;
            let mut free = vec![0u64; slots];
            let mut ref_queued_total: Time = 0;
            let mut now: Time = 0;
            for _ in 0..g.usize_in(2, 60) {
                // mix back-to-back sends (contended) with long idle
                // gaps (fast path re-arms)
                if g.bool() {
                    now += g.u64() % 60_000;
                }
                let a = g.usize_in(0, tiles as usize - 1) as u32;
                let b = g.usize_in(0, tiles as usize - 1) as u32;
                let bytes = g.usize_in(1, 512) as u64;
                let d = noc.send(now, a, b, bytes);
                let (arrive, queued) =
                    ref_send(&mesh, &mut free, now, a, b, bytes);
                ref_queued_total += queued;
                crate::prop_assert!(
                    d.arrive_ps == arrive && d.queued_ps == queued,
                    "send({now}, {a}->{b}, {bytes}B): fast ({}, {}) vs \
                     walk ({arrive}, {queued})",
                    d.arrive_ps, d.queued_ps
                );
            }
            crate::prop_assert!(
                noc.stats.queued_ps_total == ref_queued_total,
                "queued totals diverge: {} vs {ref_queued_total}",
                noc.stats.queued_ps_total
            );
            Ok(())
        });
    }
}
