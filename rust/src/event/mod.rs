//! Discrete-event microarchitecture simulator: the contention-aware
//! refinement of the analytical `sim/` model.
//!
//! The §5.2.4 analytical pipeline paces the chip by its slowest stage
//! and charges average-hop, contention-free NoC costs. That is enough
//! for Fig. 12's energy rankings but hides congestion and pipeline
//! stalls — and it can only produce a mean latency, never a
//! distribution. This subsystem rebuilds the same microarchitecture as
//! a deterministic discrete-event simulation:
//!
//! - [`engine`]: two-tier ladder event queue with slab-allocated
//!   payloads, stable FIFO tie-breaking, integer picosecond clock.
//! - [`refqueue`]: the pre-ladder binary-heap queue, retained as the
//!   differential-testing oracle behind the same [`EventQueue`] trait.
//! - [`noc`]: per-router/per-link occupancy on `arch::CMesh` XY routes
//!   (queueing instead of `transfer_latency_ns`'s contention-free
//!   formula; reduces to it exactly on an idle mesh, where a
//!   reservation fast path skips the route walk entirely).
//! - [`pipeline`]: tile-stage pipelines with finite IR/OR buffers and
//!   back-pressure from `mapping::NetworkMapping`, charging per-event
//!   energy from `energy::constants`.
//!
//! Two operating modes:
//!
//! 1. **Cross-validation** ([`cross_validate`]): replays the
//!    `sim::run_system_comparison` iso-area scenarios through the event
//!    model and checks total energy agrees within
//!    [`ENERGY_TOLERANCE`], while reporting the contention-induced
//!    latency delta the analytical model cannot see.
//! 2. **Request-level** ([`request_profile`]): Poisson request arrivals
//!    against replicated chip instances, yielding per-inference latency
//!    samples and p50/p95/p99 via `util::stats::percentile`. Replicas
//!    (optionally split further into engine shards — see
//!    [`RequestLoad::shards`]) fan out over `util::pool` on `Pcg::fork`
//!    streams derived sequentially up front, so every percentile is
//!    bit-identical at any `--threads` count.

pub mod engine;
pub mod noc;
pub mod pipeline;
pub mod refqueue;

pub use engine::{ns_to_ps, ps_to_s, Engine, EngineStats, Entry, EventQueue,
                 LadderQueue, QueueStats, Time};
pub use refqueue::BinaryHeapQueue;
pub use noc::{Delivery, NocModel, NocStats};
pub use pipeline::{hybrid_service_profile, service_profile, PipelineRun,
                   PipelineSim, ServiceProfile,
                   MAX_BUF_INFS};

use crate::config::{AcceleratorConfig, Architecture};
use crate::model;
use crate::obs::{NullRecorder, Recorder, Registry, TraceRecorder};
use crate::sim;
use crate::util::pool;
use crate::util::rng::{self, Pcg};
use crate::util::stats;
use crate::workloads::Network;
use std::sync::Arc;

/// Documented cross-validation tolerance on total energy per inference.
///
/// The event model charges the *same* per-layer compute/memory energy as
/// `sim::layer_energy` and differs only in the NoC term: actual XY hop
/// counts between stage home tiles instead of the analytical 1-hop
/// average. The divergence is therefore bounded by
/// `noc_share x (max hops - 1)`; with adjacent-stage placement the
/// measured gap is a few percent on the benchmark suite, and the event
/// total is never *below* the analytical one (hops are clamped to ≥ 1).
pub const ENERGY_TOLERANCE: f64 = 0.10;

/// Inferences replayed per scenario in cross-validation (energy per
/// inference is exact at any count — every job charges identically —
/// so a short replay suffices; latency uses the mean sojourn).
const CROSS_VALIDATION_JOBS: u64 = 4;

/// One scenario's analytical-vs-event comparison.
#[derive(Debug, Clone)]
pub struct CrossValidation {
    pub network: Arc<str>,
    pub arch: Architecture,
    pub analytical_energy_j: f64,
    pub event_energy_j: f64,
    /// |event - analytical| / analytical
    pub energy_rel_err: f64,
    pub analytical_latency_s: f64,
    /// mean per-inference sojourn through the event pipeline
    pub event_latency_s: f64,
    /// event minus analytical: interconnect + queueing the analytical
    /// model hides (never negative)
    pub contention_delta_s: f64,
    /// total head-flit NoC queueing across the replay
    pub noc_wait_s: f64,
    pub events: u64,
}

/// Replay every `sim::run_system_comparison` scenario (all networks x
/// all architectures, iso-area) through the event model. Scenarios fan
/// out over `util::pool`; each runs on its own engine, so results are
/// bit-identical at any thread count.
pub fn cross_validate(nets: &[Network]) -> Vec<CrossValidation> {
    let cmp = sim::run_system_comparison(nets);
    let scenarios: Vec<(&Network, &sim::SimResult)> = cmp
        .results
        .iter()
        .map(|r| {
            let net = nets
                .iter()
                .find(|n| n.name == r.network)
                .expect("scenario network missing from input set");
            (net, r)
        })
        .collect();
    pool::map(&scenarios, |&(net, r)| {
        cross_validate_one(net, r, cmp.reference_area)
    })
}

fn cross_validate_one(net: &Network, r: &sim::SimResult,
                      reference_area: f64) -> CrossValidation {
    // the same iso-area chip the analytical result was computed on; the
    // memoized cost table guarantees the event model sees the very
    // mapping and layer energies the analytical result was priced with
    let cfg = sim::iso_area_config(r.arch, reference_area);
    let nc = model::network_cost(net, &cfg);
    let mut ps = PipelineSim::with_costs(&cfg, &nc);
    let period = ps.bottleneck_period_ps().max(1);
    ps.inject_paced(CROSS_VALIDATION_JOBS, period);
    let run = ps.run();
    let event_latency_s = stats::mean(&run.latency_s);
    CrossValidation {
        network: r.network.clone(),
        arch: r.arch,
        analytical_energy_j: r.energy_per_inference,
        event_energy_j: run.energy_j_per_inference,
        energy_rel_err: (run.energy_j_per_inference - r.energy_per_inference)
            .abs()
            / r.energy_per_inference.max(1e-30),
        analytical_latency_s: r.latency_s,
        event_latency_s,
        contention_delta_s: event_latency_s - r.latency_s,
        noc_wait_s: run.noc_wait_s,
        events: run.engine.processed,
    }
}

/// Request-level load description.
#[derive(Debug, Clone)]
pub struct RequestLoad {
    /// total inferences across all replicas — served exactly: the first
    /// `requests % replicas` replicas take one extra job, and replicas
    /// beyond the request count serve none
    pub requests: u64,
    /// independent chip instances (one `Pcg::fork` stream each)
    pub replicas: usize,
    /// offered load as a fraction of the bottleneck service rate; see
    /// [`RequestLoad::utilization_clamped`] for the simulated range
    pub utilization: f64,
    pub seed: u64,
    /// engine shards per replica (min 1). Each shard is an independent
    /// pipeline instance of the same chip taking an equal slice of the
    /// replica's request stream, so one replica's simulation can spread
    /// over `shards` pool workers. Shard streams use the same
    /// sequential-up-front `Pcg::fork` discipline (fork index =
    /// `replica * shards + shard` inside `rng::FORK_NS_EVENT`), so any
    /// shard count is bit-identical
    /// at any `--threads`; `shards = 1` reproduces the unsharded
    /// numbers exactly. Sharding > 1 is a modeling choice — per-shard
    /// Poisson arrivals instead of one per-replica stream — not a pure
    /// reimplementation of it.
    pub shards: usize,
}

impl Default for RequestLoad {
    fn default() -> Self {
        RequestLoad {
            requests: 256,
            replicas: 4,
            utilization: 0.8,
            seed: 42,
            shards: 1,
        }
    }
}

impl RequestLoad {
    /// The utilization actually simulated: clamped to [0.05, 1.5] so
    /// the mean inter-arrival gap stays finite and the overload regime
    /// stays bounded. Everything that *labels* results (CLI/report
    /// tables) must print this, not the raw field.
    pub fn utilization_clamped(&self) -> f64 {
        self.utilization.clamp(0.05, 1.5)
    }
}

/// Tail-latency profile of one (network, config) under Poisson load.
#[derive(Debug, Clone)]
pub struct LatencyProfile {
    pub network: Arc<str>,
    pub arch: Architecture,
    pub requests: u64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
    pub energy_j_per_inference: f64,
    /// total head-flit NoC queueing across all replicas
    pub noc_wait_s: f64,
    /// start attempts deferred by finite-buffer back-pressure
    pub blocked_starts: u64,
    pub events: u64,
    /// past-scheduled events clamped to `now` across all engines
    /// (see [`EngineStats::clamped`]) — nonzero means a model bug
    pub clamped: u64,
    /// max resident-event high-water mark over all engines
    pub peak_queue: usize,
    /// per-run observability counters merged across replicas in
    /// (replica, shard) order — bit-identical at any `--threads`
    pub registry: Registry,
}

/// The warning surfaced when a profile reports clamped schedules.
/// Clamping exists as an engine-level guard (`Engine::schedule` refuses
/// to move time backwards); the pipeline model never triggers it, so a
/// nonzero count in a profile means a model bug, and every consumer
/// (event-sim's Outcome note, diagnostics) prints this one string.
pub fn clamped_warning(clamped: u64) -> Option<String> {
    if clamped == 0 {
        return None;
    }
    Some(format!(
        "WARNING: {clamped} event(s) scheduled into the past were clamped \
         to the current virtual time; latency percentiles may be skewed \
         (model bug — see EngineStats::clamped)"
    ))
}

/// Per-(replica, shard) work descriptors: `Pcg` streams forked
/// sequentially up front (the fork order, not the execution order,
/// defines the streams — same discipline as the noise MC) and job
/// counts that distribute `load.requests` exactly — first across
/// replicas (the first `requests % replicas` replicas take one extra
/// job), then each replica's count across its shards the same way —
/// so the served total always equals the ask at any shard count.
/// Zero-job shards still fork (stream assignment is positional) and
/// still run, keeping fork indices stable as counts change.
fn replica_inputs(load: &RequestLoad) -> Vec<(Pcg, u64)> {
    let replicas = load.replicas.max(1) as u64;
    let shards = load.shards.max(1) as u64;
    let base = load.requests / replicas;
    let extra = load.requests % replicas;
    let mut root = Pcg::new(load.seed);
    let mut inputs = Vec::with_capacity((replicas * shards) as usize);
    for r in 0..replicas {
        let rjobs = base + u64::from(r < extra);
        let sbase = rjobs / shards;
        let sextra = rjobs % shards;
        for s in 0..shards {
            inputs.push((
                root.fork(rng::fork_idx(rng::FORK_NS_EVENT, r * shards + s)),
                sbase + u64::from(s < sextra),
            ));
        }
    }
    inputs
}

fn run_replica<R: Recorder>(cfg: &AcceleratorConfig, nc: &model::NetworkCost,
                            load: &RequestLoad, input: &(Pcg, u64),
                            rec: R) -> (PipelineRun, R) {
    let (rng, jobs) = input;
    let mut rng = rng.clone();
    let mut ps = PipelineSim::with_costs(cfg, nc).with_recorder(rec);
    let mean_gap = ps.bottleneck_period_ps().max(1) as f64
        / load.utilization_clamped();
    ps.inject_poisson(*jobs, mean_gap, &mut rng);
    ps.run_traced()
}

fn profile_from_runs(net: &Network, cfg: &AcceleratorConfig,
                     runs: &[PipelineRun]) -> LatencyProfile {
    let lat: Vec<f64> = runs
        .iter()
        .flat_map(|r| r.latency_s.iter().copied())
        .collect();
    let total_jobs: u64 = runs.iter().map(|r| r.completed).sum();
    let total_energy: f64 = runs.iter().map(|r| r.energy_j_total).sum();
    // (replica, shard) order is the merge order — determinism contract
    let mut registry = Registry::new();
    for r in runs {
        registry.merge(&r.registry);
    }
    LatencyProfile {
        network: net.name.clone(),
        arch: cfg.arch,
        requests: total_jobs,
        p50_s: stats::percentile(&lat, 50.0),
        p95_s: stats::percentile(&lat, 95.0),
        p99_s: stats::percentile(&lat, 99.0),
        mean_s: stats::mean(&lat),
        // stats::max of nothing is the fold identity (-inf); report 0
        // like the percentiles do
        max_s: if lat.is_empty() { 0.0 } else { stats::max(&lat) },
        energy_j_per_inference: total_energy / (total_jobs as f64).max(1.0),
        noc_wait_s: runs.iter().map(|r| r.noc_wait_s).sum(),
        blocked_starts: runs.iter().map(|r| r.blocked_starts).sum(),
        events: runs.iter().map(|r| r.engine.processed).sum(),
        clamped: runs.iter().map(|r| r.engine.clamped).sum(),
        peak_queue: runs.iter().map(|r| r.engine.peak_queue).max().unwrap_or(0),
        registry,
    }
}

/// Sample per-inference latencies under Poisson arrivals and reduce to
/// percentiles. Replica shards fan out across `util::pool` sharing one
/// memoized [`model::network_cost`] table (the hot-path win: layers are
/// priced once, not once per replica); aggregation is in (replica,
/// shard) order, so the profile is bit-identical at `--threads
/// 1/2/8/...`. Serves exactly `load.requests` inferences.
pub fn request_profile(net: &Network, cfg: &AcceleratorConfig,
                       load: &RequestLoad) -> LatencyProfile {
    let nc = model::network_cost(net, cfg);
    let inputs = replica_inputs(load);
    let runs = pool::map(&inputs, |input| {
        run_replica(cfg, &nc, load, input, NullRecorder).0
    });
    profile_from_runs(net, cfg, &runs)
}

/// [`request_profile`] with the replicas run on the calling thread —
/// bit-identical to the pooled version (the pool reassembles by index).
/// For callers that are themselves items of a `pool::map` fan-out
/// (e.g. the per-scenario latency table). The persistent pool would
/// inline a nested `map` anyway (`pool::on_worker`); `map_with(1, ..)`
/// states the sequential intent explicitly and holds on callers that
/// are not pool tasks.
pub fn request_profile_sequential(net: &Network, cfg: &AcceleratorConfig,
                                  load: &RequestLoad) -> LatencyProfile {
    let nc = model::network_cost(net, cfg);
    let inputs = replica_inputs(load);
    // map_with(1, ..) short-circuits to an inline sequential map — one
    // shared body with the pooled variant, same results by contract
    let runs = pool::map_with(1, &inputs, |input| {
        run_replica(cfg, &nc, load, input, NullRecorder).0
    });
    profile_from_runs(net, cfg, &runs)
}

/// [`request_profile`] with a live [`TraceRecorder`] per (replica,
/// shard), absorbed into one combined trace in fork order under
/// `r{replica}s{shard}/` track prefixes. Results (and the absorbed
/// trace, and the merged registry) are bit-identical at any
/// `--threads`: each shard records only its own virtual timeline and
/// the absorb order is the input order, not the completion order.
/// Tracing forces the NoC route walk (see `NocModel::send_rec`), which
/// is result-identical to the idle fast path by construction — only
/// `NocStats::fast_path_hits` differs from an untraced run.
pub fn request_profile_traced(net: &Network, cfg: &AcceleratorConfig,
                              load: &RequestLoad, filter: Option<&str>)
                              -> (LatencyProfile, TraceRecorder) {
    let nc = model::network_cost(net, cfg);
    let inputs = replica_inputs(load);
    let traced = pool::map(&inputs, |input| {
        run_replica(cfg, &nc, load, input, TraceRecorder::with_filter(filter))
    });
    assemble_traced(net, cfg, load, traced)
}

/// [`request_profile_traced`] run on the calling thread — same results
/// by the `pool::map_with(1, ..)` contract; the determinism tests pin
/// the two against each other byte-for-byte.
pub fn request_profile_traced_sequential(
    net: &Network, cfg: &AcceleratorConfig, load: &RequestLoad,
    filter: Option<&str>) -> (LatencyProfile, TraceRecorder) {
    let nc = model::network_cost(net, cfg);
    let inputs = replica_inputs(load);
    let traced = pool::map_with(1, &inputs, |input| {
        run_replica(cfg, &nc, load, input, TraceRecorder::with_filter(filter))
    });
    assemble_traced(net, cfg, load, traced)
}

fn assemble_traced(net: &Network, cfg: &AcceleratorConfig,
                   load: &RequestLoad,
                   traced: Vec<(PipelineRun, TraceRecorder)>)
                   -> (LatencyProfile, TraceRecorder) {
    let shards = load.shards.max(1);
    let mut combined = TraceRecorder::new();
    let mut runs = Vec::with_capacity(traced.len());
    for (i, (run, rec)) in traced.into_iter().enumerate() {
        combined.absorb(&format!("r{}s{}/", i / shards, i % shards), rec);
        runs.push(run);
    }
    (profile_from_runs(net, cfg, &runs), combined)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn cross_validation_holds_on_alexnet_all_archs() {
        let nets = vec![workloads::alexnet()];
        let rows = cross_validate(&nets);
        // one scenario per registered architecture — the LowResolution
        // arch rides through with no event-layer edits
        assert_eq!(rows.len(), model::archs().len());
        for r in &rows {
            assert!(
                r.energy_rel_err <= ENERGY_TOLERANCE,
                "{}/{:?}: rel err {} (event {} vs analytical {})",
                r.network, r.arch, r.energy_rel_err, r.event_energy_j,
                r.analytical_energy_j
            );
            // the event refinement only ADDS hop energy, never removes
            assert!(
                r.event_energy_j >= r.analytical_energy_j * (1.0 - 1e-9),
                "{}/{:?}: event below analytical", r.network, r.arch
            );
            // and interconnect + queueing only add latency
            assert!(
                r.contention_delta_s >= -1e-15,
                "{}/{:?}: negative contention delta {}",
                r.network, r.arch, r.contention_delta_s
            );
            assert!(r.events > 0);
        }
    }

    #[test]
    fn request_profile_percentiles_are_ordered() {
        let net = workloads::alexnet();
        let cfg = AcceleratorConfig::neural_pim();
        let load =
            RequestLoad { requests: 48, replicas: 3, ..Default::default() };
        let p = request_profile(&net, &cfg, &load);
        assert_eq!(p.requests, 48);
        assert!(p.p50_s > 0.0);
        assert!(p.p50_s <= p.p95_s && p.p95_s <= p.p99_s);
        assert!(p.p99_s <= p.max_s + 1e-18);
        assert!(p.mean_s >= p.p50_s * 0.1 && p.mean_s <= p.max_s);
        assert!(p.energy_j_per_inference > 0.0);
    }

    #[test]
    fn heavier_load_has_heavier_tail() {
        let net = workloads::alexnet();
        let cfg = AcceleratorConfig::neural_pim();
        let lo = request_profile(&net, &cfg, &RequestLoad {
            requests: 64, replicas: 2, utilization: 0.3, seed: 5, shards: 1,
        });
        let hi = request_profile(&net, &cfg, &RequestLoad {
            requests: 64, replicas: 2, utilization: 1.2, seed: 5, shards: 1,
        });
        // an overloaded pipeline must queue: p99 grows
        assert!(
            hi.p99_s > lo.p99_s,
            "p99 lo {} vs hi {}", lo.p99_s, hi.p99_s
        );
    }

    #[test]
    fn sharded_profile_conserves_requests_and_is_deterministic() {
        let net = workloads::alexnet();
        let cfg = AcceleratorConfig::neural_pim();
        // 50 jobs over 3 replicas x 4 shards: uneven at both levels
        let load = RequestLoad {
            requests: 50, replicas: 3, shards: 4, ..Default::default()
        };
        let a = request_profile(&net, &cfg, &load);
        assert_eq!(a.requests, 50, "sharding must not drop or invent jobs");
        assert!(a.p50_s > 0.0 && a.p50_s <= a.p99_s);
        assert_eq!(a.clamped, 0, "pipeline never schedules into the past");
        assert!(a.peak_queue > 0);
        let b = request_profile_sequential(&net, &cfg, &load);
        // pooled and sequential fan-outs share the contract: identical
        assert_eq!(a.p99_s.to_bits(), b.p99_s.to_bits());
        assert_eq!(a.energy_j_per_inference.to_bits(),
                   b.energy_j_per_inference.to_bits());
    }

    #[test]
    fn clamped_warning_fires_only_on_nonzero_counts() {
        assert_eq!(clamped_warning(0), None);
        let w = clamped_warning(3).expect("nonzero count must warn");
        assert!(w.contains("WARNING") && w.contains('3'), "{w}");
    }

    #[test]
    fn traced_profile_matches_plain_and_carries_a_registry() {
        let net = workloads::alexnet();
        let cfg = AcceleratorConfig::neural_pim();
        let load = RequestLoad {
            requests: 12, replicas: 2, shards: 2, ..Default::default()
        };
        let plain = request_profile(&net, &cfg, &load);
        let (traced, trace) = request_profile_traced(&net, &cfg, &load, None);
        // tracing must not perturb results (bit-identical latencies)
        assert_eq!(plain.p99_s.to_bits(), traced.p99_s.to_bits());
        assert_eq!(plain.energy_j_per_inference.to_bits(),
                   traced.energy_j_per_inference.to_bits());
        assert_eq!(plain.events, traced.events);
        // every (replica, shard) contributes under its own prefix
        assert!(!trace.is_empty());
        for prefix in ["r0s0/", "r0s1/", "r1s0/", "r1s1/"] {
            assert!(
                trace.tracks().iter().any(|t| t.starts_with(prefix)),
                "missing {prefix} tracks in {:?}", trace.tracks()
            );
        }
        // the registry rides along on both paths, identically — except
        // the documented fast-path counter, which tracing suppresses
        // (live recorders force the route walk, so traced hits are 0)
        assert!(!plain.registry.is_empty());
        assert_eq!(traced.registry.counter("noc.fast_path_hits"), 0);
        let mut traced_reg = traced.registry.clone();
        traced_reg.add("noc.fast_path_hits",
                       plain.registry.counter("noc.fast_path_hits"));
        assert_eq!(traced_reg.snapshot_string(),
                   plain.registry.snapshot_string());
        assert_eq!(plain.registry.counter("pipeline.completed"), 12);
    }

    #[test]
    fn shard_job_split_is_exact_and_fork_stable() {
        let inputs = replica_inputs(&RequestLoad {
            requests: 11, replicas: 2, shards: 3, ..Default::default()
        });
        assert_eq!(inputs.len(), 6);
        let jobs: Vec<u64> = inputs.iter().map(|(_, j)| *j).collect();
        // replica 0 takes 6 (2+2+2), replica 1 takes 5 (2+2+1)
        assert_eq!(jobs, vec![2, 2, 2, 2, 2, 1]);
        // shards = 1 walks the namespaced fork indices 0..replicas in
        // order inside the event window
        let unsharded = replica_inputs(&RequestLoad {
            requests: 11, replicas: 2, shards: 1, ..Default::default()
        });
        let mut root = Pcg::new(RequestLoad::default().seed);
        for (i, (stream, _)) in unsharded.iter().enumerate() {
            let mut want =
                root.fork(rng::fork_idx(rng::FORK_NS_EVENT, i as u64));
            let mut got = stream.clone();
            assert_eq!(want.next_u64(), got.next_u64());
        }
    }
}
