//! Reference event-queue backend: the pre-ladder binary heap, retained
//! verbatim as the differential-testing oracle for [`LadderQueue`].
//!
//! This is deliberately the *only* module in `event/` allowed to touch
//! `std::collections::BinaryHeap` (verify.sh greps for strays): the hot
//! path must go through the ladder, and any future queue change has to
//! prove itself against this oracle — identical pop traces, identical
//! stats (including the multi-tier `peak_queue` high-water mark) — on
//! Pcg-seeded workloads mixing bursty clusters, same-time storms,
//! past-clamped pushes, and far-future tails.
//!
//! [`LadderQueue`]: super::engine::LadderQueue

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::engine::{Entry, EventQueue};

/// `(time, seq)`-ordered min-heap over [`Entry`]. O(log n) per
/// operation vs the ladder's amortized O(1), but with no bucketing
/// assumptions at all — which is exactly what makes it a trustworthy
/// oracle.
#[derive(Default)]
pub struct BinaryHeapQueue {
    heap: BinaryHeap<Reverse<Entry>>,
}

impl EventQueue for BinaryHeapQueue {
    fn push(&mut self, e: Entry) {
        self.heap.push(Reverse(e));
    }

    fn pop(&mut self) -> Option<Entry> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{Engine, EngineStats, LadderQueue, Time};
    use super::*;
    use crate::util::num::{fnv1a64_step, FNV1A64_OFFSET};
    use crate::util::prop;

    /// One step of a queue workload, relative to the engine clock at
    /// execution time (so the same script drives any backend).
    #[derive(Debug, Clone, Copy)]
    enum Op {
        /// schedule `delay` ps from now
        PushIn(Time),
        /// schedule `d` ps *behind* now (exercises clamp counting)
        PushPast(Time),
        /// pop up to `n` events
        Pop(u32),
    }

    fn drive<Q: EventQueue + Default>(ops: &[Op]) -> (Vec<(Time, u32)>, EngineStats) {
        let mut eng: Engine<u32, Q> = Engine::new();
        let mut trace = Vec::new();
        let mut id: u32 = 0;
        for op in ops {
            match *op {
                Op::PushIn(d) => {
                    eng.schedule_in(d, id);
                    id += 1;
                }
                Op::PushPast(d) => {
                    eng.schedule_at(eng.now().saturating_sub(d), id);
                    id += 1;
                }
                Op::Pop(n) => {
                    for _ in 0..n {
                        if let Some(p) = eng.pop() {
                            trace.push(p);
                        }
                    }
                }
            }
        }
        while let Some(p) = eng.pop() {
            trace.push(p);
        }
        (trace, eng.stats)
    }

    #[test]
    fn prop_ladder_matches_binary_heap_reference() {
        prop::check("ladder == reference pop trace", 60, |g| {
            let steps = g.usize_in(20, 120);
            let mut ops = Vec::new();
            for _ in 0..steps {
                match g.usize_in(0, 4) {
                    0 => {
                        // bursty near-future cluster
                        for _ in 0..g.usize_in(1, 40) {
                            ops.push(Op::PushIn(g.u64() % 4_096));
                        }
                    }
                    1 => {
                        // same-time storm: FIFO tie-break must hold
                        let at = g.u64() % 100_000;
                        for _ in 0..g.usize_in(2, 64) {
                            ops.push(Op::PushIn(at));
                        }
                    }
                    2 => ops.push(Op::PushIn(g.u64() % (1 << 45))), // far tail
                    3 => ops.push(Op::PushPast(g.u64() % 1_000)),
                    _ => ops.push(Op::Pop(g.usize_in(1, 32) as u32)),
                }
            }
            let (lt, ls) = drive::<LadderQueue>(&ops);
            let (bt, bs) = drive::<BinaryHeapQueue>(&ops);
            let first_diff = lt.iter().zip(&bt).position(|(a, b)| a != b);
            crate::prop_assert!(
                lt == bt,
                "pop traces diverge (len {} vs {}, first diff at {:?})",
                lt.len(),
                bt.len(),
                first_diff
            );
            crate::prop_assert!(ls == bs, "stats diverge: {:?} vs {:?}", ls, bs);
            Ok(())
        });
    }

    #[test]
    fn peak_queue_matches_reference_across_tiers() {
        // Residents spread over current bucket, window, and overflow:
        // the ladder's high-water mark must equal the reference's
        // (which trivially counts everything in one heap).
        let ops = [
            Op::PushIn(0),
            Op::PushIn(10),
            Op::PushIn(5_000),
            Op::PushIn(1 << 44),
            Op::Pop(2),
            Op::PushIn(3),
            Op::Pop(16),
        ];
        let (lt, ls) = drive::<LadderQueue>(&ops);
        let (bt, bs) = drive::<BinaryHeapQueue>(&ops);
        assert_eq!(lt, bt);
        assert_eq!(ls, bs);
        assert_eq!(ls.peak_queue, 4);
    }

    /// Golden checksum over a fixed LCG-driven workload's pop trace,
    /// pinning the `(time, seq)` pop order — FIFO tie-breaks included
    /// (every 8th event reuses the previous time) — against silent
    /// reordering in either backend. The constant is FNV-1a over the
    /// little-endian `(time, payload)` bytes of the full trace.
    fn trace_checksum<Q: EventQueue + Default>() -> u64 {
        let mut eng: Engine<u64, Q> = Engine::new();
        let mut x: u64 = 0x00c0_ffee;
        let mut t_prev: Time = 0;
        for i in 0..4_096u64 {
            x = x
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407);
            let t = if i % 8 == 7 { t_prev } else { x >> 44 };
            t_prev = t;
            eng.schedule_at(t, i);
        }
        let mut h = FNV1A64_OFFSET;
        while let Some((t, ev)) = eng.pop() {
            for b in t.to_le_bytes() {
                h = fnv1a64_step(h, b);
            }
            for b in ev.to_le_bytes() {
                h = fnv1a64_step(h, b);
            }
        }
        h
    }

    #[test]
    fn golden_trace_checksum_pins_fifo_tie_break_order() {
        const GOLDEN: u64 = 0x99ec_1704_0f20_962b;
        assert_eq!(trace_checksum::<LadderQueue>(), GOLDEN);
        assert_eq!(trace_checksum::<BinaryHeapQueue>(), GOLDEN);
    }
}
