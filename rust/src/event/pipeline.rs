//! Tile-stage pipeline with finite inter-stage buffers, NoC transport,
//! and back-pressure, layered on `mapping::NetworkMapping`.
//!
//! Stage `i` is layer `i`'s replicated array group: a deterministic
//! service time of `stage_cycles(ic) x t_cycle x 9/8` — the same §5.2.4
//! pacing the analytical simulator uses — serving one inference at a
//! time. Between stage `i` and `i+1` sits a finite buffer
//! ([`NetworkMapping::buffer_capacity_infs`]: the consumer's eDRAM
//! budget, clamped to `[1, MAX_BUF_INFS]` whole inferences). A stage
//! only starts a job when the downstream buffer has a free slot
//! (blocking-before-service), which is exactly the back-pressure the
//! slowest-stage analytical model cannot express. Stage outputs travel
//! tile-to-tile over the contention-aware [`NocModel`]; the last stage
//! egresses to tile 0 (the chip's I/O corner).
//!
//! Energy is charged per event: when a stage completes a job it charges
//! its layer's memoized `model::LayerCost::compute_e` (the compute/
//! memory share, identical to the analytical model's
//! `layer_energy total() - noc`), and every NoC delivery charges
//! `CMesh::transfer_energy` with the transfer's *actual* hop count —
//! replacing the analytical 1-hop average. HyperTransport is charged per
//! transfer on multi-chip mappings (`LayerCost::noc_e_extra`). The cost
//! table is built once per `(network, config)` and shared by every
//! replica — the pre-`model` code re-priced all layers per instance on
//! the request path.

use super::engine::{ns_to_ps, ps_to_s, Engine, EngineStats, LadderQueue,
                    Time};
use super::noc::{NocModel, NOC_CYCLE_PS};
use crate::arch::noc::CMesh;
use crate::config::{AcceleratorConfig, Architecture};
use crate::energy;
use crate::mapping::{LayerMapping, NetworkMapping};
use crate::model::{self, LayerCost, NetworkCost};
use crate::obs::{Hist, NullRecorder, Recorder, Registry};
use crate::util::rng::Pcg;
use crate::workloads::Network;
use std::collections::VecDeque;

/// Queue-depth counter sampling stride under a live recorder: one
/// `engine.queue_depth` sample every this many pops keeps traces small
/// while still showing the depth timeline.
const QUEUE_SAMPLE_STRIDE: u64 = 64;

/// Upper clamp on inter-stage buffer depth, in whole inferences: the
/// IR/OR SRAMs stage only a handful of inference outputs even when a
/// layer's output is tiny.
pub const MAX_BUF_INFS: u64 = 8;

/// Deterministic service time of one pipeline stage: the layer's
/// stage-cycle occupancy at the chip's cycle time with the §5.2.4
/// integer 9/8 two-phase overhead (exact for the 100/50 ns cycles —
/// `cycle_ps` is a multiple of 8 ps). The single pacing formula shared
/// by [`PipelineSim`] and [`service_profile`].
fn stage_service_ps(lm: &LayerMapping, ic: u64, cycle_ps: Time) -> Time {
    ((lm.stage_cycles(ic) as u128 * cycle_ps as u128 * 9) / 8) as Time
}

/// The pipeline's deterministic per-stage service times — the
/// service-time hook the `serve` layer prices simulated batches with
/// (the same pacing [`PipelineSim`] schedules by, minus NoC/buffer
/// dynamics).
#[derive(Debug, Clone)]
pub struct ServiceProfile {
    /// per-stage service time, in layer order (integer picoseconds)
    pub stage_ps: Vec<Time>,
}

impl ServiceProfile {
    /// Pipeline fill: one inference front-to-back with no overlap.
    pub fn fill_ps(&self) -> Time {
        self.stage_ps.iter().sum()
    }

    /// Steady-state pacing: the slowest stage (≥ 1 ps so rates stay
    /// finite on degenerate mappings).
    pub fn bottleneck_ps(&self) -> Time {
        self.stage_ps.iter().copied().max().unwrap_or(0).max(1)
    }

    /// A batch of `n` inferences streamed through the pipeline: fill for
    /// the first, one bottleneck period for each that follows.
    pub fn batch_ps(&self, n: u64) -> Time {
        self.fill_ps() + n.saturating_sub(1) * self.bottleneck_ps()
    }

    /// [`ServiceProfile::batch_ps`] in whole microseconds (≥ 1), the
    /// unit the serving metrics speak.
    pub fn batch_us(&self, n: u64) -> u64 {
        self.batch_ps(n).div_ceil(1_000_000).max(1)
    }
}

/// Compute the [`ServiceProfile`] of `cfg` over a memoized cost table's
/// mapping (`model::network_cost`). Pure and deterministic: safe to
/// share across threads and cache keys.
pub fn service_profile(cfg: &AcceleratorConfig,
                       nc: &NetworkCost) -> ServiceProfile {
    let ic = cfg.precision.input_cycles() as u64;
    let cycle_ps = ns_to_ps(energy::cycle_seconds(cfg) * 1e9);
    ServiceProfile {
        stage_ps: nc
            .mapping
            .layers
            .iter()
            .map(|lm| stage_service_ps(lm, ic, cycle_ps))
            .collect(),
    }
}

/// The [`ServiceProfile`] of a PIM + NPU hybrid placement: stage `i`
/// takes its service time from whichever side `placement[i]` names,
/// each priced under its own config's pacing (input cycles, cycle time)
/// and its own pure mapping's replication. The `offload` subsystem
/// reports pipeline shape through this.
pub fn hybrid_service_profile(cfg_pim: &AcceleratorConfig,
                              pim: &NetworkCost,
                              cfg_npu: &AcceleratorConfig,
                              npu: &NetworkCost,
                              placement: &[crate::mapping::Placement])
                              -> ServiceProfile {
    assert_eq!(pim.mapping.layers.len(), npu.mapping.layers.len(),
               "hybrid sides must map the same network");
    assert_eq!(placement.len(), pim.mapping.layers.len());
    let sp_pim = service_profile(cfg_pim, pim);
    let sp_npu = service_profile(cfg_npu, npu);
    ServiceProfile {
        stage_ps: placement
            .iter()
            .enumerate()
            .map(|(i, pl)| {
                if pl.is_npu() {
                    sp_npu.stage_ps[i]
                } else {
                    sp_pim.stage_ps[i]
                }
            })
            .collect(),
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// a new inference enters stage 0's admission queue
    Arrive { job: u32 },
    /// stage finished computing a job; output goes on the NoC
    StageDone { stage: u16, job: u32 },
    /// a job's activations landed in `stage`'s input buffer
    Deliver { stage: u16, job: u32 },
}

struct Stage {
    service_ps: Time,
    tile: u32,
    /// per-job compute+memory energy (layer energy minus its NoC share)
    compute_e: f64,
    /// per-transfer HyperTransport charge on multi-chip mappings
    noc_e_extra: f64,
    out_bytes: u64,
    /// scheduled A/D conversions per job (`LayerCost::adc_convs`, the
    /// Eq. 5/6/7 dataflow count for this layer)
    adc_convs: u64,
    /// shift-and-add operations per job (`LayerCost::sa_ops`)
    sa_ops: u64,
    /// jobs delivered and waiting for service (FIFO); length ≤ capacity
    queue: VecDeque<u32>,
    busy: bool,
}

/// One simulated chip instance, generic over the tracing hook: the
/// default [`NullRecorder`] monomorphizes every `rec.is_enabled()`
/// guard to a constant `false`, so the untraced pipeline compiles to
/// the pre-observability code (budgeted in `perf_hotpath --only-obs`).
pub struct PipelineSim<R: Recorder = NullRecorder> {
    engine: Engine<Ev>,
    noc: NocModel,
    stages: Vec<Stage>,
    /// credits[i]: free slots in stage i's input buffer (i ≥ 1; stage
    /// 0's admission queue is unbounded — it models the host request
    /// stream). A producer reserves a slot when it STARTS a job, so a
    /// finished output always has somewhere to land.
    credits: Vec<u64>,
    arrival_ps: Vec<Time>,
    done_ps: Vec<Time>,
    energy_j: f64,
    blocked_starts: u64,
    egress_tile: u32,
    /// which cost model priced the stages — keys the per-arch
    /// conversion counters in the registry
    arch: Architecture,
    /// running totals across completed stage services
    adc_convs: u64,
    sa_ops: u64,
    /// per-delivery head-flit queueing distribution (ps, log2 buckets)
    queued_hist: Hist,
    rec: R,
}

/// Everything a finished run reports.
#[derive(Debug, Clone)]
pub struct PipelineRun {
    pub completed: u64,
    /// sim time of the last egress
    pub makespan_s: f64,
    pub energy_j_total: f64,
    pub energy_j_per_inference: f64,
    /// per-job sojourn time (arrival -> egress), in job order
    pub latency_s: Vec<f64>,
    pub noc: super::noc::NocStats,
    pub engine: EngineStats,
    /// start attempts deferred by downstream back-pressure
    pub blocked_starts: u64,
    /// total head-flit NoC queueing across the run
    pub noc_wait_s: f64,
    /// total scheduled A/D conversions (per-arch dataflow count × jobs)
    pub adc_convs: u64,
    /// total shift-and-add operations
    pub sa_ops: u64,
    /// every counter/gauge/histogram of the run, keyed
    /// `engine.*`/`noc.*`/`pipeline.*`/`adc.*`/`sa.*` — per-arch
    /// conversion counters carry the architecture name
    pub registry: Registry,
}

impl PipelineSim<NullRecorder> {
    /// Map `net` on `cfg` and build the event model from the memoized
    /// [`model::network_cost`] table — replicas and repeated runs of the
    /// same `(network, config)` pair share one layer-cost table instead
    /// of re-pricing every layer per instance.
    pub fn new(net: &Network, cfg: &AcceleratorConfig) -> PipelineSim {
        let nc = model::network_cost(net, cfg);
        Self::with_costs(cfg, &nc)
    }

    /// Build from a cost table the caller already holds (the memoized
    /// fast path: `request_profile` fetches it once and fans replicas
    /// out over it).
    pub fn with_costs(cfg: &AcceleratorConfig, nc: &NetworkCost)
                      -> PipelineSim {
        Self::build(cfg, &nc.mapping, &nc.layers)
    }

    /// Build from a bare mapping the caller computed (hand-built layer
    /// chains in tests); prices the layers directly, uncached — the
    /// values are identical to the memoized path by construction.
    pub fn with_mapping(cfg: &AcceleratorConfig, m: &NetworkMapping)
                        -> PipelineSim {
        let multi_chip = m.chips > 1;
        let costs: Vec<LayerCost> = m
            .layers
            .iter()
            .map(|lm| model::layer_cost(lm, cfg, multi_chip))
            .collect();
        Self::build(cfg, m, &costs)
    }

    fn build(cfg: &AcceleratorConfig, m: &NetworkMapping,
             costs: &[LayerCost]) -> PipelineSim {
        assert!(!m.layers.is_empty(), "empty network");
        assert_eq!(m.layers.len(), costs.len(), "cost table arity");
        let ic = cfg.precision.input_cycles() as u64;
        let cycle_ps = ns_to_ps(energy::cycle_seconds(cfg) * 1e9);
        let tiles = m.layer_tiles(cfg);
        let stages: Vec<Stage> = m
            .layers
            .iter()
            .zip(costs)
            .zip(&tiles)
            .map(|((lm, cost), &tile)| {
                Stage {
                    service_ps: stage_service_ps(lm, ic, cycle_ps),
                    tile,
                    compute_e: cost.compute_e,
                    noc_e_extra: cost.noc_e_extra,
                    out_bytes: lm.out_bytes(),
                    adc_convs: cost.adc_convs,
                    sa_ops: cost.sa_ops,
                    queue: VecDeque::new(),
                    busy: false,
                }
            })
            .collect();
        let mut credits = vec![0u64; stages.len()];
        for (s, c) in credits.iter_mut().enumerate().skip(1) {
            *c = m.buffer_capacity_infs(s, cfg.edram_bytes, MAX_BUF_INFS);
        }
        PipelineSim {
            // the NoC cycle is the natural floor for the ladder's
            // bucket width: no event resolution below it matters, and
            // the queue skips the fine-granularity warm-up
            engine: Engine::with_queue(LadderQueue::with_granularity(
                NOC_CYCLE_PS,
            )),
            noc: NocModel::new(CMesh::new(cfg.tiles, cfg.noc_concentration)),
            stages,
            credits,
            arrival_ps: Vec::new(),
            done_ps: Vec::new(),
            energy_j: 0.0,
            blocked_starts: 0,
            egress_tile: 0,
            arch: cfg.arch,
            adc_convs: 0,
            sa_ops: 0,
            queued_hist: Hist::new(),
            rec: NullRecorder,
        }
    }
}

impl<R: Recorder> PipelineSim<R> {
    /// Swap in a tracing recorder (typically an
    /// `obs::TraceRecorder`) — builders stay on the null path, so the
    /// traced pipeline is opted into per run.
    pub fn with_recorder<R2: Recorder>(self, rec: R2) -> PipelineSim<R2> {
        PipelineSim {
            engine: self.engine,
            noc: self.noc,
            stages: self.stages,
            credits: self.credits,
            arrival_ps: self.arrival_ps,
            done_ps: self.done_ps,
            energy_j: self.energy_j,
            blocked_starts: self.blocked_starts,
            egress_tile: self.egress_tile,
            arch: self.arch,
            adc_convs: self.adc_convs,
            sa_ops: self.sa_ops,
            queued_hist: self.queued_hist,
            rec,
        }
    }

    /// The steady-state pacing of the pipeline: the slowest stage.
    pub fn bottleneck_period_ps(&self) -> Time {
        self.stages.iter().map(|s| s.service_ps).max().unwrap_or(0)
    }

    fn inject(&mut self, at: Time) {
        let job = self.arrival_ps.len() as u32;
        self.arrival_ps.push(at);
        self.done_ps.push(Time::MAX);
        self.engine.schedule_at(at, Ev::Arrive { job });
    }

    /// Inject `jobs` inferences at a fixed inter-arrival `period_ps`
    /// (the cross-validation feed: the pipeline's own steady rate).
    pub fn inject_paced(&mut self, jobs: u64, period_ps: Time) {
        for j in 0..jobs {
            self.inject(j * period_ps);
        }
    }

    /// Inject `jobs` inferences with exponential inter-arrival gaps of
    /// mean `mean_gap_ps` (the request-level mode). Deterministic per
    /// `rng` stream — fork one per replica *before* fanning out.
    pub fn inject_poisson(&mut self, jobs: u64, mean_gap_ps: f64,
                          rng: &mut Pcg) {
        let mut t: Time = 0;
        for _ in 0..jobs {
            let u = rng.uniform();
            let gap = (-mean_gap_ps * (1.0 - u).max(f64::MIN_POSITIVE).ln())
                .round() as Time;
            t += gap;
            self.inject(t);
        }
    }

    /// Start the head-of-queue job on `s` if the stage is idle and the
    /// downstream buffer can take its output. Starting frees our own
    /// input slot, which may unblock the upstream stage (recursively).
    fn try_start(&mut self, s: usize) {
        if self.stages[s].busy || self.stages[s].queue.is_empty() {
            return;
        }
        if s + 1 < self.stages.len() && self.credits[s + 1] == 0 {
            self.blocked_starts += 1;
            if self.rec.is_enabled() {
                self.rec.instant(
                    self.engine.now(),
                    &stage_track(s, self.stages[s].tile),
                    "stage.blocked",
                );
            }
            return;
        }
        let job = self.stages[s].queue.pop_front().unwrap();
        if s + 1 < self.stages.len() {
            self.credits[s + 1] -= 1; // reserve the landing slot
        }
        self.stages[s].busy = true;
        let done = self.engine.now() + self.stages[s].service_ps;
        self.engine.schedule_at(done, Ev::StageDone { stage: s as u16, job });
        if s > 0 {
            self.credits[s] += 1; // our input slot is free again
            self.try_start(s - 1);
        }
    }

    fn handle(&mut self, now: Time, ev: Ev) {
        match ev {
            Ev::Arrive { job } => {
                self.stages[0].queue.push_back(job);
                self.try_start(0);
            }
            Ev::Deliver { stage, job } => {
                let s = stage as usize;
                self.stages[s].queue.push_back(job);
                self.try_start(s);
            }
            Ev::StageDone { stage, job } => {
                let s = stage as usize;
                self.stages[s].busy = false;
                self.energy_j += self.stages[s].compute_e;
                self.adc_convs += self.stages[s].adc_convs;
                self.sa_ops += self.stages[s].sa_ops;
                let from = self.stages[s].tile;
                let bytes = self.stages[s].out_bytes;
                let last = s + 1 >= self.stages.len();
                let to = if last {
                    self.egress_tile
                } else {
                    self.stages[s + 1].tile
                };
                if self.rec.is_enabled() {
                    // the service that just ended: occupancy span
                    let service = self.stages[s].service_ps;
                    self.rec.span(
                        now - service,
                        service,
                        &stage_track(s, from),
                        "stage.serve",
                    );
                }
                let d = self.noc.send_rec(now, from, to, bytes, &mut self.rec);
                self.energy_j += d.energy_j + self.stages[s].noc_e_extra;
                self.queued_hist.observe(d.queued_ps);
                if last {
                    self.done_ps[job as usize] = d.arrive_ps;
                } else {
                    self.engine.schedule_at(
                        d.arrive_ps,
                        Ev::Deliver { stage: (s + 1) as u16, job },
                    );
                }
                self.try_start(s);
            }
        }
    }

    /// Drain every event and summarize. All injected jobs complete (the
    /// credit scheme cannot deadlock: the last stage never blocks, so
    /// every blocked chain unwinds from the back).
    pub fn run(self) -> PipelineRun {
        self.run_traced().0
    }

    /// [`PipelineSim::run`] returning the recorder too, for callers
    /// that merge per-replica traces (`event::request_profile_traced`).
    pub fn run_traced(mut self) -> (PipelineRun, R) {
        let tracing = self.rec.is_enabled();
        let mut pops: u64 = 0;
        let mut rebases_seen: u64 = 0;
        while let Some((t, ev)) = self.engine.pop() {
            if tracing {
                pops += 1;
                if pops % QUEUE_SAMPLE_STRIDE == 0 {
                    self.rec.sample(
                        t,
                        "engine.queue_depth",
                        self.engine.pending() as f64,
                    );
                }
                let rebases = self.engine.queue_stats().rebases;
                if rebases > rebases_seen {
                    rebases_seen = rebases;
                    self.rec.instant(t, "engine", "engine.ladder.rebase");
                }
            }
            self.handle(t, ev);
        }
        debug_assert!(
            self.done_ps.iter().all(|&d| d != Time::MAX),
            "job never egressed"
        );
        let completed = self.done_ps.len() as u64;
        let makespan = self.done_ps.iter().copied().max().unwrap_or(0);
        let latency_s: Vec<f64> = self
            .arrival_ps
            .iter()
            .zip(&self.done_ps)
            .map(|(&a, &d)| ps_to_s(d.saturating_sub(a)))
            .collect();
        let registry = self.fill_registry(completed);
        let run = PipelineRun {
            completed,
            makespan_s: ps_to_s(makespan),
            energy_j_total: self.energy_j,
            energy_j_per_inference: self.energy_j / (completed as f64).max(1.0),
            latency_s,
            noc: self.noc.stats,
            engine: self.engine.stats,
            blocked_starts: self.blocked_starts,
            noc_wait_s: ps_to_s(self.noc.stats.queued_ps_total),
            adc_convs: self.adc_convs,
            sa_ops: self.sa_ops,
            registry,
        };
        (run, self.rec)
    }

    /// Fold this run's plain counters into a [`Registry`] (the hot path
    /// never touches the maps — this runs once, after the drain).
    fn fill_registry(&self, completed: u64) -> Registry {
        let mut reg = Registry::new();
        let es = self.engine.stats;
        reg.add("engine.scheduled", es.scheduled);
        reg.add("engine.processed", es.processed);
        reg.add("engine.clamped", es.clamped);
        reg.gauge_max("engine.peak_queue", es.peak_queue as u64);
        let qs = self.engine.queue_stats();
        reg.add("engine.ladder.rebases", qs.rebases);
        reg.add("engine.ladder.overflow_migrated", qs.overflow_migrated);
        let ns = &self.noc.stats;
        reg.add("noc.packets", ns.packets);
        reg.add("noc.flits", ns.flits);
        reg.add("noc.hops", ns.hops_total);
        reg.add("noc.stalled_packets", ns.stalled_packets);
        reg.add("noc.fast_path_hits", ns.fast_path_hits);
        reg.add("noc.queued_ps", ns.queued_ps_total);
        reg.gauge_max("noc.queued_ps_max", ns.queued_ps_max);
        reg.merge_hist("noc.queued_ps", &self.queued_hist);
        reg.add("pipeline.completed", completed);
        reg.add("pipeline.blocked_starts", self.blocked_starts);
        reg.add(&format!("adc.convs.{}", self.arch.name()), self.adc_convs);
        reg.add(&format!("sa.ops.{}", self.arch.name()), self.sa_ops);
        reg
    }
}

/// Trace track name of a pipeline stage, e.g. `stage3.tile17`.
fn stage_track(stage: usize, tile: u32) -> String {
    format!("stage{stage}.tile{tile}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::workloads::Layer;

    /// Unreplicated 1-chip mapping for hand-built layer chains.
    fn bare_mapping(cfg: &AcceleratorConfig, layers: &[Layer])
                    -> NetworkMapping {
        NetworkMapping {
            layers: layers
                .iter()
                .map(|l| crate::mapping::map_layer(l, cfg))
                .collect(),
            chips: 1,
            placement: vec![crate::mapping::Placement::Pim; layers.len()],
        }
    }

    #[test]
    fn single_job_energy_is_exact_and_latency_covers_fill() {
        let cfg = AcceleratorConfig::neural_pim();
        let layers = vec![
            Layer::conv("l0", 3, 8, 16, 12, 1),
            Layer::conv("l1", 3, 16, 16, 10, 1),
            Layer::fc("l2", 1600, 10),
        ];
        let m = bare_mapping(&cfg, &layers);
        let mut sim1 = PipelineSim::with_mapping(&cfg, &m);
        let fill_ps: Time = sim1.stages.iter().map(|s| s.service_ps).sum();
        sim1.inject_paced(1, 1);
        let run = sim1.run();
        assert_eq!(run.completed, 1);
        // energy: sum of per-stage compute shares + per-transfer NoC with
        // actual hops (recomputed independently here)
        let mesh = CMesh::new(cfg.tiles, cfg.noc_concentration);
        let tiles = m.layer_tiles(&cfg);
        let mut want = 0.0;
        for (i, lm) in m.layers.iter().enumerate() {
            let le = crate::sim::layer_energy(lm, &cfg, false);
            want += le.total() - le.noc;
            let to = if i + 1 < m.layers.len() { tiles[i + 1] } else { 0 };
            let hops = mesh.hops(tiles[i], to);
            want += mesh.transfer_energy(lm.out_bytes(), hops);
        }
        assert!(
            (run.energy_j_total - want).abs() <= want * 1e-12,
            "event {} vs expected {want}", run.energy_j_total
        );
        // latency: at least the pure compute fill (NoC adds on top)
        assert!(run.latency_s[0] >= ps_to_s(fill_ps));
        assert!(run.latency_s[0].is_finite() && run.latency_s[0] > 0.0);
    }

    #[test]
    fn steady_state_throughput_paced_by_bottleneck() {
        let cfg = AcceleratorConfig::neural_pim();
        let layers = vec![
            Layer::conv("a", 3, 8, 8, 8, 1),
            Layer::conv("b", 3, 8, 8, 12, 1), // bottleneck: most positions
            Layer::fc("c", 1152, 10),
        ];
        let m = bare_mapping(&cfg, &layers);
        let mut sim1 = PipelineSim::with_mapping(&cfg, &m);
        let period = sim1.bottleneck_period_ps();
        sim1.inject_paced(6, period);
        let run = sim1.run();
        assert_eq!(run.completed, 6);
        // identical jobs fed at the bottleneck period egress at the
        // bottleneck period once the pipeline is full
        let spacing = run.latency_s[5] - run.latency_s[4];
        assert!(
            spacing.abs() < ps_to_s(period) * 1e-6,
            "late jobs drift: sojourn delta {spacing}"
        );
    }

    #[test]
    fn finite_buffers_backpressure_fast_producer() {
        let cfg = AcceleratorConfig::neural_pim();
        // producer's output (10'000 x 8 B) exceeds the 64 KB eDRAM ->
        // capacity clamps to 1 inference; consumer is 4x slower
        let layers = vec![
            Layer::conv("fast", 1, 1, 8, 100, 1),
            Layer::conv("slow", 1, 8, 8, 200, 1),
        ];
        let m = bare_mapping(&cfg, &layers);
        assert_eq!(m.buffer_capacity_infs(1, cfg.edram_bytes, MAX_BUF_INFS), 1);
        let mut sim1 = PipelineSim::with_mapping(&cfg, &m);
        assert!(sim1.stages[0].service_ps < sim1.stages[1].service_ps);
        sim1.inject_paced(4, 1); // near-simultaneous arrivals
        let run = sim1.run();
        assert_eq!(run.completed, 4);
        assert!(run.blocked_starts > 0, "producer never back-pressured");
        // sojourns grow while jobs queue behind the slow consumer
        assert!(run.latency_s[3] > run.latency_s[0]);
    }

    #[test]
    fn service_profile_matches_the_pipeline_stages() {
        let cfg = AcceleratorConfig::neural_pim();
        let net = crate::workloads::alexnet();
        let nc = crate::model::network_cost(&net, &cfg);
        let sp = service_profile(&cfg, &nc);
        let sim1 = PipelineSim::with_costs(&cfg, &nc);
        // one shared pacing formula: profile stages == simulator stages
        assert_eq!(sp.stage_ps.len(), sim1.stages.len());
        for (a, s) in sp.stage_ps.iter().zip(&sim1.stages) {
            assert_eq!(*a, s.service_ps);
        }
        assert_eq!(sp.bottleneck_ps(), sim1.bottleneck_period_ps().max(1));
        assert_eq!(sp.fill_ps(),
                   sim1.stages.iter().map(|s| s.service_ps).sum::<Time>());
        // batch pacing: fill + (n-1) x bottleneck, monotone in n
        assert_eq!(sp.batch_ps(1), sp.fill_ps());
        assert_eq!(
            sp.batch_ps(5),
            sp.fill_ps() + 4 * sp.bottleneck_ps()
        );
        assert!(sp.batch_us(5) >= sp.batch_us(1));
        assert!(sp.batch_us(1) >= 1);
    }

    #[test]
    fn traced_run_is_result_identical_and_fills_the_registry() {
        let cfg = AcceleratorConfig::neural_pim();
        let layers = vec![Layer::conv("x", 3, 4, 8, 6, 1),
                          Layer::fc("y", 288, 10)];
        let m = bare_mapping(&cfg, &layers);
        let mut plain = PipelineSim::with_mapping(&cfg, &m);
        plain.inject_paced(3, 1);
        let plain = plain.run();
        let mut traced = PipelineSim::with_mapping(&cfg, &m)
            .with_recorder(crate::obs::TraceRecorder::new());
        traced.inject_paced(3, 1);
        let (traced, rec) = traced.run_traced();
        // tracing must not perturb the simulation
        assert_eq!(plain.energy_j_total.to_bits(),
                   traced.energy_j_total.to_bits());
        assert_eq!(
            plain.latency_s.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            traced.latency_s.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(plain.adc_convs, traced.adc_convs);
        assert_eq!(plain.sa_ops, traced.sa_ops);
        assert!(plain.adc_convs > 0);
        // registry totals key off the architecture name
        let key = format!("adc.convs.{}", cfg.arch.name());
        assert_eq!(plain.registry.counter(&key), plain.adc_convs);
        assert_eq!(plain.registry.counter("pipeline.completed"), 3);
        assert_eq!(plain.registry.counter("engine.processed"),
                   plain.engine.processed);
        // and the trace captured stage occupancy + NoC link spans
        assert!(rec.events().iter().any(|e| e.name == "stage.serve"));
        assert!(rec.events().iter().any(|e| e.name == "noc.link"));
        // per-job conversion totals: every job crosses every stage once
        let per_inf: u64 = m
            .layers
            .iter()
            .map(|lm| crate::model::layer_cost(lm, &cfg, false).adc_convs)
            .sum();
        assert_eq!(plain.adc_convs, 3 * per_inf);
    }

    #[test]
    fn poisson_injection_is_deterministic_per_stream() {
        let cfg = AcceleratorConfig::neural_pim();
        let layers = vec![Layer::conv("x", 3, 4, 8, 6, 1),
                          Layer::fc("y", 288, 10)];
        let run = |seed: u64| {
            let m = bare_mapping(&cfg, &layers);
            let mut s = PipelineSim::with_mapping(&cfg, &m);
            let mean = s.bottleneck_period_ps() as f64 / 0.8;
            let mut rng = Pcg::new(seed);
            s.inject_poisson(32, mean, &mut rng);
            let r = s.run();
            (r.latency_s.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
             r.energy_j_total.to_bits())
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7).0, run(8).0);
    }
}
