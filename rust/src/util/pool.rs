//! Dependency-free scoped worker pool (the offline registry has no
//! `rayon`/`tokio`): `std::thread::scope` workers pulling indices from a
//! shared atomic counter (work stealing at item granularity).
//!
//! The contract every caller relies on: **results are bit-identical to a
//! sequential run regardless of thread count**. `map` reassembles results
//! by input index, so any per-item computation that is itself
//! deterministic (e.g. a Monte-Carlo trial on a pre-forked `Pcg` stream)
//! yields the same output at `--threads 1` and `--threads 8`.
//!
//! The pool size is process-global, defaulting to the machine's available
//! parallelism, and is wired to the `--threads` CLI flag by `main.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Configured thread count; 0 means "auto" (available parallelism).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Override the pool size for subsequent `map`/`for_each_indexed` calls.
/// `0` restores the default (all available cores).
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// The pool size `map` will use: the `set_threads` override, or the
/// machine's available parallelism (at least 1).
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
}

/// Parallel map preserving input order: `out[i] == f(&items[i])`.
pub fn map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    map_with(threads(), items, f)
}

/// [`map`] with an explicit worker count (used by the determinism tests
/// and the sequential-vs-parallel benches; does not touch the global).
pub fn map_with<T, R, F>(n_threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n_threads = n_threads.max(1).min(items.len());
    if n_threads <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(n_threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("pool worker panicked"));
        }
    });
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in parts.into_iter().flatten() {
        debug_assert!(out[i].is_none(), "index {i} produced twice");
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|r| r.expect("pool lost a result"))
        .collect()
}

/// Run `f(i, &items[i])` for every index across the pool. No result
/// collection; use for side-effecting sweeps (e.g. filling a pre-sized
/// output buffer through interior mutability or per-index files).
pub fn for_each_indexed<T, F>(items: &[T], f: F)
where
    T: Sync,
    F: Fn(usize, &T) + Sync,
{
    let n_threads = threads().max(1).min(items.len());
    if n_threads <= 1 {
        for (i, t) in items.iter().enumerate() {
            f(i, t);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                f(i, &items[i]);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn map_matches_sequential_at_any_thread_count() {
        let items: Vec<u64> = (0..257).collect();
        let seq: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for t in [1usize, 2, 3, 8, 64] {
            let par = map_with(t, &items, |x| x * x + 1);
            assert_eq!(par, seq, "threads = {t}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(map_with(8, &empty, |x| *x).is_empty());
        assert_eq!(map_with(8, &[41u32], |x| x + 1), vec![42]);
    }

    #[test]
    fn map_is_bitwise_thread_count_invariant_for_floats() {
        // per-item float work must reassemble identically: the pool only
        // changes *where* an item runs, never its inputs or order
        let items: Vec<f64> = (0..100).map(|i| i as f64 * 0.7).collect();
        let f = |x: &f64| (x.sin() * 1e6).ln_1p() / (x + 1.0);
        let bits = |v: Vec<f64>| -> Vec<u64> {
            v.into_iter().map(f64::to_bits).collect()
        };
        let one = bits(map_with(1, &items, f));
        for t in [2usize, 5, 8] {
            assert_eq!(bits(map_with(t, &items, f)), one, "threads = {t}");
        }
    }

    #[test]
    fn for_each_indexed_visits_every_index_once() {
        let items: Vec<usize> = (0..301).collect();
        let seen = Mutex::new(vec![0u32; items.len()]);
        for_each_indexed(&items, |i, &v| {
            assert_eq!(i, v);
            seen.lock().unwrap()[i] += 1;
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn thread_count_configuration() {
        assert!(threads() >= 1);
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
    }
}
