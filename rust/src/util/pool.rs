//! Dependency-free persistent worker pool (the offline registry has no
//! `rayon`/`tokio`): a lazily-initialized set of parked OS threads that
//! claim fixed, index-ordered chunks of each submitted map.
//!
//! The contract every caller relies on: **results are bit-identical to a
//! sequential run regardless of thread count**. [`map`] writes each
//! result into its input's slot, so any per-item computation that is
//! itself deterministic (e.g. a Monte-Carlo trial on a pre-forked `Pcg`
//! stream) yields the same output at `--threads 1` and `--threads 8`.
//! Chunking changes *where* an item runs, never its inputs or its slot.
//!
//! Scheduling model (the PR-8 overhaul; the previous engine spawned
//! fresh `std::thread::scope` workers per call and stole work one item
//! at a time off a single contended counter):
//!
//! - **Persistent workers** — `pool()` owns N-1 parked threads (the
//!   submitting thread is the Nth participant); a map call publishes one
//!   job, wakes them, and parks them again when the job drains. Pool
//!   size follows [`set_threads`]; a size change is applied lazily at
//!   the next submission (threads are spawned or retired then, never
//!   mid-job).
//! - **Deterministic chunked claiming** — the item range is cut into
//!   fixed chunks ([`chunk_size`]: adaptive to the item count, ~8 chunks
//!   per participant, capped so huge inputs still rebalance). Workers
//!   claim whole chunks off one atomic cursor; each index is written by
//!   exactly one claimant, so reassembly is by-index exactly as before.
//! - **Nested calls run inline** — a `map`/`for_each_indexed` issued
//!   from inside a pool task (worker thread *or* the participating
//!   submitter) detects it is [`on_worker`] and degrades to the
//!   sequential loop. The suite runner can fan scenarios across the pool
//!   while every scenario's own sweeps nest harmlessly, where the old
//!   engine oversubscribed the machine with scope-spawned threads.
//! - **Panic transparency** — a panicking task poisons nothing: the
//!   first payload is captured and re-thrown on the submitting thread
//!   after the job drains (the remaining chunks still run, keeping the
//!   pool state trivial).
//!
//! The pool size is process-global, defaulting to the machine's
//! available parallelism (resolved once), and is wired to the
//! `--threads` CLI flag by `main.rs`.
//!
//! This module is the crate's only thread factory outside `serve/`
//! (grep-enforced by `scripts/verify.sh`); [`on_fresh_thread`] exists
//! for the few tests that need a provably-distinct thread, and
//! [`set_spawn_baseline`] re-enables the old spawn-per-call engine so
//! `perf_hotpath --only-pool` can price exactly what persistence buys.

use std::any::Any;
use std::cell::{Cell, UnsafeCell};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Configured thread count; 0 means "auto" (available parallelism).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Bench-only escape hatch: route `map`/`for_each_indexed` through the
/// pre-PR-8 spawn-per-call scheduler (see [`set_spawn_baseline`]).
static SPAWN_BASELINE: AtomicBool = AtomicBool::new(false);

/// OS threads ever created by the persistent pool (monotonic; the
/// nested-map tests assert a warm pool stops growing).
static SPAWNED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// True on pool worker threads, and on the submitting thread while
    /// it is executing chunks of its own job.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Override the pool size for subsequent `map`/`for_each_indexed` calls.
/// `0` restores the default (all available cores). Applied lazily: the
/// next submission resizes the worker set.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// The participant count `map` will use: the `set_threads` override, or
/// the machine's available parallelism (at least 1, resolved once — the
/// OS query is not re-issued per call).
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => auto_threads(),
        n => n,
    }
}

fn auto_threads() -> usize {
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// True when the current thread is executing a pool task — the nesting
/// guard: a `map` issued here runs inline instead of re-entering the
/// pool (re-entry from a worker would deadlock on the submission lock;
/// re-entry from the old engine oversubscribed the machine).
pub fn on_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Total OS threads the persistent pool has ever spawned. Monotonic;
/// test-support (a warm pool serving nested suites must not grow).
pub fn spawned_workers() -> u64 {
    SPAWNED.load(Ordering::Relaxed)
}

/// Route subsequent calls through the retained spawn-per-call baseline
/// engine (scoped threads + item-granularity stealing) instead of the
/// persistent pool. **Benchmark-only**: `perf_hotpath --only-pool` uses
/// it to price per-call spawn overhead and nested oversubscription;
/// results are bit-identical on either engine.
pub fn set_spawn_baseline(on: bool) {
    SPAWN_BASELINE.store(on, Ordering::Relaxed);
}

/// Run `f` on a brand-new OS thread and return its result. Test-support
/// utility: this module is the only sanctioned thread factory outside
/// `serve/`, and thread-locality tests need a thread that is provably
/// not the caller (a pool worker may *be* the caller via participation).
pub fn on_fresh_thread<R, F>(f: F) -> R
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    std::thread::scope(|s| {
        s.spawn(f).join().expect("on_fresh_thread task panicked")
    })
}

/// Parallel map preserving input order: `out[i] == f(&items[i])`.
pub fn map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    map_with(threads(), items, f)
}

/// [`map`] with an explicit participant count (used by the determinism
/// tests and the sequential-vs-parallel benches). Does not change the
/// configured global count, but does resize the shared worker set for
/// the duration of the call; `map_with(1, ..)` is the guaranteed-inline
/// spelling some sequential paths rely on.
pub fn map_with<T, R, F>(n_threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let parts = n_threads.max(1).min(items.len());
    if parts <= 1 || on_worker() {
        return items.iter().map(&f).collect();
    }
    if SPAWN_BASELINE.load(Ordering::Relaxed) {
        return map_spawn(parts, items, f);
    }
    // one write slot per item; each index belongs to exactly one chunk
    // and each chunk to exactly one claimant, so the unsynchronized
    // writes never alias (see OutSlot)
    let out: Vec<OutSlot<R>> = (0..items.len()).map(|_| OutSlot::new()).collect();
    let chunk = chunk_size(items.len(), parts);
    let n_chunks = items.len().div_ceil(chunk);
    let run_chunk = |c: usize| {
        let lo = c * chunk;
        let hi = (lo + chunk).min(items.len());
        for i in lo..hi {
            out[i].set(f(&items[i]));
        }
    };
    pool().run(parts - 1, n_chunks, &run_chunk);
    out.into_iter()
        .map(|s| s.take().expect("pool lost a result"))
        .collect()
}

/// Run `f(i, &items[i])` for every index across the pool. No result
/// collection; use for side-effecting sweeps (e.g. filling a pre-sized
/// output buffer through interior mutability or per-index files).
pub fn for_each_indexed<T, F>(items: &[T], f: F)
where
    T: Sync,
    F: Fn(usize, &T) + Sync,
{
    let parts = threads().max(1).min(items.len());
    if parts <= 1 || on_worker() {
        for (i, t) in items.iter().enumerate() {
            f(i, t);
        }
        return;
    }
    if SPAWN_BASELINE.load(Ordering::Relaxed) {
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..parts {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    f(i, &items[i]);
                });
            }
        });
        return;
    }
    let chunk = chunk_size(items.len(), parts);
    let n_chunks = items.len().div_ceil(chunk);
    let run_chunk = |c: usize| {
        let lo = c * chunk;
        let hi = (lo + chunk).min(items.len());
        for i in lo..hi {
            f(i, &items[i]);
        }
    };
    pool().run(parts - 1, n_chunks, &run_chunk);
}

/// The retained pre-PR-8 engine: scoped threads spawned per call,
/// stealing single items off one shared counter. Kept as the priced
/// baseline for `BENCH_pool.json` (and as the simplest possible
/// reference the persistent pool's tests compare against).
pub fn map_spawn<T, R, F>(n_threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n_threads = n_threads.max(1).min(items.len());
    if n_threads <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(n_threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("pool worker panicked"));
        }
    });
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in parts.into_iter().flatten() {
        debug_assert!(out[i].is_none(), "index {i} produced twice");
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|r| r.expect("pool lost a result"))
        .collect()
}

/// Chunk width for `len` items across `parts` participants: ~8 chunks
/// per participant so a slow chunk rebalances, capped at 1024 so very
/// large inputs still interleave, floored at 1. Purely a scheduling
/// knob — results never depend on it (by-index reassembly).
fn chunk_size(len: usize, parts: usize) -> usize {
    len.div_ceil(parts * 8).clamp(1, 1024)
}

// ------------------------------------------------------ the pool itself --

/// One write-once result slot. Safety contract: `set(i)` is called at
/// most once per slot (each index belongs to exactly one claimed chunk),
/// and `take` only after the job fully drains — so the unsynchronized
/// interior writes never alias and are published to the submitter by the
/// job's release/acquire drain counter.
struct OutSlot<R>(UnsafeCell<Option<R>>);

unsafe impl<R: Send> Sync for OutSlot<R> {}

impl<R> OutSlot<R> {
    fn new() -> Self {
        OutSlot(UnsafeCell::new(None))
    }

    fn set(&self, v: R) {
        // SAFETY: sole writer for this slot (one chunk, one claimant).
        unsafe { *self.0.get() = Some(v) }
    }

    fn take(self) -> Option<R> {
        self.0.into_inner()
    }
}

/// One submitted map: a lifetime-erased chunk runner plus the claim and
/// drain cursors. Lives in an `Arc` so a late-waking worker can still
/// inspect it safely after completion (it only ever *runs* chunks it
/// claimed before the drain hit zero, and the submitter does not return
/// — i.e. the borrowed stack frame stays alive — until the drain hits
/// zero).
struct Job {
    /// chunk runner borrowed from the submitting `map` frame; valid
    /// until `remaining` reaches 0 (the submitter blocks until then)
    task: *const (dyn Fn(usize) + Sync),
    n_chunks: usize,
    /// claim cursor: `fetch_add` hands out chunk indices
    next: AtomicUsize,
    /// drain counter: chunks fully executed; 0 = job complete
    remaining: AtomicUsize,
    /// first panic payload from any chunk, re-thrown by the submitter
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

// SAFETY: the raw task pointer is only dereferenced for chunks claimed
// while `remaining > 0`, and the submitting frame it points into blocks
// until `remaining == 0`; all other fields are Sync.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct State {
    /// the in-flight job, if any (at most one; submissions serialize)
    job: Option<Arc<Job>>,
    /// bumped per publish so a worker never re-enters a job it finished
    epoch: u64,
    /// workers currently alive (parked or running)
    live: usize,
    /// workers that must exit (shrink protocol; drained before publish)
    excess: usize,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here waiting for a job or an exit request
    work: Condvar,
    /// the submitter (and the shrink path) wait here
    done: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    /// serializes submissions: at most one job in flight, which keeps
    /// the worker protocol trivial (parked -> run -> parked)
    submit: Mutex<()>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        shared: Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                epoch: 0,
                live: 0,
                excess: 0,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        }),
        submit: Mutex::new(()),
    })
}

/// Restores the caller's previous [`on_worker`] flag even on unwind, so
/// a panicking task cannot leave the submitting thread marked in-pool.
struct InPoolGuard(bool);

impl InPoolGuard {
    fn enter() -> InPoolGuard {
        InPoolGuard(IN_POOL.with(|c| c.replace(true)))
    }
}

impl Drop for InPoolGuard {
    fn drop(&mut self) {
        let prev = self.0;
        IN_POOL.with(|c| c.set(prev));
    }
}

/// Claim and run chunks until the cursor runs out. Every participant —
/// workers and the submitter alike — executes this same loop; whoever
/// drains the last chunk clears the published job and wakes the
/// submitter.
fn run_job(shared: &Shared, job: &Job) {
    loop {
        let c = job.next.fetch_add(1, Ordering::Relaxed);
        if c >= job.n_chunks {
            return;
        }
        // SAFETY: c < n_chunks implies remaining > 0, so the submitting
        // frame (and the closure it owns) is still alive.
        let task = unsafe { &*job.task };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(c))) {
            let mut slot = job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        // AcqRel chain: every participant's slot writes happen-before
        // its decrement, and the submitter's acquire read of 0 sees all
        // of them
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut st = shared.state.lock().unwrap();
            if st
                .job
                .as_ref()
                .is_some_and(|j| std::ptr::eq(Arc::as_ptr(j), job))
            {
                st.job = None;
            }
            drop(st);
            shared.done.notify_all();
            return;
        }
    }
}

fn worker(shared: Arc<Shared>) {
    IN_POOL.with(|c| c.set(true));
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.excess > 0 {
                    st.excess -= 1;
                    st.live -= 1;
                    drop(st);
                    shared.done.notify_all();
                    return;
                }
                if let Some(j) = &st.job {
                    if st.epoch != seen_epoch {
                        seen_epoch = st.epoch;
                        break j.clone();
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        run_job(&shared, &job);
    }
}

impl Pool {
    /// Submit one job: resize the worker set to `want`, publish the
    /// chunk runner, participate, and block until every chunk has
    /// executed. Panics from chunks are re-thrown here.
    fn run(&self, want: usize, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        let _submit = self.submit.lock().unwrap();
        self.resize_locked(want);
        // SAFETY: lifetime erasure only — the pointee outlives the job
        // because this frame blocks until the drain counter hits zero.
        let task = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync),
            >(task)
        };
        let job = Arc::new(Job {
            task,
            n_chunks,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n_chunks),
            panic: Mutex::new(None),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job.clone());
            st.epoch = st.epoch.wrapping_add(1);
        }
        self.shared.work.notify_all();
        {
            let _g = InPoolGuard::enter();
            run_job(&self.shared, &job);
        }
        let mut st = self.shared.state.lock().unwrap();
        while job.remaining.load(Ordering::Acquire) > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        // normally cleared by whoever drained the last chunk; belt and
        // braces in case that was us
        if st
            .job
            .as_ref()
            .is_some_and(|j| Arc::ptr_eq(j, &job))
        {
            st.job = None;
        }
        drop(st);
        if let Some(p) = job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }

    /// Bring the worker set to exactly `want` threads. Called with the
    /// submission lock held and no job in flight, so every live worker
    /// is parked (or en route to parking) and the shrink handshake
    /// settles before any job publishes.
    fn resize_locked(&self, want: usize) {
        let mut st = self.shared.state.lock().unwrap();
        if st.live > want {
            st.excess = st.live - want;
            self.shared.work.notify_all();
            while st.live > want {
                st = self.shared.done.wait(st).unwrap();
            }
            st.excess = 0;
        }
        while st.live < want {
            let sh = self.shared.clone();
            std::thread::Builder::new()
                .name("np-pool".into())
                .spawn(move || worker(sh))
                .expect("spawning pool worker");
            SPAWNED.fetch_add(1, Ordering::Relaxed);
            st.live += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn map_matches_sequential_at_any_thread_count() {
        let items: Vec<u64> = (0..257).collect();
        let seq: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for t in [1usize, 2, 3, 8, 64] {
            let par = map_with(t, &items, |x| x * x + 1);
            assert_eq!(par, seq, "threads = {t}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(map_with(8, &empty, |x| *x).is_empty());
        assert_eq!(map_with(8, &[41u32], |x| x + 1), vec![42]);
    }

    #[test]
    fn map_is_bitwise_thread_count_invariant_for_floats() {
        // per-item float work must reassemble identically: the pool only
        // changes *where* an item runs, never its inputs or order
        let items: Vec<f64> = (0..100).map(|i| i as f64 * 0.7).collect();
        let f = |x: &f64| (x.sin() * 1e6).ln_1p() / (x + 1.0);
        let bits = |v: Vec<f64>| -> Vec<u64> {
            v.into_iter().map(f64::to_bits).collect()
        };
        let one = bits(map_with(1, &items, f));
        for t in [2usize, 5, 8] {
            assert_eq!(bits(map_with(t, &items, f)), one, "threads = {t}");
        }
    }

    #[test]
    fn spawn_baseline_matches_persistent_engine() {
        let items: Vec<u64> = (0..333).collect();
        let seq: Vec<u64> = items.iter().map(|x| x.wrapping_mul(7) ^ 3).collect();
        assert_eq!(map_spawn(8, &items, |x| x.wrapping_mul(7) ^ 3), seq);
        assert_eq!(map_with(8, &items, |x| x.wrapping_mul(7) ^ 3), seq);
    }

    #[test]
    fn for_each_indexed_visits_every_index_once() {
        let items: Vec<usize> = (0..301).collect();
        let seen = Mutex::new(vec![0u32; items.len()]);
        for_each_indexed(&items, |i, &v| {
            assert_eq!(i, v);
            seen.lock().unwrap()[i] += 1;
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn thread_count_configuration() {
        // NOTE: lib tests run concurrently and THREADS is process-global,
        // so this test (the only mutator in the lib suite) restores auto
        // mode on exit; every map result is thread-count-invariant, so
        // the transient override cannot change any other test's output.
        assert!(threads() >= 1);
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
        // auto mode resolves available_parallelism once and keeps
        // serving it from the cached value
        assert_eq!(threads(), auto_threads());
    }

    #[test]
    fn nested_map_runs_inline_on_a_participant() {
        // every item observes on_worker() == true (workers and the
        // participating submitter), so its own map degrades to the
        // sequential loop — and the result is still correct
        let outer: Vec<u64> = (0..64).collect();
        let got = map_with(4, &outer, |&x| {
            assert!(on_worker(), "pool task not flagged in-pool");
            let inner: Vec<u64> = (0..8).collect();
            map_with(4, &inner, |&y| x * 10 + y).iter().sum::<u64>()
        });
        let want: Vec<u64> = outer
            .iter()
            .map(|&x| (0..8).map(|y| x * 10 + y).sum())
            .collect();
        assert_eq!(got, want);
        assert!(!on_worker(), "in-pool flag leaked past map return");
    }

    #[test]
    fn chunk_size_is_sane() {
        assert_eq!(chunk_size(1, 8), 1);
        assert_eq!(chunk_size(64, 8), 1);
        assert_eq!(chunk_size(6400, 8), 100);
        assert_eq!(chunk_size(10_000_000, 8), 1024);
        for (len, parts) in [(257usize, 3usize), (64, 64), (1000, 7)] {
            let c = chunk_size(len, parts);
            assert!(c >= 1 && len.div_ceil(c) >= 1);
        }
    }

    #[test]
    fn panics_propagate_to_the_submitter() {
        let items: Vec<u32> = (0..128).collect();
        let r = std::panic::catch_unwind(|| {
            map_with(4, &items, |&x| {
                if x == 77 {
                    panic!("item 77 exploded");
                }
                x
            })
        });
        let err = r.expect_err("panic must cross the pool");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("item 77"), "lost the original payload: {msg}");
        assert!(!on_worker(), "panic left the submitter flagged in-pool");
        // the pool is still usable afterwards
        assert_eq!(map_with(4, &[1u32, 2, 3], |x| x * 2), vec![2, 4, 6]);
    }

    #[test]
    fn pool_resizes_between_calls() {
        let items: Vec<u64> = (0..500).collect();
        let seq: Vec<u64> = items.iter().map(|x| x + 1).collect();
        // grow, shrink, regrow — each call resizes the shared worker
        // set; results must be identical throughout
        for t in [2usize, 16, 2, 8, 3] {
            assert_eq!(map_with(t, &items, |x| x + 1), seq, "threads = {t}");
        }
    }
}
