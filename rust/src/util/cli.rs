//! Tiny CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer")))
            .unwrap_or(default)
    }

    /// Worker-pool size requested via `--threads N`; 0 (the default when
    /// the flag is absent) means "auto" — feed it straight to
    /// [`crate::util::pool::set_threads`].
    pub fn threads(&self) -> usize {
        self.get_usize("threads", 0)
    }

    /// Every `--name` the caller passed, options and bare flags alike.
    pub fn given_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.options.keys().map(String::as_str).collect();
        v.extend(self.flags.iter().map(String::as_str));
        v
    }

    /// Reject any `--flag` not in `known`, with a "did you mean"
    /// suggestion — a silently ignored typo (`--thread 8`) is worse than
    /// an error. Returns the full complaint for all unknown names.
    pub fn reject_unknown(&self, known: &[&str]) -> Result<(), String> {
        let bad: Vec<String> = self
            .given_names()
            .into_iter()
            .filter(|n| !known.contains(n))
            .map(|n| match suggest(n, known) {
                Some(s) => format!("--{n} (did you mean --{s}?)"),
                None => format!("--{n}"),
            })
            .collect();
        if bad.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown option{} {}", if bad.len() > 1 { "s" } else { "" },
                        bad.join(", ")))
        }
    }
}

/// Levenshtein edit distance (insert/delete/substitute, unit costs).
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// The closest known name within an edit-distance budget that scales
/// with the typo's length (distance <= 2, and never more than half the
/// candidate — "x" should not suggest "dse"). Ties go to the earliest
/// candidate, so suggestion order is deterministic.
pub fn suggest<'a>(given: &str, known: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(usize, &'a str)> = None;
    for &k in known {
        let d = levenshtein(given, k);
        let budget = 2.min(k.chars().count().saturating_sub(1) / 2 + 1);
        if d <= budget && best.map(|(bd, _)| d < bd).unwrap_or(true) {
            best = Some((d, k));
        }
    }
    best.map(|(_, k)| k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&argv(&[
            "simulate", "--network", "alexnet", "--tiles=280", "--verbose",
        ]));
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.get("network"), Some("alexnet"));
        assert_eq!(a.get_usize("tiles", 0), 280);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&argv(&["--quick", "--all"]));
        assert!(a.flag("quick") && a.flag("all"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]));
        assert_eq!(a.get_or("x", "y"), "y");
        assert_eq!(a.get_f64("z", 1.5), 1.5);
    }

    #[test]
    fn levenshtein_distances() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("thread", "threads"), 1);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("netwrok", "network"), 2);
    }

    #[test]
    fn suggestions_catch_typos_but_not_noise() {
        let known = ["threads", "network", "requests", "top", "all"];
        assert_eq!(suggest("thread", &known), Some("threads"));
        assert_eq!(suggest("netwrok", &known), Some("network"));
        assert_eq!(suggest("tops", &known), Some("top"));
        // short candidates get a tight budget: "x" must not match "top"
        assert_eq!(suggest("x", &known), None);
        assert_eq!(suggest("verbose", &known), None);
    }

    #[test]
    fn reject_unknown_flags_with_suggestion() {
        let a = Args::parse(&argv(&["simulate", "--thread", "8", "--all"]));
        let err = a.reject_unknown(&["threads", "all"]).unwrap_err();
        assert!(err.contains("--thread"), "{err}");
        assert!(err.contains("did you mean --threads"), "{err}");
        assert!(!err.contains("--all,"), "{err}");
        // the same args pass once every name is known
        assert!(a.reject_unknown(&["thread", "all"]).is_ok());
    }

    #[test]
    fn reject_unknown_lists_every_offender() {
        let a = Args::parse(&argv(&["--foo", "--bar=1"]));
        let err = a.reject_unknown(&["threads"]).unwrap_err();
        assert!(err.starts_with("unknown options"), "{err}");
        assert!(err.contains("--foo") && err.contains("--bar"), "{err}");
    }

    #[test]
    fn threads_flag() {
        assert_eq!(Args::parse(&argv(&[])).threads(), 0);
        assert_eq!(Args::parse(&argv(&["--threads", "8"])).threads(), 8);
        assert_eq!(Args::parse(&argv(&["--threads=2"])).threads(), 2);
    }
}
