//! Tiny CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer")))
            .unwrap_or(default)
    }

    /// Worker-pool size requested via `--threads N`; 0 (the default when
    /// the flag is absent) means "auto" — feed it straight to
    /// [`crate::util::pool::set_threads`].
    pub fn threads(&self) -> usize {
        self.get_usize("threads", 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&argv(&[
            "simulate", "--network", "alexnet", "--tiles=280", "--verbose",
        ]));
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.get("network"), Some("alexnet"));
        assert_eq!(a.get_usize("tiles", 0), 280);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&argv(&["--quick", "--all"]));
        assert!(a.flag("quick") && a.flag("all"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]));
        assert_eq!(a.get_or("x", "y"), "y");
        assert_eq!(a.get_f64("z", 1.5), 1.5);
    }

    #[test]
    fn threads_flag() {
        assert_eq!(Args::parse(&argv(&[])).threads(), 0);
        assert_eq!(Args::parse(&argv(&["--threads", "8"])).threads(), 8);
        assert_eq!(Args::parse(&argv(&["--threads=2"])).threads(), 2);
    }
}
