//! Aligned plain-text tables — the benches print the paper's tables and
//! figure series as text rows, and this keeps them readable and diffable.
//!
//! Cells are *typed*: every cell carries its rendered text (what the
//! text tables have always shown, byte-for-byte) and optionally the
//! numeric value behind it, so the scenario layer can serialize tables
//! to JSON without re-parsing formatted strings.

use crate::util::json::{self, Json};

/// One table cell: the exact text the plain-text renderer prints, plus
/// the numeric value it was formatted from (when there is one).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Cell {
    pub text: String,
    pub value: Option<f64>,
}

impl Cell {
    /// Text-only cell (labels, names, annotations).
    pub fn s(text: impl Into<String>) -> Cell {
        Cell { text: text.into(), value: None }
    }

    /// Numeric cell: `text` is what the table prints, `value` what the
    /// JSON rendering carries alongside it.
    pub fn num(value: f64, text: impl Into<String>) -> Cell {
        Cell { text: text.into(), value: Some(value) }
    }

    fn to_json(&self) -> Json {
        match self.value {
            None => Json::Str(self.text.clone()),
            Some(v) => json::obj(vec![
                ("t", Json::Str(self.text.clone())),
                ("v", Json::Num(v)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Option<Cell> {
        match j {
            Json::Str(s) => Some(Cell::s(s.clone())),
            Json::Obj(_) => Some(Cell {
                text: j.get("t")?.as_str()?.to_string(),
                value: j.get("v").and_then(Json::as_f64),
            }),
            _ => None,
        }
    }
}

#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<Cell>>,
    title: Option<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: Some(title.to_string()),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        self.cells(cells.iter().map(|c| Cell::s(c.as_str())).collect())
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Append a row of typed [`Cell`]s.
    pub fn cells(&mut self, cells: Vec<Cell>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn title(&self) -> Option<&str> {
        self.title.as_deref()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.text.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {} ==\n", t));
        }
        let fmt_row = |cells: &[&str]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = cells[i];
                line.push_str(c);
                for _ in c.chars().count()..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        let header_refs: Vec<&str> =
            self.headers.iter().map(String::as_str).collect();
        out.push_str(&fmt_row(&header_refs));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            let refs: Vec<&str> = row.iter().map(|c| c.text.as_str()).collect();
            out.push_str(&fmt_row(&refs));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// JSON form: `{"title", "headers", "rows"}` with plain strings for
    /// text cells and `{"t", "v"}` objects for numeric ones.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("title",
             Json::Str(self.title.clone().unwrap_or_default())),
            ("headers",
             Json::Arr(self.headers.iter().cloned().map(Json::Str).collect())),
            ("rows",
             Json::Arr(
                 self.rows
                     .iter()
                     .map(|r| Json::Arr(r.iter().map(Cell::to_json).collect()))
                     .collect(),
             )),
        ])
    }

    /// Rebuild a table from its [`Table::to_json`] form (the cached
    /// results store renders text tables from stored outcomes).
    pub fn from_json(j: &Json) -> Option<Table> {
        let headers: Vec<String> = j
            .get("headers")?
            .as_arr()?
            .iter()
            .map(|h| h.as_str().map(str::to_string))
            .collect::<Option<_>>()?;
        if headers.is_empty() {
            // the renderer's width math assumes >= 1 column; reject a
            // zero-column table so a degenerate stored file reads as a
            // cache miss, not a panic at replay time
            return None;
        }
        let mut rows = Vec::new();
        for rj in j.get("rows")?.as_arr()? {
            let row: Vec<Cell> =
                rj.as_arr()?.iter().map(Cell::from_json).collect::<Option<_>>()?;
            if row.len() != headers.len() {
                return None;
            }
            rows.push(row);
        }
        Some(Table {
            headers,
            rows,
            title: j.get("title").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// Format a number with engineering notation (for power/area cells).
pub fn eng(v: f64) -> String {
    let a = v.abs();
    if v == 0.0 {
        "0".into()
    } else if a >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else if a >= 1.0 {
        format!("{:.3}", v)
    } else if a >= 1e-3 {
        format!("{:.2}m", v * 1e3)
    } else if a >= 1e-6 {
        format!("{:.2}u", v * 1e6)
    } else if a >= 1e-9 {
        format!("{:.2}n", v * 1e9)
    } else {
        format!("{:.2}p", v * 1e12)
    }
}

/// [`Cell::num`] with [`eng`] formatting — the common typed-cell case.
pub fn eng_cell(v: f64) -> Cell {
    Cell::num(v, eng(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows align on the second column
        let col = lines[1].find("val").unwrap();
        assert_eq!(lines[3].find('1'), Some(col));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn eng_formatting() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(1.5e-3), "1.50m");
        assert_eq!(eng(2.0e6), "2.00M");
        assert_eq!(eng(96.0e-3), "96.00m");
    }

    #[test]
    fn typed_cells_render_identically_to_strings() {
        let mut a = Table::new("T", &["name", "val"]);
        a.row(&["x".into(), "1.50m".into()]);
        let mut b = Table::new("T", &["name", "val"]);
        b.cells(vec![Cell::s("x"), eng_cell(1.5e-3)]);
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn json_round_trip_preserves_render_and_values() {
        let mut t = Table::new("T", &["name", "val"]);
        t.cells(vec![Cell::s("x"), Cell::num(2.5, "2.500")]);
        t.row(&["plain".into(), "-".into()]);
        let j = t.to_json();
        let back = Table::from_json(&j).unwrap();
        assert_eq!(back.render(), t.render());
        // numeric value survives; text-only cells stay strings in JSON
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[1].get("v").unwrap().as_f64(),
                   Some(2.5));
        assert!(rows[1].as_arr().unwrap()[1].as_str().is_some());
    }

    #[test]
    fn from_json_rejects_ragged_rows() {
        let j = crate::util::json::Json::parse(
            r#"{"title":"T","headers":["a","b"],"rows":[["only-one"]]}"#,
        )
        .unwrap();
        assert!(Table::from_json(&j).is_none());
    }

    #[test]
    fn from_json_rejects_zero_column_tables() {
        // render()'s width math assumes >= 1 column; a degenerate
        // stored table must read as invalid, not panic at replay time
        let j = crate::util::json::Json::parse(
            r#"{"title":"","headers":[],"rows":[[]]}"#,
        )
        .unwrap();
        assert!(Table::from_json(&j).is_none());
    }
}
