//! Aligned plain-text tables — the benches print the paper's tables and
//! figure series as text rows, and this keeps them readable and diffable.

#[derive(Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: Some(title.to_string()),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {} ==\n", t));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = &cells[i];
                line.push_str(c);
                for _ in c.chars().count()..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a number with engineering notation (for power/area cells).
pub fn eng(v: f64) -> String {
    let a = v.abs();
    if v == 0.0 {
        "0".into()
    } else if a >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else if a >= 1.0 {
        format!("{:.3}", v)
    } else if a >= 1e-3 {
        format!("{:.2}m", v * 1e3)
    } else if a >= 1e-6 {
        format!("{:.2}u", v * 1e6)
    } else if a >= 1e-9 {
        format!("{:.2}n", v * 1e9)
    } else {
        format!("{:.2}p", v * 1e12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows align on the second column
        let col = lines[1].find("val").unwrap();
        assert_eq!(lines[3].find('1'), Some(col));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn eng_formatting() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(1.5e-3), "1.50m");
        assert_eq!(eng(2.0e6), "2.00M");
        assert_eq!(eng(96.0e-3), "96.00m");
    }
}
