//! Substrate utilities built from scratch (the offline crate registry has
//! no serde / rand / clap / proptest / criterion — see DESIGN.md §4).

pub mod cli;
pub mod json;
pub mod num;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
