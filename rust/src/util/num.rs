//! Exact integer numerics the float-based helpers get subtly wrong, plus
//! the FNV-1a hash the content-addressed results store fingerprints with.

/// Exact ceil(log2(x)) for x >= 1 (0 for x <= 1).
///
/// The float route `(x as f64).log2().ceil()` mis-sizes at power-of-two
/// boundaries once `x as f64` rounds: e.g. `2^53 + 1` rounds to `2^53`,
/// whose log2 is exactly 53.0, so the float ceil answers 53 where the
/// exact answer is 54. This version never touches floats.
pub fn ceil_log2(x: u64) -> u32 {
    if x <= 1 {
        0
    } else {
        64 - (x - 1).leading_zeros()
    }
}

/// FNV-1a offset basis: the initial state every 64-bit FNV-1a stream
/// starts from (streaming callers may mix extra entropy into it).
pub const FNV1A64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One 64-bit FNV-1a step — the single fold both [`fnv1a64`] and
/// streaming callers (e.g. the serve layer's image hash) share, so the
/// algorithm can never silently diverge between copies.
#[inline]
pub fn fnv1a64_step(h: u64, byte: u8) -> u64 {
    (h ^ byte as u64).wrapping_mul(0x0000_0100_0000_01b3)
}

/// 64-bit FNV-1a over raw bytes — deterministic across runs and
/// platforms (unlike `DefaultHasher`, which is seeded per process), so
/// it is safe to key on-disk cache entries with.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    bytes.iter().fold(FNV1A64_OFFSET, |h, &b| fnv1a64_step(h, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn ceil_log2_known_values() {
        assert_eq!(ceil_log2(0), 0);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(ceil_log2(u64::MAX), 64);
    }

    #[test]
    fn ceil_log2_exact_at_power_of_two_boundaries() {
        for k in 1..63u32 {
            let p = 1u64 << k;
            assert_eq!(ceil_log2(p), k, "2^{k}");
            assert_eq!(ceil_log2(p - 1), k, "2^{k}-1");
            assert_eq!(ceil_log2(p + 1), k + 1, "2^{k}+1");
        }
    }

    #[test]
    fn ceil_log2_beats_the_float_version_where_floats_round() {
        // 2^53 + 1 is not representable as f64: the cast rounds down to
        // 2^53 and the float ceil under-sizes by one bit
        let x = (1u64 << 53) + 1;
        let float_bits = (x as f64).log2().ceil() as u32;
        assert_eq!(float_bits, 53, "float rounds the boundary away");
        assert_eq!(ceil_log2(x), 54, "exact version does not");
    }

    #[test]
    fn ceil_log2_matches_float_over_small_range() {
        // exhaustive agreement where f64 is exact (the practical CLI
        // range): the fix must not change any in-range answer
        for x in 1u64..=1 << 16 {
            assert_eq!(
                ceil_log2(x),
                (x as f64).log2().ceil() as u32,
                "x = {x}"
            );
        }
    }

    #[test]
    fn prop_ceil_log2_is_the_least_sufficient_bit_count() {
        prop::check("2^(r-1) < x <= 2^r", 500, |g| {
            let x = g.u64().max(2);
            let r = ceil_log2(x);
            // x fits in 2^r values...
            if r < 64 && (1u64 << r) < x {
                return Err(format!("2^{r} < {x}"));
            }
            // ...and r is minimal
            if (1u64 << (r - 1)) >= x {
                return Err(format!("2^{} already >= {x}", r - 1));
            }
            Ok(())
        });
    }

    #[test]
    fn fnv_is_stable_and_collision_free_on_distinct_keys() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        // pinned vector (any change to the hash invalidates stores)
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..1000u32 {
            assert!(seen.insert(fnv1a64(format!("key-{i}").as_bytes())));
        }
    }
}
