//! Mini property-based-testing harness (the offline registry has no
//! `proptest`). Deterministic generators driven by [`Pcg`], a fixed
//! number of cases per property, and input shrinking by halving.
//!
//! Usage:
//! ```ignore
//! prop::check("batch never exceeds capacity", 200, |g| {
//!     let cap = g.usize_in(1, 64);
//!     let n = g.usize_in(0, 1000);
//!     // ... return Ok(()) or Err(description)
//! });
//! ```

use super::rng::Pcg;

pub struct Gen {
    rng: Pcg,
    /// log of drawn values for the failure report
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Pcg::new(seed), trace: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = lo + self.rng.below(hi - lo + 1);
        self.trace.push(format!("usize {}", v));
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.trace.push(format!("u64 {}", v));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("f64 {}", v));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.uniform() < 0.5;
        self.trace.push(format!("bool {}", v));
        v
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.rng.below(items.len());
        self.trace.push(format!("pick[{}]", i));
        &items[i]
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| lo + self.rng.below(hi - lo + 1)).collect()
    }

    pub fn rng(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Run `cases` random cases of `property`. Panics with the failing seed and
/// the generator trace on the first failure.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut g = Gen::new(seed);
        if let Err(msg) = property(&mut g) {
            panic!(
                "property '{}' failed (case {}, seed {:#x}): {}\n  drawn: {:?}",
                name, case, seed, msg, g.trace
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            let x = g.usize_in(0, 10);
            count += 1;
            if x <= 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_trace() {
        check("must fail", 50, |g| {
            let x = g.usize_in(0, 100);
            if x < 95 {
                Ok(())
            } else {
                Err(format!("x = {}", x))
            }
        });
    }

    #[test]
    fn generators_are_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        assert_eq!(a.u64(), b.u64());
        assert_eq!(a.usize_in(0, 9), b.usize_in(0, 9));
    }
}
