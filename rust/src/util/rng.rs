//! PCG64-based PRNG + distributions (the offline registry has no `rand`).
//!
//! PCG-XSH-RR 64/32 with two independent streams combined for 64-bit
//! output. Deterministic per seed — every simulation result in
//! EXPERIMENTS.md is reproducible from its seed.

/// Fork-index namespaces: every subsystem that derives [`Pcg::fork`]
/// streams from a user seed owns one disjoint window of fork indices.
///
/// Before these existed, `serve::loadgen` forked at
/// `point * shards + shard` and `event` at `replica * shards + shard` —
/// both small dense integers starting at 0 — so two subsystems sharing a
/// root seed (the default is 42 everywhere) drew the *same* derived
/// streams for their first inputs. Each consumer now ORs its namespace
/// constant over its local index via [`fork_idx`]; local indices stay
/// dense and small, the high bits keep the windows pairwise disjoint
/// (asserted by `fork_namespaces_are_pairwise_disjoint`). Adding a new
/// forking subsystem means claiming the next constant here — never
/// reusing raw small indices.
pub const FORK_NS_BITS: u32 = 40;
/// `serve::loadgen` sweep inputs: local index `point * shards + shard`.
pub const FORK_NS_LOADGEN: u64 = 1 << FORK_NS_BITS;
/// `event` request profiles: local index `replica * shards + shard`.
pub const FORK_NS_EVENT: u64 = 2 << FORK_NS_BITS;
/// `serve::fleet` arrival-process streams (gap / thinning / burst).
pub const FORK_NS_FLEET: u64 = 3 << FORK_NS_BITS;
/// `offload` placement-search streams (hill-climb restarts / bandit arms).
pub const FORK_NS_OFFLOAD: u64 = 4 << FORK_NS_BITS;

/// Compose a namespaced fork index: `ns` is one of the `FORK_NS_*`
/// constants, `idx` the subsystem-local dense index (must fit below the
/// namespace bits so windows cannot collide).
#[inline]
pub fn fork_idx(ns: u64, idx: u64) -> u64 {
    debug_assert!(idx < (1u64 << FORK_NS_BITS),
                  "fork index {idx} overflows its namespace window");
    ns | idx
}

#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// cached second gaussian from Box-Muller
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (seed << 1) | 1, spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed ^ 0x9e3779b97f4a7c15);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-component noise).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ tag.wrapping_mul(0xda942042e4dd58b5))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection for unbiased sampling
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Lognormal multiplicative factor e^(sigma * z) — RRAM conductance
    /// variation model (§4.1.2 step 4).
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let r = (a as u128) * (b as u128);
    ((r >> 64) as u64, r as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(43);
        assert_ne!(Pcg::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Pcg::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {}", var);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(2);
        let n = 100_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
            s3 += z * z * z;
        }
        assert!((s / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.03);
        assert!((s3 / n as f64).abs() < 0.05); // symmetry
    }

    #[test]
    fn below_one_is_always_zero() {
        let mut rng = Pcg::new(12);
        for _ in 0..100 {
            assert_eq!(rng.below(1), 0);
        }
    }

    #[test]
    fn below_is_unbiased() {
        let mut rng = Pcg::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{:?}", counts);
        }
    }

    #[test]
    fn lognormal_is_positive_unit_median() {
        let mut rng = Pcg::new(4);
        let mut logs = 0.0;
        for _ in 0..10_000 {
            let f = rng.lognormal_factor(0.025);
            assert!(f > 0.0);
            logs += f.ln();
        }
        assert!((logs / 10_000.0).abs() < 0.002);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Pcg::new(7);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn fork_namespaces_are_pairwise_disjoint() {
        // the windows [ns, ns + 2^FORK_NS_BITS) must not overlap for any
        // local index a subsystem can legally use
        let spans =
            [FORK_NS_LOADGEN, FORK_NS_EVENT, FORK_NS_FLEET, FORK_NS_OFFLOAD];
        let width = 1u64 << FORK_NS_BITS;
        for (i, &a) in spans.iter().enumerate() {
            assert_eq!(a % width, 0, "namespace {a:#x} misaligned");
            for &b in &spans[i + 1..] {
                let (lo, hi) = (a.min(b), a.max(b));
                assert!(lo + width <= hi,
                        "windows {lo:#x} and {hi:#x} overlap");
            }
        }
        // and the composed indices land inside their own window
        assert_eq!(fork_idx(FORK_NS_LOADGEN, 0), FORK_NS_LOADGEN);
        assert_eq!(fork_idx(FORK_NS_EVENT, width - 1),
                   FORK_NS_EVENT | (width - 1));
        // same root seed, same local index, different subsystem:
        // different stream (the collision the namespaces exist to kill)
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        let mut fa = a.fork(fork_idx(FORK_NS_LOADGEN, 0));
        let mut fb = b.fork(fork_idx(FORK_NS_EVENT, 0));
        assert_ne!(fa.next_u64(), fb.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
