//! Descriptive statistics + the paper's SINAD metric (§5.3.1).

/// Arithmetic mean; 0 for empty input.
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}

/// Population standard deviation.
pub fn std(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
}

/// Geometric mean (used for the headline cross-benchmark speedups).
pub fn geomean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, p)
}

/// [`percentile`] over an ALREADY-SORTED slice — for callers that need
/// several percentiles of one dataset without re-sorting per call.
pub fn percentile_sorted(s: &[f64], p: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    debug_assert!(s.windows(2).all(|w| w[0] <= w[1]), "unsorted input");
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn min(v: &[f64]) -> f64 {
    v.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(v: &[f64]) -> f64 {
    v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// SINAD in dB per §5.3.1: 10 log10((P_sig + P_noise) / P_noise) with
/// P_noise = mean((hw - sw)^2) and P_sig the variance of the ideal signal.
pub fn sinad_db(d_hw: &[f64], d_sw: &[f64]) -> f64 {
    assert_eq!(d_hw.len(), d_sw.len());
    let err: Vec<f64> = d_hw.iter().zip(d_sw).map(|(h, s)| h - s).collect();
    let p_noise = err.iter().map(|e| e * e).sum::<f64>() / err.len() as f64;
    let m = mean(d_sw);
    let p_sig = d_sw.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
        / d_sw.len() as f64;
    10.0 * ((p_sig + p_noise) / p_noise.max(1e-30)).log10()
}

/// Ordinary least squares y = a*x + b. Returns (a, b).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    let a = if den == 0.0 { 0.0 } else { num / den };
    (a, my - a * mx)
}

/// Online timing accumulator for the bench harness.
#[derive(Default, Clone, Debug)]
pub struct Samples {
    pub values: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }

    pub fn std(&self) -> f64 {
        std(&self.values)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.values, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.values, 99.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "mean {:.3}{u} ± {:.3}{u} (p50 {:.3}{u}, p99 {:.3}{u}, n={})",
            self.mean(),
            self.std(),
            self.p50(),
            self.p99(),
            self.values.len(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn basic_moments() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&v) - 2.5).abs() < 1e-12);
        assert!((std(&v) - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_input_edge_cases() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // min/max of nothing are the fold identities — callers guard
        assert_eq!(min(&[]), f64::INFINITY);
        assert_eq!(max(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn single_element_edge_cases() {
        let v = [7.5];
        assert_eq!(mean(&v), 7.5);
        assert_eq!(std(&v), 0.0);
        assert!((geomean(&v) - 7.5).abs() < 1e-12);
        for p in [0.0, 37.0, 50.0, 100.0] {
            assert_eq!(percentile(&v, p), 7.5, "p = {p}");
        }
        assert_eq!(min(&v), 7.5);
        assert_eq!(max(&v), 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&v, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&v, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_property_bounds_endpoints_monotonicity() {
        prop::check("percentile in [min,max], exact endpoints, monotone",
                    150, |g| {
            let n = g.usize_in(1, 60);
            let v = g.vec_f64(n, -1e3, 1e3);
            let p1 = g.f64_in(0.0, 100.0);
            let p2 = g.f64_in(0.0, 100.0);
            let (lo, hi) = (p1.min(p2), p1.max(p2));
            crate::prop_assert!(percentile(&v, 0.0) == min(&v), "p0 != min");
            crate::prop_assert!(percentile(&v, 100.0) == max(&v),
                                "p100 != max");
            let (qlo, qhi) = (percentile(&v, lo), percentile(&v, hi));
            crate::prop_assert!(qlo <= qhi + 1e-9,
                                "not monotone: q({lo})={qlo} > q({hi})={qhi}");
            crate::prop_assert!(min(&v) - 1e-9 <= qlo && qhi <= max(&v) + 1e-9,
                                "out of range");
            Ok(())
        });
    }

    #[test]
    fn moment_properties() {
        prop::check("std >= 0, mean in [min,max], constant-vector identities",
                    150, |g| {
            let n = g.usize_in(1, 40);
            let v = g.vec_f64(n, 0.5, 100.0);
            crate::prop_assert!(std(&v) >= 0.0, "negative std");
            let m = mean(&v);
            crate::prop_assert!(min(&v) - 1e-9 <= m && m <= max(&v) + 1e-9,
                                "mean {m} outside data range");
            // geomean <= arithmetic mean on positive data (AM-GM)
            crate::prop_assert!(geomean(&v) <= m + 1e-9 * m.abs(),
                                "AM-GM violated");
            let c = g.f64_in(0.1, 10.0);
            let cv = vec![c; n];
            crate::prop_assert!((geomean(&cv) - c).abs() < 1e-9 * c,
                                "geomean of constant vector");
            crate::prop_assert!(std(&cv) < 1e-9, "nonzero constant std");
            crate::prop_assert!(percentile(&cv, g.f64_in(0.0, 100.0)) == c,
                                "percentile of constant vector");
            Ok(())
        });
    }

    #[test]
    fn sinad_of_clean_signal_is_large() {
        let sw: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 100.0).collect();
        let hw = sw.clone();
        assert!(sinad_db(&hw, &sw) > 100.0);
    }

    #[test]
    fn sinad_known_ratio() {
        // noise with power 1, signal with power 100 -> ~20 dB
        let sw: Vec<f64> = (0..20000)
            .map(|i| 10.0 * f64::sqrt(2.0) * (i as f64 * 0.01).sin())
            .collect();
        let hw: Vec<f64> = sw
            .iter()
            .enumerate()
            .map(|(i, s)| s + if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let s = sinad_db(&hw, &sw);
        assert!((s - 10.0 * (101.0f64).log10()).abs() < 0.3, "{}", s);
    }

    #[test]
    fn linreg_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        let (a, b) = linreg(&x, &y);
        assert!((a - 3.0).abs() < 1e-9 && (b + 7.0).abs() < 1e-9);
    }
}
