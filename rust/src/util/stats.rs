//! Descriptive statistics + the paper's SINAD metric (§5.3.1).

/// Arithmetic mean; 0 for empty input.
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}

/// Population standard deviation.
pub fn std(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
}

/// Geometric mean (used for the headline cross-benchmark speedups).
pub fn geomean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, p)
}

/// [`percentile`] over an ALREADY-SORTED slice — for callers that need
/// several percentiles of one dataset without re-sorting per call.
pub fn percentile_sorted(s: &[f64], p: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    debug_assert!(s.windows(2).all(|w| w[0] <= w[1]), "unsorted input");
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Smallest sample count at which [`tail_percentile`] reports the p-th
/// percentile: `ceil(100 / (100 - p))`, i.e. enough samples that at
/// least one whole sample lies beyond the requested rank (p99.9 needs
/// 1000). Below it a nearest-rank tail percentile degenerates to the
/// sample maximum and reports noise, not a tail.
pub fn tail_min_samples(p: f64) -> usize {
    debug_assert!((0.0..100.0).contains(&p) && p > 0.0, "tail p {p}");
    (100.0 / (100.0 - p)).ceil() as usize
}

/// Tail percentile with **nearest-rank** semantics: the smallest sample
/// such that at least `p`% of the data is `<=` it — `s[ceil(p/100 * n)
/// - 1]` of the sorted data, never interpolated (a tail quantile
/// interpolated between the two largest samples manufactures values no
/// request ever saw). Returns `None` (never NaN, never the max dressed
/// up as a tail) below [`tail_min_samples`].
pub fn tail_percentile(v: &[f64], p: f64) -> Option<f64> {
    if v.len() < tail_min_samples(p) {
        return None;
    }
    let mut s: Vec<f64> = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    tail_percentile_sorted(&s, p)
}

/// [`tail_percentile`] over an ALREADY-SORTED slice.
pub fn tail_percentile_sorted(s: &[f64], p: f64) -> Option<f64> {
    if s.len() < tail_min_samples(p) {
        return None;
    }
    debug_assert!(s.windows(2).all(|w| w[0] <= w[1]), "unsorted input");
    let rank = (p / 100.0 * s.len() as f64).ceil() as usize;
    Some(s[rank.clamp(1, s.len()) - 1])
}

pub fn min(v: &[f64]) -> f64 {
    v.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(v: &[f64]) -> f64 {
    v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// SINAD in dB per §5.3.1: 10 log10((P_sig + P_noise) / P_noise) with
/// P_noise = mean((hw - sw)^2) and P_sig the variance of the ideal signal.
pub fn sinad_db(d_hw: &[f64], d_sw: &[f64]) -> f64 {
    assert_eq!(d_hw.len(), d_sw.len());
    let err: Vec<f64> = d_hw.iter().zip(d_sw).map(|(h, s)| h - s).collect();
    let p_noise = err.iter().map(|e| e * e).sum::<f64>() / err.len() as f64;
    let m = mean(d_sw);
    let p_sig = d_sw.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
        / d_sw.len() as f64;
    10.0 * ((p_sig + p_noise) / p_noise.max(1e-30)).log10()
}

/// Ordinary least squares y = a*x + b. Returns (a, b).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    let a = if den == 0.0 { 0.0 } else { num / den };
    (a, my - a * mx)
}

/// Online timing accumulator for the bench harness.
#[derive(Default, Clone, Debug)]
pub struct Samples {
    pub values: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }

    pub fn std(&self) -> f64 {
        std(&self.values)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.values, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.values, 99.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "mean {:.3}{u} ± {:.3}{u} (p50 {:.3}{u}, p99 {:.3}{u}, n={})",
            self.mean(),
            self.std(),
            self.p50(),
            self.p99(),
            self.values.len(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn basic_moments() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&v) - 2.5).abs() < 1e-12);
        assert!((std(&v) - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_input_edge_cases() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // min/max of nothing are the fold identities — callers guard
        assert_eq!(min(&[]), f64::INFINITY);
        assert_eq!(max(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn single_element_edge_cases() {
        let v = [7.5];
        assert_eq!(mean(&v), 7.5);
        assert_eq!(std(&v), 0.0);
        assert!((geomean(&v) - 7.5).abs() < 1e-12);
        for p in [0.0, 37.0, 50.0, 100.0] {
            assert_eq!(percentile(&v, p), 7.5, "p = {p}");
        }
        assert_eq!(min(&v), 7.5);
        assert_eq!(max(&v), 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&v, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&v, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_property_bounds_endpoints_monotonicity() {
        prop::check("percentile in [min,max], exact endpoints, monotone",
                    150, |g| {
            let n = g.usize_in(1, 60);
            let v = g.vec_f64(n, -1e3, 1e3);
            let p1 = g.f64_in(0.0, 100.0);
            let p2 = g.f64_in(0.0, 100.0);
            let (lo, hi) = (p1.min(p2), p1.max(p2));
            crate::prop_assert!(percentile(&v, 0.0) == min(&v), "p0 != min");
            crate::prop_assert!(percentile(&v, 100.0) == max(&v),
                                "p100 != max");
            let (qlo, qhi) = (percentile(&v, lo), percentile(&v, hi));
            crate::prop_assert!(qlo <= qhi + 1e-9,
                                "not monotone: q({lo})={qlo} > q({hi})={qhi}");
            crate::prop_assert!(min(&v) - 1e-9 <= qlo && qhi <= max(&v) + 1e-9,
                                "out of range");
            Ok(())
        });
    }

    #[test]
    fn moment_properties() {
        prop::check("std >= 0, mean in [min,max], constant-vector identities",
                    150, |g| {
            let n = g.usize_in(1, 40);
            let v = g.vec_f64(n, 0.5, 100.0);
            crate::prop_assert!(std(&v) >= 0.0, "negative std");
            let m = mean(&v);
            crate::prop_assert!(min(&v) - 1e-9 <= m && m <= max(&v) + 1e-9,
                                "mean {m} outside data range");
            // geomean <= arithmetic mean on positive data (AM-GM)
            crate::prop_assert!(geomean(&v) <= m + 1e-9 * m.abs(),
                                "AM-GM violated");
            let c = g.f64_in(0.1, 10.0);
            let cv = vec![c; n];
            crate::prop_assert!((geomean(&cv) - c).abs() < 1e-9 * c,
                                "geomean of constant vector");
            crate::prop_assert!(std(&cv) < 1e-9, "nonzero constant std");
            crate::prop_assert!(percentile(&cv, g.f64_in(0.0, 100.0)) == c,
                                "percentile of constant vector");
            Ok(())
        });
    }

    #[test]
    fn tail_min_samples_at_the_usual_tails() {
        assert_eq!(tail_min_samples(50.0), 2);
        assert_eq!(tail_min_samples(99.0), 100);
        assert_eq!(tail_min_samples(99.9), 1000);
    }

    #[test]
    fn tail_percentile_guards_small_samples() {
        // 999 samples: p99.9 would just be the max — refuse
        let v: Vec<f64> = (0..999).map(|i| i as f64).collect();
        assert_eq!(tail_percentile(&v, 99.9), None);
        // one more sample crosses the guard
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert!(tail_percentile(&v, 99.9).is_some());
        assert_eq!(tail_percentile(&[], 50.0), None);
        assert_eq!(tail_percentile(&[1.0], 50.0), None);
    }

    #[test]
    fn tail_percentile_nearest_rank_at_exact_boundaries() {
        // n = 1000, values 1..=1000: nearest-rank p99.9 is
        // s[ceil(0.999 * 1000) - 1] = s[998] = 999 — one whole sample
        // (the max, 1000) lies beyond it
        let v: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(tail_percentile(&v, 99.9), Some(999.0));
        // n = 100: p99 ranks at ceil(99) = 99 -> s[98] = 99
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(tail_percentile(&v, 99.0), Some(99.0));
        // p50 of [1..=4] nearest-rank: ceil(2) = 2 -> s[1] = 2 (no
        // interpolation, unlike `percentile` which reports 2.5)
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(tail_percentile(&v, 50.0), Some(2.0));
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        // a tail value is always an actual sample
        let v: Vec<f64> = (0..2500).map(|i| (i as f64).sqrt()).collect();
        let t = tail_percentile(&v, 99.9).unwrap();
        assert!(v.contains(&t));
    }

    #[test]
    fn tail_percentile_unsorted_matches_sorted() {
        let mut v: Vec<f64> = (0..1200).map(|i| ((i * 7919) % 997) as f64)
            .collect();
        let a = tail_percentile(&v, 99.9);
        v.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(a, tail_percentile_sorted(&v, 99.9));
        assert!(a.is_some());
    }

    #[test]
    fn sinad_of_clean_signal_is_large() {
        let sw: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 100.0).collect();
        let hw = sw.clone();
        assert!(sinad_db(&hw, &sw) > 100.0);
    }

    #[test]
    fn sinad_known_ratio() {
        // noise with power 1, signal with power 100 -> ~20 dB
        let sw: Vec<f64> = (0..20000)
            .map(|i| 10.0 * f64::sqrt(2.0) * (i as f64 * 0.01).sin())
            .collect();
        let hw: Vec<f64> = sw
            .iter()
            .enumerate()
            .map(|(i, s)| s + if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let s = sinad_db(&hw, &sw);
        assert!((s - 10.0 * (101.0f64).log10()).abs() < 0.3, "{}", s);
    }

    #[test]
    fn linreg_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        let (a, b) = linreg(&x, &y);
        assert!((a - 3.0).abs() < 1e-9 && (b + 7.0).abs() < 1e-9);
    }
}
