//! Minimal JSON parser/writer.
//!
//! The offline crate registry has no `serde`, so the artifacts
//! (`periph.json`, `cnn.json`, `manifest.json`) are read with this
//! self-contained recursive-descent parser. It supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bool, null)
//! and preserves number precision as f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a (possibly nested) numeric array into `out`, returning the
    /// per-dimension shape. Used for the weight tensors in periph/cnn.json.
    pub fn to_f32_tensor(&self) -> Option<(Vec<usize>, Vec<f32>)> {
        let mut out = Vec::new();
        let mut shape = Vec::new();
        fn walk(j: &Json, depth: usize, shape: &mut Vec<usize>,
                out: &mut Vec<f32>) -> bool {
            match j {
                Json::Num(n) => {
                    if depth != shape.len() {
                        return false;
                    }
                    out.push(*n as f32);
                    true
                }
                Json::Arr(a) => {
                    if depth == shape.len() {
                        shape.push(a.len());
                    } else if shape[depth] != a.len() {
                        return false; // ragged
                    }
                    a.iter().all(|x| walk(x, depth + 1, shape, out))
                }
                _ => false,
            }
        }
        if walk(self, 0, &mut shape, &mut out) {
            Some((shape, out))
        } else {
            None
        }
    }

    // -- writer ----------------------------------------------------------
    // serialization goes through `Display`, so `.to_string()` keeps
    // working at every call site

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(true) => s.push_str("true"),
            Json::Bool(false) => s.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{}", n));
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\r' => s.push_str("\\r"),
                        '\t' => s.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            s.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(a) => {
                s.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    v.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }
}

impl Json {
    /// Pretty-printed serialization (2-space indent) for the on-disk
    /// results store and suite reports — same grammar as `Display`,
    /// just human-diffable. `Json::parse` round-trips both forms.
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write_pretty(&self, s: &mut String, indent: usize) {
        const STEP: usize = 2;
        match self {
            Json::Arr(a) if !a.is_empty() => {
                s.push('[');
                for (i, v) in a.iter().enumerate() {
                    s.push_str(if i > 0 { ",\n" } else { "\n" });
                    s.push_str(&" ".repeat(indent + STEP));
                    v.write_pretty(s, indent + STEP);
                }
                s.push('\n');
                s.push_str(&" ".repeat(indent));
                s.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                s.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    s.push_str(if i > 0 { ",\n" } else { "\n" });
                    s.push_str(&" ".repeat(indent + STEP));
                    Json::Str(k.clone()).write(s);
                    s.push_str(": ");
                    v.write_pretty(s, indent + STEP);
                }
                s.push('\n');
                s.push_str(&" ".repeat(indent));
                s.push('}');
            }
            // scalars and empty containers render compactly
            other => other.write(s),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (fast path for big tensors)
                    let start = self.pos;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64(), Some(2.0));
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn tensor_extraction() {
        let j = Json::parse("[[1,2,3],[4,5,6]]").unwrap();
        let (shape, data) = j.to_f32_tensor().unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn tensor_rejects_ragged() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        assert!(j.to_f32_tensor().is_none());
    }

    #[test]
    fn escapes_in_writer() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn pretty_round_trips_and_indents() {
        let j = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x","d":[],"e":{}}"#)
            .unwrap();
        let pretty = j.to_pretty_string();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains("\n  \"a\": [\n    1,"), "{pretty}");
        // empty containers stay compact
        assert!(pretty.contains("\"d\": []") && pretty.contains("\"e\": {}"));
    }

    /// Random `Json` value: scalars, escape-heavy strings, nested
    /// arrays/objects. Floats are drawn finite (JSON has no NaN/inf);
    /// some are rounded to integers to hit the integer-format fast path.
    fn gen_json(g: &mut crate::util::prop::Gen, depth: usize) -> Json {
        let pick = g.usize_in(0, if depth >= 3 { 4 } else { 6 });
        match pick {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => {
                let v = g.f64_in(-1e18, 1e18);
                Json::Num(if g.bool() { v.trunc() } else { v })
            }
            3 => Json::Num(g.f64_in(-1e-6, 1e-6)),
            4 => {
                let n = g.usize_in(0, 12);
                let s: String = (0..n)
                    .map(|_| {
                        *g.pick(&[
                            'a', 'β', '"', '\\', '\n', '\t', '\r', '\u{8}',
                            '\u{c}', '\u{1}', '/', '𝄞', ' ',
                        ])
                    })
                    .collect();
                Json::Str(s)
            }
            5 => {
                let n = g.usize_in(0, 4);
                Json::Arr((0..n).map(|_| gen_json(g, depth + 1)).collect())
            }
            _ => {
                let n = g.usize_in(0, 4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{}{}", i, g.usize_in(0, 9)),
                                  gen_json(g, depth + 1)))
                        .collect(),
                )
            }
        }
    }

    #[test]
    fn prop_serialization_round_trips() {
        // the results store depends on parse(to_string(j)) == j for
        // arbitrary outcomes: escapes, nesting, and float fidelity
        // (Display prints the shortest string that re-reads bit-exactly)
        crate::util::prop::check("json round-trip", 300, |g| {
            let j = gen_json(g, 0);
            let compact = Json::parse(&j.to_string())
                .map_err(|e| format!("compact re-parse failed: {e}"))?;
            if compact != j {
                return Err(format!("compact: {j:?} != {compact:?}"));
            }
            let pretty = Json::parse(&j.to_pretty_string())
                .map_err(|e| format!("pretty re-parse failed: {e}"))?;
            if pretty != j {
                return Err(format!("pretty: {j:?} != {pretty:?}"));
            }
            Ok(())
        });
    }
}
