//! Trait-based architecture cost-model layer.
//!
//! Before this subsystem existed, "what an architecture is" was encoded
//! as `match cfg.arch` arms scattered across `sim::layer_energy`,
//! `energy::{pe_budget,cycle_seconds}`, `baselines::pe_comparison`,
//! `config` and the DSE feasibility rules — adding a fourth dataflow
//! meant editing five layers in lockstep. Here every per-architecture
//! decision lives behind one [`CostModel`] trait with one impl per
//! architecture ([`archs`]), and the call sites iterate the
//! [`models`]/[`archs`] registry instead of a closed enum fan-out.
//!
//! Registering a new architecture therefore takes exactly two edits:
//! a variant in [`Architecture`] (the lightweight id the rest of the
//! crate passes around) and an impl + registry entry in `model/archs.rs`.
//! Every migrated call site — `simulate --all`, `table3`, the iso-area
//! Fig. 12 comparison, `event-sim`, the DSE feasibility rules, the CLI
//! parser — picks the newcomer up with zero further changes. The
//! RAELLA-inspired [`archs::LowResolutionModel`] is the proof: it exists
//! only in `archs.rs` plus its enum variant.
//!
//! The [`memo`] half of the subsystem owns the per-layer energy
//! computation ([`layer_cost`]) and the memoized per-`(network, config)`
//! [`NetworkCost`] table ([`network_cost`]) shared by the analytical
//! simulator, the report/DSE paths built on it, and the event
//! simulator's per-stage energy charging — the event request path used
//! to recompute the full layer-energy table once per replica.

pub mod archs;
mod memo;

pub use memo::{clear_cost_cache, cost_cache_counters, cost_cache_len,
               fill_cache_registry, layer_cost, network_cost,
               network_cost_hybrid, LayerCost, NetworkCost};

use crate::config::{AcceleratorConfig, Architecture, Precision};
use crate::energy::ComponentBudget;
use anyhow::{bail, Result};

/// Energy per inference, by component class (Fig. 13's categories).
/// Owned here so both the analytical simulator and the memoized layer
/// tables speak the same breakdown; `sim` re-exports it under its old
/// path.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub adc: f64,
    pub dac: f64,
    pub sa: f64,   // digital S+A / buffer writes+TIA / NNS+A+S/H
    pub xbar: f64, // VMM array reads
    pub memory: f64, // eDRAM + SRAM IR/OR
    pub noc: f64,  // c-mesh + HyperTransport
    pub digital: f64, // activation, pooling, element-wise
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.adc + self.dac + self.sa + self.xbar + self.memory + self.noc
            + self.digital
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.adc += other.adc;
        self.dac += other.dac;
        self.sa += other.sa;
        self.xbar += other.xbar;
        self.memory += other.memory;
        self.noc += other.noc;
        self.digital += other.digital;
    }

    pub fn categories(&self) -> [(&'static str, f64); 7] {
        [
            ("ADC", self.adc),
            ("DAC", self.dac),
            ("S+A", self.sa),
            ("Crossbar", self.xbar),
            ("Memory", self.memory),
            ("NoC+IO", self.noc),
            ("Digital", self.digital),
        ]
    }
}

/// Everything a cost model needs about one mapped layer to price its
/// conversion/accumulation interface (the quantities `sim::layer_energy`
/// derives before dispatching).
pub struct LayerCtx<'a> {
    pub cfg: &'a AcceleratorConfig,
    pub p: &'a Precision,
    /// log2 of the crossbar side
    pub n: u32,
    /// input cycles per full-precision input (Eq. 8)
    pub cycles: u64,
    /// sliding-window positions per inference
    pub positions: u64,
    /// output channels of the layer
    pub cout: u64,
    /// dot-product group-chunks per inference (positions x cout x k-chunks)
    pub group_chunks: u64,
    /// active array-cycles per inference
    pub array_cycles: u64,
}

/// The architecture-specific slice of a layer's energy: conversion,
/// accumulation, interface-local memory traffic and digital post-ops.
/// The common terms (DAC, crossbar, memory hierarchy, NoC, activation)
/// are charged identically for every architecture by [`layer_cost`].
#[derive(Debug, Clone, Copy, Default)]
pub struct InterfaceEnergy {
    pub adc: f64,
    pub sa: f64,
    pub memory: f64,
    pub digital: f64,
}

/// Table-3 row metadata for one architecture.
#[derive(Debug, Clone, Copy)]
pub struct PeMetadata {
    pub accumulation: &'static str,
    pub interface: &'static str,
    /// the A/D resolution the paper's Table 3 reports for this dataflow
    pub adc_bits: u32,
}

/// One accumulation architecture: its default chip, dataflow equations,
/// per-layer interface energy, PE periphery, and DSE service rates.
///
/// Implementations live in [`archs`]; nothing outside `model/` may
/// dispatch on [`Architecture`] (grep-enforced by `scripts/verify.sh`).
pub trait CostModel: Sync {
    /// The id this model is registered under.
    fn arch(&self) -> Architecture;

    /// Display name (tables, CLI output).
    fn name(&self) -> &'static str;

    /// Accepted `--arch` spellings, lowercase.
    fn aliases(&self) -> &'static [&'static str];

    /// The architecture's default full-chip configuration (Table 2 for
    /// Neural-PIM, the §6.1 baseline configs otherwise).
    fn default_config(&self) -> AcceleratorConfig;

    /// Architecture-specific validation beyond the common rules.
    fn validate_config(&self, _cfg: &AcceleratorConfig) -> Result<()> {
        Ok(())
    }

    /// Input-cycle time in ns (the Fig. 12b throughput mechanism).
    fn cycle_ns(&self) -> f64;

    /// A/D resolution this dataflow converts at (Eq. 2/3/4 class).
    fn adc_resolution(&self, p: &Precision, n: u32) -> u32;

    /// A/D conversions per dot-product group (Eq. 5/6/7 class).
    fn conversions_per_group(&self, p: &Precision) -> u64;

    /// Shift-and-add operations scheduled per inference of one mapped
    /// layer — the op count the observability counters report (the
    /// energy charged per op is the model's own business in
    /// [`CostModel::interface_energy`]). Default: one digital S+A per
    /// scheduled conversion, which is exact for the ISAAC-like and
    /// RAELLA-like dataflows; CASCADE's buffer-write accumulation is
    /// charged at the same per-conversion granularity. Analog-
    /// accumulation models override (Neural-PIM clocks its NNS+A every
    /// input cycle of every group-chunk).
    fn sa_ops(&self, ctx: &LayerCtx) -> u64 {
        ctx.group_chunks * self.conversions_per_group(ctx.p)
    }

    /// The architecture-specific slice of one mapped layer's energy.
    fn interface_energy(&self, ctx: &LayerCtx) -> InterfaceEnergy;

    /// Full-layer pricing override. `None` (the default) means the
    /// layer is priced by [`layer_cost`]'s crossbar dataflow: the common
    /// DAC/crossbar/memory/NoC terms plus [`CostModel::interface_energy`].
    /// A model that is *not* a crossbar VMM (the digital NPU) returns
    /// `Some` and owns the whole [`LayerCost`]; `layer_cost` consults
    /// this first, so non-crossbar architectures register without
    /// leaking their dataflow into the common path.
    fn price_layer(&self, _lm: &crate::mapping::LayerMapping,
                   _cfg: &AcceleratorConfig, _multi_chip: bool)
                   -> Option<LayerCost> {
        None
    }

    /// Whether the PE front-end is analog (crossbar + DAC rows in
    /// [`crate::energy::pe_budget`]). The digital NPU opts out: its MAC
    /// lanes and weight SRAM are listed via
    /// [`CostModel::peripheral_components`] instead.
    fn analog_frontend(&self) -> bool {
        true
    }

    /// PE periphery beyond the common crossbar + DAC rows
    /// (`energy::pe_budget` appends these).
    fn peripheral_components(&self, cfg: &AcceleratorConfig)
                             -> Vec<ComponentBudget>;

    /// Table 3 row metadata.
    fn pe_metadata(&self, cfg: &AcceleratorConfig) -> PeMetadata;

    /// Shared-converter service rate in samples/s (DSE feasibility).
    fn adc_samples_per_s(&self) -> f64;

    /// Analog accumulator service rate in ops/s; `None` means digital
    /// accumulation with no per-cycle analog rate limit.
    fn sa_ops_per_s(&self) -> Option<f64> {
        None
    }
}

/// The registry: every architecture the toolchain knows, in the order
/// reports and comparisons iterate them. Append here to register.
static MODELS: [&dyn CostModel; 5] = [
    &archs::IsaacLikeModel,
    &archs::CascadeLikeModel,
    &archs::NeuralPimModel,
    &archs::LowResolutionModel,
    &archs::NpuModel,
];

/// All registered cost models, in registry order.
pub fn models() -> &'static [&'static dyn CostModel] {
    &MODELS
}

/// All registered architecture ids, in registry order (the replacement
/// for the old closed `Architecture::all()` fan-outs).
pub fn archs() -> Vec<Architecture> {
    MODELS.iter().map(|m| m.arch()).collect()
}

/// The flagship architecture comparisons are normalized against.
pub fn reference() -> Architecture {
    Architecture::NeuralPim
}

/// Look up the cost model registered for `arch`.
pub fn cost_model(arch: Architecture) -> &'static dyn CostModel {
    *MODELS
        .iter()
        .find(|m| m.arch() == arch)
        .unwrap_or_else(|| panic!("architecture {arch:?} has no registered \
                                   cost model"))
}

/// Parse an `--arch` string against every registered model's name and
/// aliases.
pub fn parse_arch(s: &str) -> Result<Architecture> {
    let want = s.to_ascii_lowercase();
    for m in MODELS {
        if m.name().to_ascii_lowercase() == want
            || m.aliases().contains(&want.as_str())
        {
            return Ok(m.arch());
        }
    }
    bail!("unknown architecture '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_unique_ids_and_covers_the_paper_archs() {
        let a = archs();
        // the three paper architectures plus at least one registered
        // extension (no exact count: registering a new arch must not
        // require editing this test)
        assert!(a.len() >= 4, "registry shrank: {a:?}");
        for (i, x) in a.iter().enumerate() {
            for y in &a[i + 1..] {
                assert_ne!(x, y, "duplicate registry entry");
            }
        }
        for required in [Architecture::IsaacLike, Architecture::CascadeLike,
                         Architecture::NeuralPim] {
            assert!(a.contains(&required), "{required:?} missing");
        }
        assert!(a.contains(&reference()));
    }

    #[test]
    fn every_model_is_self_consistent() {
        for m in models() {
            let cfg = m.default_config();
            assert_eq!(cfg.arch, m.arch(), "{} default config arch", m.name());
            cfg.validate().unwrap();
            assert!(m.cycle_ns() > 0.0);
            assert!(m.adc_samples_per_s() > 0.0);
            let p = cfg.precision;
            let n = cfg.n_log2();
            assert!(m.adc_resolution(&p, n) >= 1);
            assert!(m.conversions_per_group(&p) >= 1);
            assert!(!m.peripheral_components(&cfg).is_empty());
            // every alias must round-trip through the parser
            for alias in m.aliases() {
                assert_eq!(parse_arch(alias).unwrap(), m.arch(), "{alias}");
            }
            assert_eq!(parse_arch(m.name()).unwrap(), m.arch());
        }
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(parse_arch("not-an-arch").is_err());
    }

    #[test]
    fn conversion_counts_keep_the_paper_ordering() {
        // §3.1: C converts once per group, B a handful, A every
        // (cycle, bit-column); the RAELLA-style reform keeps A's count
        // but converts at low resolution
        let p = Precision::default();
        let count = |a: Architecture| cost_model(a).conversions_per_group(&p);
        assert_eq!(count(Architecture::NeuralPim), 1);
        assert!(count(Architecture::CascadeLike) < count(Architecture::IsaacLike));
        assert_eq!(count(Architecture::LowResolution),
                   count(Architecture::IsaacLike));
        let n = 7;
        let bits = |a: Architecture| cost_model(a).adc_resolution(&p, n);
        assert!(bits(Architecture::LowResolution) < bits(Architecture::IsaacLike));
    }
}
