//! The registered [`CostModel`] implementations — one per architecture.
//!
//! Each impl binds an architecture to its §3 dataflow equations
//! (`dataflow::*_{a,b,c}`), its per-layer interface energy (the former
//! `sim::layer_energy` match arms), its PE periphery (the former
//! `energy::pe_budget` match arms), its default chip, and its Table-3
//! metadata. This file is the ONLY place in the crate that knows how the
//! architectures differ; registering a new one means writing an impl
//! here and appending it to `model::MODELS`.

use super::{CostModel, InterfaceEnergy, LayerCtx, PeMetadata};
use crate::config::{AcceleratorConfig, Architecture, Precision};
use crate::dataflow;
use crate::energy::{constants as k, ComponentBudget};
use anyhow::{bail, Result};

/// Shared IR row of the SAR-ADC-based PEs (ISAAC / CASCADE / RAELLA).
fn sar_ir_row(cfg: &AcceleratorConfig, cyc: f64) -> ComponentBudget {
    let m = cfg.arrays_per_pe as u64;
    let wl = cfg.xbar_size as u64;
    ComponentBudget {
        name: "ir",
        count: 1,
        unit_power: k::SRAM_E_BYTE * (wl * m) as f64 / cyc,
        unit_area: k::IR_AREA * m as f64 / 8.0,
    }
}

// ---------------------------------------------------------------- ISAAC --

/// Strategy A: per-conversion digital accumulation (ISAAC-style).
pub struct IsaacLikeModel;

impl CostModel for IsaacLikeModel {
    fn arch(&self) -> Architecture {
        Architecture::IsaacLike
    }

    fn name(&self) -> &'static str {
        "ISAAC-like"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["isaac", "isaac-like", "a"]
    }

    /// ISAAC-style baseline scaled to 8-bit inference (§6.1, Table 3):
    /// one 8-bit ADC per array, 1-bit DACs, digital S+A.
    fn default_config(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            arch: Architecture::IsaacLike,
            precision: Precision { p_d: 1, ..Default::default() },
            xbar_size: 128,
            arrays_per_pe: 64,
            adcs_per_pe: 64,
            sa_per_array: 0,
            pes_per_tile: 4,
            tiles: 280,
            cycle_ns: 100.0,
            edram_bytes: 64 * 1024,
            noc_concentration: 4,
        }
    }

    fn cycle_ns(&self) -> f64 {
        k::ISAAC_CYCLE_NS
    }

    fn adc_resolution(&self, p: &Precision, n: u32) -> u32 {
        dataflow::adc_resolution_a(p, n)
    }

    fn conversions_per_group(&self, p: &Precision) -> u64 {
        dataflow::conversions_a(p)
    }

    fn interface_energy(&self, ctx: &LayerCtx) -> InterfaceEnergy {
        let bits = dataflow::adc_resolution_a(ctx.p, ctx.n);
        // each of the 2*weight_cols BLs converts every cycle (Eq. 5,
        // doubled for the W+/W- pair)
        let convs = 2 * ctx.group_chunks * dataflow::conversions_a(ctx.p);
        InterfaceEnergy {
            adc: convs as f64 * k::adc_e_conv(bits),
            // one digital S+A op per conversion
            sa: convs as f64 * k::SA_DIGITAL_E_OP,
            // OR read-modify-write per conversion (steps 3/5, Fig. 3a)
            memory: convs as f64 * 2.0 * k::SRAM_E_BYTE,
            digital: 0.0,
        }
    }

    fn peripheral_components(&self, cfg: &AcceleratorConfig)
                             -> Vec<ComponentBudget> {
        let cyc = self.cycle_ns() * 1e-9;
        let m = cfg.arrays_per_pe as u64;
        let size = cfg.xbar_size;
        let adc_bits = dataflow::adc_resolution_a(&cfg.precision, cfg.n_log2());
        vec![
            ComponentBudget {
                name: "adc",
                count: cfg.adcs_per_pe as u64,
                unit_power: k::adc_e_conv(adc_bits) * (size as f64) / cyc,
                unit_area: k::adc_area(adc_bits),
            },
            ComponentBudget {
                name: "s+a",
                count: m,
                unit_power: k::SA_DIGITAL_E_OP * (size as f64) / cyc,
                unit_area: k::SA_DIGITAL_AREA,
            },
            sar_ir_row(cfg, cyc),
        ]
    }

    fn pe_metadata(&self, cfg: &AcceleratorConfig) -> PeMetadata {
        PeMetadata {
            accumulation: "Digital",
            interface: "S+A",
            // the paper's Table 3 lists 7-bit for the ISAAC-style
            // baseline (one fewer than Eq. 2's worst case, since one BL
            // level is spare); we report Eq. 2 - 1
            adc_bits: dataflow::adc_resolution_a(&cfg.precision,
                                                 cfg.n_log2()) - 1,
        }
    }

    /// ISAAC's SAR ADCs run at 1.28 GS/s [I].
    fn adc_samples_per_s(&self) -> f64 {
        1.28e9
    }
}

// -------------------------------------------------------------- CASCADE --

/// Strategy B: RRAM buffer arrays + shared ADCs (CASCADE-style).
pub struct CascadeLikeModel;

impl CostModel for CascadeLikeModel {
    fn arch(&self) -> Architecture {
        Architecture::CascadeLike
    }

    fn name(&self) -> &'static str {
        "CASCADE-like"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["cascade", "cascade-like", "b"]
    }

    /// CASCADE-style baseline (§6.1, Table 3): buffer arrays, TIAs,
    /// 3 shared 10-bit ADCs per 64 arrays, 1-bit DACs.
    fn default_config(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            arch: Architecture::CascadeLike,
            precision: Precision { p_d: 1, ..Default::default() },
            xbar_size: 128,
            arrays_per_pe: 64,
            adcs_per_pe: 3,
            sa_per_array: 0,
            pes_per_tile: 4,
            tiles: 280,
            cycle_ns: 100.0,
            edram_bytes: 64 * 1024,
            noc_concentration: 4,
        }
    }

    fn cycle_ns(&self) -> f64 {
        k::CASCADE_CYCLE_NS
    }

    fn adc_resolution(&self, p: &Precision, n: u32) -> u32 {
        dataflow::adc_resolution_b(p, n)
    }

    fn conversions_per_group(&self, p: &Precision) -> u64 {
        dataflow::conversions_b(p)
    }

    fn interface_energy(&self, ctx: &LayerCtx) -> InterfaceEnergy {
        // TIA subtracts W+/W- in analog: single-ended buffering
        let writes = ctx.group_chunks * ctx.cycles
            * ctx.p.weight_cols() as u64;
        let convs = ctx.group_chunks * dataflow::conversions_b(ctx.p);
        InterfaceEnergy {
            sa: writes as f64 * k::BUFFER_WRITE_E
                + ctx.array_cycles as f64 * k::TIA_E_CYCLE
                + convs as f64 * k::SA_DIGITAL_E_OP,
            // 10-bit nominal resolution at 8-bit-class conversion
            // energy (see constants::CASCADE_ADC_E_CONV)
            adc: convs as f64 * k::CASCADE_ADC_E_CONV,
            digital: convs as f64 * k::SUMAMP_E_CYCLE,
            memory: 0.0,
        }
    }

    fn peripheral_components(&self, cfg: &AcceleratorConfig)
                             -> Vec<ComponentBudget> {
        let cyc = self.cycle_ns() * 1e-9;
        let m = cfg.arrays_per_pe as u64;
        let size = cfg.xbar_size;
        let adc_bits = dataflow::adc_resolution_b(&cfg.precision, cfg.n_log2());
        vec![
            ComponentBudget {
                name: "adc",
                count: cfg.adcs_per_pe as u64,
                unit_power: k::adc_e_conv(adc_bits) * (size as f64) / cyc,
                unit_area: k::adc_area(adc_bits),
            },
            ComponentBudget {
                name: "buffer-array",
                count: m * k::BUFFER_ARRAYS_PER_XBAR as u64,
                unit_power: k::BUFFER_WRITE_E * (size as f64) / cyc / 4.0,
                unit_area: k::xbar_area(size),
            },
            ComponentBudget {
                name: "tia",
                count: m,
                unit_power: k::TIA_E_CYCLE / cyc,
                unit_area: k::TIA_AREA,
            },
            ComponentBudget {
                name: "sum-amp",
                count: m * k::BUFFER_ARRAYS_PER_XBAR as u64,
                unit_power: k::SUMAMP_E_CYCLE / cyc,
                unit_area: k::SUMAMP_AREA,
            },
            ComponentBudget {
                name: "s+a",
                count: m,
                unit_power: k::SA_DIGITAL_E_OP * (size as f64) / cyc / 8.0,
                unit_area: k::SA_DIGITAL_AREA,
            },
            sar_ir_row(cfg, cyc),
        ]
    }

    fn pe_metadata(&self, cfg: &AcceleratorConfig) -> PeMetadata {
        PeMetadata {
            accumulation: "Partially analog",
            interface: "S+A and buffer array",
            adc_bits: dataflow::adc_resolution_b(&cfg.precision,
                                                 cfg.n_log2()) - 1,
        }
    }

    fn adc_samples_per_s(&self) -> f64 {
        1.28e9
    }
}

// ----------------------------------------------------------- Neural-PIM --

/// Strategy C: fully-analog accumulation with NeuralPeriph circuits.
pub struct NeuralPimModel;

impl CostModel for NeuralPimModel {
    fn arch(&self) -> Architecture {
        Architecture::NeuralPim
    }

    fn name(&self) -> &'static str {
        "Neural-PIM"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["neural-pim", "neuralpim", "pim", "c"]
    }

    /// The paper's optimal Neural-PIM configuration (§7.1, Table 2):
    /// 64 128x128 arrays/PE, 4 NNADCs, 64 NNS+As, 4-bit DACs, 280 tiles.
    fn default_config(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            arch: Architecture::NeuralPim,
            precision: Precision { p_d: 4, ..Default::default() },
            xbar_size: 128,
            arrays_per_pe: 64,
            adcs_per_pe: 4,
            sa_per_array: 1,
            pes_per_tile: 4,
            tiles: 280,
            cycle_ns: 100.0,
            edram_bytes: 64 * 1024,
            noc_concentration: 4,
        }
    }

    fn validate_config(&self, cfg: &AcceleratorConfig) -> Result<()> {
        if cfg.sa_per_array == 0 {
            bail!("Neural-PIM needs at least one NNS+A per array");
        }
        Ok(())
    }

    fn cycle_ns(&self) -> f64 {
        k::NEURAL_PIM_CYCLE_NS
    }

    fn adc_resolution(&self, p: &Precision, _n: u32) -> u32 {
        dataflow::adc_resolution_c(p)
    }

    fn conversions_per_group(&self, _p: &Precision) -> u64 {
        dataflow::conversions_c()
    }

    fn sa_ops(&self, ctx: &LayerCtx) -> u64 {
        // analog accumulation: the NNS+A clocks once per input cycle of
        // every group-chunk (the same count interface_energy prices)
        ctx.group_chunks * ctx.cycles
    }

    fn interface_energy(&self, ctx: &LayerCtx) -> InterfaceEnergy {
        // one NNS+A op per group-chunk per cycle; 1 conversion per
        // group-chunk; inter-chunk combine is a cheap digital add
        let sa_ops = self.sa_ops(ctx);
        InterfaceEnergy {
            sa: sa_ops as f64 * (k::NNSA_E_OP + 2.0 * k::SH_E_OP),
            adc: ctx.group_chunks as f64 * k::NNADC_E_CONV,
            digital: ctx.group_chunks
                .saturating_sub(ctx.positions * ctx.cout) as f64
                * k::SA_DIGITAL_E_OP,
            memory: 0.0,
        }
    }

    fn peripheral_components(&self, cfg: &AcceleratorConfig)
                             -> Vec<ComponentBudget> {
        let cyc = self.cycle_ns() * 1e-9;
        let m = cfg.arrays_per_pe as u64;
        let wl = cfg.xbar_size as u64;
        let sa_count = (m * cfg.sa_per_array as u64).max(1);
        vec![
            ComponentBudget {
                name: "nnadc",
                count: cfg.adcs_per_pe as u64,
                unit_power: k::NNADC_E_CONV * 1.2e9 / 8.0, // [T2] duty cycle
                unit_area: k::NNADC_AREA,
            },
            ComponentBudget {
                name: "nns+a",
                count: sa_count,
                unit_power: k::NNSA_E_OP * 80e6, // 80 MHz [T2]
                unit_area: k::NNSA_AREA,
            },
            ComponentBudget {
                name: "s/h",
                count: sa_count * 144 / 64, // [T2]: 144 S/H per 64 NNS+A
                unit_power: k::SH_E_OP * 80e6,
                unit_area: k::SH_AREA,
            },
            ComponentBudget {
                name: "ir",
                count: 1,
                unit_power: k::SRAM_E_BYTE * (wl * m) as f64 / cyc,
                unit_area: k::NP_IR_AREA * (m as f64 / 64.0),
            },
        ]
    }

    fn pe_metadata(&self, cfg: &AcceleratorConfig) -> PeMetadata {
        PeMetadata {
            accumulation: "Analog",
            interface: "NNS+A",
            adc_bits: dataflow::adc_resolution_c(&cfg.precision),
        }
    }

    /// NNADCs convert at 1.2 GS/s [T2].
    fn adc_samples_per_s(&self) -> f64 {
        1.2e9
    }

    /// Each NNS+A serves its array's groups sequentially at 80 MHz [T2].
    fn sa_ops_per_s(&self) -> Option<f64> {
        Some(80e6)
    }
}

// ------------------------------------------------------ RAELLA-like -------

/// Reported A/D resolution of the speculative low-resolution dataflow
/// (RAELLA, Andrulis et al., ISCA 2023: center+offset weight encoding +
/// input speculation keep almost every conversion low-resolution).
pub const LOWRES_ADC_BITS: u32 = 6;

/// Fraction of conversions whose speculation misses and redoes the
/// conversion at the full Eq.-2 resolution (RAELLA reports a few percent
/// of slices needing recovery; we charge a conservative 5%).
pub const LOWRES_RECOVERY_FRAC: f64 = 0.05;

/// Per-conversion speculation check: one comparator + controller op,
/// a fraction of a digital S+A op.
pub const LOWRES_SPEC_E_OP: f64 = 0.04e-12;

/// Speculation controller area per array (comparator + mask logic),
/// roughly half a digital S+A unit.
pub const LOWRES_SPEC_AREA: f64 = 0.00012;

/// RAELLA-style fourth architecture: ISAAC's per-cycle conversion
/// dataflow, but almost every conversion happens on a low-resolution
/// ADC; mis-speculations redo at full resolution. Exists to prove the
/// cost-model layer is open — the rest of the crate learned about it
/// from the registry alone.
pub struct LowResolutionModel;

impl CostModel for LowResolutionModel {
    fn arch(&self) -> Architecture {
        Architecture::LowResolution
    }

    fn name(&self) -> &'static str {
        "RAELLA-like"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["raella", "raella-like", "lowres", "low-resolution", "d"]
    }

    /// ISAAC's organization (one converter per array, 1-bit DACs,
    /// digital S+A) with the converters swapped for low-resolution ones.
    fn default_config(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            arch: Architecture::LowResolution,
            precision: Precision { p_d: 1, ..Default::default() },
            xbar_size: 128,
            arrays_per_pe: 64,
            adcs_per_pe: 64,
            sa_per_array: 0,
            pes_per_tile: 4,
            tiles: 280,
            cycle_ns: 100.0,
            edram_bytes: 64 * 1024,
            noc_concentration: 4,
        }
    }

    /// ADC-rate-bound like ISAAC: the speculation logic sits off the
    /// conversion critical path.
    fn cycle_ns(&self) -> f64 {
        k::ISAAC_CYCLE_NS
    }

    fn adc_resolution(&self, p: &Precision, n: u32) -> u32 {
        LOWRES_ADC_BITS.min(dataflow::adc_resolution_a(p, n))
    }

    /// Same conversion count as Strategy A (Eq. 5); the recovery
    /// fraction is charged as energy, not extra scheduled conversions.
    fn conversions_per_group(&self, p: &Precision) -> u64 {
        dataflow::conversions_a(p)
    }

    fn interface_energy(&self, ctx: &LayerCtx) -> InterfaceEnergy {
        let bits_full = dataflow::adc_resolution_a(ctx.p, ctx.n);
        let bits_lo = LOWRES_ADC_BITS.min(bits_full);
        let convs = 2 * ctx.group_chunks * dataflow::conversions_a(ctx.p);
        InterfaceEnergy {
            // every conversion at low resolution + the recovery tail at
            // full Eq.-2 resolution
            adc: convs as f64
                * (k::adc_e_conv(bits_lo)
                    + LOWRES_RECOVERY_FRAC * k::adc_e_conv(bits_full)),
            // digital S+A per conversion + the speculation check
            sa: convs as f64 * (k::SA_DIGITAL_E_OP + LOWRES_SPEC_E_OP),
            // OR read-modify-write per conversion, as in Strategy A
            memory: convs as f64 * 2.0 * k::SRAM_E_BYTE,
            digital: 0.0,
        }
    }

    fn peripheral_components(&self, cfg: &AcceleratorConfig)
                             -> Vec<ComponentBudget> {
        let cyc = self.cycle_ns() * 1e-9;
        let m = cfg.arrays_per_pe as u64;
        let size = cfg.xbar_size;
        let bits = self.adc_resolution(&cfg.precision, cfg.n_log2());
        vec![
            ComponentBudget {
                name: "adc",
                count: cfg.adcs_per_pe as u64,
                unit_power: k::adc_e_conv(bits) * (size as f64) / cyc,
                unit_area: k::adc_area(bits),
            },
            ComponentBudget {
                name: "s+a",
                count: m,
                unit_power: k::SA_DIGITAL_E_OP * (size as f64) / cyc,
                unit_area: k::SA_DIGITAL_AREA,
            },
            ComponentBudget {
                name: "spec-ctrl",
                count: m,
                unit_power: LOWRES_SPEC_E_OP * (size as f64) / cyc,
                unit_area: LOWRES_SPEC_AREA,
            },
            sar_ir_row(cfg, cyc),
        ]
    }

    fn pe_metadata(&self, _cfg: &AcceleratorConfig) -> PeMetadata {
        PeMetadata {
            accumulation: "Digital (speculative)",
            interface: "S+A + recovery",
            adc_bits: LOWRES_ADC_BITS,
        }
    }

    fn adc_samples_per_s(&self) -> f64 {
        1.28e9
    }
}

// ------------------------------------------------------ Digital NPU -------

/// Energy of one 8-bit MAC in the NPU's digital lanes (multiplier +
/// accumulator register at the 32 nm class the rest of the constants
/// use). Calibrated so dense crossbar-friendly layers stay cheaper on
/// Neural-PIM (~0.43 pJ/MAC all-in) while short-K / low-reuse layers
/// (depthwise, small kernels, 1x1 bottlenecks) flip to the NPU — the
/// offload search's raison d'etre.
pub const NPU_E_MAC: f64 = 0.5e-12;

/// Area of one MAC lane (the digital replacement for one crossbar
/// array's worth of compute: `xbar_size x groups` MACs time-shared over
/// the input period).
pub const NPU_MAC_AREA: f64 = 4.5e-4;

/// Area of one lane's weight SRAM (holds `weights_per_array` bytes, the
/// same capacity a crossbar array holds in RRAM).
pub const NPU_WSRAM_AREA: f64 = 2.0e-4;

/// Headline parameter block of the digital NPU — what the `offload`
/// scenario reports and [`NpuModel::price_layer`] charges. Derived from
/// an [`AcceleratorConfig`] so DSE-style overrides (lane counts, cycle
/// time) flow through.
#[derive(Debug, Clone, Copy)]
pub struct NpuCost {
    /// peak tera-ops/s of the configured chip (2 ops per MAC, all lanes)
    pub tops_peak: f64,
    /// energy per 8-bit MAC, J
    pub e_mac: f64,
    /// weight/operand SRAM energy per byte, J (read or write)
    pub sram_e_byte: f64,
    /// weight fill + output drain latency for one lane's SRAM swap, ns.
    /// Weight-stationary execution amortizes this across the inference
    /// stream, so it is charged as *fill energy* per inference (every
    /// weight byte written once) and reported as a metric — it does not
    /// enter the steady-state pipeline bottleneck.
    pub fill_drain_ns: f64,
}

impl NpuCost {
    pub fn of(cfg: &AcceleratorConfig) -> NpuCost {
        NpuCost {
            tops_peak: cfg.peak_gops() / 1000.0,
            e_mac: NPU_E_MAC,
            sram_e_byte: k::SRAM_E_BYTE,
            fill_drain_ns: cfg.weights_per_array() as f64
                / cfg.xbar_size as f64 * cfg.cycle_ns,
        }
    }

    /// Full [`super::LayerCost`] of one mapped layer on the NPU. Mirrors
    /// the crossbar path's common terms exactly (eDRAM/SRAM activation
    /// traffic, NoC, activation post-op) so hybrid placements compare
    /// like-for-like; the conversion/crossbar/DAC terms are zero and the
    /// MAC lanes + per-K-chunk requantization + per-inference weight
    /// fill take their place.
    pub fn price_layer(&self, lm: &crate::mapping::LayerMapping,
                       _cfg: &AcceleratorConfig, multi_chip: bool)
                       -> super::LayerCost {
        let l = &lm.layer;
        let positions = l.positions();
        let k_dim = l.k_dim();
        let macs = l.macs();
        // partial-sum requantization events: one per dot-product group
        // per K-chunk (the NPU's analogue of a conversion)
        let group_chunks = positions * l.cout as u64 * lm.k_chunks;
        let out_bytes = positions as f64 * l.cout as f64;

        let sa = macs as f64 * self.e_mac;
        let mut digital = group_chunks as f64 * k::SA_DIGITAL_E_OP;
        digital += out_bytes * k::ACT_E_OP;
        // common activation traffic, identical to `layer_cost`
        let unique_in = (positions * l.stride as u64 * l.stride as u64
            * l.cin as u64) as f64;
        let replay = positions as f64 * k_dim as f64;
        let mut memory = (unique_in + out_bytes) * k::EDRAM_E_BYTE
            + (replay + out_bytes) * k::SRAM_E_BYTE;
        // weight-stationary fill: every weight byte written to lane
        // SRAM once per inference stream slot
        memory += l.weights() as f64 * self.sram_e_byte;
        let mut noc = out_bytes * k::NOC_E_BYTE;
        if multi_chip {
            noc += out_bytes * k::HT_E_BYTE;
        }

        let energy = super::EnergyBreakdown {
            adc: 0.0,
            dac: 0.0,
            sa,
            xbar: 0.0,
            memory,
            noc,
            digital,
        };
        super::LayerCost {
            compute_e: energy.total() - energy.noc,
            noc_e_extra: if multi_chip {
                lm.out_bytes() as f64 * k::HT_E_BYTE
            } else {
                0.0
            },
            adc_convs: group_chunks,
            sa_ops: macs,
            energy,
        }
    }
}

/// All-digital NPU: weight-stationary MAC lanes over SRAM-held weights,
/// no converters. Paced identically to Neural-PIM (same input cycle,
/// same lane shapes) so a hybrid placement's pipeline stages line up —
/// the offload win is purely an energy trade: the NPU loses the analog
/// A/D savings on dense layers but skips them entirely where crossbars
/// waste them (depthwise / short-K / low-reuse layers).
pub struct NpuModel;

impl CostModel for NpuModel {
    fn arch(&self) -> Architecture {
        Architecture::DigitalNpu
    }

    fn name(&self) -> &'static str {
        "Digital-NPU"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["npu", "digital-npu", "dnpu", "e"]
    }

    /// Iso-organization with the Neural-PIM chip: 64 lanes/PE, 4
    /// PEs/tile, 280 tiles, 100 ns input cycle at `p_d = 4` pacing —
    /// a placement search then compares layers like-for-like.
    fn default_config(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            arch: Architecture::DigitalNpu,
            precision: Precision { p_d: 4, ..Default::default() },
            xbar_size: 128,
            arrays_per_pe: 64,
            adcs_per_pe: 1,
            sa_per_array: 0,
            pes_per_tile: 4,
            tiles: 280,
            cycle_ns: 100.0,
            edram_bytes: 64 * 1024,
            noc_concentration: 4,
        }
    }

    fn cycle_ns(&self) -> f64 {
        k::NEURAL_PIM_CYCLE_NS
    }

    /// Requantization precision: outputs re-quantize to `p_o` bits.
    fn adc_resolution(&self, p: &Precision, _n: u32) -> u32 {
        p.p_o
    }

    /// One requantization event per dot-product group (the digital
    /// analogue of Strategy C's single conversion).
    fn conversions_per_group(&self, _p: &Precision) -> u64 {
        1
    }

    /// Not reachable through [`super::layer_cost`] — [`NpuModel`]
    /// overrides [`CostModel::price_layer`], which owns the whole layer
    /// cost. This is a best-effort upper bound from the ctx quantities
    /// (K rounded up to whole chunks) for any direct caller.
    fn interface_energy(&self, ctx: &LayerCtx) -> InterfaceEnergy {
        let macs_ub = ctx.group_chunks * ctx.cfg.xbar_size as u64;
        InterfaceEnergy {
            sa: macs_ub as f64 * NPU_E_MAC,
            adc: 0.0,
            digital: ctx.group_chunks as f64 * k::SA_DIGITAL_E_OP,
            memory: 0.0,
        }
    }

    fn price_layer(&self, lm: &crate::mapping::LayerMapping,
                   cfg: &AcceleratorConfig, multi_chip: bool)
                   -> Option<super::LayerCost> {
        Some(NpuCost::of(cfg).price_layer(lm, cfg, multi_chip))
    }

    /// Digital front-end: no crossbar or DAC rows in the PE budget.
    fn analog_frontend(&self) -> bool {
        false
    }

    fn peripheral_components(&self, cfg: &AcceleratorConfig)
                             -> Vec<ComponentBudget> {
        let cyc = self.cycle_ns() * 1e-9;
        let m = cfg.arrays_per_pe as u64;
        let ic = cfg.precision.input_cycles().max(1) as u64;
        // MACs one lane retires per cycle: its array-equivalent's
        // xbar_size x groups weights, spread over the input period
        let macs_per_cycle =
            (cfg.xbar_size as u64 * cfg.groups_per_array() / ic).max(1);
        vec![
            ComponentBudget {
                name: "mac-lane",
                count: m,
                unit_power: NPU_E_MAC * macs_per_cycle as f64 / cyc,
                unit_area: NPU_MAC_AREA,
            },
            ComponentBudget {
                name: "weight-sram",
                count: m,
                unit_power: k::SRAM_E_BYTE
                    * (cfg.xbar_size as u64 / ic) as f64 / cyc,
                unit_area: NPU_WSRAM_AREA,
            },
            ComponentBudget {
                name: "requant",
                count: m,
                unit_power: k::SA_DIGITAL_E_OP
                    * cfg.groups_per_array() as f64 / cyc,
                unit_area: k::SA_DIGITAL_AREA,
            },
            sar_ir_row(cfg, cyc),
        ]
    }

    fn pe_metadata(&self, cfg: &AcceleratorConfig) -> PeMetadata {
        PeMetadata {
            accumulation: "Digital (MAC lanes)",
            interface: "Requantize",
            adc_bits: cfg.precision.p_o,
        }
    }

    /// Requantizer throughput stands in for the converter service rate.
    fn adc_samples_per_s(&self) -> f64 {
        1.28e9
    }
}
