//! Per-layer energy computation and the memoized per-`(network, config)`
//! cost table.
//!
//! [`layer_cost`] is the single source of truth for one mapped layer's
//! per-inference energy: the architecture-independent terms (DAC,
//! crossbar, memory hierarchy, NoC, activation) plus the architecture's
//! [`CostModel::interface_energy`](super::CostModel::interface_energy).
//! `sim::layer_energy` is a thin wrapper over it.
//!
//! [`network_cost`] maps a network, prices every layer once, and caches
//! the resulting [`NetworkCost`] keyed by `(network, config)`. The
//! analytical simulator, the report/DSE paths built on it, and the event
//! simulator's replicas all share one table — the event request path
//! used to rebuild the full per-stage energy table for every replica.
//! The cache is process-global and thread-safe; entries are immutable
//! `Arc`s, so a race between two computing threads just inserts the same
//! deterministic value once.
//!
//! Since the PR-8 runtime overhaul the table is **sharded**: the key
//! hash picks one of [`SHARDS`] independent `RwLock<HashMap>` shards, so
//! the read-mostly warm path (every replica of an 8-thread serving sweep
//! hitting the same few tables) takes a shared lock on 1/16th of the
//! keyspace instead of serializing on one `Mutex`. Overflowing a shard
//! evicts its least-recently-touched entry (replacing the old engine's
//! blunt full-cache clear at `CACHE_CAP`), and `memo.hits` /
//! `memo.misses` / `memo.evictions` counters are exported into the
//! `obs` [`Registry`](crate::obs::Registry) via [`fill_cache_registry`].
//! Counters are exact under `--threads 1`; under contention a duplicate
//! computation can add an extra miss, but cached *values* are
//! deterministic either way (eviction only ever costs a recompute).

use super::{cost_model, EnergyBreakdown, LayerCtx};
use crate::config::AcceleratorConfig;
use crate::energy::constants as k;
use crate::mapping::{self, LayerMapping, NetworkMapping};
use crate::workloads::Network;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

/// Everything the simulators charge for one mapped layer, priced once.
#[derive(Debug, Clone)]
pub struct LayerCost {
    /// per-inference energy of this layer, by component class
    pub energy: EnergyBreakdown,
    /// `energy.total() - energy.noc` — what the event pipeline charges
    /// when a stage completes (it re-prices the NoC per transfer)
    pub compute_e: f64,
    /// per-transfer HyperTransport surcharge on multi-chip mappings
    pub noc_e_extra: f64,
    /// scheduled A/D conversions per inference: the pure Eq. 5/6/7
    /// dataflow count, `group_chunks x conversions_per_group` (NOT the
    /// W+/W- differential ×2 some energy models charge — this is the
    /// conversion *count* the paper's §3.1 comparison argues about)
    pub adc_convs: u64,
    /// shift-and-add ops per inference ([`super::CostModel::sa_ops`])
    pub sa_ops: u64,
}

/// The memoized cost table for one `(network, config)` pair: the mapping
/// and every layer's [`LayerCost`], plus the pre-summed total.
#[derive(Debug)]
pub struct NetworkCost {
    pub mapping: NetworkMapping,
    /// parallel to `mapping.layers`
    pub layers: Vec<LayerCost>,
    /// sum of `layers[i].energy` in layer order
    pub total: EnergyBreakdown,
}

/// Per-inference cost of ONE mapped layer. The architecture-specific
/// interface terms come from the registered cost model; everything else
/// is charged identically for every architecture.
pub fn layer_cost(lm: &LayerMapping, cfg: &AcceleratorConfig,
                  multi_chip: bool) -> LayerCost {
    let model = cost_model(cfg.arch);
    // non-crossbar architectures (the digital NPU) own the whole layer
    // cost; the crossbar dataflow below never applies to them
    if let Some(cost) = model.price_layer(lm, cfg, multi_chip) {
        return cost;
    }
    let p = &cfg.precision;
    let n = cfg.n_log2();
    let cycles = p.input_cycles() as u64;
    let rows = cfg.xbar_size as u64;
    let groups_per_array = cfg.groups_per_array();
    let l = &lm.layer;
    let positions = l.positions();
    let k_dim = l.k_dim();
    let k_chunks = lm.k_chunks;
    let c_chunks = (l.cout as u64).div_ceil(groups_per_array);
    // per inference: every sliding-window position evaluates every
    // chunk of the weight matrix once per input cycle
    let array_cycles = positions * k_chunks * c_chunks * cycles;
    // dot-product groups (output channel x K-chunk) per inference
    let group_chunks = positions * l.cout as u64 * k_chunks;

    // wordline side: drive the used rows each cycle (each c-chunk is a
    // separate array and drives its own copy of the rows)
    let dac = (positions * cycles * k_dim * c_chunks) as f64
        * k::dac_e_cycle(p.p_d);
    let xbar = array_cycles as f64 * k::xbar_e_cycle(cfg.xbar_size, p.p_d)
        * (k_dim.min(rows) as f64 / rows as f64);

    let ctx = LayerCtx {
        cfg,
        p,
        n,
        cycles,
        positions,
        cout: l.cout as u64,
        group_chunks,
        array_cycles,
    };
    let iface = model.interface_energy(&ctx);
    let adc_convs = group_chunks * model.conversions_per_group(p);
    let sa_ops = model.sa_ops(&ctx);
    let mut e = EnergyBreakdown {
        adc: iface.adc,
        dac,
        sa: iface.sa,
        xbar,
        memory: iface.memory,
        noc: 0.0,
        digital: iface.digital,
    };

    // memory hierarchy: each unique activation is read from eDRAM
    // once (ISAAC's buffer organization); the im2col replay — every
    // position re-reads its kh*kw*cin patch — is served by the SRAM
    // IR, and outputs stage through the OR on their way back.
    let unique_in = (positions * l.stride as u64 * l.stride as u64
        * l.cin as u64) as f64;
    let replay = positions as f64 * k_dim as f64;
    let out_bytes = positions as f64 * l.cout as f64;
    e.memory += (unique_in + out_bytes) * k::EDRAM_E_BYTE
        + (replay + out_bytes) * k::SRAM_E_BYTE;
    // NoC: activations cross one c-mesh hop between producer and
    // consumer tiles on average; chip-to-chip adds HyperTransport
    e.noc = out_bytes * k::NOC_E_BYTE;
    if multi_chip {
        e.noc += out_bytes * k::HT_E_BYTE;
    }
    // post-processing: activation function per output (+pool share)
    e.digital += out_bytes * k::ACT_E_OP;

    // replication multiplies the *array* activity but not the work:
    // replicas process different positions, so total counts above are
    // already per-inference. (Replication costs area, not energy.)
    LayerCost {
        compute_e: e.total() - e.noc,
        noc_e_extra: if multi_chip {
            lm.out_bytes() as f64 * k::HT_E_BYTE
        } else {
            0.0
        },
        adc_convs,
        sa_ops,
        energy: e,
    }
}

fn compute_network_cost(net: &Network, cfg: &AcceleratorConfig)
                        -> NetworkCost {
    let mapping = mapping::map_network(net, cfg);
    let multi_chip = mapping.chips > 1;
    let layers: Vec<LayerCost> = mapping
        .layers
        .iter()
        .map(|lm| layer_cost(lm, cfg, multi_chip))
        .collect();
    let mut total = EnergyBreakdown::default();
    for c in &layers {
        total.add(&c.energy);
    }
    NetworkCost { mapping, layers, total }
}

/// Cache key: every config field that feeds the cost computation plus a
/// structural fingerprint of the network (name alone is not enough —
/// `--network-file` lets callers define a runtime network under any
/// name).
#[derive(PartialEq, Eq, Hash, Clone)]
struct CostKey {
    cfg: [u64; 12],
    net_name: Arc<str>,
    net_layers: usize,
    net_fp: u64,
    /// 0 for pure single-architecture tables; hybrid tables
    /// ([`network_cost_hybrid`]) fingerprint the NPU config + per-layer
    /// placement here so they cache alongside the pure entries.
    placement_fp: u64,
}

fn cost_key(net: &Network, cfg: &AcceleratorConfig) -> CostKey {
    let p = &cfg.precision;
    let mut h = DefaultHasher::new();
    for l in &net.layers {
        l.name.hash(&mut h);
        l.kind.hash(&mut h);
        (l.kh, l.kw, l.cin, l.cout, l.out_h, l.out_w, l.stride).hash(&mut h);
    }
    CostKey {
        cfg: [
            cfg.arch as u64,
            ((p.p_i as u64) << 32) | p.p_w as u64,
            ((p.p_o as u64) << 32) | p.p_r as u64,
            p.p_d as u64,
            cfg.xbar_size as u64,
            cfg.arrays_per_pe as u64,
            ((cfg.adcs_per_pe as u64) << 32) | cfg.sa_per_array as u64,
            cfg.pes_per_tile as u64,
            cfg.tiles as u64,
            cfg.cycle_ns.to_bits(),
            cfg.edram_bytes,
            cfg.noc_concentration as u64,
        ],
        net_name: net.name.clone(),
        net_layers: net.layers.len(),
        net_fp: h.finish(),
        placement_fp: 0,
    }
}

/// Lock shards: the key hash fans lookups across this many independent
/// `RwLock`ed maps. 16 keeps per-shard scans trivial while making
/// 8-thread warm-path contention statistically negligible.
const SHARDS: usize = 16;

/// Soft bound on cached tables across all shards; a DSE-style sweep over
/// thousands of configs recycles least-recently-touched entries instead
/// of growing without limit (or, as the pre-shard cache did, clearing
/// everything on overflow).
const CACHE_CAP: usize = 512;

/// One cached table plus its last-touch tick (the eviction key).
struct CacheSlot {
    val: Arc<NetworkCost>,
    touched: AtomicU64,
}

/// The sharded, LRU-ish table. A private instance is constructible for
/// tests (the process-global one lives behind [`cache`]).
struct CostCache {
    shards: Vec<RwLock<HashMap<CostKey, CacheSlot>>>,
    per_shard_cap: usize,
    /// global touch clock; orders evictions, never values
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl CostCache {
    fn new(per_shard_cap: usize) -> CostCache {
        CostCache {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            per_shard_cap: per_shard_cap.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &CostKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    fn touch(&self, slot: &CacheSlot) {
        slot.touched
            .store(self.tick.fetch_add(1, Ordering::Relaxed) + 1,
                   Ordering::Relaxed);
    }

    /// The read-mostly fast path: a shared lock, a hit bump, done. On a
    /// miss, `compute` runs with **no lock held** (tables take far
    /// longer than the map ops, and a duplicate computation under
    /// contention is deterministic); the write lock then re-checks so a
    /// racing duplicate collapses onto whichever insert won.
    fn lookup_or(&self, key: CostKey,
                 compute: impl FnOnce() -> Arc<NetworkCost>)
                 -> Arc<NetworkCost> {
        let shard = &self.shards[self.shard_of(&key)];
        if let Some(slot) = shard.read().unwrap().get(&key) {
            self.touch(slot);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return slot.val.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let fresh = compute();
        let mut g = shard.write().unwrap();
        if let Some(slot) = g.get(&key) {
            self.touch(slot);
            return slot.val.clone();
        }
        if g.len() >= self.per_shard_cap {
            // evict the least-recently-touched entry of this shard (a
            // full scan: per-shard maps are at most CACHE_CAP/SHARDS
            // entries, far cheaper than recomputing one table)
            if let Some(victim) = g
                .iter()
                .min_by_key(|(_, s)| s.touched.load(Ordering::Relaxed))
                .map(|(k, _)| k.clone())
            {
                g.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let slot = CacheSlot { val: fresh.clone(), touched: AtomicU64::new(0) };
        self.touch(&slot);
        g.insert(key, slot);
        fresh
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }
}

fn cache() -> &'static CostCache {
    static CACHE: OnceLock<CostCache> = OnceLock::new();
    CACHE.get_or_init(|| CostCache::new(CACHE_CAP / SHARDS))
}

/// The memoized cost table for `(net, cfg)`: computed once per distinct
/// pair, then shared (the mapping is deterministic, so a cached table is
/// indistinguishable from a fresh one).
pub fn network_cost(net: &Network, cfg: &AcceleratorConfig)
                    -> Arc<NetworkCost> {
    let key = cost_key(net, cfg);
    cache().lookup_or(key, || Arc::new(compute_network_cost(net, cfg)))
}

fn compute_hybrid_cost(net: &Network, cfg_pim: &AcceleratorConfig,
                       cfg_npu: &AcceleratorConfig,
                       placement: &[mapping::Placement]) -> NetworkCost {
    let pim = network_cost(net, cfg_pim);
    let npu = network_cost(net, cfg_npu);
    assert_eq!(placement.len(), net.layers.len(),
               "placement length must match the network");
    let mut layers = Vec::with_capacity(placement.len());
    let mut lms = Vec::with_capacity(placement.len());
    for (i, pl) in placement.iter().enumerate() {
        let side = if pl.is_npu() { &npu } else { &pim };
        layers.push(side.layers[i].clone());
        lms.push(side.mapping.layers[i].clone());
    }
    let mut total = EnergyBreakdown::default();
    for c in &layers {
        total.add(&c.energy);
    }
    let mapping = NetworkMapping {
        layers: lms,
        chips: pim.mapping.chips.max(npu.mapping.chips),
        placement: placement.to_vec(),
    };
    NetworkCost { mapping, layers, total }
}

/// The memoized cost table for a **hybrid** placement: layer `i` is
/// priced (energy, mapping, stage shape) by whichever side
/// `placement[i]` names, each side priced under its own pure deployment
/// (its own mapping, replication and chip count). Cached alongside the
/// pure tables — the key is the PIM side's, extended with a fingerprint
/// of the NPU config + placement vector.
pub fn network_cost_hybrid(net: &Network, cfg_pim: &AcceleratorConfig,
                           cfg_npu: &AcceleratorConfig,
                           placement: &[mapping::Placement])
                           -> Arc<NetworkCost> {
    let mut key = cost_key(net, cfg_pim);
    let mut h = DefaultHasher::new();
    cost_key(net, cfg_npu).cfg.hash(&mut h);
    for pl in placement {
        pl.is_npu().hash(&mut h);
    }
    key.placement_fp = h.finish() | 1; // never collides with pure (0)
    cache().lookup_or(key, || {
        Arc::new(compute_hybrid_cost(net, cfg_pim, cfg_npu, placement))
    })
}

/// Drop every cached table (benchmarks use this to time the cold path).
/// Counters are monotonic and survive a clear.
pub fn clear_cost_cache() {
    cache().clear();
}

/// Number of cached `(network, config)` tables across all shards.
pub fn cost_cache_len() -> usize {
    cache().len()
}

/// Lifetime `(hits, misses, evictions)` of the process-global cache.
pub fn cost_cache_counters() -> (u64, u64, u64) {
    let c = cache();
    (
        c.hits.load(Ordering::Relaxed),
        c.misses.load(Ordering::Relaxed),
        c.evictions.load(Ordering::Relaxed),
    )
}

/// Export the cache counters into an `obs` registry (`memo.hits`,
/// `memo.misses`, `memo.evictions`, plus a `memo.entries` gauge).
/// Consumed by `perf_hotpath --only-pool` and `--verbose` diagnostics —
/// never folded into scenario outcomes, whose stored JSON must not
/// depend on process-global cache history.
pub fn fill_cache_registry(reg: &mut crate::obs::Registry) {
    let (h, m, e) = cost_cache_counters();
    reg.add("memo.hits", h);
    reg.add("memo.misses", m);
    reg.add("memo.evictions", e);
    reg.gauge_max("memo.entries", cost_cache_len() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn memoized_table_matches_direct_computation() {
        let net = workloads::alexnet();
        for arch in super::super::archs() {
            let cfg = AcceleratorConfig::for_arch(arch);
            let nc = network_cost(&net, &cfg);
            let direct = compute_network_cost(&net, &cfg);
            assert_eq!(nc.layers.len(), direct.layers.len());
            assert_eq!(nc.total, direct.total, "{arch:?}");
            for (a, b) in nc.layers.iter().zip(&direct.layers) {
                assert_eq!(a.energy, b.energy);
                assert_eq!(a.compute_e.to_bits(), b.compute_e.to_bits());
                assert_eq!(a.noc_e_extra.to_bits(), b.noc_e_extra.to_bits());
                assert_eq!(a.adc_convs, b.adc_convs);
                assert_eq!(a.sa_ops, b.sa_ops);
            }
        }
    }

    // NOTE: lib tests run concurrently and the cache is process-global,
    // so these assertions avoid absolute cache-length counts and never
    // clear the cache (only benches do); sharing/distinctness via
    // `Arc::ptr_eq` is stable because nothing else evicts entries.
    #[test]
    fn cache_shares_hits_and_separates_distinct_keys() {
        let net = workloads::mobilenet_v2();
        let np = AcceleratorConfig::neural_pim();
        let a = network_cost(&net, &np);
        let b = network_cost(&net, &np);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        assert!(cost_cache_len() >= 1);
        // a different config is a different entry
        let isaac = AcceleratorConfig::isaac_like();
        let c = network_cost(&net, &isaac);
        assert!(!Arc::ptr_eq(&a, &c));
        // same name, different shape -> different entry (the fingerprint
        // protects runtime-defined networks)
        let mut other = workloads::mobilenet_v2();
        other.layers.pop();
        let d = network_cost(&other, &np);
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(d.layers.len(), a.layers.len() - 1);
    }

    #[test]
    fn conversion_counts_follow_the_dataflow_equations() {
        use crate::config::Architecture;
        let net = workloads::alexnet();
        let per_net = |arch: Architecture| -> (u64, u64) {
            let cfg = AcceleratorConfig::for_arch(arch);
            let nc = network_cost(&net, &cfg);
            let model = super::super::cost_model(arch);
            let mut convs = 0u64;
            let mut sa = 0u64;
            for (lm, cost) in nc.mapping.layers.iter().zip(&nc.layers) {
                // the count is exactly group_chunks x Eq. 5/6/7
                let groups = lm.layer.positions()
                    * lm.layer.cout as u64
                    * lm.k_chunks;
                assert_eq!(
                    cost.adc_convs,
                    groups * model.conversions_per_group(&cfg.precision),
                    "{arch:?}/{}", lm.layer.name
                );
                convs += cost.adc_convs;
                sa += cost.sa_ops;
            }
            (convs, sa)
        };
        let (isaac, _) = per_net(Architecture::IsaacLike);
        let (cascade, _) = per_net(Architecture::CascadeLike);
        let (pim, pim_sa) = per_net(Architecture::NeuralPim);
        // §3.1 ordering: Neural-PIM converts once per group
        assert!(pim < cascade && cascade < isaac, "{pim} {cascade} {isaac}");
        // analog accumulation still clocks the NNS+A every input cycle
        assert!(pim_sa > pim);
    }

    /// Synthetic key `i` (distinct hash, cheap to mint in bulk).
    fn key(i: u64) -> CostKey {
        CostKey {
            cfg: [i, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0],
            net_name: format!("synthetic-{i}").into(),
            net_layers: 1,
            net_fp: i.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            placement_fp: 0,
        }
    }

    #[test]
    fn shard_overflow_evicts_least_recently_touched() {
        // private instance: the process-global cache is shared by
        // concurrently-running tests and must never be force-evicted
        let c = CostCache::new(2);
        let table =
            Arc::new(compute_network_cost(&workloads::synthetic_cnn(),
                                          &AcceleratorConfig::neural_pim()));
        // three keys landing in one shard
        let mut same: Vec<u64> = vec![0];
        let shard0 = c.shard_of(&key(0));
        let mut i = 1;
        while same.len() < 3 {
            if c.shard_of(&key(i)) == shard0 {
                same.push(i);
            }
            i += 1;
        }
        let (a, b, d) = (same[0], same[1], same[2]);
        c.lookup_or(key(a), || table.clone());
        c.lookup_or(key(b), || table.clone());
        // touch `a` so `b` is now the least-recently-used entry
        c.lookup_or(key(a), || unreachable!("a must hit"));
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        // inserting a third key overflows the 2-entry shard: `b` goes
        c.lookup_or(key(d), || table.clone());
        assert_eq!(c.evictions.load(Ordering::Relaxed), 1);
        c.lookup_or(key(a), || unreachable!("touched entry evicted"));
        let misses_before = c.misses.load(Ordering::Relaxed);
        c.lookup_or(key(b), || table.clone()); // recomputed: was evicted
        assert_eq!(c.misses.load(Ordering::Relaxed), misses_before + 1);
    }

    #[test]
    fn capacity_is_bounded_per_shard() {
        let c = CostCache::new(1);
        let table =
            Arc::new(compute_network_cost(&workloads::synthetic_cnn(),
                                          &AcceleratorConfig::neural_pim()));
        for i in 0..200 {
            c.lookup_or(key(i), || table.clone());
        }
        assert!(c.len() <= SHARDS, "len {} exceeds 1-per-shard cap", c.len());
        assert!(c.evictions.load(Ordering::Relaxed) >= 200 - SHARDS as u64);
    }

    #[test]
    fn global_counters_are_monotonic_and_hits_grow_on_reuse() {
        let net = workloads::googlenet();
        let cfg = AcceleratorConfig::neural_pim();
        let _ = network_cost(&net, &cfg);
        let (h0, m0, _) = cost_cache_counters();
        let _ = network_cost(&net, &cfg);
        let (h1, m1, _) = cost_cache_counters();
        assert!(h1 > h0, "warm lookup must count a hit ({h0} -> {h1})");
        assert!(m1 >= m0);
        let mut reg = crate::obs::Registry::default();
        fill_cache_registry(&mut reg);
        assert!(reg.counter("memo.hits") >= h1);
        assert!(reg.counter("memo.misses") >= 1);
    }

    #[test]
    fn total_is_the_sum_of_layer_energies() {
        let net = workloads::vgg16();
        let cfg = AcceleratorConfig::cascade_like();
        let nc = network_cost(&net, &cfg);
        let mut want = EnergyBreakdown::default();
        for c in &nc.layers {
            want.add(&c.energy);
        }
        assert_eq!(nc.total, want);
        for c in &nc.layers {
            let direct = c.energy.total() - c.energy.noc;
            assert!((c.compute_e - direct).abs() <= direct.abs() * 1e-12);
        }
    }
}
