//! Rust-side NeuralPeriph evaluation: loads the trained weights from
//! `artifacts/periph.json`, runs the f32 MLP/flash forwards natively, and
//! measures the Table-1 metrics (approximation error, DNL/INL, ENOB)
//! without any Python in the loop.

use crate::arch::{V_RANGE, VDD};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// A trained NNS+A: 9-input 3-layer MLP with inverter-VTC activations.
#[derive(Debug, Clone)]
pub struct NnsA {
    pub w1: Vec<f32>, // 9 x h, row-major
    pub b1: Vec<f32>,
    pub w2: Vec<f32>, // h x 1
    pub b2: f32,
    pub hidden: usize,
    pub vtc_gain: f64,
}

/// A trained flash NNADC: per-comparator thresholds + unit summing column.
#[derive(Debug, Clone)]
pub struct Nnadc {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub vm: Vec<f32>,
    pub latch_gain: f64,
    pub n_bits: u32,
}

/// Everything in periph.json.
#[derive(Debug, Clone)]
pub struct Periph {
    pub nns_a: NnsA,
    pub nns_a_msb: NnsA,
    pub nnadc: Nnadc,
    pub nnadc_naive: Nnadc,
    pub metrics: Json,
}

fn vtc(v: f64, vm: f64, gain: f64) -> f64 {
    // numerically-stable falling sigmoid
    let x = -gain * (v - vm);
    VDD / (1.0 + (-x).exp())
}

impl NnsA {
    fn from_json(j: &Json, gain: f64) -> Result<NnsA> {
        let (s1, w1) = j
            .get("w1")
            .and_then(Json::to_f32_tensor_opt)
            .ok_or_else(|| anyhow!("missing w1"))?;
        let (_, b1) = j
            .get("b1")
            .and_then(Json::to_f32_tensor_opt)
            .ok_or_else(|| anyhow!("missing b1"))?;
        let (_, w2) = j
            .get("w2")
            .and_then(Json::to_f32_tensor_opt)
            .ok_or_else(|| anyhow!("missing w2"))?;
        let (_, b2) = j
            .get("b2")
            .and_then(Json::to_f32_tensor_opt)
            .ok_or_else(|| anyhow!("missing b2"))?;
        anyhow::ensure!(s1[0] == 9, "NNS+A must have 9 inputs");
        Ok(NnsA { hidden: s1[1], w1, b1, w2, b2: b2[0], vtc_gain: gain })
    }

    /// Single forward: v_in has 9 entries (8 BL pairs + carried sum).
    pub fn forward(&self, v_in: &[f64; 9], vm: f64) -> f64 {
        let h = self.hidden;
        let mut out = self.b2 as f64;
        for j in 0..h {
            let mut pre = self.b1[j] as f64;
            for (k, v) in v_in.iter().enumerate() {
                pre += self.w1[k * h + j] as f64 * v;
            }
            out += self.w2[j] as f64 * vtc(pre, vm, self.vtc_gain);
        }
        out
    }

    /// Cyclic application over LSB-first slices (the S/H loop).
    pub fn accumulate(&self, slices: &[[f64; 8]], vm: f64) -> f64 {
        let mut acc = 0.0;
        for s in slices {
            let mut vin = [0.0f64; 9];
            vin[..8].copy_from_slice(s);
            vin[8] = acc;
            acc = self.forward(&vin, vm);
        }
        acc
    }
}

impl Nnadc {
    fn from_json(j: &Json, latch_gain: f64) -> Result<Nnadc> {
        let grab = |key: &str| -> Result<Vec<f32>> {
            Ok(j.get(key)
                .and_then(Json::to_f32_tensor_opt)
                .ok_or_else(|| anyhow!("missing {key}"))?
                .1)
        };
        let w1 = grab("w1")?;
        let b1 = grab("b1")?;
        let w2 = grab("w2")?;
        let vm = grab("vm").unwrap_or_else(|_| vec![(VDD / 2.0) as f32; w1.len()]);
        anyhow::ensure!(w1.len() == b1.len() && w1.len() == w2.len());
        Ok(Nnadc { w1, b1, w2, vm, latch_gain, n_bits: 8 })
    }

    /// Convert a normalized input in [0, 1] to a code in [0, 2^n - 1].
    pub fn convert(&self, v: f64) -> u32 {
        let mut soft = 0.0f64;
        for i in 0..self.w1.len() {
            let pre = self.w1[i] as f64 * v + self.b1[i] as f64;
            let u = 1.0 - vtc(pre, self.vm[i] as f64, self.latch_gain) / VDD;
            soft += self.w2[i] as f64 * u;
        }
        let levels = (1u32 << self.n_bits) - 1;
        ((soft * levels as f64).round().clamp(0.0, levels as f64)) as u32
    }

    /// Ramp transfer curve.
    pub fn transfer(&self, n_points: usize) -> Vec<(f64, u32)> {
        (0..n_points)
            .map(|i| {
                let v = i as f64 / (n_points - 1) as f64;
                (v, self.convert(v))
            })
            .collect()
    }
}

/// DNL/INL in LSB from a ramp sweep (mirrors train_periph.dnl_inl).
pub fn dnl_inl(transfer: &[(f64, u32)], n_bits: u32)
               -> (Vec<f64>, Vec<f64>, usize) {
    let n_codes = 1usize << n_bits;
    let lsb = 1.0 / (n_codes as f64 - 1.0);
    let mut transitions = vec![f64::NAN; n_codes - 1];
    for w in transfer.windows(2) {
        let (v1, c1) = w[1];
        let (_, c0) = w[0];
        if c1 > c0 {
            for c in (c0 as usize)..(c1 as usize).min(n_codes - 1) {
                if transitions[c].is_nan() {
                    transitions[c] = v1;
                }
            }
        }
    }
    let mut dnl = Vec::new();
    let mut inl = Vec::new();
    let mut missing = 0;
    for (i, t) in transitions.iter().enumerate() {
        if t.is_nan() {
            missing += 1;
            continue;
        }
        let ideal = (i as f64 + 0.5) * lsb;
        inl.push((t - ideal) / lsb);
        if i > 0 && !transitions[i - 1].is_nan() {
            dnl.push((t - transitions[i - 1]) / lsb - 1.0);
        }
    }
    (dnl, inl, missing)
}

/// Sine-test ENOB: (SINAD - 1.76) / 6.02.
pub fn enob(adc: &Nnadc, n_samples: usize) -> (f64, f64) {
    let n_bits = adc.n_bits;
    let mut sig = Vec::with_capacity(n_samples);
    let mut rec = Vec::with_capacity(n_samples);
    for i in 0..n_samples {
        let v = 0.5
            + 0.4999
                * (2.0 * std::f64::consts::PI * 127.0 * i as f64
                    / n_samples as f64)
                    .sin();
        sig.push(v);
        rec.push(adc.convert(v) as f64 / ((1u32 << n_bits) - 1) as f64);
    }
    let err: Vec<f64> = rec.iter().zip(&sig).map(|(r, s)| r - s).collect();
    let me = crate::util::stats::mean(&err);
    let p_noise = err.iter().map(|e| (e - me) * (e - me)).sum::<f64>()
        / err.len() as f64;
    let ms = crate::util::stats::mean(&sig);
    let p_sig =
        sig.iter().map(|s| (s - ms) * (s - ms)).sum::<f64>() / sig.len() as f64;
    let sinad = 10.0 * (p_sig / p_noise).log10();
    ((sinad - 1.76) / 6.02, sinad)
}

impl Periph {
    pub fn load(path: &str) -> Result<Periph> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let consts = j.get("constants").ok_or_else(|| anyhow!("no constants"))?;
        let g_tt = consts.get("vtc_gain_tt").and_then(Json::as_f64).unwrap_or(25.0);
        let g_latch = consts
            .get("vtc_gain_latch")
            .and_then(Json::as_f64)
            .unwrap_or(2400.0);
        Ok(Periph {
            nns_a: NnsA::from_json(
                j.get("nns_a_opt").ok_or_else(|| anyhow!("no nns_a_opt"))?, g_tt)?,
            nns_a_msb: NnsA::from_json(
                j.get("nns_a_msb").ok_or_else(|| anyhow!("no nns_a_msb"))?, g_tt)?,
            nnadc: Nnadc::from_json(
                j.get("nnadc_opt").ok_or_else(|| anyhow!("no nnadc_opt"))?,
                g_latch)?,
            nnadc_naive: Nnadc::from_json(
                j.get("nnadc_naive").ok_or_else(|| anyhow!("no nnadc_naive"))?,
                g_latch)?,
            metrics: j.get("metrics").cloned().unwrap_or(Json::Null),
        })
    }

    /// NNS+A approximation error vs the ideal recursion over random
    /// (differential) BL voltages — the Table 1 max/min error row.
    pub fn nns_a_error_stats(&self, n: usize, seed: u64) -> (f64, f64, f64) {
        let mut rng = crate::util::rng::Pcg::new(seed);
        let alpha = crate::arch::sa_alpha(4);
        let mut errs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut vin = [0.0f64; 9];
            for v in vin.iter_mut().take(8) {
                *v = rng.range(-V_RANGE / 2.0, V_RANGE / 2.0);
            }
            vin[8] = rng.range(-V_RANGE / 2.0, V_RANGE / 2.0);
            let got = self.nns_a.forward(&vin, VDD / 2.0);
            let sum: f64 = (0..8usize)
                .map(|j| 2f64.powi(j as i32) * vin[j])
                .sum();
            let want = 2f64.powi(-4) * vin[8] + sum / alpha;
            errs.push(got - want);
        }
        let mse = errs.iter().map(|e| e * e).sum::<f64>() / n as f64;
        (mse, crate::util::stats::max(&errs), crate::util::stats::min(&errs))
    }
}

// small helper so Option-chaining reads well above
trait TensorOpt {
    fn to_f32_tensor_opt(&self) -> Option<(Vec<usize>, Vec<f32>)>;
}

impl TensorOpt for Json {
    fn to_f32_tensor_opt(&self) -> Option<(Vec<usize>, Vec<f32>)> {
        self.to_f32_tensor()
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    fn ideal_adc() -> Nnadc {
        let levels = 255usize;
        let t: Vec<f64> =
            (1..=levels).map(|k| (k as f64 - 0.5) / levels as f64).collect();
        Nnadc {
            w1: vec![0.9; levels],
            b1: t.iter().map(|ti| (VDD / 2.0 - 0.9 * ti) as f32).collect(),
            w2: vec![(1.0 / levels as f64) as f32; levels],
            vm: vec![(VDD / 2.0) as f32; levels],
            latch_gain: 2400.0,
            n_bits: 8,
        }
    }

    #[test]
    fn ideal_flash_bank_is_8_bit_clean() {
        let adc = ideal_adc();
        let tr = adc.transfer(1 << 13);
        let (dnl, inl, missing) = dnl_inl(&tr, 8);
        assert_eq!(missing, 0);
        assert!(dnl.iter().all(|d| d.abs() < 0.1), "DNL {:?}",
                dnl.iter().cloned().fold(0.0f64, f64::max));
        assert!(inl.iter().all(|d| d.abs() < 0.1));
        let (e, _) = enob(&adc, 1 << 13);
        assert!(e > 7.7 && e < 8.3, "enob {e}");
    }

    #[test]
    fn transfer_monotone() {
        let adc = ideal_adc();
        let tr = adc.transfer(4096);
        assert!(tr.windows(2).all(|w| w[1].1 >= w[0].1));
        assert_eq!(tr[0].1, 0);
        assert_eq!(tr.last().unwrap().1, 255);
    }

    #[test]
    fn dnl_detects_missing_code() {
        // collapse two thresholds onto each other -> a missing code
        let mut adc = ideal_adc();
        adc.b1[100] = adc.b1[101];
        let tr = adc.transfer(1 << 13);
        let (_, _, missing) = dnl_inl(&tr, 8);
        // transitions 100/101 now coincide: code 101 skipped over
        assert!(missing <= 1); // both map to same v: first-wins fills one
        let (dnl, _, _) = dnl_inl(&tr, 8);
        assert!(dnl.iter().cloned().fold(f64::MIN, f64::max) > 0.8);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/periph.json");
        if !std::path::Path::new(path).exists() {
            return; // artifacts not built in this environment
        }
        let p = Periph::load(path).unwrap();
        assert_eq!(p.nns_a.w1.len(), 9 * p.nns_a.hidden);
        let (mse, emax, emin) = p.nns_a_error_stats(4096, 7);
        assert!(mse < 1e-3, "mse {mse}");
        assert!(emax < 0.1 && emin > -0.1);
        let tr = p.nnadc.transfer(1 << 12);
        let (_, inl, missing) = dnl_inl(&tr, 8);
        assert!(missing < 8, "missing {missing}");
        assert!(inl.iter().all(|d| d.abs() < 3.0));
    }
}
